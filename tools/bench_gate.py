#!/usr/bin/env python
"""Bench regression gate: compare a fresh ``make bench-fast`` run against the
committed ``BENCH_fit.json`` / ``BENCH_loop.json`` / ``BENCH_fleet.json`` /
``BENCH_serve.json`` / ``BENCH_pipeline.json`` / ``BENCH_transfer.json``.

The committed artifacts were produced on a different machine than CI, so raw
timings are not directly comparable.  The gate is *schema-aware* and
*median-calibrated*: per artifact it computes the ratio fresh/committed for
every comparable timing, takes the median ratio as the machine-speed factor,
and flags any timing whose ratio deviates from that median by more than the
tolerance (default 35%).  A uniform slowdown (slower runner) calibrates away;
a single regressed benchmark (e.g. an injected 10x slowdown in one group)
sticks out and fails the gate.

Hard failures, independent of any tolerance:

- a committed key missing from the fresh run (a benchmark silently dropped),
- ``identical_trees: false`` anywhere (the engines diverged — correctness),
  including the threaded-fit rows (threads=N vs threads=1 divergence),
- ``topk_match: false`` on a mega-grid recommend row (the chunked scorer
  and the numpy oracle disagree on the winners), or a committed mega-grid
  speedup below 1.5x over the argpartition path,
- a committed threaded-fit speedup below 1.5x when the row was recorded on
  >= 2 cores with working native kernels,
- fleet collector failures or non-finite/zero timings in the fresh run,
- any nonzero ``corrupt_lines`` / ``quarantined`` / ``n_quarantined``
  counter anywhere in an artifact (committed or fresh): benchmark numbers
  must come from clean data — a run that silently skipped corrupt records
  or quarantined cases measured a different workload.

Usage (CI runs this right after ``make bench-fast``, which leaves the fresh
artifacts in ``/tmp/repro_io/bench_fast``):

    python tools/bench_gate.py --fresh /tmp/repro_io/bench_fast
    python tools/bench_gate.py --fresh DIR --tolerance 0.75   # noisy runners

Exit code 0 = gate passed, 1 = regression/hard failure, 2 = usage error.
"""

from __future__ import annotations

import argparse
import json
import math
import pathlib
import sys
from typing import Dict, List, Optional, Tuple

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent

# (artifact file, loader producing {key: (fresh_value, committed_value)} plus
# hard failures) — one comparator per artifact schema.
ARTIFACTS = ("BENCH_fit.json", "BENCH_loop.json", "BENCH_fleet.json",
             "BENCH_serve.json", "BENCH_pipeline.json", "BENCH_transfer.json")

# The rows a fast (`make bench-fast`) run is REQUIRED to produce.  A fresh
# run missing one of these means a benchmark silently stopped running —
# a hard failure at any tolerance.  Changing the fast-mode bench set is
# intentional friction: update this list in the same commit.
EXPECTED_FAST_FIT_KEYS = (
    "gbt_paper_n141",
    "gbt_paper_n1024",
    "rf_paper_d10_n141",
    "rf_paper_n1024_b100",
)
# Threaded-fit rows the fast run must produce (BENCH_fit.json "threads"
# section: REPRO_NATIVE_THREADS=1 vs =N on the batched engine).
EXPECTED_FAST_THREAD_KEYS = ("rf_paper_n1024_b100",)
# Mega-grid recommend rows the fast run must produce ("recommend" section).
EXPECTED_FAST_MEGA_KEYS = ("xgboost_mega_1e5",)
# Committed-artifact claims for the two PR-10 speedups.  The threaded floor
# applies only to rows recorded with cores >= 2 and working native kernels —
# a single-core recorder proves bit-exactness (identical_trees), while CI's
# multi-core runners supply fresh multi-thread evidence every push.  The
# mega-grid floor is unconditional: the chunked scorer's win over the
# monolithic argpartition path is algorithmic (cache-resident intermediates),
# not a core-count artifact.
MIN_COMMITTED_THREAD_SPEEDUP = 1.5
MIN_COMMITTED_MEGA_SPEEDUP = 1.5
EXPECTED_FAST_FLEET_COLLECTORS = (1, 2)
EXPECTED_FAST_LOOP_CYCLES = 2  # per track
# Every (endpoint x mode x client-count) QPS row the serve bench must
# produce; a dropped row means a load point silently stopped being measured.
EXPECTED_SERVE_ENDPOINTS = ("predict", "recommend")
EXPECTED_SERVE_MODES = ("batched", "unbatched")
EXPECTED_SERVE_CLIENTS = (1, 8, 32)
# The serving tier's headline claim, enforced on the COMMITTED artifact: at
# 32 concurrent clients, micro-batched scoring must deliver >= 2x the QPS of
# the unbatched baseline on at least one endpoint (and never lose on any).
MIN_COMMITTED_SERVE_SPEEDUP_C32 = 2.0
# Every (backend, workers, policy) stall row the fast pipeline bench must
# produce; the clairvoyant prefetcher's headline claim, enforced on the
# COMMITTED artifact: on at least one simulated-storage case, walking the
# known epoch schedule ahead must cut stall time >= 1.5x vs depth prefetch.
EXPECTED_FAST_PIPELINE_KEYS = tuple(
    f"network_sim.w1.{p}" for p in ("off", "depth", "clairvoyant")
)
MIN_COMMITTED_PIPELINE_STALL_REDUCTION = 1.5
# Every held-out backend fold the fast transfer bench must produce (the
# fast synthetic track covers all four simulated backends on purpose); the
# calibration headline claim, enforced on the COMMITTED artifact: on at
# least one held-out backend, a k<=25 few-shot affine calibration must cut
# the zero-shot MAPE >= 1.5x.
EXPECTED_FAST_TRANSFER_FOLDS = ("disk", "network_sim", "object_sim", "tmpfs")
MIN_COMMITTED_TRANSFER_REDUCTION = 1.5
# Data-integrity counters: nonzero anywhere in an artifact is a hard failure
# (the run measured corrupt/quarantined data); absent keys pass (artifacts
# recorded before the counters existed).
INTEGRITY_KEYS = ("corrupt_lines", "quarantined", "n_quarantined")


class Gate:
    def __init__(self, tolerance: float, min_ms: float):
        self.tolerance = tolerance
        self.min_ms = min_ms
        self.hard: List[str] = []
        self.soft: List[str] = []
        self.compared = 0
        self.skipped = 0

    # -- helpers ---------------------------------------------------------
    def hard_fail(self, msg: str) -> None:
        self.hard.append(msg)

    def compare_timings(
        self, label: str, pairs: Dict[str, Tuple[float, float]]
    ) -> None:
        """Median-calibrated comparison of fresh vs committed timings."""
        ratios = {}
        for key, (fresh, committed) in pairs.items():
            if not (math.isfinite(fresh) and fresh > 0):
                self.hard_fail(f"{label}: {key} fresh timing is {fresh!r}")
                continue
            if not (math.isfinite(committed) and committed > 0):
                self.skipped += 1
                continue
            if committed * 1e3 < self.min_ms and fresh * 1e3 < self.min_ms:
                self.skipped += 1  # sub-millisecond noise
                continue
            ratios[key] = fresh / committed
        if len(ratios) < 2:
            return
        med = sorted(ratios.values())[len(ratios) // 2]
        lo, hi = 1.0 / (1.0 + self.tolerance), 1.0 + self.tolerance
        for key, r in sorted(ratios.items()):
            rel = r / med
            self.compared += 1
            if rel > hi:
                self.soft.append(
                    f"{label}: {key} is {rel:.2f}x slower than this run's "
                    f"baseline (fresh/committed={r:.2f}, machine factor "
                    f"{med:.2f}, tolerance {self.tolerance:.0%})"
                )
            elif rel < lo:
                # faster-than-baseline outliers are informational only
                pass

    def check_integrity(self, name: str, art: object, side: str) -> None:
        """Recursive scan for nonzero corruption/quarantine counters.

        Any ``corrupt_lines``/``quarantined``/``n_quarantined`` value != 0,
        at any nesting depth, is a hard failure at any tolerance; artifacts
        that predate the counters simply don't have the keys and pass."""
        def walk(node: object, path: str) -> None:
            if isinstance(node, dict):
                for k, v in node.items():
                    p = f"{path}.{k}" if path else str(k)
                    if (k in INTEGRITY_KEYS and isinstance(v, (int, float))
                            and v):
                        self.hard_fail(
                            f"{name}: {side} artifact reports {p}={v} — "
                            f"benchmark ran over corrupt/quarantined data"
                        )
                    else:
                        walk(v, p)
            elif isinstance(node, list):
                for i, v in enumerate(node):
                    walk(v, f"{path}[{i}]")
        walk(art, "")

    # -- per-artifact schemas -------------------------------------------
    def check_fit(self, fresh: dict, committed: dict) -> None:
        pairs: Dict[str, Tuple[float, float]] = {}
        cfit = committed.get("fit", {})
        ffit = fresh.get("fit", {})
        for key in EXPECTED_FAST_FIT_KEYS:
            if key not in ffit:
                self.hard_fail(
                    f"fit: fast run is required to produce {key!r} but did not "
                    f"(benchmark silently dropped?)"
                )
        for key, crow in cfit.items():
            frow = ffit.get(key)
            if frow is None:
                # full-run-only keys (e.g. n=10^4 rows) are not required here
                continue
            if frow.get("n") != crow.get("n") or frow.get("estimators") != crow.get("estimators"):
                self.hard_fail(
                    f"fit: {key} config drifted "
                    f"(fresh n={frow.get('n')} est={frow.get('estimators')}, "
                    f"committed n={crow.get('n')} est={crow.get('estimators')})"
                )
                continue
            for field in ("batched_s", "level_s", "reference_s"):
                if field in crow and field in frow:
                    pairs[f"{key}.{field}"] = (frow[field], crow[field])
        if not ffit:
            self.hard_fail("fit: fresh run produced no fit rows")
        for key, frow in ffit.items():
            if frow.get("identical_trees") is False:
                self.hard_fail(f"fit: {key} identical_trees is false (fresh)")
        for key, crow in cfit.items():
            if crow.get("identical_trees") is False:
                self.hard_fail(f"fit: {key} identical_trees is false (committed)")
        for key, crow in committed.get("recommend", {}).items():
            frow = fresh.get("recommend", {}).get(key)
            if frow is not None:
                pairs[f"recommend.{key}.best_ms"] = (
                    frow["best_ms"] / 1e3, crow["best_ms"] / 1e3
                )
                if "argpartition_ms" in crow and "argpartition_ms" in frow:
                    pairs[f"recommend.{key}.argpartition_ms"] = (
                        frow["argpartition_ms"] / 1e3,
                        crow["argpartition_ms"] / 1e3,
                    )
        self._check_fit_threads(fresh, committed, pairs)
        self._check_fit_mega(fresh, committed)
        self.compare_timings("fit", pairs)

    def _check_fit_threads(
        self, fresh: dict, committed: dict,
        pairs: Dict[str, Tuple[float, float]],
    ) -> None:
        """Threaded-fit rows: dropped row / divergence / committed speedup."""
        fthr = fresh.get("threads", {})
        cthr = committed.get("threads", {})
        for key in EXPECTED_FAST_THREAD_KEYS:
            if key not in fthr:
                self.hard_fail(
                    f"fit: fast run is required to produce threads row {key!r} "
                    f"but did not (threaded benchmark silently dropped?)"
                )
        for side, rows in (("fresh", fthr), ("committed", cthr)):
            for key, row in rows.items():
                if row.get("identical_trees") is False:
                    self.hard_fail(
                        f"fit: threads.{key} identical_trees is false ({side}) "
                        f"— threaded fit diverged from single-threaded"
                    )
        if not cthr:
            self.hard_fail(
                "fit: committed artifact has no threads rows — the "
                "threaded-fit claim is not recorded"
            )
        for key, crow in cthr.items():
            cores = crow.get("cores", 1)
            sp = crow.get("speedup_threads")
            if (crow.get("native") and isinstance(cores, int) and cores >= 2
                    and isinstance(sp, (int, float))
                    and sp < MIN_COMMITTED_THREAD_SPEEDUP):
                self.hard_fail(
                    f"fit: committed threads.{key} speedup is {sp}x on "
                    f"{cores} cores — below the required "
                    f"{MIN_COMMITTED_THREAD_SPEEDUP}x"
                )
            frow = fthr.get(key)
            if frow is None:
                continue
            if (frow.get("n") != crow.get("n")
                    or frow.get("estimators") != crow.get("estimators")
                    or frow.get("threads") != crow.get("threads")):
                self.hard_fail(
                    f"fit: threads.{key} config drifted "
                    f"(fresh n={frow.get('n')} est={frow.get('estimators')} "
                    f"threads={frow.get('threads')}, committed "
                    f"n={crow.get('n')} est={crow.get('estimators')} "
                    f"threads={crow.get('threads')})"
                )
                continue
            for field in ("t1_s", "tN_s"):
                if field in crow and field in frow:
                    pairs[f"threads.{key}.{field}"] = (frow[field], crow[field])

    def _check_fit_mega(self, fresh: dict, committed: dict) -> None:
        """Mega-grid recommend rows: dropped row / top-k mismatch / speedup."""
        frec = fresh.get("recommend", {})
        crec = committed.get("recommend", {})
        for key in EXPECTED_FAST_MEGA_KEYS:
            if key not in frec:
                self.hard_fail(
                    f"fit: fast run is required to produce recommend row "
                    f"{key!r} but did not (mega-grid benchmark silently "
                    f"dropped?)"
                )
        mega = lambda rows: {k: r for k, r in rows.items()
                             if "speedup_mega" in r or "topk_match" in r}
        for side, rows in (("fresh", mega(frec)), ("committed", mega(crec))):
            for key, row in rows.items():
                if row.get("topk_match") is False:
                    self.hard_fail(
                        f"fit: recommend.{key} topk_match is false ({side}) — "
                        f"the chunked scorer picked a different top-k than "
                        f"the numpy oracle"
                    )
        cmega = mega(crec)
        if not cmega:
            self.hard_fail(
                "fit: committed artifact has no mega-grid recommend row — "
                "the chunked-scorer claim is not recorded"
            )
        for key, crow in cmega.items():
            sp = crow.get("speedup_mega")
            if not (isinstance(sp, (int, float))
                    and sp >= MIN_COMMITTED_MEGA_SPEEDUP):
                self.hard_fail(
                    f"fit: committed recommend.{key} mega-grid speedup is "
                    f"{sp!r} — below the required "
                    f"{MIN_COMMITTED_MEGA_SPEEDUP}x over the argpartition path"
                )
        for key, frow in mega(frec).items():
            sp = frow.get("speedup_mega")
            if isinstance(sp, (int, float)) and sp < 1.2:
                self.soft.append(
                    f"fit: fresh recommend.{key} mega-grid speedup is {sp}x "
                    f"(committed artifact promises "
                    f">={MIN_COMMITTED_MEGA_SPEEDUP}x)"
                )

    def check_loop(self, fresh: dict, committed: dict) -> None:
        pairs: Dict[str, Tuple[float, float]] = {}
        for track in ("campaign_cycles", "synthetic_cycles"):
            fcycles = fresh.get(track) or []
            ccycles = committed.get(track) or []
            if ccycles and len(fcycles) < min(
                EXPECTED_FAST_LOOP_CYCLES, len(ccycles)
            ):
                self.hard_fail(
                    f"loop: fresh run has {len(fcycles)} {track} "
                    f"(expected >= {EXPECTED_FAST_LOOP_CYCLES})"
                )
                continue
            for fc, cc in zip(fcycles, ccycles):  # overlapping prefix
                cyc = fc.get("cycle", "?")
                if fc.get("n_observations") != cc.get("n_observations"):
                    # fast and full runs grow the dataset at different rates
                    # (seeds_per_cycle); mismatched workloads are not
                    # comparable and would bias the median machine factor
                    self.skipped += 1
                    continue
                # recommend_ms is excluded: early cycles pay one-off JIT
                # compiles whose placement differs between fast and full
                # runs; warm recommend latency is gated via BENCH_fit.json.
                for field in ("refit_ms", "cycle_s"):
                    if field in fc and field in cc:
                        scale = 1e-3 if field.endswith("_ms") else 1.0
                        pairs[f"{track}[{cyc}].{field}"] = (
                            fc[field] * scale, cc[field] * scale
                        )
        self.compare_timings("loop", pairs)

    def check_fleet(self, fresh: dict, committed: dict) -> None:
        pairs: Dict[str, Tuple[float, float]] = {}
        fruns = {r.get("collectors"): r for r in fresh.get("runs", [])}
        cruns = {r.get("collectors"): r for r in committed.get("runs", [])}
        for n in EXPECTED_FAST_FLEET_COLLECTORS:
            if cruns and n not in fruns:
                self.hard_fail(
                    f"fleet: fast run is required to cover collectors={n} "
                    f"but did not"
                )
        for n, frow in fruns.items():
            if frow.get("n_failures", 0):
                self.hard_fail(f"fleet: {frow['n_failures']} collector failures at collectors={n}")
        for n, crow in cruns.items():
            frow = fruns.get(n)
            if frow is None:
                continue
            # wall time per collected row is the machine-comparable metric
            if frow.get("rows") and crow.get("rows"):
                pairs[f"runs[{n}].wall_per_row"] = (
                    frow["wall_s"] / frow["rows"], crow["wall_s"] / crow["rows"]
                )
        self.compare_timings("fleet", pairs)

    def check_serve(self, fresh: dict, committed: dict) -> None:
        def rows_by_key(art: dict, endpoint: str) -> dict:
            return {(r.get("mode"), r.get("clients")): r
                    for r in (art.get("endpoints") or {}).get(endpoint, [])}

        pairs: Dict[str, Tuple[float, float]] = {}
        for endpoint in EXPECTED_SERVE_ENDPOINTS:
            frows = rows_by_key(fresh, endpoint)
            crows = rows_by_key(committed, endpoint)
            for mode in EXPECTED_SERVE_MODES:
                for clients in EXPECTED_SERVE_CLIENTS:
                    key = f"{endpoint}.{mode}.c{clients}"
                    frow = frows.get((mode, clients))
                    if frow is None:
                        self.hard_fail(
                            f"serve: fresh run is required to measure {key} "
                            f"but did not (QPS row silently dropped?)"
                        )
                        continue
                    qps = frow.get("qps")
                    if not (isinstance(qps, (int, float))
                            and math.isfinite(qps) and qps > 0):
                        self.hard_fail(f"serve: {key} fresh qps is {qps!r}")
                        continue
                    crow = crows.get((mode, clients))
                    if crow and crow.get("p50_ms") and frow.get("p50_ms"):
                        pairs[f"{key}.p50"] = (frow["p50_ms"] * 1e-3,
                                               crow["p50_ms"] * 1e-3)

        # the headline batching claim is enforced on the committed artifact
        # (same-machine numbers: no calibration caveats apply)
        c32 = {e: ((committed.get("speedup_batched") or {}).get(e) or {})
               .get("c32") for e in EXPECTED_SERVE_ENDPOINTS}
        if not any(isinstance(v, (int, float))
                   and v >= MIN_COMMITTED_SERVE_SPEEDUP_C32
                   for v in c32.values()):
            self.hard_fail(
                f"serve: committed batched-vs-unbatched speedup at 32 clients "
                f"is {c32} — no endpoint reaches the required "
                f"{MIN_COMMITTED_SERVE_SPEEDUP_C32}x"
            )
        for endpoint, v in c32.items():
            if isinstance(v, (int, float)) and v < 1.0:
                self.hard_fail(
                    f"serve: committed {endpoint} speedup at 32 clients is "
                    f"{v}x — batching must never lose under load"
                )
        # fresh speedups vary with runner load: regression-flag, don't fail
        fresh_c32 = [((fresh.get("speedup_batched") or {}).get(e) or {})
                     .get("c32") for e in EXPECTED_SERVE_ENDPOINTS]
        best = max((v for v in fresh_c32 if isinstance(v, (int, float))),
                   default=None)
        if best is not None and best < 1.2:
            self.soft.append(
                f"serve: fresh batched speedup at 32 clients peaked at "
                f"{best}x (committed artifact promises "
                f">={MIN_COMMITTED_SERVE_SPEEDUP_C32}x)"
            )
        ccache = committed.get("cache") or {}
        if isinstance(ccache.get("speedup_hit"), (int, float)) \
                and ccache["speedup_hit"] < 1.2:
            self.hard_fail(
                f"serve: committed cache hit speedup is "
                f"{ccache['speedup_hit']}x — the response cache stopped paying"
            )
        self.compare_timings("serve", pairs)

    def check_pipeline(self, fresh: dict, committed: dict) -> None:
        def by_key(art: dict) -> dict:
            return {c.get("key"): c for c in (art.get("cases") or [])}

        fcases, ccases = by_key(fresh), by_key(committed)
        pairs: Dict[str, Tuple[float, float]] = {}
        for key in EXPECTED_FAST_PIPELINE_KEYS:
            frow = fcases.get(key)
            if frow is None:
                self.hard_fail(
                    f"pipeline: fast run is required to measure {key} but "
                    f"did not (policy row silently dropped?)"
                )
                continue
            stall = frow.get("stall_s")
            if not (isinstance(stall, (int, float)) and math.isfinite(stall)
                    and stall >= 0):
                self.hard_fail(f"pipeline: {key} fresh stall_s is {stall!r}")
            mbs = frow.get("delivered_mb_s")
            if not (isinstance(mbs, (int, float)) and math.isfinite(mbs)
                    and mbs > 0):
                self.hard_fail(
                    f"pipeline: {key} fresh delivered_mb_s is {mbs!r}")
        for key, crow in ccases.items():
            frow = fcases.get(key)
            if frow is None:
                continue  # full-run-only cases (object_sim, 4 workers)
            if crow.get("policy") == "clairvoyant":
                # clairvoyant stalls are near-constant residue, not
                # workload-proportional: the fast run's shorter measure
                # window skews their ratio off the machine factor.  The
                # stall_reduction floor below is their gate.
                self.skipped += 1
                continue
            fs, cs = frow.get("stall_s"), crow.get("stall_s")
            if isinstance(fs, (int, float)) and isinstance(cs, (int, float)) \
                    and fs > 0 and cs > 0:
                pairs[f"{key}.stall"] = (fs, cs)

        # the headline clairvoyant claim is enforced on the committed artifact
        # (same-machine numbers: no calibration caveats apply)
        creds = [v for v in (committed.get("stall_reduction") or {}).values()
                 if isinstance(v, (int, float)) and math.isfinite(v)]
        best = max(creds, default=None)
        if best is None or best < MIN_COMMITTED_PIPELINE_STALL_REDUCTION:
            self.hard_fail(
                f"pipeline: committed clairvoyant-vs-depth stall reduction "
                f"peaks at {best} — below the required "
                f"{MIN_COMMITTED_PIPELINE_STALL_REDUCTION}x"
            )
        # fresh reductions vary with runner load: regression-flag, don't fail
        freds = [v for v in (fresh.get("stall_reduction") or {}).values()
                 if isinstance(v, (int, float)) and math.isfinite(v)]
        fbest = max(freds, default=None)
        if fbest is not None and fbest < 1.2:
            self.soft.append(
                f"pipeline: fresh clairvoyant-vs-depth stall reduction "
                f"peaked at {fbest}x (committed artifact promises "
                f">={MIN_COMMITTED_PIPELINE_STALL_REDUCTION}x)"
            )
        self.compare_timings("pipeline", pairs)

    def check_transfer(self, fresh: dict, committed: dict) -> None:
        ffolds = (fresh.get("report") or {}).get("folds") or {}
        cfolds = (committed.get("report") or {}).get("folds") or {}
        pairs: Dict[str, Tuple[float, float]] = {}
        for gname in EXPECTED_FAST_TRANSFER_FOLDS:
            fold = ffolds.get(gname)
            if fold is None:
                self.hard_fail(
                    f"transfer: fast run is required to hold out {gname!r} "
                    f"but did not (fold silently dropped?)"
                )
                continue
            zero = ((fold.get("calibration") or {}).get("curve") or {}) \
                .get("k0", {}).get("mape")
            if not (isinstance(zero, (int, float)) and math.isfinite(zero)
                    and zero > 0):
                self.hard_fail(
                    f"transfer: {gname} fresh zero-shot mape is {zero!r}")
        for gname, cs in (committed.get("fold_seconds") or {}).items():
            fs = (fresh.get("fold_seconds") or {}).get(gname)
            if isinstance(fs, (int, float)) and isinstance(cs, (int, float)) \
                    and fs > 0 and cs > 0:
                pairs[f"{gname}.fold"] = (fs, cs)

        # the headline calibration claim is enforced on the committed
        # artifact (same-machine numbers: no calibration caveats apply)
        creds = [v for v in (committed.get("mape_reduction_k25") or {}).values()
                 if isinstance(v, (int, float)) and math.isfinite(v)]
        best = max(creds, default=None)
        if best is None or best < MIN_COMMITTED_TRANSFER_REDUCTION:
            self.hard_fail(
                f"transfer: committed calibrated-vs-zero-shot MAPE reduction "
                f"peaks at {best} — below the required "
                f"{MIN_COMMITTED_TRANSFER_REDUCTION}x"
            )
        # fresh reductions vary with the CI-sized track: flag, don't fail
        freds = [v for v in (fresh.get("mape_reduction_k25") or {}).values()
                 if isinstance(v, (int, float)) and math.isfinite(v)]
        fbest = max(freds, default=None)
        if fbest is not None and fbest < 1.2:
            self.soft.append(
                f"transfer: fresh calibrated-vs-zero-shot MAPE reduction "
                f"peaked at {fbest}x (committed artifact promises "
                f">={MIN_COMMITTED_TRANSFER_REDUCTION}x)"
            )
        self.compare_timings("transfer", pairs)


def run_gate(
    fresh_dir: pathlib.Path,
    repo_root: pathlib.Path = REPO_ROOT,
    tolerance: float = 0.35,
    min_ms: float = 1.0,
) -> Gate:
    gate = Gate(tolerance, min_ms)
    checkers = {
        "BENCH_fit.json": gate.check_fit,
        "BENCH_loop.json": gate.check_loop,
        "BENCH_fleet.json": gate.check_fleet,
        "BENCH_serve.json": gate.check_serve,
        "BENCH_pipeline.json": gate.check_pipeline,
        "BENCH_transfer.json": gate.check_transfer,
    }
    for name in ARTIFACTS:
        committed_path = repo_root / name
        fresh_path = fresh_dir / name
        if not committed_path.exists():
            gate.hard_fail(f"{name}: committed artifact missing at {committed_path}")
            continue
        if not fresh_path.exists():
            gate.hard_fail(
                f"{name}: fresh artifact missing at {fresh_path} "
                f"(run `make bench-fast` first)"
            )
            continue
        try:
            committed = json.loads(committed_path.read_text())
            fresh = json.loads(fresh_path.read_text())
        except (OSError, json.JSONDecodeError) as e:
            gate.hard_fail(f"{name}: unreadable artifact ({e})")
            continue
        gate.check_integrity(name, committed, "committed")
        gate.check_integrity(name, fresh, "fresh")
        checkers[name](fresh, committed)
    return gate


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__.splitlines()[0],
    )
    ap.add_argument("--fresh", required=True,
                    help="directory holding the fresh fast-run BENCH_*.json")
    ap.add_argument("--repo-root", default=str(REPO_ROOT),
                    help="repo root holding the committed BENCH_*.json")
    ap.add_argument("--tolerance", type=float, default=0.35,
                    help="allowed deviation from the median machine factor "
                         "(default 0.35 = 35%%)")
    ap.add_argument("--min-ms", type=float, default=1.0,
                    help="skip timings where both sides are below this (ms)")
    args = ap.parse_args(argv)

    gate = run_gate(
        pathlib.Path(args.fresh),
        pathlib.Path(args.repo_root),
        args.tolerance,
        args.min_ms,
    )
    for msg in gate.hard:
        print(f"HARD FAIL: {msg}")
    for msg in gate.soft:
        print(f"REGRESSION: {msg}")
    status = "FAILED" if (gate.hard or gate.soft) else "passed"
    print(
        f"bench gate {status}: {gate.compared} timings compared, "
        f"{gate.skipped} skipped, {len(gate.soft)} regressions, "
        f"{len(gate.hard)} hard failures"
    )
    return 1 if (gate.hard or gate.soft) else 0


if __name__ == "__main__":
    sys.exit(main())
