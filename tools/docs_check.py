#!/usr/bin/env python
"""Keep the docs honest: verify CLI references and intra-repo links.

Two checks over ``README.md`` + ``docs/**/*.md`` (``make docs-check``, wired
into CI):

1. **CLI references** — every ``python -m <module> ...`` line inside a
   fenced ``bash``/``console`` block must name a module whose ``--help``
   actually works under ``PYTHONPATH=src``, and every ``-f``/``--flag`` the
   line passes must appear in that help text.  Subcommands (``campaign run``)
   are resolved to the subparser's help.  Docs drift the moment a flag is
   renamed; this turns that drift into a CI failure.
2. **Intra-repo links** — every relative markdown link target (outside code
   fences) must resolve to an existing file or directory.

Exit status: 0 clean, 1 with one line per problem on stderr.

Usage::

    python tools/docs_check.py            # check the repo this file lives in
    python tools/docs_check.py <root>     # check another tree (tests)
"""

from __future__ import annotations

import os
import pathlib
import re
import subprocess
import sys
from typing import Dict, List, Optional, Tuple

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent

_FENCE_RE = re.compile(r"^```(\w*)\s*$")
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_FLAG_RE = re.compile(r"^-{1,2}[A-Za-z][\w-]*$")
_CMD_RE = re.compile(
    r"^(?:\$\s+)?(?:[A-Z_][A-Z0-9_]*=\S+\s+)*python\s+-m\s+(\S+)\s*(.*)$")
# ``python tools/<script>.py ...`` lines (repo-relative helper CLIs like
# tools/bench_gate.py) get the same --help verification as modules.
_SCRIPT_RE = re.compile(
    r"^(?:\$\s+)?(?:[A-Z_][A-Z0-9_]*=\S+\s+)*python\s+((?:tools|benchmarks)/[\w/.-]+\.py)\s*(.*)$")


def markdown_files(root: pathlib.Path) -> List[pathlib.Path]:
    files = []
    if (root / "README.md").exists():
        files.append(root / "README.md")
    files.extend(sorted((root / "docs").rglob("*.md")) if (root / "docs").exists() else [])
    return files


def _split_fences(text: str) -> Tuple[str, List[Tuple[str, List[str]]]]:
    """(prose with code fences stripped, [(fence language, lines), ...])."""
    prose: List[str] = []
    blocks: List[Tuple[str, List[str]]] = []
    lang: Optional[str] = None
    lines: List[str] = []
    for line in text.splitlines():
        m = _FENCE_RE.match(line.strip())
        if m:
            if lang is None:
                lang, lines = m.group(1), []
            else:
                blocks.append((lang, lines))
                lang = None
            continue
        (lines if lang is not None else prose).append(line)
    return "\n".join(prose), blocks


def _join_continuations(lines: List[str]) -> List[str]:
    out: List[str] = []
    for line in lines:
        if out and out[-1].endswith("\\"):
            out[-1] = out[-1][:-1] + " " + line.strip()
        else:
            out.append(line.rstrip())
    return out


def extract_cli_commands(text: str) -> List[Tuple[str, str, List[str]]]:
    """(kind, target, argv-tokens) for every ``python -m <module>`` or
    ``python tools/<script>.py`` line in bash/console fences (``$``-prefixed
    prompt lines included, output lines ignored).  kind is "module" or
    "script"."""
    cmds = []
    _, blocks = _split_fences(text)
    for lang, lines in blocks:
        if lang not in ("bash", "sh", "shell", "console"):
            continue
        for line in _join_continuations(lines):
            m = _CMD_RE.match(line.strip())
            if m:
                cmds.append(("module", m.group(1), m.group(2).split()))
                continue
            m = _SCRIPT_RE.match(line.strip())
            if m:
                cmds.append(("script", m.group(1), m.group(2).split()))
    return cmds


class HelpCache:
    """``python -m <module> [subcommand] --help`` (or ``python <script>
    --help``) output, one subprocess per distinct target, run with src/ on
    PYTHONPATH."""

    def __init__(self, root: pathlib.Path):
        self.root = root
        self._cache: Dict[Tuple[str, str, Optional[str]], Optional[str]] = {}

    def help_text(self, module: str, sub: Optional[str],
                  kind: str = "module") -> Optional[str]:
        key = (kind, module, sub)
        if key not in self._cache:
            if kind == "script":
                script = self.root / module
                if not script.exists():
                    self._cache[key] = None
                    return None
                argv = [sys.executable, str(script), "--help"]
            else:
                argv = [sys.executable, "-m", module] + ([sub] if sub else []) + ["--help"]
            env = dict(os.environ)
            src = str(self.root / "src")
            env["PYTHONPATH"] = (src + os.pathsep + env["PYTHONPATH"]
                                 if env.get("PYTHONPATH") else src)
            try:
                proc = subprocess.run(argv, capture_output=True, text=True,
                                      timeout=120, env=env, cwd=self.root)
            except (OSError, subprocess.SubprocessError):
                proc = None
            ok = proc is not None and proc.returncode == 0
            self._cache[key] = (proc.stdout + proc.stderr) if ok else None
        return self._cache[key]


def check_cli_commands(files: List[pathlib.Path],
                       root: pathlib.Path) -> List[str]:
    errors = []
    cache = HelpCache(root)
    for path in files:
        rel = path.relative_to(root)
        for kind, module, argv in extract_cli_commands(path.read_text()):
            # the subcommand, if any, is the first non-flag token
            sub = next((t for t in argv if not t.startswith("-")), None)
            sub = sub if sub and re.fullmatch(r"[\w-]+", sub) else None
            shown = f"python -m {module}" if kind == "module" else f"python {module}"
            help_text = cache.help_text(module, sub if kind == "module" else None,
                                        kind)
            if help_text is None and sub is not None and kind == "module":
                help_text = cache.help_text(module, None)  # positional arg, not a subcommand
            if help_text is None:
                errors.append(f"{rel}: `{shown}"
                              f"{' ' + sub if sub and kind == 'module' else ''} "
                              "--help` failed (target missing or CLI broken)")
                continue
            for token in argv:
                flag = token.split("=", 1)[0]
                if not _FLAG_RE.match(flag):
                    continue
                if not re.search(rf"(?<![\w-]){re.escape(flag)}(?![\w-])",
                                 help_text):
                    errors.append(f"{rel}: `{shown}` does not "
                                  f"define {flag} (per --help)")
    return errors


def check_links(files: List[pathlib.Path], root: pathlib.Path) -> List[str]:
    errors = []
    for path in files:
        rel = path.relative_to(root)
        prose, _ = _split_fences(path.read_text())
        for target in _LINK_RE.findall(prose):
            if re.match(r"^(https?:|mailto:|#)", target):
                continue
            resolved = (path.parent / target.split("#", 1)[0]).resolve()
            if not resolved.exists():
                errors.append(f"{rel}: broken link -> {target}")
    return errors


def main(argv: Optional[List[str]] = None) -> int:
    args = argv if argv is not None else sys.argv[1:]
    root = pathlib.Path(args[0]).resolve() if args else REPO_ROOT
    files = markdown_files(root)
    if not files:
        print(f"docs-check: no markdown under {root}", file=sys.stderr)
        return 1
    errors = check_links(files, root) + check_cli_commands(files, root)
    for err in errors:
        print(f"docs-check: {err}", file=sys.stderr)
    print(f"docs-check: {len(files)} file(s), {len(errors)} problem(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
