#!/usr/bin/env python
"""Visible native-kernel health check for CI.

The native tree kernels degrade silently by design: any compile/load/self-test
failure falls back to the (bit-identical) numpy path so end users without a C
toolchain are never broken.  CI is the one place that silence is wrong — a
hosted runner *has* a compiler, so ``native.available() == False`` there means
the compile broke and every native-path benchmark/test quietly stopped
covering the C code.  This script makes that state a visible job failure:

- compiler present + native kernels load  -> exit 0 (reports cache dir, threads)
- no compiler on PATH                     -> exit 0 (numpy fallback is the
                                             supported configuration)
- REPRO_TREE_NATIVE=0                     -> exit 0 (explicitly disabled)
- compiler present + kernels unavailable  -> exit 1 (the silent-fallback bug)

Usage: PYTHONPATH=src python tools/native_check.py  (or ``make native-check``)
"""

from __future__ import annotations

import os
import shutil
import subprocess
import sys


def main() -> int:
    compiler = next(
        (cc for cc in ("cc", "gcc", "clang") if shutil.which(cc)), None
    )
    if os.environ.get("REPRO_TREE_NATIVE", "").strip() == "0":
        print("native-check: REPRO_TREE_NATIVE=0 — native kernels explicitly "
              "disabled, numpy fallback in use (ok)")
        return 0

    from repro.core import _native

    if _native.available():
        so = getattr(_native, "_lib", None)
        path = getattr(so, "_name", "?") if so is not None else "?"
        print(f"native-check: native kernels loaded from {path}")
        print(f"native-check: REPRO_NATIVE_THREADS resolves to "
              f"{_native.native_threads()} (max {_native.MAX_THREADS})")
        return 0
    if compiler is None:
        print("native-check: no C compiler on PATH — numpy fallback in use "
              "(ok, but the native kernels are untested on this host)")
        return 0
    version = subprocess.run(
        [compiler, "--version"], capture_output=True, text=True
    ).stdout.splitlines()[:1]
    print(f"native-check: FAIL — {compiler} is present "
          f"({version[0] if version else 'version unknown'}) but "
          f"native.available() is false: the kernel compile/load/self-test "
          f"broke and the numpy fallback is masking it")
    return 1


if __name__ == "__main__":
    sys.exit(main())
