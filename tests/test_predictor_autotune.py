"""The paper's workflow end-to-end on real (fast-collected) observations,
plus autotuner behaviour."""

import numpy as np
import pytest

from repro.core import (
    ConfigSpace,
    IOPerformancePredictor,
    OnlineAutotuner,
    accuracy,
    make_classifier,
    recommend,
)


def test_predictor_fast_observations(obs_fast):
    rows, cols = obs_fast
    pred = IOPerformancePredictor(model="xgboost")
    reports = pred.evaluate_zoo(cols, models=["xgboost", "linear"], with_cv=False)
    # obs_fast is live-collected benchmark data, so cross-model R2 ordering
    # is unstable under suite load (no fixed margin holds reliably); assert
    # only the stable facts — both models fit the data.  The full-141 Fig-5
    # ordering is asserted in benchmarks / EXPERIMENTS.md.
    assert reports["xgboost"].train_r2 > 0.9
    assert reports["xgboost"].test_r2 > 0.5
    assert reports["linear"].train_r2 > 0.5


def test_predict_throughput_scalar(obs_fast):
    rows, cols = obs_fast
    pred = IOPerformancePredictor(model="xgboost").fit(cols)
    t = pred.predict_throughput(
        {"batch_size": 32, "num_workers": 2, "block_kb": 64, "throughput_mb_s": 500.0}
    )
    assert np.isfinite(t) and t >= 0


def test_recommend_ranks_by_prediction(obs_fast):
    rows, cols = obs_fast
    pred = IOPerformancePredictor(model="xgboost").fit(cols)
    space = ConfigSpace(batch_size=(16, 64), num_workers=(0, 2), block_kb=(4, 64),
                        n_threads=(1,), prefetch_depth=(1,))
    top = recommend(pred, context={"throughput_mb_s": 800.0, "file_size_mb": 16.0},
                    space=space, top_k=4)
    assert len(top) == 4
    scores = [t["predicted_throughput_mb_s"] for t in top]
    assert scores == sorted(scores, reverse=True)


def test_online_autotuner_reconfigures_on_clear_signal():
    """Synthetic world: more workers => strictly higher throughput."""
    tuner = OnlineAutotuner(
        refit_every=1, min_observations=10, gain_threshold=0.05,
        space=ConfigSpace(batch_size=(32,), num_workers=(0, 2, 4),
                          block_kb=(64,), n_threads=(1,), prefetch_depth=(1,)),
        seed=0,
    )
    rng = np.random.default_rng(0)
    for i in range(40):
        w = int(rng.choice([0, 2, 4]))
        thr = 100.0 * (1 + w) * (1 + 0.01 * rng.normal())
        tuner.observe(
            {"batch_size": 32, "num_workers": w, "block_kb": 64,
             "throughput_mb_s": thr, "samples_per_second": thr * 2,
             "data_loading_ratio": 0.5 / (1 + w)},
            thr,
        )
    assert tuner.maybe_refit()
    decision = tuner.decide(
        current_config={"batch_size": 32, "num_workers": 0, "block_kb": 64,
                        "prefetch_depth": 1},
        context={"batch_size": 32, "num_workers": 0, "block_kb": 64,
                 "throughput_mb_s": 100.0, "samples_per_second": 200.0,
                 "data_loading_ratio": 0.5},
    )
    assert decision.reconfigure
    assert decision.config["num_workers"] == 4


def test_autotuner_no_churn_when_already_best():
    tuner = OnlineAutotuner(
        refit_every=1, min_observations=5, gain_threshold=0.10,
        space=ConfigSpace(batch_size=(32,), num_workers=(0, 4), block_kb=(64,),
                          n_threads=(1,), prefetch_depth=(1,)),
    )
    for w, thr in [(0, 100), (4, 500)] * 5:
        tuner.observe({"batch_size": 32, "num_workers": w, "block_kb": 64,
                       "throughput_mb_s": thr}, thr)
    tuner.maybe_refit()
    d = tuner.decide(
        current_config={"batch_size": 32, "num_workers": 4, "block_kb": 64,
                        "prefetch_depth": 1},
        context={"batch_size": 32, "num_workers": 4, "block_kb": 64,
                 "throughput_mb_s": 500.0},
    )
    assert not d.reconfigure


def test_format_classifier_rq3():
    """RQ3: classifiers recommend the best format from workload features."""
    rng = np.random.default_rng(0)
    n = 400
    X = np.stack([
        rng.uniform(1, 4096, n),   # record_kb
        rng.uniform(0, 1, n),      # compressibility
        rng.uniform(0, 1, n),      # random-access fraction
    ], axis=1)
    # ground truth: compressed if compressible, raw if tiny records, packed else
    y = np.where(X[:, 1] > 0.7, 2, np.where(X[:, 0] < 64, 0, 1))
    for name in ("logistic", "random_forest", "gbt"):
        m = make_classifier(name, n_classes=3)
        m.fit(X, y)
        acc = accuracy(y, m.predict(X))
        assert acc > (0.85 if name != "logistic" else 0.7), (name, acc)


def test_config_space_cached_grid_consistent():
    """The cached zero-copy feature matrix must agree row-for-row with the
    old per-candidate dict-merge featurization, and candidate(i) with
    candidates()[i]."""
    from repro.core.features import FeatureSpec

    spec = FeatureSpec()
    space = ConfigSpace(batch_size=(16, 64), num_workers=(0, 2), block_kb=(4, 64),
                        n_threads=(1, 2), prefetch_depth=(1, 2))
    ctx = {"throughput_mb_s": 800.0, "file_size_mb": 16.0}
    X = space.feature_matrix(spec, ctx)
    cands = space.candidates()
    assert X.shape == (space.n_candidates, spec.n_features)
    expected = np.stack([spec.row({**ctx, **c}) for c in cands])
    np.testing.assert_array_equal(X, expected)
    for i in (0, 7, len(cands) - 1):
        assert space.candidate(i) == cands[i]
    # a second call with new context rewrites only context columns
    X2 = space.feature_matrix(spec, {"throughput_mb_s": 5.0})
    expected2 = np.stack([spec.row({"throughput_mb_s": 5.0, **c}) for c in cands])
    np.testing.assert_array_equal(X2, expected2)


def test_online_autotuner_column_store_matches_rows():
    """The incremental store's zero-copy matrix equals the stack-from-dicts
    path the refit used to take."""
    tuner = OnlineAutotuner(min_observations=4, refit_every=1,
                            space=ConfigSpace(batch_size=(32,), num_workers=(0, 2),
                                              block_kb=(64,), n_threads=(1,),
                                              prefetch_depth=(1,)))
    rng = np.random.default_rng(0)
    for i in range(12):
        w = int(rng.choice([0, 2]))
        tuner.observe({"batch_size": 32, "num_workers": w, "block_kb": 64,
                       "file_size_mb": 8.0}, 100.0 * (1 + w))
    cols = tuner._columns()
    spec = tuner.spec
    X_store = tuner._store.matrix(spec.names)
    X_dict = spec.matrix(cols)
    np.testing.assert_array_equal(X_store, X_dict)
    assert tuner._store.column(spec.target).shape == (12,)
    assert (tuner._store.column(spec.target) > 0).all()
    assert tuner.maybe_refit()
    assert tuner.n_observations == 12
