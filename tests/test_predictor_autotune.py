"""The paper's workflow end-to-end on real (fast-collected) observations,
plus autotuner behaviour."""

import numpy as np
import pytest

from repro.core import (
    ConfigSpace,
    IOPerformancePredictor,
    OnlineAutotuner,
    accuracy,
    make_classifier,
    recommend,
)


def test_predictor_fast_observations(obs_fast):
    rows, cols = obs_fast
    pred = IOPerformancePredictor(model="xgboost")
    reports = pred.evaluate_zoo(cols, models=["xgboost", "linear"], with_cv=False)
    # obs_fast is live-collected benchmark data, so cross-model R2 ordering
    # is unstable under suite load (no fixed margin holds reliably); assert
    # only the stable facts — both models fit the data.  The full-141 Fig-5
    # ordering is asserted in benchmarks / EXPERIMENTS.md.
    assert reports["xgboost"].train_r2 > 0.9
    assert reports["xgboost"].test_r2 > 0.5
    assert reports["linear"].train_r2 > 0.5


def test_predict_throughput_scalar(obs_fast):
    rows, cols = obs_fast
    pred = IOPerformancePredictor(model="xgboost").fit(cols)
    t = pred.predict_throughput(
        {"batch_size": 32, "num_workers": 2, "block_kb": 64, "throughput_mb_s": 500.0}
    )
    assert np.isfinite(t) and t >= 0


def test_recommend_ranks_by_prediction(obs_fast):
    rows, cols = obs_fast
    pred = IOPerformancePredictor(model="xgboost").fit(cols)
    space = ConfigSpace(batch_size=(16, 64), num_workers=(0, 2), block_kb=(4, 64),
                        n_threads=(1,), prefetch_depth=(1,))
    top = recommend(pred, context={"throughput_mb_s": 800.0, "file_size_mb": 16.0},
                    space=space, top_k=4)
    assert len(top) == 4
    scores = [t["predicted_throughput_mb_s"] for t in top]
    assert scores == sorted(scores, reverse=True)


def test_online_autotuner_reconfigures_on_clear_signal():
    """Synthetic world: more workers => strictly higher throughput."""
    tuner = OnlineAutotuner(
        refit_every=1, min_observations=10, gain_threshold=0.05,
        space=ConfigSpace(batch_size=(32,), num_workers=(0, 2, 4),
                          block_kb=(64,), n_threads=(1,), prefetch_depth=(1,)),
        seed=0,
    )
    rng = np.random.default_rng(0)
    for i in range(40):
        w = int(rng.choice([0, 2, 4]))
        thr = 100.0 * (1 + w) * (1 + 0.01 * rng.normal())
        tuner.observe(
            {"batch_size": 32, "num_workers": w, "block_kb": 64,
             "throughput_mb_s": thr, "samples_per_second": thr * 2,
             "data_loading_ratio": 0.5 / (1 + w)},
            thr,
        )
    assert tuner.maybe_refit()
    decision = tuner.decide(
        current_config={"batch_size": 32, "num_workers": 0, "block_kb": 64,
                        "prefetch_depth": 1},
        context={"batch_size": 32, "num_workers": 0, "block_kb": 64,
                 "throughput_mb_s": 100.0, "samples_per_second": 200.0,
                 "data_loading_ratio": 0.5},
    )
    assert decision.reconfigure
    assert decision.config["num_workers"] == 4


def test_autotuner_no_churn_when_already_best():
    tuner = OnlineAutotuner(
        refit_every=1, min_observations=5, gain_threshold=0.10,
        space=ConfigSpace(batch_size=(32,), num_workers=(0, 4), block_kb=(64,),
                          n_threads=(1,), prefetch_depth=(1,)),
    )
    for w, thr in [(0, 100), (4, 500)] * 5:
        tuner.observe({"batch_size": 32, "num_workers": w, "block_kb": 64,
                       "throughput_mb_s": thr}, thr)
    tuner.maybe_refit()
    d = tuner.decide(
        current_config={"batch_size": 32, "num_workers": 4, "block_kb": 64,
                        "prefetch_depth": 1},
        context={"batch_size": 32, "num_workers": 4, "block_kb": 64,
                 "throughput_mb_s": 500.0},
    )
    assert not d.reconfigure


def test_format_classifier_rq3():
    """RQ3: classifiers recommend the best format from workload features."""
    rng = np.random.default_rng(0)
    n = 400
    X = np.stack([
        rng.uniform(1, 4096, n),   # record_kb
        rng.uniform(0, 1, n),      # compressibility
        rng.uniform(0, 1, n),      # random-access fraction
    ], axis=1)
    # ground truth: compressed if compressible, raw if tiny records, packed else
    y = np.where(X[:, 1] > 0.7, 2, np.where(X[:, 0] < 64, 0, 1))
    for name in ("logistic", "random_forest", "gbt"):
        m = make_classifier(name, n_classes=3)
        m.fit(X, y)
        acc = accuracy(y, m.predict(X))
        assert acc > (0.85 if name != "logistic" else 0.7), (name, acc)


def test_config_space_cached_grid_consistent():
    """The cached zero-copy feature matrix must agree row-for-row with the
    old per-candidate dict-merge featurization, and candidate(i) with
    candidates()[i]."""
    from repro.core.features import FeatureSpec

    spec = FeatureSpec()
    space = ConfigSpace(batch_size=(16, 64), num_workers=(0, 2), block_kb=(4, 64),
                        n_threads=(1, 2), prefetch_depth=(1, 2))
    ctx = {"throughput_mb_s": 800.0, "file_size_mb": 16.0}
    X = space.feature_matrix(spec, ctx)
    cands = space.candidates()
    assert X.shape == (space.n_candidates, spec.n_features)
    expected = np.stack([spec.row({**ctx, **c}) for c in cands])
    np.testing.assert_array_equal(X, expected)
    for i in (0, 7, len(cands) - 1):
        assert space.candidate(i) == cands[i]
    # a second call with new context rewrites only context columns
    X2 = space.feature_matrix(spec, {"throughput_mb_s": 5.0})
    expected2 = np.stack([spec.row({"throughput_mb_s": 5.0, **c}) for c in cands])
    np.testing.assert_array_equal(X2, expected2)


def _two_worker_tuner(gain_threshold=0.10, **kw):
    """Fitted tuner on a world where num_workers=4 beats num_workers=0 5x."""
    tuner = OnlineAutotuner(
        refit_every=1, min_observations=5, gain_threshold=gain_threshold,
        space=ConfigSpace(batch_size=(32,), num_workers=(0, 4), block_kb=(64,),
                          n_threads=(1,), prefetch_depth=(1,)),
        **kw,
    )
    for w, thr in [(0, 100.0), (4, 500.0)] * 5:
        tuner.observe({"batch_size": 32, "num_workers": w, "block_kb": 64,
                       "throughput_mb_s": thr}, thr)
    assert tuner.maybe_refit()
    return tuner


def test_decide_missing_knob_counts_as_difference():
    """Regression: a varied knob absent from the trainer's config dict used to
    be skipped by the same-config check, so the genuinely better config was
    reported as 'same' and never proposed."""
    tuner = _two_worker_tuner()
    d = tuner.decide(
        current_config={"batch_size": 32, "block_kb": 64},  # num_workers missing
        context={"batch_size": 32, "block_kb": 64, "throughput_mb_s": 100.0},
    )
    assert d.reconfigure
    assert d.config["num_workers"] == 4


def test_decide_extra_keys_do_not_force_mismatch():
    """Regression: non-knob keys (labels, annotations) in the trainer's config
    used to force a spurious 'different config' verdict; with the current
    config already the best, no reconfiguration must be proposed even at a
    zero gain threshold."""
    tuner = _two_worker_tuner(gain_threshold=0.0)
    d = tuner.decide(
        current_config={"batch_size": 32, "num_workers": 4, "block_kb": 64,
                        "label": "trial-7", "explore": True},
        context={"batch_size": 32, "num_workers": 4, "block_kb": 64,
                 "throughput_mb_s": 500.0},
    )
    assert not d.reconfigure


def test_seeded_and_live_rows_produce_identical_store_columns():
    """Regression: seed_observations used to ingest raw offline rows, leaving
    real values in endogenous columns that live observe() rows zero-fill — a
    train/serve skew that poisoned every refit of the continuous loop."""
    space = ConfigSpace(batch_size=(32,), num_workers=(0, 2), block_kb=(64,),
                        n_threads=(1,), prefetch_depth=(1,))
    offline_row = {
        "batch_size": 32, "num_workers": 2, "block_kb": 64,
        "file_size_mb": 8.0, "n_samples": 100,
        # endogenous measurements a live row can't provide as features:
        "samples_per_second": 123.0, "data_loading_ratio": 0.4,
        "throughput_mb_s": 456.0, "iops": 1e4,
        "target_throughput": 300.0, "backend": "tmpfs", "bench_type": "pipeline",
    }
    seeded = OnlineAutotuner(space=space)
    seeded.seed_observations([offline_row])
    live = OnlineAutotuner(space=space)
    live.observe({k: v for k, v in offline_row.items()
                  if k != "target_throughput"}, 300.0)
    np.testing.assert_array_equal(
        seeded._store.matrix(seeded.spec.names),
        live._store.matrix(live.spec.names),
    )
    np.testing.assert_array_equal(
        seeded._store.column(seeded.spec.target),
        live._store.column(live.spec.target),
    )
    # the endogenous columns specifically must be zero in the seeded store
    for col in ("samples_per_second", "data_loading_ratio",
                "throughput_mb_s", "iops"):
        assert (seeded._store.column(col) == 0).all(), col


def _campaign_record(case_id, seed, row):
    return {"case_id": case_id, "rep": 0, "seed": seed, "status": "ok",
            "row": row}


def _worker_rows(seed, scale=1.0):
    return [
        _campaign_record(f"c-w{w}-b{b}", seed, {
            "batch_size": b, "num_workers": w, "block_kb": 64,
            "file_size_mb": 8.0, "target_throughput": scale * 100.0 * (1 + w),
        })
        for w in (0, 2, 4) for b in (16, 32)
    ]


def test_ingest_records_dedups_by_key():
    tuner = OnlineAutotuner(min_observations=4,
                            space=ConfigSpace(batch_size=(16, 32),
                                              num_workers=(0, 2, 4),
                                              block_kb=(64,), n_threads=(1,),
                                              prefetch_depth=(1,)))
    recs = _worker_rows(seed=0)
    assert tuner.ingest_records(recs) == 6
    assert tuner.ingest_records(recs) == 0  # same (case_id, rep, seed) keys
    assert tuner.n_observations == 6
    # error records and new seeds behave as expected
    recs2 = _worker_rows(seed=1)
    recs2[0]["status"] = "error"
    recs2[0]["row"] = None
    assert tuner.ingest_records(recs2) == 5
    assert tuner.n_observations == 11


def test_drift_forces_refit_off_schedule():
    """A regime shift in new data must trigger a refit even when the
    refit_every schedule is nowhere near due."""
    space = ConfigSpace(batch_size=(16, 32), num_workers=(0, 2, 4),
                        block_kb=(64,), n_threads=(1,), prefetch_depth=(1,))
    tuner = OnlineAutotuner(space=space, refit_every=10_000,
                            min_observations=4, drift_threshold=0.3)
    tuner.ingest_records(_worker_rows(seed=0))
    assert tuner.maybe_refit()  # initial fit
    # same-regime data: low drift, schedule far away -> no refit
    tuner.ingest_records(_worker_rows(seed=1))
    assert tuner.last_drift < 0.3
    assert not tuner.maybe_refit()
    # regime shift: storage got 5x faster -> drift fires a refit
    tuner.ingest_records(_worker_rows(seed=2, scale=5.0))
    assert tuner.last_drift > 0.3
    assert tuner.maybe_refit()
    assert not tuner.maybe_refit()  # drift flag cleared by the refit


def test_online_autotuner_column_store_matches_rows():
    """The incremental store's zero-copy matrix equals the stack-from-dicts
    path the refit used to take."""
    tuner = OnlineAutotuner(min_observations=4, refit_every=1,
                            space=ConfigSpace(batch_size=(32,), num_workers=(0, 2),
                                              block_kb=(64,), n_threads=(1,),
                                              prefetch_depth=(1,)))
    rng = np.random.default_rng(0)
    for i in range(12):
        w = int(rng.choice([0, 2]))
        tuner.observe({"batch_size": 32, "num_workers": w, "block_kb": 64,
                       "file_size_mb": 8.0}, 100.0 * (1 + w))
    cols = tuner._columns()
    spec = tuner.spec
    X_store = tuner._store.matrix(spec.names)
    X_dict = spec.matrix(cols)
    np.testing.assert_array_equal(X_store, X_dict)
    assert tuner._store.column(spec.target).shape == (12,)
    assert (tuner._store.column(spec.target) > 0).all()
    assert tuner.maybe_refit()
    assert tuner.n_observations == 12


def test_autotuner_refit_honors_repro_tree_engine_env(monkeypatch):
    """REPRO_TREE_ENGINE set *after* import must steer OnlineAutotuner
    refits: engine resolution happens at fit time, not import time."""
    from repro.core import tree as tree_mod

    calls = []
    real = tree_mod._ENGINES["reference"]

    def spy(*args, **kwargs):
        calls.append("reference")
        return real(*args, **kwargs)

    monkeypatch.setitem(tree_mod._ENGINES, "reference", spy)
    monkeypatch.setenv("REPRO_TREE_ENGINE", "reference")
    tuner = OnlineAutotuner(
        refit_every=1, min_observations=8,
        space=ConfigSpace(batch_size=(32,), num_workers=(0, 2),
                          block_kb=(64,), n_threads=(1,), prefetch_depth=(1,)),
    )
    rng = np.random.default_rng(0)
    for _ in range(10):
        w = int(rng.choice([0, 2]))
        thr = 50.0 * (1 + w)
        tuner.observe({"batch_size": 32, "num_workers": w, "block_kb": 64}, thr)
    assert tuner.maybe_refit()
    assert calls, "refit did not route through the engine named by REPRO_TREE_ENGINE"


def test_predictor_engine_argument_overrides_env(monkeypatch):
    """An explicit engine= on the predictor beats REPRO_TREE_ENGINE."""
    from repro.core import FEATURE_NAMES, tree as tree_mod
    from repro.core.predictor import IOPerformancePredictor

    calls = []
    real = tree_mod._ENGINES["level"]

    def spy(*args, **kwargs):
        calls.append("level")
        return real(*args, **kwargs)

    monkeypatch.setitem(tree_mod._ENGINES, "level", spy)
    monkeypatch.setenv("REPRO_TREE_ENGINE", "reference")
    rng = np.random.default_rng(1)
    cols = {name: rng.random(40) * 10 for name in FEATURE_NAMES}
    cols["target_throughput"] = rng.random(40) * 100 + 10
    IOPerformancePredictor(model="xgboost", engine="level").fit(cols)
    assert calls, "explicit engine= was not honored"
