"""Sharding/mesh tests. These spawn subprocesses because the forced host
device count must be set before jax initializes (and the main test process
keeps its single-device view)."""

import json
import os
import pathlib
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.launch.analysis import CollectiveOp, parse_collectives, roofline_terms
from repro.parallel.rules import make_rules
from repro.parallel.spec import DEFAULT_RULES, Rules, partition_spec


# ---------------------------------------------------------------- specs
def test_partition_spec_basic():
    from jax.sharding import PartitionSpec as P

    r = DEFAULT_RULES
    assert partition_spec(("vocab", "embed"), r) == P("model")
    assert partition_spec(("layers", "embed", "mlp"), r) == P(None, None, "model")


def test_partition_spec_no_axis_reuse():
    from jax.sharding import PartitionSpec as P

    r = Rules.make(a="model", b="model", batch=("pod", "data"))
    # second use of "model" in one spec must be dropped
    assert partition_spec(("a", "b"), r) == P("model")
    assert partition_spec(("batch", "a"), r) == P(("pod", "data"), "model")


def test_make_rules_decode_kv_seq():
    from repro.configs import get_config

    cfg = get_config("granite-20b")  # MQA: kv=1 unshardable
    r = make_rules(cfg, "decode", global_batch=128, multi_pod=False)
    assert r.get("kv_seq") == "model"
    assert r.get("batch") == ("data",)
    r1 = make_rules(cfg, "decode", global_batch=1, multi_pod=True)
    assert r1.get("batch") is None
    assert r1.get("kv_seq") == ("data", "model")


def test_make_rules_seq_tp_vs_heads_tp():
    from repro.configs import get_config

    gem = make_rules(get_config("gemma3-4b"), "train", 256)
    assert gem.get("act_seq") == "model" and gem.get("heads") is None
    qwen = make_rules(get_config("codeqwen1.5-7b"), "train", 256)
    assert qwen.get("heads") == "model" and qwen.get("act_seq") is None


# ---------------------------------------------------------------- HLO parse
SAMPLE_HLO = """
  %ar = bf16[8,128]{1,0} all-reduce(bf16[8,128]{1,0} %x), replica_groups=[16,16]<=[256], to_apply=%add
  %ag.1 = f32[256,64]{1,0} all-gather(f32[16,64]{1,0} %y), replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}
  %rs = f32[4,32]{1,0} reduce-scatter(f32[16,32]{1,0} %z), replica_groups=[64,4]<=[256], dimensions={0}, to_apply=%add
  %cp = bf16[2,2]{1,0} collective-permute(bf16[2,2]{1,0} %w), source_target_pairs={{0,1}}
  %nothing = f32[2]{1,0} add(f32[2]{1,0} %a, f32[2]{1,0} %b)
"""


def test_parse_collectives_sample():
    ops = parse_collectives(SAMPLE_HLO)
    kinds = sorted(o.op for o in ops)
    assert kinds == ["all-gather", "all-reduce", "collective-permute", "reduce-scatter"]
    ar = next(o for o in ops if o.op == "all-reduce")
    assert ar.out_bytes == 8 * 128 * 2 and ar.group_size == 16
    assert ar.wire_bytes == pytest.approx(2 * ar.out_bytes * 15 / 16)
    ag = next(o for o in ops if o.op == "all-gather")
    assert ag.group_size == 4
    rs = next(o for o in ops if o.op == "reduce-scatter")
    assert rs.wire_bytes == pytest.approx(4 * 32 * 4 * 3)


def test_roofline_terms_bottleneck():
    t = roofline_terms(197e12, 100e9, 1e9, model_flops=197e12 * 256, n_chips=256)
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["bottleneck"] == "compute"
    assert 0 < t["roofline_fraction"] <= 1.0 + 1e-9


# ---------------------------------------------------------------- mesh (subprocess)
_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n}"
import jax, json
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config, reduced, SHAPES
from repro.train.step import make_train_bundle, make_serve_bundle
from repro.launch.mesh import make_test_mesh
import dataclasses

cfg = reduced(get_config("{arch}"))
mesh = make_test_mesh(data={data}, model={model}, pod={pod})
shape = dataclasses.replace(SHAPES["{shape}"], seq_len=64, global_batch=8)
from repro.parallel.rules import make_rules
rules = make_rules(cfg, shape.kind, shape.global_batch, multi_pod={multi_pod}, tp={model}, dp={data})
if shape.kind == "train":
    b = make_train_bundle(cfg, shape, mesh=mesh, multi_pod={multi_pod}, rules=rules)
else:
    b = make_serve_bundle(cfg, shape, mesh=mesh, multi_pod={multi_pod}, rules=rules)
named = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t, is_leaf=lambda x: isinstance(x, P))
with mesh:
    compiled = jax.jit(b.fn, in_shardings=named(b.in_shardings),
                       out_shardings=named(b.out_shardings),
                       donate_argnums=b.donate_argnums).lower(*b.abstract_inputs).compile()
ca = compiled.cost_analysis()
if isinstance(ca, (list, tuple)):  # older jax returns one dict per program
    ca = ca[0] if ca else {{}}
print(json.dumps({{"ok": True, "flops": (ca or {{}}).get("flops", 0)}}))
"""


def _run_mesh(arch, shape, data, model, pod=0, multi_pod=False):
    n = data * model * max(pod, 1)
    script = _MESH_SCRIPT.format(n=n, arch=arch, shape=shape, data=data,
                                 model=model, pod=pod, multi_pod=multi_pod)
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, cwd="/root/repo", timeout=600, env=env)
    assert r.returncode == 0, r.stderr[-3000:]
    return json.loads(r.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_small_mesh_train_compiles():
    out = _run_mesh("codeqwen1.5-7b", "train_4k", data=2, model=4)
    assert out["ok"]


@pytest.mark.slow
def test_small_multipod_mesh_train_compiles():
    out = _run_mesh("granite-moe-1b-a400m", "train_4k", data=2, model=2, pod=2,
                    multi_pod=True)
    assert out["ok"]


@pytest.mark.slow
def test_small_mesh_decode_compiles():
    out = _run_mesh("falcon-mamba-7b", "decode_32k", data=2, model=4)
    assert out["ok"]
