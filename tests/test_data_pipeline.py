"""Formats, backends, pipeline determinism/sharding/reconfiguration."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.data import (
    BACKENDS,
    DataPipeline,
    PipelineConfig,
    SyntheticTokenSource,
    TokenRecordCodec,
    open_dataset,
    write_dataset,
)
from repro.data.storage import StorageBackend


@pytest.fixture(scope="module")
def tmpfs():
    return BACKENDS["tmpfs"]


# ---------------------------------------------------------------- formats
@pytest.mark.parametrize("fmt", ["raw", "packed", "compressed", "sharded"])
def test_format_roundtrip(fmt, tmpfs):
    rng = np.random.default_rng(0)
    recs = [rng.integers(0, 255, size=64, dtype=np.uint8).tobytes() for _ in range(37)]
    man = write_dataset(tmpfs, f"t_{fmt}", recs, fmt)
    with open_dataset(tmpfs, man, block_kb=4) as r:
        assert len(r) == 37
        for i in (0, 1, 17, 36):
            assert r.read(i) == recs[i]
        got = r.read_batch([5, 2, 30])
        assert got == [recs[5], recs[2], recs[30]]


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(1, 50),
    size=st.integers(1, 2000),
    fmt=st.sampled_from(["packed", "compressed", "sharded"]),
    block_kb=st.sampled_from([1, 4, 64]),
    seed=st.integers(0, 1000),
)
def test_format_roundtrip_property(n, size, fmt, block_kb, seed):
    backend = BACKENDS["tmpfs"]
    rng = np.random.default_rng(seed)
    recs = [rng.integers(0, 255, size=size, dtype=np.uint8).tobytes() for _ in range(n)]
    man = write_dataset(backend, f"hp_{fmt}_{seed}", recs, fmt)
    with open_dataset(backend, man, block_kb=block_kb) as r:
        idx = rng.permutation(n)[: min(n, 10)]
        for i in idx:
            assert r.read(int(i)) == recs[i]


def test_simulated_backend_charges_latency(tmp_path):
    b = StorageBackend("sim", tmp_path, latency_s=2e-3, bandwidth_mb_s=100.0)
    p = b.path("x.bin")
    p.write_bytes(b"a" * 1_000_00)
    import time

    with open(p, "rb") as f:
        t0 = time.perf_counter()
        for off in range(0, 50_000, 10_000):
            b.read_block(f, off, 10_000)
        dt = time.perf_counter() - t0
    assert dt >= 5 * 2e-3  # at least the op latency


# ---------------------------------------------------------------- pipeline
def _pipe(n_hosts=1, host_id=0, **kw):
    src = SyntheticTokenSource(256, 32, 1000, seed=1)
    return DataPipeline(src, PipelineConfig(batch_size=8, **kw), host_id, n_hosts)


def test_pipeline_restart_exact():
    p1 = _pipe(shuffle=True)
    a = p1.fetch_batch(epoch=3, step=5)
    p2 = _pipe(shuffle=True)
    b = p2.fetch_batch(epoch=3, step=5)
    np.testing.assert_array_equal(a, b)


def test_pipeline_host_sharding_partition():
    full = set()
    for h in range(4):
        p = _pipe(n_hosts=4, host_id=h)
        idx = p.epoch_order(0)
        assert len(set(idx)) == len(idx)
        full |= set(int(i) for i in idx)
    assert full == set(range(256))


def test_pipeline_prefetch_iterator_matches_fetch():
    p = _pipe(num_workers=2, prefetch_depth=3)
    batches = []
    it = p.iter_epoch(0)
    for i, b in enumerate(it):
        batches.append(b)
        if i == 4:
            it.close()
            break
    for s, b in enumerate(batches):
        np.testing.assert_array_equal(b, p.fetch_batch(0, s))
    p.close()


def test_pipeline_reconfigure_preserves_order():
    p = _pipe(num_workers=0)
    before = p.fetch_batch(0, 2)
    p.reconfigure(num_workers=2, prefetch_depth=4)
    after = p.fetch_batch(0, 2)
    np.testing.assert_array_equal(before, after)
    assert p.config.num_workers == 2
    p.close()


def test_codec_roundtrip():
    c = TokenRecordCodec(16)
    t = np.arange(16, dtype=np.int32)
    assert np.array_equal(c.decode(c.encode(t)), t)


# ---------------------------------------------------------------- telemetry
def test_telemetry_ratio():
    import time

    from repro.data import StepTelemetry

    t = StepTelemetry()
    for _ in range(3):
        with t.data_wait():
            time.sleep(0.01)
        with t.compute():
            time.sleep(0.03)
        t.record_batch(8, 8 * 1024)
    r = t.data_loading_ratio()
    assert 0.1 < r < 0.45
    assert t.simulated_utilization() == pytest.approx(1 - r)
    f = t.features(batch_size=8, num_workers=0)
    assert f["samples_per_second"] > 0


def test_image_and_tabular_codecs_pipeline(tmpfs):
    """Paper §3.1.2 modalities: CIFAR-style images + tabular rows through the
    full format+pipeline stack."""
    from repro.data import ImageRecordCodec, TabularRecordCodec

    rng = np.random.default_rng(0)
    img_codec = ImageRecordCodec()
    imgs = [rng.integers(0, 255, (32, 32, 3), dtype=np.uint8) for _ in range(40)]
    man = write_dataset(tmpfs, "imgs", [img_codec.encode(i) for i in imgs], "packed")
    with open_dataset(tmpfs, man) as r:

        class Src:
            def __len__(self):
                return len(r)

            def read(self, i):
                return img_codec.decode(r.read(i))

            def record_nbytes(self):
                return img_codec.nbytes

        pipe = DataPipeline(Src(), PipelineConfig(batch_size=8))
        batch = pipe.fetch_batch(0, 0)
        assert batch.shape == (8, 32, 32, 3) and batch.dtype == np.uint8
        idx = pipe.batch_indices(0, 0)
        np.testing.assert_array_equal(batch[0], imgs[int(idx[0])])

    tab_codec = TabularRecordCodec(11)
    rows = [rng.normal(size=11).astype(np.float32) for _ in range(20)]
    man = write_dataset(tmpfs, "tab", [tab_codec.encode(x) for x in rows], "compressed")
    with open_dataset(tmpfs, man) as r:
        got = tab_codec.decode(r.read(7))
        np.testing.assert_array_equal(got, rows[7])
