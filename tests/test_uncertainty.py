"""Beyond-paper extensions: prediction intervals + stacking (paper §5.4)."""

import numpy as np

from repro.core import (
    ConformalRegressor,
    GBTConfig,
    GBTRegressor,
    RandomForestRegressor,
    RFConfig,
    Ridge,
    StackingRegressor,
    r2_score,
    rf_prediction_interval,
    train_test_split,
)


def _data(n=400, noise=0.3, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.uniform(-2, 2, (n, 6))
    y = np.sin(2 * X[:, 0]) + X[:, 1] ** 2 + noise * rng.normal(size=n)
    return X, y


def test_rf_interval_coverage_and_order():
    X, y = _data()
    tr, te = train_test_split(X.shape[0])
    m = RandomForestRegressor(RFConfig(n_estimators=60)).fit(X[tr], y[tr])
    lo, mid, hi = rf_prediction_interval(m, X[te], alpha=0.2)
    assert np.all(lo <= mid + 1e-9) and np.all(mid <= hi + 1e-9)
    # intervals should have nonzero width on noisy data
    assert (hi - lo).mean() > 0.01


def test_conformal_coverage():
    X, y = _data(n=600, noise=0.5)
    tr, te = train_test_split(X.shape[0])
    cr = ConformalRegressor(GBTRegressor(GBTConfig(n_estimators=40)), calib_frac=0.3)
    cr.fit(X[tr], y[tr], alpha=0.1)
    lo, mid, hi = cr.predict_interval(X[te])
    cover = float(np.mean((y[te] >= lo) & (y[te] <= hi)))
    # split-conformal guarantees >= 1-alpha marginal coverage in expectation;
    # allow finite-sample slack
    assert cover >= 0.80, cover


def test_stacking_beats_or_matches_components():
    X, y = _data(n=500, noise=0.4, seed=3)
    tr, te = train_test_split(X.shape[0])
    makers = {
        "gbt": lambda: GBTRegressor(GBTConfig(n_estimators=30, max_depth=3)),
        "rf": lambda: RandomForestRegressor(RFConfig(n_estimators=20, max_depth=6)),
        "ridge": lambda: Ridge(1.0),
    }
    stack = StackingRegressor(makers, k=4).fit(X[tr], y[tr])
    r2_stack = r2_score(y[te], stack.predict(X[te]))
    r2_best = max(
        r2_score(y[te], mk().fit(X[tr], y[tr]).predict(X[te])) for mk in makers.values()
    )
    assert r2_stack > r2_best - 0.05  # stacking ~matches or beats the best base
