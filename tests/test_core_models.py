"""Unit + property tests for the predictive-modeling core (the paper's zoo)."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    PCA,
    ElasticNet,
    GBTConfig,
    GBTRegressor,
    Lasso,
    LinearRegression,
    MLPConfig,
    MLPRegressor,
    RandomForestRegressor,
    RFConfig,
    Ridge,
    StandardScaler,
    cross_val_r2,
    expm1_inverse,
    log1p_transform,
    r2_score,
    rmse,
    train_test_split,
)
from repro.core.ensemble_base import predict_ensemble, predict_ensemble_np


# ---------------------------------------------------------------- linear
def test_linear_exact_recovery():
    rng = np.random.default_rng(0)
    X = rng.normal(size=(100, 5))
    beta = np.array([1.0, -2.0, 3.0, 0.5, -1.5])
    y = X @ beta + 4.0
    m = LinearRegression().fit(X, y)
    np.testing.assert_allclose(m.coef_, beta, atol=1e-5)
    assert abs(m.intercept_ - 4.0) < 1e-5


def test_ridge_shrinks_vs_ols():
    rng = np.random.default_rng(1)
    X = rng.normal(size=(40, 10))
    y = rng.normal(size=40)
    ols = LinearRegression().fit(X, y)
    ridge = Ridge(alpha=100.0).fit(X, y)
    assert np.linalg.norm(ridge.coef_) < np.linalg.norm(ols.coef_)


def test_lasso_sparsity():
    rng = np.random.default_rng(2)
    X = rng.normal(size=(120, 8))
    y = 3 * X[:, 0] - 2 * X[:, 1] + 0.01 * rng.normal(size=120)
    m = Lasso(alpha=0.5, n_iter=4000).fit(X, y)
    # irrelevant coefficients driven to (near) zero
    assert np.all(np.abs(m.coef_[2:]) < 1e-2)
    assert abs(m.coef_[0]) > 1.0


def test_elasticnet_between():
    rng = np.random.default_rng(3)
    X = rng.normal(size=(80, 6))
    y = X @ np.arange(1.0, 7.0)
    for m in (Lasso(0.1), ElasticNet(0.1, 0.5)):
        m.fit(X, y)
        assert r2_score(y, m.predict(X)) > 0.95


# ---------------------------------------------------------------- trees
def test_gbt_fits_nonlinear(synth_regression):
    X, y = synth_regression
    m = GBTRegressor(GBTConfig(n_estimators=80)).fit(X, y)
    assert r2_score(y, m.predict(X)) > 0.95
    imp = m.feature_importances_
    assert imp.shape == (11,) and abs(imp.sum() - 1.0) < 1e-6
    # true drivers are features 0..3
    assert set(np.argsort(imp)[::-1][:4]) == {0, 1, 2, 3}


def test_gbt_more_rounds_reduce_train_error(synth_regression):
    X, y = synth_regression
    errs = []
    for n in (5, 20, 80):
        m = GBTRegressor(GBTConfig(n_estimators=n, subsample=1.0)).fit(X, y)
        errs.append(rmse(y, m.predict(X)))
    assert errs[0] > errs[1] > errs[2]


def test_gbt_jax_predict_matches_numpy(synth_regression):
    X, y = synth_regression
    m = GBTRegressor(GBTConfig(n_estimators=15, max_depth=4)).fit(X, y)
    jax_pred = np.asarray(predict_ensemble(m.ensemble, X.astype(np.float32)))
    np_pred = predict_ensemble_np(m.ensemble, X)
    np.testing.assert_allclose(jax_pred, np_pred, rtol=1e-4, atol=1e-4)


def test_rf_fits_and_importances(synth_regression):
    X, y = synth_regression
    m = RandomForestRegressor(RFConfig(n_estimators=30)).fit(X, y)
    assert r2_score(y, m.predict(X)) > 0.8
    assert set(np.argsort(m.feature_importances_)[::-1][:4]) == {0, 1, 2, 3}


def test_gbt_binary_classifier():
    from repro.core import GBTBinaryClassifier

    rng = np.random.default_rng(5)
    X = rng.normal(size=(300, 4))
    y = (X[:, 0] + X[:, 1] ** 2 > 0.5).astype(np.float64)
    m = GBTBinaryClassifier(GBTConfig(n_estimators=30, max_depth=3)).fit(X, y)
    assert (m.predict(X) == y).mean() > 0.95


# ---------------------------------------------------------------- mlp
def test_mlp_learns_linear():
    rng = np.random.default_rng(6)
    X = rng.normal(size=(400, 5)).astype(np.float32)
    y = X @ np.array([1, 2, 3, 4, 5.0]) * 0.1
    m = MLPRegressor(MLPConfig(max_epochs=100, patience=20)).fit(X, y)
    assert r2_score(y, m.predict(X)) > 0.9


# ---------------------------------------------------------------- features
def test_scaler_roundtrip():
    rng = np.random.default_rng(7)
    X = rng.normal(3.0, 5.0, size=(50, 4))
    sc = StandardScaler()
    Xs = sc.fit_transform(X)
    np.testing.assert_allclose(Xs.mean(0), 0, atol=1e-9)
    np.testing.assert_allclose(Xs.std(0), 1, atol=1e-9)
    np.testing.assert_allclose(sc.inverse_transform(Xs), X, atol=1e-9)


def test_pca_properties():
    rng = np.random.default_rng(8)
    X = rng.normal(size=(60, 6)) @ rng.normal(size=(6, 6))
    p = PCA().fit(X)
    # components orthonormal
    G = p.components_ @ p.components_.T
    np.testing.assert_allclose(G, np.eye(6), atol=1e-4)  # f32 SVD
    # ratios sorted and sum to 1
    r = p.explained_variance_ratio_
    assert np.all(np.diff(r) <= 1e-6) and abs(r.sum() - 1.0) < 1e-5
    # full reconstruction
    Z = p.transform(X)
    np.testing.assert_allclose(p.inverse_transform(Z), X, atol=1e-3)
    assert 1 <= p.n_components_for_variance(0.8) <= 6


def test_log1p_roundtrip():
    y = np.array([0.0, 1.1, 48211.0])
    np.testing.assert_allclose(expm1_inverse(log1p_transform(y)), y, rtol=1e-12)


# ---------------------------------------------------------------- metrics
def test_split_and_cv_protocol():
    tr, te = train_test_split(141, 0.2, seed=42)
    assert len(te) == 28 and len(tr) == 113
    assert len(set(tr) & set(te)) == 0


def test_r2_perfect_and_mean():
    y = np.arange(10.0)
    assert r2_score(y, y) == 1.0
    assert abs(r2_score(y, np.full(10, y.mean()))) < 1e-12


# ---------------------------------------------------------------- hypothesis
@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(30, 120),
    d=st.integers(2, 8),
    seed=st.integers(0, 10_000),
)
def test_gbt_train_r2_nonneg_property(n, d, seed):
    """Boosting from the mean must never fit worse than the mean."""
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, d))
    y = rng.normal(size=n)
    m = GBTRegressor(GBTConfig(n_estimators=10, max_depth=3, subsample=1.0)).fit(X, y)
    assert r2_score(y, m.predict(X)) >= -1e-9


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(20, 100),
    scale=st.floats(0.1, 100.0),
    seed=st.integers(0, 10_000),
)
def test_scaler_invariance_property(n, scale, seed):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, 3)) * scale
    sc = StandardScaler()
    Xs = sc.fit_transform(X)
    np.testing.assert_allclose(sc.inverse_transform(Xs), X, rtol=1e-9, atol=1e-7 * scale)
