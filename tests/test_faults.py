"""Unit tests for the deterministic fault-injection layer
(``repro.service.faults``): scheduling determinism, per-stream independence,
the site hooks' failure semantics, plan serialization + env hand-off, and
process-global activation into the data layer."""

import json
import os

import pytest

from repro.data import campaign, storage
from repro.service import faults
from repro.service.faults import (
    ENV_VAR,
    FaultInjected,
    FaultPlan,
    FaultSpec,
    default_plan,
)


@pytest.fixture(autouse=True)
def _no_leaked_plan():
    """Every test leaves the process chaos-free (hooks + env var)."""
    yield
    faults.deactivate()
    assert os.environ.get(ENV_VAR) is None


# ------------------------------------------------------------- scheduling

def test_every_schedule_fires_each_kth_check():
    plan = FaultPlan(7, [FaultSpec("io_error", site="case:", every=3)])
    fired = []
    for i in range(12):
        try:
            plan.on_case("case:c0")
            fired.append(False)
        except FaultInjected:
            fired.append(True)
    assert fired == [False, False, True] * 4
    assert plan.total_injected("io_error") == 4


def test_every_schedule_never_fires_twice_in_a_row():
    """The healing guarantee: with every >= 2 a retried attempt (the very
    next check of the stream) cannot hit the same injected fault again."""
    plan = FaultPlan(3, [FaultSpec("io_error", site="case:", every=2)])
    prev = False
    for _ in range(50):
        try:
            plan.on_case("case:x")
            now = False
        except FaultInjected:
            now = True
        assert not (prev and now)
        prev = now


def test_rate_schedule_is_seed_deterministic():
    def draw(seed):
        plan = FaultPlan(seed, [FaultSpec("io_error", site="case:", rate=0.5)])
        out = []
        for _ in range(64):
            try:
                plan.on_case("case:any")
                out.append(0)
            except FaultInjected:
                out.append(1)
        return out

    a, b, c = draw(11), draw(11), draw(12)
    assert a == b
    assert a != c          # astronomically unlikely to collide over 64 draws
    assert 0 < sum(a) < 64  # rate=0.5 actually fires sometimes, not always


def test_streams_are_independent_per_site_class():
    """Checks against one site class must not advance another class's
    schedule — a chatty storage backend cannot starve or accelerate the
    campaign-case stream."""
    spec = [FaultSpec("io_error", every=3)]  # site="" matches everything
    lone = FaultPlan(5, list(spec))
    mixed = FaultPlan(5, list(spec))
    lone_fires = []
    for _ in range(9):
        try:
            lone.on_case("case:a")
            lone_fires.append(False)
        except FaultInjected:
            lone_fires.append(True)
    mixed_fires = []
    for _ in range(9):
        try:  # interleaved other-class checks (fire on their own stream)
            mixed.on_storage("read:file", 4096)
        except FaultInjected:
            pass
        try:
            mixed.on_case("case:a")
            mixed_fires.append(False)
        except FaultInjected:
            mixed_fires.append(True)
    assert mixed_fires == lone_fires


def test_max_injections_budget():
    plan = FaultPlan(1, [FaultSpec("io_error", site="case:", every=2,
                                   max_injections=2)])
    n = 0
    for _ in range(20):
        try:
            plan.on_case("case:z")
        except FaultInjected:
            n += 1
    assert n == 2
    assert plan.total_injected() == 2


# ------------------------------------------------------------- site hooks

def test_check_append_enospc_and_torn_offsets():
    plan = FaultPlan(9, [FaultSpec("enospc", site="append:", every=2)])
    assert plan.check_append("append:f.jsonl") is None
    with pytest.raises(OSError) as ei:
        plan.check_append("append:f.jsonl")
    assert "ENOSPC" in str(ei.value) or ei.value.errno is not None

    torn_plan = FaultPlan(9, [FaultSpec("torn_write", site="append:", every=2)])
    assert torn_plan.check_append("append:f.jsonl") is None
    torn = torn_plan.check_append("append:f.jsonl")
    assert isinstance(torn, int) and 1 <= torn <= 16


def test_corrupt_line_is_not_valid_json():
    plan = FaultPlan(2, [FaultSpec("corrupt_line", site="log:", every=2)])
    assert plan.corrupt_line("log:state.jsonl") is None
    garbage = plan.corrupt_line("log:state.jsonl")
    assert garbage is not None
    with pytest.raises(json.JSONDecodeError):
        json.loads(garbage)


def test_spec_validation():
    with pytest.raises(ValueError):
        FaultSpec("not_a_kind", every=2)
    with pytest.raises(ValueError):
        FaultSpec("io_error")  # neither every nor rate
    with pytest.raises(ValueError):
        FaultSpec("io_error", every=2, rate=0.5)  # both
    with pytest.raises(ValueError):
        FaultSpec("io_error", every=1)  # a retry could re-hit it
    FaultSpec("latency", every=1)  # latency never needs the healing bound


# ------------------------------------------------- serialization + env

def test_plan_round_trips_through_json_and_env():
    plan = default_plan(42, every=3)
    clone = FaultPlan.from_json(plan.to_json())
    assert clone.seed == plan.seed
    assert clone.specs == plan.specs

    faults.activate(plan)
    assert os.environ.get(ENV_VAR)
    faults.deactivate()
    assert faults.active_plan() is None

    os.environ[ENV_VAR] = plan.to_json()
    inherited = faults.activate_from_env()
    assert inherited is not None and inherited.specs == plan.specs
    assert faults.active_plan() is inherited


def test_activate_from_env_without_export_is_noop():
    os.environ.pop(ENV_VAR, None)
    assert faults.activate_from_env() is None
    assert faults.active_plan() is None


def test_activation_installs_and_removes_data_layer_hooks():
    assert campaign._FAULT_HOOK is None
    assert storage._FAULT_HOOK is None
    plan = faults.activate(default_plan(1, every=5))
    assert campaign._FAULT_HOOK is plan
    assert storage._FAULT_HOOK is not None
    faults.deactivate()
    assert campaign._FAULT_HOOK is None
    assert storage._FAULT_HOOK is None


def test_report_ledger_counts_per_kind_and_site():
    plan = FaultPlan(4, [FaultSpec("io_error", site="case:", every=2),
                         FaultSpec("corrupt_line", site="log:", every=2)])
    for _ in range(4):
        try:
            plan.on_case("case:a")
        except FaultInjected:
            pass
        plan.corrupt_line("log:s.jsonl")
    rep = plan.report()
    assert rep["seed"] == 4
    assert rep["by_kind"] == {"corrupt_line": 2, "io_error": 2}
    assert rep["total"] == 4
    assert rep["by_site"]["io_error@case:a"] == 2
