"""Campaign subsystem semantics: registry expansion (the paper's 84/52/5),
resume-after-kill, failure re-run, shard partitioning, and schema stability
of ``collect_observations`` vs. the seed implementation."""

import json

import pytest

from repro.core.features import FEATURE_NAMES, TARGET_NAME
from repro.data.campaign import (
    RunContext,
    completed_keys,
    format_backends,
    load_records,
    main as campaign_main,
    run_campaign,
    shard_cases,
    summarize,
)
from repro.data.registry import (
    BenchCase,
    CAMPAIGNS,
    Campaign,
    get_campaign,
    matrix_cases,
)

# ---------------------------------------------------------------- registry


def test_paper_campaigns_reproduce_fig2_split():
    assert len(get_campaign("paper_random_access").cases()) == 84
    assert len(get_campaign("paper_pipeline").cases()) == 52
    assert len(get_campaign("paper_concurrent").cases()) == 5
    core = get_campaign("paper_core").cases()
    assert len(core) == 141
    assert len({c.id for c in core}) == 141  # globally unique ids


def test_paper_core_is_concatenation_in_order():
    core = [c.id for c in get_campaign("paper_core").cases()]
    parts = [
        c.id
        for name in ("paper_random_access", "paper_pipeline", "paper_concurrent")
        for c in get_campaign(name).cases()
    ]
    assert core == parts


def test_extended_campaign_hits_future_work_band():
    cases = get_campaign("extended").cases()
    assert 500 <= len(cases) <= 1000
    assert len({c.id for c in cases}) == len(cases)
    # sweeps all four formats and all four backends
    assert {c.format for c in cases if c.bench_type == "pipeline"} == {
        "raw", "packed", "compressed", "sharded"}
    assert {c.backend for c in cases} == {"tmpfs", "disk", "network_sim", "object_sim"}


def test_fast_mode_ids_are_subset_schema():
    for name in ("paper_random_access", "paper_pipeline", "paper_concurrent"):
        fast = get_campaign(name).cases(fast=True)
        assert 0 < len(fast) < len(get_campaign(name).cases())
        assert len({c.id for c in fast}) == len(fast)


def test_bench_case_validation():
    with pytest.raises(ValueError):
        BenchCase(id="x", bench_type="nope")
    with pytest.raises(ValueError):
        BenchCase(id="", bench_type="pipeline")
    with pytest.raises(ValueError):
        BenchCase(id="x", bench_type="pipeline", repeats=0)


def test_matrix_cases_expansion():
    cases = matrix_cases(
        "pipeline", id_prefix="m", backend=["tmpfs", "disk"],
        format=["raw", "packed"], batch_size=[16, 32],
    )
    assert len(cases) == 8
    assert len({c.id for c in cases}) == 8
    assert cases[0].bench_type == "pipeline"


def test_duplicate_case_ids_rejected():
    camp = Campaign("dup", "", lambda fast=False: (
        BenchCase(id="a", bench_type="pipeline"),
        BenchCase(id="a", bench_type="pipeline"),
    ))
    with pytest.raises(ValueError, match="duplicate"):
        camp.cases()


# ---------------------------------------------------------------- sharding


@pytest.mark.parametrize("n_shards", [1, 2, 3, 5])
def test_shards_disjoint_and_complete(n_shards):
    cases = get_campaign("paper_core").cases()
    parts = [shard_cases(cases, h, n_shards) for h in range(n_shards)]
    ids = [c.id for p in parts for c in p]
    assert sorted(ids) == sorted(c.id for c in cases)  # complete
    assert len(set(ids)) == len(ids)  # disjoint


def test_shard_out_of_range():
    with pytest.raises(ValueError):
        shard_cases([], 2, 2)


# ---------------------------------------------------------------- runner
# A fake executor lets us test run/resume/shard semantics without real I/O.


def _fake_campaign(n=8):
    return Campaign(
        "fake", "test campaign",
        lambda fast=False: tuple(
            BenchCase(id=f"case-{i:02d}", bench_type="concurrent", backend="tmpfs")
            for i in range(n)
        ),
    )


def _ok_executor(log):
    def ex(case, ctx, seed):
        log.append(case.id)
        return {TARGET_NAME: 1.0, "bench_type": case.bench_type, "backend": case.backend}
    return ex


def test_killed_run_resumes_only_remaining(tmp_path):
    """Acceptance: kill mid-way, resume completes exactly the remaining cases."""
    camp = _fake_campaign(8)
    out = tmp_path / "fake.jsonl"
    first, second = [], []
    r1 = run_campaign(camp, out, executor=_ok_executor(first), max_cases=3)
    assert r1.n_executed == 3 and first == ["case-00", "case-01", "case-02"]
    r2 = run_campaign(camp, out, executor=_ok_executor(second))
    assert second == [f"case-{i:02d}" for i in range(3, 8)]  # only the remaining 5
    assert r2.skipped == 3
    assert len(completed_keys(load_records(out))) == 8


def test_resume_reruns_failed_cases(tmp_path):
    camp = _fake_campaign(4)
    out = tmp_path / "fake.jsonl"

    def flaky(case, ctx, seed):
        if case.id == "case-02":
            raise RuntimeError("injected benchmark crash")
        return {TARGET_NAME: 2.0, "bench_type": case.bench_type, "backend": case.backend}

    r1 = run_campaign(camp, out, executor=flaky)
    assert r1.failures == [("case-02", 0)]
    (err,) = r1.errors  # details travel on the result, not just the JSONL
    assert err["type"] == "RuntimeError" and "injected" in err["message"]
    recs = load_records(out)
    err = [r for r in recs if r["status"] == "error"]
    assert len(err) == 1 and err[0]["error"]["type"] == "RuntimeError"
    assert "injected" in err[0]["error"]["message"]

    rerun = []
    r2 = run_campaign(camp, out, executor=_ok_executor(rerun))
    assert rerun == ["case-02"]  # only the failed case re-runs
    assert r2.skipped == 3 and not r2.failures


def test_repeats_tracked_per_rep(tmp_path):
    camp = Campaign("rep", "", lambda fast=False: (
        BenchCase(id="only", bench_type="concurrent", repeats=3),))
    out = tmp_path / "rep.jsonl"
    log = []
    run_campaign(camp, out, executor=_ok_executor(log), max_cases=2)
    r2 = run_campaign(camp, out, executor=_ok_executor(log))
    assert r2.skipped == 2 and r2.n_executed == 1
    assert {(r["case_id"], r["rep"]) for r in load_records(out)} == {
        ("only", 0), ("only", 1), ("only", 2)}


def test_torn_trailing_line_is_dropped(tmp_path):
    camp = _fake_campaign(3)
    out = tmp_path / "fake.jsonl"
    run_campaign(camp, out, executor=_ok_executor([]), max_cases=2)
    with open(out, "a") as f:
        f.write('{"case_id": "case-02", "status": "ok"')  # no newline, invalid JSON
    assert len(load_records(out)) == 2
    log = []
    run_campaign(camp, out, executor=_ok_executor(log))
    assert log == ["case-02"]


def test_shard_runs_write_disjoint_files(tmp_path):
    camp = _fake_campaign(7)
    seen = []
    for h in range(3):
        run_campaign(camp, tmp_path / f"s{h}.jsonl", shard=(h, 3),
                     executor=_ok_executor(seen))
    assert sorted(seen) == [f"case-{i:02d}" for i in range(7)]
    recs = [r for h in range(3) for r in load_records(tmp_path / f"s{h}.jsonl")]
    assert {r["shard"] for r in recs} == {"0/3", "1/3", "2/3"}


def test_provenance_fields_present(tmp_path):
    camp = _fake_campaign(1)
    out = tmp_path / "p.jsonl"
    run_campaign(camp, out, executor=_ok_executor([]), seed=7)
    (rec,) = load_records(out)
    for field in ("schema_version", "campaign", "case_id", "rep", "seed",
                  "shard", "host", "git", "case", "status", "row", "elapsed_s"):
        assert field in rec, field
    assert rec["seed"] == 7
    assert rec["case"]["id"] == "case-00"


def test_new_seed_collects_fresh_rows(tmp_path):
    """Same campaign + same file + new seed appends rows instead of no-opping."""
    camp = _fake_campaign(3)
    out = tmp_path / "seeds.jsonl"
    run_campaign(camp, out, executor=_ok_executor([]), seed=0)
    log = []
    r2 = run_campaign(camp, out, executor=_ok_executor(log), seed=5)
    assert len(log) == 3 and r2.skipped == 0  # seed 5 is a fresh collection
    r3 = run_campaign(camp, out, executor=_ok_executor([]), seed=5)
    assert r3.skipped == 3 and r3.n_executed == 0  # same seed resumes
    assert len(load_records(out)) == 6


def test_midfile_corruption_warns_not_silently_drops(tmp_path, capsys):
    camp = _fake_campaign(3)
    out = tmp_path / "c.jsonl"
    run_campaign(camp, out, executor=_ok_executor([]))
    lines = out.read_text().splitlines()
    lines[1] = lines[1][:20]  # corrupt a mid-file line
    out.write_text("\n".join(lines) + "\n")
    recs = load_records(out)
    assert len(recs) == 2
    assert "malformed JSONL" in capsys.readouterr().err


# ---------------------------------------------------------------- summarize


def test_summarize_groups_and_failures(tmp_path):
    camp = _fake_campaign(5)
    out = tmp_path / "s.jsonl"

    def flaky(case, ctx, seed):
        if case.id.endswith("04"):
            raise ValueError("boom")
        return {TARGET_NAME: 10.0, "bench_type": case.bench_type, "backend": case.backend}

    run_campaign(camp, out, executor=flaky)
    report = summarize(load_records(out))
    assert report["n_ok"] == 4 and report["n_failed"] == 1
    (g,) = report["groups"].values()
    assert g["target_throughput_mb_s"]["count"] == 4
    assert g["target_throughput_mb_s"]["mean"] == pytest.approx(10.0)
    assert g["failures"] == 1
    # a successful resume re-run supersedes the stale error record
    run_campaign(camp, out, executor=_ok_executor([]))
    report = summarize(load_records(out))
    assert report["n_ok"] == 5 and report["n_failed"] == 0
    (g,) = report["groups"].values()
    assert g["failures"] == 0


def test_summarize_by_backend_breakdown(tmp_path):
    """Per-backend rows/error-rate breakdown (transfer-split auditability)."""
    camp = Campaign(
        "multi", "two-backend campaign",
        lambda fast=False: tuple(
            BenchCase(id=f"m-{i:02d}", bench_type="concurrent",
                      backend="tmpfs" if i % 2 == 0 else "disk")
            for i in range(6)
        ),
    )
    out = tmp_path / "mb.jsonl"

    def flaky(case, ctx, seed):
        if case.backend == "disk" and case.id.endswith("05"):
            raise ValueError("disk boom")
        return {TARGET_NAME: 5.0, "bench_type": case.bench_type,
                "backend": case.backend}

    run_campaign(camp, out, executor=flaky)
    report = summarize(load_records(out), corrupt_lines=2)
    assert sorted(report["backends"]) == ["disk", "tmpfs"]
    assert report["backends"]["tmpfs"] == {
        "rows": 3, "failures": 0, "quarantined": 0, "retried": 0,
        "error_rate": 0.0,
    }
    disk = report["backends"]["disk"]
    assert disk["rows"] == 2 and disk["failures"] == 1
    assert disk["error_rate"] == pytest.approx(1 / 3, abs=1e-6)
    # corrupt_lines is file-level, surfaced alongside (not split across) backends
    assert report["corrupt_lines"] == 2
    table = format_backends(report)
    assert "corrupt_lines=2" in table and "disk" in table and "tmpfs" in table


def test_cli_summarize_by_backend(tmp_path, capsys):
    camp = Campaign(
        "multi2", "two-backend campaign",
        lambda fast=False: tuple(
            BenchCase(id=f"n-{i:02d}", bench_type="concurrent",
                      backend="tmpfs" if i < 2 else "disk")
            for i in range(4)
        ),
    )
    out = tmp_path / "nb.jsonl"
    run_campaign(camp, out, executor=_ok_executor([]))
    assert campaign_main(["summarize", "--out", str(out), "--by-backend"]) == 0
    text = capsys.readouterr().out
    assert "backend" in text and "err_rate" in text
    assert campaign_main(
        ["summarize", "--out", str(out), "--by-backend", "--json"]) == 0
    payload = json.loads(capsys.readouterr().out)
    assert payload == {
        "tmpfs": {"rows": 2, "failures": 0, "quarantined": 0, "retried": 0,
                  "error_rate": 0.0},
        "disk": {"rows": 2, "failures": 0, "quarantined": 0, "retried": 0,
                 "error_rate": 0.0},
    }


# ---------------------------------------------------------------- end-to-end


def test_real_run_concurrent_fast_jsonl(tmp_path):
    """A real (tiny) campaign through the JSONL store, then resume no-ops."""
    out = tmp_path / "cc.jsonl"
    r1 = run_campaign("paper_concurrent", out, fast=True)
    assert r1.n_executed == 2 and not r1.failures
    rows = [r["row"] for r in load_records(out)]
    for row in rows:
        assert row[TARGET_NAME] > 0
        assert set(FEATURE_NAMES) <= set(row)
    r2 = run_campaign("paper_concurrent", out, fast=True)
    assert r2.n_executed == 0 and r2.skipped == 2


def test_collect_observations_schema_unchanged(obs_fast):
    """The seed row schema survives the campaign refactor (acceptance)."""
    rows, cols = obs_fast
    assert len(rows) == 26  # seed fast-mode count: 8 ra + 16 pl + 2 cc
    base = set(FEATURE_NAMES) | {TARGET_NAME, "bench_type", "backend"}
    # measured knob/telemetry columns added after the seed (deliberate
    # features, consumed by the autotuner) — anything else is leakage
    known_extras = {"format", "utilization", "access", "data_wait_s",
                    "prefetch_policy", "lookahead_batches", "cache_budget_mb"}
    for row in rows:
        extra = set(row) - base - known_extras
        assert not extra, extra  # no provenance leakage into observation rows
        assert base <= set(row)
    assert set(cols) == set(FEATURE_NAMES) | {TARGET_NAME}
    assert {r["bench_type"] for r in rows} == {"io_random", "pipeline", "concurrent"}


def test_cli_list_and_summarize(tmp_path, capsys):
    assert campaign_main(["list"]) == 0
    assert "paper_core" in capsys.readouterr().out
    out = tmp_path / "cc.jsonl"
    run_campaign("paper_concurrent", out, fast=True)
    assert campaign_main(["summarize", "--out", str(out)]) == 0
    assert "concurrent/tmpfs" in capsys.readouterr().out
    assert campaign_main(["summarize", "--out", str(out), "--json"]) == 0
    assert json.loads(capsys.readouterr().out)["n_ok"] == 2


def test_campaign_registry_is_extensible():
    from repro.data.registry import register_campaign

    name = "test_tmp_campaign"
    try:
        @register_campaign(name, "scratch")
        def _tmp(fast=False):
            return [BenchCase(id="t0", bench_type="concurrent")]

        assert len(get_campaign(name).cases()) == 1
        with pytest.raises(ValueError, match="already registered"):
            register_campaign(name, "dup")(_tmp)
    finally:
        CAMPAIGNS.pop(name, None)


# ---------------------------------------------------------------- merge


def _rec(case_id, rep, seed, status="ok", mark=None):
    return {
        "case_id": case_id, "rep": rep, "seed": seed, "status": status,
        "row": {TARGET_NAME: 1.0} if status == "ok" else None,
        "case": {"bench_type": "concurrent", "backend": "tmpfs"},
        "mark": mark,
    }


def test_merge_records_keeps_latest_per_key():
    from repro.data.campaign import merge_records

    recs = [
        _rec("a", 0, 0, status="error", mark=1),
        _rec("b", 0, 0, mark=2),
        _rec("a", 0, 0, mark=3),       # supersedes the error record
        _rec("a", 0, 7, mark=4),       # different seed: kept separately
        _rec("a", 1, 0, mark=5),       # different rep: kept separately
        _rec("b", 0, 0, mark=6),       # supersedes mark=2
    ]
    merged = merge_records(recs)
    by_key = {(r["case_id"], r["rep"], r["seed"]): r["mark"] for r in merged}
    assert len(merged) == 4
    assert by_key[("a", 0, 0)] == 3
    assert by_key[("b", 0, 0)] == 6
    assert by_key[("a", 0, 7)] == 4
    assert by_key[("a", 1, 0)] == 5
    # stable first-seen key order
    assert [r["mark"] for r in merged] == [3, 6, 4, 5]


def test_merge_files_dedups_across_shards(tmp_path):
    """Two shard files + an overlapping re-run merge to one record per key."""
    from repro.data.campaign import merge_files

    log = []
    for shard in (0, 1):
        run_campaign(_fake_campaign(6), tmp_path / f"s{shard}.jsonl",
                     shard=(shard, 2), executor=_ok_executor(log))
    # simulate a re-collection of shard 0 with a new seed appended to s0
    run_campaign(_fake_campaign(6), tmp_path / "s0.jsonl", shard=(0, 2),
                 seed=9, executor=_ok_executor(log))
    n_read, merged_ret = merge_files(
        [tmp_path / "s0.jsonl", tmp_path / "s1.jsonl"], tmp_path / "merged.jsonl")
    merged = load_records(tmp_path / "merged.jsonl")
    assert n_read == 9 and len(merged_ret) == 9  # 6 cases + 3 seed-9 re-runs
    assert merged == merged_ret  # what was returned is what was written
    keys = {(r["case_id"], r["rep"], r["seed"]) for r in merged}
    assert len(keys) == len(merged) == 9
    # merging the merged file with a shard again is idempotent
    n_read2, merged2 = merge_files(
        [tmp_path / "merged.jsonl", tmp_path / "s1.jsonl"], tmp_path / "m2.jsonl")
    assert len(merged2) == 9
    rep = summarize(load_records(tmp_path / "m2.jsonl"))
    assert rep["n_ok"] == 9 and rep["n_failed"] == 0


def test_cli_merge(tmp_path, capsys):
    log = []
    for shard in (0, 1):
        run_campaign(_fake_campaign(4), tmp_path / f"s{shard}.jsonl",
                     shard=(shard, 2), executor=_ok_executor(log))
    rc = campaign_main(
        ["merge", str(tmp_path / "s0.jsonl"), str(tmp_path / "s1.jsonl"),
         "--out", str(tmp_path / "all.jsonl")])
    assert rc == 0
    out = capsys.readouterr().out
    assert "4 records -> 4 unique" in out
    assert len(load_records(tmp_path / "all.jsonl")) == 4
    rc = campaign_main(["merge", str(tmp_path / "nope.jsonl"),
                        "--out", str(tmp_path / "x.jsonl")])
    assert rc == 2
