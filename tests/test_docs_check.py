"""The docs-check tool (tools/docs_check.py, `make docs-check`): the repo's
own docs must pass, and deliberately broken docs must fail — a broken link,
an undefined CLI flag, and an unimportable module each trip it."""

import importlib.util
import pathlib
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent


def _tool():
    spec = importlib.util.spec_from_file_location(
        "docs_check", ROOT / "tools" / "docs_check.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _tree(tmp_path, readme: str) -> pathlib.Path:
    (tmp_path / "docs").mkdir(exist_ok=True)
    (tmp_path / "README.md").write_text(readme)
    # the real package tree, so `python -m repro...` resolves under the
    # fake doc root and only the *documented flags* are wrong
    (tmp_path / "src").symlink_to(ROOT / "src")
    return tmp_path


def test_repo_docs_pass():
    """The committed docs reference only real modules, real flags, and
    resolvable links (the same check `make docs-check` runs in CI)."""
    assert _tool().main([str(ROOT)]) == 0


def test_broken_link_fails(tmp_path, capsys):
    tool = _tool()
    root = _tree(tmp_path, "see [missing](docs/nope.md)\n")
    assert tool.main([str(root)]) == 1
    assert "broken link -> docs/nope.md" in capsys.readouterr().err


def test_link_inside_code_fence_is_ignored(tmp_path):
    tool = _tool()
    root = _tree(tmp_path,
                 "```python\nrows[0][\"x\"](docs/not-a-link.md)\n```\n")
    assert tool.main([str(root)]) == 0


def test_undefined_cli_flag_fails(tmp_path, capsys):
    tool = _tool()
    root = _tree(tmp_path, "```bash\n"
                 "PYTHONPATH=src python -m repro.service.loop --definitely-not-a-flag\n"
                 "```\n")
    assert tool.main([str(root)]) == 1
    err = capsys.readouterr().err
    assert "does not define --definitely-not-a-flag" in err


def test_unimportable_module_fails(tmp_path, capsys):
    tool = _tool()
    root = _tree(tmp_path, "```bash\n"
                 "PYTHONPATH=src python -m repro.no_such_module --fast\n"
                 "```\n")
    assert tool.main([str(root)]) == 1
    assert "target missing or CLI broken" in capsys.readouterr().err


def test_subcommand_flags_resolve_against_subparser(tmp_path):
    """`campaign run --force` is only defined on the `run` subparser — the
    checker must consult the subcommand's help, not the top-level parser's."""
    tool = _tool()
    root = _tree(tmp_path, "```bash\n"
                 "PYTHONPATH=src python -m repro.data.campaign run --force --fast\n"
                 "```\n")
    assert tool.main([str(root)]) == 0


def test_extract_cli_commands_parsing():
    tool = _tool()
    text = (
        "prose python -m not.in.a.fence --skip\n"
        "```console\n"
        "$ PYTHONPATH=src python -m repro.data.campaign list\n"
        "extended   724 cases   output line, not a command\n"
        "```\n"
        "```bash\n"
        "PYTHONPATH=src python -m repro.data.campaign merge \\\n"
        "    a.jsonl --out b.jsonl\n"
        "```\n"
    )
    cmds = tool.extract_cli_commands(text)
    assert cmds == [
        ("module", "repro.data.campaign", ["list"]),
        ("module", "repro.data.campaign", ["merge", "a.jsonl", "--out", "b.jsonl"]),
    ]


def test_script_cli_references_are_verified(tmp_path, capsys):
    """``python tools/<script>.py`` lines get the same --help treatment as
    ``python -m`` modules (the bench-gate CLI is documented this way)."""
    tool = _tool()
    root = tmp_path
    tools = root / "tools"
    tools.mkdir()
    (tools / "okscript.py").write_text(
        "import argparse\n"
        "p = argparse.ArgumentParser()\n"
        "p.add_argument('--fresh')\n"
        "p.parse_args()\n"
    )
    (root / "README.md").write_text(
        "```bash\n"
        "python tools/okscript.py --fresh /tmp/x\n"
        "```\n"
    )
    assert tool.main([str(root)]) == 0

    (root / "README.md").write_text(
        "```bash\n"
        "python tools/okscript.py --no-such-flag\n"
        "```\n"
    )
    assert tool.main([str(root)]) == 1
    assert "--no-such-flag" in capsys.readouterr().err

    (root / "README.md").write_text(
        "```bash\n"
        "python tools/missing_script.py --fresh x\n"
        "```\n"
    )
    assert tool.main([str(root)]) == 1
    assert "target missing or CLI broken" in capsys.readouterr().err
