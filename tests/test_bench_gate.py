"""Bench regression gate (tools/bench_gate.py) + benchmark harness exit codes.

The gate compares a fresh fast-bench run against the committed BENCH_*.json
with median calibration: a uniform machine-speed factor passes, a single
regressed benchmark fails, and identical_trees=false / missing artifacts are
hard failures at any tolerance.
"""

import copy
import json
import pathlib
import sys

import pytest

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "tools"))

import bench_gate  # noqa: E402


def _fit_art():
    return {
        "schema": 2,
        "fit": {
            "gbt_paper_n141": {
                "n": 141, "estimators": 100,
                "batched_s": 0.05, "level_s": 0.2, "reference_s": 1.5,
                "speedup_batched": 4.0, "identical_trees": True,
            },
            "gbt_paper_n1024": {
                "n": 1024, "estimators": 100,
                "batched_s": 0.1, "level_s": 0.5, "reference_s": 2.2,
                "speedup_batched": 5.0, "identical_trees": True,
            },
            "rf_paper_d10_n141": {
                "n": 141, "estimators": 50,
                "batched_s": 0.02, "level_s": 0.2, "reference_s": 1.1,
                "speedup_batched": 10.0, "identical_trees": True,
            },
            "rf_paper_n1024_b100": {
                "n": 1024, "estimators": 100,
                "batched_s": 0.15, "level_s": 1.2,
                "speedup_batched": 8.0, "identical_trees": True,
            },
        },
        "threads": {
            "rf_paper_n1024_b100": {
                "n": 1024, "estimators": 100, "threads": 4, "cores": 4,
                "native": True, "t1_s": 0.6, "tN_s": 0.2,
                "speedup_threads": 3.0, "identical_trees": True,
            },
        },
        "recommend": {
            "xgboost_paper_1800": {"candidates": 1800, "best_ms": 7.0,
                                   "configs_per_s": 250000},
            "xgboost_mega_1e5": {
                "candidates": 100000, "best_ms": 300.0,
                "argpartition_ms": 600.0, "speedup_mega": 2.0,
                "configs_per_s": 333333, "topk_match": True,
            },
        },
    }


def _loop_art():
    return {
        "schema": 1,
        "campaign_cycles": [
            {"cycle": 0, "refit_ms": 100.0, "recommend_ms": 200.0, "cycle_s": 1.0},
            {"cycle": 1, "refit_ms": 90.0, "recommend_ms": 150.0, "cycle_s": 0.9},
        ],
        "synthetic_cycles": [
            {"cycle": 0, "refit_ms": 120.0, "recommend_ms": 80.0, "cycle_s": 0.5},
        ],
    }


def _fleet_art():
    return {
        "schema": 1,
        "runs": [
            {"collectors": 1, "rows": 24, "wall_s": 36.0, "rows_per_s": 0.66,
             "speedup_vs_1": 1.0, "n_failures": 0},
            {"collectors": 2, "rows": 24, "wall_s": 19.0, "rows_per_s": 1.25,
             "speedup_vs_1": 1.88, "n_failures": 0},
        ],
    }


def _serve_art():
    endpoints = {"predict": [], "recommend": []}
    speedups = {"predict": {}, "recommend": {}}
    base = {"predict": 2.0, "recommend": 5.0}  # ms per request, single client
    for endpoint in ("predict", "recommend"):
        for mode in ("batched", "unbatched"):
            for clients in (1, 8, 32):
                # batched scales sublinearly, unbatched serializes
                factor = clients ** (0.3 if mode == "batched" else 0.8)
                p50 = base[endpoint] * factor
                endpoints[endpoint].append({
                    "clients": clients, "n_requests": 96, "mode": mode,
                    "qps": round(clients * 1e3 / p50, 1),
                    "p50_ms": round(p50, 3), "p95_ms": round(p50 * 1.5, 3),
                    "p99_ms": round(p50 * 2.0, 3),
                })
        rows = {(r["mode"], r["clients"]): r for r in endpoints[endpoint]}
        for clients in (1, 8, 32):
            speedups[endpoint][f"c{clients}"] = round(
                rows[("batched", clients)]["qps"]
                / rows[("unbatched", clients)]["qps"], 2)
    return {
        "schema": 1, "n_candidates": 144, "n_observations": 144,
        "endpoints": endpoints, "speedup_batched": speedups,
        "cache": {"n_contexts": 16, "cold_qps": 200.0, "hit_qps": 1200.0,
                  "cold_p50_ms": 5.0, "hit_p50_ms": 0.8, "speedup_hit": 6.0},
    }


def _pipeline_art():
    stalls = {"off": 0.40, "depth": 0.38, "clairvoyant": 0.005}
    cases = []
    reduction = {}
    for backend in ("network_sim", "object_sim"):
        for w in (1, 4):
            for policy, stall in stalls.items():
                cases.append({
                    "key": f"{backend}.w{w}.{policy}", "backend": backend,
                    "workers": w, "policy": policy, "stall_s": stall,
                    "delivered_mb_s": 3.0 if policy == "clairvoyant" else 0.2,
                    "hit_ratio": 1.0 if policy == "clairvoyant" else 0.0,
                })
            reduction[f"{backend}.w{w}"] = round(
                stalls["depth"] / stalls["clairvoyant"], 2)
    return {"schema": 1, "cases": cases, "stall_reduction": reduction,
            "max_stall_reduction": max(reduction.values())}


def _transfer_art():
    folds = {}
    zero = {"tmpfs": 60.0, "disk": 25.0, "network_sim": 30.0,
            "object_sim": 300.0}
    for backend, k0 in zero.items():
        k25 = round(k0 / (4.0 if backend in ("tmpfs", "object_sim") else 1.3), 4)
        folds[backend] = {
            "n_train": 144, "n_test": 48, "n_eval": 23, "n_calib_pool": 25,
            "zoo": {"xgboost": {"r2": 0.7, "mape": k0, "median_ape": k0 / 2}},
            "calibration": {
                "curve": {
                    "k0": {"mape": k0, "median_ape": k0 / 2, "r2": 0.7},
                    "k25": {"mape": k25, "median_ape": k25 / 2, "r2": 0.9},
                },
                "calibrators": {"k25": {"kind": "affine", "a": 1.0,
                                        "b": 0.5, "n": 25}},
                "mape_reduction": {"k25": round(k0 / k25, 4)},
                "mape_reduction_k25": round(k0 / k25, 4),
            },
        }
    reductions = {b: f["calibration"]["mape_reduction_k25"]
                  for b, f in folds.items()}
    return {
        "schema": 1,
        "n_per_backend": 48,
        "report": {
            "schema": 1, "group_key": "backend", "seed": 0, "ks": [0, 25],
            "n_rows": 192, "n_features": 16, "models": ["xgboost"],
            "calibration_model": "xgboost", "calibrator": "affine",
            "folds": folds,
            "max_mape_reduction_k25": max(reductions.values()),
        },
        "fold_seconds": {b: 1.5 for b in zero},
        "mape_reduction_k25": reductions,
        "max_mape_reduction_k25": max(reductions.values()),
    }


@pytest.fixture()
def arts(tmp_path):
    committed = tmp_path / "repo"
    fresh = tmp_path / "fresh"
    committed.mkdir()
    fresh.mkdir()
    for d in (committed, fresh):
        (d / "BENCH_fit.json").write_text(json.dumps(_fit_art()))
        (d / "BENCH_loop.json").write_text(json.dumps(_loop_art()))
        (d / "BENCH_fleet.json").write_text(json.dumps(_fleet_art()))
        (d / "BENCH_serve.json").write_text(json.dumps(_serve_art()))
        (d / "BENCH_pipeline.json").write_text(json.dumps(_pipeline_art()))
        (d / "BENCH_transfer.json").write_text(json.dumps(_transfer_art()))
    return committed, fresh


def _rewrite(d, name, obj):
    (d / name).write_text(json.dumps(obj))


def test_gate_passes_on_identical_artifacts(arts):
    committed, fresh = arts
    gate = bench_gate.run_gate(fresh, committed)
    assert not gate.hard and not gate.soft
    assert gate.compared > 0


def test_gate_calibrates_uniform_machine_factor(arts):
    """A uniformly 3x slower runner is NOT a regression."""
    committed, fresh = arts
    art = _fit_art()
    for row in art["fit"].values():
        for f in ("batched_s", "level_s", "reference_s"):
            if f in row:
                row[f] *= 3.0
    art["recommend"]["xgboost_paper_1800"]["best_ms"] *= 3.0
    _rewrite(fresh, "BENCH_fit.json", art)
    loop = _loop_art()
    for track in ("campaign_cycles", "synthetic_cycles"):
        for c in loop[track]:
            for f in ("refit_ms", "recommend_ms", "cycle_s"):
                c[f] *= 3.0
    _rewrite(fresh, "BENCH_loop.json", loop)
    gate = bench_gate.run_gate(fresh, committed)
    assert not gate.hard and not gate.soft


def test_gate_catches_injected_10x_slowdown(arts):
    """One benchmark regressing 10x must fail even on a 2x-slower machine."""
    committed, fresh = arts
    art = _fit_art()
    for row in art["fit"].values():
        for f in ("batched_s", "level_s", "reference_s"):
            if f in row:
                row[f] *= 2.0  # machine factor
    art["fit"]["gbt_paper_n1024"]["batched_s"] *= 10.0  # the regression
    _rewrite(fresh, "BENCH_fit.json", art)
    gate = bench_gate.run_gate(fresh, committed)
    assert not gate.hard
    assert any("gbt_paper_n1024.batched_s" in m for m in gate.soft)


def test_gate_hard_fails_on_identical_trees_false(arts):
    committed, fresh = arts
    art = _fit_art()
    art["fit"]["rf_paper_d10_n141"]["identical_trees"] = False
    _rewrite(fresh, "BENCH_fit.json", art)
    gate = bench_gate.run_gate(fresh, committed)
    assert any("identical_trees" in m for m in gate.hard)


def test_gate_hard_fails_on_missing_fresh_artifact(arts):
    committed, fresh = arts
    (fresh / "BENCH_fleet.json").unlink()
    gate = bench_gate.run_gate(fresh, committed)
    assert any("BENCH_fleet.json" in m and "missing" in m for m in gate.hard)


def test_gate_hard_fails_on_config_drift(arts):
    """Same key but different n/estimators means the bench changed shape —
    timings are not comparable and the gate must say so."""
    committed, fresh = arts
    art = _fit_art()
    art["fit"]["gbt_paper_n141"]["estimators"] = 10
    _rewrite(fresh, "BENCH_fit.json", art)
    gate = bench_gate.run_gate(fresh, committed)
    assert any("config drifted" in m for m in gate.hard)


def test_gate_hard_fails_on_fleet_collector_failures(arts):
    committed, fresh = arts
    art = _fleet_art()
    art["runs"][1]["n_failures"] = 2
    _rewrite(fresh, "BENCH_fleet.json", art)
    gate = bench_gate.run_gate(fresh, committed)
    assert any("collector failures" in m for m in gate.hard)


def test_gate_hard_fails_on_nonzero_corrupt_lines(arts):
    """A benchmark run that skipped corrupt records measured a different
    workload — hard failure wherever the counter appears."""
    committed, fresh = arts
    art = _fleet_art()
    art["runs"][0]["corrupt_lines"] = 3
    _rewrite(fresh, "BENCH_fleet.json", art)
    gate = bench_gate.run_gate(fresh, committed)
    assert any("corrupt_lines=3" in m and "fresh" in m for m in gate.hard)


def test_gate_hard_fails_on_quarantines_in_committed_artifact(arts):
    committed, fresh = arts
    art = _loop_art()
    art["campaign_cycles"][0]["faults"] = {"quarantined": 1, "retried": 0}
    _rewrite(committed, "BENCH_loop.json", art)
    gate = bench_gate.run_gate(fresh, committed)
    assert any("quarantined=1" in m and "committed" in m for m in gate.hard)


def test_gate_passes_on_zero_integrity_counters(arts):
    """Zero-valued (or absent) integrity counters are clean runs."""
    committed, fresh = arts
    art = _fleet_art()
    for run in art["runs"]:
        run["corrupt_lines"] = 0
        run["quarantined"] = 0
    _rewrite(fresh, "BENCH_fleet.json", art)
    _rewrite(committed, "BENCH_fleet.json", art)
    gate = bench_gate.run_gate(fresh, committed)
    assert not gate.hard


def test_gate_main_exit_codes(arts):
    committed, fresh = arts
    assert bench_gate.main(["--fresh", str(fresh), "--repo-root", str(committed)]) == 0
    art = _fit_art()
    art["fit"]["gbt_paper_n141"]["identical_trees"] = False
    _rewrite(fresh, "BENCH_fit.json", art)
    assert bench_gate.main(["--fresh", str(fresh), "--repo-root", str(committed)]) == 1


# ---------------------------------------------------------------- benchmarks.run


def test_bench_run_exits_nonzero_when_group_raises(monkeypatch):
    """A broken bench group must fail the run (CI must not green-light a
    partial benchmark pass)."""
    import benchmarks.fit_bench as fit_bench
    import benchmarks.run as bench_run

    def boom(fast, artifact_dir=None):
        raise RuntimeError("injected bench failure")

    monkeypatch.setattr(fit_bench, "bench_fit", boom)
    with pytest.raises(SystemExit) as exc:
        bench_run.main(["--fast", "--only", "fit"])
    assert exc.value.code == 1


def test_bench_run_unknown_group_is_an_error():
    import benchmarks.run as bench_run

    with pytest.raises(SystemExit) as exc:
        bench_run.main(["--fast", "--only", "nonexistent_group"])
    assert exc.value.code == 2


def test_gate_hard_fails_when_serve_qps_row_is_dropped(arts):
    """The serve bench silently dropping a load point (say batched/c32 —
    exactly the row the headline claim rests on) must hard-fail."""
    committed, fresh = arts
    art = _serve_art()
    art["endpoints"]["predict"] = [
        r for r in art["endpoints"]["predict"]
        if not (r["mode"] == "batched" and r["clients"] == 32)
    ]
    _rewrite(fresh, "BENCH_serve.json", art)
    gate = bench_gate.run_gate(fresh, committed)
    assert any(
        "predict.batched.c32" in m and "dropped" in m for m in gate.hard
    )


def test_gate_hard_fails_when_committed_serve_speedup_below_2x(arts):
    committed, fresh = arts
    art = _serve_art()
    art["speedup_batched"]["predict"]["c32"] = 1.4
    art["speedup_batched"]["recommend"]["c32"] = 1.6
    _rewrite(committed, "BENCH_serve.json", art)
    gate = bench_gate.run_gate(fresh, committed)
    assert any("no endpoint reaches" in m for m in gate.hard)


def test_gate_catches_serve_latency_regression(arts):
    """One endpoint's batched p50 blowing up 10x is a regression even after
    median calibration against the other serve rows."""
    committed, fresh = arts
    art = _serve_art()
    for r in art["endpoints"]["recommend"]:
        if r["mode"] == "batched" and r["clients"] == 32:
            r["p50_ms"] *= 10.0
    _rewrite(fresh, "BENCH_serve.json", art)
    gate = bench_gate.run_gate(fresh, committed)
    assert not gate.hard
    assert any("recommend.batched.c32.p50" in m for m in gate.soft)


def test_gate_hard_fails_when_pipeline_policy_row_is_dropped(arts):
    """The fast pipeline bench silently dropping a policy row (say the
    clairvoyant one the stall claim rests on) must hard-fail."""
    committed, fresh = arts
    art = _pipeline_art()
    art["cases"] = [c for c in art["cases"]
                    if c["key"] != "network_sim.w1.clairvoyant"]
    _rewrite(fresh, "BENCH_pipeline.json", art)
    gate = bench_gate.run_gate(fresh, committed)
    assert any("network_sim.w1.clairvoyant" in m and "dropped" in m
               for m in gate.hard)


def test_gate_hard_fails_when_committed_stall_reduction_below_floor(arts):
    """The committed clairvoyant-vs-depth stall reduction dipping below the
    1.5x floor on every case means the prefetcher stopped paying."""
    committed, fresh = arts
    art = _pipeline_art()
    art["stall_reduction"] = {k: 1.2 for k in art["stall_reduction"]}
    _rewrite(committed, "BENCH_pipeline.json", art)
    gate = bench_gate.run_gate(fresh, committed)
    assert any("stall reduction" in m and "below the required" in m
               for m in gate.hard)


def test_gate_flags_fresh_stall_reduction_collapse(arts):
    """A fresh run where clairvoyant barely beats depth is a regression
    flag (runner noise), not a hard failure."""
    committed, fresh = arts
    art = _pipeline_art()
    for c in art["cases"]:
        if c["policy"] == "clairvoyant":
            c["stall_s"] = 0.36
    art["stall_reduction"] = {k: 1.06 for k in art["stall_reduction"]}
    _rewrite(fresh, "BENCH_pipeline.json", art)
    gate = bench_gate.run_gate(fresh, committed)
    assert not gate.hard
    assert any("pipeline: fresh clairvoyant-vs-depth" in m for m in gate.soft)


def test_gate_catches_pipeline_stall_regression(arts):
    """An off/depth stall blowing up 10x against the machine factor is a
    regression after calibration against the other pipeline rows."""
    committed, fresh = arts
    art = _pipeline_art()
    for c in art["cases"]:
        if c["key"] == "object_sim.w1.depth":
            c["stall_s"] *= 10.0
    _rewrite(fresh, "BENCH_pipeline.json", art)
    gate = bench_gate.run_gate(fresh, committed)
    assert not gate.hard
    assert any("object_sim.w1.depth.stall" in m for m in gate.soft)


def test_gate_hard_fails_when_transfer_fold_is_dropped(arts):
    """The fast transfer run silently dropping a held-out backend fold (say
    object_sim — the one the calibration claim rests on) must hard-fail."""
    committed, fresh = arts
    art = _transfer_art()
    del art["report"]["folds"]["object_sim"]
    del art["fold_seconds"]["object_sim"]
    del art["mape_reduction_k25"]["object_sim"]
    _rewrite(fresh, "BENCH_transfer.json", art)
    gate = bench_gate.run_gate(fresh, committed)
    assert any("'object_sim'" in m and "dropped" in m for m in gate.hard)


def test_gate_hard_fails_when_committed_calibration_below_floor(arts):
    """The committed calibrated-vs-zero-shot MAPE reduction dipping below
    the 1.5x floor on every fold means few-shot calibration stopped paying."""
    committed, fresh = arts
    art = _transfer_art()
    art["mape_reduction_k25"] = {k: 1.1 for k in art["mape_reduction_k25"]}
    _rewrite(committed, "BENCH_transfer.json", art)
    gate = bench_gate.run_gate(fresh, committed)
    assert any("MAPE reduction" in m and "below the required" in m
               for m in gate.hard)


def test_gate_flags_fresh_calibration_collapse(arts):
    """A fresh run where calibration barely improves on zero-shot is a
    regression flag (CI-sized track noise), not a hard failure."""
    committed, fresh = arts
    art = _transfer_art()
    art["mape_reduction_k25"] = {k: 1.05 for k in art["mape_reduction_k25"]}
    _rewrite(fresh, "BENCH_transfer.json", art)
    gate = bench_gate.run_gate(fresh, committed)
    assert not gate.hard
    assert any("transfer: fresh calibrated-vs-zero-shot" in m
               for m in gate.soft)


def test_gate_hard_fails_on_bad_transfer_zero_shot_mape(arts):
    committed, fresh = arts
    art = _transfer_art()
    art["report"]["folds"]["disk"]["calibration"]["curve"]["k0"]["mape"] = 0.0
    _rewrite(fresh, "BENCH_transfer.json", art)
    gate = bench_gate.run_gate(fresh, committed)
    assert any("disk fresh zero-shot mape" in m for m in gate.hard)


def test_gate_catches_transfer_fold_slowdown(arts):
    """One fold's wall-clock blowing up 10x against the machine factor is a
    regression after calibration against the other folds."""
    committed, fresh = arts
    art = _transfer_art()
    art["fold_seconds"]["network_sim"] *= 10.0
    _rewrite(fresh, "BENCH_transfer.json", art)
    gate = bench_gate.run_gate(fresh, committed)
    assert not gate.hard
    assert any("network_sim.fold" in m for m in gate.soft)


# ------------------------------------------------- threaded fit + mega recommend


def test_gate_hard_fails_when_threads_row_is_dropped(arts):
    """The fast run silently dropping the threaded-fit row must hard-fail."""
    committed, fresh = arts
    art = _fit_art()
    del art["threads"]["rf_paper_n1024_b100"]
    _rewrite(fresh, "BENCH_fit.json", art)
    gate = bench_gate.run_gate(fresh, committed)
    assert any("threads row" in m and "silently dropped" in m
               for m in gate.hard)


def test_gate_hard_fails_on_non_identical_threaded_fit(arts):
    """An injected threads-vs-single-thread divergence is a correctness
    hard failure on either side, at any tolerance."""
    committed, fresh = arts
    art = _fit_art()
    art["threads"]["rf_paper_n1024_b100"]["identical_trees"] = False
    _rewrite(fresh, "BENCH_fit.json", art)
    gate = bench_gate.run_gate(fresh, committed)
    assert any("threads.rf_paper_n1024_b100" in m and "identical_trees" in m
               for m in gate.hard)
    _rewrite(fresh, "BENCH_fit.json", _fit_art())
    _rewrite(committed, "BENCH_fit.json", art)
    gate = bench_gate.run_gate(fresh, committed)
    assert any("identical_trees is false (committed)" in m for m in gate.hard)


def test_gate_hard_fails_on_committed_thread_speedup_below_floor(arts):
    """A committed multi-core threads row below 1.5x means the pool stopped
    paying — hard failure."""
    committed, fresh = arts
    art = _fit_art()
    art["threads"]["rf_paper_n1024_b100"]["speedup_threads"] = 1.1
    _rewrite(committed, "BENCH_fit.json", art)
    gate = bench_gate.run_gate(fresh, committed)
    assert any("threads.rf_paper_n1024_b100" in m and "below the required" in m
               for m in gate.hard)


def test_gate_accepts_single_core_committed_threads_row(arts):
    """A threads row recorded on one core proves bit-exactness but cannot
    show parallel speedup — the floor must not apply there."""
    committed, fresh = arts
    art = _fit_art()
    art["threads"]["rf_paper_n1024_b100"].update(
        {"cores": 1, "speedup_threads": 0.97})
    _rewrite(committed, "BENCH_fit.json", art)
    _rewrite(fresh, "BENCH_fit.json", art)
    gate = bench_gate.run_gate(fresh, committed)
    assert not gate.hard


def test_gate_hard_fails_on_threads_config_drift(arts):
    committed, fresh = arts
    art = _fit_art()
    art["threads"]["rf_paper_n1024_b100"]["threads"] = 2
    _rewrite(fresh, "BENCH_fit.json", art)
    gate = bench_gate.run_gate(fresh, committed)
    assert any("threads.rf_paper_n1024_b100 config drifted" in m
               for m in gate.hard)


def test_gate_hard_fails_when_mega_row_is_dropped(arts):
    """The fast run silently dropping the mega-grid recommend row must
    hard-fail."""
    committed, fresh = arts
    art = _fit_art()
    del art["recommend"]["xgboost_mega_1e5"]
    _rewrite(fresh, "BENCH_fit.json", art)
    gate = bench_gate.run_gate(fresh, committed)
    assert any("xgboost_mega_1e5" in m and "silently dropped" in m
               for m in gate.hard)


def test_gate_hard_fails_on_mega_topk_mismatch(arts):
    """The chunked scorer disagreeing with the numpy oracle on the top-k is
    a correctness hard failure, fresh or committed."""
    committed, fresh = arts
    art = _fit_art()
    art["recommend"]["xgboost_mega_1e5"]["topk_match"] = False
    _rewrite(fresh, "BENCH_fit.json", art)
    gate = bench_gate.run_gate(fresh, committed)
    assert any("topk_match is false (fresh)" in m for m in gate.hard)


def test_gate_hard_fails_on_committed_mega_speedup_below_floor(arts):
    committed, fresh = arts
    art = _fit_art()
    art["recommend"]["xgboost_mega_1e5"]["speedup_mega"] = 1.2
    _rewrite(committed, "BENCH_fit.json", art)
    gate = bench_gate.run_gate(fresh, committed)
    assert any("mega-grid speedup" in m and "below the required" in m
               for m in gate.hard)


def test_gate_flags_fresh_mega_speedup_collapse(arts):
    """A fresh mega-grid speedup collapse is a regression flag (runner
    noise), not a hard failure."""
    committed, fresh = arts
    art = _fit_art()
    art["recommend"]["xgboost_mega_1e5"]["speedup_mega"] = 1.05
    _rewrite(fresh, "BENCH_fit.json", art)
    gate = bench_gate.run_gate(fresh, committed)
    assert not gate.hard
    assert any("mega-grid speedup is 1.05x" in m for m in gate.soft)


def test_gate_hard_fails_when_required_fast_row_is_dropped(arts):
    """The fast run silently dropping one of its required rows (e.g. a new
    skip condition in fit_bench) must hard-fail, not pass by omission."""
    committed, fresh = arts
    art = _fit_art()
    del art["fit"]["rf_paper_d10_n141"]
    _rewrite(fresh, "BENCH_fit.json", art)
    gate = bench_gate.run_gate(fresh, committed)
    assert any(
        "rf_paper_d10_n141" in m and "silently dropped" in m for m in gate.hard
    )
