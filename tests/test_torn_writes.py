"""Property tests: truncating a JSONL artifact at an *arbitrary byte offset*
(the residue of a killed writer, a full disk, or a torn sector) is fully
recovered by resume — the final dataset has every expected
``(case_id, rep, seed)`` key exactly once, with no duplicates and no losses.

Hypothesis drives the cut point; ``tests/_hypothesis_compat.py`` degrades
these to skips when hypothesis is not installed."""

import json
import pathlib
import tempfile

from _hypothesis_compat import given, settings, st

from repro.data.campaign import (
    completed_keys,
    load_records_ex,
    repair_jsonl_tail,
    run_campaign,
)
from repro.data.registry import Campaign, matrix_cases
from repro.service.fleet import synthetic_executor
from repro.service.state import LoopState


def _campaign():
    return Campaign(
        "torn_fake", "torn-write test campaign",
        lambda fast=False: tuple(matrix_cases(
            "pipeline", id_prefix="tw", backend=["tmpfs"], format=["raw"],
            batch_size=[16, 32], num_workers=[0, 2, 4],
        )),
    )


EXPECTED_KEYS = {(c.id, 0, 3) for c in _campaign().cases(False)}

_BASELINE: dict = {}


def _baseline_bytes() -> bytes:
    """One full fault-free campaign artifact, computed once per process."""
    if "bytes" not in _BASELINE:
        with tempfile.TemporaryDirectory() as d:
            out = pathlib.Path(d) / "c.jsonl"
            run_campaign(_campaign(), out, seed=3,
                         executor=synthetic_executor)
            _BASELINE["bytes"] = out.read_bytes()
    return _BASELINE["bytes"]


@settings(max_examples=15, deadline=None)
@given(st.floats(min_value=0.0, max_value=1.0))
def test_truncated_campaign_artifact_resumes_losslessly(frac):
    data = _baseline_bytes()
    cut = int(frac * len(data))
    with tempfile.TemporaryDirectory() as d:
        out = pathlib.Path(d) / "c.jsonl"
        out.write_bytes(data[:cut])
        result = run_campaign(_campaign(), out, seed=3,
                              executor=synthetic_executor)
        assert result.failures == []
        assert result.skipped + result.n_executed == len(EXPECTED_KEYS)
        records, n_corrupt, torn_tail = load_records_ex(out)
        # the resumed file is fully parseable: the torn fragment was cut
        # before the first new append, never glued onto it
        assert n_corrupt == 0 and not torn_tail
        keys = [(r["case_id"], r["rep"], r["seed"]) for r in records]
        assert len(keys) == len(set(keys))      # no duplicate keys
        assert set(keys) == EXPECTED_KEYS       # no lost keys
        assert completed_keys(records) == EXPECTED_KEYS


@settings(max_examples=10, deadline=None)
@given(st.floats(min_value=0.0, max_value=1.0), st.integers(0, 2 ** 32 - 1))
def test_truncated_state_log_append_never_glues(frac, nonce):
    """Appending to a state log with a torn tail must not merge the fragment
    and the new record into one corrupt line — the new record always lands
    complete and readable."""
    with tempfile.TemporaryDirectory() as d:
        path = pathlib.Path(d) / "loop_state.jsonl"
        state = LoopState(path)
        state.append({"cycle": 0, "nonce": nonce})
        state.append({"cycle": 1, "nonce": nonce})
        data = path.read_bytes()
        cut = max(1, int(frac * len(data)))  # keep at least one byte
        path.write_bytes(data[:cut])
        state.append({"cycle": 9, "nonce": nonce})
        records, n_corrupt, torn_tail = load_records_ex(path)
        assert n_corrupt == 0 and not torn_tail
        assert records[-1] == {k: records[-1][k] for k in records[-1]}  # parses
        assert any(r.get("cycle") == 9 for r in records)  # never lost


def test_truncation_sweep_without_hypothesis(tmp_path):
    """Deterministic fallback for the property above: a fixed sweep of cut
    offsets (including the exact boundaries 0, mid-line, line-end, EOF) that
    runs even where hypothesis is not installed."""
    data = _baseline_bytes()
    line_end = data.find(b"\n") + 1
    cuts = sorted({0, 1, line_end - 1, line_end, line_end + 1,
                   len(data) // 3, len(data) // 2, len(data) - 1, len(data)})
    for i, cut in enumerate(cuts):
        out = tmp_path / f"cut_{i}.jsonl"
        out.write_bytes(data[:cut])
        run_campaign(_campaign(), out, seed=3, executor=synthetic_executor)
        records, n_corrupt, torn_tail = load_records_ex(out)
        assert n_corrupt == 0 and not torn_tail, f"cut={cut}"
        keys = [(r["case_id"], r["rep"], r["seed"]) for r in records]
        assert len(keys) == len(set(keys)), f"cut={cut}"
        assert set(keys) == EXPECTED_KEYS, f"cut={cut}"


def test_repair_jsonl_tail_shapes(tmp_path):
    p = tmp_path / "x.jsonl"
    assert not repair_jsonl_tail(p)             # missing file
    p.write_text('{"a": 1}\n{"b": 2}\n')
    assert not repair_jsonl_tail(p)             # clean file untouched
    assert p.read_text() == '{"a": 1}\n{"b": 2}\n'
    p.write_text('{"a": 1}\n{"b": 2')           # malformed torn tail: cut
    assert repair_jsonl_tail(p)
    assert p.read_text() == '{"a": 1}\n'
    assert json.loads(p.read_text()) == {"a": 1}
    p.write_text('{"a": 1}\n{"b": 2}')          # valid tail, lost newline:
    assert repair_jsonl_tail(p)                 # sealed, record kept
    assert p.read_text() == '{"a": 1}\n{"b": 2}\n'
