"""Access-plan layer + clairvoyant prefetcher (docs/prefetching.md).

The load-bearing guarantees:

- the plan layer (``record_span`` / ``block_plan`` / ``fetch`` /
  ``decode_span``) reproduces ``read()``/``read_batch()`` byte-for-byte for
  all four formats, with coalesced, deduplicated block plans;
- all three prefetch policies deliver byte-identical batch streams, across
  formats, access patterns, and mid-epoch restarts — and ``reconfigure()``
  mid-epoch never duplicates or drops a batch;
- the block cache evicts schedule-expired blocks before useful ones;
- the prefetch knobs flow through telemetry features, the ``prefetch``
  campaign, and the online autotuner's recommendation path.
"""

import numpy as np
import pytest

from repro.core.autotune import KNOB_NAMES, ConfigSpace, OnlineAutotuner
from repro.core.features import AUTOTUNE_FEATURE_NAMES, FEATURE_NAMES
from repro.data import (
    BACKENDS,
    DataPipeline,
    PipelineConfig,
    StepTelemetry,
    TokenRecordCodec,
    open_dataset,
    write_dataset,
)
from repro.data.formats import BlockRead, assemble_span
from repro.data.prefetch import (
    PREFETCH_POLICIES,
    BlockCache,
    ClairvoyantPrefetcher,
    policy_code,
    policy_name,
)
from repro.data.registry import get_campaign

FORMATS = ("raw", "packed", "compressed", "sharded")


@pytest.fixture(scope="module")
def tmpfs():
    return BACKENDS["tmpfs"]


def _dataset(tmpfs, fmt, n=48, seq_len=32, seed=7, tag=""):
    codec = TokenRecordCodec(seq_len)
    rng = np.random.default_rng(seed)
    recs = [codec.encode(rng.integers(0, 50_000, size=seq_len, dtype=np.int32))
            for _ in range(n)]
    man = write_dataset(tmpfs, f"pf_{fmt}{tag}", recs, fmt)
    return man, recs, codec


# ---------------------------------------------------------------- plan layer

def test_policy_codes_roundtrip():
    for code, name in enumerate(PREFETCH_POLICIES):
        assert policy_code(name) == code
        assert policy_code(code) == code
        assert policy_name(code) == name
        assert policy_name(name) == name
    with pytest.raises(ValueError):
        policy_code("eager")
    with pytest.raises(ValueError):
        policy_code(3)


@pytest.mark.parametrize("fmt", FORMATS)
def test_record_span_plus_decode_matches_read(fmt, tmpfs):
    man, recs, _ = _dataset(tmpfs, fmt, tag="_span")
    with open_dataset(tmpfs, man, block_kb=4) as r:
        for i in (0, 1, 23, 47):
            fi, off, size = r.record_span(i)
            assert size > 0
            span = r.fetch(BlockRead(fi, off, size))
            assert r.decode_span(i, fi, off, span) == recs[i]
            assert r.read(i) == recs[i]


def test_block_plan_coalesces_and_dedups(tmpfs):
    man, _, codec = _dataset(tmpfs, "packed", tag="_plan")
    with open_dataset(tmpfs, man, block_kb=4) as r:
        # sequential indices coalesce into one contiguous read
        plan = r.block_plan(range(48))
        assert len(plan) == 1
        assert plan[0].offset == 0
        assert plan[0].offset % 4096 == 0
        # duplicate indices plan each block once
        assert r.block_plan([3, 3, 3]) == r.block_plan([3])
        # every planned block is aligned to the block size
        for br in r.block_plan([0, 17, 44]):
            assert br.offset % 4096 == 0


@pytest.mark.parametrize("fmt", FORMATS)
def test_read_batch_byte_identity(fmt, tmpfs):
    man, recs, _ = _dataset(tmpfs, fmt, tag="_batch")
    with open_dataset(tmpfs, man, block_kb=4) as r:
        idx = [5, 2, 2, 47, 0, 31]
        assert r.read_batch(idx) == [recs[i] for i in idx]


def test_assemble_span_crosses_block_boundaries():
    blob = bytes(range(256)) * 4  # 1 KiB
    bs = 64

    def get_block(fi, boff):
        return blob[boff:boff + bs]

    for off, size in ((0, 10), (60, 10), (63, 129), (0, len(blob))):
        assert assemble_span(get_block, 0, off, size, bs) == blob[off:off + size]


# ------------------------------------------------------- policy equivalence

def _pipe(tmpfs, man, seq_len, **kw):
    reader = open_dataset(tmpfs, man, block_kb=kw.pop("block_kb", 4))
    cfg = PipelineConfig(batch_size=8, seed=3, **kw)
    return DataPipeline.from_reader(reader, seq_len, cfg), reader


def _collect(pipe, epoch=0, start_step=0):
    out = list(pipe.iter_epoch(epoch, start_step=start_step))
    return [b.copy() for b in out]


@pytest.mark.parametrize("fmt", FORMATS)
@pytest.mark.parametrize("shuffle", [True, False])
def test_policy_equivalence_and_restart(fmt, shuffle, tmpfs):
    """3 policies x 4 formats x shuffle on/off x mid-epoch resume: identical
    batch streams everywhere."""
    man, _, _ = _dataset(tmpfs, fmt, tag="_eq")
    ref = None
    for policy in PREFETCH_POLICIES:
        pipe, reader = _pipe(tmpfs, man, 32, shuffle=shuffle,
                             prefetch_policy=policy, lookahead_batches=4,
                             cache_budget_mb=1.0, num_workers=2)
        full = _collect(pipe)
        resumed = _collect(pipe, start_step=2)
        stats = pipe.prefetch_stats()
        pipe.close()
        reader.close()
        if ref is None:
            ref = full
        assert len(full) == pipe.steps_per_epoch()
        for a, b in zip(full, ref):
            np.testing.assert_array_equal(a, b)
        for a, b in zip(resumed, ref[2:]):
            np.testing.assert_array_equal(a, b)
        if policy == "clairvoyant":
            assert stats is not None and stats["hits"] > 0


def test_zipf_access_is_restart_exact(tmpfs):
    man, _, _ = _dataset(tmpfs, "packed", tag="_zipf")
    streams = []
    for policy in ("off", "clairvoyant"):
        pipe, reader = _pipe(tmpfs, man, 32, access="zipf",
                             prefetch_policy=policy, cache_budget_mb=1.0)
        order = pipe.epoch_order(1)
        assert order.shape[0] == 48
        assert len(set(order.tolist())) < 48  # hot set repeats records
        streams.append(_collect(pipe, epoch=1))
        pipe.close()
        reader.close()
    for a, b in zip(*streams):
        np.testing.assert_array_equal(a, b)


def test_reconfigure_mid_epoch_no_dup_no_drop(tmpfs):
    """Switching policy (and knobs) mid-epoch changes mechanics only: the
    remaining batches continue exactly where the stream left off."""
    man, _, _ = _dataset(tmpfs, "packed", tag="_mid")
    pipe, reader = _pipe(tmpfs, man, 32, prefetch_policy="off")
    ref = _collect(pipe)
    got = []
    it = pipe.iter_epoch(0)
    for s, batch in enumerate(it):
        got.append(batch.copy())
        if s == 1:
            pipe.reconfigure(prefetch_policy="clairvoyant",
                             lookahead_batches=2, cache_budget_mb=1.0)
        elif s == 3:
            pipe.reconfigure(prefetch_policy=0)  # numeric code for "off"
    assert len(got) == len(ref)
    for a, b in zip(got, ref):
        np.testing.assert_array_equal(a, b)
    pipe.close()
    reader.close()


def test_reconfigure_rejects_unknown_knobs(tmpfs):
    man, _, _ = _dataset(tmpfs, "packed", tag="_knob")
    pipe, reader = _pipe(tmpfs, man, 32)
    with pytest.raises(ValueError, match="unknown pipeline knob"):
        pipe.reconfigure(prefetch_dept=4)  # typo must surface, not no-op
    cfg = pipe.reconfigure(prefetch_policy=2)
    assert cfg.prefetch_policy == "clairvoyant"
    with pytest.raises(ValueError, match="prefetch_policy"):
        pipe.reconfigure(prefetch_policy="eager")
    pipe.close()
    reader.close()


def test_block_kb_reconfigure_drops_stale_prefetcher(tmpfs):
    man, _, _ = _dataset(tmpfs, "packed", tag="_bkb")
    pipe, reader = _pipe(tmpfs, man, 32, prefetch_policy="clairvoyant",
                         cache_budget_mb=1.0)
    first = pipe.fetch_batch(0, 0)
    before = _collect(pipe)
    assert pipe.prefetch_stats() is not None
    pipe.reconfigure(block_kb=8)
    assert pipe.prefetch_stats() is None  # stale plan granularity dropped
    assert reader.block_kb == 8
    after = _collect(pipe)
    np.testing.assert_array_equal(first, after[0])
    for a, b in zip(before, after):
        np.testing.assert_array_equal(a, b)
    pipe.close()
    reader.close()


# ---------------------------------------------------------------- the cache

def test_block_cache_schedule_aware_eviction():
    c = BlockCache(budget_bytes=25)  # holds two 10-byte blocks
    c.put((0, 0), b"B" * 10, last_use=5)   # LRU-oldest but still scheduled
    c.put((0, 10), b"A" * 10, last_use=1)  # expired once pos > 1
    c.pos = 3
    c.put((0, 20), b"C" * 10, last_use=6)
    # plain LRU would evict B (oldest); schedule-aware evicts expired A
    assert (0, 0) in c and (0, 20) in c
    assert (0, 10) not in c
    assert c.evicted == 1 and c.expired_evictions == 1
    # with nothing expired, fall back to LRU order
    c.put((0, 30), b"D" * 10, last_use=9)
    assert (0, 0) not in c
    assert c.evicted == 2 and c.expired_evictions == 1
    assert c.nbytes <= 25


def test_block_cache_keeps_one_over_budget_entry():
    c = BlockCache(budget_bytes=4)
    c.put((0, 0), b"x" * 64, last_use=0)
    assert len(c) == 1 and c.get((0, 0)) == b"x" * 64


def test_prefetcher_reconfigure_shrinks_cache(tmpfs):
    man, _, _ = _dataset(tmpfs, "packed", n=64, tag="_shrink")
    reader = open_dataset(tmpfs, man, block_kb=1)
    pipe = DataPipeline.from_reader(
        reader, 32, PipelineConfig(batch_size=8, seed=0, block_kb=1,
                                   prefetch_policy="clairvoyant"))
    pf = ClairvoyantPrefetcher(reader, pipe, lookahead_batches=8,
                               cache_budget_mb=1.0, workers=1)
    for s in range(4):
        pf.advance(0, s)
        for i in pipe.batch_indices(0, s):
            pf.read_record(int(i))
    assert len(pf.cache) > 1
    pf.reconfigure(cache_budget_mb=1e-6)  # ~1 byte: evict down to one entry
    assert len(pf.cache) == 1
    assert pf.stats()["evicted"] > 0
    pf.close()
    pipe.close()
    reader.close()


# ------------------------------------------------------- features / knobs

def test_autotune_feature_names_extend_paper_spec():
    assert AUTOTUNE_FEATURE_NAMES[: len(FEATURE_NAMES)] == FEATURE_NAMES
    for knob in ("prefetch_policy", "lookahead_batches", "cache_budget_mb"):
        assert knob in AUTOTUNE_FEATURE_NAMES
        assert knob in KNOB_NAMES
        assert knob not in FEATURE_NAMES  # the paper's 11 stay untouched


def test_telemetry_features_export_prefetch_knobs():
    t = StepTelemetry()
    with t.data_wait():
        pass
    with t.compute():
        pass
    t.record_batch(8, 1024)
    f = t.features(batch_size=8, num_workers=2, block_kb=16,
                   prefetch_policy="clairvoyant", lookahead_batches=4,
                   cache_budget_mb=32.0)
    assert f["prefetch_policy"] == 2  # numeric code in feature rows
    assert f["lookahead_batches"] == 4
    assert f["cache_budget_mb"] == 32.0


def test_default_config_space_grid_unchanged():
    """The new knobs are single-valued by default: the paper's 1,800-config
    grid must not grow underneath existing campaigns."""
    assert ConfigSpace().n_candidates == 1800


def test_prefetch_campaign_registered():
    camp = get_campaign("prefetch")
    for fast in (True, False):
        cases = camp.cases(fast)
        assert cases
        ids = [c.id for c in cases]
        assert len(ids) == len(set(ids))  # resume/shard keys must be unique
        assert {c.prefetch_policy for c in cases} == set(PREFETCH_POLICIES)
        assert all(c.bench_type == "pipeline" for c in cases)
        assert any(c.n_hosts == 2 for c in cases)  # sharded-epoch coverage
        assert any(c.access == "zipf" for c in cases)
    full = camp.cases(False)
    assert {c.backend for c in full} == {"network_sim", "object_sim"}


def test_autotuner_recommends_clairvoyant_when_it_wins():
    """Regression: the online tuner must rank/learn the new knobs — fed a
    run where clairvoyant wins, decide() proposes it."""
    space = ConfigSpace(batch_size=(32,), num_workers=(0,), block_kb=(16,),
                        n_threads=(1,), prefetch_depth=(2,),
                        prefetch_policy=(0, 1, 2))
    tuner = OnlineAutotuner(space=space, refit_every=3, min_observations=6,
                            min_config_diversity=3, gain_threshold=0.10)
    assert tuner._varied_knobs == ("prefetch_policy",)
    rng = np.random.default_rng(0)
    throughput = {0: 40.0, 1: 55.0, 2: 220.0}
    for rep in range(4):
        for code, mbs in throughput.items():
            feats = {"prefetch_policy": code, "file_size_mb": 12.0,
                     "n_samples": 0.0}
            tuner.observe(feats, mbs * (1.0 + 0.02 * rng.standard_normal()))
    assert tuner.maybe_refit()
    context = {"prefetch_policy": 1, "file_size_mb": 12.0, "n_samples": 0.0,
               "throughput_mb_s": throughput[1]}
    ranked = tuner.ranked(context, top_k=3)
    assert ranked and ranked[0]["prefetch_policy"] == 2
    current = {"batch_size": 32, "num_workers": 0, "block_kb": 16,
               "n_threads": 1, "prefetch_depth": 2, "prefetch_policy": 1}
    decision = tuner.decide(current, context)
    assert decision.reconfigure
    assert decision.config["prefetch_policy"] == 2
    assert decision.predicted_gain > 0.5
