"""ServeEngine (repro.serve.engine): continuous-batching slot lifecycle —
recycling after EOS/max_tokens, latency accounting, mixed-length prompts —
on a reduced dense config (first tier-1 coverage for the engine)."""

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import get_api
from repro.parallel.spec import init_params
from repro.serve.engine import Request, ServeEngine

VOCAB_SEED = np.random.default_rng(7)


@pytest.fixture(scope="module")
def model():
    cfg = reduced(get_config("codeqwen1.5-7b"))  # plain dense causal arch
    api = get_api(cfg)
    params = init_params(api.param_specs(cfg), jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture()
def engine(model):
    # function-scoped: slot caches and positions carry garbage across
    # requests by design (masking hides it), but tests asserting exact token
    # reproduction need a cold engine
    cfg, params = model
    return ServeEngine(cfg, params, max_len=64, slots=2)


def _prompt(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)


def test_run_completes_more_requests_than_slots(model, engine):
    """5 requests through 2 slots: every slot must be recycled at least once
    and every request runs to its own max_tokens."""
    cfg, _ = model
    reqs = [Request(rid=i, prompt=_prompt(cfg, 3, seed=i), max_tokens=2 + i)
            for i in range(5)]
    done = engine.run(reqs)
    assert {r.rid for r in done} == {0, 1, 2, 3, 4}
    assert all(r.done for r in done)
    assert [len(r.tokens) for r in sorted(done, key=lambda r: r.rid)] == \
        [2, 3, 4, 5, 6]
    # all slots returned to the free list; the engine is reusable
    assert sorted(engine._free) == [0, 1] and not engine._active
    assert engine.run([Request(rid=9, prompt=_prompt(cfg, 2), max_tokens=1)])


def test_latency_is_populated_and_ordered(model, engine):
    cfg, _ = model
    engine.run([Request(rid=9, prompt=_prompt(cfg, 2), max_tokens=1)])  # warm
    short = Request(rid=0, prompt=_prompt(cfg, 2), max_tokens=1)
    long = Request(rid=1, prompt=_prompt(cfg, 2, seed=1), max_tokens=40)
    done = engine.run([short]) + engine.run([long])
    assert all(r.latency_s > 0 for r in done)
    # latency spans prefill start -> finish, so more decode steps take longer
    assert long.latency_s > short.latency_s


def test_eos_finishes_early_and_frees_slot(model, engine):
    """A request whose eos_id matches the first greedily decoded token must
    finish after exactly one token, well short of max_tokens."""
    cfg, _ = model
    prompt = _prompt(cfg, 4, seed=3)
    [probe] = engine.run([Request(rid=0, prompt=prompt, max_tokens=4)])
    assert len(probe.tokens) == 4  # eos_id=-1 never fires

    # same prompt on a cold engine decodes the same greedy sequence
    eos_engine = ServeEngine(cfg, engine.params, max_len=64, slots=2)
    [req] = eos_engine.run([Request(rid=1, prompt=prompt, max_tokens=4,
                                    eos_id=probe.tokens[0])])
    assert req.done and req.tokens == [probe.tokens[0]]
    assert sorted(eos_engine._free) == [0, 1]


def test_mixed_length_prompts_batch_together(model, engine):
    """Slots holding prompts of different lengths decode in one batch without
    interfering with each other's completion bookkeeping."""
    cfg, _ = model
    lengths = [1, 7, 3, 5]
    reqs = [Request(rid=i, prompt=_prompt(cfg, n, seed=10 + i), max_tokens=3)
            for i, n in enumerate(lengths)]
    done = engine.run(reqs)
    assert {r.rid for r in done} == {0, 1, 2, 3}
    assert all(len(r.tokens) == 3 for r in done)
    assert all(0 <= t < cfg.vocab_padded for r in done for t in r.tokens)


def test_submit_rejects_when_full_then_recycles(model, engine):
    cfg, _ = model
    a = Request(rid=0, prompt=_prompt(cfg, 2), max_tokens=2)
    b = Request(rid=1, prompt=_prompt(cfg, 2, seed=1), max_tokens=2)
    assert engine.submit(a) and engine.submit(b)
    assert not engine.submit(Request(rid=2, prompt=_prompt(cfg, 2)))  # full
    while engine._active:
        engine.step()
    assert a.done and b.done
    assert engine.submit(Request(rid=2, prompt=_prompt(cfg, 2)))  # recycled
    while engine._active:
        engine.step()
