"""Architecture config sanity: exact assigned dims, padding rules, cells."""

import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, get_config, list_cells, reduced, shape_supported


EXPECTED_DIMS = {
    # (layers, d_model, heads, kv, d_ff, vocab)
    "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
    "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
    "granite-20b": (52, 6144, 48, 1, 24576, 49152),
    "gemma3-4b": (34, 2560, 8, 4, 10240, 262144),
    "deepseek-coder-33b": (62, 7168, 56, 8, 19200, 32256),
    "codeqwen1.5-7b": (32, 4096, 32, 32, 13440, 92416),
    "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
    "whisper-base": (6, 512, 8, 8, 2048, 51865),
    "paligemma-3b": (18, 2048, 8, 1, 16384, 257216),
    "falcon-mamba-7b": (64, 4096, 0, 0, 0, 65024),
}


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_assigned_dims_exact(name):
    c = get_config(name)
    exp = EXPECTED_DIMS[name]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab_size) == exp


def test_moe_configs():
    c1 = get_config("granite-moe-1b-a400m")
    assert (c1.n_experts, c1.top_k) == (32, 8) and c1.n_experts_padded == 32
    c3 = get_config("granite-moe-3b-a800m")
    assert (c3.n_experts, c3.top_k) == (40, 8) and c3.n_experts_padded == 48
    j = get_config("jamba-v0.1-52b")
    assert (j.n_experts, j.top_k, j.attn_period) == (16, 2, 8)


def test_vocab_padding_multiple_of_256():
    for c in ARCHS.values():
        assert c.vocab_padded % 256 == 0 and c.vocab_padded >= c.vocab_size


def test_param_counts_plausible():
    # ballpark totals (within 35% of the named sizes; vocab+arch variants)
    approx = {
        "granite-20b": 20e9, "deepseek-coder-33b": 33e9, "codeqwen1.5-7b": 7e9,
        "falcon-mamba-7b": 7e9, "jamba-v0.1-52b": 52e9,
    }
    for name, target in approx.items():
        n = get_config(name).param_count()
        assert 0.65 * target < n < 1.35 * target, (name, n)
    # MoE active < total
    gm = get_config("granite-moe-1b-a400m")
    assert gm.active_param_count() < gm.param_count()


def test_40_cells_accounted():
    cells = list_cells()
    assert len(cells) == 40
    skips = [c for c in cells if not c["run"]]
    assert len(skips) == 7  # 7 archs skip long_500k
    assert all(c["shape"] == "long_500k" for c in skips)


def test_gemma3_local_global_pattern():
    c = get_config("gemma3-4b")
    globals_ = [i for i in range(c.n_layers) if c.is_global_layer(i)]
    assert globals_ == [5, 11, 17, 23, 29]  # every 6th of 34 layers


def test_jamba_attn_positions():
    c = get_config("jamba-v0.1-52b")
    attn = [i for i in range(c.n_layers) if c.is_attn_layer(i)]
    assert attn == [4, 12, 20, 28]  # 1 per 8-layer block
    moe = [i for i in range(c.n_layers) if c.is_moe_layer(i)]
    assert len(moe) == 16  # every other layer


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_reduced_configs_are_small(name):
    c = reduced(get_config(name))
    assert c.d_model <= 64 and c.n_layers <= 8
    assert c.param_count() < 10_000_000


def test_shapes_registry():
    assert SHAPES["train_4k"].kind == "train"
    assert SHAPES["decode_32k"].kind == "decode"
    assert SHAPES["long_500k"].global_batch == 1
