"""Cross-backend transfer semantics (``repro.core.transfer``): fold
disjointness/completeness, byte-identical report determinism, the few-shot
calibration learning curve, host profiles, and the CLI."""

import json

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.features import (
    FEATURE_NAMES,
    HOST_PROFILE_FEATURE_NAMES,
    TARGET_NAME,
    TRANSFER_FEATURE_NAMES,
    transfer_spec,
)
from repro.core.transfer import (
    AffineCalibrator,
    BACKEND_CLASSES,
    ResidualGBTCalibrator,
    SYNTHETIC_BACKENDS,
    backend_class,
    default_profiles,
    evaluate_transfer,
    format_report,
    group_folds,
    main as transfer_main,
    make_calibrator,
    measure_host_profile,
    observations_from_records,
    profile_for_backend,
    synthetic_transfer_observations,
)

FAST_MODELS = ("linear", "ridge")


@pytest.fixture(scope="module")
def synth():
    """Small synthetic track shared by the harness tests (module-scoped:
    generation is cheap, but the fitted folds are not)."""
    return synthetic_transfer_observations(n_per_backend=48, seed=0)


@pytest.fixture(scope="module")
def report(synth):
    obs, groups = synth
    return evaluate_transfer(obs, groups, models=FAST_MODELS,
                             calibration_model="xgboost", seed=0)


# ---------------------------------------------------------------- features

def test_transfer_spec_extends_paper_spec():
    spec = transfer_spec()
    assert spec.names[: len(FEATURE_NAMES)] == FEATURE_NAMES
    assert spec.names == TRANSFER_FEATURE_NAMES
    assert set(HOST_PROFILE_FEATURE_NAMES) <= set(spec.names)
    assert spec.n_features == len(FEATURE_NAMES) + len(HOST_PROFILE_FEATURE_NAMES)


def test_backend_class_codes_stable_and_disjoint():
    for name, code in BACKEND_CLASSES.items():
        assert backend_class(name) == code
    # unknown backends: stable across calls, never colliding with the four
    assert backend_class("lustre_fs") == backend_class("lustre_fs")
    assert backend_class("lustre_fs") >= 4
    assert backend_class("lustre_fs") != backend_class("beegfs")


def test_default_profiles_cover_shipped_backends():
    profiles = default_profiles()
    assert set(profiles) == set(SYNTHETIC_BACKENDS)
    for name, prof in profiles.items():
        feats = prof.as_features()
        assert set(feats) == set(HOST_PROFILE_FEATURE_NAMES)
        assert feats["baseline_read_mb_s"] > 0
    # tiers are ordered: tmpfs > disk > network_sim > object_sim
    reads = [profiles[n].baseline_read_mb_s for n in SYNTHETIC_BACKENDS]
    assert reads == sorted(reads, reverse=True)


def test_profile_for_unknown_backend_synthesized():
    prof = profile_for_backend("exotic_store")
    assert prof.backend == "exotic_store"
    assert prof.backend_class == backend_class("exotic_store")
    assert prof.baseline_read_mb_s == 0.0  # "never measured"


def test_measure_host_profile_real_io(tmp_path):
    from repro.data.storage import StorageBackend

    backend = StorageBackend("disk_t", tmp_path)
    prof = measure_host_profile(backend, size_mb=0.5, block_kb=64)
    assert prof.backend == "disk_t"
    assert prof.baseline_read_mb_s > 0 and prof.baseline_write_mb_s > 0
    assert prof.cpu_count >= 1
    assert not list(tmp_path.glob("hostprofile_*"))  # probe file cleaned up


# ------------------------------------------------------------------ folds

def test_group_folds_disjoint_and_complete(synth):
    _, groups = synth
    folds = group_folds(groups)
    assert set(folds) == set(SYNTHETIC_BACKENDS)
    all_idx = np.concatenate(list(folds.values()))
    assert len(all_idx) == len(groups)
    assert len(set(all_idx.tolist())) == len(groups)  # disjoint
    for g, ix in folds.items():
        assert all(groups[i] == g for i in ix.tolist())


@settings(max_examples=25, deadline=None)
@given(st.lists(st.sampled_from(["a", "b", "c", "d"]), min_size=2, max_size=64))
def test_every_row_in_exactly_one_fold(labels):
    """Property: each observation lands in exactly one held-out fold."""
    folds = group_folds(labels)
    seen = [i for ix in folds.values() for i in ix.tolist()]
    assert sorted(seen) == list(range(len(labels)))
    for g, ix in folds.items():
        assert {labels[i] for i in ix.tolist()} == {g}


# ------------------------------------------------------------- calibrators

def test_affine_calibrator_k0_is_identity():
    cal = AffineCalibrator()
    p = np.linspace(1.0, 5.0, 7)
    assert np.allclose(cal.apply(None, p), p)
    cal.fit(None, np.empty(0), np.empty(0))
    assert np.allclose(cal.apply(None, p), p)


def test_affine_calibrator_single_row_is_offset_only():
    cal = AffineCalibrator().fit(None, np.asarray([2.0]), np.asarray([3.5]))
    assert cal.a == 1.0 and cal.b == pytest.approx(1.5)


def test_affine_calibrator_recovers_scale_shift():
    rng = np.random.default_rng(0)
    p = rng.uniform(1.0, 6.0, 40)
    y = 1.3 * p + 0.7
    cal = AffineCalibrator().fit(None, p, y)
    assert cal.a == pytest.approx(1.3, abs=1e-9)
    assert cal.b == pytest.approx(0.7, abs=1e-9)
    assert np.allclose(cal.apply(None, p), y)


def test_affine_calibrator_never_inverts_ordering():
    # anti-correlated residuals would fit a <= 0: fall back to offset-only
    p = np.asarray([1.0, 2.0, 3.0, 4.0])
    y = np.asarray([4.0, 3.0, 2.0, 1.0])
    cal = AffineCalibrator().fit(None, p, y)
    assert cal.a == 1.0  # monotone by construction
    out = cal.apply(None, p)
    assert np.all(np.diff(out) > 0)  # ranking preserved


def test_gbt_calibrator_degrades_to_affine_below_min_rows():
    X = np.random.default_rng(1).uniform(size=(8, 3))
    p = np.linspace(1.0, 3.0, 8)
    cal = ResidualGBTCalibrator(min_rows=16).fit(X, p, p + 0.5)
    assert cal.model is None
    assert cal.as_dict()["estimators"] == 0
    assert np.allclose(cal.apply(X, p), p + 0.5)


def test_gbt_calibrator_fits_residual_structure():
    rng = np.random.default_rng(2)
    X = rng.uniform(size=(64, 3))
    p = rng.uniform(1.0, 4.0, 64)
    y = p + np.where(X[:, 0] > 0.5, 1.0, -1.0)  # knob-dependent residual
    cal = ResidualGBTCalibrator(min_rows=16).fit(X, p, y)
    assert cal.model is not None
    err = np.abs(cal.apply(X, p) - y)
    base = np.abs(AffineCalibrator().fit(X, p, y).apply(X, p) - y)
    assert err.mean() < base.mean()


def test_make_calibrator_rejects_unknown_kind():
    assert make_calibrator("affine").kind == "affine"
    assert make_calibrator("gbt").kind == "gbt"
    with pytest.raises(ValueError, match="unknown calibrator"):
        make_calibrator("quantile")


# ---------------------------------------------------------------- harness

def test_report_covers_all_folds_and_models(report):
    assert set(report["folds"]) == set(SYNTHETIC_BACKENDS)
    for fold in report["folds"].values():
        assert set(fold["zoo"]) == set(FAST_MODELS)
        assert fold["n_train"] + fold["n_test"] == report["n_rows"]
        assert fold["n_eval"] >= fold["n_test"] // 4
        curve = fold["calibration"]["curve"]
        assert "k0" in curve
        for point in curve.values():
            assert np.isfinite(point["mape"]) and point["mape"] >= 0


def test_report_is_deterministic(synth):
    obs, groups = synth
    a = evaluate_transfer(obs, groups, models=FAST_MODELS, seed=0)
    b = evaluate_transfer(obs, groups, models=FAST_MODELS, seed=0)
    assert json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True)


def test_timings_stay_out_of_the_report(synth):
    obs, groups = synth
    timings = {}
    with_t = evaluate_transfer(obs, groups, models=FAST_MODELS, seed=0,
                               timings=timings)
    without = evaluate_transfer(obs, groups, models=FAST_MODELS, seed=0)
    assert json.dumps(with_t, sort_keys=True) == json.dumps(without, sort_keys=True)
    assert set(timings) == set(SYNTHETIC_BACKENDS)
    assert all(t > 0 for t in timings.values())


def test_calibration_curve_monotone_on_synthetic_track(synth):
    """k=25 must beat zero-shot where transfer actually fails: the scale
    extremes (tmpfs, object_sim) force the tree model to extrapolate, and
    the backend scale is a pure log-space shift — exactly what the affine
    correction removes.  Interior folds sit inside the training range, so
    calibration is allowed to be a wash there, but never much worse."""
    obs, groups = synth
    rep = evaluate_transfer(obs, groups, models=("xgboost",), ks=(0, 25),
                            calibration_model="xgboost", seed=0)
    for gname, fold in rep["folds"].items():
        curve = fold["calibration"]["curve"]
        if gname in ("tmpfs", "object_sim"):  # extrapolated folds
            assert curve["k25"]["mape"] <= curve["k0"]["mape"], gname
        else:
            assert curve["k25"]["mape"] <= 1.2 * curve["k0"]["mape"], gname
    assert rep["max_mape_reduction_k25"] >= 1.5


def test_evaluate_transfer_input_validation(synth):
    obs, groups = synth
    with pytest.raises(ValueError, match="groups length"):
        evaluate_transfer(obs, groups[:-1], models=FAST_MODELS)
    with pytest.raises(ValueError, match=">= 2 distinct groups"):
        evaluate_transfer(obs, ["only"] * len(groups), models=FAST_MODELS)
    with pytest.raises(ValueError, match="negative"):
        evaluate_transfer(obs, groups, models=FAST_MODELS, ks=(0, -5))


def test_observations_from_records_roundtrip():
    records = []
    for i, backend in enumerate(("tmpfs", "disk")):
        for j in range(3):
            row = {name: float(i + j + 1) for name in FEATURE_NAMES}
            row.update({TARGET_NAME: 100.0 * (i + 1), "backend": backend})
            records.append({"status": "ok", "row": row,
                            "host": f"host{i}", "case_id": f"c{i}{j}"})
    records.append({"status": "error", "case_id": "bad"})  # skipped
    obs, groups = observations_from_records(records)
    assert groups == ["tmpfs"] * 3 + ["disk"] * 3
    assert set(obs) == set(TRANSFER_FEATURE_NAMES) | {TARGET_NAME}
    assert obs["backend_class"].tolist() == [0.0] * 3 + [1.0] * 3
    assert obs["baseline_read_mb_s"][0] > obs["baseline_read_mb_s"][3]
    by_host, hosts = observations_from_records(records, group_key="host")
    assert hosts == ["host0"] * 3 + ["host1"] * 3


def test_format_report_lists_every_fold(report):
    text = format_report(report)
    for backend in SYNTHETIC_BACKENDS:
        assert backend in text
    assert "leave-one-backend-out" in text


# -------------------------------------------------------------------- CLI

def test_cli_fast_deterministic_json(tmp_path, capsys):
    args = ["--fast", "--n-per-backend", "24", "--models", "linear", "ridge",
            "--k", "0", "5", "--json"]
    assert transfer_main(args) == 0
    first = capsys.readouterr().out
    assert transfer_main(args) == 0
    second = capsys.readouterr().out
    assert first == second  # byte-identical report
    payload = json.loads(first)
    assert set(payload["folds"]) == set(SYNTHETIC_BACKENDS)


def test_cli_writes_report_file(tmp_path, capsys):
    out = tmp_path / "transfer" / "report.json"
    assert transfer_main(["--fast", "--n-per-backend", "16", "--models",
                          "linear", "ridge", "--k", "0", "--out", str(out)]) == 0
    assert "leave-one-backend-out" in capsys.readouterr().out
    assert json.loads(out.read_text())["group_key"] == "backend"


def test_cli_records_mode(tmp_path, capsys):
    rows = []
    for i, backend in enumerate(("tmpfs", "disk", "network_sim")):
        for j in range(8):
            row = {name: float(1 + i + 0.1 * j) for name in FEATURE_NAMES}
            row.update({TARGET_NAME: 50.0 * (i + 1) + j, "backend": backend})
            rows.append({"status": "ok", "row": row, "case_id": f"c{i}_{j}",
                         "rep": 0, "seed": j})
    path = tmp_path / "merged.jsonl"
    path.write_text("".join(json.dumps(r) + "\n" for r in rows))
    assert transfer_main(["--records", str(path), "--models", "linear",
                          "ridge", "--k", "0", "5"]) == 0
    text = capsys.readouterr().out
    assert "network_sim" in text


def test_cli_errors_are_usage_exits(tmp_path, capsys):
    assert transfer_main(["--records", str(tmp_path / "nope.jsonl")]) == 2
    assert "no such result file" in capsys.readouterr().err
    empty = tmp_path / "empty.jsonl"
    empty.write_text("")
    assert transfer_main(["--records", str(empty)]) == 2
    assert "no successful observation rows" in capsys.readouterr().err
    # single-group records cannot be folded
    row = {name: 1.0 for name in FEATURE_NAMES}
    row.update({TARGET_NAME: 10.0, "backend": "tmpfs"})
    single = tmp_path / "single.jsonl"
    single.write_text(json.dumps({"status": "ok", "row": row,
                                  "case_id": "c0"}) + "\n")
    assert transfer_main(["--records", str(single), "--models", "linear"]) == 2
    assert "2 distinct groups" in capsys.readouterr().err
