"""StepTelemetry correctness: exception-safe timing windows and finite
exports (regression tests for the continuous-loop bugfixes)."""

import numpy as np
import pytest

from repro.data.telemetry import StepTelemetry


def test_exception_in_step_body_still_records_sample():
    t = StepTelemetry()
    with pytest.raises(RuntimeError):
        with t.data_wait():
            raise RuntimeError("loader crashed")
    with pytest.raises(ValueError):
        with t.compute():
            raise ValueError("step blew up")
    assert len(t.data_times) == 1 and len(t.compute_times) == 1
    assert t.data_times[0] >= 0.0 and t.compute_times[0] >= 0.0


def test_windows_stay_paired_across_failures():
    """A mid-run failure must not desynchronize the data/compute windows."""
    t = StepTelemetry()
    for i in range(5):
        try:
            with t.data_wait():
                if i == 2:
                    raise RuntimeError("transient read error")
        except RuntimeError:
            pass
        with t.compute():
            pass
        t.record_batch(4, 4096)
    assert len(t.data_times) == len(t.compute_times) == 5
    assert 0.0 <= t.data_loading_ratio() <= 1.0


def test_delivered_mb_s_finite_without_samples():
    t = StepTelemetry()
    assert t.delivered_mb_s() == 0.0  # no data at all
    t.record_batch(4, 1_000_000)
    assert t.delivered_mb_s() == 0.0  # bytes but no data-wait time yet
    t.data_times.append(0.5)
    assert t.delivered_mb_s() == pytest.approx(2.0)  # 1 MB / 0.5 s


def test_exported_features_always_finite():
    t = StepTelemetry()
    feats = t.features(batch_size=32, num_workers=2, block_kb=64)
    assert all(np.isfinite(float(v)) for v in feats.values())
