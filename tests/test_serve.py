"""Concurrent serving tier (repro.service.serve): batched-vs-sequential
byte-equivalence, refit-aware cache invalidation, hot-swap races, graceful
drain, kill -9 resumability of the embedded loop, and the torn-tail-safe
state readers it polls."""

import contextlib
import http.client
import json
import os
import signal
import subprocess
import sys
import threading
import time

import pytest

from _hypothesis_compat import given, settings, st
from repro.core.autotune import ConfigSpace, OnlineAutotuner
from repro.core.features import TARGET_NAME
from repro.service.serve import (
    MicroBatcher,
    RecommendationService,
    ResponseCache,
    ServeConfig,
    context_key,
    run_smoke,
    synthetic_observations,
    warm_tuner_from_records,
)
from repro.service.serve import main as serve_main
from repro.service.state import LoopState, read_complete_records

CTX = {"file_size_mb": 64.0, "n_samples": 1000.0, "throughput_mb_s": 150.0}


def _space():
    return ConfigSpace(batch_size=(16, 32, 64), num_workers=(0, 2, 4),
                       block_kb=(64, 256), n_threads=(1,),
                       prefetch_depth=(1, 2))


def _fitted_tuner(scale=1.0, **kw):
    kw.setdefault("min_observations", 8)
    kw.setdefault("refit_every", 8)
    t = OnlineAutotuner(space=_space(), **kw)
    rows = synthetic_observations(t.space, n_repeats=1)
    if scale != 1.0:
        rows = [{**r, TARGET_NAME: r[TARGET_NAME] * scale} for r in rows]
    t.seed_observations(rows)
    assert t.maybe_refit()
    return t


@pytest.fixture(scope="module")
def frozen_tuner():
    """One fitted model shared by the read-only tests (never refit)."""
    return _fitted_tuner()


@contextlib.contextmanager
def _serving(tuner, **kw):
    svc = RecommendationService(tuner, ServeConfig(**kw))
    svc.start()
    try:
        yield svc
    finally:
        svc.shutdown()


def _raw(port, method, path, payload=None, timeout=30):
    """One HTTP request; returns (status, raw body bytes)."""
    conn = http.client.HTTPConnection("127.0.0.1", port, timeout=timeout)
    try:
        body = json.dumps(payload).encode() if payload is not None else None
        conn.request(method, path, body=body)
        resp = conn.getresponse()
        return resp.status, resp.read()
    finally:
        conn.close()


def _fire_concurrent(port, reqs):
    """All requests released through one barrier; responses in request order."""
    results = [None] * len(reqs)
    barrier = threading.Barrier(len(reqs))

    def worker(i, req):
        barrier.wait()
        results[i] = _raw(port, *req)

    threads = [threading.Thread(target=worker, args=(i, r))
               for i, r in enumerate(reqs)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return results


def _mixed_requests(n=16):
    cands = _space().candidates()
    reqs = []
    for i in range(n):
        if i % 3 == 2:  # recommends share the context -> in-batch dedup path
            reqs.append(("POST", "/recommend", {"context": CTX, "top_k": 3}))
        else:
            reqs.append(("POST", "/predict",
                         {"context": CTX, "config": cands[i % len(cands)]}))
    return reqs


# ------------------------------------------------- batched == sequential

def test_batched_concurrent_equals_sequential_bytes(frozen_tuner):
    """N concurrent clients against the micro-batched service get
    byte-identical JSON to N serial requests against the unbatched one."""
    reqs = _mixed_requests(16)
    with _serving(frozen_tuner, batching=False, cache_size=0) as svc:
        serial = [_raw(svc.port, *r) for r in reqs]
    # a batch window holds the door open so the barrier-released clients
    # actually coalesce (drain-only batching would be timing-dependent here)
    with _serving(frozen_tuner, batching=True, cache_size=0,
                  batch_window_ms=100, max_batch=64) as svc:
        concurrent = _fire_concurrent(svc.port, reqs)
        assert svc._batcher.max_batch_seen >= 2  # coalescing really happened
    assert all(s == 200 for s, _ in serial)
    assert serial == concurrent  # statuses AND raw bytes


def test_recommend_dedup_scores_shared_context_once(frozen_tuner):
    with _serving(frozen_tuner, batching=True, cache_size=0,
                  batch_window_ms=100) as svc:
        reqs = [("POST", "/recommend", {"context": CTX, "top_k": 4})] * 6
        results = _fire_concurrent(svc.port, reqs)
    bodies = {body for _, body in results}
    assert len(bodies) == 1  # all clients saw one identical ranking


# ------------------------------------------------- cache correctness

def test_cache_hit_equals_cold_and_refit_invalidates():
    tuner = _fitted_tuner()
    payload = {"context": CTX, "top_k": 3}
    with _serving(tuner, batching=True, cache_size=64) as svc:
        s1, cold = _raw(svc.port, "POST", "/recommend", payload)
        s2, hit = _raw(svc.port, "POST", "/recommend", payload)
        assert (s1, s2) == (200, 200)
        assert hit == cold and svc.cache.hits == 1
        assert json.loads(cold)["model_generation"] == 1

        # key is order-insensitive over the context dict
        flipped = {"top_k": 3,
                   "context": dict(reversed(list(CTX.items())))}
        _, hit2 = _raw(svc.port, "POST", "/recommend", flipped)
        assert hit2 == cold and svc.cache.hits == 2

        # refit on changed data: generation bumps, old entries unreachable
        rows = [{**r, TARGET_NAME: r[TARGET_NAME] * (3.0 if r["num_workers"] == 0 else 0.5)}
                for r in synthetic_observations(tuner.space, n_repeats=1)]
        tuner.seed_observations(rows)
        assert tuner.maybe_refit() and tuner.generation == 2

        s3, fresh = _raw(svc.port, "POST", "/recommend", payload)
        assert s3 == 200
        assert json.loads(fresh)["model_generation"] == 2  # never the old gen
        assert fresh != cold
        s4, hit3 = _raw(svc.port, "POST", "/recommend", payload)
        assert hit3 == fresh and svc.cache.hits == 3


def test_predict_cache_keys_on_config_too(frozen_tuner):
    with _serving(frozen_tuner, batching=True, cache_size=64) as svc:
        a = _raw(svc.port, "POST", "/predict",
                 {"context": CTX, "config": {"batch_size": 16, "num_workers": 0}})
        b = _raw(svc.port, "POST", "/predict",
                 {"context": CTX, "config": {"batch_size": 64, "num_workers": 4}})
        assert a[1] != b[1]  # different configs must not collide
        assert svc.cache.hits == 0 and svc.cache.misses == 2


# ------------------------------------------------- hot-swap hammer

def test_hot_swap_hammer_never_mixes_generations():
    """Requests hammer the service while the main thread forces refits; every
    response's value must match the model of the generation it is tagged with
    (a mixed (model, generation) pair would produce a foreign value)."""
    tuner = _fitted_tuner(refit_every=1)
    probe = {"context": CTX,
             "config": {"batch_size": 32, "num_workers": 2, "block_kb": 64,
                        "prefetch_depth": 1}}
    row = tuner.spec.row(tuner.filter_context(probe["context"],
                                              knobs=probe["config"]))

    def expected_value(snap):
        return float(snap.predict_throughput_batch(row[None, :])[0])

    expected = {1: expected_value(tuner.snapshot())}
    stop = threading.Event()
    failures = []

    def hammer():
        while not stop.is_set():
            status, body = _raw(svc.port, "POST", "/predict", probe)
            if status != 200:
                failures.append((status, body))
                continue
            resp = json.loads(body)
            gen = resp["model_generation"]
            want = expected.get(gen)
            # `expected` is recorded right after each swap; a gen published
            # between a response and this check is filled in by then
            if want is None:
                time.sleep(0.01)
                want = expected.get(gen)
            if want != resp["predicted_throughput_mb_s"]:
                failures.append((gen, resp["predicted_throughput_mb_s"], want))

    with _serving(tuner, batching=True, cache_size=32,
                  batch_window_ms=2) as svc:
        threads = [threading.Thread(target=hammer) for _ in range(6)]
        for t in threads:
            t.start()
        try:
            for round_ in range(4):  # force refits while the hammer runs
                rows = [{**r, TARGET_NAME: r[TARGET_NAME] * (1 + 0.5 * round_)}
                        for r in synthetic_observations(tuner.space, n_repeats=1)]
                tuner.seed_observations(rows)
                assert tuner.maybe_refit()
                expected[tuner.generation] = expected_value(tuner.snapshot())
        finally:
            time.sleep(0.2)
            stop.set()
            for t in threads:
                t.join()
    assert not failures
    assert tuner.generation == 5  # the hammer really spanned 4 swaps


# ------------------------------------------------- graceful shutdown

def test_graceful_shutdown_drains_inflight_requests(frozen_tuner):
    svc = RecommendationService(
        frozen_tuner, ServeConfig(batching=True, cache_size=0,
                                  batch_window_ms=500, max_batch=64))
    svc.start()
    results = [None] * 8
    started = threading.Barrier(9)

    def client(i):
        started.wait()
        results[i] = _raw(svc.port, "POST", "/predict",
                          {"context": CTX, "config": {"batch_size": 16}})

    threads = [threading.Thread(target=client, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    started.wait()
    time.sleep(0.15)  # let the requests land inside the open batch window
    svc.shutdown()    # must score the queued batch, not abandon it
    for t in threads:
        t.join()
    assert all(r is not None and r[0] == 200 for r in results)
    bodies = {body for _, body in results}
    assert len(bodies) == 1  # identical probe -> identical canonical bytes
    with pytest.raises(OSError):  # and the socket is really gone
        _raw(svc.port, "GET", "/healthz", timeout=2)


def test_healthz_and_routing_errors(frozen_tuner):
    svc = RecommendationService(frozen_tuner, ServeConfig())
    status, body = svc.handle("GET", "/healthz", b"")
    assert status == 200 and json.loads(body)["fitted"] is True
    assert svc.handle("GET", "/nope", b"")[0] == 404
    assert svc.handle("POST", "/predict", b"{not json")[0] == 400
    assert svc.handle("POST", "/recommend", b'{"top_k": 0}')[0] == 400
    assert svc.handle("POST", "/recommend", b'{"context": []}')[0] == 400
    status, body = svc.handle("GET", "/explain", b"")
    exp = json.loads(body)
    assert status == 200 and exp["model_generation"] == 1
    assert [f["name"] for f in exp["features"]] == list(frozen_tuner.spec.names)


def test_unfitted_service_returns_503():
    svc = RecommendationService(OnlineAutotuner(space=_space()), ServeConfig())
    status, body = svc.handle("POST", "/predict", b'{"context": {}}')
    assert status == 503 and json.loads(body)["model_generation"] == 0
    assert svc.handle("POST", "/recommend", b'{"context": {}}')[0] == 503
    assert svc.handle("GET", "/explain", b"")[0] == 503


# ------------------------------------------------- embedded loop: kill -9

LOOP_ARGS = ["--campaign", "paper_concurrent", "--fast", "--cycles", "2",
             "--min-observations", "4", "--refit-every", "2"]


def _wait_for(predicate, timeout=60.0, interval=0.05):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if predicate():
            return True
        time.sleep(interval)
    return False


def test_kill9_embedded_loop_is_resumable(tmp_path):
    """SIGKILL the serving process mid-run; the loop state must resume
    exactly like a killed standalone loop (PR 3 guarantee)."""
    out = tmp_path / "serve_loop"
    env = {**os.environ, "PYTHONPATH": "src"}
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.service.serve", "--loop",
         *LOOP_ARGS, "--out-dir", str(out)],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT)
    try:
        state = LoopState(out / "loop_state.jsonl")
        assert _wait_for(lambda: len(state.cycles()) >= 1), \
            proc.communicate(timeout=5)[0]
    finally:
        proc.kill()  # SIGKILL: no atexit, no drain, nothing
        proc.wait(timeout=30)
    completed = [c["cycle"] for c in LoopState(out / "loop_state.jsonl").cycles()]
    assert completed and completed[0] == 0

    # resume through the standalone loop CLI against the same out-dir
    from repro.service.loop import main as loop_main
    assert loop_main([*LOOP_ARGS, "--out-dir", str(out)]) == 0
    cycles = LoopState(out / "loop_state.jsonl").cycles()
    assert [c["cycle"] for c in cycles] == [0, 1]
    assert LoopState(out / "loop_state.jsonl").next_cycle() == 2


# ------------------------------------------------- torn-tail state readers

def test_state_reader_tolerates_mid_append_tail(tmp_path):
    """A reader polling loop_state.jsonl while the writer is mid-record must
    see exactly the complete records (satellite fix regression test)."""
    path = tmp_path / "loop_state.jsonl"
    rec = {"schema_version": 2, "status": "ok", "n_observations": 4,
           "current_config": {"batch_size": 16}}
    with open(path, "w") as f:
        f.write(json.dumps({**rec, "cycle": 0}) + "\n")
        f.write(json.dumps({**rec, "cycle": 1}) + "\n")
        f.write('{"schema_version": 2, "cycle": 2, "status": "o')  # torn tail
    assert len(read_complete_records(path)) == 2
    st_ = LoopState(path)
    assert [c["cycle"] for c in st_.cycles()] == [0, 1]
    assert st_.next_cycle() == 2
    # the writer finishes its record -> the reader sees it on the next poll
    with open(path, "a") as f:
        f.write('k", "n_observations": 6, "current_config": {}}\n')
    assert [c["cycle"] for c in st_.cycles()] == [0, 1, 2]
    assert read_complete_records(tmp_path / "missing.jsonl") == []


def test_stats_reads_state_while_writer_appends(tmp_path, frozen_tuner):
    out = tmp_path / "serve"
    out.mkdir()
    rec = {"schema_version": 2, "cycle": 0, "status": "ok",
           "n_observations": 9, "refit": True, "drift": None,
           "current_config": {"batch_size": 16}}
    with open(out / "loop_state.jsonl", "w") as f:
        f.write(json.dumps(rec) + "\n")
        f.write('{"cycle": 1, "status": "o')  # concurrent append in flight
    svc = RecommendationService(frozen_tuner, ServeConfig(out_dir=out))
    status, body = svc.handle("GET", "/stats", b"")
    stats = json.loads(body)
    assert status == 200
    assert stats["loop"]["cycles_completed"] == 1
    assert stats["loop"]["last_cycle"]["cycle"] == 0


# ------------------------------------------------- warm start + smoke

def test_warm_from_records_and_smoke(tmp_path):
    space = _space()
    records = []
    for i, cand in enumerate(synthetic_observations(space, n_repeats=1)):
        row = dict(cand)
        records.append({"case_id": f"c{i}", "rep": 0, "seed": 1000,
                        "status": "ok", "row": row})
    path = tmp_path / "merged.jsonl"
    with open(path, "w") as f:
        for r in records:
            f.write(json.dumps(r) + "\n")
    tuner = OnlineAutotuner(space=space, min_observations=8)
    assert warm_tuner_from_records(tuner, path) == len(records)
    assert tuner.fitted and tuner.generation == 1

    # the CLI smoke path end-to-end (quiet), both serving modes
    assert run_smoke(ServeConfig(), progress=lambda m: None) == 0
    assert serve_main(["--smoke", "--no-batch", "--no-cache"]) == 0


# ------------------------------------------------- property tests

@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 30), st.booleans()), max_size=120),
       st.integers(min_value=1, max_value=7))
def test_lru_cache_never_exceeds_bound(ops, capacity):
    cache = ResponseCache(capacity)
    shadow = {}
    for key, is_put in ops:
        if is_put:
            cache.put((key,), str(key).encode())
            shadow[(key,)] = str(key).encode()
        else:
            got = cache.get((key,))
            assert got is None or got == shadow[(key,)]
        assert len(cache) <= capacity


@settings(max_examples=50, deadline=None)
@given(st.dictionaries(
    st.sampled_from(["batch_size", "num_workers", "file_size_mb",
                     "n_samples", "label"]),
    st.one_of(st.integers(-10**6, 10**6),
              st.floats(allow_nan=False, allow_infinity=False, width=32),
              st.text(max_size=8)),
    max_size=5),
    st.randoms(use_true_random=False))
def test_context_key_is_order_insensitive(d, rnd):
    items = list(d.items())
    rnd.shuffle(items)
    assert context_key(dict(items)) == context_key(d)
    # ints and equal floats canonicalize together (JSON clients disagree)
    assert context_key({"a": 1}) == context_key({"a": 1.0})
    assert context_key({}) == context_key(None) == ()


@settings(max_examples=15, deadline=None)
@given(st.lists(st.integers(0, 11), min_size=1, max_size=12))
def test_random_batch_partition_scores_identically(frozen_tuner, cut_points):
    """Scoring a random partition of a request list batch-by-batch yields the
    same bodies as scoring it as one batch (batching is invisible)."""
    svc = RecommendationService(frozen_tuner, ServeConfig(cache_size=0))
    cands = _space().candidates()

    def make_pendings():
        ps = []
        for i in range(12):
            if i % 4 == 3:
                ps.append(svc._recommend_pending(CTX, top_k=3))
            else:
                ps.append(svc._predict_pending(CTX, cands[(7 * i) % len(cands)]))
        return ps

    whole = make_pendings()
    svc._score_batch(whole)
    parts = make_pendings()
    bounds = sorted({0, 12, *[c % 12 for c in cut_points]})
    for lo, hi in zip(bounds, bounds[1:]):
        svc._score_batch(parts[lo:hi])
    assert all(p.event.is_set() for p in whole + parts)
    assert [p.body for p in whole] == [p.body for p in parts]
    assert [p.status for p in whole] == [p.status for p in parts]


# ------------------------------------------------- micro-batcher mechanics

def test_microbatcher_coalesces_and_drains_on_stop():
    scored = []
    gate = threading.Event()

    def score(batch):
        gate.wait(5)
        scored.append(len(batch))
        for p in batch:
            p.finish(200, b"{}")

    class P:  # minimal pending stand-in
        def __init__(self):
            self.event = threading.Event()

        def finish(self, status, body):
            self.event.set()

    mb = MicroBatcher(score, max_batch=8)
    first = P()
    assert mb.submit(first)  # worker picks it up and blocks in score()
    time.sleep(0.05)
    rest = [P() for _ in range(10)]
    for p in rest:
        assert mb.submit(p)
    gate.set()
    mb.stop()  # drain: all 11 scored before the worker exits
    assert not mb.submit(P())  # closed
    assert all(p.event.is_set() for p in [first] + rest)
    assert sum(scored) == 11
    assert mb.max_batch_seen == 8  # the queued 10 coalesced up to the cap
