"""Per-kernel allclose tests vs the pure-jnp oracles (interpret mode on CPU),
with hypothesis sweeps over shapes and dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.ops import flash_attention_op, gbt_predict_op, rmsnorm_op
from repro.kernels.ref import (
    attention_reference,
    gbt_predict_reference,
    rmsnorm_reference,
)


def _qkv(key, B, S, H, KV, Dh, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (B, S, H, Dh), dtype)
    k = jax.random.normal(k2, (B, S, KV, Dh), dtype)
    v = jax.random.normal(k3, (B, S, KV, Dh), dtype)
    return q, k, v


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "mask",
    [dict(causal=True), dict(causal=False), dict(causal=True, window=64),
     dict(causal=True, prefix=32)],
)
def test_flash_attention_masks_dtypes(dtype, mask):
    q, k, v = _qkv(jax.random.PRNGKey(0), 2, 256, 8, 4, 64, dtype)
    o = flash_attention_op(q, k, v, q_block=64, kv_block=64, **mask)
    r = attention_reference(q, k, v, **mask)
    err = float(jnp.max(jnp.abs(o.astype(jnp.float32) - r.astype(jnp.float32))))
    assert err < TOL[dtype], (mask, err)


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 3),
    n_blocks=st.integers(1, 4),
    h_pow=st.integers(0, 3),
    g_pow=st.integers(0, 2),
    dh=st.sampled_from([32, 64, 128]),
    causal=st.booleans(),
)
def test_flash_attention_shape_sweep(b, n_blocks, h_pow, g_pow, dh, causal):
    KV = 2 ** h_pow
    H = KV * 2 ** g_pow
    S = 64 * n_blocks
    q, k, v = _qkv(jax.random.PRNGKey(b), b, S, H, KV, dh, jnp.float32)
    o = flash_attention_op(q, k, v, causal=causal, q_block=64, kv_block=64)
    r = attention_reference(q, k, v, causal=causal)
    assert o.shape == q.shape
    err = float(jnp.max(jnp.abs(o - r)))
    assert err < 2e-5, err


def test_flash_attention_mqa():
    q, k, v = _qkv(jax.random.PRNGKey(9), 2, 128, 8, 1, 64, jnp.float32)
    o = flash_attention_op(q, k, v, causal=True, q_block=64, kv_block=64)
    r = attention_reference(q, k, v, causal=True)
    assert float(jnp.max(jnp.abs(o - r))) < 2e-5


# ---------------------------------------------------------------- rmsnorm
@settings(max_examples=10, deadline=None)
@given(
    rows=st.integers(1, 300),
    d=st.sampled_from([64, 128, 256, 512]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_rmsnorm_sweep(rows, d, dtype):
    x = jax.random.normal(jax.random.PRNGKey(rows), (rows, d), dtype)
    s = jax.random.normal(jax.random.PRNGKey(d), (d,), jnp.float32)
    o = rmsnorm_op(x, s, block_rows=64)
    r = rmsnorm_reference(x, s)
    err = float(jnp.max(jnp.abs(o.astype(jnp.float32) - r.astype(jnp.float32))))
    assert err < (1e-5 if dtype == jnp.float32 else 5e-2)


# ---------------------------------------------------------------- gbt
@settings(max_examples=8, deadline=None)
@given(
    n=st.integers(10, 200),
    n_estimators=st.integers(1, 25),
    depth=st.integers(1, 5),
    seed=st.integers(0, 100),
)
def test_gbt_kernel_sweep(n, n_estimators, depth, seed):
    from repro.core import GBTConfig, GBTRegressor

    rng = np.random.default_rng(seed)
    X = rng.normal(size=(max(n, 12), 7))
    y = np.sin(X[:, 0]) + X[:, 1]
    m = GBTRegressor(GBTConfig(n_estimators=n_estimators, max_depth=depth)).fit(X, y)
    ens = m.ensemble
    pk = np.asarray(gbt_predict_op(X, ens, row_block=64))
    pn = m.predict(X)  # numpy/JAX reference path
    np.testing.assert_allclose(pk, pn, rtol=1e-4, atol=1e-4)


def test_gbt_kernel_vs_jnp_oracle(synth_regression):
    from repro.core import GBTConfig, GBTRegressor

    X, y = synth_regression
    m = GBTRegressor(GBTConfig(n_estimators=12, max_depth=4)).fit(X, y)
    ens = m.ensemble
    pk = gbt_predict_op(X, ens, row_block=128)
    pr = gbt_predict_reference(
        jnp.asarray(X, jnp.float32), ens.feature, ens.threshold, ens.left,
        ens.right, ens.value, ens.max_depth, ens.base_score, ens.scale,
    )
    np.testing.assert_allclose(np.asarray(pk), np.asarray(pr), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------- mamba scan
def _mamba_ref(x, dt, B, C, a_log, d_skip):
    A = -jnp.exp(a_log.astype(jnp.float32))
    a = jnp.exp(dt[..., None].astype(jnp.float32) * A)
    bx = (dt.astype(jnp.float32) * x.astype(jnp.float32))[..., None] * \
        B[:, :, None, :].astype(jnp.float32)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, Bc = jax.lax.associative_scan(combine, (a, bx), axis=1)
    return (Bc * C[:, :, None, :].astype(jnp.float32)).sum(-1) + \
        d_skip * x.astype(jnp.float32)


@settings(max_examples=6, deadline=None)
@given(
    b=st.integers(1, 2),
    n_chunks=st.integers(1, 4),
    di=st.sampled_from([32, 64]),
    ds=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 50),
)
def test_mamba_scan_kernel_sweep(b, n_chunks, di, ds, seed):
    from repro.kernels.mamba_scan import mamba_scan

    S = 32 * n_chunks
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (b, S, di), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, di), jnp.float32))
    B = jax.random.normal(ks[2], (b, S, ds), jnp.float32)
    C = jax.random.normal(ks[3], (b, S, ds), jnp.float32)
    a_log = jnp.log(jnp.arange(1, ds + 1, dtype=jnp.float32))[None].repeat(di, 0)
    d_skip = jnp.ones((di,), jnp.float32)
    y_k = mamba_scan(x, dt, B, C, a_log, d_skip, chunk=32, di_block=32, interpret=True)
    y_r = _mamba_ref(x, dt, B, C, a_log, d_skip)
    assert float(jnp.max(jnp.abs(y_k - y_r))) < 1e-4


def test_ssm_chunk_local_path_matches_reference():
    """§Perf T1 lever correctness: chunk-local gates == full-seq reference."""
    import numpy as np

    from repro.configs import get_config, reduced
    from repro.models import get_api
    from repro.parallel.spec import init_params

    cfg0 = reduced(get_config("falcon-mamba-7b")).replace(ssm_scan_chunk=8)
    cfg1 = cfg0.replace(ssm_chunk_local=True)
    api = get_api(cfg0)
    params = init_params(api.param_specs(cfg0), jax.random.PRNGKey(0))
    batch = {"tokens": jnp.arange(2 * 32).reshape(2, 32) % cfg0.vocab_size,
             "labels": jnp.ones((2, 32), jnp.int32)}
    l0 = float(api.loss_fn(cfg0, params, batch))
    l1 = float(api.loss_fn(cfg1, params, batch))
    assert abs(l0 - l1) < 1e-5


def test_moe_local_dispatch_matches_full():
    """§Perf T3 lever correctness: sharded local dispatch sums == full."""
    import numpy as np

    from repro.models.common import moe_combine, moe_dispatch, moe_expert_compute

    T, D, E, K = 64, 16, 8, 2
    x = jax.random.normal(jax.random.PRNGKey(1), (T, D), jnp.float32)
    router = jax.random.normal(jax.random.PRNGKey(2), (D, E), jnp.float32)
    w_in = jax.random.normal(jax.random.PRNGKey(3), (E, D, 32), jnp.float32) * 0.1
    w_gate = jax.random.normal(jax.random.PRNGKey(4), (E, D, 32), jnp.float32) * 0.1
    w_out = jax.random.normal(jax.random.PRNGKey(5), (E, 32, D), jnp.float32) * 0.1
    xe, meta, C = moe_dispatch(x, router, n_experts=E, top_k=K, capacity_factor=1.25)
    full = moe_combine(moe_expert_compute(xe, w_in, w_gate, w_out), meta, T, D, E, C,
                       jnp.float32)
    acc = jnp.zeros((T, D), jnp.float32)
    for rank in range(2):
        lo, nl = rank * 4, 4
        xe_l, meta_l, C2 = moe_dispatch(
            x, router, n_experts=E, top_k=K, capacity_factor=1.25,
            expert_lo=lo, n_local=nl)
        acc = acc + moe_combine(
            moe_expert_compute(xe_l, w_in[lo:lo + nl], w_gate[lo:lo + nl],
                               w_out[lo:lo + nl]),
            meta_l, T, D, nl, C2, jnp.float32)
    assert float(jnp.max(jnp.abs(acc - full))) < 1e-5


def test_attn_probs_bf16_close_to_f32():
    """§Perf T2 lever: bf16 probs stay within bf16 tolerance of f32 path."""
    from repro.models.common import attention_heads_tp

    q, k, v = _qkv(jax.random.PRNGKey(7), 2, 128, 8, 4, 64, jnp.float32)
    o32 = attention_heads_tp(q, k, v, q_chunk=64)
    o16 = attention_heads_tp(q, k, v, q_chunk=64, probs_bf16=True)
    assert float(jnp.max(jnp.abs(o32 - o16))) < 3e-2
