"""Optimizer, checkpoint manager, trainer fault tolerance, serving engine."""

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.optim import AdamWConfig, adamw_update, compress_grads, cosine_schedule, decompress_grads


# ---------------------------------------------------------------- optim
def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, clip_norm=None)
    p = {"w": jnp.array([5.0, -3.0])}
    mu = {"w": jnp.zeros(2)}
    nu = {"w": jnp.zeros(2)}
    for step in range(200):
        g = {"w": 2 * p["w"]}  # grad of ||w||^2
        p, mu, nu, _ = adamw_update(g, p, mu, nu, jnp.int32(step), cfg)
    assert float(jnp.abs(p["w"]).max()) < 1e-2


def test_grad_clip_bounds_norm():
    cfg = AdamWConfig(clip_norm=1.0)
    g = {"w": jnp.full((4,), 100.0)}
    p = {"w": jnp.zeros(4)}
    mu = {"w": jnp.zeros(4)}
    nu = {"w": jnp.zeros(4)}
    _, mu2, _, m = adamw_update(g, p, mu, nu, jnp.int32(0), cfg)
    assert m["grad_norm"] > 100  # pre-clip norm reported
    assert float(jnp.abs(mu2["w"]).max()) <= 0.1 * 0.51  # (1-b1)*clipped

def test_cosine_schedule_shape():
    s0 = float(cosine_schedule(jnp.int32(0), warmup=10, total=100))
    sw = float(cosine_schedule(jnp.int32(10), warmup=10, total=100))
    send = float(cosine_schedule(jnp.int32(100), warmup=10, total=100))
    assert s0 == 0.0 and abs(sw - 1.0) < 1e-6 and send == pytest.approx(0.1, abs=1e-6)


def test_gradient_compression_error_feedback():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=512).astype(np.float32))}
    q, s, resid = compress_grads(g)
    deq = decompress_grads(q, s)
    err = float(jnp.abs(deq["w"] - g["w"]).max())
    assert err <= float(s["w"]) * 0.5 + 1e-7  # quantization bound
    # error feedback: residual carries exactly the quantization error
    np.testing.assert_allclose(
        np.asarray(resid["w"]), np.asarray(g["w"] - deq["w"]), atol=1e-7
    )


# ---------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.float32(3.5)},
            "step": jnp.int32(7)}
    for step in (1, 2, 3):
        mgr.save(step, tree, blocking=True)
    assert mgr.latest_step() == 3
    dirs = sorted(d.name for d in tmp_path.iterdir())
    assert dirs == ["step_2", "step_3"]  # retention
    restored = mgr.restore(tree)
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))
    assert float(restored["b"]["c"]) == 3.5


def test_checkpoint_atomicity_ignores_tmp(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    tree = {"a": jnp.zeros(3)}
    mgr.save(5, tree, blocking=True)
    # simulate a crash mid-write
    (tmp_path / "step_9.tmp").mkdir()
    assert mgr.latest_step() == 5


# ---------------------------------------------------------------- trainer
def _tiny_trainer(tmp_path, steps, autotune=False):
    from repro.configs import get_config, reduced
    from repro.data import DataPipeline, PipelineConfig, SyntheticTokenSource
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = reduced(get_config("codeqwen1.5-7b"))
    src = SyntheticTokenSource(128, 33, cfg.vocab_size, seed=0)
    pipe = DataPipeline(src, PipelineConfig(batch_size=4))
    tcfg = TrainerConfig(num_steps=steps, ckpt_every=4, ckpt_dir=str(tmp_path),
                         autotune=autotune, log_every=1000)
    return Trainer(cfg, pipe, tcfg)


def test_trainer_checkpoint_resume_exact(tmp_path):
    out1 = _tiny_trainer(tmp_path, 6).run()
    assert out1["final_step"] == 6
    # a new trainer resumes from the saved step and continues
    t2 = _tiny_trainer(tmp_path, 10)
    out2 = t2.run()
    assert out2["final_step"] == 10
    assert int(out2["state"]["step"]) == 10
    # compare against an uninterrupted run: same pipeline order -> same batches
    t3 = _tiny_trainer(tmp_path / "fresh", 10)
    out3 = t3.run()
    np.testing.assert_allclose(
        np.asarray(out2["state"]["params"]["final_norm"], np.float32),
        np.asarray(out3["state"]["params"]["final_norm"], np.float32),
        rtol=2e-3, atol=2e-3,
    )


def test_trainer_stop_flag_saves(tmp_path):
    t = _tiny_trainer(tmp_path, 50)
    orig = t._step

    def step_and_stop(state, batch):
        out = orig(state, batch)
        if int(out[0]["step"]) >= 3:
            t._stop = True  # simulates SIGTERM handler
        return out

    t._step = step_and_stop
    out = t.run()
    assert out["final_step"] == 3
    assert t.ckpt.latest_step() == 3  # emergency save happened


# ---------------------------------------------------------------- serving
def test_serve_engine_batched_requests():
    from repro.configs import get_config, reduced
    from repro.models import get_api
    from repro.parallel.spec import init_params
    from repro.serve import Request, ServeEngine

    cfg = reduced(get_config("codeqwen1.5-7b"))
    api = get_api(cfg)
    params = init_params(api.param_specs(cfg), jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_len=64, slots=2)
    reqs = [
        Request(rid=i, prompt=np.arange(1 + i, 5 + i, dtype=np.int32), max_tokens=4)
        for i in range(3)
    ]
    done = eng.run(reqs)
    assert len(done) == 3
    assert all(len(r.tokens) == 4 for r in done)
    # greedy decoding is deterministic: same prompt -> same continuation
    eng2 = ServeEngine(cfg, params, max_len=64, slots=2)
    again = eng2.run([Request(rid=9, prompt=np.arange(1, 5, dtype=np.int32), max_tokens=4)])
    assert again[0].tokens == [t for t in done[0].tokens]
