import numpy as np
import pytest

# NOTE: deliberately no XLA_FLAGS here — smoke tests must see 1 device.
# Mesh/sharding tests spawn subprocesses that set their own device count.


@pytest.fixture(scope="session")
def obs_fast():
    """Small real observation set collected once per session."""
    from repro.data.dataset import collect_observations, observations_to_columns

    rows = collect_observations(fast=True, force=False, cache=None)
    return rows, observations_to_columns(rows)


@pytest.fixture(scope="session")
def synth_regression():
    rng = np.random.default_rng(0)
    X = rng.uniform(-2, 2, (300, 11))
    y = np.sin(2 * X[:, 0]) + X[:, 1] ** 2 + 0.5 * X[:, 2] * X[:, 3]
    return X, y
