"""Optional-``hypothesis`` shim for the test suite.

The property tests use hypothesis when available; when it is not installed
(minimal containers), importing this module instead of ``hypothesis`` keeps
the module importable so every non-property test still runs.  The stand-in
``@given`` replaces the test with a zero-argument function that calls
``pytest.skip``, so property tests report as skipped, not errored.

Usage in test modules::

    from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            def _skipped():
                pytest.skip("hypothesis not installed (see requirements-dev.txt)")

            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped

        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _Strategies:
        """Accepts any ``st.<name>(...)`` call and returns a placeholder."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _Strategies()
