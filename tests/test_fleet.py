"""Fleet collection (repro.service.fleet): the byte-identical-merge
invariant across collector counts, crash/stale re-leasing (including a real
``kill -9``), coordinator resume, state-schema migration, and per-host
provenance in ``--status``."""

import os
import signal
import socket
import threading
import time

import pytest

from repro.core.autotune import ConfigSpace
from repro.data.campaign import load_records
from repro.data.registry import Campaign, matrix_cases
from repro.service.fleet import (
    FleetConfig,
    FleetCoordinator,
    run_collector,
    synthetic_executor,
)
from repro.service.fleet import main as fleet_main
from repro.service.loop import ContinuousTuningLoop, LoopConfig, _format_status
from repro.service.loop import main as loop_main
from repro.service.state import STATE_SCHEMA_VERSION, LoopState

# All in-process tests share one deterministic 6-case campaign and the
# synthetic executor: any collector topology must reproduce the exact same
# merged.jsonl bytes as an uninterrupted single-host run.


def _campaign():
    return Campaign(
        "fleet_fake", "test campaign",
        lambda fast=False: tuple(matrix_cases(
            "pipeline", id_prefix="ff", backend=["tmpfs"], format=["raw"],
            batch_size=[16, 32], num_workers=[0, 2, 4],
        )),
    )


def _space():
    return ConfigSpace(batch_size=(16, 32), num_workers=(0, 2, 4),
                       block_kb=(64,), n_threads=(1,), prefetch_depth=(1,))


def _fleet_cfg(out_dir, collectors, **kw):
    kw.setdefault("campaign", _campaign())
    kw.setdefault("cycles", 2)
    kw.setdefault("space", _space())
    kw.setdefault("min_observations", 6)
    kw.setdefault("refit_every", 6)
    kw.setdefault("poll_interval_s", 0.01)
    kw.setdefault("executor_kind", "synthetic")
    return FleetConfig(out_dir=out_dir, collectors=collectors, **kw)


def _single_host_bytes(tmp_path, cycles=2):
    """merged.jsonl bytes of the reference single-host loop run."""
    out = tmp_path / "single"
    cfg = LoopConfig(out_dir=out, campaign=_campaign(), cycles=cycles,
                     space=_space(), min_observations=6, refit_every=6)
    records = ContinuousTuningLoop(cfg, executor=synthetic_executor).run()
    return (out / "merged.jsonl").read_bytes(), records


def _decision_view(record):
    return {k: record[k] for k in
            ("cycle", "n_observations", "refit", "current_config", "top")} | {
            "decision": record["decision"]}


class _Handle:
    """In-process stand-in for a collector process that already exited."""

    def __init__(self, rc=0):
        self._rc = rc
        self.pid = os.getpid()

    def poll(self):
        return self._rc

    def kill(self):
        self._rc = -9


class _HangHandle:
    """A worker that stays alive but makes no progress (no heartbeats)."""

    def __init__(self):
        self._rc = None
        self.pid = 0

    def poll(self):
        return self._rc

    def kill(self):
        self._rc = -9


def _inline_spawn(cfg, fail_plan=None):
    """Spawn hook running the collector synchronously in this process.

    ``fail_plan`` maps (cycle, shard, attempt) -> max_cases: the attempt
    executes that many cases, writes no completion record, and its handle
    reports exit code -9 — exactly what a mid-shard ``kill -9`` leaves
    behind (durable partial records, no shard_done)."""
    plan = dict(fail_plan or {})

    def spawn(shard, cycle, attempt):
        max_cases = plan.get((cycle, shard, attempt))
        run_collector(cfg, cycle, shard, max_cases=max_cases, attempt=attempt)
        return _Handle(-9 if max_cases is not None else 0)

    return spawn


# ------------------------------------------------------- merge invariant


def test_fleet_merged_byte_identical_across_collector_counts(tmp_path):
    """The core fleet guarantee: merged.jsonl after every cycle is
    byte-identical for 1, 2, and 4 collectors — and identical to a plain
    single-host loop run — and so are the decisions taken on top of it."""
    ref_bytes, ref_records = _single_host_bytes(tmp_path)
    for n in (1, 2, 4):
        cfg = _fleet_cfg(tmp_path / f"fleet{n}", collectors=n)
        records = FleetCoordinator(cfg, spawn=_inline_spawn(cfg)).run()
        assert (cfg.out_dir / "merged.jsonl").read_bytes() == ref_bytes
        assert len(records) == len(ref_records) == 2
        for a, b in zip(ref_records, records):
            assert _decision_view(a) == _decision_view(b)
        assert records[0]["schema_version"] == STATE_SCHEMA_VERSION
        assert records[0]["collectors"] == n
        assert set(records[0]["hosts"]) == {f"host_{i}" for i in range(n)}
        assert records[0]["n_executed"] == 6  # disjoint + complete shards


def test_fleet_collector_crash_releases_and_dataset_matches(tmp_path):
    """Shard 1's first attempt dies after one case; the coordinator
    re-leases it, the replacement resumes the missing cases, and the final
    dataset is still byte-identical to the single-host run."""
    ref_bytes, _ = _single_host_bytes(tmp_path, cycles=1)
    cfg = _fleet_cfg(tmp_path / "crash", collectors=2, cycles=1)
    spawn = _inline_spawn(cfg, fail_plan={(0, 1, 0): 1})
    coord = FleetCoordinator(cfg, spawn=spawn)
    records = coord.run()
    assert records[0]["releases"] == 1
    assert records[0]["hosts"]["host_1"]["releases"] == 1
    assert records[0]["hosts"]["host_0"]["releases"] == 0
    leases = coord.fleet_log.records(type="lease", cycle=0, shard=1)
    assert [r["attempt"] for r in leases] == [0, 1]
    assert (cfg.out_dir / "merged.jsonl").read_bytes() == ref_bytes


def test_fleet_stale_collector_is_killed_and_released(tmp_path):
    """A worker that stays alive but stops heartbeating is declared stale,
    killed, and its shard re-leased."""
    cfg = _fleet_cfg(tmp_path / "stale", collectors=2, cycles=1,
                     heartbeat_timeout_s=0.2)
    hang = _HangHandle()
    state = {"hung_once": False}

    def spawn(shard, cycle, attempt):
        if shard == 0 and not state["hung_once"]:
            state["hung_once"] = True
            return hang
        run_collector(cfg, cycle, shard, attempt=attempt)
        return _Handle(0)

    records = FleetCoordinator(cfg, spawn=spawn).run()
    assert hang.poll() == -9  # the coordinator killed the stale worker
    assert records[0]["releases"] == 1
    keys = {(r["case_id"], r["rep"], r["seed"])
            for r in load_records(cfg.out_dir / "merged.jsonl")}
    assert len(keys) == 6  # dataset complete despite the hang


def test_fleet_case_failure_is_not_a_crash(tmp_path):
    """A collector whose *cases* fail still completes its shard: the failure
    is a durable error record (healed by the next invocation's repair pass),
    not a worker crash — the shard must NOT be re-leased."""
    cfg = _fleet_cfg(tmp_path / "flaky", collectors=2, cycles=1)

    def flaky(case, ctx, seed):
        if case.id == "ff-tmpfs-raw-b32-w4":
            raise RuntimeError("transient storage error")
        return synthetic_executor(case, ctx, seed)

    def spawn(shard, cycle, attempt):
        results = run_collector(cfg, cycle, shard, executor=flaky,
                                attempt=attempt)
        # mirror the subprocess contract: non-zero exit when cases failed
        return _Handle(1 if any(r.failures for r in results) else 0)

    coord = FleetCoordinator(cfg, spawn=spawn)
    records = coord.run()
    assert records[0]["n_failures"] == 1
    assert records[0]["releases"] == 0  # completed-with-failures != crashed
    assert all(r["attempt"] == 0
               for r in coord.fleet_log.records(type="lease", cycle=0))
    # next invocation's repair pass heals the dataset (inherited behavior)
    healed = FleetCoordinator(cfg, spawn=_inline_spawn(cfg))
    assert healed.run() == []  # all cycles complete; repair only
    keys = {(r["case_id"], r["rep"], r["seed"])
            for r in load_records(cfg.out_dir / "merged.jsonl")
            if r["status"] == "ok"}
    assert len(keys) == 6


def test_fleet_role_equals_collector_spelling(tmp_path):
    """`--role=collector` must run a collector, not a coordinator (regression:
    the light-path sniff only matched the space-separated form)."""
    out = tmp_path / "eq"
    rc = fleet_main(["--role=collector", "--campaign", "paper_concurrent",
                     "--fast", "--executor", "synthetic",
                     "--out-dir", str(out), "--cycle", "0", "--shard", "0/2",
                     "--seeds", "1000"])
    assert rc == 0
    from repro.service.fleet import collector_shard_path
    assert collector_shard_path(out, 0, 0).exists()
    assert not (out / "loop_state.jsonl").exists()  # no coordinator ran


def test_fleet_slow_case_is_not_declared_stale(tmp_path):
    """Liveness ticks keep a worker alive through a case slower than the
    heartbeat timeout (regression: per-case-only heartbeats made the
    coordinator kill healthy workers mid-long-I/O and loop on re-leases)."""
    cfg = FleetConfig(
        campaign="paper_concurrent", fast=True, cycles=1, collectors=2,
        out_dir=tmp_path / "slow", executor_kind="synthetic",
        sleep_per_case=5.0,          # one case >> heartbeat_timeout
        heartbeat_timeout_s=3.0, heartbeat_every_s=0.3,
        min_observations=99, poll_interval_s=0.05,
    )
    records = FleetCoordinator(cfg).run()
    assert records[0]["releases"] == 0  # nobody was killed as stale
    assert records[0]["n_executed"] == 2


def test_fleet_repair_uses_original_collector_count(tmp_path):
    """A fleet resumed with a different --collectors still repairs old
    cycles under the shard split they were collected with (regression:
    shards >= the new count were never scanned)."""
    cfg = _fleet_cfg(tmp_path / "resize", collectors=2, cycles=1)

    def flaky(case, ctx, seed):
        if case.id == "ff-tmpfs-raw-b32-w4":  # lands in shard 1 of 2
            raise RuntimeError("transient storage error")
        return synthetic_executor(case, ctx, seed)

    def spawn(shard, cycle, attempt):
        run_collector(cfg, cycle, shard, executor=flaky, attempt=attempt)
        return _Handle(0)

    first = FleetCoordinator(cfg, spawn=spawn).run()
    assert first[0]["n_failures"] == 1

    cfg2 = _fleet_cfg(tmp_path / "resize", collectors=1, cycles=1)
    healed = FleetCoordinator(cfg2, spawn=_inline_spawn(cfg2),
                              executor=synthetic_executor)
    assert healed.run() == []  # cycles complete; repair pass only
    ok = {(r["case_id"], r["rep"], r["seed"])
          for r in load_records(cfg.out_dir / "merged.jsonl")
          if r["status"] == "ok"}
    assert len(ok) == 6  # the shard-1 failure healed despite collectors=1


def test_fleet_gives_up_after_max_leases(tmp_path):
    """A shard that dies on every lease stops the cycle with a clear error
    instead of re-leasing forever; no cycle record is written."""
    cfg = _fleet_cfg(tmp_path / "doomed", collectors=2, cycles=1, max_leases=2)

    def spawn(shard, cycle, attempt):
        if shard == 0:
            return _Handle(1)  # dies instantly, every time
        run_collector(cfg, cycle, shard)
        return _Handle(0)

    coord = FleetCoordinator(cfg, spawn=spawn)
    with pytest.raises(RuntimeError, match="giving up"):
        coord.run()
    assert coord.state.next_cycle() == 0  # cycle not marked complete


def test_fleet_resume_between_cycles_matches_straight_run(tmp_path):
    """A coordinator killed between cycles resumes (warm-start over the
    per-host shard layout) and reaches the same decisions and bytes."""
    scfg = _fleet_cfg(tmp_path / "straight", collectors=2)
    straight = FleetCoordinator(scfg, spawn=_inline_spawn(scfg)).run()
    cfg = _fleet_cfg(tmp_path / "killed", collectors=2)
    FleetCoordinator(cfg, spawn=_inline_spawn(cfg)).run(max_cycles=1)
    rest = FleetCoordinator(cfg, spawn=_inline_spawn(cfg)).run()
    assert [r["cycle"] for r in rest] == [1]
    resumed = LoopState(cfg.out_dir / "loop_state.jsonl").cycles()
    assert len(resumed) == len(straight) == 2
    for a, b in zip(straight, resumed):
        assert _decision_view(a) == _decision_view(b)
    assert ((cfg.out_dir / "merged.jsonl").read_bytes()
            == (scfg.out_dir / "merged.jsonl").read_bytes())


# ------------------------------------------------------- real processes


def test_fleet_kill9_subprocess_collector_recovers(tmp_path):
    """An actual ``kill -9`` of a collector *process* mid-cycle: the
    coordinator sees the death, re-leases the shard, and the merged dataset
    is byte-identical to an undisturbed 1-collector fleet run."""
    common = dict(campaign="paper_concurrent", fast=True, cycles=1,
                  seeds_per_cycle=2, min_observations=4, refit_every=4,
                  executor_kind="synthetic", poll_interval_s=0.05)
    ref_cfg = FleetConfig(out_dir=tmp_path / "ref", collectors=1, **common)
    FleetCoordinator(ref_cfg).run()

    cfg = FleetConfig(out_dir=tmp_path / "killed", collectors=2,
                      sleep_per_case=0.5, heartbeat_timeout_s=60.0, **common)
    coord = FleetCoordinator(cfg)
    killed = {}

    def killer():
        # SIGKILL shard 1's first worker as soon as its lease is logged —
        # python startup plus the per-case pacing sleep guarantees it is
        # still mid-shard (it can't even have finished importing).
        deadline = time.time() + 60
        while time.time() < deadline:
            for lease in coord.fleet_log.records(type="lease", cycle=0, shard=1):
                if lease.get("attempt") == 0 and lease.get("worker_pid"):
                    try:
                        os.kill(lease["worker_pid"], signal.SIGKILL)
                        killed["pid"] = lease["worker_pid"]
                    except ProcessLookupError:
                        pass
                    return
            time.sleep(0.02)

    t = threading.Thread(target=killer)
    t.start()
    records = coord.run()
    t.join()
    assert killed, "test harness never found a worker to kill"
    assert records[0]["releases"] >= 1
    assert ((cfg.out_dir / "merged.jsonl").read_bytes()
            == (ref_cfg.out_dir / "merged.jsonl").read_bytes())


def test_fleet_cli_end_to_end(tmp_path, capsys):
    """Coordinator CLI with real subprocess collectors: run, no-op resume,
    then --status with per-host provenance and the fleet log summary."""
    out = tmp_path / "fleet"
    args = ["--collectors", "2", "--executor", "synthetic",
            "--campaign", "paper_concurrent", "--fast", "--cycles", "1",
            "--min-observations", "4", "--refit-every", "2",
            "--out-dir", str(out)]
    assert fleet_main(args) == 0
    capsys.readouterr()
    assert fleet_main(args) == 0
    assert "already complete" in capsys.readouterr().out
    assert fleet_main(["--status", "--out-dir", str(out)]) == 0
    status = capsys.readouterr().out
    assert "per-host provenance:" in status
    assert "fleet log:" in status
    assert socket.gethostname() in status


def test_canonical_merge_success_beats_stale_error():
    """A success is never shadowed by a stale error record for the same key,
    regardless of input order (regression: after a mid-cycle --collectors
    resize, the old split's error file can sort *after* the new split's
    success file, and last-in-input-order would keep the error)."""
    from repro.data.campaign import canonical_records

    err = {"case_id": "c", "rep": 0, "seed": 1000, "status": "error",
           "row": None, "error": {"type": "RuntimeError"}}
    ok = {"case_id": "c", "rep": 0, "seed": 1000, "status": "ok",
          "row": {"target_throughput": 1.0}}
    index = {"c": 0}
    for order in ([ok, err], [err, ok]):
        [merged] = canonical_records(order, index)
        assert merged["status"] == "ok"
    # error vs error still resolves latest-wins
    err2 = dict(err, error={"type": "OSError"})
    [merged] = canonical_records([err, err2], index)
    assert merged["error"]["type"] == "OSError"


# ------------------------------------------------------- state & status


def test_loop_state_v1_migration_shim(tmp_path):
    """Pre-fleet (schema v1) loop_state.jsonl files load, resume, and render
    under the v2 readers via the upgrade shim."""
    st = LoopState(tmp_path / "state.jsonl")
    st.append({
        "schema_version": 1, "cycle": 0, "status": "ok", "campaign": "x",
        "host": "oldbox", "n_executed": 26, "n_failures": 1,
        "n_observations": 26, "n_new_rows": 26, "refit": True, "drift": None,
        "refit_s": 0.1, "recommend_s": 0.002,
        "decision": {"reconfigure": False, "explore": False,
                     "predicted_gain": 0.0, "config": {}},
        "current_config": {"num_workers": 2},
    })
    [rec] = st.cycles()
    assert rec["schema_version"] == STATE_SCHEMA_VERSION
    assert rec["collectors"] == 1 and rec["releases"] == 0
    assert rec["hosts"] == {"host_0": {"host": "oldbox", "n_executed": 26,
                                       "n_failures": 1, "releases": 0}}
    assert st.next_cycle() == 1
    assert st.current_config() == {"num_workers": 2}
    rendered = _format_status(st.cycles())
    assert "oldbox" in rendered and "per-host provenance:" in rendered


def test_loop_status_cli_shows_per_host_provenance(tmp_path, capsys):
    """Regression (PR 4 satellite): single-host --status surfaces host
    identity — fleet and single-host cycle records share one schema."""
    out = tmp_path / "cli"
    assert loop_main(["--campaign", "paper_concurrent", "--fast",
                      "--cycles", "1", "--min-observations", "4",
                      "--refit-every", "2", "--out-dir", str(out)]) == 0
    capsys.readouterr()
    assert loop_main(["--status", "--out-dir", str(out)]) == 0
    status = capsys.readouterr().out
    assert "hosts" in status  # the per-cycle collector-count column
    assert "per-host provenance:" in status
    assert f"host={socket.gethostname()}" in status
