"""Per-architecture smoke tests (reduced configs): one forward/train step on
CPU asserting output shapes + finite values, plus prefill/decode parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, reduced
from repro.models import get_api
from repro.parallel.spec import init_params

B, S = 2, 32


def _batch(cfg):
    if cfg.family == "encdec":
        return {
            "frames": jnp.zeros((B, S, cfg.d_model), cfg.dtype),
            "tokens": jnp.zeros((B, cfg.dec_len), jnp.int32),
            "labels": jnp.ones((B, cfg.dec_len), jnp.int32),
        }
    if cfg.family == "vlm":
        return {
            "prefix_embeds": jnp.zeros((B, cfg.prefix_len, cfg.d_model), cfg.dtype),
            "tokens": jnp.zeros((B, S - cfg.prefix_len), jnp.int32),
            "labels": jnp.ones((B, S - cfg.prefix_len), jnp.int32),
        }
    return {"tokens": jnp.zeros((B, S), jnp.int32), "labels": jnp.ones((B, S), jnp.int32)}


@pytest.fixture(scope="module")
def arch_state():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = reduced(get_config(name))
            api = get_api(cfg)
            params = init_params(api.param_specs(cfg), jax.random.PRNGKey(0))
            cache[name] = (cfg, api, params)
        return cache[name]

    return get


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_train_step_smoke(name, arch_state):
    cfg, api, params = arch_state(name)
    loss = jax.jit(lambda p, b: api.loss_fn(cfg, p, b))(params, _batch(cfg))
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), name
    # random-init loss should be near ln(vocab)
    assert 0.5 * np.log(cfg.vocab_size) < float(loss) < 2.0 * np.log(cfg.vocab_size)


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_grad_step_smoke(name, arch_state):
    cfg, api, params = arch_state(name)
    g = jax.jit(jax.grad(lambda p, b: api.loss_fn(cfg, p, b)))(params, _batch(cfg))
    leaves = jax.tree.leaves(g)
    assert leaves, name
    assert all(bool(jnp.isfinite(x.astype(jnp.float32)).all()) for x in leaves), name
    total = sum(float(jnp.sum(jnp.abs(x.astype(jnp.float32)))) for x in leaves)
    assert total > 0, name


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_decode_step_smoke(name, arch_state):
    cfg, api, params = arch_state(name)
    cache = init_params(api.init_cache_specs(cfg, B, S), jax.random.PRNGKey(1))
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, new_cache = jax.jit(
        lambda p, c, t: api.decode_step(cfg, p, c, t, jnp.int32(3))
    )(params, cache, tok)
    assert logits.shape == (B, cfg.vocab_padded)
    assert bool(jnp.isfinite(logits).all()), name
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_prefill_smoke(name, arch_state):
    cfg, api, params = arch_state(name)
    if cfg.family == "encdec":
        arg = jnp.zeros((B, S, cfg.d_model), cfg.dtype)
    else:
        arg = jnp.zeros((B, S), jnp.int32)
    logits = jax.jit(lambda p, t: api.prefill(cfg, p, t))(params, arg)
    assert logits.shape == (B, cfg.vocab_padded)
    assert bool(jnp.isfinite(logits).all()), name


def test_decode_matches_prefill_dense(arch_state):
    """Greedy parity: decoding t tokens step-by-step must equal prefill logits
    at the same position (codeqwen = plain dense causal arch)."""
    cfg, api, params = arch_state("codeqwen1.5-7b")
    T = 8
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, size=(1, T)).astype(np.int32)
    # prefill path: logits of last position
    pl = api.prefill(cfg, params, jnp.asarray(toks))
    # decode path: feed tokens one by one
    cache = init_params(api.init_cache_specs(cfg, 1, T), jax.random.PRNGKey(0))
    for i in range(T):
        dl, cache = api.decode_step(
            cfg, params, cache, jnp.asarray(toks[:, i: i + 1]), jnp.int32(i)
        )
    np.testing.assert_allclose(
        np.asarray(pl, np.float32), np.asarray(dl, np.float32), rtol=2e-2, atol=2e-2
    )


def test_moe_capacity_drops_are_bounded(arch_state):
    """With cf=1.25 on random routing, most tokens keep all top-k slots."""
    cfg, api, params = arch_state("granite-moe-1b-a400m")
    from repro.models.common import moe_dispatch

    rng = jax.random.PRNGKey(0)
    x = jax.random.normal(rng, (256, cfg.d_model), jnp.float32)
    router = jax.random.normal(jax.random.PRNGKey(1), (cfg.d_model, cfg.n_experts), jnp.float32)
    xe, (slot, st, sg, keep), C = moe_dispatch(
        x, router, n_experts=cfg.n_experts_padded, top_k=cfg.top_k,
        capacity_factor=cfg.capacity_factor,
    )
    assert float(keep.mean()) > 0.8
    assert xe.shape == (cfg.n_experts_padded, C, cfg.d_model)


def test_long_context_support_flags():
    from repro.configs import shape_supported

    ok, _ = shape_supported(get_config("falcon-mamba-7b"), "long_500k")
    assert ok
    ok, why = shape_supported(get_config("granite-20b"), "long_500k")
    assert not ok and "full-attention" in why
