"""Chaos suite: the collect→merge→refit→serve path under seeded fault
injection (``repro.service.faults``).

The load-bearing assertions mirror the robustness guarantees in
``docs/robustness.md``:

- **Chaos equivalence** — with the deterministic ``every=k`` schedule
  (k >= 2), every injected transient fault is healed by one bounded retry /
  durable-append recovery / reader skip, so the canonical merged dataset is
  *byte-identical* to a fault-free run, for both a plain campaign and a
  2-collector fleet.
- **Accounting** — every injected fault shows up in provenance: retry counts
  on records, write-retry counts in shard_done records, corrupt-line counts
  at the readers; the plan's ledger reconciles exactly.
- **Containment** — deadlines turn runaway cases into recorded timeouts,
  repeated non-transient failures quarantine a key, poisoned rows are
  rejected before refit, a bad refit rolls back to the previous model, and
  the serving tier sheds (503) or deadlines (504) instead of hanging; chaos
  never surfaces to clients as a 500.
"""

import json
import queue
import threading
import time

import pytest

from repro.core.autotune import ConfigSpace, OnlineAutotuner
from repro.core.features import TARGET_NAME
from repro.data.campaign import case_index, load_records, load_records_ex, \
    merge_files, run_campaign
from repro.data.registry import Campaign, matrix_cases
from repro.service import faults
from repro.service.faults import FaultPlan, FaultSpec, default_plan
from repro.service.fleet import FleetConfig, FleetCoordinator, run_collector, \
    synthetic_executor
from repro.service.loop import ContinuousTuningLoop, LoopConfig
from repro.service.serve import MicroBatcher, RecommendationService, \
    ServeConfig, _Pending, synthetic_observations
from repro.service.state import FleetLog, LoopState


@pytest.fixture(autouse=True)
def _no_leaked_plan():
    yield
    faults.deactivate()


def _campaign():
    return Campaign(
        "chaos_fake", "chaos test campaign",
        lambda fast=False: tuple(matrix_cases(
            "pipeline", id_prefix="ch", backend=["tmpfs"], format=["raw"],
            batch_size=[16, 32], num_workers=[0, 2, 4],
        )),
    )


def _space():
    return ConfigSpace(batch_size=(16, 32), num_workers=(0, 2, 4),
                       block_kb=(64,), n_threads=(1,), prefetch_depth=(1,))


def _count_bad_lines(path):
    if not path.exists():
        return 0
    n = 0
    for line in path.read_text().splitlines():
        try:
            json.loads(line)
        except ValueError:
            n += 1
    return n


# ------------------------------------------------- campaign-level healing

def test_campaign_retries_heal_injected_io_errors(tmp_path):
    """Every injected transient I/O error is retried away: no failures, the
    retry count reconciles with the plan's ledger, and the canonical dataset
    matches a fault-free run byte-for-byte."""
    camp = _campaign()
    clean = tmp_path / "clean.jsonl"
    run_campaign(camp, clean, seed=5, executor=synthetic_executor)

    plan = faults.activate(FaultPlan(21, [
        FaultSpec("io_error", site="case:", every=3)]), export_env=False)
    chaos = tmp_path / "chaos.jsonl"
    result = run_campaign(camp, chaos, seed=5, executor=synthetic_executor,
                          max_retries=2)
    faults.deactivate()

    assert result.failures == []
    assert plan.total_injected("io_error") > 0
    assert result.retried == plan.total_injected("io_error")
    merge_files([clean], tmp_path / "m_clean.jsonl", index=case_index(camp))
    merge_files([chaos], tmp_path / "m_chaos.jsonl", index=case_index(camp))
    assert (tmp_path / "m_clean.jsonl").read_bytes() == \
           (tmp_path / "m_chaos.jsonl").read_bytes()


def test_clairvoyant_prefetcher_heals_injected_read_faults():
    """Transient io_error at the storage ``read:`` site during clairvoyant
    iteration is retried away inside the prefetcher: the batch stream stays
    byte-identical to a fault-free run and failed fetches never poison the
    cache (a poisoned block would corrupt a batch, not just slow it)."""
    import numpy as np

    from repro.data import (BACKENDS, DataPipeline, PipelineConfig,
                            TokenRecordCodec, open_dataset, write_dataset)

    backend = BACKENDS["tmpfs"]
    codec = TokenRecordCodec(32)
    rng = np.random.default_rng(11)
    recs = [codec.encode(rng.integers(0, 50_000, size=32, dtype=np.int32))
            for _ in range(4096)]
    man = write_dataset(backend, "chaos_pf", recs, "packed")

    def run_epoch():
        # the dataset must dwarf one lookahead window: block_plan coalesces
        # contiguous blocks, so a small file collapses into one or two huge
        # reads and the every=3 schedule never gets enough checks to fire
        reader = open_dataset(backend, man, block_kb=1)
        pipe = DataPipeline.from_reader(reader, 32, PipelineConfig(
            batch_size=8, seed=2, prefetch_policy="clairvoyant",
            lookahead_batches=4, cache_budget_mb=1.0))
        # deeper retry budget than the 1-in-`every` fire rate can exhaust,
        # so no interleaving of prefetch threads can surface a raw fault
        pipe._ensure_prefetcher().max_retries = 4
        batches = [b.copy() for b in pipe.iter_epoch(0)]
        stats = pipe.prefetch_stats()
        pipe.close()
        reader.close()
        return batches, stats

    clean, _ = run_epoch()
    plan = faults.activate(FaultPlan(17, [
        FaultSpec("io_error", site="read:", every=3)]), export_env=False)
    chaos, stats = run_epoch()
    faults.deactivate()

    assert plan.total_injected("io_error") > 0
    assert stats["retries"] > 0  # the faults really hit the prefetch path
    assert len(chaos) == len(clean) > 0
    for a, b in zip(chaos, clean):
        np.testing.assert_array_equal(a, b)


def test_campaign_durable_append_heals_enospc_and_torn_writes(tmp_path):
    """ENOSPC and torn writes on the result file are recovered in place:
    the file stays fully parseable, holds every record exactly once, and
    each injected write fault is one counted recovery."""
    plan = faults.activate(FaultPlan(33, [
        FaultSpec("enospc", site="append:", every=2),
        FaultSpec("torn_write", site="append:", every=3),
    ]), export_env=False)
    out = tmp_path / "torn.jsonl"
    result = run_campaign(_campaign(), out, seed=1,
                          executor=synthetic_executor)
    faults.deactivate()

    injected = plan.total_injected("enospc") + plan.total_injected("torn_write")
    assert injected > 0
    assert result.write_retries == injected
    records, n_corrupt, torn_tail = load_records_ex(out)
    assert n_corrupt == 0 and not torn_tail
    assert len(records) == 6 == len({r["case_id"] for r in records})
    assert all(r["status"] == "ok" for r in records)


def test_campaign_deadline_then_quarantine(tmp_path):
    """A case overrunning its deadline is recorded as a timeout; after
    ``quarantine_after`` non-transient failures its key is quarantined and
    every later resume skips it without running it again."""
    camp = _campaign()
    out = tmp_path / "slow.jsonl"

    def slow(case, ctx, seed):
        if case.id == "ch-tmpfs-raw-b16-w0":
            time.sleep(0.5)
        return synthetic_executor(case, ctx, seed)

    kw = dict(executor=slow, deadline_s=0.05, max_retries=2,
              quarantine_after=2)
    r1 = run_campaign(camp, out, **kw)
    assert r1.n_timeouts == 1 and len(r1.failures) == 1
    recs = load_records(out)
    bad = [r for r in recs if r["status"] == "error"]
    assert len(bad) == 1 and bad[0]["error"]["category"] == "timeout"

    r2 = run_campaign(camp, out, **kw)          # second timeout -> count 2
    assert r2.n_timeouts == 1 and r2.skipped == 5
    r3 = run_campaign(camp, out, **kw)          # count 2 -> quarantined
    assert r3.n_quarantined == 1 and r3.n_executed == 0
    quar = [r for r in load_records(out) if r["status"] == "quarantined"]
    assert len(quar) == 1 and quar[0]["case_id"] == "ch-tmpfs-raw-b16-w0"

    r4 = run_campaign(camp, out, **kw)          # terminal: plain resume skip
    assert r4.n_executed == 0 and r4.n_quarantined == 0 and r4.skipped == 6


# ------------------------------------------------- fleet chaos equivalence

def _fleet_cfg(out_dir, **kw):
    kw.setdefault("campaign", _campaign())
    kw.setdefault("cycles", 2)
    kw.setdefault("space", _space())
    kw.setdefault("min_observations", 6)
    kw.setdefault("refit_every", 6)
    kw.setdefault("poll_interval_s", 0.01)
    kw.setdefault("executor_kind", "synthetic")
    return FleetConfig(out_dir=out_dir, collectors=2, **kw)


class _Handle:
    def __init__(self, rc=0):
        self._rc = rc
        self.pid = 0

    def poll(self):
        return self._rc

    def kill(self):
        self._rc = -9


def _inline_spawn(cfg):
    def spawn(shard, cycle, attempt):
        run_collector(cfg, cycle, shard, attempt=attempt)
        return _Handle(0)
    return spawn


def _decision_view(record):
    return {k: record[k] for k in
            ("cycle", "n_observations", "refit", "current_config", "top",
             "decision")}


def test_fleet_chaos_merged_byte_identical_and_accounted(tmp_path):
    """The tentpole acceptance check: a 2-collector fleet run under the full
    deterministic chaos mix produces a merged.jsonl byte-identical to the
    fault-free run, takes the same decisions, and accounts for every
    injected fault in provenance counters."""
    clean_cfg = _fleet_cfg(tmp_path / "clean")
    clean_records = FleetCoordinator(
        clean_cfg, spawn=_inline_spawn(clean_cfg)).run()
    clean_bytes = (clean_cfg.out_dir / "merged.jsonl").read_bytes()

    plan = faults.activate(default_plan(123, every=3), export_env=False)
    chaos_cfg = _fleet_cfg(tmp_path / "chaos")
    chaos_records = FleetCoordinator(
        chaos_cfg, spawn=_inline_spawn(chaos_cfg)).run()
    faults.deactivate()

    # equivalence: same dataset bytes, same decisions on top of it
    assert (chaos_cfg.out_dir / "merged.jsonl").read_bytes() == clean_bytes
    assert len(chaos_records) == len(clean_records) == 2
    for a, b in zip(clean_records, chaos_records):
        assert _decision_view(a) == _decision_view(b)

    # the plan actually fired, and nothing it injected went unaccounted
    rep = plan.report()
    assert rep["total"] > 0
    totals = {k: 0 for k in ("retried", "timeouts", "quarantined",
                             "write_retries")}
    for r in chaos_records:
        for k in totals:
            totals[k] += int(r["faults"].get(k, 0))
    assert totals["retried"] == plan.total_injected("io_error")
    assert totals["write_retries"] == (plan.total_injected("enospc")
                                       + plan.total_injected("torn_write"))
    assert totals["timeouts"] == 0 and totals["quarantined"] == 0
    n_bad = (_count_bad_lines(chaos_cfg.out_dir / "loop_state.jsonl")
             + _count_bad_lines(chaos_cfg.out_dir / "fleet_state.jsonl"))
    assert n_bad == plan.total_injected("corrupt_line") > 0

    # the readers skip-and-count exactly those lines, and resume still works
    state = LoopState(chaos_cfg.out_dir / "loop_state.jsonl")
    cycles = state.cycles()
    assert len(cycles) == 2
    assert state.corrupt_lines == _count_bad_lines(state.path)
    log = FleetLog(chaos_cfg.out_dir / "fleet_state.jsonl")
    assert log.records(type="shard_done")
    assert log.corrupt_lines == _count_bad_lines(log.path)


def test_loop_refit_guard_rejects_poisoned_rows(tmp_path):
    """A poisoned (non-finite target) observation is rejected before it can
    reach the model: the loop completes, counts the rejection in the cycle's
    faults block, and still fits on the remaining clean rows."""
    def poisoned(case, ctx, seed):
        row = synthetic_executor(case, ctx, seed)
        if case.id == "ch-tmpfs-raw-b32-w4":
            row[TARGET_NAME] = float("nan")
        return row

    cfg = LoopConfig(out_dir=tmp_path / "loop", campaign=_campaign(),
                     cycles=2, space=_space(), min_observations=4,
                     refit_every=4)
    loop = ContinuousTuningLoop(cfg, executor=poisoned)
    records = loop.run()
    assert len(records) == 2
    assert records[0]["faults"]["rejected_rows"] == 1
    assert records[1]["faults"]["rejected_rows"] == 1  # re-poisoned per cycle
    assert loop.tuner.fitted
    assert records[-1]["n_observations"] == 10  # 12 rows - 2 rejected


def test_kill_mid_calibration_resumes_without_double_counting(tmp_path):
    """A crash inside the few-shot calibration (after the cycle's rows are on
    disk, before the cycle record lands) must not double-count calibration
    rows on resume: the re-run cycle calibrates once, from the same rows, and
    the state file's total matches an uninterrupted run exactly."""
    def switching(case, ctx, seed):
        backend = "syn_a" if seed < 1100 else "syn_b"
        scale = 1.0 if backend == "syn_a" else 3.0
        thr = scale * 100.0 * (1 + case.num_workers) * (1 + 0.002 * (seed % 5))
        return {TARGET_NAME: thr, "batch_size": case.batch_size,
                "num_workers": case.num_workers, "block_kb": case.block_kb,
                "file_size_mb": 8.0, "bench_type": "pipeline",
                "backend": backend}

    def cfg_for(name):
        return LoopConfig(out_dir=tmp_path / name, campaign=_campaign(),
                          cycles=2, space=_space(), min_observations=6,
                          refit_every=6)

    clean = ContinuousTuningLoop(cfg_for("clean"), executor=switching).run()
    assert clean[1]["transfer"]["calibrated"]
    clean_rows = sum(c["transfer"]["calibration_rows"] for c in clean)

    # crash mid-calibration: cycle 1's shard data is durable, its record is
    # not — the moral equivalent of kill -9 inside _transfer_step
    from repro.core.transfer import AffineCalibrator
    from repro.service import loop as loop_mod

    class _Killed(RuntimeError):
        pass

    class _CrashingCalibrator(AffineCalibrator):
        def fit(self, X, pred_log, y_log):
            raise _Killed("kill -9 mid-calibration")

    cfg = cfg_for("chaos")
    orig = loop_mod.AffineCalibrator
    loop_mod.AffineCalibrator = _CrashingCalibrator
    try:
        with pytest.raises(_Killed):
            ContinuousTuningLoop(cfg, executor=switching).run()
    finally:
        loop_mod.AffineCalibrator = orig
    st = LoopState(cfg.out_dir / "loop_state.jsonl")
    assert st.next_cycle() == 1  # cycle 1 never completed

    # resume: the re-run cycle re-detects syn_b and calibrates exactly once
    calls = []
    rest = ContinuousTuningLoop(cfg, executor=lambda c, x, s:
                                (calls.append(s), switching(c, x, s))[1]).run()
    assert [r["cycle"] for r in rest] == [1]
    assert rest[0]["transfer"]["calibrated"]
    resumed = st.cycles()
    assert sum(c["transfer"]["calibration_rows"] for c in resumed) == clean_rows
    assert resumed[1]["transfer"] == clean[1]["transfer"]
    # cycle 1's rows were already durable: nothing was re-collected
    assert 1000 not in set(calls)


def test_autotuner_rollback_restores_previous_generation():
    """``rollback()`` republishes the previous model under a *new*
    generation (cache invalidation must fire), flags the tuner degraded, and
    a later clean refit closes the circuit."""
    space = _space()
    tuner = OnlineAutotuner(space=space, min_observations=6, refit_every=6)
    tuner.seed_observations(synthetic_observations(space, n_repeats=1))
    assert tuner.maybe_refit() and tuner.generation == 1
    assert not tuner.rollback()  # nothing to roll back to yet

    tuner.seed_observations(synthetic_observations(space, n_repeats=1))
    assert tuner.maybe_refit() and tuner.generation == 2
    assert tuner.rollback()
    assert tuner.generation == 3      # forward, never reused
    assert tuner.degraded and tuner.rollbacks == 1
    assert not tuner.rollback()       # the stash is single-depth

    tuner.seed_observations(synthetic_observations(space, n_repeats=1))
    assert tuner.maybe_refit() and tuner.generation == 4
    assert not tuner.degraded         # clean refit closes the circuit


# ------------------------------------------------- serving under pressure

def _frozen_tuner():
    space = ConfigSpace(batch_size=(16, 32), num_workers=(0, 2),
                        block_kb=(64,), n_threads=(1,), prefetch_depth=(1,))
    tuner = OnlineAutotuner(space=space, min_observations=4, refit_every=4)
    tuner.seed_observations(synthetic_observations(space, n_repeats=1))
    assert tuner.maybe_refit()
    return tuner


def test_microbatcher_bounded_queue_sheds():
    """Past ``max_queue`` queued requests, ``submit`` raises ``queue.Full``
    instead of growing the backlog — the service turns that into a 503."""
    gate = threading.Event()
    mb = MicroBatcher(lambda batch: gate.wait(timeout=10), max_batch=1,
                      max_queue=2)
    first = _Pending("predict", ())
    assert mb.submit(first)
    deadline = time.monotonic() + 5
    while mb.depth > 0 and time.monotonic() < deadline:
        time.sleep(0.005)  # wait for the worker to take it (and block)
    assert mb.submit(_Pending("predict", ()))
    assert mb.submit(_Pending("predict", ()))
    with pytest.raises(queue.Full):
        mb.submit(_Pending("predict", ()))
    gate.set()
    mb.stop()
    assert not mb.submit(_Pending("predict", ()))  # closed, not full


def test_serve_deadline_budget_times_out_stuck_scoring(tmp_path):
    """A request whose scoring cannot finish inside the deadline budget gets
    a 504 instead of hanging the client forever."""
    svc = RecommendationService(_frozen_tuner(),
                                ServeConfig(deadline_ms=150.0))
    svc.start()
    try:
        with svc._score_lock:  # wedge the scorer; the batcher blocks on it
            status, body = svc.handle(
                "POST", "/predict", b'{"context": {}, "config": {}}')
        assert status == 504
        assert "deadline" in json.loads(body)["error"]
        status, body = svc.handle("GET", "/stats", b"")
        assert json.loads(body)["admission"]["deadline_timeouts"] == 1
    finally:
        svc.shutdown()


def test_healthz_degrades_on_loop_death_and_rollback():
    svc = RecommendationService(_frozen_tuner(), ServeConfig())
    status, body = svc.handle("GET", "/healthz", b"")
    h = json.loads(body)
    assert status == 200 and h["status"] == "ok"
    assert h["circuit"]["loop_alive"] is None

    # embedded loop thread died on an error -> degraded (still HTTP 200:
    # the process serves; its freshness pipeline is what broke)
    dead = threading.Thread(target=lambda: None)
    dead.start()
    dead.join()
    svc._loop_thread = dead
    svc.loop_error = "RuntimeError: collect exploded"
    status, body = svc.handle("GET", "/healthz", b"")
    h = json.loads(body)
    assert status == 200 and h["status"] == "degraded"
    assert h["circuit"]["loop_alive"] is False
    assert "exploded" in h["circuit"]["loop_error"]

    svc._loop_thread = None
    svc.loop_error = None
    svc.tuner.seed_observations(
        synthetic_observations(svc.tuner.space, n_repeats=1))
    svc.tuner.maybe_refit()
    assert svc.tuner.rollback()
    status, body = svc.handle("GET", "/healthz", b"")
    h = json.loads(body)
    assert status == 200 and h["status"] == "degraded"
    assert h["circuit"]["model_degraded"] and h["circuit"]["rollbacks"] == 1


def test_serve_storm_under_chaos_no_hangs_no_500s(tmp_path):
    """Clients hammering the service while the embedded loop collects under
    chaos see only complete responses: every status is 200 or 503 (unfitted
    early on), every 200 body is single-generation, nothing hangs, and the
    loop itself survives the injected faults."""
    faults.activate(default_plan(31, every=3), export_env=False)
    cfg = LoopConfig(out_dir=tmp_path / "loop", campaign=_campaign(),
                     cycles=2, space=_space(), min_observations=6,
                     refit_every=6)
    loop = ContinuousTuningLoop(cfg, executor=synthetic_executor)
    svc = RecommendationService(loop.tuner, ServeConfig(), loop=loop)
    svc.start()
    statuses, bad_bodies = [], []
    lock = threading.Lock()

    def client(i):
        payloads = [
            ("POST", "/predict", b'{"context": {"file_size_mb": 8},'
                                 b' "config": {"batch_size": 16}}'),
            ("POST", "/recommend", b'{"context": {}, "top_k": 2}'),
            ("GET", "/healthz", b""),
            ("GET", "/stats", b""),
        ]
        for j in range(6):
            method, path, body = payloads[(i + j) % len(payloads)]
            status, resp = svc.handle(method, path, body)
            obj = json.loads(resp)
            with lock:
                statuses.append(status)
                if status == 200 and "model_generation" in obj and \
                        not isinstance(obj["model_generation"], int):
                    bad_bodies.append(obj)
            time.sleep(0.01)

    threads = [threading.Thread(target=client, args=(i,)) for i in range(8)]
    try:
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
            assert not t.is_alive()  # no hangs
        deadline = time.monotonic() + 120
        while svc._loop_thread.is_alive() and time.monotonic() < deadline:
            time.sleep(0.05)
        assert not svc._loop_thread.is_alive()
        assert svc.loop_error is None  # the loop survived the chaos
    finally:
        svc.shutdown()
        faults.deactivate()
    assert set(statuses) <= {200, 503}  # bounded 503s OK; never 500/504
    assert not bad_bodies
    status, body = svc.handle("GET", "/healthz", b"")
    assert json.loads(body)["circuit"]["loop_error"] is None
