"""Continuous tuning loop (repro.service): dataset growth, refit, kill/resume
semantics, decision determinism, and the CLI end-to-end."""

import pytest

from repro.core.autotune import ConfigSpace
from repro.core.features import TARGET_NAME
from repro.data.campaign import load_records, run_campaign_batch
from repro.data.registry import Campaign, matrix_cases
from repro.service.loop import ContinuousTuningLoop, LoopConfig
from repro.service.loop import main as loop_main
from repro.service.state import LoopState

# A deterministic synthetic world (no real I/O): more workers -> faster, with
# a small seed-dependent wiggle so each cycle's rows are distinct but exactly
# reproducible.


def _campaign():
    return Campaign(
        "loop_fake", "test campaign",
        lambda fast=False: tuple(matrix_cases(
            "pipeline", id_prefix="lf", backend=["tmpfs"], format=["raw"],
            batch_size=[16, 32], num_workers=[0, 2, 4],
        )),
    )


def _executor(calls=None):
    def ex(case, ctx, seed):
        if calls is not None:
            calls.append((case.id, seed))
        thr = 100.0 * (1 + case.num_workers) * (1 + 0.002 * (seed % 5))
        return {TARGET_NAME: thr, "batch_size": case.batch_size,
                "num_workers": case.num_workers, "block_kb": case.block_kb,
                "file_size_mb": 8.0, "bench_type": "pipeline",
                "backend": "tmpfs"}
    return ex


def _cfg(out_dir, **kw):
    kw.setdefault("campaign", _campaign())
    kw.setdefault("cycles", 3)
    kw.setdefault("space", ConfigSpace(
        batch_size=(16, 32), num_workers=(0, 2, 4), block_kb=(64,),
        n_threads=(1,), prefetch_depth=(1,)))
    kw.setdefault("min_observations", 6)
    kw.setdefault("refit_every", 6)
    kw.setdefault("seed", 0)
    return LoopConfig(out_dir=out_dir, **kw)


def _decision_view(record):
    """The decision-relevant slice of a cycle record (provenance like
    timestamps and latency excluded)."""
    return {k: record[k] for k in
            ("cycle", "n_observations", "refit", "current_config", "top")} | {
            "decision": record["decision"]}


# ---------------------------------------------------------------- core loop


def test_loop_grows_dataset_refits_and_recommends(tmp_path):
    cfg = _cfg(tmp_path / "loop")
    records = ContinuousTuningLoop(cfg, executor=_executor()).run()
    assert [r["cycle"] for r in records] == [0, 1, 2]
    assert [r["n_observations"] for r in records] == [6, 12, 18]  # grows
    assert all(r["refit"] for r in records)  # refit_every == rows per cycle
    assert records[1]["drift"] is not None  # drift measured once a model exists
    # the loop discovered the best knob setting and adopted it
    assert records[-1]["current_config"]["num_workers"] == 4
    assert records[-1]["top"][0]["num_workers"] == 4
    scores = [t["predicted_throughput_mb_s"] for t in records[-1]["top"]]
    assert scores == sorted(scores, reverse=True)
    # per-cycle provenance carries the refit/recommend latency split
    assert all(r["refit_s"] >= 0 and r["recommend_s"] >= 0 for r in records)
    # the state file mirrors what run() returned
    st = LoopState(cfg.out_dir / "loop_state.jsonl")
    assert [c["cycle"] for c in st.cycles()] == [0, 1, 2]
    assert st.next_cycle() == 3
    assert st.current_config() == records[-1]["current_config"]


# refit_every == rows-per-cycle (6) refits every cycle; 8 leaves the schedule
# mid-window at the kill point, exercising the warm-start replay that must
# restore the exact refit-schedule position (not just the data).
@pytest.mark.parametrize("refit_every", [6, 8])
def test_loop_kill_between_cycles_resumes(tmp_path, refit_every):
    cfg = _cfg(tmp_path / "killed", refit_every=refit_every)
    first = ContinuousTuningLoop(cfg, executor=_executor()).run(max_cycles=1)
    assert [r["cycle"] for r in first] == [0]
    # "new process": a fresh instance pointed at the same out_dir
    calls = []
    rest = ContinuousTuningLoop(cfg, executor=_executor(calls)).run()
    assert [r["cycle"] for r in rest] == [1, 2]
    # cycle 0's seed window was not re-collected
    assert cfg.base_seed not in {s for _, s in calls}
    # and the killed+resumed run reaches the same decisions, refit points,
    # and drift values as an uninterrupted run with the same seed
    cfg2 = _cfg(tmp_path / "straight", refit_every=refit_every)
    straight = ContinuousTuningLoop(cfg2, executor=_executor()).run()
    resumed = LoopState(cfg.out_dir / "loop_state.jsonl").cycles()
    assert len(straight) == len(resumed) == 3
    for a, b in zip(straight, resumed):
        assert _decision_view(a) == _decision_view(b)
        assert a["drift"] == b["drift"]


def test_loop_repairs_failed_cases_on_next_invocation(tmp_path):
    """A transient benchmark crash in a completed cycle re-runs (and only it)
    on the next invocation, healing the dataset."""
    cfg = _cfg(tmp_path / "flaky", cycles=2)

    def flaky_one(case, ctx, seed):
        if case.id == "lf-tmpfs-raw-b32-w4":
            raise RuntimeError("transient storage error")
        return _executor()(case, ctx, seed)

    first = ContinuousTuningLoop(cfg, executor=flaky_one).run(max_cycles=1)
    assert first[0]["n_failures"] == 1
    assert first[0]["n_observations"] == 5  # one row short

    calls = []
    rest = ContinuousTuningLoop(cfg, executor=_executor(calls)).run()
    # the repair pass re-ran exactly the failed case from cycle 0's window
    assert ("lf-tmpfs-raw-b32-w4", cfg.base_seed) in calls
    assert len(calls) == 1 + 6  # 1 repaired + cycle 1's full window
    assert rest[-1]["n_observations"] == 12  # dataset healed + grown


def test_loop_repairs_failure_in_final_cycle(tmp_path):
    """A failure in the LAST cycle still heals: the repair pass runs before
    the 'all cycles complete' early exit."""
    from repro.data.campaign import rows_from_records

    cfg = _cfg(tmp_path / "lastfail", cycles=1)

    def flaky_one(case, ctx, seed):
        if case.id == "lf-tmpfs-raw-b32-w4":
            raise RuntimeError("transient storage error")
        return _executor()(case, ctx, seed)

    first = ContinuousTuningLoop(cfg, executor=flaky_one).run()
    assert first[0]["n_failures"] == 1
    calls = []
    loop = ContinuousTuningLoop(cfg, executor=_executor(calls))
    assert loop.run() == []  # all cycles complete -> no new cycle records
    assert calls == [("lf-tmpfs-raw-b32-w4", cfg.base_seed)]  # but it healed
    assert len(rows_from_records(load_records(loop.merged_path))) == 6


def test_loop_resume_replays_exploration(tmp_path):
    """With too few observed configs for the model (cold start), decisions
    come from the exploration sequence — which must survive kill+resume
    instead of restarting and re-proposing the same candidates."""
    two_case = Campaign(
        "loop_two", "2-case campaign (diversity below min_config_diversity)",
        lambda fast=False: tuple(matrix_cases(
            "pipeline", id_prefix="lt", backend=["tmpfs"], format=["raw"],
            batch_size=[16], num_workers=[0, 2],
        )),
    )
    space = ConfigSpace(batch_size=(16, 32), num_workers=(0, 2, 4),
                        block_kb=(64,), n_threads=(1,), prefetch_depth=(1,))
    kw = dict(campaign=two_case, cycles=3, space=space,
              min_observations=2, refit_every=2, seed=0)
    straight = ContinuousTuningLoop(
        _cfg(tmp_path / "straight", **kw), executor=_executor()).run()
    assert any(r["decision"]["explore"] for r in straight)  # cold start active
    cfg = _cfg(tmp_path / "killed", **kw)
    ContinuousTuningLoop(cfg, executor=_executor()).run(max_cycles=1)
    ContinuousTuningLoop(cfg, executor=_executor()).run()
    resumed = LoopState(cfg.out_dir / "loop_state.jsonl").cycles()
    assert [r["decision"] for r in resumed] == [r["decision"] for r in straight]


def test_loop_kill_mid_cycle_resumes_remaining_cases(tmp_path):
    """A loop killed during collection re-runs only the missing cases of the
    in-flight cycle (campaign-level resume inside the cycle's shard file)."""
    cfg = _cfg(tmp_path / "midkill", cycles=1)
    loop = ContinuousTuningLoop(cfg, executor=_executor())
    # simulate the kill: 2 of 6 cases already collected into the shard file
    run_campaign_batch(cfg.campaign, loop._shard_path(0), loop._cycle_seeds(0),
                       executor=_executor(), max_cases=2)
    calls = []
    records = ContinuousTuningLoop(cfg, executor=_executor(calls)).run()
    assert len(calls) == 4  # only the remaining cases executed
    assert records[0]["n_executed"] == 4
    assert records[0]["n_observations"] == 6  # full cycle dataset regardless


def test_loop_determinism_under_fixed_seed(tmp_path):
    views = []
    for d in ("a", "b"):
        cfg = _cfg(tmp_path / d)
        records = ContinuousTuningLoop(cfg, executor=_executor()).run()
        views.append([_decision_view(r) for r in records])
    assert views[0] == views[1]


def test_loop_merged_dataset_dedups_shards(tmp_path):
    from repro.data.dataset import observations_from_jsonl

    cfg = _cfg(tmp_path / "merged")
    loop = ContinuousTuningLoop(cfg, executor=_executor())
    loop.run()
    merged = load_records(loop.merged_path)
    keys = {(r["case_id"], r["rep"], r["seed"]) for r in merged}
    assert len(keys) == len(merged) == 18  # 6 cases x 3 seed windows
    # the JSONL observation reader agrees with the loop's ingested store
    rows = observations_from_jsonl([loop.merged_path])
    assert len(rows) == loop.tuner.n_observations == 18
    assert all(row[TARGET_NAME] > 0 for row in rows)


# ------------------------------------------------- transfer (schema v4)


def _backend_switch_executor(switch_seed):
    """Rows report backend ``syn_a`` below ``switch_seed`` and ``syn_b`` at or
    above it — the cycle whose seed window crosses the switch introduces a
    never-before-seen backend profile mid-run (3x the throughput scale, the
    multiplicative shift few-shot calibration repairs)."""
    def ex(case, ctx, seed):
        backend = "syn_a" if seed < switch_seed else "syn_b"
        scale = 1.0 if backend == "syn_a" else 3.0
        thr = scale * 100.0 * (1 + case.num_workers) * (1 + 0.002 * (seed % 5))
        return {TARGET_NAME: thr, "batch_size": case.batch_size,
                "num_workers": case.num_workers, "block_kb": case.block_kb,
                "file_size_mb": 8.0, "bench_type": "pipeline",
                "backend": backend}
    return ex


def test_new_backend_profile_calibrates_instead_of_refitting(tmp_path):
    """Cycle 2's seed window (1200+) introduces backend ``syn_b``: the loop
    must fit a few-shot affine calibration from that cycle's rows and skip
    the scheduled refit, recording both in the v4 ``transfer`` block."""
    cfg = _cfg(tmp_path / "xfer")
    loop = ContinuousTuningLoop(cfg, executor=_backend_switch_executor(1200))
    records = loop.run()

    t0, t1, t2 = (r["transfer"] for r in records)
    # cycle 0: first profile appears before any model exists -> no calibration
    assert t0["new_profiles"] == ["syn_a"] and t0["known_profiles"] == 1
    assert not t0["calibrated"] and records[0]["refit"]
    # cycle 1: nothing new
    assert t1["new_profiles"] == [] and not t1["calibrated"]
    # cycle 2: syn_b appears with a fitted model -> calibrate, skip refit
    assert t2["new_profiles"] == ["syn_b"] and t2["known_profiles"] == 2
    assert t2["calibrated"] and not records[2]["refit"]
    assert 0 < t2["calibration_rows"] <= cfg.calibration_k
    cal = t2["calibrations"]["syn_b"]
    assert cal["kind"] == "affine" and cal["n"] == t2["calibration_rows"]
    assert "syn_b" in loop.calibrators and loop.calibrators["syn_b"].a > 0
    # the state file round-trips the transfer block at schema v4
    st = LoopState(cfg.out_dir / "loop_state.jsonl")
    stored = st.cycles()
    assert all(c["schema_version"] == 4 for c in stored)
    assert stored[2]["transfer"] == t2


def test_calibration_k_zero_disables_calibration(tmp_path):
    cfg = _cfg(tmp_path / "nok", calibration_k=0)
    records = ContinuousTuningLoop(
        cfg, executor=_backend_switch_executor(1200)).run()
    t2 = records[2]["transfer"]
    assert t2["new_profiles"] == ["syn_b"]
    assert not t2["calibrated"] and t2["calibrations"] == {}
    assert records[2]["refit"]  # the scheduled refit ran as usual


def test_resume_replays_calibration_decision(tmp_path):
    """Kill after the calibration cycle: the warm-started resume must rebuild
    the same known-profile set and skipped-refit schedule, so the remaining
    cycles reach the same decisions as an uninterrupted run."""
    cfg = _cfg(tmp_path / "xkill", cycles=4)
    ex = _backend_switch_executor(1200)
    first = ContinuousTuningLoop(cfg, executor=ex).run(max_cycles=3)
    assert first[2]["transfer"]["calibrated"]
    rest = ContinuousTuningLoop(cfg, executor=ex).run()
    assert [r["cycle"] for r in rest] == [3]
    # syn_b is known after resume: no re-calibration, refits resume
    assert rest[0]["transfer"]["new_profiles"] == []
    assert not rest[0]["transfer"]["calibrated"]

    straight = ContinuousTuningLoop(
        _cfg(tmp_path / "xstraight", cycles=4), executor=ex).run()
    resumed = LoopState(cfg.out_dir / "loop_state.jsonl").cycles()
    for a, b in zip(straight, resumed):
        assert _decision_view(a) == _decision_view(b)
        assert a["transfer"] == b["transfer"]
        assert a["refit"] == b["refit"]


def test_state_upgrades_v1_v2_v3_to_v4(tmp_path):
    """Records written by every previous schema read back as v4 with the
    synthesized provenance blocks, idempotently."""
    from repro.service.state import (
        STATE_SCHEMA_VERSION, ZERO_FAULTS, ZERO_TRANSFER, upgrade_record,
    )

    st = LoopState(tmp_path / "state.jsonl")
    st.append({"schema_version": 1, "cycle": 0, "status": "ok",
               "host": "box-a", "n_executed": 4, "n_failures": 1,
               "current_config": {"num_workers": 0}})
    st.append({"schema_version": 2, "cycle": 1, "status": "ok",
               "collectors": 2, "releases": 0, "hosts": {},
               "current_config": {"num_workers": 2}})
    st.append({"schema_version": 3, "cycle": 2, "status": "ok",
               "collectors": 1, "releases": 0, "hosts": {},
               "faults": {**ZERO_FAULTS, "retried": 3},
               "current_config": {"num_workers": 4}})
    v1, v2, v3 = st.cycles()
    assert all(c["schema_version"] == STATE_SCHEMA_VERSION
               for c in (v1, v2, v3))
    # v1 grew the per-host block from its flat fields
    assert v1["hosts"]["host_0"] == {"host": "box-a", "n_executed": 4,
                                     "n_failures": 1, "releases": 0}
    # pre-hardening/pre-transfer records read as all-clear
    assert v1["faults"] == ZERO_FAULTS and v2["faults"] == ZERO_FAULTS
    assert v3["faults"]["retried"] == 3  # existing blocks are preserved
    for c in (v1, v2, v3):
        assert c["transfer"] == ZERO_TRANSFER
    # upgrades are idempotent and never alias the zero blocks
    assert upgrade_record(v1) == v1
    v1["transfer"]["new_profiles"].append("mutated")
    assert ZERO_TRANSFER["new_profiles"] == []
    assert v2["transfer"]["new_profiles"] == []


# ---------------------------------------------------------------- state


def test_loop_state_resume_points(tmp_path):
    st = LoopState(tmp_path / "state.jsonl")
    assert st.cycles() == [] and st.next_cycle() == 0
    assert st.current_config() is None
    st.append({"schema_version": 1, "cycle": 0, "status": "ok",
               "current_config": {"num_workers": 0}})
    st.append({"schema_version": 1, "cycle": 1, "status": "ok",
               "current_config": {"num_workers": 2}})
    assert st.next_cycle() == 2
    assert st.current_config() == {"num_workers": 2}
    # a re-run cycle record supersedes the earlier one (latest wins)
    st.append({"schema_version": 1, "cycle": 1, "status": "ok",
               "current_config": {"num_workers": 4}})
    assert [c["cycle"] for c in st.cycles()] == [0, 1]
    assert st.current_config() == {"num_workers": 4}
    # a torn trailing line (killed writer) is tolerated
    with open(st.path, "a") as f:
        f.write('{"cycle": 2, "status": "ok"')
    assert st.next_cycle() == 2


# ---------------------------------------------------------------- CLI


def test_loop_cli_end_to_end(tmp_path, capsys):
    """Real (tiny) campaign through the CLI: 2 cycles, then resume no-ops,
    then --status renders the cycle log."""
    out = tmp_path / "cli"
    args = ["--campaign", "paper_concurrent", "--fast", "--cycles", "2",
            "--min-observations", "4", "--refit-every", "2",
            "--out-dir", str(out), "--base-seed", "3000"]
    assert loop_main(args) == 0
    st = LoopState(out / "loop_state.jsonl")
    cycles = st.cycles()
    assert [c["cycle"] for c in cycles] == [0, 1]
    assert cycles[-1]["n_observations"] == 4  # 2 fast concurrent cases/cycle
    assert cycles[-1]["refit"]
    capsys.readouterr()
    # second invocation: everything complete, exits cleanly
    assert loop_main(args) == 0
    assert "already complete" in capsys.readouterr().out
    assert loop_main(["--status", "--out-dir", str(out)]) == 0
    status = capsys.readouterr().out
    assert "cycle" in status and " 0 " in status
