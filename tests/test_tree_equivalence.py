"""Golden equivalence: the level-wise and batched tree engines must reproduce
the reference DFS builder *exactly* — same arrays, same node numbering, same
leaf routing — on the paper model configs and across a property sweep of
builder settings.  (The oracle stays available via engine="reference" /
REPRO_TREE_ENGINE; the batched engine additionally proves its native-C and
pure-numpy code paths identical.)"""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import GBTBinaryClassifier, GBTConfig, GBTRegressor, RandomForestRegressor, RFConfig
from repro.core import _native
from repro.core.tree import (
    BinnedData,
    TreeBuilderConfig,
    bin_features,
    build_forest_batched,
    build_tree,
    build_tree_with_leaves,
    compute_bins,
    resolve_engine,
)

TREE_FIELDS = ("feature", "threshold", "left", "right", "value", "gain", "cover")
ENSEMBLE_FIELDS = ("feature", "threshold", "left", "right", "value")


def _assert_trees_identical(ta, tb):
    for f in TREE_FIELDS:
        np.testing.assert_array_equal(
            getattr(ta, f), getattr(tb, f), err_msg=f"tree field {f!r} differs"
        )


def _assert_ensembles_identical(ea, eb):
    for f in ENSEMBLE_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(ea, f)), np.asarray(getattr(eb, f)),
            err_msg=f"ensemble field {f!r} differs",
        )
    assert ea.base_score == eb.base_score and ea.scale == eb.scale


def _data(n=260, d=11, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.uniform(-2, 2, (n, d))
    y = np.sin(2 * X[:, 0]) + X[:, 1] ** 2 + 0.5 * X[:, 2] * X[:, 3]
    y = y + 0.05 * rng.normal(size=n)
    return X, y


# ---------------------------------------------------------------- paper configs


def test_gbt_paper_config_engines_identical():
    """Paper §3.3.2 GBT (depth 6, lr 0.1, subsample 0.8): byte-identical fit."""
    X, y = _data()
    cfg = GBTConfig(n_estimators=12, seed=3)  # paper hyperparams, fewer rounds
    m_ref = GBTRegressor(cfg, engine="reference").fit(X, y)
    for engine in ("level", "batched"):
        m_e = GBTRegressor(cfg, engine=engine).fit(X, y)
        _assert_ensembles_identical(m_e.ensemble, m_ref.ensemble)
        np.testing.assert_array_equal(
            m_e.feature_importances_, m_ref.feature_importances_
        )
        np.testing.assert_array_equal(m_e.predict(X), m_ref.predict(X))


def test_rf_paper_config_engines_identical():
    """Paper §3.3.2 RF (depth 10, min_samples_split 5): byte-identical fit."""
    X, y = _data()
    cfg = RFConfig(n_estimators=8, seed=5)  # paper tree params, fewer trees
    m_ref = RandomForestRegressor(cfg, engine="reference").fit(X, y)
    for engine in ("level", "batched"):
        m_e = RandomForestRegressor(cfg, engine=engine).fit(X, y)
        _assert_ensembles_identical(m_e.ensemble, m_ref.ensemble)
        np.testing.assert_array_equal(m_e.predict(X), m_ref.predict(X))


def test_gbt_classifier_engines_identical():
    rng = np.random.default_rng(7)
    X = rng.normal(size=(220, 5))
    y = (X[:, 0] + X[:, 1] ** 2 > 0.4).astype(np.float64)
    cfg = GBTConfig(n_estimators=10, max_depth=3, seed=0)
    m_ref = GBTBinaryClassifier(cfg, engine="reference").fit(X, y)
    for engine in ("level", "batched"):
        m_e = GBTBinaryClassifier(cfg, engine=engine).fit(X, y)
        _assert_ensembles_identical(m_e.ensemble, m_ref.ensemble)
        np.testing.assert_array_equal(m_e.predict_proba(X), m_ref.predict_proba(X))


def test_default_engine_is_batched_and_flag_gated(monkeypatch):
    from repro.core import tree as tree_mod

    assert tree_mod.DEFAULT_ENGINE in tree_mod._ENGINES
    assert set(tree_mod._ENGINES) == {"batched", "level", "reference"}
    with pytest.raises(ValueError, match="unknown tree engine"):
        build_tree(np.zeros((4, 2), np.uint16), [np.array([0.5])] * 2,
                   np.zeros(4), np.ones(4), TreeBuilderConfig(), engine="nope")
    # resolve_engine precedence: explicit beats env beats built-in default,
    # and the env var is re-read at call time (not import time).
    monkeypatch.delenv("REPRO_TREE_ENGINE", raising=False)
    assert resolve_engine() == "batched"
    monkeypatch.setenv("REPRO_TREE_ENGINE", "reference")
    assert resolve_engine() == "reference"
    assert resolve_engine("level") == "level"


# ---------------------------------------------------------------- single trees


def _tree_case(n, d, depth, bins, seed, zero_frac=0.0, int_hess=False, round_X=False):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, d))
    if round_X:
        X = np.round(X)  # heavy bin ties -> exercises tie-breaking
    y = rng.normal(size=n)
    g = -(y - y.mean())
    h = np.ones(n)
    if int_hess:  # RF-style bootstrap weights (including zeros)
        h = rng.integers(0, 3, n).astype(np.float64)
        g = g * h
    elif zero_frac > 0.0:  # GBT subsample-style zeroed rows
        mask = rng.random(n) < (1.0 - zero_frac)
        g, h = np.where(mask, g, 0.0), np.where(mask, h, 0.0)
    edges = compute_bins(X, bins)
    Xb = bin_features(X, edges)
    cfg = TreeBuilderConfig(max_depth=depth, max_bins=bins)
    return Xb, edges, g, h, cfg


def _assert_engines_match(Xb, edges, g, h, cfg):
    t_ref, leaf_ref = build_tree_with_leaves(Xb, edges, g, h, cfg, engine="reference")
    for engine in ("level", "batched"):
        t_e, leaf_e = build_tree_with_leaves(Xb, edges, g, h, cfg, engine=engine)
        _assert_trees_identical(t_ref, t_e)
        np.testing.assert_array_equal(leaf_ref, leaf_e, err_msg=f"engine {engine!r}")
        # every routed leaf really is a leaf
        assert (t_e.feature[leaf_e] == -1).all()
    return t_ref


def test_leaf_assignment_matches_reference_and_is_terminal():
    Xb, edges, g, h, cfg = _tree_case(300, 6, 6, 32, seed=1, zero_frac=0.25)
    _assert_engines_match(Xb, edges, g, h, cfg)


def test_binned_data_reuse_matches_plain_arrays():
    """Passing a prebuilt BinnedData (the ensemble fast path) changes nothing."""
    Xb, edges, g, h, cfg = _tree_case(200, 5, 5, 24, seed=2)
    data = BinnedData.build(Xb, edges)
    t_plain, leaf_plain = build_tree_with_leaves(Xb, edges, g, h, cfg)
    for _ in range(2):  # scratch buffers are reused across calls
        t_data, leaf_data = build_tree_with_leaves(data, None, g, h, cfg)
        _assert_trees_identical(t_plain, t_data)
        np.testing.assert_array_equal(leaf_plain, leaf_data)


def test_constant_feature_and_tiny_n():
    for n in (1, 2, 5):
        rng = np.random.default_rng(n)
        X = np.column_stack([np.ones(n), rng.normal(size=n)])
        y = rng.normal(size=n)
        edges = compute_bins(X, 8)
        Xb = bin_features(X, edges)
        cfg = TreeBuilderConfig(max_depth=3, max_bins=8)
        _assert_engines_match(Xb, edges, -(y - y.mean()), np.ones(n), cfg)


# ---------------------------------------------------------------- property sweep


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(5, 300),
    d=st.integers(1, 7),
    depth=st.integers(1, 10),
    bins=st.integers(2, 72),
    seed=st.integers(0, 10_000),
    flavor=st.sampled_from(["plain", "rounded", "zeros", "int_hess"]),
)
def test_engine_equivalence_property(n, d, depth, bins, seed, flavor):
    """Bit-identical trees across depths/bins/row-weight patterns.

    Covers both histogram layouts of the level engine (dense frontier and
    candidate-compacted) since depth ranges beyond the dense cutoff."""
    Xb, edges, g, h, cfg = _tree_case(
        n, d, depth, bins, seed,
        zero_frac=0.3 if flavor == "zeros" else 0.0,
        int_hess=flavor == "int_hess",
        round_X=flavor == "rounded",
    )
    _assert_engines_match(Xb, edges, g, h, cfg)


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    min_child_weight=st.sampled_from([1e-3, 0.5, 1.0, 5.0]),
    reg_lambda=st.sampled_from([0.25, 1.0, 3.0]),
    gamma=st.sampled_from([0.0, 0.05, 0.5]),
    min_samples_split=st.integers(2, 12),
)
def test_engine_equivalence_regularizers_property(
    seed, min_child_weight, reg_lambda, gamma, min_samples_split
):
    rng = np.random.default_rng(seed)
    n = 180
    X = rng.normal(size=(n, 5))
    y = rng.normal(size=n)
    edges = compute_bins(X, 32)
    Xb = bin_features(X, edges)
    cfg = TreeBuilderConfig(
        max_depth=6,
        min_samples_split=min_samples_split,
        min_child_weight=min_child_weight,
        reg_lambda=reg_lambda,
        gamma=gamma,
        max_bins=32,
    )
    _assert_engines_match(Xb, edges, -(y - y.mean()), np.ones(n), cfg)


# ---------------------------------------------------------------- batched engine


def _rf_data(n=500, d=8, seed=11):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, d))
    y = X[:, 0] * 2 - X[:, 1] ** 2 + 0.1 * rng.normal(size=n)
    return X, y


def test_build_forest_batched_matches_reference_per_tree():
    """The ensemble API grows every tree bit-identically to per-tree
    reference builds on the same (grad, hess) rows (RF bootstrap weights)."""
    X, y = _rf_data()
    n = X.shape[0]
    rng = np.random.default_rng(3)
    edges = compute_bins(X, 32)
    data = BinnedData.build(bin_features(X, edges), edges)
    cfg = TreeBuilderConfig(max_depth=8, min_samples_split=5,
                            min_child_weight=1.0, reg_lambda=0.0, max_bins=32)
    W = np.stack([
        np.bincount(rng.integers(0, n, n), minlength=n).astype(np.float64)
        for _ in range(6)
    ])
    grads = -(y - y.mean())[None, :] * W
    for t, (tree, leaf) in enumerate(build_forest_batched(data, grads, W, cfg)):
        t_ref, leaf_ref = build_tree_with_leaves(
            data, None, grads[t], W[t], cfg, engine="reference"
        )
        _assert_trees_identical(t_ref, tree)
        np.testing.assert_array_equal(leaf_ref, leaf, err_msg=f"tree {t}")


def test_rf_all_engines_identical_bootstrap():
    """RF fit (bootstrap weights, colsample=1.0) is bit-identical across all
    three engines — the batched path pre-draws the same bootstrap stream."""
    X, y = _rf_data(400, 6)
    cfg = RFConfig(n_estimators=7, max_depth=7, seed=9)
    m_ref = RandomForestRegressor(cfg, engine="reference").fit(X, y)
    for engine in ("level", "batched"):
        m_e = RandomForestRegressor(cfg, engine=engine).fit(X, y)
        _assert_ensembles_identical(m_e.ensemble, m_ref.ensemble)
        np.testing.assert_array_equal(
            m_e.feature_importances_, m_ref.feature_importances_
        )


def test_rf_colsample_engines_equivalent():
    """With colsample < 1.0 all three engines are bit-identical: per-node
    feature subsets are keyed on (per-tree base key, heap path), so the DFS,
    frontier, and lockstep traversal orders draw the same subsets, and the
    batched RF path replays the per-tree (bootstrap, base-key) stream in one
    lockstep build — the PR 5 caveat is closed."""
    X, y = _rf_data(600, 8, seed=21)
    cfg = RFConfig(n_estimators=30, max_depth=7, colsample=0.5, seed=2)
    m_ref = RandomForestRegressor(cfg, engine="reference").fit(X, y)
    for engine in ("level", "batched"):
        m_e = RandomForestRegressor(cfg, engine=engine).fit(X, y)
        _assert_ensembles_identical(m_e.ensemble, m_ref.ensemble)
        np.testing.assert_array_equal(
            m_e.feature_importances_, m_ref.feature_importances_
        )


def test_batched_single_tree_colsample_replays_level_engine():
    """B=1 batched builds consume the column-sampling RNG in the level
    engine's frontier order, so single-tree colsample fits replay exactly."""
    rng = np.random.default_rng(5)
    n, d = 300, 8
    X = rng.normal(size=(n, d))
    y = rng.normal(size=n)
    edges = compute_bins(X, 24)
    Xb = bin_features(X, edges)
    cfg = TreeBuilderConfig(max_depth=6, max_bins=24)
    g = -(y - y.mean())
    h = np.ones(n)
    t_lvl, leaf_lvl = build_tree_with_leaves(
        Xb, edges, g, h, cfg, rng=np.random.default_rng(77), colsample=0.5,
        engine="level",
    )
    t_bat, leaf_bat = build_tree_with_leaves(
        Xb, edges, g, h, cfg, rng=np.random.default_rng(77), colsample=0.5,
        engine="batched",
    )
    _assert_trees_identical(t_lvl, t_bat)
    np.testing.assert_array_equal(leaf_lvl, leaf_bat)


def test_batched_numpy_fallback_matches_native(monkeypatch):
    """With the native kernels disabled the pure-numpy layouts must produce
    the same trees (the equivalence that keeps no-compiler platforms safe)."""
    X, y = _rf_data(350, 7, seed=31)
    cfg = RFConfig(n_estimators=4, max_depth=9, seed=1)
    m_native = RandomForestRegressor(cfg, engine="batched").fit(X, y)
    monkeypatch.setattr(_native, "_tried", True)
    monkeypatch.setattr(_native, "_lib", None)
    assert not _native.available()
    m_numpy = RandomForestRegressor(cfg, engine="batched").fit(X, y)
    _assert_ensembles_identical(m_native.ensemble, m_numpy.ensemble)


# ------------------------------------------------------------- threaded kernels


def _fit_with_threads(monkeypatch, ctor, X, y, nt):
    monkeypatch.setenv("REPRO_NATIVE_THREADS", str(nt))
    return ctor().fit(X, y)


def test_rf_paper_threads_byte_identical(monkeypatch):
    """Determinism hammer: the paper RF config fit at REPRO_NATIVE_THREADS
    in {1, 2, 4} is byte-identical (ownership partitioning: every node is
    processed end-to-end by one thread, so no reduction order changes)."""
    X, y = _data(400, 11, seed=13)
    cfg = RFConfig(n_estimators=12, seed=4)  # paper depth/min_samples_split
    ctor = lambda: RandomForestRegressor(cfg, engine="batched")
    base = _fit_with_threads(monkeypatch, ctor, X, y, 1)
    for nt in (2, 4):
        m = _fit_with_threads(monkeypatch, ctor, X, y, nt)
        _assert_ensembles_identical(base.ensemble, m.ensemble)
        np.testing.assert_array_equal(
            base.feature_importances_, m.feature_importances_
        )


def test_gbt_paper_threads_byte_identical(monkeypatch):
    """Paper GBT config (subsample 0.8) at threads in {1, 2, 4}: identical."""
    X, y = _data(400, 11, seed=23)
    cfg = GBTConfig(n_estimators=10, seed=6)
    ctor = lambda: GBTRegressor(cfg, engine="batched")
    base = _fit_with_threads(monkeypatch, ctor, X, y, 1)
    for nt in (2, 4):
        m = _fit_with_threads(monkeypatch, ctor, X, y, nt)
        _assert_ensembles_identical(base.ensemble, m.ensemble)


def test_rf_colsample_threads_byte_identical(monkeypatch):
    """colsample<1 + threads: the keyed column draws are thread-count
    independent, so the hardest combination is still byte-identical."""
    X, y = _rf_data(300, 8, seed=41)
    cfg = RFConfig(n_estimators=6, max_depth=7, colsample=0.5, seed=3)
    ctor = lambda: RandomForestRegressor(cfg, engine="batched")
    base = _fit_with_threads(monkeypatch, ctor, X, y, 1)
    m = _fit_with_threads(monkeypatch, ctor, X, y, 4)
    _assert_ensembles_identical(base.ensemble, m.ensemble)


def test_native_threads_env_read_at_fit_time(monkeypatch):
    """REPRO_NATIVE_THREADS is re-read on every call (fit time), never
    cached at import time, and clamps to MAX_THREADS."""
    monkeypatch.delenv("REPRO_NATIVE_THREADS", raising=False)
    assert _native.native_threads() == 1
    monkeypatch.setenv("REPRO_NATIVE_THREADS", "3")
    assert _native.native_threads() == 3
    monkeypatch.setenv("REPRO_NATIVE_THREADS", " 8 ")
    assert _native.native_threads() == 8
    monkeypatch.setenv("REPRO_NATIVE_THREADS", str(10 * _native.MAX_THREADS))
    assert _native.native_threads() == _native.MAX_THREADS


@pytest.mark.parametrize("bad", ["0", "-2", "two", "1.5", ""])
def test_native_threads_invalid_falls_back_with_single_warning(
    monkeypatch, bad
):
    """Invalid REPRO_NATIVE_THREADS values (0, negatives, non-ints) fall
    back to 1 thread with exactly one RuntimeWarning per distinct value —
    mirroring the REPRO_TREE_ENGINE regression contract."""
    monkeypatch.setattr(_native, "_warned_threads", set())
    monkeypatch.setenv("REPRO_NATIVE_THREADS", bad)
    with pytest.warns(RuntimeWarning, match="REPRO_NATIVE_THREADS"):
        assert _native.native_threads() == 1
    import warnings as _w

    with _w.catch_warnings():
        _w.simplefilter("error")  # a second warning would raise
        assert _native.native_threads() == 1


@pytest.mark.skipif(not _native.available(), reason="native kernels unavailable")
def test_native_kernels_threaded_match_single_thread():
    """Direct kernel check: segment_sums / split_finder / partition produce
    byte-identical outputs at any thread count (not just via full fits)."""
    rng = np.random.default_rng(29)
    n, segs = 5000, 37
    vals = rng.normal(size=n)
    bounds = np.sort(rng.choice(np.arange(1, n), segs - 1, replace=False))
    starts = np.concatenate([[0], bounds]).astype(np.int64)
    counts = np.diff(np.concatenate([starts, [n]])).astype(np.int64)
    rows = np.arange(n, dtype=np.int64)
    outs = []
    for nt in (1, 2, 5):
        out = np.empty(segs)
        _native.segment_sums(vals, rows, starts, counts, out, nthreads=nt)
        outs.append(out)
    np.testing.assert_array_equal(outs[0], outs[1])
    np.testing.assert_array_equal(outs[0], outs[2])


# ------------------------------------------------------------ mega-grid recommend


def _fitted_predictor(model: str):
    from repro.core import FEATURE_NAMES, IOPerformancePredictor

    rng = np.random.default_rng(0)
    n = 240
    cols = {name: rng.uniform(1, 100, n) for name in FEATURE_NAMES}
    cols["target_throughput"] = (
        rng.uniform(10, 500, n) + 2.0 * cols[FEATURE_NAMES[0]]
    )
    return IOPerformancePredictor(model=model).fit(cols)


def _topk_key(recs):
    return [tuple(sorted((k, v) for k, v in r.items()
                         if k != "predicted_throughput_mb_s")) for r in recs]


@pytest.mark.parametrize("model", ["xgboost", "random_forest"])
def test_recommend_chunked_matches_oracle_paper_grid(model):
    """The chunked packed-ensemble scorer picks the identical top-k (and
    reports identical values) to the numpy oracle on the paper's 1,800-config
    grid, for both ensemble models."""
    from repro.core import ConfigSpace, recommend

    pred = _fitted_predictor(model)
    ctx = {"throughput_mb_s": 800.0, "file_size_mb": 64.0, "iops": 5e4}
    space = ConfigSpace()
    r_o = recommend(pred, ctx, space, top_k=5, scorer="oracle")
    r_c = recommend(pred, ctx, space, top_k=5, scorer="chunked")
    assert _topk_key(r_o) == _topk_key(r_c)
    for a, b in zip(r_o, r_c):
        assert a["predicted_throughput_mb_s"] == pytest.approx(
            b["predicted_throughput_mb_s"], rel=0, abs=0
        )


def test_recommend_pallas_kernel_matches_oracle_paper_grid():
    """The Pallas one-hot-matmul kernel (interpret mode off-TPU) and the
    numpy oracle pick the identical top-k on the paper 1,800-config grid."""
    from repro.core import ConfigSpace, recommend

    pred = _fitted_predictor("xgboost")
    ctx = {"throughput_mb_s": 800.0, "file_size_mb": 64.0, "iops": 5e4}
    space = ConfigSpace()
    r_o = recommend(pred, ctx, space, top_k=5, scorer="oracle")
    r_p = recommend(pred, ctx, space, top_k=5, scorer="pallas")
    assert _topk_key(r_o) == _topk_key(r_p)
    for a, b in zip(r_o, r_p):
        assert a["predicted_throughput_mb_s"] == b["predicted_throughput_mb_s"]


def test_recommend_auto_routes_and_falls_back():
    """scorer="auto" keeps small grids and non-ensemble models on the oracle
    path, routes mega grids through the chunked scorer, and forcing the
    packed scorers on a linear model falls back instead of crashing."""
    from repro.core import ConfigSpace, recommend
    from repro.core.autotune import MEGA_GRID_MIN, score_grid

    ctx = {"throughput_mb_s": 800.0, "file_size_mb": 64.0}
    small = ConfigSpace()
    assert small.n_candidates < MEGA_GRID_MIN
    mega = ConfigSpace(prefetch_policy=(0, 1), lookahead_batches=(4, 8),
                       cache_budget_mb=(32.0, 64.0))  # 1800 * 8 = 14400
    assert mega.n_candidates >= MEGA_GRID_MIN
    pred = _fitted_predictor("xgboost")
    assert score_grid(pred, ctx, small)[1] == "oracle"
    assert score_grid(pred, ctx, mega)[1] in ("chunked", "pallas")
    r_a = recommend(pred, ctx, mega, top_k=4)
    r_o = recommend(pred, ctx, mega, top_k=4, scorer="oracle")
    assert _topk_key(r_a) == _topk_key(r_o)
    lin = _fitted_predictor("linear")
    assert score_grid(lin, ctx, mega)[1] == "oracle"
    assert len(recommend(lin, ctx, small, top_k=3, scorer="pallas")) == 3
    with pytest.raises(ValueError, match="unknown scorer"):
        recommend(pred, ctx, small, scorer="warp")


def test_segment_sums_fast_matches_loop():
    from repro.core.tree import _segment_sums_fast, _segment_sums_loop

    rng = np.random.default_rng(17)
    lens = np.asarray(
        list(range(0, 132)) + [200, 1000, 8192, 8193, 20000], np.int64
    )
    vals = rng.normal(size=int(lens.sum()))
    vals *= 10.0 ** rng.integers(-8, 8, size=vals.size)
    starts = np.concatenate([[0], np.cumsum(lens)[:-1]])
    a = np.empty(lens.size)
    b = np.empty(lens.size)
    _segment_sums_loop(vals, starts, lens, a)
    _segment_sums_fast(vals, starts, lens, b)
    # The vectorized emulation either matches this numpy build bit-for-bit
    # (and then the engine may use it) or the runtime probe must say no.
    from repro.core.tree import _pairwise_emulation_ok

    assert np.array_equal(a, b) == _pairwise_emulation_ok() or np.array_equal(a, b)
