"""ETL micro-suite correctness (paper §3.1.3 adaptation)."""

import jax.numpy as jnp
import numpy as np

from repro.data.etl import etl_filter, etl_group_aggregate, etl_join, make_etl_table


def test_filter():
    t = make_etl_table(1000, seed=1)
    vals, count = etl_filter(jnp.asarray(t["values"]), jnp.float32(0.0))
    ref = t["values"] > 0
    assert int(count) == int(ref.sum())
    np.testing.assert_allclose(np.asarray(vals), np.where(ref, t["values"], 0.0))


def test_group_aggregate():
    t = make_etl_table(5000, n_groups=16, seed=2)
    sums, counts = etl_group_aggregate(jnp.asarray(t["keys"]), jnp.asarray(t["values"]), 16)
    ref_sums = np.bincount(t["keys"], weights=t["values"], minlength=16)
    np.testing.assert_allclose(np.asarray(sums), ref_sums, rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(counts), np.bincount(t["keys"], minlength=16))


def test_join():
    t = make_etl_table(256, n_groups=8, seed=3)
    rk = jnp.arange(8, dtype=jnp.int32)
    rv = jnp.linspace(0, 1, 8, dtype=jnp.float32)
    joined, matched = etl_join(jnp.asarray(t["keys"]), jnp.asarray(t["values"]), rk, rv)
    assert int(matched) == 256  # all keys exist in right table
    ref = t["values"] + np.linspace(0, 1, 8, dtype=np.float32)[t["keys"]]
    np.testing.assert_allclose(np.asarray(joined), ref, rtol=1e-5)
