"""Fleet scaling benchmark: rows-per-wallclock at 1/2/4 collectors.

Runs one collection cycle of the ``fleet_probe`` campaign (random-access
I/O on the calibrated network/object-store simulators — wall time is I/O
wait, the fleet's real-world regime) under ``FleetCoordinator`` with 1, 2,
and 4 collector subprocesses, and reports rows collected per second of
cycle wall time.  Refitting is disabled (``min_observations`` out of reach)
so the number isolates the collect + lease-supervision + merge path; worker
spawn/import overhead is deliberately *included* — it is part of what a
real fleet pays per cycle.

Run via ``PYTHONPATH=src python -m benchmarks.run --only fleet``.  The full
run writes ``BENCH_fleet.json`` at the repo root so collector scaling is
tracked across PRs; ``--fast`` keeps everything CI-sized (1/2 collectors,
one seed) and skips the artifact.
"""

from __future__ import annotations

import json
import pathlib
import shutil
import time
from typing import List, Tuple

from ._util import emit_artifact

Row = Tuple[str, float, str]

ARTIFACT = pathlib.Path(__file__).resolve().parent.parent / "BENCH_fleet.json"
SCRATCH = pathlib.Path("/tmp/repro_io/bench_fleet")


def bench_fleet(fast: bool, artifact_dir=None) -> List[Row]:
    from repro.data.campaign import load_records_ex
    from repro.service.fleet import FleetConfig, FleetCoordinator

    rows: List[Row] = []
    art = {"schema": 1, "campaign": "fleet_probe",
           "metric": "rows collected per second of cycle wall time", "runs": []}
    counts = (1, 2) if fast else (1, 2, 4)
    base_rps = None
    for n in counts:
        out = SCRATCH / f"c{n}"
        shutil.rmtree(out, ignore_errors=True)
        cfg = FleetConfig(
            campaign="fleet_probe", fast=fast, collectors=n, cycles=1,
            seeds_per_cycle=1 if fast else 3, base_seed=9000, out_dir=out,
            min_observations=10_000,  # never refit: measure collection
            poll_interval_s=0.05,
        )
        t0 = time.perf_counter()
        records = FleetCoordinator(cfg).run()
        wall = time.perf_counter() - t0
        r = records[0]
        n_rows = r["n_executed"]
        faults = r.get("faults") or {}
        _, n_corrupt, _ = load_records_ex(out / "merged.jsonl")
        rps = n_rows / wall
        if base_rps is None:
            base_rps = rps
        speedup = rps / base_rps
        rows.append((
            f"fleet_collect_c{n}", wall * 1e6,
            f"rows={n_rows} rows_per_s={rps:.2f} speedup={speedup:.2f}x "
            f"failures={r['n_failures']} releases={r['releases']}",
        ))
        art["runs"].append({
            "collectors": n, "rows": n_rows, "wall_s": round(wall, 3),
            "rows_per_s": round(rps, 3), "speedup_vs_1": round(speedup, 3),
            "n_failures": r["n_failures"], "releases": r["releases"],
            # integrity counters: tools/bench_gate.py hard-fails if any
            # benchmark run ever reports corrupt or quarantined data
            "quarantined": int(faults.get("quarantined", 0)),
            "corrupt_lines": int(n_corrupt),
        })

    row = emit_artifact(art, "BENCH_fleet.json", fast, artifact_dir, ARTIFACT,
                        "fleet_artifact")
    if row:
        rows.append(row)
    return rows
