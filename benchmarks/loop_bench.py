"""Continuous-loop benchmarks: per-cycle collect/merge/refit/re-recommend
latency as the observation dataset grows.

Two tracks:

- **campaign** — the real loop over the fast ``paper_core`` campaign (real
  storage I/O, 26 rows/cycle): end-to-end cycle wall time plus the refit and
  recommend slices the paper's "minutes" claim rests on.
- **synthetic** — a fake executor (no storage I/O) grows the dataset to the
  paper's 500-1000-observation future-work band, isolating how refit and
  recommend latency scale with ``n_observations``.

Run via ``PYTHONPATH=src python -m benchmarks.run --only loop``.  The full
run writes ``BENCH_loop.json`` at the repo root so the loop's latency
trajectory is tracked across PRs; ``--fast`` keeps everything CI-sized and
skips the artifact.
"""

from __future__ import annotations

import pathlib
import shutil
import zlib
from typing import List, Tuple

from ._util import emit_artifact, time_once

Row = Tuple[str, float, str]

ARTIFACT = pathlib.Path(__file__).resolve().parent.parent / "BENCH_loop.json"
SCRATCH = pathlib.Path("/tmp/repro_io/bench_loop")


def _synthetic_campaign():
    """96 pipeline-shaped cases over the autotuner's knob axes (no real I/O:
    the executor below fabricates the measurement)."""
    from repro.data.registry import Campaign, matrix_cases

    return Campaign(
        "loop_synth", "synthetic knob sweep for loop scaling",
        lambda fast=False: tuple(matrix_cases(
            "pipeline", id_prefix="ls", backend=["tmpfs"], format=["raw"],
            batch_size=[16, 32, 64, 128], num_workers=[0, 1, 2, 4],
            prefetch_depth=[1, 2, 4], block_kb=[16, 64],
        )),
    )


def _synthetic_executor(case, ctx, seed: int) -> dict:
    """Deterministic performance model: workers and prefetch help with
    diminishing returns, large batches amortize overhead, plus seed jitter."""
    from repro.core.features import TARGET_NAME

    w, pf, b = case.num_workers, case.prefetch_depth, case.batch_size
    thr = 80.0 * (1 + 0.9 * w ** 0.7) * (1 + 0.15 * (pf - 1)) * (b / 64.0) ** 0.2
    # crc32, not hash(): stable across processes (PYTHONHASHSEED)
    jitter = (seed * 2654435761 + zlib.crc32(case.id.encode())) % 97 - 48
    thr *= 1 + 0.02 * jitter / 48.0
    return {
        TARGET_NAME: thr, "batch_size": b, "num_workers": w,
        "block_kb": case.block_kb, "file_size_mb": 64.0,
        "bench_type": "pipeline", "backend": "tmpfs",
    }


def _run_loop(cfg, executor=None) -> List[dict]:
    from repro.service.loop import ContinuousTuningLoop

    return ContinuousTuningLoop(cfg, executor=executor).run()


def bench_loop(fast: bool, artifact_dir=None) -> List[Row]:
    from repro.core.autotune import ConfigSpace
    from repro.service.loop import LoopConfig

    rows: List[Row] = []
    art = {"schema": 1, "campaign_cycles": [], "synthetic_cycles": []}

    # -- real fast-campaign loop ---------------------------------------
    out = SCRATCH / "campaign"
    shutil.rmtree(out, ignore_errors=True)
    cfg = LoopConfig(
        campaign="paper_core", fast=True, cycles=2 if fast else 4,
        out_dir=out, base_seed=5000, min_observations=24, refit_every=20,
    )
    for r in _run_loop(cfg):
        derived = (
            f"n_obs={r['n_observations']} refit_ms={r['refit_s'] * 1e3:.1f} "
            f"recommend_ms={r['recommend_s'] * 1e3:.2f} "
            f"drift={r['drift']} gain={r['decision']['predicted_gain']:.2f}"
        )
        rows.append((f"loop_campaign_cycle{r['cycle']}", r["elapsed_s"] * 1e6, derived))
        art["campaign_cycles"].append({
            "cycle": r["cycle"], "n_observations": r["n_observations"],
            "refit_ms": round(r["refit_s"] * 1e3, 2),
            "recommend_ms": round(r["recommend_s"] * 1e3, 3),
            "cycle_s": r["elapsed_s"], "drift": r["drift"],
            "reconfigure": r["decision"]["reconfigure"],
        })

    # -- synthetic growth to the 500-1000-observation band -------------
    out = SCRATCH / "synthetic"
    shutil.rmtree(out, ignore_errors=True)
    space = ConfigSpace(batch_size=(16, 32, 64, 128), num_workers=(0, 1, 2, 4),
                        block_kb=(16, 64), n_threads=(1,), prefetch_depth=(1, 2, 4))
    cfg = LoopConfig(
        campaign=_synthetic_campaign(), cycles=2 if fast else 5,
        seeds_per_cycle=1 if fast else 2, out_dir=out, space=space,
        base_seed=7000, min_observations=24, refit_every=20,
    )
    for r in _run_loop(cfg, executor=_synthetic_executor):
        derived = (
            f"n_obs={r['n_observations']} refit_ms={r['refit_s'] * 1e3:.1f} "
            f"recommend_ms={r['recommend_s'] * 1e3:.2f} drift={r['drift']}"
        )
        rows.append((f"loop_synth_cycle{r['cycle']}", r["elapsed_s"] * 1e6, derived))
        art["synthetic_cycles"].append({
            "cycle": r["cycle"], "n_observations": r["n_observations"],
            "refit_ms": round(r["refit_s"] * 1e3, 2),
            "recommend_ms": round(r["recommend_s"] * 1e3, 3),
            "cycle_s": r["elapsed_s"], "drift": r["drift"],
        })

    # -- refit-stage engine A/B on the final synthetic store -------------
    # Same-run comparison (immune to machine drift across PRs): refit the
    # loop's model on the grown dataset once per tree engine.
    from repro.core import IOPerformancePredictor
    from repro.data.dataset import observations_from_jsonl, observations_to_columns

    obs_rows = observations_from_jsonl([out / "merged.jsonl"])
    obs = observations_to_columns(obs_rows)
    n_obs = len(obs_rows)
    if n_obs:
        refit_t = {}
        for engine in ("batched", "level"):
            pred = IOPerformancePredictor(model="xgboost", engine=engine)
            pred.fit(obs)  # warm
            refit_t[engine] = min(
                time_once(lambda: pred.fit(obs)) for _ in range(3)
            )
        sp = refit_t["level"] / refit_t["batched"]
        rows.append((
            "loop_refit_engine_ab", refit_t["batched"] * 1e6,
            f"n_obs={n_obs} batched_ms={refit_t['batched'] * 1e3:.1f} "
            f"level_ms={refit_t['level'] * 1e3:.1f} speedup={sp:.2f}x",
        ))
        art["refit_engine_ab"] = {
            "n_observations": n_obs,
            "batched_ms": round(refit_t["batched"] * 1e3, 2),
            "level_ms": round(refit_t["level"] * 1e3, 2),
            "speedup_batched": round(sp, 2),
        }

    row = emit_artifact(art, "BENCH_loop.json", fast, artifact_dir, ARTIFACT,
                        "loop_artifact")
    if row:
        rows.append(row)
    return rows
