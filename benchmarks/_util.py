"""Small helpers shared by the benchmark groups."""

from __future__ import annotations

import json
import pathlib
import time
from typing import Optional, Tuple


def time_once(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def emit_artifact(art: dict, name: str, fast: bool, artifact_dir,
                  full_path: pathlib.Path, label: str) -> Optional[Tuple[str, float, str]]:
    """Write the group's JSON artifact: to the repo root in full mode, to
    ``artifact_dir`` (the bench-gate's fresh-run input) in fast mode.
    Returns the CSV row to append, or None if nothing was written."""
    if not fast:
        full_path.write_text(json.dumps(art, indent=2) + "\n")
        return (label, 0.0, f"wrote {full_path.name}")
    if artifact_dir is not None:
        out = pathlib.Path(artifact_dir) / name
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(art, indent=2) + "\n")
        return (label, 0.0, f"wrote {out}")
    return None
