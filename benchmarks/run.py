"""Benchmark harness — one function per paper table/figure plus the dry-run
roofline table. Prints ``name,us_per_call,derived`` CSV.

Usage: PYTHONPATH=src python -m benchmarks.run [--fast] [--only GROUP]
       [--artifact-dir DIR]

``--artifact-dir`` makes the artifact-writing groups (fit/loop/fleet/serve/
pipeline/transfer) emit
their CI-sized JSON artifacts there even in ``--fast`` mode — the input of
the bench regression gate (``tools/bench_gate.py``).  Any group that raises
marks the whole run failed (non-zero exit), so CI cannot green-light a run
that silently skipped a benchmark; an unknown ``--only`` group is an error,
not an empty no-op run.
"""

from __future__ import annotations

import argparse
import inspect
import pathlib
import sys
import traceback


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="small observation set, skip CV/MLP (CI mode)")
    ap.add_argument("--only", default=None, help="run a single benchmark group")
    ap.add_argument("--artifact-dir", default=None,
                    help="write fast-mode BENCH_*.json artifacts to this dir")
    args = ap.parse_args(argv)

    from . import fit_bench
    from . import fleet_bench
    from . import loop_bench
    from . import paper_experiments as pe
    from . import pipeline_bench
    from . import roofline
    from . import serve_bench
    from . import transfer_bench

    groups = {
        "fit": fit_bench.bench_fit,
        "fleet": fleet_bench.bench_fleet,
        "loop": loop_bench.bench_loop,
        "pipeline": pipeline_bench.bench_pipeline,
        "serve": serve_bench.bench_serve,
        "transfer": transfer_bench.bench_transfer,
        "dataset": pe.bench_dataset,
        "campaign": pe.bench_campaign,
        "pca": pe.bench_pca,
        "model_comparison": pe.bench_model_comparison,
        "feature_importance": pe.bench_feature_importance,
        "util_impact": pe.bench_util_impact,
        "etl": pe.bench_etl,
        "recommendation": pe.bench_recommendation,
        "extensions": pe.bench_extensions,
        "kernels": pe.bench_kernels,
    }
    if args.only:
        if args.only not in groups and args.only != "roofline":
            ap.error(
                f"unknown benchmark group {args.only!r}; "
                f"choose from {sorted(groups) + ['roofline']}"
            )
        groups = {args.only: groups[args.only]} if args.only in groups else {}

    print("name,us_per_call,derived")
    failures = 0
    for gname, fn in groups.items():
        kwargs = {}
        if (
            args.artifact_dir
            and "artifact_dir" in inspect.signature(fn).parameters
        ):
            kwargs["artifact_dir"] = pathlib.Path(args.artifact_dir)
        try:
            for name, us, derived in fn(args.fast, **kwargs):
                print(f"{name},{us:.1f},{derived}")
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{gname},0,ERROR {type(e).__name__}: {e}", file=sys.stdout)
            traceback.print_exc(file=sys.stderr)

    # roofline rows from the dry-run artifacts (if present)
    if args.only in (None, "roofline"):
        try:
            recs = roofline.load_records()
            for name, us, derived in roofline.csv_rows(recs):
                print(f"{name},{us:.1f},{derived}")
            s = roofline.summarize(recs)
            print(f"roofline_summary,0,{s}")
        except Exception as e:  # noqa: BLE001
            print(f"roofline,0,ERROR {e}")

    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
