"""Benchmark harness — one function per paper table/figure plus the dry-run
roofline table. Prints ``name,us_per_call,derived`` CSV.

Usage: PYTHONPATH=src python -m benchmarks.run [--fast]
"""

from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="small observation set, skip CV/MLP (CI mode)")
    ap.add_argument("--only", default=None, help="run a single benchmark group")
    args = ap.parse_args()

    from . import fit_bench
    from . import fleet_bench
    from . import loop_bench
    from . import paper_experiments as pe
    from . import roofline

    groups = {
        "fit": fit_bench.bench_fit,
        "fleet": fleet_bench.bench_fleet,
        "loop": loop_bench.bench_loop,
        "dataset": pe.bench_dataset,
        "campaign": pe.bench_campaign,
        "pca": pe.bench_pca,
        "model_comparison": pe.bench_model_comparison,
        "feature_importance": pe.bench_feature_importance,
        "util_impact": pe.bench_util_impact,
        "etl": pe.bench_etl,
        "recommendation": pe.bench_recommendation,
        "extensions": pe.bench_extensions,
        "kernels": pe.bench_kernels,
    }
    if args.only:
        groups = {args.only: groups[args.only]} if args.only in groups else {}

    print("name,us_per_call,derived")
    failures = 0
    for gname, fn in groups.items():
        try:
            for name, us, derived in fn(args.fast):
                print(f"{name},{us:.1f},{derived}")
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{gname},0,ERROR {type(e).__name__}: {e}", file=sys.stdout)
            traceback.print_exc(file=sys.stderr)

    # roofline rows from the dry-run artifacts (if present)
    if args.only in (None, "roofline"):
        try:
            recs = roofline.load_records()
            for name, us, derived in roofline.csv_rows(recs):
                print(f"{name},{us:.1f},{derived}")
            s = roofline.summarize(recs)
            print(f"roofline_summary,0,{s}")
        except Exception as e:  # noqa: BLE001
            print(f"roofline,0,ERROR {e}")

    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
