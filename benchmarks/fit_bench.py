"""Fit-path benchmarks: the batched ensemble engine vs the level-wise and
reference builders, and the zero-copy ``recommend()`` serving path.

Run via ``PYTHONPATH=src python -m benchmarks.run --only fit``.  The full run
writes a ``BENCH_fit.json`` artifact at the repo root so the fit-performance
trajectory is tracked across PRs; ``--fast`` keeps everything CI-sized and
writes the artifact only when ``--artifact-dir`` is given (the bench-gate's
fresh-run input).

Engines are timed alternately (each takes its best of ``reps`` runs) so
background load on a shared box biases no engine, and every row asserts the
engines produced byte-identical ensembles — a false ``identical_trees`` is a
correctness regression and hard-fails the CI gate (``tools/bench_gate.py``).
"""

from __future__ import annotations

import os
import pathlib
from typing import Dict, List, Optional, Tuple

import numpy as np

from ._util import emit_artifact, time_once as _time_once

Row = Tuple[str, float, str]

ARTIFACT = pathlib.Path(__file__).resolve().parent.parent / "BENCH_fit.json"

# Thread count for the threaded-fit rows (REPRO_NATIVE_THREADS); the gate
# enforces the >=1.5x speedup floor only on rows recorded with cores >= 2 —
# a single-core recording machine can still prove bit-exactness, and CI's
# multi-core runners provide the fresh speedup evidence.
BENCH_THREADS = 4


def _cores() -> int:
    try:
        return len(os.sched_getaffinity(0))
    except AttributeError:  # pragma: no cover - non-Linux
        return os.cpu_count() or 1


def _fit_times_threads(model_ctor, X, y, threads: int, reps: int = 2):
    """({"t1": s, "tN": s}, identical) for the batched engine at
    REPRO_NATIVE_THREADS=1 vs =threads (env re-read at fit time)."""
    times: Dict[str, List[float]] = {"t1": [], "tN": []}
    models: Dict[str, object] = {}
    prev = os.environ.get("REPRO_NATIVE_THREADS")
    try:
        for _ in range(reps):
            for key, nt in (("t1", 1), ("tN", threads)):
                os.environ["REPRO_NATIVE_THREADS"] = str(nt)
                m = model_ctor(engine="batched")
                times[key].append(_time_once(lambda: m.fit(X, y)))
                models[key] = m
    finally:
        if prev is None:
            os.environ.pop("REPRO_NATIVE_THREADS", None)
        else:
            os.environ["REPRO_NATIVE_THREADS"] = prev
    ref = models["t1"].ensemble
    identical = all(
        np.array_equal(np.asarray(getattr(ref, f)),
                       np.asarray(getattr(models["tN"].ensemble, f)))
        for f in ("feature", "threshold", "left", "right", "value")
    )
    return {k: min(ts) for k, ts in times.items()}, identical


def _synth(n: int, d: int = 11, seed: int = 0):
    """Regression data shaped like the paper's 11-feature observations."""
    rng = np.random.default_rng(seed)
    X = rng.uniform(-2, 2, (n, d))
    y = np.sin(2 * X[:, 0]) + X[:, 1] ** 2 + 0.5 * X[:, 2] * X[:, 3]
    return X, y + 0.1 * rng.normal(size=n)


def _fit_times(model_ctor, X, y, engines, reps: int = 2):
    """({engine: best_fit_seconds}, identical) for one model config."""
    times: Dict[str, List[float]] = {e: [] for e in engines}
    models: Dict[str, object] = {}
    for _ in range(reps):
        for e in engines:
            m = model_ctor(engine=e)
            times[e].append(_time_once(lambda: m.fit(X, y)))
            models[e] = m
    ref = models[engines[0]].ensemble
    identical = all(
        np.array_equal(np.asarray(getattr(ref, f)),
                       np.asarray(getattr(models[e].ensemble, f)))
        for e in engines[1:]
        for f in ("feature", "threshold", "left", "right", "value")
    )
    return {e: min(ts) for e, ts in times.items()}, identical


def bench_fit(fast: bool, artifact_dir: Optional[pathlib.Path] = None) -> List[Row]:
    from repro.core import (
        ConfigSpace,
        GBTConfig,
        GBTRegressor,
        IOPerformancePredictor,
        RandomForestRegressor,
        RFConfig,
        recommend,
    )
    from repro.core import _native

    rows: List[Row] = []
    art: Dict[str, dict] = {
        "schema": 2,
        "native_kernels": _native.available(),
        "fit": {},
        "threads": {},
        "recommend": {},
    }

    # -- engine comparison: batched vs level vs reference ----------------
    sizes = (141, 1024) if fast else (141, 1024, 10_000)
    # Round counts chosen so the reference fit stays tractable at n=10^4;
    # all engines always run the SAME config, so ratios are unaffected.
    configs = [
        ("gbt_paper", lambda ne, engine: GBTRegressor(
            GBTConfig(n_estimators=ne, seed=0), engine=engine),
            {141: 100, 1024: 100, 10_000: 20}),
        # Deep-tree GBT: the dataset-growth / autotuner stress shape where
        # the reference's per-node Python overhead dominates.
        ("gbt_deep_d10", lambda ne, engine: GBTRegressor(
            GBTConfig(n_estimators=ne, max_depth=10, seed=0), engine=engine),
            {141: 50, 1024: 20, 10_000: 8}),
        ("rf_paper_d10", lambda ne, engine: RandomForestRegressor(
            RFConfig(n_estimators=ne, seed=0), engine=engine),
            {141: 50, 1024: 20, 10_000: 8}),
    ]
    # warm the kernels/allocator once so no engine eats the cold start
    Xw, yw = _synth(141)
    GBTRegressor(GBTConfig(n_estimators=3, seed=0)).fit(Xw, yw)
    RandomForestRegressor(RFConfig(n_estimators=2, seed=0)).fit(Xw, yw)

    for name, ctor, per_n in configs:
        if fast and name == "gbt_deep_d10":
            continue
        for n in sizes:
            if fast and name == "rf_paper_d10" and n != 141:
                continue
            ne = per_n[n]
            X, y = _synth(n)
            t, identical = _fit_times(
                lambda engine: ctor(ne, engine), X, y,
                engines=("batched", "level", "reference"),
            )
            sp_level = t["reference"] / t["level"]
            sp_batched = t["level"] / t["batched"]
            rows_s = n * ne / t["batched"]
            rows.append((
                f"fit_{name}_n{n}", t["batched"] * 1e6,
                f"estimators={ne} rows_per_s={rows_s:.0f} "
                f"level_us={t['level'] * 1e6:.0f} ref_us={t['reference'] * 1e6:.0f} "
                f"speedup_batched={sp_batched:.1f}x identical={identical}",
            ))
            art["fit"][f"{name}_n{n}"] = {
                "n": n, "estimators": ne,
                "batched_s": round(t["batched"], 4),
                "level_s": round(t["level"], 4),
                "reference_s": round(t["reference"], 4),
                "speedup_level": round(sp_level, 2),
                "speedup_batched": round(sp_batched, 2),
                "rows_per_s": round(rows_s),
                "identical_trees": identical,
            }

    # -- paper-scale ensembles (100 trees): batched vs level only --------
    # (the reference engine would take ~30 s per fit at this size)
    big = [
        ("rf_paper", lambda engine: RandomForestRegressor(
            RFConfig(n_estimators=100, seed=0), engine=engine)),
        ("gbt_paper_full", lambda engine: GBTRegressor(
            GBTConfig(n_estimators=100, seed=0), engine=engine)),
    ]
    big_sizes = (1024,) if fast else (1024, 10_000)
    for name, ctor in big:
        if fast and name == "gbt_paper_full":
            continue
        for n in big_sizes:
            X, y = _synth(n)
            t, identical = _fit_times(
                ctor, X, y, engines=("batched", "level"),
                reps=1 if fast else 2,
            )
            sp = t["level"] / t["batched"]
            rows_s = n * 100 / t["batched"]
            rows.append((
                f"fit_{name}_n{n}_b100", t["batched"] * 1e6,
                f"estimators=100 rows_per_s={rows_s:.0f} "
                f"level_us={t['level'] * 1e6:.0f} "
                f"speedup_batched={sp:.1f}x identical={identical}",
            ))
            art["fit"][f"{name}_n{n}_b100"] = {
                "n": n, "estimators": 100,
                "batched_s": round(t["batched"], 4),
                "level_s": round(t["level"], 4),
                "speedup_batched": round(sp, 2),
                "rows_per_s": round(rows_s),
                "identical_trees": identical,
            }

    # -- threaded native fit: REPRO_NATIVE_THREADS=1 vs =N ----------------
    # Only the batched engine is timed (the native kernels are its hot
    # path); every row also proves the threaded fit is byte-identical to
    # the single-threaded one — the gate hard-fails on identical=false.
    threaded = [
        ("rf_paper_n1024_b100", 1024, 100, lambda engine: RandomForestRegressor(
            RFConfig(n_estimators=100, seed=0), engine=engine)),
        ("rf_paper_n10000_b100", 10_000, 100, lambda engine: RandomForestRegressor(
            RFConfig(n_estimators=100, seed=0), engine=engine)),
        ("gbt_paper_full_n10000_b100", 10_000, 100, lambda engine: GBTRegressor(
            GBTConfig(n_estimators=100, seed=0), engine=engine)),
    ]
    cores = _cores()
    for name, n, ne, ctor in threaded:
        if fast and n != 1024:
            continue
        X, y = _synth(n)
        t, identical = _fit_times_threads(
            ctor, X, y, BENCH_THREADS, reps=1 if fast else 2)
        sp = t["t1"] / t["tN"]
        rows.append((
            f"fit_threads_{name}", t["tN"] * 1e6,
            f"threads={BENCH_THREADS} cores={cores} t1_us={t['t1'] * 1e6:.0f} "
            f"speedup_threads={sp:.2f}x identical={identical}",
        ))
        art["threads"][name] = {
            "n": n, "estimators": ne,
            "threads": BENCH_THREADS, "cores": cores,
            "native": _native.available(),
            "t1_s": round(t["t1"], 4),
            "tN_s": round(t["tN"], 4),
            "speedup_threads": round(sp, 2),
            "identical_trees": identical,
        }

    # -- recommend() serving latency ------------------------------------
    n_obs = 141
    Xo, yo = _synth(n_obs)
    from repro.core import FEATURE_NAMES

    cols = {name: Xo[:, i] * 10 + 50 for i, name in enumerate(FEATURE_NAMES)}
    cols["target_throughput"] = np.abs(yo) * 500 + 10
    ctx = {"throughput_mb_s": 800.0, "file_size_mb": 64.0, "iops": 5e4}
    grids = {
        # the paper's full §5.2 sweep: DEFAULT_SPACE, 1,800 candidates (~10^3)
        "paper_1800": ConfigSpace(),
        "1e5": ConfigSpace(batch_size=(16, 24, 32, 48, 64, 96, 128, 192, 256, 384),
                           num_workers=(0, 1, 2, 3, 4, 6, 8, 12, 16, 24),
                           block_kb=(4, 8, 16, 32, 64, 128, 256, 512, 1024, 2048),
                           n_threads=(1, 2, 3, 4, 6, 8, 12, 16, 24, 32),
                           prefetch_depth=(1, 2, 3, 4, 6, 8, 12, 16, 24, 32)),  # 10^5
    }
    if fast:
        grids.pop("1e5")
    for model in ("xgboost", "ridge"):
        pred = IOPerformancePredictor(model=model).fit(cols)
        for gname, space in grids.items():
            recommend(pred, ctx, space, top_k=5)  # warm: jit + matrix cache
            ts = [_time_once(lambda: recommend(pred, ctx, space, top_k=5))
                  for _ in range(5)]
            best = min(ts)
            ncand = space.n_candidates
            rows.append((
                f"recommend_{model}_{gname}", best * 1e6,
                f"candidates={ncand} configs_per_s={ncand / best:.0f}",
            ))
            art["recommend"][f"{model}_{gname}"] = {
                "candidates": ncand, "best_ms": round(best * 1e3, 3),
                "configs_per_s": round(ncand / best),
            }

    # -- mega-grid recommend: chunked packed-ensemble vs argpartition ----
    # The tentpole claim: at 10^5-10^6 candidates, the chunked float32
    # scorer (Pallas kernel on TPU, jitted dense descent elsewhere) beats
    # the monolithic numpy/argpartition path >= 1.5x AND picks the same
    # top-k.  Fast mode measures the 10^5 grid; full runs the 10^6 grid.
    mega_grids = {
        "1e5": ConfigSpace(
            batch_size=(16, 24, 32, 48, 64, 96, 128, 192, 256, 384),
            num_workers=(0, 1, 2, 3, 4, 6, 8, 12, 16, 24),
            block_kb=(4, 8, 16, 32, 64, 128, 256, 512, 1024, 2048),
            n_threads=(1, 2, 3, 4, 6, 8, 12, 16, 24, 32),
            prefetch_depth=(1, 2, 3, 4, 6, 8, 12, 16, 24, 32)),  # 10^5
        "1e6": ConfigSpace(
            batch_size=(16, 24, 32, 48, 64, 96, 128, 192, 256, 384),
            num_workers=(0, 1, 2, 3, 4, 6, 8, 12, 16, 24),
            block_kb=(4, 8, 16, 32, 64, 128, 256, 512, 1024, 2048),
            n_threads=(1, 2, 3, 4, 6, 8, 12, 16, 24, 32),
            prefetch_depth=(1, 2, 3, 4, 6, 8, 12, 16, 24, 32),
            prefetch_policy=(0, 1),
            lookahead_batches=(4, 8, 16, 32, 64)),  # 10^6
    }
    if fast:
        mega_grids.pop("1e6")
    else:
        mega_grids.pop("1e5")
    pred = IOPerformancePredictor(model="xgboost").fit(cols)

    def _topk_key(rs):
        return [tuple(sorted((k, v) for k, v in r.items()
                             if k != "predicted_throughput_mb_s")) for r in rs]

    mega_reps = 3 if fast else 5
    for gname, space in mega_grids.items():
        # warm both scorers: jit compiles + knob-column/matrix caches
        r_base = recommend(pred, ctx, space, top_k=5, scorer="oracle")
        r_mega = recommend(pred, ctx, space, top_k=5)  # auto -> chunked/pallas
        topk_match = _topk_key(r_base) == _topk_key(r_mega)
        t_base = min(_time_once(
            lambda: recommend(pred, ctx, space, top_k=5, scorer="oracle"))
            for _ in range(mega_reps))
        t_mega = min(_time_once(
            lambda: recommend(pred, ctx, space, top_k=5))
            for _ in range(mega_reps))
        sp = t_base / t_mega
        ncand = space.n_candidates
        rows.append((
            f"recommend_xgboost_mega_{gname}", t_mega * 1e6,
            f"candidates={ncand} configs_per_s={ncand / t_mega:.0f} "
            f"argpartition_ms={t_base * 1e3:.1f} speedup_mega={sp:.2f}x "
            f"topk_match={topk_match}",
        ))
        art["recommend"][f"xgboost_mega_{gname}"] = {
            "candidates": ncand,
            "best_ms": round(t_mega * 1e3, 3),
            "argpartition_ms": round(t_base * 1e3, 3),
            "speedup_mega": round(sp, 2),
            "configs_per_s": round(ncand / t_mega),
            "topk_match": topk_match,
        }

    row = emit_artifact(art, "BENCH_fit.json", fast, artifact_dir, ARTIFACT,
                        "fit_artifact")
    if row:
        rows.append(row)
    return rows
