"""Fit-path benchmarks: the level-wise tree engine vs the reference builder,
and the zero-copy ``recommend()`` serving path.

Run via ``PYTHONPATH=src python -m benchmarks.run --only fit``.  The full run
writes a ``BENCH_fit.json`` artifact at the repo root so the fit-performance
trajectory is tracked across PRs; ``--fast`` keeps everything CI-sized and
skips the artifact.
"""

from __future__ import annotations

import json
import pathlib
import time
from typing import Dict, List, Tuple

import numpy as np

Row = Tuple[str, float, str]

ARTIFACT = pathlib.Path(__file__).resolve().parent.parent / "BENCH_fit.json"


def _synth(n: int, d: int = 11, seed: int = 0):
    """Regression data shaped like the paper's 11-feature observations."""
    rng = np.random.default_rng(seed)
    X = rng.uniform(-2, 2, (n, d))
    y = np.sin(2 * X[:, 0]) + X[:, 1] ** 2 + 0.5 * X[:, 2] * X[:, 3]
    return X, y + 0.1 * rng.normal(size=n)


def _time_once(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def _fit_speedup(model_ctor, X, y, reps: int = 2) -> Tuple[float, float, bool]:
    """(level_s, reference_s, identical) for one model config.

    Engines are timed alternately and each takes its best of ``reps`` runs, so
    background load on a shared box biases neither side."""
    t_level, t_ref = [], []
    m_level = m_ref = None
    for _ in range(reps):
        m_level = model_ctor(engine="level")
        t_level.append(_time_once(lambda: m_level.fit(X, y)))
        m_ref = model_ctor(engine="reference")
        t_ref.append(_time_once(lambda: m_ref.fit(X, y)))
    identical = all(
        np.array_equal(np.asarray(getattr(m_level.ensemble, f)),
                       np.asarray(getattr(m_ref.ensemble, f)))
        for f in ("feature", "threshold", "left", "right", "value")
    )
    return min(t_level), min(t_ref), identical


def bench_fit(fast: bool) -> List[Row]:
    from repro.core import (
        ConfigSpace,
        GBTConfig,
        GBTRegressor,
        IOPerformancePredictor,
        RandomForestRegressor,
        RFConfig,
        recommend,
    )

    rows: List[Row] = []
    art: Dict[str, dict] = {"schema": 1, "fit": {}, "recommend": {}}

    # -- GBT / RF fit wall time + engine speedup ------------------------
    sizes = (141, 1024) if fast else (141, 1024, 10_000)
    # Round counts chosen so the reference fit stays tractable at n=10^4;
    # both engines always run the SAME config, so the ratio is unaffected.
    gbt_rounds = {141: 100, 1024: 100, 10_000: 20}
    configs = [
        # (name, per-n model ctor, estimators-per-n)
        ("gbt_paper", lambda ne, engine: GBTRegressor(
            GBTConfig(n_estimators=ne, seed=0), engine=engine), gbt_rounds),
        # Deep-tree GBT: the dataset-growth / autotuner stress shape where
        # the reference's per-node Python overhead dominates.
        ("gbt_deep_d10", lambda ne, engine: GBTRegressor(
            GBTConfig(n_estimators=ne, max_depth=10, seed=0), engine=engine),
            {141: 50, 1024: 20, 10_000: 8}),
        ("rf_paper_d10", lambda ne, engine: RandomForestRegressor(
            RFConfig(n_estimators=ne, seed=0), engine=engine),
            {141: 50, 1024: 20, 10_000: 8}),
    ]
    # warm the kernels/allocator once so neither engine eats the cold start
    Xw, yw = _synth(141)
    GBTRegressor(GBTConfig(n_estimators=3, seed=0)).fit(Xw, yw)

    for name, ctor, per_n in configs:
        if fast and name != "gbt_paper":
            continue
        for n in sizes:
            ne = per_n[n]
            X, y = _synth(n)
            t_level, t_ref, identical = _fit_speedup(
                lambda engine: ctor(ne, engine), X, y
            )
            speedup = t_ref / t_level
            rows_s = n * ne / t_level
            rows.append((
                f"fit_{name}_n{n}", t_level * 1e6,
                f"estimators={ne} rows_per_s={rows_s:.0f} ref_us={t_ref * 1e6:.0f} "
                f"speedup={speedup:.1f}x identical={identical}",
            ))
            art["fit"][f"{name}_n{n}"] = {
                "n": n, "estimators": ne,
                "level_s": round(t_level, 4), "reference_s": round(t_ref, 4),
                "speedup": round(speedup, 2), "rows_per_s": round(rows_s),
                "identical_trees": identical,
            }

    # -- recommend() serving latency ------------------------------------
    n_obs = 141
    Xo, yo = _synth(n_obs)
    from repro.core import FEATURE_NAMES

    cols = {name: Xo[:, i] * 10 + 50 for i, name in enumerate(FEATURE_NAMES)}
    cols["target_throughput"] = np.abs(yo) * 500 + 10
    ctx = {"throughput_mb_s": 800.0, "file_size_mb": 64.0, "iops": 5e4}
    grids = {
        # the paper's full §5.2 sweep: DEFAULT_SPACE, 1,800 candidates (~10^3)
        "paper_1800": ConfigSpace(),
        "1e5": ConfigSpace(batch_size=(16, 24, 32, 48, 64, 96, 128, 192, 256, 384),
                           num_workers=(0, 1, 2, 3, 4, 6, 8, 12, 16, 24),
                           block_kb=(4, 8, 16, 32, 64, 128, 256, 512, 1024, 2048),
                           n_threads=(1, 2, 3, 4, 6, 8, 12, 16, 24, 32),
                           prefetch_depth=(1, 2, 3, 4, 6, 8, 12, 16, 24, 32)),  # 10^5
    }
    if fast:
        grids.pop("1e5")
    for model in ("xgboost", "ridge"):
        pred = IOPerformancePredictor(model=model).fit(cols)
        for gname, space in grids.items():
            recommend(pred, ctx, space, top_k=5)  # warm: jit + matrix cache
            ts = [_time_once(lambda: recommend(pred, ctx, space, top_k=5))
                  for _ in range(5)]
            best = min(ts)
            ncand = space.n_candidates
            rows.append((
                f"recommend_{model}_{gname}", best * 1e6,
                f"candidates={ncand} configs_per_s={ncand / best:.0f}",
            ))
            art["recommend"][f"{model}_{gname}"] = {
                "candidates": ncand, "best_ms": round(best * 1e3, 3),
                "configs_per_s": round(ncand / best),
            }

    if not fast:
        ARTIFACT.write_text(json.dumps(art, indent=2) + "\n")
        rows.append(("fit_artifact", 0.0, f"wrote {ARTIFACT.name}"))
    return rows
