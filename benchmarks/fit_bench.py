"""Fit-path benchmarks: the batched ensemble engine vs the level-wise and
reference builders, and the zero-copy ``recommend()`` serving path.

Run via ``PYTHONPATH=src python -m benchmarks.run --only fit``.  The full run
writes a ``BENCH_fit.json`` artifact at the repo root so the fit-performance
trajectory is tracked across PRs; ``--fast`` keeps everything CI-sized and
writes the artifact only when ``--artifact-dir`` is given (the bench-gate's
fresh-run input).

Engines are timed alternately (each takes its best of ``reps`` runs) so
background load on a shared box biases no engine, and every row asserts the
engines produced byte-identical ensembles — a false ``identical_trees`` is a
correctness regression and hard-fails the CI gate (``tools/bench_gate.py``).
"""

from __future__ import annotations

import pathlib
from typing import Dict, List, Optional, Tuple

import numpy as np

from ._util import emit_artifact, time_once as _time_once

Row = Tuple[str, float, str]

ARTIFACT = pathlib.Path(__file__).resolve().parent.parent / "BENCH_fit.json"


def _synth(n: int, d: int = 11, seed: int = 0):
    """Regression data shaped like the paper's 11-feature observations."""
    rng = np.random.default_rng(seed)
    X = rng.uniform(-2, 2, (n, d))
    y = np.sin(2 * X[:, 0]) + X[:, 1] ** 2 + 0.5 * X[:, 2] * X[:, 3]
    return X, y + 0.1 * rng.normal(size=n)


def _fit_times(model_ctor, X, y, engines, reps: int = 2):
    """({engine: best_fit_seconds}, identical) for one model config."""
    times: Dict[str, List[float]] = {e: [] for e in engines}
    models: Dict[str, object] = {}
    for _ in range(reps):
        for e in engines:
            m = model_ctor(engine=e)
            times[e].append(_time_once(lambda: m.fit(X, y)))
            models[e] = m
    ref = models[engines[0]].ensemble
    identical = all(
        np.array_equal(np.asarray(getattr(ref, f)),
                       np.asarray(getattr(models[e].ensemble, f)))
        for e in engines[1:]
        for f in ("feature", "threshold", "left", "right", "value")
    )
    return {e: min(ts) for e, ts in times.items()}, identical


def bench_fit(fast: bool, artifact_dir: Optional[pathlib.Path] = None) -> List[Row]:
    from repro.core import (
        ConfigSpace,
        GBTConfig,
        GBTRegressor,
        IOPerformancePredictor,
        RandomForestRegressor,
        RFConfig,
        recommend,
    )
    from repro.core import _native

    rows: List[Row] = []
    art: Dict[str, dict] = {
        "schema": 2,
        "native_kernels": _native.available(),
        "fit": {},
        "recommend": {},
    }

    # -- engine comparison: batched vs level vs reference ----------------
    sizes = (141, 1024) if fast else (141, 1024, 10_000)
    # Round counts chosen so the reference fit stays tractable at n=10^4;
    # all engines always run the SAME config, so ratios are unaffected.
    configs = [
        ("gbt_paper", lambda ne, engine: GBTRegressor(
            GBTConfig(n_estimators=ne, seed=0), engine=engine),
            {141: 100, 1024: 100, 10_000: 20}),
        # Deep-tree GBT: the dataset-growth / autotuner stress shape where
        # the reference's per-node Python overhead dominates.
        ("gbt_deep_d10", lambda ne, engine: GBTRegressor(
            GBTConfig(n_estimators=ne, max_depth=10, seed=0), engine=engine),
            {141: 50, 1024: 20, 10_000: 8}),
        ("rf_paper_d10", lambda ne, engine: RandomForestRegressor(
            RFConfig(n_estimators=ne, seed=0), engine=engine),
            {141: 50, 1024: 20, 10_000: 8}),
    ]
    # warm the kernels/allocator once so no engine eats the cold start
    Xw, yw = _synth(141)
    GBTRegressor(GBTConfig(n_estimators=3, seed=0)).fit(Xw, yw)
    RandomForestRegressor(RFConfig(n_estimators=2, seed=0)).fit(Xw, yw)

    for name, ctor, per_n in configs:
        if fast and name == "gbt_deep_d10":
            continue
        for n in sizes:
            if fast and name == "rf_paper_d10" and n != 141:
                continue
            ne = per_n[n]
            X, y = _synth(n)
            t, identical = _fit_times(
                lambda engine: ctor(ne, engine), X, y,
                engines=("batched", "level", "reference"),
            )
            sp_level = t["reference"] / t["level"]
            sp_batched = t["level"] / t["batched"]
            rows_s = n * ne / t["batched"]
            rows.append((
                f"fit_{name}_n{n}", t["batched"] * 1e6,
                f"estimators={ne} rows_per_s={rows_s:.0f} "
                f"level_us={t['level'] * 1e6:.0f} ref_us={t['reference'] * 1e6:.0f} "
                f"speedup_batched={sp_batched:.1f}x identical={identical}",
            ))
            art["fit"][f"{name}_n{n}"] = {
                "n": n, "estimators": ne,
                "batched_s": round(t["batched"], 4),
                "level_s": round(t["level"], 4),
                "reference_s": round(t["reference"], 4),
                "speedup_level": round(sp_level, 2),
                "speedup_batched": round(sp_batched, 2),
                "rows_per_s": round(rows_s),
                "identical_trees": identical,
            }

    # -- paper-scale ensembles (100 trees): batched vs level only --------
    # (the reference engine would take ~30 s per fit at this size)
    big = [
        ("rf_paper", lambda engine: RandomForestRegressor(
            RFConfig(n_estimators=100, seed=0), engine=engine)),
        ("gbt_paper_full", lambda engine: GBTRegressor(
            GBTConfig(n_estimators=100, seed=0), engine=engine)),
    ]
    big_sizes = (1024,) if fast else (1024, 10_000)
    for name, ctor in big:
        if fast and name == "gbt_paper_full":
            continue
        for n in big_sizes:
            X, y = _synth(n)
            t, identical = _fit_times(
                ctor, X, y, engines=("batched", "level"),
                reps=1 if fast else 2,
            )
            sp = t["level"] / t["batched"]
            rows_s = n * 100 / t["batched"]
            rows.append((
                f"fit_{name}_n{n}_b100", t["batched"] * 1e6,
                f"estimators=100 rows_per_s={rows_s:.0f} "
                f"level_us={t['level'] * 1e6:.0f} "
                f"speedup_batched={sp:.1f}x identical={identical}",
            ))
            art["fit"][f"{name}_n{n}_b100"] = {
                "n": n, "estimators": 100,
                "batched_s": round(t["batched"], 4),
                "level_s": round(t["level"], 4),
                "speedup_batched": round(sp, 2),
                "rows_per_s": round(rows_s),
                "identical_trees": identical,
            }

    # -- recommend() serving latency ------------------------------------
    n_obs = 141
    Xo, yo = _synth(n_obs)
    from repro.core import FEATURE_NAMES

    cols = {name: Xo[:, i] * 10 + 50 for i, name in enumerate(FEATURE_NAMES)}
    cols["target_throughput"] = np.abs(yo) * 500 + 10
    ctx = {"throughput_mb_s": 800.0, "file_size_mb": 64.0, "iops": 5e4}
    grids = {
        # the paper's full §5.2 sweep: DEFAULT_SPACE, 1,800 candidates (~10^3)
        "paper_1800": ConfigSpace(),
        "1e5": ConfigSpace(batch_size=(16, 24, 32, 48, 64, 96, 128, 192, 256, 384),
                           num_workers=(0, 1, 2, 3, 4, 6, 8, 12, 16, 24),
                           block_kb=(4, 8, 16, 32, 64, 128, 256, 512, 1024, 2048),
                           n_threads=(1, 2, 3, 4, 6, 8, 12, 16, 24, 32),
                           prefetch_depth=(1, 2, 3, 4, 6, 8, 12, 16, 24, 32)),  # 10^5
    }
    if fast:
        grids.pop("1e5")
    for model in ("xgboost", "ridge"):
        pred = IOPerformancePredictor(model=model).fit(cols)
        for gname, space in grids.items():
            recommend(pred, ctx, space, top_k=5)  # warm: jit + matrix cache
            ts = [_time_once(lambda: recommend(pred, ctx, space, top_k=5))
                  for _ in range(5)]
            best = min(ts)
            ncand = space.n_candidates
            rows.append((
                f"recommend_{model}_{gname}", best * 1e6,
                f"candidates={ncand} configs_per_s={ncand / best:.0f}",
            ))
            art["recommend"][f"{model}_{gname}"] = {
                "candidates": ncand, "best_ms": round(best * 1e3, 3),
                "configs_per_s": round(ncand / best),
            }

    row = emit_artifact(art, "BENCH_fit.json", fast, artifact_dir, ARTIFACT,
                        "fit_artifact")
    if row:
        rows.append(row)
    return rows
