"""Cross-backend transfer benchmark: zero-shot vs few-shot calibrated error.

Runs the leave-one-backend-out harness (``repro.core.transfer``) on the
synthetic four-backend transfer track and reports, per held-out backend,
the zero-shot MAPE of the calibration model and the k-shot learning curve.
The artifact's headline number is the k<=25 calibration MAPE reduction per
fold: an affine residual correction fitted from a handful of observations
must repair most of the scale error a model trained on the *other* backends
makes on a backend it has never seen.

Run via ``PYTHONPATH=src python -m benchmarks.run --only transfer``.  The
full run writes ``BENCH_transfer.json`` at the repo root so the calibration
claim is tracked across PRs (``tools/bench_gate.py`` enforces a floor on the
committed reduction); ``--fast`` keeps it CI-sized (72 rows/backend, three
models) while still covering all four simulated backends, so every fold the
gate expects exists in both modes.
"""

from __future__ import annotations

import pathlib
from typing import List, Tuple

from ._util import emit_artifact

Row = Tuple[str, float, str]

ARTIFACT = pathlib.Path(__file__).resolve().parent.parent / "BENCH_transfer.json"


def bench_transfer(fast: bool, artifact_dir=None) -> List[Row]:
    from repro.core.transfer import (
        DEFAULT_KS,
        evaluate_transfer,
        synthetic_transfer_observations,
    )

    n_per_backend = 72 if fast else 160
    models = ("linear", "ridge", "xgboost") if fast else None  # None = full zoo
    ks = (0, 5, 25) if fast else DEFAULT_KS

    obs, groups = synthetic_transfer_observations(
        n_per_backend=n_per_backend, seed=0)
    timings: dict = {}
    report = evaluate_transfer(
        obs, groups, models=models, ks=ks, seed=0, timings=timings)

    art = {
        "schema": 1,
        "metric": "leave-one-backend-out MAPE, zero-shot vs k-shot affine "
                  "calibration, per held-out backend",
        "n_per_backend": n_per_backend,
        # the harness report is deterministic; wall-clock lives outside it
        "report": report,
        "fold_seconds": {g: round(t, 6) for g, t in sorted(timings.items())},
        "mape_reduction_k25": {
            g: f["calibration"]["mape_reduction_k25"]
            for g, f in report["folds"].items()
        },
        "max_mape_reduction_k25": report["max_mape_reduction_k25"],
    }

    rows: List[Row] = []
    for gname, fold in report["folds"].items():
        zero = fold["calibration"]["curve"]["k0"]["mape"]
        red = fold["calibration"]["mape_reduction_k25"]
        rows.append((
            f"transfer_{gname}", timings.get(gname, 0.0) * 1e6,
            f"zero_shot_mape={zero:.1f}% reduction_k25={red}x",
        ))
    rows.append((
        "transfer_mape_reduction", 0.0,
        f"calibrated_vs_zero_shot_max={art['max_mape_reduction_k25']}x",
    ))

    row = emit_artifact(art, "BENCH_transfer.json", fast, artifact_dir,
                        ARTIFACT, "transfer_artifact")
    if row:
        rows.append(row)
    return rows
