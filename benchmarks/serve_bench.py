"""Serving-tier benchmarks: QPS + latency percentiles of the concurrent
recommendation service under 1/8/32 clients, batched vs unbatched scoring,
and response-cache hit vs cold.

Everything is measured end-to-end through real HTTP against an in-process
``RecommendationService`` (threaded clients with keep-alive connections), so
the numbers include routing, JSON, and socket costs — what a deployment
would actually see.  The headline number the bench gate enforces: micro-
batched scoring must deliver at least 2x the QPS of unbatched scoring at 32
concurrent clients (dispatch amortization for /predict, in-batch context
dedup for /recommend).

Run via ``PYTHONPATH=src python -m benchmarks.run --only serve``.  The full
run writes ``BENCH_serve.json`` at the repo root; ``--fast`` writes the
CI-sized variant into the bench-gate's fresh-artifact directory.
"""

from __future__ import annotations

import http.client
import json
import pathlib
import threading
import time
from typing import List, Optional, Tuple

import numpy as np

from ._util import emit_artifact

Row = Tuple[str, float, str]

ARTIFACT = pathlib.Path(__file__).resolve().parent.parent / "BENCH_serve.json"

CLIENTS = (1, 8, 32)
MODES = ("batched", "unbatched")


def _space():
    from repro.core.autotune import ConfigSpace

    # moderate grid: recommend scoring is real work (432 candidates) without
    # dominating the unbatched baseline so badly the comparison gets silly
    return ConfigSpace(batch_size=(16, 32, 64, 128),
                       num_workers=(0, 1, 2, 4),
                       block_kb=(16, 64, 256), n_threads=(1,),
                       prefetch_depth=(1, 2, 4))


def _fitted_tuner():
    from repro.core.autotune import OnlineAutotuner
    from repro.service.serve import synthetic_observations

    space = _space()
    tuner = OnlineAutotuner(space=space, min_observations=32, refit_every=64)
    tuner.seed_observations(synthetic_observations(space, n_repeats=1))
    assert tuner.maybe_refit()
    return tuner


def _client(port: int, path: str, payloads: List[dict],
            latencies: List[float], barrier: threading.Barrier) -> None:
    conn = http.client.HTTPConnection("127.0.0.1", port, timeout=60)
    try:
        barrier.wait()
        for pl in payloads:
            body = json.dumps(pl).encode()
            t0 = time.perf_counter()
            conn.request("POST", path, body=body)
            resp = conn.getresponse()
            data = resp.read()
            latencies.append(time.perf_counter() - t0)
            assert resp.status == 200, data
    finally:
        conn.close()


def _measure(port: int, path: str, payloads_per_client: List[List[dict]]) -> dict:
    """Fire all clients through one barrier; returns qps + percentiles."""
    clients = len(payloads_per_client)
    per_client: List[List[float]] = [[] for _ in range(clients)]
    barrier = threading.Barrier(clients + 1)
    threads = [
        threading.Thread(target=_client,
                         args=(port, path, pls, per_client[i], barrier))
        for i, pls in enumerate(payloads_per_client)
    ]
    for t in threads:
        t.start()
    barrier.wait()
    t0 = time.perf_counter()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    lats = np.asarray([l for ls in per_client for l in ls])
    n = int(lats.size)
    return {
        "clients": clients,
        "n_requests": n,
        "qps": round(n / wall, 1),
        "p50_ms": round(float(np.percentile(lats, 50)) * 1e3, 3),
        "p95_ms": round(float(np.percentile(lats, 95)) * 1e3, 3),
        "p99_ms": round(float(np.percentile(lats, 99)) * 1e3, 3),
    }


def _predict_payloads(space, clients: int, per_client: int) -> List[List[dict]]:
    """Distinct configs cycling the grid: no two concurrent requests are
    dedupable, so the batched win here is pure dispatch amortization."""
    cands = space.candidates()
    ctx = {"file_size_mb": 64.0, "n_samples": 1000.0}
    out = []
    for c in range(clients):
        out.append([
            {"context": ctx,
             "config": cands[(c * per_client + i) % len(cands)]}
            for i in range(per_client)
        ])
    return out


def _recommend_payloads(clients: int, per_client: int,
                        n_contexts: int = 4) -> List[List[dict]]:
    """A small pool of workload contexts shared across clients — the
    realistic shape (many tenants, few workload classes) that lets the
    batcher collapse concurrent requests into one grid scoring each."""
    contexts = [{"file_size_mb": float(2 ** (5 + i)), "n_samples": 1000.0}
                for i in range(n_contexts)]
    out = []
    for c in range(clients):
        out.append([
            {"context": contexts[(c + i) % n_contexts], "top_k": 3}
            for i in range(per_client)
        ])
    return out


def bench_serve(fast: bool, artifact_dir=None) -> List[Row]:
    from repro.service.serve import RecommendationService, ServeConfig

    tuner = _fitted_tuner()
    space = tuner.space
    total_target = 96 if fast else 288  # requests per (endpoint, mode, clients)

    rows: List[Row] = []
    art: dict = {
        "schema": 1,
        "n_candidates": space.n_candidates,
        "n_observations": tuner.n_observations,
        "endpoints": {"predict": [], "recommend": []},
        "speedup_batched": {"predict": {}, "recommend": {}},
    }

    qps: dict = {}
    for mode in MODES:
        svc = RecommendationService(tuner, ServeConfig(
            batching=(mode == "batched"), cache_size=0))
        svc.start()
        try:
            for endpoint, payload_fn in (
                ("predict", lambda c, p: _predict_payloads(space, c, p)),
                ("recommend", lambda c, p: _recommend_payloads(c, p)),
            ):
                for clients in CLIENTS:
                    per_client = max(3, total_target // clients)
                    payloads = payload_fn(clients, per_client)
                    _measure(svc.port, f"/{endpoint}", payloads)  # warm
                    m = _measure(svc.port, f"/{endpoint}", payloads)
                    m["mode"] = mode
                    qps[(endpoint, mode, clients)] = m["qps"]
                    art["endpoints"][endpoint].append(m)
                    rows.append((
                        f"serve_{endpoint}_{mode}_c{clients}",
                        m["p50_ms"] * 1e3,
                        f"qps={m['qps']} p95_ms={m['p95_ms']} "
                        f"p99_ms={m['p99_ms']} n={m['n_requests']}",
                    ))
        finally:
            svc.shutdown()

    for endpoint in ("predict", "recommend"):
        for clients in CLIENTS:
            sp = (qps[(endpoint, "batched", clients)]
                  / qps[(endpoint, "unbatched", clients)])
            art["speedup_batched"][endpoint][f"c{clients}"] = round(sp, 2)
        sp32 = art["speedup_batched"][endpoint]["c32"]
        rows.append((
            f"serve_{endpoint}_speedup", 0.0,
            f"batched_vs_unbatched c1={art['speedup_batched'][endpoint]['c1']}x "
            f"c8={art['speedup_batched'][endpoint]['c8']}x c32={sp32}x",
        ))

    # -- response cache: hit vs cold over one distinct-context sweep -----
    svc = RecommendationService(tuner, ServeConfig(batching=True,
                                                   cache_size=1024))
    svc.start()
    try:
        n_ctx = 16 if fast else 48
        payloads = [[{"context": {"file_size_mb": float(8 + i),
                                  "n_samples": 1000.0}, "top_k": 3}
                     for i in range(n_ctx)]]
        cold = _measure(svc.port, "/recommend", payloads)
        assert svc.cache.misses >= n_ctx
        hit = _measure(svc.port, "/recommend", payloads)
        assert svc.cache.hits >= n_ctx
    finally:
        svc.shutdown()
    art["cache"] = {
        "n_contexts": n_ctx,
        "cold_qps": cold["qps"], "hit_qps": hit["qps"],
        "cold_p50_ms": cold["p50_ms"], "hit_p50_ms": hit["p50_ms"],
        "speedup_hit": round(hit["qps"] / cold["qps"], 2),
    }
    rows.append((
        "serve_cache_hit", hit["p50_ms"] * 1e3,
        f"hit_qps={hit['qps']} cold_qps={cold['qps']} "
        f"speedup={art['cache']['speedup_hit']}x",
    ))
    rows.append(("serve_cache_cold", cold["p50_ms"] * 1e3,
                 f"cold_qps={cold['qps']} n_ctx={n_ctx}"))

    row = emit_artifact(art, "BENCH_serve.json", fast, artifact_dir, ARTIFACT,
                        "serve_artifact")
    if row:
        rows.append(row)
    return rows
