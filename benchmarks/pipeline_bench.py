"""Prefetch-policy pipeline benchmark: stall time vs. delivered throughput.

Runs the token pipeline on the calibrated network/object-store simulators
(the regimes where prefetching pays) with each ``prefetch_policy`` —
``off`` / ``depth`` / ``clairvoyant`` — at 1 and 4 workers, and reports the
measure-window stall time (summed ``data_wait`` seconds) and delivered MB/s
per case.  The artifact's headline number is the clairvoyant-vs-depth stall
reduction per (backend, workers) point: the schedule-driven prefetcher reads
the *known* epoch order ahead, so stalls should collapse rather than merely
overlap.

Run via ``PYTHONPATH=src python -m benchmarks.run --only pipeline``.  The
full run writes ``BENCH_pipeline.json`` at the repo root so the stall
reduction is tracked across PRs (``tools/bench_gate.py`` enforces a floor on
the committed claim); ``--fast`` keeps it CI-sized (network_sim, 1 worker).
"""

from __future__ import annotations

import pathlib
from typing import List, Tuple

from ._util import emit_artifact

Row = Tuple[str, float, str]

ARTIFACT = pathlib.Path(__file__).resolve().parent.parent / "BENCH_pipeline.json"

POLICIES = ("off", "depth", "clairvoyant")


def bench_pipeline(fast: bool, artifact_dir=None) -> List[Row]:
    from repro.core.features import TARGET_NAME
    from repro.data.campaign import RunContext, run_pipeline_case
    from repro.data.storage import BACKENDS

    rows: List[Row] = []
    art = {
        "schema": 1,
        "metric": "measure-window stall seconds (data_wait) and delivered "
                  "MB/s per prefetch policy",
        "cases": [],
        "stall_reduction": {},  # clairvoyant vs depth, per backend.wN
    }
    backends = ("network_sim",) if fast else ("network_sim", "object_sim")
    worker_counts = (1,) if fast else (1, 4)
    n_records = 192 if fast else 512
    probe_steps, measure_steps = (1, 4) if fast else (2, 8)

    ctx = RunContext()
    stalls = {}
    for bname in backends:
        backend = BACKENDS[bname]
        manifest = ctx.manifest(backend, "packed", n_records, 64, 0)
        for w in worker_counts:
            for policy in POLICIES:
                r = run_pipeline_case(
                    backend, manifest, "packed", batch=32, workers=w,
                    seq_len=64, compute_s=0.002, probe_steps=probe_steps,
                    measure_steps=measure_steps, block_kb=16,
                    prefetch_policy=policy, lookahead_batches=8,
                    cache_budget_mb=8.0, access="shuffle",
                )
                key = f"{bname}.w{w}.{policy}"
                stall = float(r["data_wait_s"])
                mbs = float(r[TARGET_NAME])
                hit = float(r.get("prefetch_hit_ratio", 0.0))
                stalls[(bname, w, policy)] = stall
                art["cases"].append({
                    "key": key, "backend": bname, "workers": w,
                    "policy": policy, "stall_s": round(stall, 6),
                    "delivered_mb_s": round(mbs, 3),
                    "hit_ratio": round(hit, 4),
                })
                rows.append((
                    f"pipeline_{key}", stall * 1e6,
                    f"delivered={mbs:.1f}MB/s hit={hit:.2f}",
                ))
    # a fully-hidden stall still yields a finite ratio (floor at 0.1ms)
    floor = 1e-4
    for bname in backends:
        for w in worker_counts:
            red = (stalls[(bname, w, "depth")]
                   / max(stalls[(bname, w, "clairvoyant")], floor))
            art["stall_reduction"][f"{bname}.w{w}"] = round(red, 2)
    art["max_stall_reduction"] = max(art["stall_reduction"].values())
    rows.append((
        "pipeline_stall_reduction", 0.0,
        f"clairvoyant_vs_depth_max={art['max_stall_reduction']}x",
    ))

    row = emit_artifact(art, "BENCH_pipeline.json", fast, artifact_dir,
                        ARTIFACT, "pipeline_artifact")
    if row:
        rows.append(row)
    return rows
