"""One benchmark per paper table/figure. Each returns a list of CSV rows
(name, us_per_call, derived)."""

from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

Row = Tuple[str, float, str]


def _t(fn, reps=1):
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    return (time.perf_counter() - t0) / reps * 1e6, out


# ------------------------------------------------------------------ Fig 2/3
def bench_dataset(fast: bool) -> List[Row]:
    from repro.data.dataset import collect_observations, observations_to_columns
    from repro.data.registry import get_campaign

    n_cases = {
        name: len(get_campaign(name).cases(fast))
        for name in ("paper_random_access", "paper_pipeline", "paper_concurrent")
    }
    us, rows = _t(lambda: collect_observations(fast=fast))
    cols = observations_to_columns(rows)
    t = cols["target_throughput"]
    skew = float(np.mean((t - t.mean()) ** 3) / t.std() ** 3)
    tl = np.log1p(t)
    skew_log = float(np.mean((tl - tl.mean()) ** 3) / tl.std() ** 3)
    return [
        ("fig2_dataset_collection", us,
         f"n={len(rows)} campaigns=" + "+".join(str(v) for v in n_cases.values())),
        ("fig3_target_skewness_raw", 0.0, f"skew={skew:.2f} (paper: 2.50)"),
        ("fig3_target_skewness_log1p", 0.0, f"skew={skew_log:.2f}"),
        ("fig3_target_range", 0.0,
         f"min={t.min():.2f}MB/s max={t.max():.1f}MB/s"),
    ]


# ------------------------------------------------------------------ Fig 4
def bench_pca(fast: bool) -> List[Row]:
    from repro.core import PCA, FeatureSpec, StandardScaler
    from repro.data.dataset import collect_observations, observations_to_columns

    cols = observations_to_columns(collect_observations(fast=fast))
    X = StandardScaler().fit_transform(FeatureSpec().matrix(cols))
    us, p = _t(lambda: PCA().fit(X))
    r = p.explained_variance_ratio_
    return [
        ("fig4_pca_fit", us, f"pc1={r[0]:.3f} pc1+2={r[:2].sum():.3f} "
         f"k80={p.n_components_for_variance(0.8)} k95={p.n_components_for_variance(0.95)} "
         "(paper: 0.190/0.357/7/9)"),
    ]


# ------------------------------------------------------------------ Fig 5/6/7
def bench_model_comparison(fast: bool) -> List[Row]:
    from repro.core import IOPerformancePredictor
    from repro.data.dataset import collect_observations, observations_to_columns

    cols = observations_to_columns(collect_observations(fast=fast))
    pred = IOPerformancePredictor()
    models = ["linear", "ridge", "lasso", "elasticnet", "random_forest", "xgboost"]
    if not fast:
        models.append("mlp")
    us, reports = _t(lambda: pred.evaluate_zoo(cols, models=models, with_cv=not fast))
    rows: List[Row] = [("fig5_zoo_fit_total", us, f"models={len(models)}")]
    for name, r in sorted(reports.items(), key=lambda kv: -kv[1].test_r2):
        rows.append((
            f"fig5_{name}", 0.0,
            f"test_r2={r.test_r2:.4f} train_r2={r.train_r2:.4f} mae={r.test_mae:.3f}",
        ))
    x = reports["xgboost"]
    rows.append(("fig6_xgboost_errors", 0.0,
                 f"mean%err={x.mean_pct_err:.1f} median%err={x.median_pct_err:.1f} "
                 "(paper: 11.8/8.1)"))
    if not fast:
        rows.append(("fig7_xgboost_cv", 0.0,
                     f"cv_r2={x.cv_mean:.3f}+-{x.cv_std:.3f} (paper: 0.966+-0.016)"))
        rf = reports["random_forest"]
        rows.append(("fig7_rf_cv", 0.0, f"cv_r2={rf.cv_mean:.3f}+-{rf.cv_std:.3f}"))
    return rows


# ------------------------------------------------------------------ Fig 8
def bench_feature_importance(fast: bool) -> List[Row]:
    from repro.core import FEATURE_NAMES, IOPerformancePredictor, rank_features
    from repro.data.dataset import collect_observations, observations_to_columns

    cols = observations_to_columns(collect_observations(fast=fast))
    rows: List[Row] = []
    for model in ("xgboost", "random_forest"):
        pred = IOPerformancePredictor(model=model).fit(cols)
        top = rank_features(pred.feature_importances_, FEATURE_NAMES)[:4]
        rows.append((f"fig8_importance_{model}", 0.0,
                     " ".join(f"{n}={v:.2f}" for n, v in top)))
    return rows


# ------------------------------------------------------------------ Fig 1
def bench_util_impact(fast: bool) -> List[Row]:
    """Poor vs optimized pipeline config -> simulated accelerator utilization."""
    from repro.data import BACKENDS, DataPipeline, PipelineConfig, TokenRecordCodec
    from repro.data import open_dataset, write_dataset
    from repro.data.campaign import simulated_compute as _simulated_compute

    # network-attached storage sim: per-op latency dominates, so prefetch +
    # workers genuinely overlap I/O with compute (the paper's Fig-1 regime)
    backend = BACKENDS["network_sim"]
    seq = 256
    codec = TokenRecordCodec(seq)
    rng = np.random.default_rng(0)
    n = 256 if fast else 512
    recs = [codec.encode(rng.integers(0, 50000, seq).astype(np.int32)) for _ in range(n)]
    man = write_dataset(backend, "fig1", recs, "packed")

    def run(cfgkw, compute_s=0.004):
        from repro.data.telemetry import StepTelemetry

        reader = open_dataset(backend, man, block_kb=cfgkw.pop("block_kb", 64))
        pipe = DataPipeline.from_reader(reader, seq, PipelineConfig(**cfgkw))
        tele = StepTelemetry()
        it = pipe.iter_epoch(0)
        for s in range(min(10, pipe.steps_per_epoch())):
            with tele.data_wait():
                b = next(it)
            with tele.compute():
                _simulated_compute(compute_s)
            tele.record_batch(b.shape[0], b.nbytes)
        it.close(); pipe.close(); reader.close()
        return tele.simulated_utilization()

    # poor: serial fetch, one op per record against a ~1ms-latency store
    us_poor, util_poor = _t(lambda: run(
        dict(batch_size=32, num_workers=0, prefetch_depth=1, block_kb=4),
        compute_s=0.03))
    # optimized: workers + deep prefetch overlap the latency behind compute
    us_opt, util_opt = _t(lambda: run(
        dict(batch_size=32, num_workers=8, prefetch_depth=4, block_kb=64),
        compute_s=0.03))
    return [
        ("fig1_util_poor_config", us_poor, f"util={util_poor:.1%} (paper: 45.5%)"),
        ("fig1_util_optimized", us_opt, f"util={util_opt:.1%} (paper: 93.1%)"),
    ]


# ------------------------------------------------------------------ §3.1.3
def bench_etl(fast: bool) -> List[Row]:
    from repro.data.etl import bench_etl as _bench

    out = _bench(n_rows=20_000 if fast else 100_000)
    rows = []
    for op, d in out.items():
        rows.append((f"etl_{op}_jax", d["jax_s"] * 1e6,
                     f"np_us={d['np_s'] * 1e6:.0f} n_rows={d['n_rows']}"))
    return rows


# ------------------------------------------------------------------ §5.2
def bench_recommendation(fast: bool) -> List[Row]:
    """The paper's headline: configuration search in ms, not days."""
    from repro.core import ConfigSpace, IOPerformancePredictor, recommend
    from repro.data.dataset import collect_observations, observations_to_columns

    cols = observations_to_columns(collect_observations(fast=fast))
    pred = IOPerformancePredictor(model="xgboost").fit(cols)
    space = ConfigSpace()
    n = len(space.candidates())
    ctx = {"throughput_mb_s": 800.0, "file_size_mb": 64.0, "iops": 5e4}
    recommend(pred, ctx, space, top_k=5)  # warm
    us, top = _t(lambda: recommend(pred, ctx, space, top_k=5), reps=3)
    return [(
        "s52_recommend_sweep", us,
        f"candidates={n} configs_per_s={n / (us / 1e6):.0f} "
        f"best={top[0]['predicted_throughput_mb_s']:.0f}MB/s",
    )]


# ------------------------------------------------------------------ §5.4 (beyond-paper)
def bench_extensions(fast: bool) -> List[Row]:
    """The paper's named future-work items, implemented: prediction
    intervals, ensemble stacking, and the dataset-size learning curve."""
    from repro.core import (
        ConformalRegressor, FeatureSpec, GBTConfig, GBTRegressor,
        RandomForestRegressor, RFConfig, Ridge, StackingRegressor,
        log1p_transform, r2_score, rf_prediction_interval, train_test_split,
    )
    from repro.data.dataset import collect_observations, observations_to_columns

    cols = observations_to_columns(collect_observations(fast=fast))
    X = FeatureSpec().matrix(cols)
    y = log1p_transform(cols["target_throughput"])
    n = X.shape[0]
    tr, te = train_test_split(n)
    rows: List[Row] = []

    # learning curve: R2 vs training-set size (paper: "expand to 500-1000")
    rng = np.random.default_rng(0)
    for frac in (0.25, 0.5, 1.0):
        k = max(12, int(len(tr) * frac))
        sub = rng.choice(tr, size=k, replace=False)
        m = GBTRegressor(GBTConfig(n_estimators=60)).fit(X[sub], y[sub])
        rows.append((f"s54_learning_curve_n{k}", 0.0,
                     f"test_r2={r2_score(y[te], m.predict(X[te])):.4f}"))

    # prediction intervals
    rf = RandomForestRegressor(RFConfig(n_estimators=40)).fit(X[tr], y[tr])
    lo, mid, hi = rf_prediction_interval(rf, X[te], alpha=0.2)
    cov = float(np.mean((y[te] >= lo) & (y[te] <= hi)))
    rows.append(("s54_rf_interval_80", 0.0,
                 f"coverage={cov:.2f} width={float((hi - lo).mean()):.3f}"))
    cr = ConformalRegressor(GBTRegressor(GBTConfig(n_estimators=40))).fit(
        X[tr], y[tr], alpha=0.1)
    lo, mid, hi = cr.predict_interval(X[te])
    cov = float(np.mean((y[te] >= lo) & (y[te] <= hi)))
    rows.append(("s54_conformal_interval_90", 0.0,
                 f"coverage={cov:.2f} q={cr.q_:.3f}"))

    # stacking
    us, stack = _t(lambda: StackingRegressor({
        "gbt": lambda: GBTRegressor(GBTConfig(n_estimators=40)),
        "rf": lambda: RandomForestRegressor(RFConfig(n_estimators=30)),
        "ridge": lambda: Ridge(1.0),
    }, k=4).fit(X[tr], y[tr]))
    rows.append(("s54_stacking", us,
                 f"test_r2={r2_score(y[te], stack.predict(X[te])):.4f}"))
    return rows


# ------------------------------------------------------------------ §3.1 campaigns
def bench_campaign(fast: bool) -> List[Row]:
    """Registry expansion + resumable JSONL collection overhead (campaign.py)."""
    import pathlib
    import tempfile

    from repro.data.campaign import load_records, run_campaign, summarize
    from repro.data.registry import list_campaigns

    rows: List[Row] = []
    for c in list_campaigns():
        us, cases = _t(lambda c=c: c.cases(fast))
        rows.append((f"campaign_expand_{c.name}", us, f"cases={len(cases)}"))
    with tempfile.TemporaryDirectory() as td:
        out = pathlib.Path(td) / "cc.jsonl"
        us, res = _t(lambda: run_campaign("paper_concurrent", out, fast=True))
        report = summarize(load_records(out))
        rows.append(("campaign_run_concurrent_fast", us,
                     f"executed={res.n_executed} ok={report['n_ok']}"))
        us, res = _t(lambda: run_campaign("paper_concurrent", out, fast=True))
        rows.append(("campaign_resume_noop", us,
                     f"executed={res.n_executed} skipped={res.skipped}"))
    return rows


# ------------------------------------------------------------------ kernels
def bench_kernels(fast: bool) -> List[Row]:
    import jax
    import jax.numpy as jnp

    from repro.core import GBTConfig, GBTRegressor
    from repro.core.ensemble_base import predict_ensemble
    from repro.models.common import attention_heads_tp
    from repro.kernels.ref import rmsnorm_reference

    rows: List[Row] = []
    # reference attention path (XLA CPU) — what the dry-run lowers
    B, S, H, KV, Dh = 1, 512, 8, 4, 64
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, Dh), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KV, Dh), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KV, Dh), jnp.float32)
    att = jax.jit(lambda q, k, v: attention_heads_tp(q, k, v, q_chunk=128))
    jax.block_until_ready(att(q, k, v))
    us, _ = _t(lambda: jax.block_until_ready(att(q, k, v)), reps=5)
    flops = 2 * 2 * S * S * H * Dh * B
    rows.append(("kernel_attention_ref_xla", us, f"gflops_s={flops / us / 1e3:.1f}"))

    x = jax.random.normal(jax.random.PRNGKey(3), (4096, 1024), jnp.float32)
    s = jnp.ones((1024,), jnp.float32)
    rn = jax.jit(lambda x, s: rmsnorm_reference(x, s))
    jax.block_until_ready(rn(x, s))
    us, _ = _t(lambda: jax.block_until_ready(rn(x, s)), reps=10)
    gb = x.nbytes * 2 / 1e9
    rows.append(("kernel_rmsnorm_ref_xla", us, f"gb_s={gb / (us / 1e6):.1f}"))

    # GBT ensemble inference (JAX dense-descent path used by the autotuner)
    rng = np.random.default_rng(0)
    X = rng.normal(size=(2048, 11))
    y = rng.normal(size=2048)
    m = GBTRegressor(GBTConfig(n_estimators=100, max_depth=6)).fit(X[:256], y[:256])
    Xj = jnp.asarray(X, jnp.float32)
    pe = jax.jit(lambda X: predict_ensemble(m.ensemble, X))
    jax.block_until_ready(pe(Xj))
    us, _ = _t(lambda: jax.block_until_ready(pe(Xj)), reps=5)
    rows.append(("kernel_gbt_predict_jax", us,
                 f"rows_per_s={2048 / (us / 1e6):.0f} trees=100"))
    return rows
