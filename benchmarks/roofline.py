"""Roofline table builder: reads results/dryrun/*.json (produced by
``python -m repro.launch.dryrun``) and renders the EXPERIMENTS.md §Roofline
table + CSV rows for benchmarks/run.py."""

from __future__ import annotations

import json
import pathlib
from typing import Dict, List, Optional

DRYRUN_DIR = pathlib.Path("/root/repo/results/dryrun")


def load_records(directory: pathlib.Path = DRYRUN_DIR, tag: str = "baseline") -> List[dict]:
    recs = []
    for f in sorted(directory.glob(f"*__{tag}.json")):
        recs.append(json.loads(f.read_text()))
    return recs


def _fmt_s(x: Optional[float]) -> str:
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def bandwidth_fraction(r: dict) -> Optional[float]:
    """For memory-bound steps (decode especially): fraction of per-device HLO
    byte traffic that is irreducible input state (params + caches). 1.0 would
    mean every byte moved was a parameter/cache byte."""
    args = (r.get("memory") or {}).get("argument_bytes")
    per_dev = (r.get("cost") or {}).get("bytes_accessed")
    if not args or not per_dev:
        return None
    return min(float(args) / float(per_dev), 1.0)


def markdown_table(recs: List[dict], mesh: str = "16x16") -> str:
    lines = [
        "| arch | shape | compute | memory | collective | bottleneck | "
        "MODEL_FLOPS/HLO | roofline frac | BW frac | per-dev peak mem |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("mesh") != mesh:
            continue
        if r["status"] == "skipped":
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | SKIPPED | — | — | — | "
                f"{r.get('skip_reason', '')[:40]} |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | | | |")
            continue
        t = r["roofline"]
        mem = r.get("memory", {}).get("peak_bytes") or 0
        bw = bandwidth_fraction(r)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_s(t['compute_s'])} | "
            f"{_fmt_s(t['memory_s'])} | {_fmt_s(t['collective_s'])} | "
            f"**{t['bottleneck']}** | {t.get('useful_flops_ratio', 0):.2f} | "
            f"{t.get('roofline_fraction', 0):.3f} | "
            f"{'-' if bw is None else f'{bw:.2f}'} | {mem / 1e9:.1f}GB |"
        )
    return "\n".join(lines)


def csv_rows(recs: List[dict]) -> List[tuple]:
    rows = []
    for r in recs:
        name = f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}"
        if r["status"] == "ok":
            t = r["roofline"]
            dominant = max(t["compute_s"], t["memory_s"], t["collective_s"])
            rows.append((name, dominant * 1e6,
                         f"bottleneck={t['bottleneck']} frac={t.get('roofline_fraction', 0):.3f}"))
        else:
            rows.append((name, 0.0, r["status"]))
    return rows


def summarize(recs: List[dict]) -> Dict[str, int]:
    out = {"ok": 0, "error": 0, "skipped": 0}
    for r in recs:
        out[r["status"]] = out.get(r["status"], 0) + 1
    return out


if __name__ == "__main__":
    recs = load_records()
    print(markdown_table(recs, "16x16"))
    print()
    print(markdown_table(recs, "2x16x16"))
    print(summarize(recs))
