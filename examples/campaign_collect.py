"""Campaign quickstart: declarative, resumable benchmark collection.

1. List the registered campaigns (the paper's 84/52/5 plus `extended`).
2. Run the fast paper campaigns, appending one JSONL record per case.
3. Re-run: resume skips everything already completed.
4. Aggregate the per-backend/format summary report.

Run: PYTHONPATH=src python examples/campaign_collect.py
The same flow via the CLI:  python -m repro.data.campaign list|run|summarize
"""

import pathlib
import tempfile

from repro.data.campaign import format_summary, load_records, run_campaign, summarize
from repro.data.registry import list_campaigns


def main():
    print("== 1. registered campaigns ==")
    for c in list_campaigns():
        print(f"   {c.name:24s} {len(c.cases()):>4d} cases "
              f"(fast: {len(c.cases(fast=True))})  {c.description}")

    out_dir = pathlib.Path(tempfile.mkdtemp(prefix="repro_campaign_"))
    out = out_dir / "paper_fast.jsonl"

    print("== 2. collecting (fast paper campaigns -> JSONL) ==")
    for name in ("paper_random_access", "paper_pipeline", "paper_concurrent"):
        res = run_campaign(name, out, fast=True)
        print(f"   {name:24s} executed={res.n_executed:3d} "
              f"skipped={res.skipped} failed={len(res.failures)}")

    print("== 3. resume is a no-op when everything is done ==")
    res = run_campaign("paper_pipeline", out, fast=True)
    print(f"   paper_pipeline           executed={res.n_executed:3d} skipped={res.skipped}")

    print("== 4. summary report ==")
    print(format_summary(summarize(load_records(out))))
    print(f"\nresults kept at {out}")


if __name__ == "__main__":
    main()
