"""Quickstart: the paper's workflow end-to-end in ~1 minute.

1. Benchmark the real storage stack of this machine (fast subset).
2. Fit the model zoo (JAX GBT = the paper's XGBoost winner).
3. Predict throughput for unseen configurations and print the top
   recommendations — the paper's "days of trial-and-error -> minutes".

Run: PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import (
    FEATURE_NAMES,
    ConfigSpace,
    IOPerformancePredictor,
    rank_features,
    recommend,
)
from repro.data.dataset import collect_observations, observations_to_columns


def main():
    print("== 1. collecting I/O observations (fast subset) ==")
    rows = collect_observations(fast=True, cache=None)
    cols = observations_to_columns(rows)
    print(f"   {len(rows)} observations, target range "
          f"{cols['target_throughput'].min():.1f}..{cols['target_throughput'].max():.0f} MB/s")

    print("== 2. fitting the model zoo ==")
    pred = IOPerformancePredictor(model="xgboost")
    reports = pred.evaluate_zoo(cols, models=["linear", "random_forest", "xgboost"],
                                with_cv=False)
    for name, r in sorted(reports.items(), key=lambda kv: -kv[1].test_r2):
        print(f"   {name:14s} test R2={r.test_r2:.4f} mean%err={r.mean_pct_err:.1f}")

    print("== 3. feature importance (paper Fig 8) ==")
    pred.fit(cols)
    for name, v in rank_features(pred.feature_importances_, FEATURE_NAMES)[:5]:
        print(f"   {name:28s} {v:.3f}")

    print("== 4. configuration recommendation (paper §5.2) ==")
    context = {"throughput_mb_s": 500.0, "file_size_mb": 64.0, "iops": 2e4}
    space = ConfigSpace()
    top = recommend(pred, context, space, top_k=5)
    print(f"   scored {len(space.candidates())} candidate configs")
    for t in top:
        print(f"   predicted {t['predicted_throughput_mb_s']:8.1f} MB/s  <- "
              f"batch={t['batch_size']} workers={t['num_workers']} "
              f"block={t['block_kb']}KB prefetch={t['prefetch_depth']}")


if __name__ == "__main__":
    main()
