"""Live pipeline autotuning (the paper's technique inside a training loop).

Starts a training-style loop against simulated network storage with a
deliberately bad pipeline config; the OnlineAutotuner observes telemetry,
refits its predictor, and reconfigures the pipeline live. Watch the
simulated accelerator utilization climb (paper Fig 1).

Run: PYTHONPATH=src python examples/autotune_pipeline.py
"""

import time

import numpy as np

from repro.core import ConfigSpace, OnlineAutotuner
from repro.data import (
    BACKENDS,
    DataPipeline,
    PipelineConfig,
    StepTelemetry,
    TokenRecordCodec,
    open_dataset,
    write_dataset,
)


def busy_compute(seconds: float):
    t0 = time.perf_counter()
    while time.perf_counter() - t0 < seconds:
        pass


def main():
    backend = BACKENDS["network_sim"]  # ~1ms/op latency: I/O genuinely hurts
    seq = 256
    codec = TokenRecordCodec(seq)
    rng = np.random.default_rng(0)
    records = [codec.encode(rng.integers(0, 50_000, seq).astype(np.int32))
               for _ in range(2048)]
    manifest = write_dataset(backend, "autotune_demo", records, "packed")
    reader = open_dataset(backend, manifest, block_kb=4)

    # deliberately poor starting config
    pipe = DataPipeline.from_reader(
        reader, seq, PipelineConfig(batch_size=32, num_workers=0, prefetch_depth=1,
                                    block_kb=4))
    tuner = OnlineAutotuner(
        refit_every=5, min_observations=6, gain_threshold=0.05,
        min_config_diversity=6,  # explore 6 distinct configs before exploiting
        space=ConfigSpace(batch_size=(32,), num_workers=(0, 2, 4, 8),
                          block_kb=(4, 64, 256), n_threads=(1,),
                          prefetch_depth=(1, 4)),
    )
    tele = StepTelemetry(window=5)
    step = 0
    for epoch in range(30):
        it = pipe.iter_epoch(epoch)
        while True:
            try:
                with tele.data_wait():
                    batch = next(it)
            except StopIteration:
                break
            with tele.compute():
                busy_compute(0.02)
            tele.record_batch(batch.shape[0], batch.nbytes)
            step += 1
            if step % 5 == 0:
                feats = tele.features(pipe.config.batch_size,
                                      pipe.config.num_workers,
                                      pipe.config.block_kb)
                tuner.observe(feats, feats["throughput_mb_s"])
                tuner.maybe_refit()
                cur = {"batch_size": pipe.config.batch_size,
                       "num_workers": pipe.config.num_workers,
                       "block_kb": pipe.config.block_kb,
                       "prefetch_depth": pipe.config.prefetch_depth}
                d = tuner.decide(cur, feats)
                print(f"step {step:3d} util={tele.simulated_utilization():6.1%} "
                      f"cfg={cur['num_workers']}w/{cur['block_kb']}KB/"
                      f"p{cur['prefetch_depth']} "
                      f"{'-> RECONFIG ' + str(d.config) if d.reconfigure else ''}")
                if d.reconfigure:
                    pipe.reconfigure(**{k: v for k, v in d.config.items()
                                        if k in ("num_workers", "block_kb",
                                                 "prefetch_depth")})
                    it.close()
                    break
            if step >= 90:
                it.close()
                break
        if step >= 90:
            break
    print(f"final utilization: {tele.simulated_utilization():.1%}")
    pipe.close()
    reader.close()


if __name__ == "__main__":
    main()
