"""End-to-end training driver: real on-disk dataset -> tunable pipeline ->
fault-tolerant trainer (checkpoints + autotune) for any LM-family arch.

This drives a few hundred steps of a reduced-config model on CPU; on a pod,
the same Trainer wraps the pjit train step from repro.train.step (see
repro/launch/dryrun.py for the production-mesh lowering of every arch).

Run: PYTHONPATH=src python examples/train_lm.py [--arch codeqwen1.5-7b]
     PYTHONPATH=src python examples/train_lm.py --arch falcon-mamba-7b --steps 50
"""

import argparse

import numpy as np

from repro.configs import get_config, reduced
from repro.data import (
    BACKENDS,
    DataPipeline,
    PipelineConfig,
    TokenRecordCodec,
    open_dataset,
    write_dataset,
)
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="codeqwen1.5-7b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    print(f"== training {cfg.name} (reduced: {cfg.n_layers}L d{cfg.d_model}) ==")

    # real storage-backed dataset (the thing the paper optimizes)
    seq = args.seq_len + 1
    codec = TokenRecordCodec(seq)
    rng = np.random.default_rng(0)
    records = [
        codec.encode(rng.integers(0, cfg.vocab_size, seq, dtype=np.int32))
        for _ in range(2048)
    ]
    backend = BACKENDS["tmpfs"]
    manifest = write_dataset(backend, f"ex_train_{args.arch}", records, "packed")
    reader = open_dataset(backend, manifest)
    pipe = DataPipeline.from_reader(
        reader, seq, PipelineConfig(batch_size=args.batch_size, num_workers=0)
    )

    trainer = Trainer(
        cfg, pipe,
        TrainerConfig(num_steps=args.steps, ckpt_every=50,
                      ckpt_dir=f"/tmp/repro_ckpt_{args.arch}", log_every=20),
    )
    out = trainer.run()
    h = out["history"]
    k = max(len(h) // 10, 1)
    print(f"loss: first10={np.mean(h[:k]):.4f} last10={np.mean(h[-k:]):.4f} "
          f"(steps={out['final_step']})")
    assert np.mean(h[-k:]) < np.mean(h[:k]), "loss should decrease"
    print("OK — loss decreased; checkpoints in", trainer.tcfg.ckpt_dir)
    pipe.close()
    reader.close()


if __name__ == "__main__":
    main()
