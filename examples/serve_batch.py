"""Batched serving example: continuous-batching engine over the jit'd
KV-cache decode step (slots recycle as requests finish).

Run: PYTHONPATH=src python examples/serve_batch.py [--arch gemma3-4b]
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models import get_api
from repro.parallel.spec import init_params
from repro.serve import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="codeqwen1.5-7b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-tokens", type=int, default=12)
    ap.add_argument("--slots", type=int, default=3)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    assert cfg.family in ("dense", "moe", "vlm", "ssm", "hybrid")
    api = get_api(cfg)
    params = init_params(api.param_specs(cfg), jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, max_len=128, slots=args.slots)

    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i,
                prompt=rng.integers(1, cfg.vocab_size, size=4 + i % 5).astype(np.int32),
                max_tokens=args.max_tokens)
        for i in range(args.requests)
    ]
    t0 = time.perf_counter()
    done = engine.run(reqs)
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.tokens) for r in done)
    print(f"== served {len(done)} requests on {args.slots} slots "
          f"({cfg.name} reduced) ==")
    for r in sorted(done, key=lambda r: r.rid):
        print(f"  req {r.rid}: prompt_len={len(r.prompt)} -> {r.tokens} "
              f"({r.latency_s * 1e3:.0f}ms)")
    print(f"throughput: {total_tokens / dt:.1f} tok/s (CPU, reduced config)")


if __name__ == "__main__":
    main()
