PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test bench-fast campaign-smoke loop-smoke fleet-smoke docs-check dev-deps

test:  ## tier-1 suite (ROADMAP verify command)
	$(PYTHON) -m pytest -x -q

bench-fast:  ## per-figure paper benchmarks, CI-sized
	$(PYTHON) -m benchmarks.run --fast

campaign-smoke:  ## paper campaigns end-to-end (fast) + non-empty summary check
	$(PYTHON) -m repro.data.campaign smoke --out /tmp/repro_io/campaign_smoke

loop-smoke:  ## continuous tuning loop: 2 fast cycles, then resume runs a 3rd
	$(PYTHON) -m repro.service.loop --fast --campaign paper_concurrent \
	    --cycles 2 --min-observations 4 --refit-every 2 \
	    --out-dir /tmp/repro_io/loop_smoke --force
	$(PYTHON) -m repro.service.loop --fast --campaign paper_concurrent \
	    --cycles 3 --min-observations 4 --refit-every 2 \
	    --out-dir /tmp/repro_io/loop_smoke
	$(PYTHON) -m repro.service.loop --status --out-dir /tmp/repro_io/loop_smoke

fleet-smoke:  ## 2-collector fleet, synthetic dry-run rows, then --status
	$(PYTHON) -m repro.service.fleet --collectors 2 --executor synthetic \
	    --fast --campaign paper_concurrent --cycles 2 \
	    --min-observations 4 --refit-every 2 \
	    --out-dir /tmp/repro_io/fleet_smoke --force
	$(PYTHON) -m repro.service.fleet --status --out-dir /tmp/repro_io/fleet_smoke

docs-check:  ## docs CLI references + intra-repo links (tools/docs_check.py)
	$(PYTHON) tools/docs_check.py

dev-deps:  ## test-only dependencies (hypothesis, pytest)
	$(PYTHON) -m pip install -r requirements-dev.txt
