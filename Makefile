PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test bench-fast campaign-smoke dev-deps

test:  ## tier-1 suite (ROADMAP verify command)
	$(PYTHON) -m pytest -x -q

bench-fast:  ## per-figure paper benchmarks, CI-sized
	$(PYTHON) -m benchmarks.run --fast

campaign-smoke:  ## paper campaigns end-to-end (fast) + non-empty summary check
	$(PYTHON) -m repro.data.campaign smoke --out /tmp/repro_io/campaign_smoke

dev-deps:  ## test-only dependencies (hypothesis, pytest)
	$(PYTHON) -m pip install -r requirements-dev.txt
