PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
BENCH_FAST_DIR ?= /tmp/repro_io/bench_fast
BENCH_GATE_FLAGS ?=

.PHONY: test native-check bench-fast bench-gate campaign-smoke loop-smoke fleet-smoke serve-smoke prefetch-smoke chaos-smoke transfer-smoke docs-check dev-deps

test:  ## tier-1 suite (ROADMAP verify command)
	$(PYTHON) -m pytest -x -q

native-check:  ## fail if a C compiler is present but the native tree kernels won't load
	$(PYTHON) tools/native_check.py

bench-fast:  ## per-figure paper benchmarks, CI-sized; leaves fresh BENCH_*.json in $(BENCH_FAST_DIR)
	$(PYTHON) -m benchmarks.run --fast --artifact-dir $(BENCH_FAST_DIR)

bench-gate:  ## compare the fresh fast run in $(BENCH_FAST_DIR) against committed BENCH_*.json (run bench-fast first)
	$(PYTHON) tools/bench_gate.py --fresh $(BENCH_FAST_DIR) $(BENCH_GATE_FLAGS)

campaign-smoke:  ## paper campaigns end-to-end (fast) + non-empty summary check
	$(PYTHON) -m repro.data.campaign smoke --out /tmp/repro_io/campaign_smoke

loop-smoke:  ## continuous tuning loop: 2 fast cycles, then resume runs a 3rd
	$(PYTHON) -m repro.service.loop --fast --campaign paper_concurrent \
	    --cycles 2 --min-observations 4 --refit-every 2 \
	    --out-dir /tmp/repro_io/loop_smoke --force
	$(PYTHON) -m repro.service.loop --fast --campaign paper_concurrent \
	    --cycles 3 --min-observations 4 --refit-every 2 \
	    --out-dir /tmp/repro_io/loop_smoke
	$(PYTHON) -m repro.service.loop --status --out-dir /tmp/repro_io/loop_smoke

fleet-smoke:  ## 2-collector fleet, synthetic dry-run rows, then --status
	$(PYTHON) -m repro.service.fleet --collectors 2 --executor synthetic \
	    --fast --campaign paper_concurrent --cycles 2 \
	    --min-observations 4 --refit-every 2 \
	    --out-dir /tmp/repro_io/fleet_smoke --force
	$(PYTHON) -m repro.service.fleet --status --out-dir /tmp/repro_io/fleet_smoke

serve-smoke:  ## recommendation service: in-process server, all endpoints probed
	$(PYTHON) -m repro.service.serve --smoke
	$(PYTHON) -m repro.service.serve --smoke --no-batch --no-cache

prefetch-smoke:  ## prefetch campaign (fast) + per-policy stall comparison bench
	$(PYTHON) -m repro.data.campaign run --campaign prefetch --fast \
	    --out /tmp/repro_io/prefetch_smoke/prefetch.jsonl --force
	$(PYTHON) -m benchmarks.run --fast --only pipeline

chaos-smoke:  ## chaos-equivalence: fleet under seeded fault injection vs clean run, merged.jsonl must be byte-identical
	$(PYTHON) -m repro.service.fleet --collectors 2 --executor synthetic \
	    --fast --campaign paper_concurrent --cycles 2 \
	    --min-observations 4 --refit-every 2 \
	    --out-dir /tmp/repro_io/chaos_smoke/clean --force
	$(PYTHON) -m repro.service.fleet --collectors 2 --executor synthetic \
	    --fast --campaign paper_concurrent --cycles 2 \
	    --min-observations 4 --refit-every 2 --chaos-seed 123 \
	    --out-dir /tmp/repro_io/chaos_smoke/chaos --force
	cmp /tmp/repro_io/chaos_smoke/clean/merged.jsonl /tmp/repro_io/chaos_smoke/chaos/merged.jsonl
	$(PYTHON) -m repro.service.fleet --status --out-dir /tmp/repro_io/chaos_smoke/chaos
	$(PYTHON) -m repro.service.serve --smoke --chaos-seed 123

transfer-smoke:  ## leave-one-backend-out harness (fast) + one k=5 calibration curve
	$(PYTHON) -m repro.core.transfer --fast --k 0 5 \
	    --out /tmp/repro_io/transfer_smoke/report.json
	$(PYTHON) -m repro.core.transfer --fast --n-per-backend 32 \
	    --models linear ridge --k 0 5 --json > /dev/null

docs-check:  ## docs CLI references + intra-repo links (tools/docs_check.py)
	$(PYTHON) tools/docs_check.py

dev-deps:  ## test-only dependencies (hypothesis, pytest)
	$(PYTHON) -m pip install -r requirements-dev.txt
