"""Fault-tolerant trainer with the paper's autotuner in the loop.

Per step: data-wait (telemetry) -> jit'd train_step -> compute telemetry.
Every ``autotune_every`` steps the OnlineAutotuner ingests the telemetry
window as a new observation, refits its predictor, and — if a reconfiguration
is predicted to beat the current pipeline by >=10% — live-reconfigures the
pipeline (workers / prefetch / block size). This is the paper's contribution
running *inside* the trainer, and doubles as straggler self-mitigation: a
host whose storage degrades re-tunes from its own local telemetry.

Fault tolerance: atomic async checkpoints every ``ckpt_every`` steps,
auto-resume from the latest on start, SIGTERM/SIGINT -> synchronous
emergency save. The data order is a pure function of (seed, epoch, step),
so restarts are batch-exact. Restore is mesh-shape-agnostic (elastic).
"""

from __future__ import annotations

import dataclasses
import pathlib
import signal
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.autotune import ConfigSpace, OnlineAutotuner
from ..checkpoint import CheckpointManager
from ..data.pipeline import DataPipeline
from ..data.telemetry import StepTelemetry
from ..models import ModelConfig, get_api
from ..optim import AdamWConfig
from ..parallel.spec import init_params
from .step import make_train_bundle

__all__ = ["TrainerConfig", "Trainer"]


@dataclasses.dataclass
class TrainerConfig:
    num_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_keep: int = 3
    autotune: bool = True
    autotune_every: int = 10
    log_every: int = 10
    opt: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)
    seed: int = 0


class Trainer:
    def __init__(
        self,
        cfg: ModelConfig,
        pipeline: DataPipeline,
        tcfg: TrainerConfig,
        shape=None,
        make_batch: Optional[Callable] = None,
    ):
        self.cfg = cfg
        self.pipeline = pipeline
        self.tcfg = tcfg
        self.api = get_api(cfg)
        self.telemetry = StepTelemetry(window=max(tcfg.autotune_every, 10))
        self.autotuner = OnlineAutotuner(
            refit_every=tcfg.autotune_every,
            min_observations=8,
            space=ConfigSpace(
                batch_size=(pipeline.config.batch_size,),  # batch fixed by model step
                num_workers=(0, 1, 2, 4),
                block_kb=(16, 64, 256, 1024),
                n_threads=(1,),
                prefetch_depth=(1, 2, 4),
            ),
        )
        self.make_batch = make_batch or self._default_make_batch
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.ckpt_keep)
        self._stop = False

        # jit'd step (local mesh-free path; launch/train.py builds the pjit one)
        def step_fn(state, batch):
            def loss_of(p):
                return self.api.loss_fn(cfg, p, batch)

            loss, grads = jax.value_and_grad(loss_of)(state["params"])
            from ..optim import adamw_update, cosine_schedule

            lr_scale = cosine_schedule(state["step"], 10, tcfg.num_steps)
            new_p, mu, nu, om = adamw_update(
                grads, state["params"], state["mu"], state["nu"], state["step"],
                tcfg.opt, lr_scale,
            )
            return (
                {"params": new_p, "mu": mu, "nu": nu, "step": state["step"] + 1},
                {"loss": loss, **om},
            )

        self._step = jax.jit(step_fn, donate_argnums=(0,))

    # ------------------------------------------------------------------
    def _default_make_batch(self, tokens: np.ndarray) -> Dict[str, Any]:
        inp = tokens[:, :-1]
        lab = tokens[:, 1:]
        return {"tokens": jnp.asarray(inp), "labels": jnp.asarray(lab)}

    def init_state(self):
        specs = self.api.param_specs(self.cfg)
        params = init_params(specs, jax.random.PRNGKey(self.tcfg.seed))
        from ..optim import adamw_init_specs

        mu_s, nu_s = adamw_init_specs(specs, self.tcfg.opt)
        mu = init_params(mu_s, jax.random.PRNGKey(0))
        nu = init_params(nu_s, jax.random.PRNGKey(0))
        return {"params": params, "mu": mu, "nu": nu, "step": jnp.int32(0)}

    # ------------------------------------------------------------------
    def run(self) -> Dict[str, Any]:
        state = self.init_state()
        restored = self.ckpt.restore(state)
        start_step = 0
        if restored is not None:
            state = restored
            start_step = int(state["step"])
            print(f"[trainer] resumed from step {start_step}")

        prev_handlers = {}
        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                prev_handlers[sig] = signal.signal(sig, self._on_signal)
            except ValueError:
                pass  # non-main thread

        history = []
        steps_per_epoch = self.pipeline.steps_per_epoch()
        step = start_step
        try:
            while step < self.tcfg.num_steps and not self._stop:
                epoch = step // steps_per_epoch
                it = self.pipeline.iter_epoch(epoch, start_step=step % steps_per_epoch)
                for tokens in it:
                    if step >= self.tcfg.num_steps or self._stop:
                        it.close()
                        break
                    with self.telemetry.data_wait():
                        batch = self.make_batch(tokens)
                    with self.telemetry.compute():
                        state, metrics = self._step(state, batch)
                        jax.block_until_ready(metrics["loss"])
                    self.telemetry.record_batch(tokens.shape[0], tokens.nbytes)
                    step += 1
                    loss = float(metrics["loss"])
                    history.append(loss)

                    if step % self.tcfg.log_every == 0:
                        print(f"[trainer] step {step} loss {loss:.4f} "
                              f"util {self.telemetry.simulated_utilization():.2%} "
                              f"data_ratio {self.telemetry.data_loading_ratio():.2%}")
                    if self.tcfg.autotune and step % self.tcfg.autotune_every == 0:
                        self._autotune_tick()
                    if step % self.tcfg.ckpt_every == 0:
                        self.ckpt.save(step, state)
        finally:
            self.ckpt.save(step, state, blocking=True)  # emergency/final save
            for sig, h in prev_handlers.items():
                signal.signal(sig, h)
        return {"state": state, "history": history, "final_step": step}

    # ------------------------------------------------------------------
    def _on_signal(self, signum, frame):
        print(f"[trainer] signal {signum}: emergency checkpoint + stop")
        self._stop = True

    def _autotune_tick(self):
        feats = self.telemetry.features(
            batch_size=self.pipeline.config.batch_size,
            num_workers=self.pipeline.config.num_workers,
            block_kb=self.pipeline.config.block_kb,
            prefetch_policy=self.pipeline.config.prefetch_policy,
            lookahead_batches=self.pipeline.config.lookahead_batches,
            cache_budget_mb=self.pipeline.config.cache_budget_mb,
        )
        self.autotuner.observe(feats, feats["throughput_mb_s"])
        self.autotuner.maybe_refit()
        current = {
            "batch_size": self.pipeline.config.batch_size,
            "num_workers": self.pipeline.config.num_workers,
            "block_kb": self.pipeline.config.block_kb,
            "prefetch_depth": self.pipeline.config.prefetch_depth,
            "prefetch_policy": feats["prefetch_policy"],  # numeric code
            "lookahead_batches": self.pipeline.config.lookahead_batches,
            "cache_budget_mb": self.pipeline.config.cache_budget_mb,
        }
        decision = self.autotuner.decide(current, feats)
        if decision.reconfigure:
            knobs = {k: v for k, v in decision.config.items()
                     if k in ("num_workers", "block_kb", "prefetch_depth",
                              "prefetch_policy", "lookahead_batches",
                              "cache_budget_mb")}
            print(f"[autotune] reconfiguring pipeline: {knobs} "
                  f"(predicted +{decision.predicted_gain:.0%})")
            self.pipeline.reconfigure(**knobs)
