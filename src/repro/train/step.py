"""train_step / serve_step builders: pure functions + their sharding specs.

The returned functions close over (cfg, api, ctx) and take explicit state so
they lower under pjit with in/out shardings derived from the logical rules.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..models import ModelConfig, ShardCtx, get_api
from ..optim import AdamWConfig, adamw_init_specs, adamw_update, cosine_schedule
from ..parallel.rules import make_rules, mesh_dp_axes
from ..parallel.spec import Rules, abstract_params, partition_spec, tree_partition_specs

__all__ = ["StepBundle", "make_train_bundle", "make_serve_bundle", "make_prefill_bundle"]


@dataclasses.dataclass
class StepBundle:
    """A lowered-able step: fn + abstract inputs + in/out shardings."""

    fn: Callable
    in_shardings: Any
    out_shardings: Any
    abstract_inputs: Tuple
    donate_argnums: Tuple[int, ...] = ()
    ctx: Optional[ShardCtx] = None


def _batch_pspec(inputs: Dict[str, jax.ShapeDtypeStruct], rules: Rules):
    """Batch arrays shard on their leading batch dim."""
    out = {}
    for k, v in inputs.items():
        if k == "pos":
            out[k] = P()
        else:
            out[k] = partition_spec(("batch",) + (None,) * (len(v.shape) - 1), rules)
    return out


def make_train_bundle(
    cfg: ModelConfig,
    shape,
    mesh=None,
    multi_pod: bool = False,
    opt: Optional[AdamWConfig] = None,
    rules: Optional[Rules] = None,
    total_steps: int = 10_000,
    warmup: int = 200,
    accum_steps: int = 1,
) -> StepBundle:
    """``accum_steps > 1`` enables gradient accumulation: the global batch is
    split into microbatches scanned sequentially (f32 grad accumulator), so
    per-device activation memory scales down ~accum_steps x at identical
    optimizer semantics — the standard lever for fitting long-sequence train
    steps in HBM."""
    from ..configs import input_specs  # local import to avoid cycle

    api = get_api(cfg)
    opt = opt or AdamWConfig()
    rules = rules or make_rules(cfg, "train", shape.global_batch, multi_pod)
    ctx = ShardCtx(mesh=mesh, rules=rules, dp_axes=mesh_dp_axes(multi_pod))

    pspecs = api.param_specs(cfg)
    mu_specs, nu_specs = adamw_init_specs(pspecs, opt)
    state_specs = {"params": pspecs, "mu": mu_specs, "nu": nu_specs}
    state_pspec = {
        **tree_partition_specs(state_specs, rules),
        "step": P(),
    }
    spec = input_specs(cfg, shape)
    batch_abstract = spec["inputs"]
    batch_pspec = _batch_pspec(batch_abstract, rules)

    def _grads_of(params, batch):
        if accum_steps <= 1:
            return jax.value_and_grad(
                lambda p: api.loss_fn(cfg, p, batch, ctx)
            )(params)

        def split(x):  # [B, ...] -> [accum, B/accum, ...]
            assert x.shape[0] % accum_steps == 0, (x.shape, accum_steps)
            return x.reshape(accum_steps, x.shape[0] // accum_steps, *x.shape[1:])

        micro = jax.tree.map(split, batch)
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )

        def body(carry, mb):
            lsum, gsum = carry
            loss, grads = jax.value_and_grad(
                lambda p: api.loss_fn(cfg, p, mb, ctx)
            )(params)
            gsum = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), gsum, grads)
            return (lsum + loss, gsum), None

        (lsum, gsum), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), zeros), micro,
            unroll=True if cfg.unroll_scans else 1,
        )
        inv = 1.0 / accum_steps
        return lsum * inv, jax.tree.map(lambda g: g * inv, gsum)

    def train_step(state, batch):
        loss, grads = _grads_of(state["params"], batch)
        lr_scale = cosine_schedule(state["step"], warmup, total_steps)
        new_params, new_mu, new_nu, om = adamw_update(
            grads, state["params"], state["mu"], state["nu"], state["step"], opt, lr_scale
        )
        new_state = {
            "params": new_params,
            "mu": new_mu,
            "nu": new_nu,
            "step": state["step"] + 1,
        }
        return new_state, {"loss": loss, **om}

    abstract_state = {
        "params": abstract_params(pspecs),
        "mu": abstract_params(mu_specs),
        "nu": abstract_params(nu_specs),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }
    return StepBundle(
        fn=train_step,
        in_shardings=(state_pspec, batch_pspec),
        out_shardings=(state_pspec, {"loss": P(), "grad_norm": P()}),
        abstract_inputs=(abstract_state, batch_abstract),
        donate_argnums=(0,),
        ctx=ctx,
    )


def make_serve_bundle(
    cfg: ModelConfig, shape, mesh=None, multi_pod: bool = False,
    rules: Optional[Rules] = None,
) -> StepBundle:
    from ..configs import input_specs

    api = get_api(cfg)
    rules = rules or make_rules(cfg, "decode", shape.global_batch, multi_pod)
    ctx = ShardCtx(mesh=mesh, rules=rules, dp_axes=mesh_dp_axes(multi_pod))
    spec = input_specs(cfg, shape)
    cache_pspec = tree_partition_specs(spec["cache_specs"], rules)
    pspecs = api.param_specs(cfg)
    param_pspec = tree_partition_specs(pspecs, rules)
    logits_pspec = partition_spec(("batch", "vocab"), rules)

    def serve_step(params, cache, token, pos):
        logits, new_cache = api.decode_step(cfg, params, cache, token, pos, ctx)
        return logits, new_cache

    tok_pspec = partition_spec(("batch", None), rules)
    return StepBundle(
        fn=serve_step,
        in_shardings=(param_pspec, cache_pspec, tok_pspec, P()),
        out_shardings=(logits_pspec, cache_pspec),
        abstract_inputs=(
            abstract_params(pspecs),
            spec["cache"],
            spec["inputs"]["token"],
            spec["inputs"]["pos"],
        ),
        donate_argnums=(1,),
        ctx=ctx,
    )


def make_prefill_bundle(
    cfg: ModelConfig, shape, mesh=None, multi_pod: bool = False,
    rules: Optional[Rules] = None,
) -> StepBundle:
    from ..configs import input_specs

    api = get_api(cfg)
    rules = rules or make_rules(cfg, "prefill", shape.global_batch, multi_pod)
    ctx = ShardCtx(mesh=mesh, rules=rules, dp_axes=mesh_dp_axes(multi_pod))
    spec = input_specs(cfg, shape)
    pspecs = api.param_specs(cfg)
    param_pspec = tree_partition_specs(pspecs, rules)
    inputs = spec["inputs"]
    in_pspec = _batch_pspec(inputs, rules)
    logits_pspec = partition_spec(("batch", "vocab"), rules)

    if cfg.family == "encdec":
        def prefill_fn(params, frames):
            return api.prefill(cfg, params, frames, ctx)
        abstract = (abstract_params(pspecs), inputs["frames"])
        in_sh = (param_pspec, in_pspec["frames"])
    elif cfg.family == "vlm":
        def prefill_fn(params, prefix_embeds, tokens):
            from ..models import transformer
            return transformer.prefill(cfg, params, tokens, ctx, prefix_embeds=prefix_embeds)
        abstract = (abstract_params(pspecs), inputs["prefix_embeds"], inputs["tokens"])
        in_sh = (param_pspec, in_pspec["prefix_embeds"], in_pspec["tokens"])
    else:
        def prefill_fn(params, tokens):
            return api.prefill(cfg, params, tokens, ctx)
        abstract = (abstract_params(pspecs), inputs["tokens"])
        in_sh = (param_pspec, in_pspec["tokens"])

    return StepBundle(
        fn=prefill_fn,
        in_shardings=in_sh,
        out_shardings=logits_pspec,
        abstract_inputs=abstract,
        ctx=ctx,
    )
