"""repro.train — step builders and the fault-tolerant trainer."""

from .step import StepBundle, make_prefill_bundle, make_serve_bundle, make_train_bundle  # noqa: F401
from .trainer import Trainer, TrainerConfig  # noqa: F401
