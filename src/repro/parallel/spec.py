"""Logical-axis sharding system (MaxText-style, hand-rolled).

Every parameter/activation declares *logical* axes ("vocab", "embed", "mlp",
"heads", "expert", "batch", "seq", ...). A ``Rules`` mapping assigns logical
axes to mesh axes; changing the mapping re-shards the whole model — this is
the main hillclimbing lever, no model code changes needed.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

__all__ = ["ParamSpec", "Rules", "DEFAULT_RULES", "POD_RULES", "partition_spec",
           "tree_partition_specs", "abstract_params", "init_params", "logical_constraint"]

MeshAxes = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Declarative parameter: shape + dtype + logical axes + init scale."""

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    dtype: Any = jnp.bfloat16
    init: str = "normal"  # normal | zeros | ones | mamba_a | conv
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


@dataclasses.dataclass(frozen=True)
class Rules:
    """logical axis -> mesh axis (or tuple of mesh axes, or None=replicated)."""

    table: Tuple[Tuple[str, MeshAxes], ...]

    @classmethod
    def make(cls, **kw: MeshAxes) -> "Rules":
        return cls(tuple(sorted(kw.items())))

    def get(self, logical: Optional[str]) -> MeshAxes:
        if logical is None:
            return None
        for k, v in self.table:
            if k == logical:
                return v
        return None

    def replace(self, **kw: MeshAxes) -> "Rules":
        d = dict(self.table)
        d.update(kw)
        return Rules(tuple(sorted(d.items())))


# Baseline rules: DP over (pod, data); TP/EP/SP over model.
DEFAULT_RULES = Rules.make(
    batch=("data",),
    expert="model",
    heads="model",
    kv_heads="model",
    mlp="model",
    vocab="model",
    embed=None,
    seq=None,
    kv_seq="model",     # decode KV-cache sequence sharding (MQA/GQA fallback)
    act_seq=None,       # activation sequence dim (SP hillclimb lever)
    state=None,
    layers=None,
    conv=None,
    capacity=None,
    frames=None,
)

POD_RULES = DEFAULT_RULES.replace(batch=("pod", "data"))


def partition_spec(axes: Sequence[Optional[str]], rules: Rules) -> P:
    mesh_axes = []
    used: set = set()
    for a in axes:
        m = rules.get(a)
        if m is None:
            mesh_axes.append(None)
            continue
        ms = (m,) if isinstance(m, str) else tuple(m)
        ms = tuple(x for x in ms if x not in used)
        used.update(ms)
        if not ms:
            mesh_axes.append(None)
        elif len(ms) == 1:
            mesh_axes.append(ms[0])
        else:
            mesh_axes.append(ms)
    # strip trailing Nones for tidiness
    while mesh_axes and mesh_axes[-1] is None:
        mesh_axes.pop()
    return P(*mesh_axes)


def tree_partition_specs(spec_tree, rules: Rules):
    return jax.tree.map(
        lambda s: partition_spec(s.axes, rules),
        spec_tree,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def abstract_params(spec_tree):
    """ShapeDtypeStruct tree — used by the dry-run (no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
        spec_tree,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def _init_one(key, s: ParamSpec):
    if s.init == "zeros":
        return jnp.zeros(s.shape, s.dtype)
    if s.init == "ones":
        return jnp.ones(s.shape, s.dtype)
    if s.init == "mamba_a":
        # mamba A_log init: log(1..d_state) broadcast
        n = s.shape[-1]
        a = jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32))
        return jnp.broadcast_to(a, s.shape).astype(s.dtype)
    fan_in = s.shape[0] if len(s.shape) >= 2 else max(s.shape[0], 1)
    std = s.scale / np.sqrt(fan_in)
    return (jax.random.normal(key, s.shape, jnp.float32) * std).astype(s.dtype)


def init_params(spec_tree, key):
    leaves, treedef = jax.tree.flatten(
        spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec)
    )
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef, [_init_one(k, s) for k, s in zip(keys, leaves)])


def logical_constraint(x, axes: Sequence[Optional[str]], rules: Optional[Rules]):
    """with_sharding_constraint by logical axes (no-op outside pjit/mesh)."""
    if rules is None:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, partition_spec(axes, rules))
    except (ValueError, RuntimeError) as e:
        # no mesh in scope (single-device unit tests) or indivisible dim
        if "mesh" in str(e) or "divisible" in str(e) or isinstance(e, ValueError):
            return x
        raise
