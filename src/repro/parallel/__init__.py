"""repro.parallel — logical-axis sharding rules and param specs."""

from .rules import make_rules, mesh_dp_axes  # noqa: F401
from .spec import (  # noqa: F401
    DEFAULT_RULES,
    POD_RULES,
    ParamSpec,
    Rules,
    abstract_params,
    init_params,
    logical_constraint,
    partition_spec,
    tree_partition_specs,
)
