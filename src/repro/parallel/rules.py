"""Per-(arch × shape × mesh) sharding-rule selection.

This is the baseline policy; hillclimbing (EXPERIMENTS.md §Perf) perturbs the
returned Rules. Policy:

- batch   -> all DP axes ("pod","data") when the global batch divides; else None
- heads / kv_heads -> "model" when divisible by TP (heads_tp archs)
- act_seq -> "model" for seq_tp archs on train/prefill (sequence parallelism)
- kv_seq  -> decode-cache sequence sharding when kv heads are unshardable;
             spreads over idle DP axes too when batch == 1 (long-context)
- mlp     -> "model" (TP); over ("data","model") for batch-1 SSM decode
- expert  -> "model" (EP)
- vocab   -> "model"
"""

from __future__ import annotations

from typing import Tuple

from ..models.config import ModelConfig
from .spec import Rules

__all__ = ["make_rules", "mesh_dp_axes"]


def mesh_dp_axes(multi_pod: bool) -> Tuple[str, ...]:
    return ("pod", "data") if multi_pod else ("data",)


def make_rules(
    cfg: ModelConfig,
    kind: str,  # train | prefill | decode
    global_batch: int,
    multi_pod: bool = False,
    tp: int = 16,
    dp: int = 16,
) -> Rules:
    dp_axes = mesh_dp_axes(multi_pod)
    n_dp = dp * (2 if multi_pod else 1)
    batch_axes = dp_axes if global_batch % n_dp == 0 else None

    heads_ok = cfg.n_heads > 0 and cfg.n_heads % tp == 0
    kv_ok = cfg.n_kv_heads > 0 and cfg.n_kv_heads % tp == 0
    seq_tp = cfg.attn_mode == "seq_tp" and kind in ("train", "prefill")

    r = dict(
        batch=batch_axes,
        vocab="model",
        embed=None,
        mlp="model",
        expert="model",
        layers=None,
        state=None,
        heads=("model" if heads_ok and not seq_tp else None),
        kv_heads=("model" if kv_ok and not seq_tp else None),
        act_seq=("model" if seq_tp else None),
        kv_seq=None,
        capacity=None,
        frames=None,
        conv=None,
    )

    if kind == "decode":
        # cache sequence sharding when kv heads can't use the model axis
        if not kv_ok:
            r["kv_seq"] = ("data", "model") if batch_axes is None else "model"
        if batch_axes is None and cfg.family == "ssm":
            # batch-1 SSM decode: spread channels over every axis
            r["mlp"] = (("pod", "data", "model") if multi_pod else ("data", "model"))
    return Rules.make(**r)
