"""Mamba selective-scan chunk kernel (TPU adaptation of the CUDA fused scan).

Contract: projections (dt/B/C) happen outside (they contract over the full
d_inner and stay cheap); the kernel consumes dt, B, C, x and forms the gates
``a = exp(dt*A)`` and ``b = dt*B*x`` IN REGISTERS — the [S, di, ds] gate
tensors never touch HBM. HBM traffic is exactly the kernel operands:
x, dt (di-wide), B, C (ds-wide), y out — ~10 bytes/element of [S, di] vs
the reference lowering's ~100s (see EXPERIMENTS.md §Perf T1).

Grid: (batch, di_blocks, chunks); the chunk axis is innermost/sequential on
TPU, so the recurrence state h [di_block, ds] lives in VMEM scratch across
chunk steps. Within a chunk the recurrence runs as a fori_loop of VPU ops.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(x_ref, dt_ref, b_ref, c_ref, a_log_ref, d_ref, o_ref, h_scr,
                 *, chunk: int, ds: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0].astype(jnp.float32)  # [chunk, dib]
    dt = dt_ref[0].astype(jnp.float32)  # [chunk, dib]
    B = b_ref[0].astype(jnp.float32)  # [chunk, ds]
    C = c_ref[0].astype(jnp.float32)  # [chunk, ds]
    A = -jnp.exp(a_log_ref[0].astype(jnp.float32))  # [dib, ds]
    D = d_ref[0].astype(jnp.float32)  # [dib]

    def step(t, carry):
        h, y = carry  # h: [dib, ds]; y: [chunk, dib]
        dt_t = jax.lax.dynamic_slice_in_dim(dt, t, 1, 0)[0]  # [dib]
        x_t = jax.lax.dynamic_slice_in_dim(x, t, 1, 0)[0]
        B_t = jax.lax.dynamic_slice_in_dim(B, t, 1, 0)[0]  # [ds]
        C_t = jax.lax.dynamic_slice_in_dim(C, t, 1, 0)[0]
        a_t = jnp.exp(dt_t[:, None] * A)  # [dib, ds] — in registers
        b_t = (dt_t * x_t)[:, None] * B_t[None, :]
        h = a_t * h + b_t
        y_t = (h * C_t[None, :]).sum(axis=1) + D * x_t  # [dib]
        y = jax.lax.dynamic_update_slice_in_dim(y, y_t[None], t, 0)
        return h, y

    h0 = h_scr[...]
    y0 = jnp.zeros_like(x)
    h_end, y = jax.lax.fori_loop(0, chunk, step, (h0, y0))
    h_scr[...] = h_end
    o_ref[0] = y.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("chunk", "di_block", "interpret"),
)
def mamba_scan(x, dt, B, C, a_log, d_skip, *, chunk: int = 64,
               di_block: int = 256, interpret: bool = False):
    """x, dt: [b, S, di]; B, C: [b, S, ds]; a_log: [di, ds]; d_skip: [di].
    Returns y [b, S, di] f32-accurate selective scan output."""
    b, S, di = x.shape
    ds = B.shape[-1]
    chunk = min(chunk, S)
    di_block = min(di_block, di)
    assert S % chunk == 0 and di % di_block == 0
    grid = (b * (di // di_block), 1, S // chunk)  # flat (batch x di-block)

    # reshape to expose (batch*di_block) grid axis
    xr = x.reshape(b, S, di // di_block, di_block).transpose(0, 2, 1, 3) \
         .reshape(b * (di // di_block), S, di_block)
    dtr = dt.reshape(b, S, di // di_block, di_block).transpose(0, 2, 1, 3) \
         .reshape(b * (di // di_block), S, di_block)
    Br = jnp.repeat(B, di // di_block, axis=0).reshape(b * (di // di_block), S, ds) \
        if di // di_block > 1 else B
    Cr = jnp.repeat(C, di // di_block, axis=0).reshape(b * (di // di_block), S, ds) \
        if di // di_block > 1 else C
    a_log_r = a_log.reshape(di // di_block, di_block, ds)
    d_r = d_skip.reshape(di // di_block, di_block)
    n_dib = di // di_block

    out = pl.pallas_call(
        functools.partial(_scan_kernel, chunk=chunk, ds=ds),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, di_block), lambda g, _, ci: (g, ci, 0)),
            pl.BlockSpec((1, chunk, di_block), lambda g, _, ci: (g, ci, 0)),
            pl.BlockSpec((1, chunk, ds), lambda g, _, ci: (g, ci, 0)),
            pl.BlockSpec((1, chunk, ds), lambda g, _, ci: (g, ci, 0)),
            pl.BlockSpec((1, di_block, ds), lambda g, _, ci, n=n_dib: (g % n, 0, 0)),
            pl.BlockSpec((1, di_block), lambda g, _, ci, n=n_dib: (g % n, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, di_block), lambda g, _, ci: (g, ci, 0)),
        out_shape=jax.ShapeDtypeStruct(xr.shape, jnp.float32),
        scratch_shapes=[pltpu.VMEM((di_block, ds), jnp.float32)],
        interpret=interpret,
    )(xr, dtr, Br, Cr, a_log_r.reshape(n_dib, di_block, ds), d_r)

    y = out.reshape(b, n_dib, S, di_block).transpose(0, 2, 1, 3).reshape(b, S, di)
    return y


def analytic_hbm_bytes(b: int, S: int, di: int, ds: int,
                       in_dtype_bytes: int = 2) -> int:
    """Per-call HBM traffic of the kernel (operands only; gates in VMEM)."""
    return (
        b * S * di * (in_dtype_bytes + 4)  # x (in dtype) + dt f32
        + 2 * b * S * ds * 4               # B, C
        + b * S * di * 4                   # y out f32
        + di * ds * 4 + di * 4             # A_log, D
    )
