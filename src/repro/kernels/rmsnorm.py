"""Fused RMSNorm kernel: one HBM read of x, one write of y (the XLA reference
lowering round-trips the normalized intermediate). Row-tiled BlockSpec; the
full hidden dim stays resident in VMEM (d_model <= ~8k rows fit easily)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, scale, *, eps: float = 1e-6, block_rows: int = 256, interpret: bool = False):
    """x: [..., D]; scale: [D]."""
    orig_shape = x.shape
    D = x.shape[-1]
    x2 = x.reshape(-1, D)
    N = x2.shape[0]
    block_rows = min(block_rows, N)
    pad = (-N) % block_rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    n_blocks = x2.shape[0] // block_rows

    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, scale)
    if pad:
        out = out[:N]
    return out.reshape(orig_shape)
