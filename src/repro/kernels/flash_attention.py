"""Flash attention for TPU (pl.pallas_call + BlockSpec VMEM tiling).

Design (TPU-native, not a CUDA port):
- grid = (batch*kv_heads*q_groups, n_q_blocks, n_kv_blocks); the kv-block axis
  is the innermost, sequentially-executed grid dimension on TPU, so the
  online-softmax state (m, l, acc) lives in VMEM scratch and persists across
  kv steps — no HBM round-trip for scores, exactly the flash recurrence.
- BlockSpecs stream one (q_block x d) and one (kv_block x d) tile at a time;
  MXU-aligned block sizes (multiples of 128 on the matmul dims).
- Masks: causal, sliding-window, prefix-LM — computed from global indices.

The reference oracle is ref.py::attention_reference; tests sweep shapes and
dtypes in interpret mode (CPU) against it.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, causal: bool, window: Optional[int],
                 prefix: Optional[int], q_block: int, kv_block: int,
                 n_kv: int, seq_q: int, seq_kv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale  # [q_block, d]
    k = k_ref[0].astype(jnp.float32)  # [kv_block, d]
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [q_block, kv_block]

    q_pos = qi * q_block + jax.lax.broadcasted_iota(jnp.int32, (q_block, kv_block), 0)
    k_pos = ki * kv_block + jax.lax.broadcasted_iota(jnp.int32, (q_block, kv_block), 1)
    ok = jnp.ones((q_block, kv_block), jnp.bool_)
    if causal:
        allowed = k_pos <= q_pos
        if prefix is not None:
            allowed = allowed | (k_pos < prefix)
        ok &= allowed
    if window is not None:
        ok &= k_pos > q_pos - window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ()))
    )
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == n_kv - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "prefix", "scale", "q_block", "kv_block", "interpret"),
)
def flash_attention(
    q, k, v, *,
    causal: bool = True,
    window: Optional[int] = None,
    prefix: Optional[int] = None,
    scale: Optional[float] = None,
    q_block: int = 256,
    kv_block: int = 256,
    interpret: bool = False,
):
    """q: [B, Sq, H, D]; k/v: [B, Skv, KVH, D] -> [B, Sq, H, D].

    GQA handled by folding the group into the batch*head grid axis.
    """
    B, Sq, H, D = q.shape
    Skv, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    scale = float(scale if scale is not None else D ** -0.5)
    q_block = min(q_block, Sq)
    kv_block = min(kv_block, Skv)
    assert Sq % q_block == 0 and Skv % kv_block == 0

    # [B, S, H, D] -> [B*H, S, D] with H-major grouping matching kv heads
    qg = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kg = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1).reshape(B * H, Skv, D)
    vg = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1).reshape(B * H, Skv, D)

    n_q = Sq // q_block
    n_kv = Skv // kv_block
    grid = (B * H, n_q, n_kv)

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window, prefix=prefix,
        q_block=q_block, kv_block=kv_block, n_kv=n_kv, seq_q=Sq, seq_kv=Skv,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q_block, D), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, kv_block, D), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, kv_block, D), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_block, D), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block, D), jnp.float32),
        ],
        interpret=interpret,
    )(qg, kg, vg)
    return out.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)
