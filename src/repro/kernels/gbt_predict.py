"""Batched GBT-ensemble inference kernel (the paper's hot path: scoring 10^4+
candidate configurations per autotune sweep).

TPU adaptation: tree descent is gather-heavy on GPU; TPUs prefer dense math.
Each descent step is re-expressed as ONE-HOT matmuls against the node tables
(node one-hot [rows, nodes] x table [nodes] -> per-row attribute), so the
whole kernel is MXU/VPU-friendly with zero gathers. Tree tables are small
(100 trees x 127 nodes) and stay VMEM-resident; the tree axis is the
innermost sequential grid dim with a per-row accumulator in VMEM scratch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gbt_kernel(x_ref, feat_ref, thr_ref, left_ref, right_ref, val_ref,
                o_ref, acc_scr, *, max_depth: int, n_trees: int, n_nodes: int,
                base_score: float, scale: float):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[...].astype(jnp.float32)  # [rows, F]
    rows, F = x.shape
    feat = feat_ref[0].astype(jnp.float32)  # [nodes] (float for one-hot dots)
    thr = thr_ref[0]
    left = left_ref[0].astype(jnp.float32)
    right = right_ref[0].astype(jnp.float32)
    val = val_ref[0]

    node_iota = jax.lax.broadcasted_iota(jnp.float32, (rows, n_nodes), 1)
    feat_iota = jax.lax.broadcasted_iota(jnp.float32, (rows, F), 1)

    idx = jnp.zeros((rows,), jnp.float32)  # node index per row (as float)
    for _ in range(max_depth + 1):
        oh = (node_iota == idx[:, None]).astype(jnp.float32)  # [rows, nodes]
        fi = oh @ feat  # [rows] feature index (or -1 at leaves)
        ti_ = oh @ thr
        li = oh @ left
        ri = oh @ right
        leaf = fi < 0.0
        f_oh = (feat_iota == jnp.maximum(fi, 0.0)[:, None]).astype(jnp.float32)
        fx = jnp.sum(x * f_oh, axis=1)
        nxt = jnp.where(fx <= ti_, li, ri)
        idx = jnp.where(leaf, idx, nxt)

    oh = (node_iota == idx[:, None]).astype(jnp.float32)
    acc_scr[...] = acc_scr[...] + oh @ val

    @pl.when(ti == n_trees - 1)
    def _finish():
        o_ref[...] = (base_score + scale * acc_scr[...]).astype(o_ref.dtype)


def gbt_predict_ensemble(ens, X, *, row_block: int = 256, interpret: bool = False):
    """Score a ``PackedEnsemble`` with the one-hot-matmul kernel.

    Convenience wrapper used by ``autotune.recommend``'s mega-grid path: the
    ensemble's node tables and affine output transform map 1:1 onto the kernel
    arguments, so callers never unpack the dataclass by hand.  ``interpret=True``
    runs the same kernel through the Pallas interpreter off-TPU (the oracle
    tests exercise it on CPU)."""
    return gbt_predict(
        X, ens.feature, ens.threshold, ens.left, ens.right, ens.value,
        max_depth=ens.max_depth, base_score=ens.base_score, scale=ens.scale,
        row_block=row_block, interpret=interpret,
    )


@functools.partial(
    jax.jit,
    static_argnames=("max_depth", "base_score", "scale", "row_block", "interpret"),
)
def gbt_predict(
    X, feature, threshold, left, right, value, *,
    max_depth: int, base_score: float = 0.0, scale: float = 1.0,
    row_block: int = 256, interpret: bool = False,
):
    """X: [N, F] f32; tree tables: [T, nodes]. Returns [N] f32 predictions."""
    X = jnp.asarray(X, jnp.float32)
    N, F = X.shape
    T, n_nodes = feature.shape
    row_block = min(row_block, N)
    pad = (-N) % row_block
    if pad:
        X = jnp.pad(X, ((0, pad), (0, 0)))
    n_row_blocks = X.shape[0] // row_block

    out = pl.pallas_call(
        functools.partial(
            _gbt_kernel, max_depth=max_depth, n_trees=T, n_nodes=n_nodes,
            base_score=float(base_score), scale=float(scale),
        ),
        grid=(n_row_blocks, T),
        in_specs=[
            pl.BlockSpec((row_block, F), lambda ri, ti: (ri, 0)),
            pl.BlockSpec((1, n_nodes), lambda ri, ti: (ti, 0)),
            pl.BlockSpec((1, n_nodes), lambda ri, ti: (ti, 0)),
            pl.BlockSpec((1, n_nodes), lambda ri, ti: (ti, 0)),
            pl.BlockSpec((1, n_nodes), lambda ri, ti: (ti, 0)),
            pl.BlockSpec((1, n_nodes), lambda ri, ti: (ti, 0)),
        ],
        out_specs=pl.BlockSpec((row_block,), lambda ri, ti: (ri,)),
        out_shape=jax.ShapeDtypeStruct((X.shape[0],), jnp.float32),
        scratch_shapes=[pltpu.VMEM((row_block,), jnp.float32)],
        interpret=interpret,
    )(X, feature.astype(jnp.int32), threshold.astype(jnp.float32),
      left.astype(jnp.int32), right.astype(jnp.int32), value.astype(jnp.float32))
    return out[:N]
