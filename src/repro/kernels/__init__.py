"""repro.kernels — Pallas TPU kernels (validated in interpret mode on CPU).

flash_attention: dominant FLOP hot-spot of every transformer cell.
rmsnorm:        fused memory-bound norm.
gbt_predict:    the paper's hot path — batched ensemble inference for
                autotune sweeps, one-hot-matmul descent (gather-free).
"""

from .ops import flash_attention_op, gbt_predict_op, rmsnorm_op  # noqa: F401
