"""jit'd public wrappers for the Pallas kernels.

On this CPU container the kernels run with ``interpret=True`` (the Pallas
interpreter executes the kernel body in Python — exact semantics, no TPU).
On TPU set ``REPRO_KERNEL_INTERPRET=0`` (or pass interpret=False) to compile
the real Mosaic kernels.
"""

from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention as _flash
from .gbt_predict import gbt_predict as _gbt
from .rmsnorm import rmsnorm as _rmsnorm


def _default_interpret() -> bool:
    env = os.environ.get("REPRO_KERNEL_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def flash_attention_op(q, k, v, *, causal=True, window=None, prefix=None,
                       scale=None, q_block=256, kv_block=256, interpret=None):
    return _flash(q, k, v, causal=causal, window=window, prefix=prefix,
                  scale=scale, q_block=q_block, kv_block=kv_block,
                  interpret=_default_interpret() if interpret is None else interpret)


def rmsnorm_op(x, scale, *, eps=1e-6, block_rows=256, interpret=None):
    return _rmsnorm(x, scale, eps=eps, block_rows=block_rows,
                    interpret=_default_interpret() if interpret is None else interpret)


def gbt_predict_op(X, ensemble, *, row_block=256, interpret=None):
    """ensemble: core.ensemble_base.PackedEnsemble."""
    return _gbt(
        jnp.asarray(X, jnp.float32),
        ensemble.feature, ensemble.threshold, ensemble.left, ensemble.right,
        ensemble.value, max_depth=ensemble.max_depth,
        base_score=float(ensemble.base_score), scale=float(ensemble.scale),
        row_block=row_block,
        interpret=_default_interpret() if interpret is None else interpret,
    )
