"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def attention_reference(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None,
                        prefix: Optional[int] = None,
                        scale: Optional[float] = None):
    """Dense softmax attention with the same masks as the kernel."""
    B, Sq, H, D = q.shape
    Skv, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    scale = float(scale if scale is not None else D ** -0.5)
    q5 = q.reshape(B, Sq, KVH, G, D).astype(jnp.float32) * scale
    s = jnp.einsum("bqhgd,bshd->bhgqs", q5, k.astype(jnp.float32))
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Skv)[None, :]
    ok = jnp.ones((Sq, Skv), bool)
    if causal:
        allowed = kp <= qp
        if prefix is not None:
            allowed = allowed | (kp < prefix)
        ok &= allowed
    if window is not None:
        ok &= kp > qp - window
    s = jnp.where(ok[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqs,bshd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, D).astype(q.dtype)


def rmsnorm_reference(x, scale, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def gbt_predict_reference(X, feature, threshold, left, right, value,
                          max_depth: int, base_score: float, scale: float):
    """Dense-array ensemble descent (matches core.ensemble_base semantics).

    X: [N, F] f32; tree arrays: [T, nodes].
    """
    X = jnp.asarray(X, jnp.float32)
    N = X.shape[0]
    T = feature.shape[0]

    def one_tree(f, thr, l, r, val):
        idx = jnp.zeros(N, jnp.int32)
        for _ in range(max_depth + 1):
            fi = f[idx]
            leaf = fi < 0
            fx = jnp.take_along_axis(X, jnp.maximum(fi, 0)[:, None], axis=1)[:, 0]
            nxt = jnp.where(fx <= thr[idx], l[idx], r[idx])
            idx = jnp.where(leaf, idx, nxt)
        return val[idx]

    per_tree = jax.vmap(one_tree)(feature, threshold, left, right, value)
    return base_score + scale * per_tree.sum(axis=0)
