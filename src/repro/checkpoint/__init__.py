"""repro.checkpoint — async, atomic, retention-managed checkpointing."""

from .manager import CheckpointManager, load_latest, restore_tree, save_tree  # noqa: F401
