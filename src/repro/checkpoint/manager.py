"""Checkpointing for fault tolerance at pod scale.

Design:
- **atomic**: write to ``step_XXXX.tmp/`` then ``os.rename`` — a crash never
  leaves a half checkpoint visible; restore scans only committed dirs.
- **async**: device->host transfer happens on the caller thread (cheap),
  serialization+fsync on a background thread so the train loop never blocks.
- **sharded / multi-host**: each process writes only its addressable shards
  (``process_<i>.npz``); restore concatenates. On this single-process
  container that is one file, but the layout is pod-ready.
- **elastic**: arrays are saved UNSHARDED (logical layout) with the logical
  PartitionSpec stored alongside, so a restart may use a different mesh
  shape — resharding happens at device_put time.
- **retention**: keep the newest ``keep`` checkpoints.
"""

from __future__ import annotations

import json
import os
import pathlib
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = np.asarray(leaf)
        # npz can't round-trip ml_dtypes (bf16 loads back as void): store such
        # leaves as f32 (exact upcast from bf16); restore casts back.
        if arr.dtype.kind == "V" or str(arr.dtype) in ("bfloat16", "float8_e4m3fn",
                                                       "float8_e5m2"):
            arr = arr.astype(np.float32)
        out[key] = arr
    return out


def save_tree(tree, directory: pathlib.Path, process_index: int = 0):
    directory.mkdir(parents=True, exist_ok=True)
    arrays = _flatten(tree)
    np.savez(directory / f"process_{process_index}.npz", **arrays)


def restore_tree(template, directory: pathlib.Path, process_index: int = 0):
    """Restore into the structure of ``template`` (values replaced)."""
    data = np.load(directory / f"process_{process_index}.npz")
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = data[key]
        if hasattr(leaf, "dtype"):
            # cast back through jnp so ml_dtypes (bf16) round-trip
            import jax.numpy as jnp

            leaves.append(jnp.asarray(arr).astype(leaf.dtype))
        else:
            leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def load_latest(root: pathlib.Path) -> Optional[pathlib.Path]:
    if not root.exists():
        return None
    steps = []
    for d in root.iterdir():
        m = _STEP_RE.match(d.name)
        if m and d.is_dir():
            steps.append((int(m.group(1)), d))
    return max(steps)[1] if steps else None


class CheckpointManager:
    def __init__(self, root, keep: int = 3, process_index: int = 0):
        self.root = pathlib.Path(root)
        self.keep = keep
        self.process_index = process_index
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save ---------------------------------------------------------
    def save(self, step: int, tree, blocking: bool = False):
        self.wait()  # one in-flight save at a time
        host_tree = jax.tree.map(np.asarray, tree)  # device->host, caller thread

        def _write():
            try:
                tmp = self.root / f"step_{step}.tmp"
                final = self.root / f"step_{step}"
                if tmp.exists():
                    shutil.rmtree(tmp)
                save_tree(host_tree, tmp, self.process_index)
                meta = {"step": step}
                (tmp / "meta.json").write_text(json.dumps(meta))
                if final.exists():
                    shutil.rmtree(final)
                os.rename(tmp, final)
                self._gc()
            except BaseException as e:  # noqa: BLE001
                self._error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = sorted(
            (int(_STEP_RE.match(d.name).group(1)), d)
            for d in self.root.iterdir()
            if d.is_dir() and _STEP_RE.match(d.name)
        )
        for _, d in steps[: -self.keep]:
            shutil.rmtree(d, ignore_errors=True)

    # -- restore --------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        d = load_latest(self.root)
        return int(_STEP_RE.match(d.name).group(1)) if d else None

    def restore(self, template) -> Optional[Any]:
        d = load_latest(self.root)
        if d is None:
            return None
        return restore_tree(template, d, self.process_index)
