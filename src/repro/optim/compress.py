"""Gradient compression for the slow inter-pod links (distributed-optimization
trick): per-tensor int8 quantization with f32 scale. Applied to the gradient
tree before the cross-pod all-reduce when ``TrainConfig.compress_grads`` is
set; decompressed before the optimizer. Lossy — error feedback buffer keeps
the quantization residual and re-adds it next step (1-bit-Adam style)."""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["compress_grads", "decompress_grads"]


def _q(x):
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_grads(grads, residual=None) -> Tuple[dict, dict, dict]:
    """Returns (quantized tree, scales tree, new residual tree)."""
    if residual is not None:
        grads = jax.tree.map(lambda g, r: g.astype(jnp.float32) + r, grads, residual)
    qs = jax.tree.map(_q, grads)
    q = jax.tree.map(lambda t: t[0], qs, is_leaf=lambda x: isinstance(x, tuple))
    s = jax.tree.map(lambda t: t[1], qs, is_leaf=lambda x: isinstance(x, tuple))
    deq = jax.tree.map(lambda qq, ss: qq.astype(jnp.float32) * ss, q, s)
    new_residual = jax.tree.map(lambda g, d: g.astype(jnp.float32) - d, grads, deq)
    return q, s, new_residual


def decompress_grads(q, s):
    return jax.tree.map(lambda qq, ss: qq.astype(jnp.float32) * ss, q, s)
