"""AdamW with global-norm clipping. Moments are f32; parameters stay in the
model dtype (bf16) with f32 update math (see DESIGN.md: a full f32
master-weight copy is a config switch away via ``master_dtype``)."""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..parallel.spec import ParamSpec

__all__ = ["AdamWConfig", "adamw_init_specs", "adamw_update"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    moment_dtype: Any = jnp.float32


def adamw_init_specs(param_specs, cfg: AdamWConfig):
    """ParamSpec tree for (mu, nu) with the same logical axes as params."""

    def mom(s: ParamSpec) -> ParamSpec:
        return ParamSpec(s.shape, s.axes, cfg.moment_dtype, init="zeros")

    is_spec = lambda x: isinstance(x, ParamSpec)
    return (
        jax.tree.map(mom, param_specs, is_leaf=is_spec),
        jax.tree.map(mom, param_specs, is_leaf=is_spec),
    )


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def adamw_update(grads, params, mu, nu, step, cfg: AdamWConfig, lr_scale=1.0):
    """Returns (new_params, new_mu, new_nu, metrics)."""
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads)
    else:
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

    t = step.astype(jnp.float32) + 1.0
    c1 = 1.0 - cfg.b1 ** t
    c2 = 1.0 - cfg.b2 ** t
    lr = cfg.lr * lr_scale

    new_mu = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g, mu, grads)
    new_nu = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g, nu, grads)

    def upd(p, m, v):
        mhat = m / c1
        vhat = v / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, new_mu, new_nu)
    return new_params, new_mu, new_nu, {"grad_norm": gnorm}
