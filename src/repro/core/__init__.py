"""repro.core — the paper's contribution: predictive I/O performance modeling.

Public API:
    IOPerformancePredictor  — Fig-10 workflow (fit zoo, predict, report)
    recommend / OnlineAutotuner — configuration recommendation (paper §5.2)
    GBTRegressor / RandomForestRegressor / linear models / MLPRegressor
    FeatureSpec / StandardScaler / PCA / metrics
"""

from .autotune import AutotuneDecision, ConfigSpace, OnlineAutotuner, recommend  # noqa: F401
from .classify import CLASSIFIER_ZOO, LogisticRegression, make_classifier  # noqa: F401
from .ensemble_base import PackedEnsemble, predict_ensemble  # noqa: F401
from .features import (  # noqa: F401
    FEATURE_NAMES,
    PCA,
    FeatureSpec,
    StandardScaler,
    expm1_inverse,
    log1p_transform,
)
from .forest import RandomForestClassifier, RandomForestRegressor, RFConfig  # noqa: F401
from .gbt import GBTBinaryClassifier, GBTConfig, GBTRegressor  # noqa: F401
from .importance import permutation_importance, rank_features  # noqa: F401
from .linear import ElasticNet, Lasso, LinearRegression, Ridge  # noqa: F401
from .metrics import (  # noqa: F401
    accuracy,
    cross_val_r2,
    f1_binary,
    kfold_indices,
    mae,
    pct_errors,
    r2_score,
    rmse,
    train_test_split,
)
from .mlp import MLPConfig, MLPRegressor  # noqa: F401
from .predictor import MODEL_ZOO, IOPerformancePredictor, ModelReport, make_model  # noqa: F401
from .uncertainty import ConformalRegressor, StackingRegressor, rf_prediction_interval  # noqa: F401
