"""repro.core — the paper's contribution: predictive I/O performance modeling.

Public API:
    IOPerformancePredictor  — Fig-10 workflow (fit zoo, predict, report)
    recommend / OnlineAutotuner — configuration recommendation (paper §5.2)
    GBTRegressor / RandomForestRegressor / linear models / MLPRegressor
    FeatureSpec / StandardScaler / PCA / metrics

Submodules load lazily (PEP 562): the modeling stack pulls in jax, and the
fleet's collector processes — which import ``repro.data.campaign`` and
therefore touch this package for ``core.features`` — must not pay jax's
import cost per spawned worker just to run I/O benchmarks.
"""

_EXPORTS = {
    "autotune": ("AutotuneDecision", "ConfigSpace", "OnlineAutotuner", "recommend"),
    "classify": ("CLASSIFIER_ZOO", "LogisticRegression", "make_classifier"),
    "ensemble_base": ("PackedEnsemble", "predict_ensemble"),
    "features": ("FEATURE_NAMES", "TARGET_NAME", "PCA", "FeatureSpec",
                 "StandardScaler", "expm1_inverse", "log1p_transform"),
    "forest": ("RandomForestClassifier", "RandomForestRegressor", "RFConfig"),
    "gbt": ("GBTBinaryClassifier", "GBTConfig", "GBTRegressor"),
    "importance": ("permutation_importance", "rank_features"),
    "linear": ("ElasticNet", "Lasso", "LinearRegression", "Ridge"),
    "metrics": ("accuracy", "cross_val_r2", "f1_binary", "kfold_indices",
                "mae", "pct_errors", "r2_score", "rmse", "train_test_split"),
    "mlp": ("MLPConfig", "MLPRegressor"),
    "predictor": ("MODEL_ZOO", "IOPerformancePredictor", "ModelReport", "make_model"),
    "uncertainty": ("ConformalRegressor", "StackingRegressor", "rf_prediction_interval"),
}

_NAME_TO_MODULE = {name: mod for mod, names in _EXPORTS.items() for name in names}

__all__ = sorted(_NAME_TO_MODULE)


def __getattr__(name: str):
    module = _NAME_TO_MODULE.get(name)
    if module is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    from importlib import import_module

    value = getattr(import_module(f".{module}", __name__), name)
    globals()[name] = value  # cache: subsequent lookups skip __getattr__
    return value


def __dir__():
    return sorted(set(globals()) | set(__all__))
