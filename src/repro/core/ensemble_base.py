"""Shared dense-ensemble representation + JAX inference for GBT and RF.

An ensemble of B trees, each padded to ``max_nodes``, is stored as stacked
arrays ``[B, max_nodes]``.  Prediction descends all trees in lockstep for
``max_depth+1`` gather steps — a dense, branch-free tensor program that jit's,
vmaps and shards cleanly (and backs the Pallas kernel in
``repro/kernels/gbt_predict.py``).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .tree import TreeArrays

__all__ = [
    "PackedEnsemble",
    "pack_trees",
    "predict_ensemble",
    "predict_ensemble_np",
    "ceil_pow2",
]


def ceil_pow2(n: int, floor: int = 1) -> int:
    """Smallest power of two >= max(n, floor).

    Shared by the serving tier's micro-batcher and the mega-grid scorer's
    tail chunk: padding row counts to powers of two keeps the number of
    distinct jit-compiled shapes logarithmic in the batch-size range."""
    return 1 << max(max(int(n), int(floor)) - 1, 0).bit_length()


@dataclasses.dataclass
class PackedEnsemble:
    feature: jnp.ndarray  # int32  [B, N]
    threshold: jnp.ndarray  # float32[B, N]
    left: jnp.ndarray  # int32  [B, N]
    right: jnp.ndarray  # int32  [B, N]
    value: jnp.ndarray  # float32[B, N]
    max_depth: int
    base_score: float = 0.0
    scale: float = 1.0  # learning rate (GBT) or 1/B (RF), folded at predict

    @property
    def n_trees(self) -> int:
        return int(self.feature.shape[0])

    def tree_dict(self):
        return dict(
            feature=self.feature,
            threshold=self.threshold,
            left=self.left,
            right=self.right,
            value=self.value,
        )


def pack_trees(
    trees: Sequence[TreeArrays], max_depth: int, base_score: float, scale: float
) -> PackedEnsemble:
    """Stack trees into [B, max_nodes] arrays, padding in place.

    One flat scatter per field instead of 5 slice-assignments per tree: the
    batched engine fits a 100-tree paper forest in tens of milliseconds, at
    which point 500 small ``__setitem__`` calls are a visible fraction of the
    whole fit.  Padded slots are self-looping zero-value leaves.
    """
    B = len(trees)
    ks = np.asarray([t.n_nodes for t in trees], np.int64)
    N = int(ks.max())
    # flat positions of every real node: tree b's node i at b*N + i
    starts = np.concatenate([[0], np.cumsum(ks)[:-1]])
    pos = np.repeat(np.arange(B, dtype=np.int64) * N, ks) + (
        np.arange(int(ks.sum())) - np.repeat(starts, ks)
    )

    def scat(field, fill, dtype):
        buf = np.full(B * N, fill, dtype) if np.isscalar(fill) else fill
        buf[pos] = np.concatenate([getattr(t, field) for t in trees])
        return buf.reshape(B, N)

    # Padded nodes self-loop so the fixed-depth descent stays put on them.
    feature = scat("feature", -1, np.int32)
    threshold = scat("threshold", 0.0, np.float32)
    value = scat("value", 0.0, np.float32)
    left = scat("left", np.tile(np.arange(N, dtype=np.int32), B), np.int32)
    right = scat("right", np.tile(np.arange(N, dtype=np.int32), B), np.int32)
    return PackedEnsemble(
        feature=jnp.asarray(feature),
        threshold=jnp.asarray(threshold),
        left=jnp.asarray(left),
        right=jnp.asarray(right),
        value=jnp.asarray(value),
        max_depth=max_depth,
        base_score=base_score,
        scale=scale,
    )


def _descend_one_tree(feature, threshold, left, right, value, x, max_depth):
    """Descend one tree for one row. x: [D]."""

    def step(_, idx):
        f = feature[idx]
        leaf = f < 0
        fx = x[jnp.maximum(f, 0)]
        nxt = jnp.where(fx <= threshold[idx], left[idx], right[idx])
        return jnp.where(leaf, idx, nxt)

    idx = jax.lax.fori_loop(0, max_depth + 1, step, jnp.int32(0))
    return value[idx]


@partial(jax.jit, static_argnames=("max_depth",))
def _predict_packed(tree_arrays: dict, X: jnp.ndarray, max_depth: int) -> jnp.ndarray:
    """Sum of per-tree predictions. X: [n, D] -> [n]."""
    per_tree = jax.vmap(  # over trees
        lambda f, t, l, r, v: jax.vmap(  # over rows
            lambda x: _descend_one_tree(f, t, l, r, v, x, max_depth)
        )(X)
    )(
        tree_arrays["feature"],
        tree_arrays["threshold"],
        tree_arrays["left"],
        tree_arrays["right"],
        tree_arrays["value"],
    )
    return per_tree.sum(axis=0)


def predict_ensemble(ens: PackedEnsemble, X: jnp.ndarray) -> jnp.ndarray:
    """base_score + scale * sum_b tree_b(X).  X: [n, D] float32."""
    X = jnp.asarray(X, jnp.float32)
    raw = _predict_packed(ens.tree_dict(), X, ens.max_depth)
    return ens.base_score + ens.scale * raw


def predict_ensemble_np(ens: PackedEnsemble, X: np.ndarray) -> np.ndarray:
    """Pure-numpy oracle, used in tests against the JAX/Pallas paths."""
    from .tree import TreeArrays, predict_tree_np

    total = np.zeros(X.shape[0], dtype=np.float64)
    for b in range(ens.n_trees):
        t = TreeArrays(
            feature=np.asarray(ens.feature[b]),
            threshold=np.asarray(ens.threshold[b]),
            left=np.asarray(ens.left[b]),
            right=np.asarray(ens.right[b]),
            value=np.asarray(ens.value[b]),
            gain=np.zeros_like(np.asarray(ens.value[b])),
            cover=np.zeros_like(np.asarray(ens.value[b])),
        )
        total += predict_tree_np(t, X, ens.max_depth)
    return ens.base_score + ens.scale * total
