"""Evaluation protocol (paper §3.3.4): R2/RMSE/MAE, percentage errors in the
original (expm1) space, 80/20 split with seed 42, and 5-fold CV."""

from __future__ import annotations

from typing import Callable, Sequence

import numpy as np

__all__ = [
    "r2_score",
    "rmse",
    "mae",
    "pct_errors",
    "train_test_split",
    "kfold_indices",
    "cross_val_r2",
    "accuracy",
    "f1_binary",
]


def r2_score(y_true: np.ndarray, y_pred: np.ndarray) -> float:
    y_true = np.asarray(y_true, np.float64)
    y_pred = np.asarray(y_pred, np.float64)
    ss_res = float(np.sum((y_true - y_pred) ** 2))
    ss_tot = float(np.sum((y_true - y_true.mean()) ** 2))
    return 1.0 - ss_res / ss_tot if ss_tot > 0 else 0.0


def rmse(y_true, y_pred) -> float:
    return float(np.sqrt(np.mean((np.asarray(y_true) - np.asarray(y_pred)) ** 2)))


def mae(y_true, y_pred) -> float:
    return float(np.mean(np.abs(np.asarray(y_true) - np.asarray(y_pred))))


def pct_errors(y_true_raw, y_pred_raw) -> dict:
    """Mean/median absolute percentage error in original throughput space."""
    t = np.asarray(y_true_raw, np.float64)
    p = np.asarray(y_pred_raw, np.float64)
    pe = np.abs(p - t) / np.maximum(np.abs(t), 1e-9) * 100.0
    return {"mean_pct_err": float(pe.mean()), "median_pct_err": float(np.median(pe))}


def train_test_split(n: int, test_frac: float = 0.2, seed: int = 42):
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    n_test = int(round(test_frac * n))
    return perm[n_test:], perm[:n_test]  # train_idx, test_idx


def kfold_indices(n: int, k: int = 5, seed: int = 42):
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    folds = np.array_split(perm, k)
    out = []
    for i in range(k):
        test = folds[i]
        train = np.concatenate([folds[j] for j in range(k) if j != i])
        out.append((train, test))
    return out


def cross_val_r2(
    make_model: Callable, X: np.ndarray, y: np.ndarray, k: int = 5, seed: int = 42
) -> np.ndarray:
    scores = []
    for tr, te in kfold_indices(X.shape[0], k, seed):
        m = make_model()
        m.fit(X[tr], y[tr])
        scores.append(r2_score(y[te], m.predict(X[te])))
    return np.asarray(scores)


def accuracy(y_true, y_pred) -> float:
    return float(np.mean(np.asarray(y_true) == np.asarray(y_pred)))


def f1_binary(y_true, y_pred) -> float:
    y_true = np.asarray(y_true)
    y_pred = np.asarray(y_pred)
    tp = float(np.sum((y_pred == 1) & (y_true == 1)))
    fp = float(np.sum((y_pred == 1) & (y_true == 0)))
    fn = float(np.sum((y_pred == 0) & (y_true == 1)))
    denom = 2 * tp + fp + fn
    return 2 * tp / denom if denom > 0 else 0.0
