"""Cross-backend transfer: leave-one-backend-out evaluation + few-shot
residual calibration.

The paper's 0.991 R² is an *in-distribution* number: train and test rows come
from the same host and the same storage backends.  The question that makes
the predictor useful at fleet scale is generalization — train on one storage
backend/host profile, predict on another (PAPERS.md's *ML-based Modeling to
Predict I/O Performance on Different Storage Sub-systems*).  This module
answers it three ways:

1. **Host-profile features** (``features.HOST_PROFILE_FEATURE_NAMES``): who
   measured a row, not what was measured — backend class, cpu count,
   page-cache size, and baseline read/write microbench fingerprints.  They
   are appended to the paper's 11-feature spec (``features.transfer_spec``)
   so one model can be trained across heterogeneous backends.
2. **A leave-one-group-out harness** (:func:`evaluate_transfer`): hold out
   every backend (or host) in turn, fit the model zoo on the rest, and
   report per-held-out-group R²/MAPE — the honest transfer counterpart of
   the in-distribution CV in ``predictor.evaluate_zoo``.
3. **Few-shot calibration** (:class:`AffineCalibrator`,
   :class:`ResidualGBTCalibrator`): a residual correction fitted from
   ``k ≪ 100`` observations on the new backend, swept over
   ``k ∈ {0, 5, 10, 25, 50}`` to show dozens of rows recover most of the
   in-distribution accuracy.  Tree ensembles cannot extrapolate beyond the
   throughput range they were trained on, so a never-seen backend's
   predictions are off by roughly a multiplicative factor — which is exactly
   what an affine correction in log1p space removes.  An affine map with
   ``a > 0`` is monotone, so calibration changes *absolute* predictions
   without reordering a ranked recommendation list.

Reports are **deterministic**: same inputs + seed → byte-identical
``json.dumps(report, sort_keys=True)``.  Wall-clock timings are therefore
returned out-of-band (the ``timings`` argument), never inside the report.

CLI::

    python -m repro.core.transfer --fast                 # synthetic track
    python -m repro.core.transfer --records merged.jsonl # real campaign rows
    python -m repro.core.transfer --group host --k 0 5 25

The synthetic track (:func:`synthetic_transfer_observations`) is a
deterministic backend-heterogeneous dataset modeled on the four shipped
storage tiers — the fixture behind ``tests/test_transfer.py``,
``make transfer-smoke`` and ``BENCH_transfer.json``.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import pathlib
import sys
import time
import zlib
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from .features import (
    FEATURE_NAMES,
    HOST_PROFILE_FEATURE_NAMES,
    TARGET_NAME,
    TRANSFER_FEATURE_NAMES,
    FeatureSpec,
    expm1_inverse,
    log1p_transform,
    transfer_spec,
)
from .metrics import pct_errors, r2_score
from .predictor import MODEL_ZOO, make_model

__all__ = [
    "BACKEND_CLASSES",
    "HostProfile",
    "default_profiles",
    "profile_for_backend",
    "measure_host_profile",
    "synthetic_transfer_observations",
    "SYNTHETIC_BACKENDS",
    "observations_from_records",
    "group_folds",
    "AffineCalibrator",
    "ResidualGBTCalibrator",
    "make_calibrator",
    "evaluate_transfer",
    "format_report",
    "DEFAULT_KS",
    "main",
]

# Numeric backend codes for the ``backend_class`` feature.  Unknown backends
# get a stable crc32-derived code >= 4 (stable across processes, unlike
# ``hash()``), so a new storage tier never collides with the shipped four.
BACKEND_CLASSES = {"tmpfs": 0, "disk": 1, "network_sim": 2, "object_sim": 3}

DEFAULT_KS = (0, 5, 10, 25, 50)


def backend_class(name: str) -> int:
    known = BACKEND_CLASSES.get(name)
    if known is not None:
        return known
    return 4 + zlib.crc32(str(name).encode()) % 96


@dataclasses.dataclass(frozen=True)
class HostProfile:
    """Host-profile fingerprint of one (host, backend) measurement context.

    ``baseline_read_mb_s``/``baseline_write_mb_s`` are single-stream
    microbench fingerprints — what this backend delivers for a plain
    sequential transfer, before any pipeline/knob effects."""

    backend: str
    backend_class: int
    cpu_count: int = 1
    page_cache_mb: float = 0.0
    baseline_read_mb_s: float = 0.0
    baseline_write_mb_s: float = 0.0

    def as_features(self) -> Dict[str, float]:
        """The ``HOST_PROFILE_FEATURE_NAMES`` columns for this profile."""
        return {
            "backend_class": float(self.backend_class),
            "host_cpu_count": float(self.cpu_count),
            "host_page_cache_mb": float(self.page_cache_mb),
            "baseline_read_mb_s": float(self.baseline_read_mb_s),
            "baseline_write_mb_s": float(self.baseline_write_mb_s),
        }


# Calibrated default fingerprints for the four shipped tiers (read, write,
# per-op latency in ms).  Machine-independent on purpose: the deterministic
# synthetic track and record evaluation on machines that never ran a
# microbench both key off these; ``measure_host_profile`` replaces them with
# measured numbers when asked.
_DEFAULT_FINGERPRINTS = {
    "tmpfs": (5200.0, 4600.0, 0.0),
    "disk": (1750.0, 1150.0, 0.05),
    "network_sim": (1040.0, 960.0, 1.0),
    "object_sim": (330.0, 290.0, 8.0),
}

_SYNTH_LATENCY_MS = {name: lat for name, (_, _, lat) in
                     _DEFAULT_FINGERPRINTS.items()}

SYNTHETIC_BACKENDS = ("tmpfs", "disk", "network_sim", "object_sim")

_DEFAULT_CPU = 8
_DEFAULT_PAGE_CACHE_MB = 4096.0


def default_profiles() -> Dict[str, HostProfile]:
    """Deterministic profiles for the shipped backends (no I/O performed)."""
    return {
        name: HostProfile(
            backend=name,
            backend_class=backend_class(name),
            cpu_count=_DEFAULT_CPU,
            page_cache_mb=_DEFAULT_PAGE_CACHE_MB,
            baseline_read_mb_s=read,
            baseline_write_mb_s=write,
        )
        for name, (read, write, _lat) in _DEFAULT_FINGERPRINTS.items()
    }


def profile_for_backend(
    name: str, profiles: Optional[Dict[str, HostProfile]] = None
) -> HostProfile:
    """Profile for ``name``, synthesizing a zeroed one for unknown backends
    (stable ``backend_class``, zero fingerprints: "never measured")."""
    profiles = profiles if profiles is not None else default_profiles()
    prof = profiles.get(name)
    if prof is not None:
        return prof
    return HostProfile(backend=name, backend_class=backend_class(name))


def _page_cache_mb() -> float:
    """Best-effort page-cache size from /proc/meminfo (0.0 when unreadable)."""
    try:
        for line in pathlib.Path("/proc/meminfo").read_text().splitlines():
            if line.startswith("Cached:"):
                return float(line.split()[1]) / 1024.0
    except (OSError, ValueError, IndexError):
        pass
    return 0.0


def measure_host_profile(backend, size_mb: float = 2.0,
                         block_kb: int = 256, seed: int = 0) -> HostProfile:
    """Measured fingerprint: time one sequential write + read on ``backend``.

    ``backend`` is a ``repro.data.storage.StorageBackend``.  The probe is a
    few MB on purpose — a fingerprint, not a benchmark — so fleet collectors
    can afford one per (host, backend) at startup."""
    rng = np.random.default_rng(seed)
    block = int(block_kb) * 1024
    n_blocks = max(1, int(size_mb * 1024 * 1024) // block)
    payload = rng.integers(0, 256, size=block, dtype=np.uint8).tobytes()
    path = backend.path(f"hostprofile_{seed}.bin")
    t0 = time.perf_counter()
    with open(path, "wb") as f:
        for _ in range(n_blocks):
            f.write(payload)
            backend.charge(block)
        f.flush()
        os.fsync(f.fileno())
    write_s = max(time.perf_counter() - t0, 1e-9)
    t0 = time.perf_counter()
    with open(path, "rb") as f:
        for i in range(n_blocks):
            backend.read_block(f, i * block, block)
    read_s = max(time.perf_counter() - t0, 1e-9)
    path.unlink(missing_ok=True)
    total_mb = n_blocks * block / 1e6
    return HostProfile(
        backend=backend.name,
        backend_class=backend_class(backend.name),
        cpu_count=os.cpu_count() or 1,
        page_cache_mb=round(_page_cache_mb(), 1),
        baseline_read_mb_s=round(total_mb / read_s, 2),
        baseline_write_mb_s=round(total_mb / write_s, 2),
    )


# ------------------------------------------------------------------ data

def synthetic_transfer_observations(
    n_per_backend: int = 96,
    backends: Sequence[str] = SYNTHETIC_BACKENDS,
    seed: int = 0,
    profiles: Optional[Dict[str, HostProfile]] = None,
) -> Tuple[dict, List[str]]:
    """Deterministic backend-heterogeneous observations: ``(columns, groups)``.

    Each backend contributes ``n_per_backend`` rows whose target throughput
    scales with the backend's baseline fingerprint (multiplicative — a pure
    shift in log space) and suffers a latency penalty interacting with the
    block size.  Knob effects (workers, batch, threads, block) are shared
    across backends, so a model trained on three backends has seen the
    *shape* but not the *scale* of the fourth — the exact failure mode
    few-shot affine calibration is designed to repair.

    Returns the column dict over ``TRANSFER_FEATURE_NAMES`` +
    ``target_throughput``, and the parallel per-row backend labels.
    """
    profiles = profiles if profiles is not None else default_profiles()
    rng = np.random.default_rng(seed)
    cols: Dict[str, List[np.ndarray]] = {n: [] for n in TRANSFER_FEATURE_NAMES}
    targets: List[np.ndarray] = []
    groups: List[str] = []
    n = int(n_per_backend)
    for name in backends:
        prof = profile_for_backend(name, profiles)
        scale = max(prof.baseline_read_mb_s, 1.0)
        lat_ms = _SYNTH_LATENCY_MS.get(name, 0.0)

        block = rng.choice([16.0, 64.0, 256.0, 1024.0], size=n)
        workers = rng.choice([1.0, 2.0, 4.0, 8.0], size=n)
        batch = rng.choice([16.0, 32.0, 64.0, 128.0], size=n)
        threads = rng.choice([1.0, 2.0, 4.0], size=n)
        file_mb = rng.choice([64.0, 256.0, 1024.0], size=n)
        n_samples = rng.choice([200.0, 400.0, 800.0], size=n)

        # shared knob shape x backend-specific scale x latency penalty
        shape = ((block / 256.0) ** 0.2
                 * (1.0 + 0.55 * np.log2(workers))
                 * (batch / 64.0) ** 0.15
                 * threads ** 0.25)
        penalty = 1.0 / (1.0 + lat_ms * 64.0 / block)
        noise = np.exp(rng.normal(0.0, 0.04, size=n))
        target = 0.35 * scale * shape * penalty * noise

        # measured per-row proxies (noisy, like real probe measurements)
        single = scale * penalty * np.exp(rng.normal(0.0, 0.05, size=n))
        iops = single * 1024.0 / block
        sps = target / batch * 64.0 * np.exp(rng.normal(0.0, 0.1, size=n))
        load_ratio = np.clip(
            1.0 / (1.0 + 0.002 * single) + rng.normal(0.0, 0.02, size=n),
            0.01, 0.99)
        aggregate = single * workers ** 0.8 * np.exp(
            rng.normal(0.0, 0.05, size=n))

        per_backend = {
            "block_kb": block,
            "file_size_mb": file_mb,
            "n_samples": n_samples,
            "throughput_mb_s": single,
            "iops": iops,
            "n_threads": threads,
            "batch_size": batch,
            "samples_per_second": sps,
            "data_loading_ratio": load_ratio,
            "num_workers": workers,
            "aggregate_throughput_mb_s": aggregate,
        }
        per_backend.update(
            {k: np.full(n, v) for k, v in prof.as_features().items()})
        for key in TRANSFER_FEATURE_NAMES:
            cols[key].append(np.asarray(per_backend[key], np.float64))
        targets.append(target)
        groups.extend([name] * n)
    observations = {k: np.concatenate(v) for k, v in cols.items()}
    observations[TARGET_NAME] = np.concatenate(targets)
    return observations, groups


def observations_from_records(
    records: Iterable[dict],
    profiles: Optional[Dict[str, HostProfile]] = None,
    group_key: str = "backend",
) -> Tuple[dict, List[str]]:
    """``(columns, groups)`` from campaign JSONL records.

    Successful rows contribute the 11 paper features (missing keys -> 0,
    like ``FeatureSpec.row``) plus host-profile columns looked up per
    backend.  ``group_key`` selects the fold label: ``"backend"`` (the
    row's storage backend) or ``"host"`` (the collecting host from record
    provenance — note canonical merges strip ``host``, so leave-one-host-out
    needs raw shard records)."""
    profiles = profiles if profiles is not None else default_profiles()
    rows: List[dict] = []
    groups: List[str] = []
    for r in records:
        if r.get("status") != "ok" or not r.get("row"):
            continue
        row = r["row"]
        backend = str(row.get("backend") or "?")
        if group_key == "host":
            groups.append(str(r.get("host") or "?"))
        else:
            groups.append(backend)
        merged = dict(row)
        merged.update(profile_for_backend(backend, profiles).as_features())
        rows.append(merged)
    observations = {
        name: np.asarray([float(r.get(name, 0.0) or 0.0) for r in rows],
                         np.float64)
        for name in TRANSFER_FEATURE_NAMES + (TARGET_NAME,)
    }
    return observations, groups


def group_folds(groups: Sequence[str]) -> Dict[str, np.ndarray]:
    """Leave-one-group-out folds: group label -> held-out row indices.

    Disjoint and complete by construction — every row lands in exactly one
    held-out fold (its own group's) — and deterministically ordered (sorted
    group labels, ascending indices)."""
    by_group: Dict[str, List[int]] = {}
    for i, g in enumerate(groups):
        by_group.setdefault(str(g), []).append(i)
    return {g: np.asarray(ix, np.int64) for g, ix in sorted(by_group.items())}


# ------------------------------------------------------------ calibration

class AffineCalibrator:
    """Affine residual correction in log1p space: ``ŷ = a·p + b``.

    ``k = 0`` -> identity (zero-shot); ``k = 1`` (or a degenerate prediction
    spread) -> offset-only, the pure scale correction; ``k >= 2`` -> least
    squares, falling back to offset-only if the fitted slope is non-positive
    (a tiny sample must never invert the prediction ordering — monotone
    corrections leave ranked recommendations unchanged)."""

    kind = "affine"

    def __init__(self, seed: int = 0):
        self.a = 1.0
        self.b = 0.0
        self.n = 0

    def fit(self, X: np.ndarray, pred_log: np.ndarray, y_log: np.ndarray):
        p = np.asarray(pred_log, np.float64).ravel()
        y = np.asarray(y_log, np.float64).ravel()
        self.n = int(p.size)
        if p.size == 0:
            return self
        if p.size == 1 or float(np.ptp(p)) < 1e-9:
            self.a, self.b = 1.0, float(np.mean(y - p))
            return self
        pm, ym = float(p.mean()), float(y.mean())
        var = float(np.mean((p - pm) ** 2))
        cov = float(np.mean((p - pm) * (y - ym)))
        a = cov / (var + 1e-12)
        if a <= 0.0:
            self.a, self.b = 1.0, ym - pm
        else:
            self.a, self.b = a, ym - a * pm
        return self

    def apply(self, X: np.ndarray, pred_log: np.ndarray) -> np.ndarray:
        return self.a * np.asarray(pred_log, np.float64) + self.b

    def as_dict(self) -> dict:
        return {"kind": self.kind, "a": round(self.a, 6),
                "b": round(self.b, 6), "n": self.n}


class ResidualGBTCalibrator:
    """Shallow GBT on residuals ``y_log - pred_log`` over the feature row.

    For larger ``k`` (a few dozen rows) a depth-2 booster picks up
    knob-dependent residual structure an affine map cannot; below
    ``min_rows`` it degrades to the affine correction — a handful of rows
    cannot support tree splits."""

    kind = "gbt"

    def __init__(self, seed: int = 0, n_estimators: int = 24,
                 max_depth: int = 2, min_rows: int = 16):
        self.seed = seed
        self.n_estimators = n_estimators
        self.max_depth = max_depth
        self.min_rows = min_rows
        self.model = None
        self.affine = AffineCalibrator(seed)
        self.n = 0

    def fit(self, X: np.ndarray, pred_log: np.ndarray, y_log: np.ndarray):
        X = np.asarray(X, np.float64)
        p = np.asarray(pred_log, np.float64).ravel()
        y = np.asarray(y_log, np.float64).ravel()
        self.n = int(p.size)
        self.affine.fit(X, p, y)
        if p.size >= self.min_rows:
            from .gbt import GBTConfig, GBTRegressor

            self.model = GBTRegressor(GBTConfig(
                n_estimators=self.n_estimators, max_depth=self.max_depth,
                learning_rate=0.3, subsample=1.0, seed=self.seed))
            self.model.fit(X, y - self.affine.apply(X, p))
        return self

    def apply(self, X: np.ndarray, pred_log: np.ndarray) -> np.ndarray:
        out = self.affine.apply(X, pred_log)
        if self.model is not None:
            out = out + self.model.predict(np.asarray(X, np.float64))
        return out

    def as_dict(self) -> dict:
        return {"kind": self.kind, "n": self.n,
                "estimators": 0 if self.model is None else self.n_estimators,
                "affine": self.affine.as_dict()}


_CALIBRATORS = {"affine": AffineCalibrator, "gbt": ResidualGBTCalibrator}


def make_calibrator(kind: str = "affine", seed: int = 0):
    try:
        return _CALIBRATORS[kind](seed=seed)
    except KeyError:
        raise ValueError(
            f"unknown calibrator {kind!r}; choose from {sorted(_CALIBRATORS)}"
        ) from None


# --------------------------------------------------------------- harness

def evaluate_transfer(
    observations: dict,
    groups: Sequence[str],
    models: Optional[Sequence[str]] = None,
    spec: Optional[FeatureSpec] = None,
    calibration_model: str = "xgboost",
    calibrator_kind: str = "affine",
    ks: Sequence[int] = DEFAULT_KS,
    seed: int = 0,
    group_key: str = "backend",
    engine: Optional[str] = None,
    timings: Optional[dict] = None,
) -> dict:
    """Leave-one-group-out transfer report for the model zoo + calibration
    learning curve.

    For every distinct group label the harness fits each model on all other
    groups and scores the held-out group.  The held-out rows are split
    deterministically (seeded per fold) into a calibration pool of
    ``max(ks)`` rows and a fixed evaluation set; every ``k`` — including
    ``k = 0``, the zero-shot baseline — is scored on the *same* evaluation
    rows, so the learning curve compares like with like.

    The returned report is deterministic: same inputs + ``seed`` give a
    byte-identical ``json.dumps(report, sort_keys=True)``.  Pass a
    ``timings`` dict to receive wall-clock seconds per fold out-of-band
    (they never enter the report).
    """
    if spec is None:
        have_profile = all(n in observations
                           for n in HOST_PROFILE_FEATURE_NAMES)
        spec = transfer_spec() if have_profile else FeatureSpec()
    X = spec.matrix(observations)
    y_raw = np.asarray(observations[TARGET_NAME], np.float64)
    y_log = log1p_transform(y_raw)
    if len(groups) != X.shape[0]:
        raise ValueError(
            f"groups length {len(groups)} != n_rows {X.shape[0]}")
    folds = group_folds(groups)
    if len(folds) < 2:
        raise ValueError(
            "leave-one-group-out needs >= 2 distinct groups, got "
            f"{sorted(folds)}")
    model_names = list(models) if models else list(MODEL_ZOO)
    ks = tuple(sorted({int(k) for k in ks}))
    if any(k < 0 for k in ks):
        raise ValueError(f"negative calibration k in {ks}")
    max_k = max(ks) if ks else 0

    report: dict = {
        "schema": 1,
        "group_key": group_key,
        "seed": int(seed),
        "ks": list(ks),
        "n_rows": int(X.shape[0]),
        "n_features": int(spec.n_features),
        "models": sorted(set(model_names) | {calibration_model}),
        "calibration_model": calibration_model,
        "calibrator": calibrator_kind,
        "folds": {},
    }
    all_idx = np.arange(X.shape[0])
    in_fold = {g: set(ix.tolist()) for g, ix in folds.items()}
    for gname, test_idx in folds.items():
        t_fold = time.perf_counter()
        mask = np.ones(X.shape[0], bool)
        mask[test_idx] = False
        train_idx = all_idx[mask]
        if train_idx.size == 0:
            continue
        # deterministic per-fold calibration/eval split of the held-out rows:
        # reserve at least a quarter of the fold (>= 1 row) for evaluation
        rng = np.random.default_rng([int(seed), zlib.crc32(gname.encode())])
        perm = test_idx[rng.permutation(test_idx.size)]
        n_calib = min(max_k, test_idx.size - max(1, test_idx.size // 4))
        n_calib = max(n_calib, 0)
        calib_pool, eval_idx = perm[:n_calib], perm[n_calib:]
        ks_eff = [k for k in ks if k <= n_calib]
        if not ks_eff or ks_eff[0] != 0:
            ks_eff = [0] + ks_eff

        fold: dict = {
            "n_train": int(train_idx.size),
            "n_test": int(test_idx.size),
            "n_eval": int(eval_idx.size),
            "n_calib_pool": int(n_calib),
            "zoo": {},
        }
        fitted = {}
        for name in model_names:
            m = make_model(name, seed, engine=engine)
            m.fit(X[train_idx], y_log[train_idx])
            fitted[name] = m
            pred = m.predict(X[eval_idx])
            pe = pct_errors(y_raw[eval_idx], expm1_inverse(pred))
            fold["zoo"][name] = {
                "r2": round(r2_score(y_log[eval_idx], pred), 6),
                "mape": round(pe["mean_pct_err"], 6),
                "median_ape": round(pe["median_pct_err"], 6),
            }
        cal_model = fitted.get(calibration_model)
        if cal_model is None:
            cal_model = make_model(calibration_model, seed, engine=engine)
            cal_model.fit(X[train_idx], y_log[train_idx])
        pred_eval = cal_model.predict(X[eval_idx])

        curve: dict = {}
        calibrators: dict = {}
        for k in ks_eff:
            if k == 0:
                corrected = pred_eval
            else:
                idx = calib_pool[:k]
                cal = make_calibrator(calibrator_kind, seed)
                cal.fit(X[idx], cal_model.predict(X[idx]), y_log[idx])
                corrected = cal.apply(X[eval_idx], pred_eval)
                calibrators[f"k{k}"] = cal.as_dict()
            pe = pct_errors(y_raw[eval_idx], expm1_inverse(corrected))
            curve[f"k{k}"] = {
                "mape": round(pe["mean_pct_err"], 6),
                "median_ape": round(pe["median_pct_err"], 6),
                "r2": round(r2_score(y_log[eval_idx], corrected), 6),
            }
        zero = curve["k0"]["mape"]
        reductions = {
            f"k{k}": round(zero / max(curve[f"k{k}"]["mape"], 1e-6), 4)
            for k in ks_eff if k > 0
        }
        small_ks = [k for k in ks_eff if 0 < k <= 25]
        k_star = max(small_ks) if small_ks else None
        fold["calibration"] = {
            "curve": curve,
            "calibrators": calibrators,
            "mape_reduction": reductions,
            "mape_reduction_k25": (
                reductions[f"k{k_star}"] if k_star is not None else None),
        }
        report["folds"][gname] = fold
        if timings is not None:
            timings[gname] = time.perf_counter() - t_fold

    reductions_k25 = [
        f["calibration"]["mape_reduction_k25"]
        for f in report["folds"].values()
        if f["calibration"]["mape_reduction_k25"] is not None
    ]
    report["max_mape_reduction_k25"] = (
        max(reductions_k25) if reductions_k25 else None)
    # a self-check, not an assumption: every row in exactly one held-out fold
    covered = sum(len(s) for s in in_fold.values())
    assert covered == X.shape[0], "folds must cover every row exactly once"
    return report


def format_report(report: dict) -> str:
    """Human-readable per-fold table (deterministic, no timings)."""
    lines = [
        f"leave-one-{report['group_key']}-out: {report['n_rows']} rows, "
        f"{len(report['folds'])} folds, "
        f"calibration={report['calibration_model']}/{report['calibrator']} "
        f"k={report['ks']}"
    ]
    hdr = (f"{'held-out':16s} {'n_tr':>5s} {'n_ev':>5s} {'best zoo':>14s} "
           f"{'r2':>7s} {'mape0':>8s} {'mape25':>8s} {'cut':>6s}")
    lines.append(hdr)
    lines.append("-" * len(hdr))
    for gname, fold in sorted(report["folds"].items()):
        best = min(fold["zoo"].items(), key=lambda kv: kv[1]["mape"])
        curve = fold["calibration"]["curve"]
        small = [k for k in report["ks"] if 0 < k <= 25
                 and f"k{k}" in curve]
        mape25 = curve[f"k{max(small)}"]["mape"] if small else float("nan")
        red = fold["calibration"]["mape_reduction_k25"]
        lines.append(
            f"{gname:16s} {fold['n_train']:>5d} {fold['n_eval']:>5d} "
            f"{best[0]:>14s} {best[1]['r2']:>7.3f} "
            f"{curve['k0']['mape']:>8.1f} {mape25:>8.1f} "
            f"{'-' if red is None else f'{red:.1f}x':>6s}"
        )
    if report.get("max_mape_reduction_k25") is not None:
        lines.append(
            f"max few-shot (k<=25) MAPE reduction: "
            f"{report['max_mape_reduction_k25']:.1f}x")
    return "\n".join(lines)


# ------------------------------------------------------------------ CLI

def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.core.transfer",
        description="Leave-one-backend-out (or leave-one-host-out) transfer "
                    "evaluation of the model zoo, with a few-shot residual-"
                    "calibration learning curve per held-out group.",
    )
    ap.add_argument("--records", type=pathlib.Path, nargs="+", default=None,
                    help="campaign/merged JSONL files to evaluate "
                         "(default: the deterministic synthetic track)")
    ap.add_argument("--group", choices=("backend", "host"), default="backend",
                    help="fold key: leave one backend or one host out")
    ap.add_argument("--models", nargs="+", default=None,
                    help="model zoo subset (default: the whole zoo)")
    ap.add_argument("--model", default="xgboost",
                    help="model the calibration curve is computed for")
    ap.add_argument("--calibrator", choices=sorted(_CALIBRATORS),
                    default="affine", help="residual corrector kind")
    ap.add_argument("--k", type=int, nargs="+", default=list(DEFAULT_KS),
                    help="calibration learning-curve sizes (0 = zero-shot)")
    ap.add_argument("--seed", type=int, default=0,
                    help="model + fold-split seed (reports are deterministic "
                         "for a fixed seed)")
    ap.add_argument("--n-per-backend", type=int, default=96,
                    help="synthetic-track rows per backend")
    ap.add_argument("--fast", action="store_true",
                    help="CI-sized: 72 rows/backend, linear+ridge+xgboost")
    ap.add_argument("--out", type=pathlib.Path, default=None,
                    help="write the JSON report here (sorted keys)")
    ap.add_argument("--json", action="store_true",
                    help="print the JSON report instead of the table")
    args = ap.parse_args(argv)

    if args.records:
        missing = [p for p in args.records if not p.exists()]
        if missing:
            print(f"error: no such result file: "
                  f"{', '.join(map(str, missing))}", file=sys.stderr)
            return 2
        from ..data.campaign import load_records  # lazy: core must not

        # depend on the data layer at import time
        records: List[dict] = []
        for p in args.records:
            records.extend(load_records(p))
        observations, groups = observations_from_records(
            records, group_key=args.group)
        if not groups:
            print("error: no successful observation rows in the given "
                  "records", file=sys.stderr)
            return 2
    else:
        n = min(args.n_per_backend, 72) if args.fast else args.n_per_backend
        observations, groups = synthetic_transfer_observations(
            n_per_backend=n, seed=args.seed)

    models = args.models
    if models is None and args.fast:
        models = ["linear", "ridge", "xgboost"]
    try:
        report = evaluate_transfer(
            observations, groups, models=models, ks=args.k, seed=args.seed,
            calibration_model=args.model, calibrator_kind=args.calibrator,
            group_key=args.group,
        )
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    payload = json.dumps(report, sort_keys=True, indent=2)
    if args.out:
        args.out.parent.mkdir(parents=True, exist_ok=True)
        args.out.write_text(payload + "\n")
    print(payload if args.json else format_report(report))
    if args.out:
        print(f"report -> {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
