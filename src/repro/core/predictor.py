"""The paper's Fig-10 workflow as a library object.

observations -> feature matrix -> log1p target -> model zoo fit/eval ->
throughput prediction for unseen configurations -> (autotune.py) recommendation.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Callable, Dict, Optional

import numpy as np

from .features import FEATURE_NAMES, FeatureSpec, StandardScaler, expm1_inverse, log1p_transform
from .forest import RandomForestRegressor, RFConfig
from .gbt import GBTConfig, GBTRegressor
from .linear import ElasticNet, Lasso, LinearRegression, Ridge
from .metrics import cross_val_r2, mae, pct_errors, r2_score, rmse, train_test_split
from .mlp import MLPConfig, MLPRegressor

__all__ = [
    "MODEL_ZOO",
    "make_model",
    "ModelReport",
    "IOPerformancePredictor",
    "PredictorSnapshot",
]


# Paper hyperparameters (§3.3).  ``engine`` selects the tree-fitting engine
# for the ensemble models (None = tree.resolve_engine's default, which honors
# REPRO_TREE_ENGINE at fit time); the other models ignore it.
MODEL_ZOO: Dict[str, Callable] = {
    "linear": lambda seed=0, engine=None: LinearRegression(),
    "ridge": lambda seed=0, engine=None: Ridge(alpha=1.0),
    "lasso": lambda seed=0, engine=None: Lasso(alpha=0.1),
    "elasticnet": lambda seed=0, engine=None: ElasticNet(alpha=0.1, l1_ratio=0.5),
    "random_forest": lambda seed=0, engine=None: RandomForestRegressor(
        RFConfig(n_estimators=100, max_depth=10, min_samples_split=5, seed=seed),
        engine=engine,
    ),
    "xgboost": lambda seed=0, engine=None: GBTRegressor(
        GBTConfig(
            n_estimators=100,
            max_depth=6,
            learning_rate=0.1,
            subsample=0.8,
            seed=seed,
        ),
        engine=engine,
    ),
    "mlp": lambda seed=0, engine=None: _ScaledMLP(seed),
}


class _ScaledMLP:
    """MLP with StandardScaler inputs (paper: scaling only for the NN)."""

    def __init__(self, seed: int = 0):
        self.scaler = StandardScaler()
        self.mlp = MLPRegressor(MLPConfig(seed=seed))

    def fit(self, X, y):
        self.mlp.fit(self.scaler.fit_transform(X), y)
        return self

    def predict(self, X):
        return self.mlp.predict(self.scaler.transform(X))


def make_model(name: str, seed: int = 0, engine: Optional[str] = None):
    return MODEL_ZOO[name](seed=seed, engine=engine)


@dataclasses.dataclass
class ModelReport:
    name: str
    train_r2: float
    test_r2: float
    test_rmse: float
    test_mae: float
    mean_pct_err: float
    median_pct_err: float
    cv_mean: float = float("nan")
    cv_std: float = float("nan")

    def as_dict(self):
        return dataclasses.asdict(self)


class PredictorSnapshot:
    """Immutable view of one fitted model for lock-free concurrent readers.

    The serving tier (``repro.service.serve``) scores many requests from many
    threads while a background refit may swap the live model underneath.  A
    snapshot pins ``(model, generation)`` at a single instant, so everything
    scored against it — a whole micro-batch — sees exactly one model: no
    response can ever mix feature schema or model generation.  The wrapped
    model object is never mutated after fitting (refits build a *new* model,
    see ``IOPerformancePredictor.build_model``), which is what makes sharing
    it across threads without a lock sound.
    """

    __slots__ = ("spec", "model", "model_name", "generation")

    def __init__(self, spec: FeatureSpec, model, model_name: str, generation: int):
        self.spec = spec
        self.model = model
        self.model_name = model_name
        self.generation = generation

    def predict_log(self, X: np.ndarray) -> np.ndarray:
        return self.model.predict(np.asarray(X, np.float64))

    def predict_throughput(self, config: dict) -> float:
        x = self.spec.row(config)[None, :]
        return float(expm1_inverse(self.predict_log(x))[0])

    def predict_throughput_batch(self, X: np.ndarray) -> np.ndarray:
        return expm1_inverse(self.predict_log(X))

    @property
    def feature_importances_(self):
        return getattr(self.model, "feature_importances_", None)


class IOPerformancePredictor:
    """Fit the model zoo on I/O observations; predict/recommend configs.

    ``observations`` is a dict of equal-length column arrays containing the 11
    canonical features plus ``target_throughput`` (MB/s, raw space).
    """

    def __init__(
        self,
        spec: Optional[FeatureSpec] = None,
        model: str = "xgboost",
        seed: int = 0,
        engine: Optional[str] = None,
    ):
        self.spec = spec or FeatureSpec()
        self.model_name = model
        self.seed = seed
        self.engine = engine  # tree engine for ensemble models (None = default)
        self.model = None
        self.reports: Dict[str, ModelReport] = {}

    # ------------------------------------------------------------------
    def evaluate_zoo(
        self,
        observations: dict,
        models: Optional[list] = None,
        with_cv: bool = True,
        test_frac: float = 0.2,
        split_seed: int = 42,
    ) -> Dict[str, ModelReport]:
        X = self.spec.matrix(observations)
        y_raw = np.asarray(observations[self.spec.target], np.float64)
        y = log1p_transform(y_raw)
        tr, te = train_test_split(X.shape[0], test_frac, split_seed)
        for name in models or list(MODEL_ZOO):
            m = make_model(name, self.seed, engine=self.engine)
            m.fit(X[tr], y[tr])
            pred_tr = m.predict(X[tr])
            pred_te = m.predict(X[te])
            pe = pct_errors(y_raw[te], expm1_inverse(pred_te))
            rep = ModelReport(
                name=name,
                train_r2=r2_score(y[tr], pred_tr),
                test_r2=r2_score(y[te], pred_te),
                test_rmse=rmse(y[te], pred_te),
                test_mae=mae(y[te], pred_te),
                mean_pct_err=pe["mean_pct_err"],
                median_pct_err=pe["median_pct_err"],
            )
            if with_cv and name in ("xgboost", "random_forest", "lasso"):
                scores = cross_val_r2(
                    lambda: make_model(name, self.seed, engine=self.engine), X, y, k=5
                )
                rep.cv_mean = float(scores.mean())
                rep.cv_std = float(scores.std())
            self.reports[name] = rep
        return self.reports

    # ------------------------------------------------------------------
    def fit(self, observations: dict):
        return self.fit_matrix(
            self.spec.matrix(observations),
            np.asarray(observations[self.spec.target], np.float64),
        )

    def fit_matrix(self, X: np.ndarray, y_raw: np.ndarray):
        """Fit from a prebuilt [n, n_features] matrix + raw targets (MB/s).

        The zero-copy path used by ``OnlineAutotuner.maybe_refit``: the online
        column store hands over views of its live buffer, so refits skip the
        dict-of-columns restacking entirely.

        Concurrency contract: ``self.model`` is only ever assigned a *fully
        fitted* model in one atomic reference swap — a concurrent reader (or a
        ``snapshot()``) sees either the complete old model or the complete new
        one, never a half-trained object.
        """
        self.model = self.build_model(X, y_raw)
        return self

    def build_model(self, X: np.ndarray, y_raw: np.ndarray):
        """Fit and return a new model WITHOUT touching ``self.model``.

        The hot-swap primitive behind concurrent serving: a background refit
        trains off to the side (this call can take hundreds of milliseconds)
        and the caller publishes the result with a single reference
        assignment, so in-flight predictions keep using the previous model.
        """
        y = log1p_transform(np.asarray(y_raw, np.float64))
        model = make_model(self.model_name, self.seed, engine=self.engine)
        model.fit(np.asarray(X, np.float64), y)
        return model

    def snapshot(self, generation: int = 0) -> PredictorSnapshot:
        """Immutable ``(model, generation)`` view for concurrent scoring."""
        assert self.model is not None, "fit() first"
        return PredictorSnapshot(self.spec, self.model, self.model_name, generation)

    def predict_log(self, X: np.ndarray) -> np.ndarray:
        assert self.model is not None, "fit() first"
        return self.model.predict(np.asarray(X, np.float64))

    def predict_throughput(self, config: dict) -> float:
        """Predict raw MB/s for one configuration dict (missing keys -> 0)."""
        x = self.spec.row(config)[None, :]
        return float(expm1_inverse(self.predict_log(x))[0])

    def predict_throughput_batch(self, X: np.ndarray) -> np.ndarray:
        return expm1_inverse(self.predict_log(X))

    def relative_errors(self, X: np.ndarray, y_raw: np.ndarray) -> np.ndarray:
        """Per-row ``|predicted - actual| / actual`` in raw MB/s space.

        The drift score of the continuous loop: measured on freshly collected
        rows *before* they are ingested, a high median says the fitted model
        no longer describes the storage it is tuning."""
        pred = self.predict_throughput_batch(np.asarray(X, np.float64))
        y = np.asarray(y_raw, np.float64)
        return np.abs(pred - y) / np.maximum(np.abs(y), 1e-9)

    @property
    def feature_importances_(self):
        return getattr(self.model, "feature_importances_", None)

    # ------------------------------------------------------------------
    def save_reports(self, path: str):
        p = pathlib.Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(
            json.dumps({k: v.as_dict() for k, v in self.reports.items()}, indent=2)
        )
