"""Linear baselines (paper §3.3.1), in JAX.

- LinearRegression / Ridge: closed-form normal equations (jnp.linalg.solve).
- Lasso / ElasticNet: FISTA proximal gradient, sklearn objective conventions:
      Lasso:       (1/2n)||y - Xb||^2 + alpha ||b||_1
      ElasticNet:  (1/2n)||y - Xb||^2 + alpha*l1_ratio ||b||_1
                                     + 0.5*alpha*(1-l1_ratio) ||b||^2
Intercepts are unpenalized (fit on centered data, like sklearn).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["LinearRegression", "Ridge", "Lasso", "ElasticNet"]


@partial(jax.jit, static_argnames=())
def _solve_ridge(Xc, yc, alpha):
    d = Xc.shape[1]
    A = Xc.T @ Xc + alpha * jnp.eye(d, dtype=Xc.dtype)
    b = Xc.T @ yc
    return jnp.linalg.solve(A, b)


@partial(jax.jit, static_argnames=("n_iter",))
def _fista(Xc, yc, l1, l2, n_iter=2000):
    """Minimize (1/2n)||y-Xb||^2 + l1||b||_1 + (l2/2)||b||^2."""
    n, d = Xc.shape
    # Lipschitz constant of smooth part: (sigma_max^2 / n) + l2.
    sig = jnp.linalg.norm(Xc, ord=2)
    L = sig * sig / n + l2 + 1e-12
    step = 1.0 / L

    def soft(x, t):
        return jnp.sign(x) * jnp.maximum(jnp.abs(x) - t, 0.0)

    def body(_, carry):
        b, z, t = carry
        grad = Xc.T @ (Xc @ z - yc) / n + l2 * z
        b_new = soft(z - step * grad, step * l1)
        t_new = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t))
        z_new = b_new + (t - 1.0) / t_new * (b_new - b)
        return b_new, z_new, t_new

    b0 = jnp.zeros(d, Xc.dtype)
    b, _, _ = jax.lax.fori_loop(0, n_iter, body, (b0, b0, jnp.array(1.0, Xc.dtype)))
    return b


class _LinBase:
    def __init__(self):
        self.coef_ = None
        self.intercept_ = 0.0

    def _center(self, X, y):
        X = jnp.asarray(np.asarray(X, np.float64))
        y = jnp.asarray(np.asarray(y, np.float64))
        xm, ym = X.mean(0), y.mean()
        return X - xm, y - ym, xm, ym

    def _finish(self, coef, xm, ym):
        self.coef_ = np.asarray(coef)
        self.intercept_ = float(ym - jnp.dot(xm, coef))
        return self

    def predict(self, X):
        return np.asarray(X, np.float64) @ self.coef_ + self.intercept_


class LinearRegression(_LinBase):
    def fit(self, X, y):
        Xc, yc, xm, ym = self._center(X, y)
        return self._finish(_solve_ridge(Xc, yc, 1e-10), xm, ym)


class Ridge(_LinBase):
    def __init__(self, alpha: float = 1.0):
        super().__init__()
        self.alpha = alpha

    def fit(self, X, y):
        Xc, yc, xm, ym = self._center(X, y)
        return self._finish(_solve_ridge(Xc, yc, self.alpha), xm, ym)


class Lasso(_LinBase):
    def __init__(self, alpha: float = 0.1, n_iter: int = 2000):
        super().__init__()
        self.alpha, self.n_iter = alpha, n_iter

    def fit(self, X, y):
        Xc, yc, xm, ym = self._center(X, y)
        return self._finish(_fista(Xc, yc, self.alpha, 0.0, self.n_iter), xm, ym)


class ElasticNet(_LinBase):
    def __init__(self, alpha: float = 0.1, l1_ratio: float = 0.5, n_iter: int = 2000):
        super().__init__()
        self.alpha, self.l1_ratio, self.n_iter = alpha, l1_ratio, n_iter

    def fit(self, X, y):
        Xc, yc, xm, ym = self._center(X, y)
        l1 = self.alpha * self.l1_ratio
        l2 = self.alpha * (1.0 - self.l1_ratio)
        return self._finish(_fista(Xc, yc, l1, l2, self.n_iter), xm, ym)
