"""Optional native (C) kernels for the batched tree engine.

The batched engine's hot loops — per-(node, feature) histogram accumulation,
cumulative-gain evaluation and best-split selection, per-node G/H sums, and
the frontier row partition — are memory-bound in numpy: every elementwise
pass re-streams multi-megabyte arrays through DRAM, and every row gather
materializes a fresh copy.  The C kernels below take the frontier's row-index
array plus per-node ranges directly (no gathers, no zero-row compaction) and
evaluate the *same* double-precision operations in the *same* order per cell
(compiled with ``-ffp-contract=off`` so every multiply/divide/add is the
identical correctly-rounded IEEE operation numpy performs), which makes the
resulting trees bit-identical to the numpy path while doing one cache-
resident pass per node instead of ~fifteen DRAM passes per level.

The library is compiled lazily with the system C compiler into a per-user
cache directory and loaded via ctypes.  Anything going wrong — no compiler,
compile error, load error, failed self-test — silently disables the native
path; the numpy implementation in ``tree.py`` is always available and
produces identical results.  ``REPRO_TREE_NATIVE=0`` disables it explicitly.

Threading
---------
``segment_sums``, ``split_finder`` and ``partition`` accept a worker-thread
count (``REPRO_NATIVE_THREADS``, re-read at every fit — see
:func:`native_threads`).  Parallelism is *ownership partitioning*: the work
items (candidate nodes for ``split_finder``/``partition``, segments for
``segment_sums``) are split into contiguous chunks balanced by row count, and
each item is processed end-to-end by exactly one thread running the identical
sequential code — per-node G/H histogram accumulation stays in ascending-row
order, the per-node feature scan stays feature-major, and every result is
written to its fixed output slot.  No partial sum ever crosses a thread
boundary, so the combination order is the single-threaded order by
construction and results are bit-identical for any thread count (the
load-time self-test proves this for threads ∈ {1, 3}).  Threads are spawned
with raw ``pthread_create`` per call (no OpenMP runtime dependency); a failed
spawn degrades to inline execution of that chunk.

Kernels:

- ``segment_sums``: per-segment sums of ``vals[rows[...]]`` replicating
  numpy's pairwise blocking (n < 8 sequential; n <= 128 eight accumulators +
  sequential remainder; n <= 8192 recursive halving at multiples of 8; larger
  accumulated in sequential 8192-element blocks).  Verified bit-exact against
  ``np.sum`` in the load-time self-test.
- ``split_finder``: for each candidate node (a contiguous range of ``rows``),
  scatter its rows into per-feature gradient/hessian histograms (row-major
  ``Xb`` so each row costs one cache line; zero-weight rows contribute exact
  ``+0.0``) and select the best (feature, bin) cut by the XGBoost gain with
  the reference engine's exact operation order and first-occurrence
  tie-breaking.
- ``partition``: route each split node's rows left/right on its chosen
  (feature, bin) cut, emitting the next level's grouped row array
  (all-left-blocks then all-right-blocks) and per-node left counts.
- ``relabel_dfs``: the BFS -> reference-DFS node permutation walk (serial —
  the walk is inherently sequential and never hot).
"""

from __future__ import annotations

import ctypes
import hashlib
import os
import pathlib
import subprocess
import tempfile
import warnings
from typing import Optional

import numpy as np

__all__ = ["lib", "available", "native_threads", "MAX_THREADS"]

_SOURCE = r"""
#include <stdint.h>
#include <string.h>
#include <math.h>
#include <pthread.h>

/* ------------------------------------------------------------------ */
/* Worker pool: split [0, n) work items into <= nt contiguous chunks   */
/* balanced by per-item weight and run each chunk on its own thread.   */
/* Every item is processed by exactly one thread running the identical */
/* sequential code, so results are bit-identical for any nt.  Chunk 0  */
/* runs on the calling thread; a failed pthread_create degrades to     */
/* inline execution of that chunk.                                     */
/* ------------------------------------------------------------------ */

#define WT_MAX_THREADS 64

/* fn(ctx, chunk, lo, hi): process items [lo, hi) using per-thread slab
 * `chunk` (0 <= chunk < nt) for any scratch space. */
typedef void (*wt_fn)(void *ctx, int64_t chunk, int64_t lo, int64_t hi);

typedef struct {
    wt_fn fn;
    void *ctx;
    int64_t chunk, lo, hi;
} wt_task;

static void *wt_thread_main(void *arg)
{
    wt_task *t = (wt_task *)arg;
    t->fn(t->ctx, t->chunk, t->lo, t->hi);
    return NULL;
}

/* Per-item weight is wa[i] - (wb ? wb[i] : 0); wa == NULL means unit
 * weight.  Boundaries only affect load balance, never results. */
static void wt_run(wt_fn fn, void *ctx, int64_t n,
                   const int64_t *wa, const int64_t *wb, int64_t nt)
{
    if (nt > WT_MAX_THREADS) nt = WT_MAX_THREADS;
    if (nt > n) nt = n;
    if (nt <= 1) {
        fn(ctx, 0, 0, n);
        return;
    }
    int64_t bounds[WT_MAX_THREADS + 1];
    bounds[0] = 0;
    if (wa) {
        double total = 0.0;
        for (int64_t i = 0; i < n; i++)
            total += (double)(wa[i] - (wb ? wb[i] : 0));
        double acc = 0.0;
        int64_t c = 1;
        for (int64_t i = 0; i < n && c < nt; i++) {
            acc += (double)(wa[i] - (wb ? wb[i] : 0));
            while (c < nt && acc * (double)nt >= total * (double)c)
                bounds[c++] = i + 1;
        }
        while (c < nt) bounds[c++] = n;
        bounds[nt] = n;
    } else {
        for (int64_t c = 1; c <= nt; c++) bounds[c] = n * c / nt;
    }
    pthread_t tids[WT_MAX_THREADS];
    wt_task tasks[WT_MAX_THREADS];
    int started[WT_MAX_THREADS];
    for (int64_t c = 1; c < nt; c++) {
        tasks[c].fn = fn;
        tasks[c].ctx = ctx;
        tasks[c].chunk = c;
        tasks[c].lo = bounds[c];
        tasks[c].hi = bounds[c + 1];
        started[c] =
            pthread_create(&tids[c], NULL, wt_thread_main, &tasks[c]) == 0;
    }
    fn(ctx, 0, bounds[0], bounds[1]);
    for (int64_t c = 1; c < nt; c++) {
        if (started[c]) pthread_join(tids[c], NULL);
        else fn(ctx, tasks[c].chunk, tasks[c].lo, tasks[c].hi);
    }
}

/* numpy's pairwise summation blocking (see numpy loops.c.src), including the
 * reduce-buffer behaviour of accumulating 8192-element blocks sequentially,
 * applied to an index-gathered sequence vals[rows[i]].  Compiled with
 * -ffp-contract=off every add is the same rounded IEEE add numpy performs,
 * so results are bit-identical to np.sum of the gathered copy. */
static double pairwise_sum_idx(const double *vals, const int64_t *rows,
                               int64_t n)
{
    if (n < 8) {
        double res = 0.0;
        for (int64_t i = 0; i < n; i++) res += vals[rows[i]];
        return res;
    }
    if (n <= 128) {
        double r[8];
        int64_t i;
        for (i = 0; i < 8; i++) r[i] = vals[rows[i]];
        for (i = 8; i + 8 <= n; i += 8) {
            r[0] += vals[rows[i + 0]]; r[1] += vals[rows[i + 1]];
            r[2] += vals[rows[i + 2]]; r[3] += vals[rows[i + 3]];
            r[4] += vals[rows[i + 4]]; r[5] += vals[rows[i + 5]];
            r[6] += vals[rows[i + 6]]; r[7] += vals[rows[i + 7]];
        }
        double res = ((r[0] + r[1]) + (r[2] + r[3])) +
                     ((r[4] + r[5]) + (r[6] + r[7]));
        for (; i < n; i++) res += vals[rows[i]];
        return res;
    }
    if (n <= 8192) {
        int64_t n2 = n / 2;
        n2 -= n2 % 8;
        return pairwise_sum_idx(vals, rows, n2) +
               pairwise_sum_idx(vals, rows + n2, n - n2);
    }
    double res = pairwise_sum_idx(vals, rows, 8192);
    for (int64_t i = 8192; i < n; i += 8192) {
        int64_t blk = n - i < 8192 ? n - i : 8192;
        res += pairwise_sum_idx(vals, rows + i, blk);
    }
    return res;
}

typedef struct {
    const double *vals;
    const int64_t *rows, *starts, *counts;
    double *out;
} ss_ctx;

static void ss_range(void *arg, int64_t chunk, int64_t lo, int64_t hi)
{
    ss_ctx *c = (ss_ctx *)arg;
    (void)chunk;
    for (int64_t i = lo; i < hi; i++)
        c->out[i] = pairwise_sum_idx(c->vals, c->rows + c->starts[i],
                                     c->counts[i]);
}

/* Each segment is summed whole by one thread with the exact pairwise
 * blocking above, so the result is independent of nthreads. */
void segment_sums(const double *vals, const int64_t *rows,
                  const int64_t *starts, const int64_t *counts,
                  int64_t nseg, double *out, int64_t nthreads)
{
    ss_ctx c = {vals, rows, starts, counts, out};
    wt_run(ss_range, &c, nseg, counts, NULL, nthreads);
}

/* BFS ids -> the reference engine's DFS emission order.  perm[b] is the
 * reference id of BFS node b; the reference allocates both children when it
 * pops a split node and pops the right child first. */
void relabel_dfs(int64_t nn, const int64_t *feature, const int64_t *left,
                 const int64_t *right, int64_t *perm, int64_t *stack)
{
    int64_t top = 0, nxt = 1;
    perm[0] = 0;
    stack[top++] = 0;
    while (top > 0) {
        int64_t b = stack[--top];
        if (feature[b] >= 0) {
            int64_t l = left[b], r = right[b];
            perm[l] = nxt;
            perm[r] = nxt + 1;
            nxt += 2;
            stack[top++] = l;
            stack[top++] = r;
        }
    }
}

/* Best split per candidate node.  Node i's rows are rows[rstart[i] ..
 * rend[i]) — flat ids t*n + orig into grad/hess, orig into xb ([n, d]
 * row-major, so one row's d bins share a cache line).  hess == NULL means
 * all-ones hessians.  Histogram accumulation order is the row order
 * (ascending original ids within a node); prefix sums walk bins left to
 * right; the gain expression reproduces the reference engine's elementwise
 * operation order:
 *     0.5 * (GL*GL/(HL+lam) + GR*GR/(HR+lam) - parent) - gamma
 * Tie-breaking is first-occurrence over row-major (feature, bin) via strict
 * greater-than updates.  colmask (uint8 [M, d]) optionally restricts
 * features per node.  hist is caller scratch of nthreads*2*d*nbmax doubles
 * (one G/H histogram slab per worker thread); candidate nodes are divided
 * among threads weighted by row count, each node fully owned by one
 * thread. */
typedef struct {
    int64_t d, nbmax, n;
    const int64_t *rstart, *rend, *rows;
    const uint16_t *xb;
    const double *grad, *hess, *Gn, *Hn, *Pn;
    const int64_t *nb;
    const uint8_t *colmask;
    double lam, mcw, gamma;
    double *hist;
    double *best_gain;
    int64_t *best_j, *best_b;
    double *best_hl;
} sf_ctx;

static void sf_range(void *arg, int64_t chunk, int64_t lo, int64_t hi)
{
    sf_ctx *c = (sf_ctx *)arg;
    int64_t d = c->d, nbmax = c->nbmax, n = c->n;
    double *gh = c->hist + chunk * 2 * d * nbmax;
    double *hh = gh + d * nbmax;
    for (int64_t i = lo; i < hi; i++) {
        int64_t r0 = c->rstart[i], r1 = c->rend[i];
        double G = c->Gn[i], H = c->Hn[i], parent = c->Pn[i];
        memset(gh, 0, (size_t)(d * nbmax) * sizeof(double));
        memset(hh, 0, (size_t)(d * nbmax) * sizeof(double));
        if (c->hess) {
            for (int64_t r = r0; r < r1; r++) {
                int64_t id = c->rows[r];
                const uint16_t *xrow = c->xb + (id % n) * d;
                double g = c->grad[id], h = c->hess[id];
                for (int64_t j = 0; j < d; j++) {
                    gh[j * nbmax + xrow[j]] += g;
                    hh[j * nbmax + xrow[j]] += h;
                }
            }
        } else {
            for (int64_t r = r0; r < r1; r++) {
                int64_t id = c->rows[r];
                const uint16_t *xrow = c->xb + (id % n) * d;
                double g = c->grad[id];
                for (int64_t j = 0; j < d; j++) {
                    gh[j * nbmax + xrow[j]] += g;
                    hh[j * nbmax + xrow[j]] += 1.0;
                }
            }
        }
        double bg = -INFINITY, bhl = 0.0;
        int64_t bj = 0, bb = 0;
        for (int64_t j = 0; j < d; j++) {
            if (c->colmask && !c->colmask[i * d + j]) continue;
            int64_t nbj = c->nb[j];
            if (nbj <= 1) continue;
            const double *ghj = gh + j * nbmax;
            const double *hhj = hh + j * nbmax;
            double GL = 0.0, HL = 0.0;
            double fbg = -INFINITY, fhl = 0.0;
            int64_t fb = -1;
            for (int64_t b = 0; b < nbj - 1; b++) {
                GL += ghj[b];
                HL += hhj[b];
                if (HL < c->mcw) continue;
                double HR = H - HL;
                if (HR < c->mcw) continue;
                double GR = G - GL;
                double t3 = (GL * GL) / (HL + c->lam);
                double t6 = (GR * GR) / (HR + c->lam);
                double g = 0.5 * ((t3 + t6) - parent) - c->gamma;
                if (g > fbg) {
                    fbg = g;
                    fb = b;
                    fhl = HL;
                }
            }
            if (fb >= 0 && fbg > bg) {
                bg = fbg;
                bj = j;
                bb = fb;
                bhl = fhl;
            }
        }
        c->best_gain[i] = bg;
        c->best_j[i] = bj;
        c->best_b[i] = bb;
        c->best_hl[i] = bhl;
    }
}

void split_finder(int64_t M, int64_t d, int64_t nbmax, int64_t n,
                  const int64_t *rstart, const int64_t *rend,
                  const int64_t *rows, const uint16_t *xb,
                  const double *grad, const double *hess,
                  const double *Gn, const double *Hn, const double *Pn,
                  const int64_t *nb, const uint8_t *colmask,
                  double lam, double mcw, double gamma, double *hist,
                  double *best_gain, int64_t *best_j, int64_t *best_b,
                  double *best_hl, int64_t nthreads)
{
    sf_ctx c = {d, nbmax, n, rstart, rend, rows, xb, grad, hess,
                Gn, Hn, Pn, nb, colmask, lam, mcw, gamma, hist,
                best_gain, best_j, best_b, best_hl};
    wt_run(sf_range, &c, M, rend, rstart, nthreads);
}

/* Route each split node's rows left/right on its (feature, bin) cut.  The
 * output layout is the batched engine's next-level frontier: all left blocks
 * in node order, then all right blocks in node order, rows ascending within
 * each block.  scratch needs 2*S+2 int64.  Two parallel passes over nodes
 * (count, then scatter into disjoint precomputed ranges) with a serial
 * prefix-offset step between them; each node is owned by one thread in both
 * passes, so the output is independent of nthreads. */
typedef struct {
    int64_t d, n;
    const int64_t *rstart, *rend, *rows;
    const uint16_t *xb;
    const int64_t *sf, *sb;
    int64_t *out_rows, *lcounts, *loff, *roff;
} pt_ctx;

static void pt_count_range(void *arg, int64_t chunk, int64_t lo, int64_t hi)
{
    pt_ctx *c = (pt_ctx *)arg;
    (void)chunk;
    for (int64_t i = lo; i < hi; i++) {
        int64_t j = c->sf[i], b = c->sb[i], cnt = 0;
        for (int64_t r = c->rstart[i]; r < c->rend[i]; r++) {
            int64_t id = c->rows[r];
            cnt += c->xb[(id % c->n) * c->d + j] <= b;
        }
        c->lcounts[i] = cnt;
    }
}

static void pt_scatter_range(void *arg, int64_t chunk, int64_t lo, int64_t hi)
{
    pt_ctx *c = (pt_ctx *)arg;
    (void)chunk;
    for (int64_t i = lo; i < hi; i++) {
        int64_t j = c->sf[i], b = c->sb[i];
        int64_t lo_ = c->loff[i], ro_ = c->roff[i];
        for (int64_t r = c->rstart[i]; r < c->rend[i]; r++) {
            int64_t id = c->rows[r];
            if (c->xb[(id % c->n) * c->d + j] <= b) c->out_rows[lo_++] = id;
            else c->out_rows[ro_++] = id;
        }
    }
}

void partition(int64_t S, int64_t d, int64_t n,
               const int64_t *rstart, const int64_t *rend,
               const int64_t *rows, const uint16_t *xb,
               const int64_t *sf, const int64_t *sb,
               int64_t *out_rows, int64_t *lcounts, int64_t *scratch,
               int64_t nthreads)
{
    int64_t *loff = scratch;
    int64_t *roff = scratch + S + 1;
    pt_ctx c = {d, n, rstart, rend, rows, xb, sf, sb,
                out_rows, lcounts, loff, roff};
    wt_run(pt_count_range, &c, S, rend, rstart, nthreads);
    int64_t acc = 0;
    for (int64_t i = 0; i < S; i++) { loff[i] = acc; acc += lcounts[i]; }
    for (int64_t i = 0; i < S; i++) {
        roff[i] = acc;
        acc += (rend[i] - rstart[i]) - lcounts[i];
    }
    wt_run(pt_scatter_range, &c, S, rend, rstart, nthreads);
}
"""

_CFLAGS = [
    "-O2", "-fPIC", "-shared", "-pthread",
    "-ffp-contract=off", "-fno-fast-math",
]

#: Hard cap on worker threads (tid/scratch arrays in the C pool are fixed).
MAX_THREADS = 64

_lib: Optional[ctypes.CDLL] = None
_tried = False

_warned_threads: set = set()


def native_threads() -> int:
    """Worker-thread count for the parallel kernels.

    Reads ``REPRO_NATIVE_THREADS`` on *every* call (the kernel wrappers call
    it per invocation, so ``os.environ`` changes take effect at the next fit
    — mirroring ``resolve_engine``'s late read of ``REPRO_TREE_ENGINE``).
    Values that are not positive integers (``0``, negatives, non-ints) fall
    back to 1 with a single warning per distinct bad value.  Results are
    bit-identical at any setting; only wall-clock changes.
    """
    raw = os.environ.get("REPRO_NATIVE_THREADS")
    if raw is None:
        return 1
    try:
        val = int(str(raw).strip())
    except ValueError:
        val = -1
    if val < 1:
        if raw not in _warned_threads:
            _warned_threads.add(raw)
            warnings.warn(
                f"REPRO_NATIVE_THREADS={raw!r} is not a positive integer; "
                "falling back to 1 thread",
                RuntimeWarning,
                stacklevel=2,
            )
        return 1
    return min(val, MAX_THREADS)


def _cache_dir() -> pathlib.Path:
    base = os.environ.get("XDG_CACHE_HOME") or os.path.join(
        os.path.expanduser("~"), ".cache"
    )
    return pathlib.Path(base) / "repro_io" / "native"


def _compile() -> Optional[pathlib.Path]:
    tag = hashlib.sha256((_SOURCE + " ".join(_CFLAGS)).encode()).hexdigest()[:16]
    for cc in ("cc", "gcc", "clang"):
        try:
            d = _cache_dir()
            d.mkdir(parents=True, exist_ok=True)
        except OSError:
            d = pathlib.Path(tempfile.mkdtemp(prefix="repro_native_"))
        so = d / f"fast_hist_{tag}.so"
        if so.exists():
            return so
        src = d / f"fast_hist_{tag}.c"
        try:
            src.write_text(_SOURCE)
            tmp = d / f".fast_hist_{tag}.{os.getpid()}.so"
            res = subprocess.run(
                [cc, *_CFLAGS, "-o", str(tmp), str(src)],
                capture_output=True,
                timeout=120,
            )
            if res.returncode == 0:
                os.replace(tmp, so)  # atomic vs concurrent builders
                return so
        except (OSError, subprocess.SubprocessError):
            continue
    return None


_I64 = ctypes.POINTER(ctypes.c_int64)
_F64 = ctypes.POINTER(ctypes.c_double)
_U16 = ctypes.POINTER(ctypes.c_uint16)
_U8 = ctypes.POINTER(ctypes.c_uint8)
_i64 = ctypes.c_int64
_f64 = ctypes.c_double


def _bind(lib: ctypes.CDLL) -> ctypes.CDLL:
    lib.segment_sums.restype = None
    lib.segment_sums.argtypes = [_F64, _I64, _I64, _I64, _i64, _F64, _i64]
    lib.relabel_dfs.restype = None
    lib.relabel_dfs.argtypes = [_i64, _I64, _I64, _I64, _I64, _I64]
    lib.split_finder.restype = None
    lib.split_finder.argtypes = [
        _i64, _i64, _i64, _i64, _I64, _I64, _I64, _U16,
        _F64, _F64, _F64, _F64, _F64, _I64, _U8,
        _f64, _f64, _f64, _F64, _F64, _I64, _I64, _F64, _i64,
    ]
    lib.partition.restype = None
    lib.partition.argtypes = [
        _i64, _i64, _i64, _I64, _I64, _I64, _U16, _I64, _I64,
        _I64, _I64, _I64, _i64,
    ]
    return lib


def _p(a, typ):
    return a.ctypes.data_as(typ)


def _c64(a):
    return np.ascontiguousarray(a, np.int64)


def _selftest(lib: ctypes.CDLL) -> bool:
    """Bit-exactness probe: the native kernels must reproduce numpy exactly.

    Every kernel runs at 1 and 3 worker threads; both must match the numpy
    transcription bit-for-bit (ownership partitioning makes the threaded
    result the single-threaded result by construction — this check keeps it
    that way).
    """
    rng = np.random.default_rng(20260729)
    # -- segment_sums vs np.sum over the full blocking regime ------------
    lens = np.asarray(
        list(range(0, 140)) + [200, 1000, 8192, 8193, 9999, 20000], np.int64
    )
    total = int(lens.sum())
    vals = rng.normal(size=total) * 10.0 ** rng.integers(-8, 8, size=total)
    rows = rng.permutation(total).astype(np.int64)
    starts = np.concatenate([[0], np.cumsum(lens)[:-1]])
    want = np.asarray(
        [vals[rows[s : s + c]].sum() for s, c in zip(starts, lens)]
    )
    for nt in (1, 3):
        out = np.empty(lens.size)
        lib.segment_sums(
            _p(vals, _F64), _p(rows, _I64), _p(starts, _I64), _p(lens, _I64),
            _i64(lens.size), _p(out, _F64), _i64(nt),
        )
        if not np.array_equal(out, want):
            return False
    # -- split_finder + partition vs a literal numpy transcription -------
    n, d, nbmax, M = 120, 3, 9, 4
    xb = rng.integers(0, nbmax, size=(n, d)).astype(np.uint16)
    nb = np.full(d, nbmax, np.int64)
    rows = np.sort(rng.permutation(n)[: M * 25]).astype(np.int64)
    rstart = np.arange(M, dtype=np.int64) * 25
    rend = rstart + 25
    grad = rng.normal(size=n)
    hess = rng.integers(0, 3, size=n).astype(np.float64)
    lam, mcw, gamma = 1.0, 0.5, 0.01
    Gn = np.empty(M)
    Hn = np.empty(M)
    for i in range(M):
        Gn[i] = grad[rows[rstart[i] : rend[i]]].sum()
        Hn[i] = hess[rows[rstart[i] : rend[i]]].sum()
    Pn = Gn * Gn / (Hn + lam)
    ref = None
    for nt in (1, 3):
        bg = np.empty(M)
        bj = np.empty(M, np.int64)
        bb = np.empty(M, np.int64)
        bhl = np.empty(M)
        lib.split_finder(
            _i64(M), _i64(d), _i64(nbmax), _i64(n), _p(rstart, _I64),
            _p(rend, _I64), _p(rows, _I64), _p(xb, _U16), _p(grad, _F64),
            _p(hess, _F64), _p(Gn, _F64), _p(Hn, _F64), _p(Pn, _F64),
            _p(nb, _I64), None, _f64(lam), _f64(mcw), _f64(gamma),
            _p(np.empty(nt * 2 * d * nbmax), _F64),
            _p(bg, _F64), _p(bj, _I64), _p(bb, _I64), _p(bhl, _F64),
            _i64(nt),
        )
        if ref is None:
            ref = (bg.copy(), bj.copy(), bb.copy(), bhl.copy())
        elif not all(
            np.array_equal(a, b) for a, b in zip(ref, (bg, bj, bb, bhl))
        ):
            return False
    bg, bj, bb, bhl = ref
    for i in range(M):
        best = (-np.inf, 0, 0)
        r = rows[rstart[i] : rend[i]]
        for j in range(d):
            b_ = xb[r, j]
            Gh = np.bincount(b_, weights=grad[r], minlength=nbmax)
            Hh = np.bincount(b_, weights=hess[r], minlength=nbmax)
            GL = np.cumsum(Gh)[:-1]
            HL = np.cumsum(Hh)[:-1]
            GR = Gn[i] - GL
            HR = Hn[i] - HL
            ok = (HL >= mcw) & (HR >= mcw)
            with np.errstate(divide="ignore", invalid="ignore"):
                gain = 0.5 * (
                    GL * GL / (HL + lam) + GR * GR / (HR + lam) - Pn[i]
                ) - gamma
            gain = np.where(ok, gain, -np.inf)
            if ok.any():
                bi = int(np.argmax(gain))
                if gain[bi] > best[0]:
                    best = (float(gain[bi]), j, bi)
        if best[0] != bg[i] or (
            np.isfinite(bg[i]) and (best[1] != bj[i] or best[2] != bb[i])
        ):
            return False
    # partition: lefts-then-rights, ascending within each block
    want_rows = None
    for nt in (1, 3):
        out_rows = np.empty(rows.size, np.int64)
        lcounts = np.empty(M, np.int64)
        lib.partition(
            _i64(M), _i64(d), _i64(n), _p(rstart, _I64), _p(rend, _I64),
            _p(rows, _I64), _p(xb, _U16), _p(bj, _I64), _p(bb, _I64),
            _p(out_rows, _I64), _p(lcounts, _I64),
            _p(np.empty(2 * M + 2, np.int64), _I64), _i64(nt),
        )
        if want_rows is None:
            lefts, rights = [], []
            for i in range(M):
                r = rows[rstart[i] : rend[i]]
                go = xb[r, bj[i]] <= bb[i]
                lefts.append(r[go])
                rights.append(r[~go])
                if lcounts[i] != int(go.sum()):
                    return False
            want_rows = np.concatenate(lefts + rights)
            want_lcounts = lcounts.copy()
        if not np.array_equal(out_rows, want_rows):
            return False
        if not np.array_equal(lcounts, want_lcounts):
            return False
    return True


def lib() -> Optional[ctypes.CDLL]:
    """The compiled kernel library, or None if unavailable/disabled."""
    global _lib, _tried
    if _tried:
        return _lib
    _tried = True
    if os.environ.get("REPRO_TREE_NATIVE", "1") in ("0", "false", "no"):
        return None
    try:
        so = _compile()
        if so is None:
            return None
        cand = _bind(ctypes.CDLL(str(so)))
        if not _selftest(cand):
            return None
        _lib = cand
    except Exception:  # noqa: BLE001 — any failure means "no native path"
        _lib = None
    return _lib


def available() -> bool:
    return lib() is not None


# ---------------------------------------------------------------------------
# numpy-facing wrappers (callers must have checked ``available()``)
# ---------------------------------------------------------------------------


def segment_sums(vals, rows, starts, counts, out, nthreads=None):
    """out[i] = vals[rows[starts[i]:starts[i]+counts[i]]].sum() (pairwise)."""
    nt = native_threads() if nthreads is None else nthreads
    lib().segment_sums(
        _p(np.ascontiguousarray(vals, np.float64), _F64),
        _p(_c64(rows), _I64), _p(_c64(starts), _I64), _p(_c64(counts), _I64),
        _i64(counts.shape[0]), _p(out, _F64), _i64(nt),
    )
    return out


def relabel_dfs(feature, left, right):
    """BFS -> reference-DFS permutation for one finished tree."""
    nn = feature.shape[0]
    perm = np.empty(nn, np.int64)
    stack = np.empty(nn + 2, np.int64)
    lib().relabel_dfs(
        _i64(nn), _p(_c64(feature), _I64), _p(_c64(left), _I64),
        _p(_c64(right), _I64), _p(perm, _I64), _p(stack, _I64),
    )
    return perm


def split_finder(rstart, rend, rows, xb, grad, hess, Gn, Hn, Pn, nb, colmask,
                 lam, mcw, gamma, out_gain, out_j, out_b, out_hl,
                 nthreads=None):
    M = rstart.shape[0]
    n, d = xb.shape
    nbmax = int(nb.max()) if d else 1
    nt = native_threads() if nthreads is None else nthreads
    nt = max(1, min(nt, MAX_THREADS))
    hist = np.empty(nt * 2 * d * nbmax)
    if colmask is not None:
        colmask = np.ascontiguousarray(colmask).view(np.uint8)
    lib().split_finder(
        _i64(M), _i64(d), _i64(nbmax), _i64(n),
        _p(_c64(rstart), _I64), _p(_c64(rend), _I64), _p(_c64(rows), _I64),
        _p(xb, _U16),
        _p(np.ascontiguousarray(grad, np.float64), _F64),
        None if hess is None else _p(np.ascontiguousarray(hess, np.float64), _F64),
        _p(np.ascontiguousarray(Gn), _F64), _p(np.ascontiguousarray(Hn), _F64),
        _p(np.ascontiguousarray(Pn), _F64), _p(_c64(nb), _I64),
        None if colmask is None else _p(colmask, _U8),
        _f64(lam), _f64(mcw), _f64(gamma), _p(hist, _F64),
        _p(out_gain, _F64), _p(out_j, _I64), _p(out_b, _I64),
        _p(out_hl, _F64), _i64(nt),
    )


def partition(rstart, rend, rows, xb, sf, sb, nthreads=None):
    """Returns (out_rows, lcounts): next-level grouped rows + left counts."""
    S = rstart.shape[0]
    n, d = xb.shape
    rstart = _c64(rstart)
    rend = _c64(rend)
    total = int((rend - rstart).sum())
    out_rows = np.empty(total, np.int64)
    lcounts = np.empty(S, np.int64)
    scratch = np.empty(2 * S + 2, np.int64)
    nt = native_threads() if nthreads is None else nthreads
    lib().partition(
        _i64(S), _i64(d), _i64(n), _p(rstart, _I64), _p(rend, _I64),
        _p(_c64(rows), _I64), _p(xb, _U16), _p(_c64(sf), _I64),
        _p(_c64(sb), _I64), _p(out_rows, _I64), _p(lcounts, _I64),
        _p(scratch, _I64), _i64(nt),
    )
    return out_rows, lcounts
