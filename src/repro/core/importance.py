"""Feature-importance analysis (paper §4.3).

Two built-in notions come from the models themselves (gain-based for GBT,
impurity/gain for RF — both exposed as ``feature_importances_``); this module
adds model-agnostic permutation importance for cross-checking the paper's
claim that throughput metrics + batch size dominate.
"""

from __future__ import annotations

from typing import Sequence

import numpy as np

from .metrics import r2_score

__all__ = ["permutation_importance", "rank_features"]


def permutation_importance(
    model, X: np.ndarray, y: np.ndarray, n_repeats: int = 5, seed: int = 0
) -> np.ndarray:
    """Mean R2 drop when each column is shuffled."""
    rng = np.random.default_rng(seed)
    base = r2_score(y, model.predict(X))
    n, d = X.shape
    drops = np.zeros(d)
    for j in range(d):
        tot = 0.0
        for _ in range(n_repeats):
            Xp = X.copy()
            Xp[:, j] = Xp[rng.permutation(n), j]
            tot += base - r2_score(y, model.predict(Xp))
        drops[j] = tot / n_repeats
    return drops


def rank_features(importances: np.ndarray, names: Sequence[str]) -> list[tuple[str, float]]:
    order = np.argsort(importances)[::-1]
    return [(names[i], float(importances[i])) for i in order]
