"""Beyond-paper: uncertainty quantification + ensemble stacking — both named
as future work in the paper (§5.4 "Add prediction intervals", "Try ensemble
stacking").

- Prediction intervals: RF per-tree spread (quantiles of the bootstrap
  ensemble) and GBT residual-conformal intervals (split-conformal: hold-out
  residual quantile added to point predictions — distribution-free coverage).
- Stacking: ridge meta-learner over out-of-fold predictions of the zoo.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import jax
import numpy as np

from .ensemble_base import PackedEnsemble
from .forest import RandomForestRegressor
from .linear import Ridge
from .metrics import kfold_indices
from .tree import TreeArrays, predict_tree_np

__all__ = ["rf_prediction_interval", "ConformalRegressor", "StackingRegressor"]


def _per_tree_predictions(ens: PackedEnsemble, X: np.ndarray) -> np.ndarray:
    """[n_trees, n] matrix of per-tree outputs (numpy path)."""
    out = np.zeros((ens.n_trees, X.shape[0]))
    for b in range(ens.n_trees):
        t = TreeArrays(
            feature=np.asarray(ens.feature[b]), threshold=np.asarray(ens.threshold[b]),
            left=np.asarray(ens.left[b]), right=np.asarray(ens.right[b]),
            value=np.asarray(ens.value[b]),
            gain=np.zeros(1), cover=np.zeros(1),
        )
        out[b] = predict_tree_np(t, X, ens.max_depth)
    return out


def rf_prediction_interval(
    model: RandomForestRegressor, X: np.ndarray, alpha: float = 0.1
):
    """(lo, mid, hi) from the bootstrap-tree distribution (RF ensemble spread)."""
    ens = model.ensemble
    per_tree = ens.base_score + _per_tree_predictions(ens, X)  # each tree is mean-offset
    lo = np.quantile(per_tree, alpha / 2, axis=0)
    hi = np.quantile(per_tree, 1 - alpha / 2, axis=0)
    return lo, per_tree.mean(axis=0), hi


class ConformalRegressor:
    """Split-conformal wrapper: distribution-free 1-alpha coverage intervals
    around any point regressor."""

    def __init__(self, base_model, calib_frac: float = 0.25, seed: int = 0):
        self.base = base_model
        self.calib_frac = calib_frac
        self.seed = seed
        self.q_: Optional[float] = None

    def fit(self, X: np.ndarray, y: np.ndarray, alpha: float = 0.1):
        rng = np.random.default_rng(self.seed)
        n = X.shape[0]
        perm = rng.permutation(n)
        n_cal = max(2, int(round(self.calib_frac * n)))
        cal, tr = perm[:n_cal], perm[n_cal:]
        self.base.fit(X[tr], y[tr])
        resid = np.abs(y[cal] - self.base.predict(X[cal]))
        k = min(int(np.ceil((1 - alpha) * (n_cal + 1))), n_cal)
        self.q_ = float(np.sort(resid)[k - 1])
        return self

    def predict_interval(self, X: np.ndarray):
        mid = self.base.predict(X)
        return mid - self.q_, mid, mid + self.q_


class StackingRegressor:
    """Out-of-fold stacking: base models' OOF predictions -> ridge meta."""

    def __init__(self, make_models: Dict[str, callable], k: int = 5,
                 meta_alpha: float = 1.0, seed: int = 42):
        self.make_models = make_models
        self.k = k
        self.meta = Ridge(alpha=meta_alpha)
        self.models_ = {}
        self.seed = seed

    def fit(self, X: np.ndarray, y: np.ndarray):
        n = X.shape[0]
        oof = np.zeros((n, len(self.make_models)))
        for j, (name, mk) in enumerate(self.make_models.items()):
            for tr, te in kfold_indices(n, self.k, self.seed):
                m = mk()
                m.fit(X[tr], y[tr])
                oof[te, j] = m.predict(X[te])
            final = mk()
            final.fit(X, y)
            self.models_[name] = final
        self.meta.fit(oof, y)
        return self

    def predict(self, X: np.ndarray) -> np.ndarray:
        base = np.stack([m.predict(X) for m in self.models_.values()], axis=1)
        return self.meta.predict(base)
