"""MLP regressor (paper §3.3.3): hidden (64,32,16), ReLU, Adam, L2 alpha=1e-3,
early stopping patience=10 on a 10% validation split. Pure JAX."""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["MLPConfig", "MLPRegressor"]


@dataclasses.dataclass(frozen=True)
class MLPConfig:
    hidden: tuple = (64, 32, 16)
    l2: float = 1e-3
    lr: float = 1e-3
    max_epochs: int = 500
    batch_size: int = 32
    patience: int = 10
    val_frac: float = 0.1
    seed: int = 0


def _init(key, sizes):
    params = []
    for i in range(len(sizes) - 1):
        key, k = jax.random.split(key)
        w = jax.random.normal(k, (sizes[i], sizes[i + 1])) * jnp.sqrt(2.0 / sizes[i])
        params.append({"w": w, "b": jnp.zeros(sizes[i + 1])})
    return params


def _forward(params, x):
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            x = jax.nn.relu(x)
    return x[..., 0]


def _loss(params, x, y, l2):
    pred = _forward(params, x)
    mse = jnp.mean((pred - y) ** 2)
    reg = sum(jnp.sum(p["w"] ** 2) for p in params)
    return mse + l2 * reg


@partial(jax.jit, static_argnames=("l2", "lr"))
def _adam_step(params, opt, x, y, l2, lr):
    m, v, t = opt
    grads = jax.grad(_loss)(params, x, y, l2)
    t = t + 1
    b1, b2, eps = 0.9, 0.999, 1e-8
    m = jax.tree.map(lambda a, g: b1 * a + (1 - b1) * g, m, grads)
    v = jax.tree.map(lambda a, g: b2 * a + (1 - b2) * g * g, v, grads)
    mhat = jax.tree.map(lambda a: a / (1 - b1**t), m)
    vhat = jax.tree.map(lambda a: a / (1 - b2**t), v)
    params = jax.tree.map(
        lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mhat, vhat
    )
    return params, (m, v, t)


class MLPRegressor:
    def __init__(self, config: Optional[MLPConfig] = None, **kw):
        self.config = config or MLPConfig(**kw)
        self.params = None

    def fit(self, X: np.ndarray, y: np.ndarray):
        cfg = self.config
        X = jnp.asarray(X, jnp.float32)
        y = jnp.asarray(y, jnp.float32)
        n, d = X.shape
        rng = np.random.default_rng(cfg.seed)
        perm = rng.permutation(n)
        n_val = max(1, int(round(cfg.val_frac * n)))
        vi, ti = perm[:n_val], perm[n_val:]
        Xt, yt, Xv, yv = X[ti], y[ti], X[vi], y[vi]

        key = jax.random.PRNGKey(cfg.seed)
        params = _init(key, (d, *cfg.hidden, 1))
        zeros = jax.tree.map(jnp.zeros_like, params)
        opt = (zeros, jax.tree.map(jnp.zeros_like, params), jnp.int32(0))

        best_val, best_params, bad = np.inf, params, 0
        nt = Xt.shape[0]
        for epoch in range(cfg.max_epochs):
            order = rng.permutation(nt)
            for s in range(0, nt, cfg.batch_size):
                idx = order[s : s + cfg.batch_size]
                params, opt = _adam_step(params, opt, Xt[idx], yt[idx], cfg.l2, cfg.lr)
            val = float(jnp.mean((_forward(params, Xv) - yv) ** 2))
            if val < best_val - 1e-7:
                best_val, best_params, bad = val, params, 0
            else:
                bad += 1
                if bad >= cfg.patience:
                    break
        self.params = best_params
        return self

    def predict(self, X: np.ndarray) -> np.ndarray:
        assert self.params is not None, "fit() first"
        return np.asarray(_forward(self.params, jnp.asarray(X, jnp.float32)))
