"""Feature engineering (paper §3.2): the 11-feature spec, log1p target
transform, StandardScaler, and PCA (JAX-backed).

jax is imported lazily (only ``PCA.fit`` needs it): this module sits on the
fleet collector's import path via ``repro.data.campaign``, and collector
processes — spawned once per cycle per shard — should not pay jax's import
cost just to run I/O benchmarks."""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

__all__ = [
    "FEATURE_NAMES",
    "AUTOTUNE_FEATURE_NAMES",
    "HOST_PROFILE_FEATURE_NAMES",
    "TRANSFER_FEATURE_NAMES",
    "transfer_spec",
    "FeatureSpec",
    "log1p_transform",
    "expm1_inverse",
    "StandardScaler",
    "PCA",
]

# The paper's 11 numeric features (§3.2.1), in canonical column order.
FEATURE_NAMES = (
    "block_kb",
    "file_size_mb",
    "n_samples",
    "throughput_mb_s",
    "iops",
    "n_threads",
    "batch_size",
    "samples_per_second",
    "data_loading_ratio",
    "num_workers",
    "aggregate_throughput_mb_s",
)

TARGET_NAME = "target_throughput"

# Beyond-paper prefetch knobs (``data/prefetch.py``), appended for the online
# tuner's feature view so it can rank/learn them; the offline predictor keeps
# the paper's 11-feature spec above.  ``prefetch_policy`` is the numeric
# policy code (0=off, 1=depth, 2=clairvoyant).
AUTOTUNE_FEATURE_NAMES = FEATURE_NAMES + (
    "prefetch_policy",
    "lookahead_batches",
    "cache_budget_mb",
)

# Host-profile features (``core/transfer.py``): who measured a row, not what
# was measured.  Derived per storage backend / host from fleet provenance and
# baseline microbench fingerprints, and appended to the paper spec so one
# model can be trained across heterogeneous backends and evaluated
# leave-one-backend-out.  ``backend_class`` is the numeric backend code
# (``transfer.BACKEND_CLASSES``).
HOST_PROFILE_FEATURE_NAMES = (
    "backend_class",
    "host_cpu_count",
    "host_page_cache_mb",
    "baseline_read_mb_s",
    "baseline_write_mb_s",
)

TRANSFER_FEATURE_NAMES = FEATURE_NAMES + HOST_PROFILE_FEATURE_NAMES


def transfer_spec() -> "FeatureSpec":
    """The cross-backend spec: paper features + host-profile columns."""
    return FeatureSpec(names=TRANSFER_FEATURE_NAMES)


@dataclasses.dataclass(frozen=True)
class FeatureSpec:
    names: tuple = FEATURE_NAMES
    target: str = TARGET_NAME

    @property
    def n_features(self) -> int:
        return len(self.names)

    def matrix(self, obs: dict) -> np.ndarray:
        """dict of column arrays -> [n, n_features] float64 matrix."""
        cols = [np.asarray(obs[name], np.float64) for name in self.names]
        return np.stack(cols, axis=1)

    def row(self, config: dict, default: float = 0.0) -> np.ndarray:
        return np.asarray(
            [float(config.get(name, default)) for name in self.names], np.float64
        )

    def matrix_from_candidates(
        self,
        columns: Dict[str, np.ndarray],
        n: int,
        context: Optional[dict] = None,
        default: float = 0.0,
    ) -> np.ndarray:
        """Vectorized candidate featurization: [n, n_features] from per-knob
        value columns plus scalar ``context`` fallbacks.

        Replaces the per-candidate dict-merge + ``row()`` loop: each feature
        column is either a grid column (one [n] copy) or a scalar fill.
        ``columns`` takes precedence over ``context``, mirroring the old
        ``{**context, **candidate}`` merge semantics.
        """
        context = context or {}
        X = np.empty((n, self.n_features), np.float64)
        for k, name in enumerate(self.names):
            col = columns.get(name)
            if col is not None:
                X[:, k] = col
            else:
                X[:, k] = float(context.get(name, default))
        return X


def log1p_transform(y: np.ndarray) -> np.ndarray:
    return np.log1p(np.asarray(y, np.float64))


def expm1_inverse(y_log: np.ndarray) -> np.ndarray:
    return np.expm1(np.asarray(y_log, np.float64))


class StandardScaler:
    def __init__(self):
        self.mean_ = None
        self.scale_ = None

    def fit(self, X: np.ndarray):
        X = np.asarray(X, np.float64)
        self.mean_ = X.mean(axis=0)
        std = X.std(axis=0)
        self.scale_ = np.where(std > 0, std, 1.0)
        return self

    def transform(self, X: np.ndarray) -> np.ndarray:
        return (np.asarray(X, np.float64) - self.mean_) / self.scale_

    def fit_transform(self, X: np.ndarray) -> np.ndarray:
        return self.fit(X).transform(X)

    def inverse_transform(self, Xs: np.ndarray) -> np.ndarray:
        return np.asarray(Xs, np.float64) * self.scale_ + self.mean_


class PCA:
    """PCA via SVD of the centered, standardized-optional matrix (paper §3.2.3)."""

    def __init__(self, n_components: Optional[int] = None):
        self.n_components = n_components
        self.components_ = None
        self.explained_variance_ = None
        self.explained_variance_ratio_ = None
        self.mean_ = None

    def fit(self, X: np.ndarray):
        import jax.numpy as jnp  # deferred: see module docstring

        X = jnp.asarray(np.asarray(X, np.float64))
        self.mean_ = np.asarray(X.mean(axis=0))
        Xc = X - X.mean(axis=0)
        _, s, vt = jnp.linalg.svd(Xc, full_matrices=False)
        var = np.asarray(s) ** 2 / (X.shape[0] - 1)
        k = self.n_components or vt.shape[0]
        self.components_ = np.asarray(vt)[:k]
        self.explained_variance_ = var[:k]
        self.explained_variance_ratio_ = var[:k] / var.sum()
        return self

    def transform(self, X: np.ndarray) -> np.ndarray:
        return np.asarray((np.asarray(X, np.float64) - self.mean_) @ self.components_.T)

    def fit_transform(self, X):
        return self.fit(X).transform(X)

    def inverse_transform(self, Z: np.ndarray) -> np.ndarray:
        return np.asarray(Z) @ self.components_ + self.mean_

    def n_components_for_variance(self, frac: float) -> int:
        cum = np.cumsum(self.explained_variance_ratio_)
        return int(np.searchsorted(cum, frac) + 1)
