"""Configuration recommendation + online pipeline autotuning (paper §5.2).

Two layers:

1. ``recommend()`` — the paper's offline use-case: enumerate a candidate grid
   of pipeline knobs, featurize each candidate, predict log-throughput with a
   fitted ``IOPerformancePredictor``, return ranked configs.  Small grids are
   ONE batched JAX ensemble inference (milliseconds for 10^5 candidates) over
   a cached feature matrix — per ``decide()`` only the scalar context columns
   are rewritten in place (zero per-candidate Python work).  Mega grids
   (``MEGA_GRID_MIN``+ candidates) with a GBT/RF predictor are scored in
   fixed-size float32 chunks through the packed-ensemble program — the Pallas
   one-hot-matmul kernel on TPU, the jitted dense descent elsewhere — so the
   per-tree intermediates stay VMEM/cache-resident instead of spilling
   O(n_candidates x n_trees) floats to DRAM; the classic numpy path remains
   the oracle (``scorer="oracle"``).

2. ``OnlineAutotuner`` — the framework integration: lives inside the trainer
   (step-granularity telemetry) or behind the ``repro.service`` loop/fleet
   (cycle-granularity campaign batches via ``ingest_records``), periodically
   refits, and proposes a reconfiguration whenever the predicted gain over the
   current config exceeds a threshold. This is the paper's "days -> minutes"
   loop run continuously, and doubles as straggler mitigation (a slow host
   re-tunes its own pipeline from its own telemetry).  Observations land in an
   incremental column store (amortized-doubling buffer), so a refit hands the
   model a zero-copy view of history instead of re-materializing every row.
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from .ensemble_base import PackedEnsemble, ceil_pow2, predict_ensemble
from .features import AUTOTUNE_FEATURE_NAMES, FeatureSpec
from .predictor import IOPerformancePredictor, PredictorSnapshot

__all__ = [
    "ConfigSpace",
    "recommend",
    "score_grid",
    "OnlineAutotuner",
    "AutotuneDecision",
    "DEFAULT_SPACE",
    "MEGA_GRID_MIN",
    "MEGA_GRID_CHUNK",
]

KNOB_NAMES = ("batch_size", "num_workers", "block_kb", "n_threads", "prefetch_depth",
              "prefetch_policy", "lookahead_batches", "cache_budget_mb")


@dataclasses.dataclass(frozen=True)
class ConfigSpace:
    """Discrete grid over the tunable pipeline knobs (paper §3.1 parameters).

    The expanded grid (per-knob columns, candidate dicts, and per-spec feature
    matrices) is cached on the instance: ``OnlineAutotuner.decide`` calls
    ``recommend`` every step, and rebuilding 1,800+ row grids from dicts each
    time used to dominate the serving path.
    """

    batch_size: Sequence[int] = (16, 32, 64, 128, 256)
    num_workers: Sequence[int] = (0, 1, 2, 4, 8)
    block_kb: Sequence[int] = (4, 16, 64, 256, 1024, 4096)
    n_threads: Sequence[int] = (1, 2, 4, 8)
    prefetch_depth: Sequence[int] = (1, 2, 4)  # beyond-paper knob
    # prefetch-policy knobs (data/prefetch.py) — numeric policy codes
    # (0=off, 1=depth, 2=clairvoyant); single-valued by default so the
    # paper's 1,800-config grid is unchanged unless a campaign varies them
    prefetch_policy: Sequence[int] = (1,)
    lookahead_batches: Sequence[int] = (8,)
    cache_budget_mb: Sequence[float] = (64.0,)

    def __post_init__(self):
        for k in KNOB_NAMES:  # normalize to tuples (hashable, immutable)
            object.__setattr__(self, k, tuple(getattr(self, k)))
        object.__setattr__(self, "_cache", {})

    # -- grid expansion (cached) ---------------------------------------
    @property
    def n_candidates(self) -> int:
        n = 1
        for k in KNOB_NAMES:
            n *= len(getattr(self, k))
        return n

    def _grid_shape(self) -> Tuple[int, ...]:
        return tuple(len(getattr(self, k)) for k in KNOB_NAMES)

    def knob_columns(self) -> Dict[str, np.ndarray]:
        """Per-knob value columns of the expanded grid, in ``candidates()``
        order (itertools.product over KNOB_NAMES), each [n_candidates]."""
        cols = self._cache.get("knob_columns")
        if cols is None:
            grids = np.meshgrid(
                *[np.asarray(getattr(self, k), np.float64) for k in KNOB_NAMES],
                indexing="ij",
            )
            cols = {k: g.reshape(-1) for k, g in zip(KNOB_NAMES, grids)}
            self._cache["knob_columns"] = cols
        return cols

    def candidates(self) -> List[dict]:
        """Candidate knob dicts (cached; prefer ``candidate(i)`` / the column
        API for large grids — this materializes n_candidates dicts)."""
        cands = self._cache.get("candidates")
        if cands is None:
            grids = [getattr(self, k) for k in KNOB_NAMES]
            cands = [dict(zip(KNOB_NAMES, vals)) for vals in itertools.product(*grids)]
            self._cache["candidates"] = cands
        return cands

    def candidate(self, i: int) -> dict:
        """The i-th candidate dict (original Python value types), without
        materializing the whole list."""
        idx = np.unravel_index(int(i), self._grid_shape())
        return {k: getattr(self, k)[j] for k, j in zip(KNOB_NAMES, idx)}

    # -- zero-copy feature matrix --------------------------------------
    def feature_matrix(self, spec: FeatureSpec, context: dict) -> np.ndarray:
        """[n_candidates, n_features] matrix for ``spec``: knob columns from
        the cached grid, remaining features from scalar ``context`` values
        (missing -> 0.0, mirroring ``FeatureSpec.row``).

        The knob columns are written once and cached per spec; only the
        context columns are overwritten on subsequent calls.  The returned
        array is the cached buffer — treat it as read-only.
        """
        key = ("matrix", spec.names)
        X = self._cache.get(key)
        if X is None:
            X = spec.matrix_from_candidates(self.knob_columns(), self.n_candidates)
            self._cache[key] = X
        for k, name in enumerate(spec.names):
            if name not in KNOB_NAMES:
                X[:, k] = float(context.get(name, 0.0))
        return X


DEFAULT_SPACE = ConfigSpace()


# -- mega-grid scoring -----------------------------------------------------
# Above MEGA_GRID_MIN candidates, an ensemble-backed recommend() stops
# materializing the [n, F] float64 matrix + one monolithic inference and
# instead scores fixed-size float32 chunks assembled straight from the cached
# knob columns.  Chunks are MEGA_GRID_CHUNK rows; the tail is padded to a
# power of two (floor _MEGA_TAIL_FLOOR) so the jit cache stays logarithmic in
# the grid size, exactly like the serving tier's micro-batch buckets.
MEGA_GRID_MIN = 4096
MEGA_GRID_CHUNK = 8192
_MEGA_TAIL_FLOOR = 256


def _packed_model(predictor) -> Optional[PackedEnsemble]:
    """The predictor's ``PackedEnsemble`` when its ``predict`` is exactly the
    packed-ensemble program (GBT/RF models), else ``None``."""
    ens = getattr(getattr(predictor, "model", None), "ensemble", None)
    return ens if isinstance(ens, PackedEnsemble) else None


def _on_tpu() -> bool:
    try:
        import jax

        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover - jax is a hard dep everywhere else
        return False


def _resolve_scorer(scorer: str, ens: Optional[PackedEnsemble], n: int) -> str:
    if scorer not in ("auto", "oracle", "chunked", "pallas"):
        raise ValueError(f"unknown scorer {scorer!r}")
    if scorer == "oracle" or ens is None:
        return "oracle"
    if scorer == "auto":
        if n < MEGA_GRID_MIN:
            return "oracle"
        return "pallas" if _on_tpu() else "chunked"
    return scorer


def _score_grid_packed(
    ens: PackedEnsemble,
    spec: FeatureSpec,
    space: ConfigSpace,
    context: dict,
    *,
    chunk: int,
    pallas: bool,
) -> np.ndarray:
    """Float32 log-space scores of every grid candidate, chunk by chunk.

    Each [chunk, F] block is written into a reused float32 buffer: knob
    columns sliced from the cached grid, context features (chunk-invariant)
    filled once per buffer shape.  Pad rows are scored and discarded — per-row
    descent is independent, so padding never changes a real row."""
    n = space.n_candidates
    cols = space.knob_columns()
    names = spec.names
    knob_cols = [(j, cols[name]) for j, name in enumerate(names) if name in KNOB_NAMES]
    ctx_vals = [
        (j, float(context.get(name, 0.0)))
        for j, name in enumerate(names)
        if name not in KNOB_NAMES
    ]
    interpret = pallas and not _on_tpu()
    if pallas:
        from ..kernels.gbt_predict import gbt_predict_ensemble
    scores = np.empty(n, np.float32)
    buffers: Dict[int, np.ndarray] = {}
    lo = 0
    while lo < n:
        rows = min(chunk, n - lo)
        padded = chunk if rows == chunk else ceil_pow2(rows, _MEGA_TAIL_FLOOR)
        buf = buffers.get(padded)
        if buf is None:
            buf = np.zeros((padded, len(names)), np.float32)
            for j, v in ctx_vals:
                buf[:, j] = v
            buffers[padded] = buf
        for j, col in knob_cols:
            buf[:rows, j] = col[lo : lo + rows]
            if rows < padded:
                buf[rows:, j] = 0.0
        if pallas:
            out = gbt_predict_ensemble(ens, buf, interpret=interpret)
        else:
            out = predict_ensemble(ens, buf)
        scores[lo : lo + rows] = np.asarray(out)[:rows]
        lo += rows
    return scores


def score_grid(
    predictor,
    context: dict,
    space: ConfigSpace = DEFAULT_SPACE,
    *,
    scorer: str = "auto",
    chunk: int = MEGA_GRID_CHUNK,
) -> Tuple[np.ndarray, str]:
    """Score every candidate in the grid; returns ``(scores, mode)``.

    ``scores`` is [n_candidates] and monotone in predicted throughput: raw
    MB/s float64 under ``"oracle"`` (the classic batched numpy path), float32
    log-space ensemble outputs under ``"chunked"``/``"pallas"`` (expm1 is
    monotone, so the ranking is the same and the mega path skips n expm1s).
    ``scorer="auto"`` picks the packed path for ensemble models on grids of
    ``MEGA_GRID_MIN``+ candidates — the Pallas kernel on TPU, the jitted
    dense descent elsewhere — and the oracle otherwise; forcing
    ``"chunked"``/``"pallas"`` on a non-ensemble model falls back to oracle.
    """
    ens = _packed_model(predictor)
    mode = _resolve_scorer(scorer, ens, space.n_candidates)
    if mode == "oracle":
        X = space.feature_matrix(predictor.spec, context)
        return np.asarray(predictor.predict_throughput_batch(X)), mode
    return (
        _score_grid_packed(
            ens, predictor.spec, space, context, chunk=chunk,
            pallas=(mode == "pallas"),
        ),
        mode,
    )


def recommend(
    predictor: IOPerformancePredictor,
    context: dict,
    space: ConfigSpace = DEFAULT_SPACE,
    top_k: int = 5,
    scorer: str = "auto",
    chunk: int = MEGA_GRID_CHUNK,
) -> List[dict]:
    """Ranked top-k configurations by predicted throughput.

    One grid scoring (see ``score_grid``) + an O(n) argpartition; only the k
    winning candidate dicts are built.  When the mega-grid path scored in
    float32 log space, the winners are re-scored through the oracle path so
    the reported ``predicted_throughput_mb_s`` values are identical to what
    the numpy baseline would report.
    """
    scores, mode = score_grid(predictor, context, space, scorer=scorer, chunk=chunk)
    n = scores.shape[0]
    k = min(top_k, n)
    if k < n:
        part = np.argpartition(-scores, k - 1)[:k]
        order = part[np.argsort(scores[part])[::-1]]
    else:
        order = np.argsort(scores)[::-1]
    winners = [space.candidate(i) for i in order]
    if mode == "oracle":
        pred_k = scores[order]
    else:
        names = predictor.spec.names
        Xk = np.empty((k, len(names)), np.float64)
        for r, cand in enumerate(winners):
            for j, name in enumerate(names):
                Xk[r, j] = (
                    float(cand[name]) if name in KNOB_NAMES
                    else float(context.get(name, 0.0))
                )
        pred_k = np.asarray(predictor.predict_throughput_batch(Xk))
        resort = np.argsort(-pred_k, kind="stable")
        winners = [winners[int(r)] for r in resort]
        pred_k = pred_k[resort]
    return [
        {**cand, "predicted_throughput_mb_s": float(pred_k[r])}
        for r, cand in enumerate(winners)
    ]


@dataclasses.dataclass
class AutotuneDecision:
    reconfigure: bool
    config: Optional[dict]
    predicted_gain: float
    current_throughput: float


class _ColumnStore:
    """Append-only observation matrix with amortized-doubling growth.

    Rows are feature dicts; columns are ``keys``.  ``matrix()``/``column()``
    return zero-copy views of the live buffer, so a refit never re-stacks
    history."""

    def __init__(self, keys: Sequence[str]):
        self.keys = tuple(keys)
        self._pos = {k: i for i, k in enumerate(self.keys)}
        self._buf = np.zeros((0, len(self.keys)), np.float64)
        self.n = 0

    def append(self, row: dict) -> None:
        if self.n == self._buf.shape[0]:
            grown = np.zeros((max(64, 2 * self._buf.shape[0]), len(self.keys)))
            grown[: self.n] = self._buf[: self.n]
            self._buf = grown
        out = self._buf[self.n]
        for k, v in row.items():
            i = self._pos.get(k)
            if i is not None:
                out[i] = float(v)
        self.n += 1

    def matrix(self, names: Sequence[str]) -> np.ndarray:
        """View of the first len(names) columns (requires ``names`` to be a
        prefix of ``keys``, which holds for spec.names + [target])."""
        assert tuple(names) == self.keys[: len(names)], "column order mismatch"
        return self._buf[: self.n, : len(names)]

    def column(self, key: str) -> np.ndarray:
        return self._buf[: self.n, self._pos[key]]

    def columns(self) -> Dict[str, np.ndarray]:
        return {k: self.column(k) for k in self.keys}


class OnlineAutotuner:
    """Streaming observation buffer + periodic refit + reconfiguration hints."""

    def __init__(
        self,
        spec: Optional[FeatureSpec] = None,
        space: ConfigSpace = DEFAULT_SPACE,
        refit_every: int = 20,
        min_observations: int = 24,
        gain_threshold: float = 0.10,  # propose only if >=10% predicted speedup
        model: str = "xgboost",
        seed: int = 0,
        min_config_diversity: int = 3,  # explore until this many distinct configs seen
        drift_threshold: float = 0.5,  # force refit if new-data median rel. error exceeds
        engine: Optional[str] = None,  # tree engine for refits (None = default)
    ):
        # default online view: paper features + prefetch knobs, so the
        # tuner can rank/learn prefetch_policy/lookahead/cache budget
        self.spec = spec or FeatureSpec(names=AUTOTUNE_FEATURE_NAMES)
        self.space = space
        self.refit_every = refit_every
        self.min_observations = min_observations
        self.gain_threshold = gain_threshold
        self.min_config_diversity = min_config_diversity
        self.drift_threshold = drift_threshold
        self.predictor = IOPerformancePredictor(
            self.spec, model=model, seed=seed, engine=engine
        )
        self._store = _ColumnStore(tuple(self.spec.names) + (self.spec.target,))
        self._since_fit = 0
        self._fitted = False
        # Hot-swap state: a refit builds the new model OFF the lock, then
        # publishes (model, generation) under it — snapshot() readers get a
        # consistent pair, and nothing ever observes a half-trained model.
        self._swap_lock = threading.Lock()
        self._generation = 0
        # Rollback state: the model the last refit displaced, republishable
        # via rollback() when a poisoned cycle slips past the ingest guard.
        self._prev_model = None
        self.rollbacks = 0
        self.degraded = False  # True while serving a rolled-back model
        self._explored: List[tuple] = []
        self._seen_keys: set = set()
        self._ingested_keys: set = set()  # (case_id, rep, seed) of campaign records
        self._drift_refit = False
        self.last_drift = float("nan")
        # Exploration order: deterministic permutation over the (cached)
        # candidate list, computed once instead of per decide() call.
        self._explore_order: Optional[np.ndarray] = None

    # Exogenous workload descriptors kept as features for the ONLINE tuner.
    # Endogenous measurements (throughput_mb_s, samples_per_second,
    # data_loading_ratio, iops) are *consequences* of the knobs — using them
    # as features online makes every candidate predict the current measured
    # value (the identity shortcut), so they are filtered here. The offline
    # IOPerformancePredictor keeps the paper's full 11-feature set.
    STATIC_KEYS = ("file_size_mb", "n_samples")

    def _filter_features(self, feats: dict, knobs: Optional[dict] = None) -> dict:
        keep = set(self._varied_knobs) | set(self.STATIC_KEYS)
        out = {k: float(v) for k, v in feats.items() if k in keep}
        if knobs:
            out.update({k: float(v) for k, v in knobs.items() if k in keep})
        return out

    # ------------------------------------------------------------------
    def _ingest(self, row: dict) -> None:
        self._store.append(row)
        self._seen_keys.add(self._config_key(row))
        self._since_fit += 1

    def seed_observations(self, rows: List[dict]):
        """Warm-start from an offline benchmark sweep (the paper's 141-row
        dataset): gives the predictor cross-configuration signal before any
        live telemetry arrives.

        Rows pass through the same endogenous-measurement filter as live
        ``observe()`` rows: offline rows carry real values in columns (e.g.
        ``samples_per_second``) that live telemetry zero-fills, and mixing the
        two would train the model on features it never sees at decision time.
        The *offline* ``IOPerformancePredictor`` keeps the paper's full
        11-feature path — the filter applies only to this online store."""
        for r in rows:
            row = self._filter_features(r)
            row[self.spec.target] = float(r.get(self.spec.target, 0.0))
            self._ingest(row)

    def ingest_records(self, records: Iterable[dict]) -> int:
        """Incrementally ingest campaign JSONL records (``campaign.py``
        schema: provenance + ``row``), skipping records already ingested.

        Records are keyed by ``(case_id, rep, seed)`` — the same identity the
        campaign runner and ``merge_records`` use — so the continuous loop can
        hand over the *full* merged record list every cycle and only the new
        rows land in the store.  Returns the number of rows ingested.

        Drift trigger: if a model is fitted, the prediction error on the new
        rows is measured *before* they are ingested; a median relative error
        above ``drift_threshold`` marks the model stale, and the next
        ``maybe_refit()`` fires regardless of the ``refit_every`` schedule.
        """
        fresh: List[dict] = []
        for rec in records:
            if rec.get("status") != "ok" or not rec.get("row"):
                continue
            key = (rec.get("case_id"), rec.get("rep", 0), rec.get("seed", 0))
            if key in self._ingested_keys:
                continue
            self._ingested_keys.add(key)
            fresh.append(rec["row"])
        if fresh:
            self._update_drift(fresh)
            self.seed_observations(fresh)
        return len(fresh)

    def _update_drift(self, rows: List[dict]) -> None:
        """Median relative prediction error of the current model on rows it
        has not seen — measured on the filtered (online) feature view."""
        if not self._fitted:
            return
        filtered = [self._filter_features(r) for r in rows]
        X = np.stack([self.spec.row(f) for f in filtered])
        y = np.asarray([float(r.get(self.spec.target, 0.0)) for r in rows])
        self.last_drift = float(np.median(self.predictor.relative_errors(X, y)))
        if self.last_drift > self.drift_threshold:
            self._drift_refit = True

    @property
    def _varied_knobs(self) -> tuple:
        return tuple(k for k in KNOB_NAMES if len(getattr(self.space, k)) > 1)

    def _config_key(self, cfg: dict) -> tuple:
        return tuple(cfg.get(k) for k in self._varied_knobs)

    def _diversity(self) -> int:
        return len(self._seen_keys)

    def mark_explored(self, config: dict) -> None:
        """Record that an exploration proposal was already issued for
        ``config`` — the resume path replays past explore decisions through
        this so a restarted tuner doesn't re-propose the same candidates."""
        key = self._config_key(config)
        if key not in self._explored:
            self._explored.append(key)

    def _next_unexplored(self, current: dict) -> Optional[dict]:
        seen = self._seen_keys | set(self._explored)
        seen.add(self._config_key(current))
        cands = self.space.candidates()  # cached on the space
        if self._explore_order is None:
            # deterministic shuffle: spread exploration across all knobs early
            self._explore_order = np.random.default_rng(1234).permutation(len(cands))
        for i in self._explore_order:
            if self._config_key(cands[i]) not in seen:
                self._explored.append(self._config_key(cands[i]))
                return cands[i]
        return None

    @property
    def n_observations(self) -> int:
        return self._store.n

    def observe(self, features: dict, target_throughput: float):
        row = self._filter_features(features)
        row[self.spec.target] = float(target_throughput)
        self._ingest(row)

    def _columns(self) -> dict:
        return self._store.columns()

    @property
    def fitted(self) -> bool:
        return self._fitted

    def maybe_refit(self) -> bool:
        if self._store.n < self.min_observations:
            return False
        if (
            self._fitted
            and not self._drift_refit
            and self._since_fit < self.refit_every
        ):
            return False
        # Zero-copy views of the live store: [n, F] feature block + target.
        # The (slow) fit happens off the swap lock against a fixed-length view
        # — concurrent appends only touch rows past n — and the result is
        # published atomically with its generation bump, so snapshot() readers
        # never see a half-trained model or a (model, generation) mismatch.
        model = self.predictor.build_model(
            self._store.matrix(self.spec.names),
            self._store.column(self.spec.target),
        )
        with self._swap_lock:
            self._prev_model = self.predictor.model if self._fitted else None
            self.predictor.model = model
            self._generation += 1
            self._fitted = True
            self.degraded = False  # a clean refit closes the circuit
        self._since_fit = 0
        self._drift_refit = False
        return True

    def rollback(self) -> bool:
        """Republish the model the last refit displaced (poisoned-cycle
        recovery): returns False when there is no previous generation.

        The generation bumps *forward* — never backward — so snapshot-derived
        cache keys invalidate exactly like a refit and no reader can conflate
        the restored model with the poisoned one it replaces.  The tuner is
        marked ``degraded`` until the next clean refit."""
        with self._swap_lock:
            if self._prev_model is None:
                return False
            self.predictor.model = self._prev_model
            self._prev_model = None  # one level of undo, not a history
            self._generation += 1
            self.rollbacks += 1
            self.degraded = True
        # A rollback means the latest observations produced a bad model —
        # force drift-triggered refit consideration once newer data arrives.
        self._since_fit = 0
        return True

    @property
    def generation(self) -> int:
        """Monotonic model generation: 0 until the first fit, then +1 per
        completed refit.  Cache keys derived from it invalidate atomically
        the instant a refit publishes (``snapshot()`` hands out the pair)."""
        return self._generation

    def snapshot(self) -> Optional[PredictorSnapshot]:
        """Consistent ``(model, generation)`` view for concurrent scoring, or
        ``None`` until the first fit.  Successive refits never mutate a
        published snapshot's model — in-flight work finishes on the model it
        started with (the serving tier's no-mixed-batch guarantee)."""
        with self._swap_lock:
            if not self._fitted:
                return None
            return self.predictor.snapshot(self._generation)

    def filter_context(self, context: dict, knobs: Optional[dict] = None) -> dict:
        """Public view of the online feature filter (see ``_filter_features``):
        the serving tier must featurize exactly like ``ranked()``/``decide()``
        or batched results would diverge from the in-process path."""
        return self._filter_features(context, knobs=knobs)

    def ranked(self, context: dict, top_k: int = 5) -> List[dict]:
        """Ranked top-k candidate configs under the live (filtered) context —
        the continuous loop's re-recommend report.  Empty until fitted."""
        if not self._fitted:
            return []
        return recommend(
            self.predictor, self._filter_features(context), self.space, top_k=top_k
        )

    def decide(
        self,
        current_config: dict,
        context: dict,
        best: Optional[dict] = None,
    ) -> AutotuneDecision:
        """Given live context telemetry, propose the best predicted config.

        Cold start: until ``min_config_diversity`` distinct configs have been
        observed the model has no cross-config signal, so we EXPLORE —
        propose the next unexplored candidate instead of exploiting.

        ``best`` short-circuits the internal top-1 grid inference with an
        already-ranked winner (callers that just computed ``ranked()`` pass
        ``ranked(...)[0]`` to avoid scoring the grid twice).
        """
        cur = float(context.get("throughput_mb_s", 0.0))
        if self._diversity() < self.min_config_diversity:
            cand = self._next_unexplored(current_config)
            if cand is not None:
                return AutotuneDecision(True, {**cand, "explore": True}, 0.0, cur)
        if not self._fitted:
            return AutotuneDecision(False, None, 0.0, cur)
        if best is None:
            best = self.ranked(context, top_k=1)[0]
        cur_pred = self.predictor.predict_throughput(
            self._filter_features(context, knobs=current_config)
        )
        base = max(cur_pred, 1e-9)
        gain = (best["predicted_throughput_mb_s"] - base) / base
        # Compare over the *varied knobs* only: a knob missing from the
        # trainer's dict must count as a difference (not be skipped), and
        # extra non-knob keys (labels, annotations) must not force a
        # spurious "different config" verdict.
        same = all(best.get(k) == current_config.get(k) for k in self._varied_knobs)
        if not same and gain >= self.gain_threshold:
            return AutotuneDecision(True, best, float(gain), cur)
        return AutotuneDecision(False, None, float(gain), cur)
