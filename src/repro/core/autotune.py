"""Configuration recommendation + online pipeline autotuning (paper §5.2).

Two layers:

1. ``recommend()`` — the paper's offline use-case: enumerate a candidate grid
   of pipeline knobs, featurize each candidate, predict log-throughput with a
   fitted ``IOPerformancePredictor``, return ranked configs.  The prediction
   over the whole grid is ONE batched JAX ensemble inference (milliseconds for
   10^5 candidates).

2. ``OnlineAutotuner`` — the framework integration: lives inside the trainer,
   ingests live pipeline telemetry as new observations, periodically refits,
   and proposes a reconfiguration whenever the predicted gain over the current
   config exceeds a threshold. This is the paper's "days -> minutes" loop run
   continuously at step granularity, and doubles as straggler mitigation (a
   slow host re-tunes its own pipeline from its own telemetry).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence

import numpy as np

from .features import FeatureSpec
from .predictor import IOPerformancePredictor

__all__ = ["ConfigSpace", "recommend", "OnlineAutotuner", "DEFAULT_SPACE"]


@dataclasses.dataclass(frozen=True)
class ConfigSpace:
    """Discrete grid over the tunable pipeline knobs (paper §3.1 parameters)."""

    batch_size: Sequence[int] = (16, 32, 64, 128, 256)
    num_workers: Sequence[int] = (0, 1, 2, 4, 8)
    block_kb: Sequence[int] = (4, 16, 64, 256, 1024, 4096)
    n_threads: Sequence[int] = (1, 2, 4, 8)
    prefetch_depth: Sequence[int] = (1, 2, 4)  # beyond-paper knob

    def candidates(self) -> List[dict]:
        keys = ("batch_size", "num_workers", "block_kb", "n_threads", "prefetch_depth")
        grids = [getattr(self, k) for k in keys]
        return [dict(zip(keys, vals)) for vals in itertools.product(*grids)]


DEFAULT_SPACE = ConfigSpace()


def _featurize(
    candidates: List[dict], context: dict, spec: FeatureSpec
) -> np.ndarray:
    """Candidate knobs + measured context features -> [n, 11] matrix.

    ``context`` carries the measured features a knob doesn't set (current
    throughput_mb_s, iops, file_size_mb, ...), mirroring how the paper's
    feature vector mixes configuration with observed telemetry.
    """
    rows = []
    for c in candidates:
        merged = dict(context)
        merged.update(c)
        rows.append(spec.row(merged))
    return np.stack(rows, axis=0)


def recommend(
    predictor: IOPerformancePredictor,
    context: dict,
    space: ConfigSpace = DEFAULT_SPACE,
    top_k: int = 5,
) -> List[dict]:
    """Ranked top-k configurations by predicted throughput."""
    cands = space.candidates()
    X = _featurize(cands, context, predictor.spec)
    pred = predictor.predict_throughput_batch(X)
    order = np.argsort(pred)[::-1][:top_k]
    return [
        {**cands[i], "predicted_throughput_mb_s": float(pred[i])} for i in order
    ]


@dataclasses.dataclass
class AutotuneDecision:
    reconfigure: bool
    config: Optional[dict]
    predicted_gain: float
    current_throughput: float


class OnlineAutotuner:
    """Streaming observation buffer + periodic refit + reconfiguration hints."""

    def __init__(
        self,
        spec: Optional[FeatureSpec] = None,
        space: ConfigSpace = DEFAULT_SPACE,
        refit_every: int = 20,
        min_observations: int = 24,
        gain_threshold: float = 0.10,  # propose only if >=10% predicted speedup
        model: str = "xgboost",
        seed: int = 0,
        min_config_diversity: int = 3,  # explore until this many distinct configs seen
    ):
        self.spec = spec or FeatureSpec()
        self.space = space
        self.refit_every = refit_every
        self.min_observations = min_observations
        self.gain_threshold = gain_threshold
        self.min_config_diversity = min_config_diversity
        self.predictor = IOPerformancePredictor(self.spec, model=model, seed=seed)
        self._rows: List[dict] = []
        self._since_fit = 0
        self._fitted = False
        self._explored: List[tuple] = []

    # Exogenous workload descriptors kept as features for the ONLINE tuner.
    # Endogenous measurements (throughput_mb_s, samples_per_second,
    # data_loading_ratio, iops) are *consequences* of the knobs — using them
    # as features online makes every candidate predict the current measured
    # value (the identity shortcut), so they are filtered here. The offline
    # IOPerformancePredictor keeps the paper's full 11-feature set.
    STATIC_KEYS = ("file_size_mb", "n_samples")

    def _filter_features(self, feats: dict, knobs: Optional[dict] = None) -> dict:
        keep = set(self._varied_knobs) | set(self.STATIC_KEYS)
        out = {k: float(v) for k, v in feats.items() if k in keep}
        if knobs:
            out.update({k: float(v) for k, v in knobs.items() if k in keep})
        return out

    # ------------------------------------------------------------------
    def seed_observations(self, rows: List[dict]):
        """Warm-start from an offline benchmark sweep (the paper's 141-row
        dataset): gives the predictor cross-configuration signal before any
        live telemetry arrives."""
        self._rows.extend(rows)
        self._since_fit += len(rows)

    @property
    def _varied_knobs(self) -> tuple:
        return tuple(
            k for k in ("batch_size", "num_workers", "block_kb", "n_threads",
                        "prefetch_depth")
            if len(getattr(self.space, k)) > 1
        )

    def _config_key(self, cfg: dict) -> tuple:
        return tuple(cfg.get(k) for k in self._varied_knobs)

    def _diversity(self) -> int:
        return len({self._config_key(r) for r in self._rows})

    def _next_unexplored(self, current: dict) -> Optional[dict]:
        seen = {self._config_key(r) for r in self._rows} | set(self._explored)
        seen.add(self._config_key(current))
        cands = self.space.candidates()
        # deterministic shuffle: spread exploration across all knobs early
        order = np.random.default_rng(1234).permutation(len(cands))
        for i in order:
            if self._config_key(cands[i]) not in seen:
                self._explored.append(self._config_key(cands[i]))
                return cands[i]
        return None

    @property
    def n_observations(self) -> int:
        return len(self._rows)

    def observe(self, features: dict, target_throughput: float):
        row = self._filter_features(features)
        row[self.spec.target] = float(target_throughput)
        self._rows.append(row)
        self._since_fit += 1

    def _columns(self) -> dict:
        keys = list(self.spec.names) + [self.spec.target]
        return {
            k: np.asarray([r.get(k, 0.0) for r in self._rows], np.float64) for k in keys
        }

    def maybe_refit(self) -> bool:
        if len(self._rows) < self.min_observations:
            return False
        if self._fitted and self._since_fit < self.refit_every:
            return False
        self.predictor.fit(self._columns())
        self._fitted = True
        self._since_fit = 0
        return True

    def decide(self, current_config: dict, context: dict) -> AutotuneDecision:
        """Given live context telemetry, propose the best predicted config.

        Cold start: until ``min_config_diversity`` distinct configs have been
        observed the model has no cross-config signal, so we EXPLORE —
        propose the next unexplored candidate instead of exploiting.
        """
        cur = float(context.get("throughput_mb_s", 0.0))
        if self._diversity() < self.min_config_diversity:
            cand = self._next_unexplored(current_config)
            if cand is not None:
                return AutotuneDecision(True, {**cand, "explore": True}, 0.0, cur)
        if not self._fitted:
            return AutotuneDecision(False, None, 0.0, cur)
        static_ctx = self._filter_features(context)
        best = recommend(self.predictor, static_ctx, self.space, top_k=1)[0]
        cur_pred = self.predictor.predict_throughput(
            self._filter_features(context, knobs=current_config)
        )
        base = max(cur_pred, 1e-9)
        gain = (best["predicted_throughput_mb_s"] - base) / base
        same = all(best.get(k) == current_config.get(k) for k in current_config)
        if not same and gain >= self.gain_threshold:
            return AutotuneDecision(True, best, float(gain), cur)
        return AutotuneDecision(False, None, float(gain), cur)
