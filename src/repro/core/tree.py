"""Histogram-based decision-tree builders shared by GBT (gbt.py) and RF (forest.py).

Design
------
Building greedy trees is inherently sequential and data-dependent, so the
*builder* runs host-side on numpy (fast for the paper's n=141..10^4 regime).
The *fitted* trees are packed into dense, fixed-shape arrays (heap-free child
pointers) so that inference is a pure JAX tensor program: iterative descent,
``max_depth`` gather steps, fully vmappable over rows and trees, and
Pallas-tileable (see ``repro/kernels/gbt_predict.py``).

Two builder engines produce the same trees (see ``docs/fit-engine.md``):

- ``"level"`` (default): level-wise frontier building.  One vectorized
  histogram accumulation per depth over *all* frontier nodes at once — a
  single ``np.bincount`` scatter-add over flattened ``(node, feature, bin)``
  keys — followed by a vectorized cumsum-gain best-split selection across the
  whole frontier and a vectorized partition.  No per-node or per-feature
  Python loops on the O(n·d) paths.
- ``"reference"``: the original per-node DFS builder, kept as the slow oracle
  for equivalence tests and benchmarks.

With ``colsample == 1.0`` the two engines are bit-identical: the level-wise
engine accumulates every histogram bin in the same ascending-row order the
reference's per-node ``np.bincount`` does, evaluates the gain formula with the
same elementwise float64 operations, reproduces the reference's
first-occurrence argmax tie-breaking, and finally relabels its breadth-first
node ids into the reference's DFS emission order.  (With ``colsample < 1.0``
the engines consume the column-sampling RNG in different node orders, so
trees are equivalent in distribution but not replayable across engines.)

Both engines also return the per-row leaf assignment they already know from
partitioning, so boosting (gbt.py) updates its running predictions by
scattering leaf values instead of re-descending every row each round.

The split objective is the XGBoost second-order gain

    gain = 1/2 * [ GL^2/(HL+lam) + GR^2/(HR+lam) - G^2/(H+lam) ] - gamma

with leaf weight ``w = -G/(H+lam)``.  Random-Forest regression is the special
case g = -(y - mean), h = 1, lam = 0 (variance reduction; leaf = mean).
"""

from __future__ import annotations

import dataclasses
import os
from typing import List, Optional, Tuple

import numpy as np

__all__ = [
    "TreeArrays",
    "TreeBuilderConfig",
    "BinnedData",
    "DEFAULT_ENGINE",
    "build_tree",
    "build_tree_with_leaves",
    "compute_bins",
    "bin_features",
    "predict_tree_np",
]

# Flag-gated engine default: REPRO_TREE_ENGINE=reference restores the oracle.
DEFAULT_ENGINE = os.environ.get("REPRO_TREE_ENGINE", "level")


@dataclasses.dataclass
class TreeArrays:
    """One fitted tree as dense arrays (n_nodes entries, DFS emission order).

    ``feature[i] < 0`` marks a leaf; leaves self-loop (left==right==i) so a
    fixed ``max_depth``-step descent always lands on the correct leaf.
    """

    feature: np.ndarray  # int32  [n_nodes]
    threshold: np.ndarray  # float32[n_nodes]  (raw feature-space threshold)
    left: np.ndarray  # int32  [n_nodes]
    right: np.ndarray  # int32  [n_nodes]
    value: np.ndarray  # float32[n_nodes]  (leaf weight; internal nodes too, for truncation)
    gain: np.ndarray  # float32[n_nodes]  (split gain; 0 at leaves) — for importances
    cover: np.ndarray  # float32[n_nodes]  (sum of hessians reaching node)

    @property
    def n_nodes(self) -> int:
        return int(self.feature.shape[0])


@dataclasses.dataclass(frozen=True)
class TreeBuilderConfig:
    max_depth: int = 6
    min_samples_split: int = 2
    min_child_weight: float = 1e-3  # min hessian sum per child
    reg_lambda: float = 1.0
    gamma: float = 0.0  # min gain to split
    max_bins: int = 64


def compute_bins(X: np.ndarray, max_bins: int) -> list[np.ndarray]:
    """Quantile bin edges per feature. Edges are *upper* bounds; a row goes
    left iff ``x <= threshold``."""
    edges = []
    for j in range(X.shape[1]):
        col = X[:, j]
        qs = np.quantile(col, np.linspace(0, 1, max_bins + 1)[1:-1])
        e = np.unique(qs.astype(np.float64))
        edges.append(e)
    return edges


def bin_features(X: np.ndarray, edges: list[np.ndarray]) -> np.ndarray:
    """Map raw features to bin indices (uint16)."""
    out = np.empty(X.shape, dtype=np.uint16)
    for j, e in enumerate(edges):
        out[:, j] = np.searchsorted(e, X[:, j], side="left")
    return out


def _leaf_value(G: float, H: float, lam: float) -> float:
    return float(-G / (H + lam))


@dataclasses.dataclass
class BinnedData:
    """Pre-binned features plus the level-wise engine's per-fit precomputes.

    Ensembles build 100+ trees from one binning, so everything derivable from
    ``(Xb, edges)`` alone — the feature-major scatter-key offsets, padded
    thresholds, and cut-validity mask — is computed once here instead of once
    per tree.
    """

    Xb: np.ndarray  # uint16 [n, d] bin indices
    edges: list  # per-feature bin edges (float64)
    nb: np.ndarray  # int64 [d]: bins per feature (edges[j].size + 1)
    nbmax: int  # max bins over features
    key_off: np.ndarray  # intp [d, n]: j*nbmax + Xb[i, j] (scatter-key offsets)
    thr_pad: np.ndarray  # float64 [d, nbmax-1]: edges padded to a rectangle
    cut_valid: np.ndarray  # bool [d, nbmax-1]: which padded cuts are real
    # Reusable per-level scratch (lazily allocated): stable-size buffers keep
    # the hot loop free of large fresh allocations across 100+ trees per fit.
    _keybuf: Optional[np.ndarray] = dataclasses.field(default=None, repr=False)
    _offs: Optional[np.ndarray] = dataclasses.field(default=None, repr=False)

    @classmethod
    def build(cls, Xb: np.ndarray, edges: list) -> "BinnedData":
        n, d = Xb.shape
        nb = np.asarray([e.size + 1 for e in edges], np.int64)
        nbmax = int(nb.max()) if d else 1
        ncut = max(nbmax - 1, 1)
        key_off = Xb.T.astype(np.intp)
        key_off += (np.arange(d, dtype=np.intp) * nbmax)[:, None]
        thr_pad = np.zeros((d, ncut), np.float64)
        for j, e in enumerate(edges):
            thr_pad[j, : e.size] = e
        cut_valid = np.arange(nbmax - 1)[None, :] < (nb[:, None] - 1)
        return cls(Xb, edges, nb, nbmax, key_off, thr_pad, cut_valid)

    def scratch(self) -> Tuple[np.ndarray, np.ndarray]:
        """(keybuf [d, n] intp, offs [n] intp), allocated once per dataset."""
        if self._keybuf is None:
            d, n = self.key_off.shape
            self._keybuf = np.empty((d, n), np.intp)
            self._offs = np.empty(n, np.intp)
        return self._keybuf, self._offs


# ======================================================================
# Reference engine: per-node DFS (the oracle)
# ======================================================================


def _build_reference(
    Xb,
    edges: list[np.ndarray],
    grad: np.ndarray,
    hess: np.ndarray,
    cfg: TreeBuilderConfig,
    rng: Optional[np.random.Generator],
    colsample: float,
) -> Tuple[TreeArrays, np.ndarray]:
    """Greedy DFS histogram tree on pre-binned features ``Xb``."""
    if isinstance(Xb, BinnedData):
        edges = Xb.edges
        Xb = Xb.Xb
    n, d = Xb.shape
    feature, threshold, left, right, value, gains, covers = [], [], [], [], [], [], []
    leaf_of_row = np.zeros(n, dtype=np.int32)

    # Each queue entry: (node_id, row_indices, depth)
    def new_node() -> int:
        feature.append(-1)
        threshold.append(0.0)
        left.append(0)
        right.append(0)
        value.append(0.0)
        gains.append(0.0)
        covers.append(0.0)
        return len(feature) - 1

    root = new_node()
    stack = [(root, np.arange(n), 0)]
    lam = cfg.reg_lambda

    while stack:
        nid, rows, depth = stack.pop()
        g = grad[rows]
        h = hess[rows]
        G, H = float(g.sum()), float(h.sum())
        value[nid] = _leaf_value(G, H, lam)
        covers[nid] = H
        parent_score = G * G / (H + lam)

        make_leaf = (
            depth >= cfg.max_depth
            or rows.size < cfg.min_samples_split
            or H < 2 * cfg.min_child_weight
        )
        best = None  # (gain, feat, bin_idx)
        if not make_leaf:
            feats = np.arange(d)
            if colsample < 1.0 and rng is not None:
                k = max(1, int(round(colsample * d)))
                feats = rng.choice(d, size=k, replace=False)
            for j in feats:
                e = edges[j]
                nb = e.size + 1
                if nb <= 1:
                    continue
                b = Xb[rows, j]
                Gh = np.bincount(b, weights=g, minlength=nb)
                Hh = np.bincount(b, weights=h, minlength=nb)
                GL = np.cumsum(Gh)[:-1]
                HL = np.cumsum(Hh)[:-1]
                GR = G - GL
                HR = H - HL
                ok = (HL >= cfg.min_child_weight) & (HR >= cfg.min_child_weight)
                if not ok.any():
                    continue
                with np.errstate(divide="ignore", invalid="ignore"):
                    gain = 0.5 * (
                        GL * GL / (HL + lam) + GR * GR / (HR + lam) - parent_score
                    ) - cfg.gamma
                gain = np.where(ok, gain, -np.inf)
                bi = int(np.argmax(gain))
                if best is None or gain[bi] > best[0]:
                    best = (float(gain[bi]), int(j), bi)
            if best is None or best[0] <= 0.0:
                make_leaf = True

        if make_leaf:
            left[nid] = nid
            right[nid] = nid
            leaf_of_row[rows] = nid
            continue

        gbest, j, bi = best
        thr = float(edges[j][bi])
        go_left = Xb[rows, j] <= bi
        lrows, rrows = rows[go_left], rows[~go_left]
        lid, rid = new_node(), new_node()
        feature[nid] = j
        threshold[nid] = thr
        left[nid] = lid
        right[nid] = rid
        gains[nid] = gbest
        stack.append((lid, lrows, depth + 1))
        stack.append((rid, rrows, depth + 1))

    tree = TreeArrays(
        feature=np.asarray(feature, np.int32),
        threshold=np.asarray(threshold, np.float32),
        left=np.asarray(left, np.int32),
        right=np.asarray(right, np.int32),
        value=np.asarray(value, np.float32),
        gain=np.asarray(gains, np.float32),
        cover=np.asarray(covers, np.float32),
    )
    return tree, leaf_of_row


# ======================================================================
# Level-wise engine: vectorized frontier building
# ======================================================================


def _relabel_to_reference_order(
    feature: np.ndarray,
    threshold: np.ndarray,
    left: np.ndarray,
    right: np.ndarray,
    value: np.ndarray,
    gain: np.ndarray,
    cover: np.ndarray,
    leaf_of_row: np.ndarray,
) -> Tuple[TreeArrays, np.ndarray]:
    """Permute level-order (BFS) node ids into the reference engine's DFS
    emission order, so both engines produce byte-identical ``TreeArrays``.

    The reference allocates both children when it *processes* (pops) a split
    node, and its LIFO stack pops the right child first; replaying that walk
    over the finished structure yields the exact id permutation.
    """
    nn = feature.shape[0]
    perm = np.empty(nn, np.int64)  # bfs id -> reference id
    perm[0] = 0
    stack = [0]
    nxt = 1
    while stack:
        b = stack.pop()
        if feature[b] >= 0:
            l, r = int(left[b]), int(right[b])
            perm[l] = nxt
            perm[r] = nxt + 1
            nxt += 2
            stack.append(l)
            stack.append(r)
    inv = np.empty(nn, np.int64)  # reference id -> bfs id
    inv[perm] = np.arange(nn)
    tree = TreeArrays(
        feature=feature[inv].astype(np.int32),
        threshold=threshold[inv].astype(np.float32),
        left=perm[left[inv]].astype(np.int32),
        right=perm[right[inv]].astype(np.int32),
        value=value[inv].astype(np.float32),
        gain=gain[inv].astype(np.float32),
        cover=cover[inv].astype(np.float32),
    )
    return tree, perm[leaf_of_row].astype(np.int32)


def _build_levelwise(
    Xb,
    edges: list[np.ndarray],
    grad: np.ndarray,
    hess: np.ndarray,
    cfg: TreeBuilderConfig,
    rng: Optional[np.random.Generator],
    colsample: float,
) -> Tuple[TreeArrays, np.ndarray]:
    """Level-wise frontier builder: one scatter-add histogram per depth."""
    data = Xb if isinstance(Xb, BinnedData) else BinnedData.build(Xb, edges)
    Xb = data.Xb
    n, d = Xb.shape
    lam = cfg.reg_lambda
    mcw = cfg.min_child_weight
    nbmax = data.nbmax
    ncut = nbmax - 1  # padded candidate-cut slots per feature

    sample_cols = colsample < 1.0 and rng is not None
    k_cols = max(1, int(round(colsample * d))) if sample_cols else d
    # Rows with grad == hess == 0 (e.g. GBT's subsample mask) contribute exact
    # +0.0 to every histogram bin, so they can skip the scatter-add (they still
    # partition, for the leaf assignment).  With 0/1 hessians — GBT regression,
    # where a zero hessian also implies a zero gradient — the hessian histogram
    # degenerates to an integer count of the contributing rows.
    hess_is_01 = bool(np.all(np.where(hess == 0.0, grad == 0.0, hess == 1.0)))
    hess_all_one = bool(np.all(hess == 1.0))
    # Per-build feature-tiled weights for the dense scheme (lazy).
    wg_all: Optional[np.ndarray] = None
    wh_all: Optional[np.ndarray] = None

    # Per-level output chunks, concatenated once at the end.
    feat_lv: List[np.ndarray] = []
    thr_lv: List[np.ndarray] = []
    left_lv: List[np.ndarray] = []
    right_lv: List[np.ndarray] = []
    val_lv: List[np.ndarray] = []
    gain_lv: List[np.ndarray] = []
    cov_lv: List[np.ndarray] = []

    leaf_of_row = np.zeros(n, dtype=np.int64)
    # Frontier state: rows grouped by frontier node (ascending row ids within
    # each group — the invariant that makes histogram accumulation order match
    # the reference), plus per-group row counts.
    srows = np.arange(n)
    counts = np.asarray([n], dtype=np.int64)
    level_start = 0  # BFS id of the first frontier node
    n_alloc = 1

    for depth in range(cfg.max_depth + 1):
        F = counts.shape[0]
        node_ids = level_start + np.arange(F)
        starts = np.concatenate([[0], np.cumsum(counts)])
        at_root = depth == 0
        gsort = grad if at_root else grad[srows]
        hsort = hess if at_root else hess[srows]
        # Per-node G/H as contiguous-slice sums: numpy's pairwise reduction
        # over the same ascending-row sequence the reference sums, so the
        # totals (and hence leaf values) are bit-identical to the oracle.
        G = np.empty(F, np.float64)
        H = np.empty(F, np.float64)
        for i in range(F):
            G[i] = gsort[starts[i] : starts[i + 1]].sum()
            H[i] = hsort[starts[i] : starts[i + 1]].sum()
        with np.errstate(divide="ignore", invalid="ignore"):
            value = -G / (H + lam)
            parent_score = G * G / (H + lam)

        leaf_rule = (
            (depth >= cfg.max_depth)
            | (counts < cfg.min_samples_split)
            | (H < 2 * mcw)
        )
        split_feature = np.full(F, -1, np.int64)
        split_bin = np.zeros(F, np.int64)
        split_gain = np.zeros(F, np.float64)
        split_thr = np.zeros(F, np.float64)

        cand = np.flatnonzero(~leaf_rule)
        if cand.size and ncut > 0:
            C = cand.size
            is_cand = ~leaf_rule
            n_active = int(starts[-1])
            # One scatter-add over flattened (node, feature, bin) keys builds
            # every frontier histogram at once. For a fixed key, bincount
            # accumulates contributions in ascending-row order — exactly the
            # order of the reference's per-node bincount.  Two key layouts:
            #
            # - dense (small frontier, most rows still active — the GBT d<=6
            #   regime): no row gathers at all.  Per-row node offsets are
            #   scattered into a reusable [n] buffer (settled rows point at a
            #   dump block past the real histograms), keys are one in-place
            #   broadcast add over the per-fit offset matrix, and weights are
            #   the per-build feature-tiled grad/hess.  Zero-weight rows add
            #   exact +0.0 and leaf-rule nodes' slots are simply never read.
            # - compact (deep/sparse frontiers — the RF d10 regime): gather
            #   candidate-node rows, drop exact-zero (grad, hess) pairs, and
            #   scatter into candidate-compacted keys.
            dense = F <= 96 and 4 * n_active >= 3 * n
            if dense:
                M = F
                hist_nodes = np.arange(F)
                if wg_all is None:
                    wg_all = np.tile(grad, d)
                    if not hess_all_one:
                        wh_all = np.tile(hess, d)
                keybuf, offs = data.scratch()
                blk = d * nbmax
                nkeys = (F + 1) * blk  # +1: dump block for settled rows
                offs.fill(F * blk)
                offs[srows] = np.repeat(np.arange(F) * blk, counts)
                np.add(data.key_off, offs[None, :], out=keybuf)
                flat = keybuf.reshape(-1)
                Gh = np.bincount(flat, weights=wg_all, minlength=nkeys)[: F * blk]
                if hess_all_one:
                    Hh = np.bincount(flat, minlength=nkeys)[: F * blk].astype(
                        np.float64
                    )
                else:
                    Hh = np.bincount(flat, weights=wh_all, minlength=nkeys)[: F * blk]
            else:
                M = C
                hist_nodes = cand
                # Gather candidate rows (grouped by node, ascending in group).
                if C == F:
                    crows = srows
                    cgrad = gsort
                    chess = hsort
                    cnodes = np.repeat(np.arange(F), counts) if F > 1 else None
                else:
                    row_mask = np.repeat(is_cand, counts)
                    crows = srows[row_mask]
                    cgrad = gsort[row_mask]
                    chess = hsort[row_mask]
                    cnodes = np.repeat(np.cumsum(is_cand) - 1, counts)[row_mask]
                nz = (cgrad != 0.0) | (chess != 0.0)
                if np.all(nz):
                    hrows, hg, hh = crows, cgrad, chess
                    hnodes = cnodes
                else:
                    hrows, hg, hh = crows[nz], cgrad[nz], chess[nz]
                    hnodes = cnodes[nz] if cnodes is not None else None
                nkeys = C * d * nbmax
                keys = data.key_off[:, hrows]
                if hnodes is not None:
                    keys += (hnodes * (d * nbmax))[None, :]
                flat = keys.reshape(-1)
                Gh = np.bincount(flat, weights=np.tile(hg, d), minlength=nkeys)
                if hess_is_01:
                    Hh = np.bincount(flat, minlength=nkeys).astype(np.float64)
                else:
                    Hh = np.bincount(flat, weights=np.tile(hh, d), minlength=nkeys)
            GL = np.cumsum(Gh.reshape(M, d, nbmax), axis=2)[:, :, :ncut]
            HL = np.cumsum(Hh.reshape(M, d, nbmax), axis=2)[:, :, :ncut]
            GR = G[hist_nodes, None, None] - GL
            HR = H[hist_nodes, None, None] - HL
            ok = (HL >= mcw) & (HR >= mcw) & data.cut_valid[None, :, :]
            # In-place evaluation of the reference's gain expression
            #   0.5 * (GL^2/(HL+lam) + GR^2/(HR+lam) - parent_score) - gamma
            # with identical operation order at every element.
            with np.errstate(divide="ignore", invalid="ignore"):
                gain = GL * GL
                gain /= HL + lam
                np.multiply(GR, GR, out=GR)
                HR += lam
                GR /= HR
                gain += GR
                gain -= parent_score[hist_nodes, None, None]
                gain *= 0.5
                gain -= cfg.gamma
            gain[~ok] = -np.inf
            if sample_cols:
                # Per-node column subsample over candidate nodes in frontier
                # order (not the reference's DFS order — see module docstring).
                col_mask = np.zeros((M, d), bool)
                for i in (cand if dense else range(C)):
                    col_mask[i, rng.choice(d, size=k_cols, replace=False)] = True
                gain[~col_mask] = -np.inf
            # First-occurrence argmax over row-major (feature, bin) replicates
            # the reference tie-breaking: earliest feature whose max attains
            # the global max, earliest bin within it.
            flatg = gain.reshape(M, d * ncut)
            bi_flat = np.argmax(flatg, axis=1)
            best_gain = flatg[np.arange(M), bi_flat]
            do_split = best_gain > 0.0
            if dense:
                do_split &= is_cand
            j_sel = bi_flat // ncut
            b_sel = bi_flat % ncut
            tgt = hist_nodes[do_split]
            split_feature[tgt] = j_sel[do_split]
            split_bin[tgt] = b_sel[do_split]
            split_gain[tgt] = best_gain[do_split]
            split_thr[tgt] = data.thr_pad[j_sel[do_split], b_sel[do_split]]

        is_split = split_feature >= 0
        sn = np.flatnonzero(is_split)
        S = sn.size
        # Children are allocated all-left-then-all-right so next level's
        # grouped row array is two boolean gathers, no sort. The final
        # relabeling pass erases this internal numbering anyway.
        lid = np.full(F, -1, np.int64)
        rid = np.full(F, -1, np.int64)
        lid[sn] = n_alloc + np.arange(S)
        rid[sn] = n_alloc + S + np.arange(S)

        feat_lv.append(split_feature)
        thr_lv.append(split_thr)
        left_lv.append(np.where(is_split, lid, node_ids))
        right_lv.append(np.where(is_split, rid, node_ids))
        val_lv.append(value)
        gain_lv.append(np.where(is_split, split_gain, 0.0))
        cov_lv.append(H)

        # Vectorized partition: rows of leaf nodes settle; rows of split nodes
        # route left/right on their node's (feature, bin) cut.
        if S == 0:
            leaf_of_row[srows] = np.repeat(node_ids, counts)
            break
        if S == F:
            arows = srows
            scounts = counts
        else:
            row_split = np.repeat(is_split, counts)
            leaf_of_row[srows[~row_split]] = np.repeat(
                node_ids[~is_split], counts[~is_split]
            )
            arows = srows[row_split]
            scounts = counts[sn]
        rj = np.repeat(split_feature[sn], scounts)
        rb = np.repeat(split_bin[sn], scounts)
        go_left = Xb[arows, rj] <= rb
        # Per-parent left-row counts: reduceat over the grouped go_left flags
        # (split parents always hold >= 1 row, so no empty segments).
        seg = np.concatenate([[0], np.cumsum(scounts)[:-1]])
        lcounts = np.add.reduceat(go_left.astype(np.int64), seg)
        srows = np.concatenate([arows[go_left], arows[~go_left]])
        counts = np.concatenate([lcounts, scounts - lcounts])
        level_start = n_alloc
        n_alloc += 2 * S

    return _relabel_to_reference_order(
        np.concatenate(feat_lv),
        np.concatenate(thr_lv),
        np.concatenate(left_lv),
        np.concatenate(right_lv),
        np.concatenate(val_lv),
        np.concatenate(gain_lv),
        np.concatenate(cov_lv),
        leaf_of_row,
    )


_ENGINES = {"level": _build_levelwise, "reference": _build_reference}


def build_tree_with_leaves(
    Xb,
    edges: Optional[list] = None,
    grad: Optional[np.ndarray] = None,
    hess: Optional[np.ndarray] = None,
    cfg: Optional[TreeBuilderConfig] = None,
    rng: Optional[np.random.Generator] = None,
    colsample: float = 1.0,
    engine: Optional[str] = None,
) -> Tuple[TreeArrays, np.ndarray]:
    """Build one tree and return ``(tree, leaf_of_row)``.

    ``Xb`` is either a uint16 bin matrix (with ``edges``) or a prebuilt
    :class:`BinnedData`.  ``leaf_of_row[i]`` is the node id row i settles in —
    the builder already knows it from partitioning, so boosting can scatter
    leaf values instead of re-descending every row (``predict_tree_np``) each
    round.
    """
    name = engine or DEFAULT_ENGINE
    try:
        fn = _ENGINES[name]
    except KeyError:
        raise ValueError(f"unknown tree engine {name!r}; want one of {sorted(_ENGINES)}")
    return fn(Xb, edges, grad, hess, cfg, rng, colsample)


def build_tree(
    Xb,
    edges: Optional[list] = None,
    grad: Optional[np.ndarray] = None,
    hess: Optional[np.ndarray] = None,
    cfg: Optional[TreeBuilderConfig] = None,
    rng: Optional[np.random.Generator] = None,
    colsample: float = 1.0,
    engine: Optional[str] = None,
) -> TreeArrays:
    """Greedy histogram tree on pre-binned features ``Xb``."""
    return build_tree_with_leaves(Xb, edges, grad, hess, cfg, rng, colsample, engine)[0]


def predict_tree_np(tree: TreeArrays, X: np.ndarray, max_depth: int) -> np.ndarray:
    """Numpy oracle for a single tree (matches JAX/Pallas descent exactly)."""
    idx = np.zeros(X.shape[0], dtype=np.int64)
    for _ in range(max_depth + 1):
        f = tree.feature[idx]
        leaf = f < 0
        fx = X[np.arange(X.shape[0]), np.maximum(f, 0)]
        go_left = fx <= tree.threshold[idx]
        nxt = np.where(go_left, tree.left[idx], tree.right[idx])
        idx = np.where(leaf, idx, nxt)
    return tree.value[idx].astype(np.float64)
