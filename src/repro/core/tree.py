"""Histogram-based decision-tree builders shared by GBT (gbt.py) and RF (forest.py).

Design
------
Building greedy trees is inherently sequential and data-dependent, so the
*builder* runs host-side on numpy (fast for the paper's n=141..10^4 regime).
The *fitted* trees are packed into dense, fixed-shape arrays (heap-free child
pointers) so that inference is a pure JAX tensor program: iterative descent,
``max_depth`` gather steps, fully vmappable over rows and trees, and
Pallas-tileable (see ``repro/kernels/gbt_predict.py``).

Two builder engines produce the same trees (see ``docs/fit-engine.md``):

- ``"level"`` (default): level-wise frontier building.  One vectorized
  histogram accumulation per depth over *all* frontier nodes at once — a
  single ``np.bincount`` scatter-add over flattened ``(node, feature, bin)``
  keys — followed by a vectorized cumsum-gain best-split selection across the
  whole frontier and a vectorized partition.  No per-node or per-feature
  Python loops on the O(n·d) paths.
- ``"reference"``: the original per-node DFS builder, kept as the slow oracle
  for equivalence tests and benchmarks.

The engines are bit-identical at any ``colsample``: the level-wise engine
accumulates every histogram bin in the same ascending-row order the
reference's per-node ``np.bincount`` does, evaluates the gain formula with the
same elementwise float64 operations, reproduces the reference's
first-occurrence argmax tie-breaking, and finally relabels its breadth-first
node ids into the reference's DFS emission order.  Column subsampling
(``colsample < 1.0``) is traversal-order independent by construction: each
tree consumes exactly *one* draw from the caller's generator (a 62-bit base
key — see ``_colsample_base``), and every node's feature subset comes from a
fresh generator keyed on ``(base, heap path)`` (root = 1, children ``2p`` /
``2p + 1`` — ``_colsample_cols``).  DFS, level-wise frontier, and lockstep
batched builds therefore draw identical per-node subsets no matter what
order they visit nodes in, so all three engines replay each other exactly.

Both engines also return the per-row leaf assignment they already know from
partitioning, so boosting (gbt.py) updates its running predictions by
scattering leaf values instead of re-descending every row each round.

The split objective is the XGBoost second-order gain

    gain = 1/2 * [ GL^2/(HL+lam) + GR^2/(HR+lam) - G^2/(H+lam) ] - gamma

with leaf weight ``w = -G/(H+lam)``.  Random-Forest regression is the special
case g = -(y - mean), h = 1, lam = 0 (variance reduction; leaf = mean).
"""

from __future__ import annotations

import dataclasses
import os
from typing import List, Optional, Tuple

import numpy as np

from . import _native

__all__ = [
    "TreeArrays",
    "TreeBuilderConfig",
    "BinnedData",
    "DEFAULT_ENGINE",
    "build_tree",
    "build_tree_with_leaves",
    "build_forest_batched",
    "compute_bins",
    "bin_features",
    "predict_tree_np",
    "resolve_engine",
]

# The builder used when neither ``engine=`` nor REPRO_TREE_ENGINE says
# otherwise.  ``resolve_engine`` re-reads the environment on every build, so
# flipping REPRO_TREE_ENGINE mid-process (e.g. around an ``OnlineAutotuner``
# refit) takes effect immediately.
_BUILTIN_DEFAULT = "batched"
DEFAULT_ENGINE = os.environ.get("REPRO_TREE_ENGINE", _BUILTIN_DEFAULT)


def resolve_engine(engine: Optional[str] = None) -> str:
    """Explicit ``engine=`` beats REPRO_TREE_ENGINE beats the built-in."""
    return engine or os.environ.get("REPRO_TREE_ENGINE", _BUILTIN_DEFAULT)


@dataclasses.dataclass
class TreeArrays:
    """One fitted tree as dense arrays (n_nodes entries, DFS emission order).

    ``feature[i] < 0`` marks a leaf; leaves self-loop (left==right==i) so a
    fixed ``max_depth``-step descent always lands on the correct leaf.
    """

    feature: np.ndarray  # int32  [n_nodes]
    threshold: np.ndarray  # float32[n_nodes]  (raw feature-space threshold)
    left: np.ndarray  # int32  [n_nodes]
    right: np.ndarray  # int32  [n_nodes]
    value: np.ndarray  # float32[n_nodes]  (leaf weight; internal nodes too, for truncation)
    gain: np.ndarray  # float32[n_nodes]  (split gain; 0 at leaves) — for importances
    cover: np.ndarray  # float32[n_nodes]  (sum of hessians reaching node)

    @property
    def n_nodes(self) -> int:
        return int(self.feature.shape[0])


@dataclasses.dataclass(frozen=True)
class TreeBuilderConfig:
    max_depth: int = 6
    min_samples_split: int = 2
    min_child_weight: float = 1e-3  # min hessian sum per child
    reg_lambda: float = 1.0
    gamma: float = 0.0  # min gain to split
    max_bins: int = 64


def compute_bins(X: np.ndarray, max_bins: int) -> list[np.ndarray]:
    """Quantile bin edges per feature. Edges are *upper* bounds; a row goes
    left iff ``x <= threshold``."""
    edges = []
    for j in range(X.shape[1]):
        col = X[:, j]
        qs = np.quantile(col, np.linspace(0, 1, max_bins + 1)[1:-1])
        e = np.unique(qs.astype(np.float64))
        edges.append(e)
    return edges


def bin_features(X: np.ndarray, edges: list[np.ndarray]) -> np.ndarray:
    """Map raw features to bin indices (uint16)."""
    out = np.empty(X.shape, dtype=np.uint16)
    for j, e in enumerate(edges):
        out[:, j] = np.searchsorted(e, X[:, j], side="left")
    return out


def _leaf_value(G: float, H: float, lam: float) -> float:
    return float(-G / (H + lam))


def _colsample_base(rng: np.random.Generator) -> int:
    """The one draw a column-subsampled tree consumes from ``rng``.

    Every engine draws this exactly once per tree, before expanding any node,
    so the shared stream advances identically no matter which engine builds
    the tree (or whether trees are built serially or in lockstep)."""
    return int(rng.integers(0, np.int64(1) << 62))


def _colsample_cols(base: int, path: int, d: int, k: int) -> np.ndarray:
    """Feature subset for the node at heap ``path`` (root=1, children 2p and
    2p+1) of the tree keyed ``base``.

    A fresh generator seeded on ``(base, path)`` makes the draw a pure
    function of tree identity and node position — independent of the order
    nodes are visited in, which is what lets DFS (reference), frontier
    (level), and lockstep (batched) builds produce identical subsets.

    The subset is returned in ascending feature order so that the
    reference's sequential feature loop breaks equal-gain ties the same way
    the vectorized engines' row-major argmax does."""
    words = [base & 0xFFFFFFFF, (base >> 32) & 0xFFFFFFFF]
    p = int(path)
    while True:  # low-to-high 32-bit limbs; last limb nonzero (path >= 1)
        words.append(p & 0xFFFFFFFF)
        p >>= 32
        if not p:
            break
    return np.sort(np.random.default_rng(words).choice(d, size=k, replace=False))


def _path_dtype(max_depth: int):
    """Heap paths fit int64 through depth 62; Python ints beyond that."""
    return np.int64 if max_depth <= 60 else object


@dataclasses.dataclass
class BinnedData:
    """Pre-binned features plus the level-wise engine's per-fit precomputes.

    Ensembles build 100+ trees from one binning, so everything derivable from
    ``(Xb, edges)`` alone — the feature-major scatter-key offsets, padded
    thresholds, and cut-validity mask — is computed once here instead of once
    per tree.
    """

    Xb: np.ndarray  # uint16 [n, d] bin indices
    edges: list  # per-feature bin edges (float64)
    nb: np.ndarray  # int64 [d]: bins per feature (edges[j].size + 1)
    nbmax: int  # max bins over features
    key_off: np.ndarray  # intp [d, n]: j*nbmax + Xb[i, j] (scatter-key offsets)
    thr_pad: np.ndarray  # float64 [d, nbmax-1]: edges padded to a rectangle
    cut_valid: np.ndarray  # bool [d, nbmax-1]: which padded cuts are real
    # Reusable per-level scratch (lazily allocated): stable-size buffers keep
    # the hot loop free of large fresh allocations across 100+ trees per fit.
    _keybuf: Optional[np.ndarray] = dataclasses.field(default=None, repr=False)
    _offs: Optional[np.ndarray] = dataclasses.field(default=None, repr=False)

    @classmethod
    def build(cls, Xb: np.ndarray, edges: list) -> "BinnedData":
        n, d = Xb.shape
        nb = np.asarray([e.size + 1 for e in edges], np.int64)
        nbmax = int(nb.max()) if d else 1
        ncut = max(nbmax - 1, 1)
        key_off = Xb.T.astype(np.intp)
        key_off += (np.arange(d, dtype=np.intp) * nbmax)[:, None]
        thr_pad = np.zeros((d, ncut), np.float64)
        for j, e in enumerate(edges):
            thr_pad[j, : e.size] = e
        cut_valid = np.arange(nbmax - 1)[None, :] < (nb[:, None] - 1)
        return cls(Xb, edges, nb, nbmax, key_off, thr_pad, cut_valid)

    def scratch(self) -> Tuple[np.ndarray, np.ndarray]:
        """(keybuf [d, n] intp, offs [n] intp), allocated once per dataset."""
        if self._keybuf is None:
            d, n = self.key_off.shape
            self._keybuf = np.empty((d, n), np.intp)
            self._offs = np.empty(n, np.intp)
        return self._keybuf, self._offs


# ======================================================================
# Reference engine: per-node DFS (the oracle)
# ======================================================================


def _build_reference(
    Xb,
    edges: list[np.ndarray],
    grad: np.ndarray,
    hess: np.ndarray,
    cfg: TreeBuilderConfig,
    rng: Optional[np.random.Generator],
    colsample: float,
) -> Tuple[TreeArrays, np.ndarray]:
    """Greedy DFS histogram tree on pre-binned features ``Xb``."""
    if isinstance(Xb, BinnedData):
        edges = Xb.edges
        Xb = Xb.Xb
    n, d = Xb.shape
    feature, threshold, left, right, value, gains, covers = [], [], [], [], [], [], []
    leaf_of_row = np.zeros(n, dtype=np.int32)

    # Each queue entry: (node_id, row_indices, depth)
    def new_node() -> int:
        feature.append(-1)
        threshold.append(0.0)
        left.append(0)
        right.append(0)
        value.append(0.0)
        gains.append(0.0)
        covers.append(0.0)
        return len(feature) - 1

    root = new_node()
    stack = [(root, np.arange(n), 0, 1)]  # (..., heap path)
    lam = cfg.reg_lambda
    sample_cols = colsample < 1.0 and rng is not None
    cs_base = _colsample_base(rng) if sample_cols else 0

    while stack:
        nid, rows, depth, path = stack.pop()
        g = grad[rows]
        h = hess[rows]
        G, H = float(g.sum()), float(h.sum())
        value[nid] = _leaf_value(G, H, lam)
        covers[nid] = H
        parent_score = G * G / (H + lam)

        make_leaf = (
            depth >= cfg.max_depth
            or rows.size < cfg.min_samples_split
            or H < 2 * cfg.min_child_weight
        )
        best = None  # (gain, feat, bin_idx)
        if not make_leaf:
            feats = np.arange(d)
            if sample_cols:
                k = max(1, int(round(colsample * d)))
                feats = _colsample_cols(cs_base, path, d, k)
            for j in feats:
                e = edges[j]
                nb = e.size + 1
                if nb <= 1:
                    continue
                b = Xb[rows, j]
                Gh = np.bincount(b, weights=g, minlength=nb)
                Hh = np.bincount(b, weights=h, minlength=nb)
                GL = np.cumsum(Gh)[:-1]
                HL = np.cumsum(Hh)[:-1]
                GR = G - GL
                HR = H - HL
                ok = (HL >= cfg.min_child_weight) & (HR >= cfg.min_child_weight)
                if not ok.any():
                    continue
                with np.errstate(divide="ignore", invalid="ignore"):
                    gain = 0.5 * (
                        GL * GL / (HL + lam) + GR * GR / (HR + lam) - parent_score
                    ) - cfg.gamma
                gain = np.where(ok, gain, -np.inf)
                bi = int(np.argmax(gain))
                if best is None or gain[bi] > best[0]:
                    best = (float(gain[bi]), int(j), bi)
            if best is None or best[0] <= 0.0:
                make_leaf = True

        if make_leaf:
            left[nid] = nid
            right[nid] = nid
            leaf_of_row[rows] = nid
            continue

        gbest, j, bi = best
        thr = float(edges[j][bi])
        go_left = Xb[rows, j] <= bi
        lrows, rrows = rows[go_left], rows[~go_left]
        lid, rid = new_node(), new_node()
        feature[nid] = j
        threshold[nid] = thr
        left[nid] = lid
        right[nid] = rid
        gains[nid] = gbest
        stack.append((lid, lrows, depth + 1, 2 * path))
        stack.append((rid, rrows, depth + 1, 2 * path + 1))

    tree = TreeArrays(
        feature=np.asarray(feature, np.int32),
        threshold=np.asarray(threshold, np.float32),
        left=np.asarray(left, np.int32),
        right=np.asarray(right, np.int32),
        value=np.asarray(value, np.float32),
        gain=np.asarray(gains, np.float32),
        cover=np.asarray(covers, np.float32),
    )
    return tree, leaf_of_row


# ======================================================================
# Level-wise engine: vectorized frontier building
# ======================================================================


def _relabel_to_reference_order(
    feature: np.ndarray,
    threshold: np.ndarray,
    left: np.ndarray,
    right: np.ndarray,
    value: np.ndarray,
    gain: np.ndarray,
    cover: np.ndarray,
    leaf_of_row: np.ndarray,
) -> Tuple[TreeArrays, np.ndarray]:
    """Permute level-order (BFS) node ids into the reference engine's DFS
    emission order, so both engines produce byte-identical ``TreeArrays``.

    The reference allocates both children when it *processes* (pops) a split
    node, and its LIFO stack pops the right child first; replaying that walk
    over the finished structure yields the exact id permutation.
    """
    nn = feature.shape[0]
    if nn > 64 and _native.available():
        perm = _native.relabel_dfs(feature, left, right)  # bfs -> reference
    else:
        perm = np.empty(nn, np.int64)  # bfs id -> reference id
        perm[0] = 0
        stack = [0]
        nxt = 1
        while stack:
            b = stack.pop()
            if feature[b] >= 0:
                l, r = int(left[b]), int(right[b])
                perm[l] = nxt
                perm[r] = nxt + 1
                nxt += 2
                stack.append(l)
                stack.append(r)
    inv = np.empty(nn, np.int64)  # reference id -> bfs id
    inv[perm] = np.arange(nn)
    tree = TreeArrays(
        feature=feature[inv].astype(np.int32),
        threshold=threshold[inv].astype(np.float32),
        left=perm[left[inv]].astype(np.int32),
        right=perm[right[inv]].astype(np.int32),
        value=value[inv].astype(np.float32),
        gain=gain[inv].astype(np.float32),
        cover=cover[inv].astype(np.float32),
    )
    return tree, perm[leaf_of_row].astype(np.int32)


def _build_levelwise(
    Xb,
    edges: list[np.ndarray],
    grad: np.ndarray,
    hess: np.ndarray,
    cfg: TreeBuilderConfig,
    rng: Optional[np.random.Generator],
    colsample: float,
) -> Tuple[TreeArrays, np.ndarray]:
    """Level-wise frontier builder: one scatter-add histogram per depth."""
    data = Xb if isinstance(Xb, BinnedData) else BinnedData.build(Xb, edges)
    Xb = data.Xb
    n, d = Xb.shape
    lam = cfg.reg_lambda
    mcw = cfg.min_child_weight
    nbmax = data.nbmax
    ncut = nbmax - 1  # padded candidate-cut slots per feature

    sample_cols = colsample < 1.0 and rng is not None
    k_cols = max(1, int(round(colsample * d))) if sample_cols else d
    cs_base = _colsample_base(rng) if sample_cols else 0
    # Rows with grad == hess == 0 (e.g. GBT's subsample mask) contribute exact
    # +0.0 to every histogram bin, so they can skip the scatter-add (they still
    # partition, for the leaf assignment).  With 0/1 hessians — GBT regression,
    # where a zero hessian also implies a zero gradient — the hessian histogram
    # degenerates to an integer count of the contributing rows.
    hess_is_01 = bool(np.all(np.where(hess == 0.0, grad == 0.0, hess == 1.0)))
    hess_all_one = bool(np.all(hess == 1.0))
    # Per-build feature-tiled weights for the dense scheme (lazy).
    wg_all: Optional[np.ndarray] = None
    wh_all: Optional[np.ndarray] = None

    # Per-level output chunks, concatenated once at the end.
    feat_lv: List[np.ndarray] = []
    thr_lv: List[np.ndarray] = []
    left_lv: List[np.ndarray] = []
    right_lv: List[np.ndarray] = []
    val_lv: List[np.ndarray] = []
    gain_lv: List[np.ndarray] = []
    cov_lv: List[np.ndarray] = []

    leaf_of_row = np.zeros(n, dtype=np.int64)
    # Frontier state: rows grouped by frontier node (ascending row ids within
    # each group — the invariant that makes histogram accumulation order match
    # the reference), plus per-group row counts.
    srows = np.arange(n)
    counts = np.asarray([n], dtype=np.int64)
    level_start = 0  # BFS id of the first frontier node
    n_alloc = 1
    paths = np.ones(1, dtype=_path_dtype(cfg.max_depth))  # heap paths

    for depth in range(cfg.max_depth + 1):
        F = counts.shape[0]
        node_ids = level_start + np.arange(F)
        starts = np.concatenate([[0], np.cumsum(counts)])
        at_root = depth == 0
        gsort = grad if at_root else grad[srows]
        hsort = hess if at_root else hess[srows]
        # Per-node G/H as contiguous-slice sums: numpy's pairwise reduction
        # over the same ascending-row sequence the reference sums, so the
        # totals (and hence leaf values) are bit-identical to the oracle.
        G = np.empty(F, np.float64)
        H = np.empty(F, np.float64)
        for i in range(F):
            G[i] = gsort[starts[i] : starts[i + 1]].sum()
            H[i] = hsort[starts[i] : starts[i + 1]].sum()
        with np.errstate(divide="ignore", invalid="ignore"):
            value = -G / (H + lam)
            parent_score = G * G / (H + lam)

        leaf_rule = (
            (depth >= cfg.max_depth)
            | (counts < cfg.min_samples_split)
            | (H < 2 * mcw)
        )
        split_feature = np.full(F, -1, np.int64)
        split_bin = np.zeros(F, np.int64)
        split_gain = np.zeros(F, np.float64)
        split_thr = np.zeros(F, np.float64)

        cand = np.flatnonzero(~leaf_rule)
        if cand.size and ncut > 0:
            C = cand.size
            is_cand = ~leaf_rule
            n_active = int(starts[-1])
            # One scatter-add over flattened (node, feature, bin) keys builds
            # every frontier histogram at once. For a fixed key, bincount
            # accumulates contributions in ascending-row order — exactly the
            # order of the reference's per-node bincount.  Two key layouts:
            #
            # - dense (small frontier, most rows still active — the GBT d<=6
            #   regime): no row gathers at all.  Per-row node offsets are
            #   scattered into a reusable [n] buffer (settled rows point at a
            #   dump block past the real histograms), keys are one in-place
            #   broadcast add over the per-fit offset matrix, and weights are
            #   the per-build feature-tiled grad/hess.  Zero-weight rows add
            #   exact +0.0 and leaf-rule nodes' slots are simply never read.
            # - compact (deep/sparse frontiers — the RF d10 regime): gather
            #   candidate-node rows, drop exact-zero (grad, hess) pairs, and
            #   scatter into candidate-compacted keys.
            dense = F <= 96 and 4 * n_active >= 3 * n
            if dense:
                M = F
                hist_nodes = np.arange(F)
                if wg_all is None:
                    wg_all = np.tile(grad, d)
                    if not hess_all_one:
                        wh_all = np.tile(hess, d)
                keybuf, offs = data.scratch()
                blk = d * nbmax
                nkeys = (F + 1) * blk  # +1: dump block for settled rows
                offs.fill(F * blk)
                offs[srows] = np.repeat(np.arange(F) * blk, counts)
                np.add(data.key_off, offs[None, :], out=keybuf)
                flat = keybuf.reshape(-1)
                Gh = np.bincount(flat, weights=wg_all, minlength=nkeys)[: F * blk]
                if hess_all_one:
                    Hh = np.bincount(flat, minlength=nkeys)[: F * blk].astype(
                        np.float64
                    )
                else:
                    Hh = np.bincount(flat, weights=wh_all, minlength=nkeys)[: F * blk]
            else:
                M = C
                hist_nodes = cand
                # Gather candidate rows (grouped by node, ascending in group).
                if C == F:
                    crows = srows
                    cgrad = gsort
                    chess = hsort
                    cnodes = np.repeat(np.arange(F), counts) if F > 1 else None
                else:
                    row_mask = np.repeat(is_cand, counts)
                    crows = srows[row_mask]
                    cgrad = gsort[row_mask]
                    chess = hsort[row_mask]
                    cnodes = np.repeat(np.cumsum(is_cand) - 1, counts)[row_mask]
                nz = (cgrad != 0.0) | (chess != 0.0)
                if np.all(nz):
                    hrows, hg, hh = crows, cgrad, chess
                    hnodes = cnodes
                else:
                    hrows, hg, hh = crows[nz], cgrad[nz], chess[nz]
                    hnodes = cnodes[nz] if cnodes is not None else None
                nkeys = C * d * nbmax
                keys = data.key_off[:, hrows]
                if hnodes is not None:
                    keys += (hnodes * (d * nbmax))[None, :]
                flat = keys.reshape(-1)
                Gh = np.bincount(flat, weights=np.tile(hg, d), minlength=nkeys)
                if hess_is_01:
                    Hh = np.bincount(flat, minlength=nkeys).astype(np.float64)
                else:
                    Hh = np.bincount(flat, weights=np.tile(hh, d), minlength=nkeys)
            GL = np.cumsum(Gh.reshape(M, d, nbmax), axis=2)[:, :, :ncut]
            HL = np.cumsum(Hh.reshape(M, d, nbmax), axis=2)[:, :, :ncut]
            GR = G[hist_nodes, None, None] - GL
            HR = H[hist_nodes, None, None] - HL
            ok = (HL >= mcw) & (HR >= mcw) & data.cut_valid[None, :, :]
            # In-place evaluation of the reference's gain expression
            #   0.5 * (GL^2/(HL+lam) + GR^2/(HR+lam) - parent_score) - gamma
            # with identical operation order at every element.
            with np.errstate(divide="ignore", invalid="ignore"):
                gain = GL * GL
                gain /= HL + lam
                np.multiply(GR, GR, out=GR)
                HR += lam
                GR /= HR
                gain += GR
                gain -= parent_score[hist_nodes, None, None]
                gain *= 0.5
                gain -= cfg.gamma
            gain[~ok] = -np.inf
            if sample_cols:
                # Per-node column subsets keyed on (tree base, heap path) —
                # identical to the reference's DFS draws by construction.
                col_mask = np.zeros((M, d), bool)
                for mi, node in (
                    zip(cand, cand) if dense else enumerate(cand)
                ):
                    cols = _colsample_cols(cs_base, int(paths[node]), d, k_cols)
                    col_mask[mi, cols] = True
                gain[~col_mask] = -np.inf
            # First-occurrence argmax over row-major (feature, bin) replicates
            # the reference tie-breaking: earliest feature whose max attains
            # the global max, earliest bin within it.
            flatg = gain.reshape(M, d * ncut)
            bi_flat = np.argmax(flatg, axis=1)
            best_gain = flatg[np.arange(M), bi_flat]
            do_split = best_gain > 0.0
            if dense:
                do_split &= is_cand
            j_sel = bi_flat // ncut
            b_sel = bi_flat % ncut
            tgt = hist_nodes[do_split]
            split_feature[tgt] = j_sel[do_split]
            split_bin[tgt] = b_sel[do_split]
            split_gain[tgt] = best_gain[do_split]
            split_thr[tgt] = data.thr_pad[j_sel[do_split], b_sel[do_split]]

        is_split = split_feature >= 0
        sn = np.flatnonzero(is_split)
        S = sn.size
        # Children are allocated all-left-then-all-right so next level's
        # grouped row array is two boolean gathers, no sort. The final
        # relabeling pass erases this internal numbering anyway.
        lid = np.full(F, -1, np.int64)
        rid = np.full(F, -1, np.int64)
        lid[sn] = n_alloc + np.arange(S)
        rid[sn] = n_alloc + S + np.arange(S)

        feat_lv.append(split_feature)
        thr_lv.append(split_thr)
        left_lv.append(np.where(is_split, lid, node_ids))
        right_lv.append(np.where(is_split, rid, node_ids))
        val_lv.append(value)
        gain_lv.append(np.where(is_split, split_gain, 0.0))
        cov_lv.append(H)

        # Vectorized partition: rows of leaf nodes settle; rows of split nodes
        # route left/right on their node's (feature, bin) cut.
        if S == 0:
            leaf_of_row[srows] = np.repeat(node_ids, counts)
            break
        if S == F:
            arows = srows
            scounts = counts
        else:
            row_split = np.repeat(is_split, counts)
            leaf_of_row[srows[~row_split]] = np.repeat(
                node_ids[~is_split], counts[~is_split]
            )
            arows = srows[row_split]
            scounts = counts[sn]
        rj = np.repeat(split_feature[sn], scounts)
        rb = np.repeat(split_bin[sn], scounts)
        go_left = Xb[arows, rj] <= rb
        # Per-parent left-row counts: reduceat over the grouped go_left flags
        # (split parents always hold >= 1 row, so no empty segments).
        seg = np.concatenate([[0], np.cumsum(scounts)[:-1]])
        lcounts = np.add.reduceat(go_left.astype(np.int64), seg)
        srows = np.concatenate([arows[go_left], arows[~go_left]])
        counts = np.concatenate([lcounts, scounts - lcounts])
        paths = np.concatenate([2 * paths[sn], 2 * paths[sn] + 1])
        level_start = n_alloc
        n_alloc += 2 * S

    return _relabel_to_reference_order(
        np.concatenate(feat_lv),
        np.concatenate(thr_lv),
        np.concatenate(left_lv),
        np.concatenate(right_lv),
        np.concatenate(val_lv),
        np.concatenate(gain_lv),
        np.concatenate(cov_lv),
        leaf_of_row,
    )


# ======================================================================
# Batched engine: all B trees of an ensemble level-by-level in lockstep
# ======================================================================
#
# Random forests build B *independent* trees from one binning; the level
# engine still pays its ~40 numpy-call per-level overhead B times over.  The
# batched engine grows every tree of the ensemble in lockstep — one fused
# histogram scatter over flattened (tree, node, feature, bin) keys, one gain
# evaluation, one partition per depth for the whole forest — so the per-level
# launch overhead is paid once, not B times.  Bit-exactness with the
# reference follows the same invariants as the level engine (ascending-row
# accumulation order, identical elementwise gain ops, DFS relabeling), plus
# one new one: per-node G/H sums replicate numpy's pairwise summation
# blocking (``_segment_sums``), verified against ``np.sum`` at runtime with a
# per-segment fallback if this numpy build sums differently.

_PAIRWISE_OK: Optional[bool] = None


def _segment_sums_loop(vals, starts, counts, out):
    for i in range(counts.shape[0]):
        out[i] = vals[starts[i] : starts[i] + counts[i]].sum()
    return out


def _sums_upto128(vals, starts, counts):
    """Pairwise-emulated sums for segments of length 0..128 (numpy's
    non-recursive regime): n < 8 sequential, else eight accumulators over
    8-strided lanes, combined ``((r0+r1)+(r2+r3))+((r4+r5)+(r6+r7))`` with a
    sequential remainder tail.  Vectorized across segments (sorted descending
    so each unrolled step works on a plain prefix)."""
    out = np.zeros(counts.shape[0])
    small = np.flatnonzero((counts > 0) & (counts < 8))
    if small.size:
        sst = starts[small]
        acc = vals[sst].copy()
        scnt = counts[small]
        for k in range(1, 7):
            sel = scnt > k
            if not sel.any():
                break
            acc[sel] += vals[sst[sel] + k]
        out[small] = acc
    mid = np.flatnonzero(counts >= 8)
    if mid.size:
        order = mid[np.argsort(-counts[mid], kind="stable")]
        st = starts[order]
        cnt = counts[order]
        nblk = cnt >> 3  # full 8-blocks; block 0 initializes the lanes
        r = vals[st[:, None] + np.arange(8)]
        for b in range(1, int(nblk[0])):
            pref = int(np.searchsorted(-nblk, -(b + 1), side="right"))
            if pref == 0:
                break
            r[:pref] += vals[st[:pref, None] + (8 * b + np.arange(8))]
        res = ((r[:, 0] + r[:, 1]) + (r[:, 2] + r[:, 3])) + (
            (r[:, 4] + r[:, 5]) + (r[:, 6] + r[:, 7])
        )
        rem = cnt & 7
        if rem.any():
            tail = st + (nblk << 3)
            for k in range(7):
                sel = rem > k
                if not sel.any():
                    break
                res[sel] += vals[tail[sel] + k]
        out[order] = res
    return out


def _segment_sums_fast(vals, starts, counts, out):
    starts = np.asarray(starts)
    small = counts <= 128
    if small.all():
        out[:] = _sums_upto128(vals, starts, counts)
        return out
    out[small] = _sums_upto128(vals, starts[small], counts[small])
    # Long segments are few (near-root frontiers); numpy's own pairwise sum
    # is the oracle, so a per-segment loop is both exact and cheap here.
    for i in np.flatnonzero(~small):
        out[i] = vals[starts[i] : starts[i] + counts[i]].sum()
    return out


def _pairwise_emulation_ok() -> bool:
    """Does the vectorized emulation reproduce this numpy's ``np.sum`` bits?"""
    global _PAIRWISE_OK
    if _PAIRWISE_OK is None:
        rng = np.random.default_rng(20260729)
        lens = np.asarray(
            list(range(1, 130)) * 2 + [130, 200, 1000], np.int64
        )
        vals = rng.normal(size=int(lens.sum())) * 10.0 ** rng.integers(
            -8, 8, size=int(lens.sum())
        )
        starts = np.concatenate([[0], np.cumsum(lens)[:-1]])
        want = np.empty(lens.size)
        _segment_sums_loop(vals, starts, lens, want)
        got = np.empty(lens.size)
        _segment_sums_fast(vals, starts, lens, got)
        _PAIRWISE_OK = bool(np.array_equal(want, got))
    return _PAIRWISE_OK


def _segment_sums(vals, starts, counts, out):
    """Per-segment sums of ``vals`` (contiguous slices), bit-identical to a
    per-segment ``np.sum`` loop.  Prefers the native kernel (load-time
    self-test proves it matches ``np.sum``), then the vectorized numpy
    emulation (gated by its own runtime probe), then the plain loop."""
    if _native.available():
        return _native.segment_sums(vals, starts, counts, out)
    if _pairwise_emulation_ok():
        return _segment_sums_fast(vals, starts, counts, out)
    return _segment_sums_loop(vals, starts, counts, out)


@dataclasses.dataclass
class _BatchedScratch:
    """Reusable buffers for the fused histogram/gain kernel (capacity-doubled
    on the flattened (node, feature, bin) cell count)."""

    cells: int = 0
    GR: Optional[np.ndarray] = None
    HR: Optional[np.ndarray] = None
    HLlam: Optional[np.ndarray] = None
    gain: Optional[np.ndarray] = None
    bad: Optional[np.ndarray] = None  # bool
    bad2: Optional[np.ndarray] = None  # bool
    keybuf: Optional[np.ndarray] = None  # intp, sized to the row count
    invalid_cut: Optional[np.ndarray] = None  # bool [d, nbmax]

    def ensure(self, cells: int, rows: int):
        if self.cells < cells:
            self.cells = max(cells, 2 * self.cells)
            c = self.cells
            self.GR = np.empty(c)
            self.HR = np.empty(c)
            self.HLlam = np.empty(c)
            self.gain = np.empty(c)
            self.bad = np.empty(c, bool)
            self.bad2 = np.empty(c, bool)
        if self.keybuf is None or self.keybuf.shape[0] < rows:
            self.keybuf = np.empty(
                max(rows, 2 * (0 if self.keybuf is None else self.keybuf.shape[0])),
                np.intp,
            )


def _batched_scratch(data: BinnedData) -> _BatchedScratch:
    sc = getattr(data, "_batched", None)
    if sc is None:
        sc = _BatchedScratch()
        # cut position p of feature j is a real candidate iff p < nb[j] - 1
        sc.invalid_cut = np.arange(data.nbmax)[None, :] >= (data.nb[:, None] - 1)
        data._batched = sc
    return sc



# Cap on fused (node, feature, bin) cells per histogram/gain round; larger
# frontiers are processed in node chunks (each still thousands of cells, so
# the per-call amortization survives) to bound scratch memory.
_BATCH_MAX_CELLS = 1 << 21
# Frontier chunks with at least this many fused cells switch to the
# feature-major layout (smaller cache-resident per-feature arrays).
_FEATURE_MAJOR_CELLS = 1 << 17


def _numpy_split_search(data, sc, XbT, srows, starts, counts, cand, gsort,
                        grad_flat, hess_flat, nz_flat, all_nz, at_root, G, H,
                        parent_score, leaf_rule, cfg, lam, mcw, hess_unit,
                        col_mask, best_gain, best_j, best_b, best_hl,
                        n, d, nbmax, dn):
    """Pure-numpy split search — the fallback when the native kernel is
    unavailable.  Bit-identical to the native path: same histogram
    accumulation order, same elementwise gain operation order, same
    first-occurrence (feature, bin) tie-breaking."""
    C = cand.size
    F = counts.shape[0]
    is_cand = ~leaf_rule
    if gsort is None:
        gsort = grad_flat if at_root else np.take(grad_flat, srows)
    if nz_flat is None:
        nz_flat = (grad_flat != 0.0) | (hess_flat != 0.0)
        all_nz = bool(nz_flat.all())
    # Candidate rows, grouped by candidate node, ascending per group;
    # zero-weight rows are compacted away before the scatter.
    if C == F and all_nz:
        zrows, zg, zcounts = srows, gsort, counts
    else:
        if C == F:
            zmask = nz_flat if at_root else np.take(nz_flat, srows)
        else:
            zmask = np.repeat(is_cand, counts)
            if not all_nz:
                zmask &= nz_flat if at_root else np.take(nz_flat, srows)
        zrows = srows[zmask]
        zg = gsort[zmask]
        cs = np.concatenate([[0], np.cumsum(zmask.astype(np.int64))])
        zcounts = cs[starts[cand + 1]] - cs[starts[cand]]
    zh = None if hess_unit else np.take(hess_flat, zrows)
    zstarts = np.concatenate([[0], np.cumsum(zcounts)])
    orig_all = zrows % n

    chunk = max(1, _BATCH_MAX_CELLS // dn)
    for c0 in range(0, C, chunk):
        c1 = min(c0 + chunk, C)
        M = c1 - c0
        cells = M * dn
        r0, r1 = int(zstarts[c0]), int(zstarts[c1])
        m = r1 - r0
        orig = orig_all[r0:r1]
        wg = zg[r0:r1]
        wh = None if zh is None else zh[r0:r1]
        Gn = G[cand[c0:c1], None]
        Hn = H[cand[c0:c1], None]
        Pn = parent_score[cand[c0:c1], None]
        aM = np.arange(M)
        bgc = best_gain[c0:c1]
        bjc = best_j[c0:c1]
        bbc = best_b[c0:c1]
        bhc = best_hl[c0:c1]

        if cells >= _FEATURE_MAJOR_CELLS:
            # -- feature-major: cache-resident per-feature chains -----------
            mlen = M * nbmax
            sc.ensure(mlen, m)
            base = np.repeat(aM * nbmax, zcounts[c0:c1]).astype(np.intp)
            keybuf = sc.keybuf[:m]
            HR = sc.HR[:mlen].reshape(M, nbmax)
            GR = sc.GR[:mlen].reshape(M, nbmax)
            gain = sc.gain[:mlen].reshape(M, nbmax)
            bad = sc.bad[:mlen].reshape(M, nbmax)
            bad2 = sc.bad2[:mlen].reshape(M, nbmax)
            HLlam = sc.HLlam[:mlen].reshape(M, nbmax)
            for j in range(d):
                np.add(base, np.take(XbT[j], orig), out=keybuf,
                       casting="unsafe")
                GL = np.bincount(
                    keybuf, weights=wg, minlength=mlen
                ).reshape(M, nbmax)
                if hess_unit:
                    HL = np.bincount(keybuf, minlength=mlen).astype(
                        np.float64
                    ).reshape(M, nbmax)
                else:
                    HL = np.bincount(
                        keybuf, weights=wh, minlength=mlen
                    ).reshape(M, nbmax)
                np.cumsum(GL, axis=1, out=GL)
                np.cumsum(HL, axis=1, out=HL)
                np.less(HL, mcw, out=bad)
                np.subtract(Hn, HL, out=HR)
                np.less(HR, mcw, out=bad2)
                np.logical_or(bad, bad2, out=bad)
                np.logical_or(bad, sc.invalid_cut[j][None, :], out=bad)
                with np.errstate(divide="ignore", invalid="ignore"):
                    np.multiply(GL, GL, out=gain)
                    if lam != 0.0:
                        np.add(HL, lam, out=HLlam)
                        gain /= HLlam
                    else:
                        gain /= HL
                    np.subtract(Gn, GL, out=GR)
                    GR *= GR
                    HR += lam
                    GR /= HR
                    gain += GR
                    gain -= Pn
                    gain *= 0.5
                    if cfg.gamma != 0.0:
                        gain -= cfg.gamma
                np.copyto(gain, -np.inf, where=bad)
                bi = np.argmax(gain, axis=1)
                val = gain[aM, bi]
                upd = val > bgc  # strict: earlier feature wins ties
                if col_mask is not None:
                    upd &= col_mask[c0:c1, j]
                if upd.any():
                    bgc[upd] = val[upd]
                    bjc[upd] = j
                    bbc[upd] = bi[upd]
                    bhc[upd] = HL[upd, bi[upd]]  # pre-lam cumsum
        else:
            # -- fused: one scatter for all (node, feature, bin) ------------
            sc.ensure(cells, m)
            keys = data.key_off[:, orig]
            keys += (np.repeat(aM, zcounts[c0:c1]) * dn)[None, :]
            flat = keys.reshape(-1)
            GL = np.bincount(
                flat, weights=np.tile(wg, d), minlength=cells
            ).reshape(M, d, nbmax)
            if hess_unit:
                HL = np.bincount(flat, minlength=cells).astype(
                    np.float64
                ).reshape(M, d, nbmax)
            else:
                HL = np.bincount(
                    flat, weights=np.tile(wh, d), minlength=cells
                ).reshape(M, d, nbmax)
            np.cumsum(GL, axis=2, out=GL)
            np.cumsum(HL, axis=2, out=HL)
            HR = sc.HR[:cells].reshape(M, d, nbmax)
            GR = sc.GR[:cells].reshape(M, d, nbmax)
            gain = sc.gain[:cells].reshape(M, d, nbmax)
            bad = sc.bad[:cells].reshape(M, d, nbmax)
            bad2 = sc.bad2[:cells].reshape(M, d, nbmax)
            np.less(HL, mcw, out=bad)
            np.subtract(Hn[:, :, None], HL, out=HR)
            np.less(HR, mcw, out=bad2)
            np.logical_or(bad, bad2, out=bad)
            np.logical_or(bad, sc.invalid_cut[None, :, :], out=bad)
            with np.errstate(divide="ignore", invalid="ignore"):
                np.multiply(GL, GL, out=gain)
                if lam != 0.0:
                    HLlam = sc.HLlam[:cells].reshape(M, d, nbmax)
                    np.add(HL, lam, out=HLlam)
                    gain /= HLlam
                else:
                    gain /= HL
                np.subtract(Gn[:, :, None], GL, out=GR)
                GR *= GR
                HR += lam
                GR /= HR
                gain += GR
                gain -= Pn[:, :, None]
                gain *= 0.5
                if cfg.gamma != 0.0:
                    gain -= cfg.gamma
            np.copyto(gain, -np.inf, where=bad)
            if col_mask is not None:
                np.copyto(gain, -np.inf, where=~col_mask[c0:c1, :, None])
            # First-occurrence argmax over row-major (feature, bin)
            # replicates the reference tie-breaking.
            flatg = gain.reshape(M, dn)
            bi = np.argmax(flatg, axis=1)
            bgc[:] = flatg[aM, bi]
            bjc[:] = bi // nbmax
            bbc[:] = bi % nbmax
            bhc[:] = HL.reshape(M, dn)[aM, bi]  # pre-lam cumsum


def build_forest_batched(
    data: BinnedData,
    grads: np.ndarray,
    hesses: np.ndarray,
    cfg: TreeBuilderConfig,
    rngs=None,
    colsample: float = 1.0,
    col_keys=None,
) -> List[Tuple[TreeArrays, np.ndarray]]:
    """Grow all ``B`` independent trees level-by-level in lockstep.

    ``grads``/``hesses`` are ``[B, n]`` per-tree gradient/hessian rows over
    the shared binning.  Returns one ``(tree, leaf_of_row)`` pair per tree,
    bit-identical to running the reference builder per tree at any
    ``colsample``.  With ``colsample < 1.0`` pass either ``rngs`` (one
    generator per tree; each is consumed for exactly one ``_colsample_base``
    draw up front) or ``col_keys`` (the base keys themselves, for callers
    that interleave key draws with other per-tree consumption of a shared
    stream — see ``RandomForestRegressor.fit``).  Per-node feature subsets
    are then keyed on ``(base, heap path)``, so the lockstep build draws the
    same subsets the serial engines draw.

    The heavy per-level work — per-node G/H sums, histogram + best-split
    search, and the row partition — runs in the native kernels of
    ``_native.py`` when a C compiler is available (bit-exact by construction
    and load-time self-test; ``REPRO_NATIVE_THREADS`` workers, re-read here
    at every fit), falling back to vectorized numpy layouts otherwise:

    - *fused* (small frontiers): one scatter-add over flattened
      ``(node, feature, bin)`` keys for every candidate node of every tree,
      then one gain chain and one argmax — per-level launch overhead is paid
      once for the whole forest.
    - *feature-major* (large frontiers): per feature, a ``[nodes, bins]``
      histogram small enough to stay cache-resident through its entire
      cumsum -> gain -> argmax chain, with a running strict-``>`` best-split
      update that replicates the reference's feature iteration (earlier
      feature wins ties).
    """
    grads = np.ascontiguousarray(grads, np.float64)
    hesses = np.ascontiguousarray(hesses, np.float64)
    B, n = grads.shape
    Xb = data.Xb
    d = Xb.shape[1]
    lam = cfg.reg_lambda
    mcw = cfg.min_child_weight
    nbmax = data.nbmax
    dn = d * nbmax
    nat = _native.available()
    sc = _batched_scratch(data)
    XbT = getattr(data, "_XbT", None)
    if XbT is None:
        XbT = data._XbT = np.ascontiguousarray(Xb.T)

    sample_cols = colsample < 1.0 and (rngs is not None or col_keys is not None)
    k_cols = max(1, int(round(colsample * d))) if sample_cols else d
    if sample_cols and col_keys is None:
        # One base-key draw per tree, in tree order — exactly what the
        # serial engines consume from these generators.
        col_keys = [_colsample_base(r) for r in rngs]
    grad_flat = grads.reshape(-1)
    hess_flat = hesses.reshape(-1)
    # Integer hessians (RF bootstrap counts, GBT regression's 0/1 subsample
    # mask) make every hessian sum exact in any order, so H flows down the
    # tree by subtraction (child = parent - sibling) instead of per-level
    # segment sums, and with 0/1 hessians the hessian histogram degenerates
    # to an unweighted key count.  Zero-weight rows contribute exact +0.0 to
    # every histogram bin (the level engine's rule), so the numpy layouts may
    # drop them from the scatter and the native kernel may keep them.
    hess_int = mcw > 0 and bool(np.all(hesses == np.floor(hesses)))
    hess_one = bool(np.all(hesses == 1.0))
    hess_unit = hess_one or (
        hess_int
        and bool(np.all(np.where(hesses == 0.0, grads == 0.0, hesses == 1.0)))
    )
    nz_flat = None
    all_nz = True
    if not nat:
        nz_flat = (grad_flat != 0.0) | (hess_flat != 0.0)
        all_nz = bool(nz_flat.all())

    # Frontier state over all trees at once.  Rows are keyed by flat id
    # t*n + row; each frontier node's rows stay grouped and ascending.
    srows = np.arange(B * n, dtype=np.int64)
    counts = np.full(B, n, dtype=np.int64)
    node_tree = np.arange(B, dtype=np.int64)
    node_bfs = np.zeros(B, dtype=np.int64)  # per-tree BFS id of each node
    node_path = (
        np.ones(B, dtype=_path_dtype(cfg.max_depth)) if sample_cols else None
    )
    nthreads = _native.native_threads() if nat else 1  # re-read per fit
    n_alloc = np.ones(B, dtype=np.int64)
    leaf_flat = np.zeros(B * n, dtype=np.int64)
    H_state = hesses.sum(axis=1) if hess_int else None

    feat_lv: List[np.ndarray] = []
    thr_lv: List[np.ndarray] = []
    left_lv: List[np.ndarray] = []
    right_lv: List[np.ndarray] = []
    val_lv: List[np.ndarray] = []
    gain_lv: List[np.ndarray] = []
    cov_lv: List[np.ndarray] = []
    tree_lv: List[np.ndarray] = []
    bfs_lv: List[np.ndarray] = []

    for depth in range(cfg.max_depth + 1):
        F = counts.shape[0]
        starts = np.concatenate([[0], np.cumsum(counts)])
        at_root = depth == 0
        gsort = hsort = None
        G = np.empty(F)
        if nat:
            _native.segment_sums(
                grad_flat, srows, starts[:-1], counts, G, nthreads=nthreads
            )
        else:
            gsort = grad_flat if at_root else np.take(grad_flat, srows)
            _segment_sums(gsort, starts[:-1], counts, G)
        if hess_int:
            H = H_state
        else:
            H = np.empty(F)
            if nat:
                _native.segment_sums(
                    hess_flat, srows, starts[:-1], counts, H, nthreads=nthreads
                )
            else:
                hsort = hess_flat if at_root else np.take(hess_flat, srows)
                _segment_sums(hsort, starts[:-1], counts, H)
        with np.errstate(divide="ignore", invalid="ignore"):
            value = -G / (H + lam)
            parent_score = G * G / (H + lam)

        leaf_rule = (
            (depth >= cfg.max_depth)
            | (counts < cfg.min_samples_split)
            | (H < 2 * mcw)
        )
        split_feature = np.full(F, -1, np.int64)
        split_bin = np.zeros(F, np.int64)
        split_gain = np.zeros(F, np.float64)
        split_thr = np.zeros(F, np.float64)
        Hl_split = np.zeros(F, np.float64) if hess_int else None

        cand = np.flatnonzero(~leaf_rule)
        C = cand.size
        if C and nbmax > 1:
            col_mask = None
            if sample_cols:
                # Keyed per-node subsets: (tree base key, heap path) fully
                # determine the draw, so lockstep order is irrelevant.
                col_mask = np.zeros((C, d), bool)
                for ci in range(C):
                    node = cand[ci]
                    base = col_keys[int(node_tree[node])]
                    cols = _colsample_cols(
                        base, int(node_path[node]), d, k_cols
                    )
                    col_mask[ci, cols] = True

            best_gain = np.full(C, -np.inf)
            best_j = np.zeros(C, np.int64)
            best_b = np.zeros(C, np.int64)
            best_hl = np.zeros(C)

            if nat and mcw > 0:
                # Candidate rows are contiguous ranges of srows — the kernel
                # gathers grad/hess and bins per row itself, so no compaction
                # or weight materialization happens on the Python side.
                _native.split_finder(
                    starts[cand], starts[cand + 1], srows, Xb, grad_flat,
                    None if hess_one else hess_flat,
                    np.ascontiguousarray(G[cand]),
                    np.ascontiguousarray(H[cand]),
                    np.ascontiguousarray(parent_score[cand]),
                    data.nb, col_mask, lam, mcw, cfg.gamma,
                    best_gain, best_j, best_b, best_hl, nthreads=nthreads,
                )
            else:
                _numpy_split_search(
                    data, sc, XbT, srows, starts, counts, cand, gsort,
                    grad_flat, hess_flat, nz_flat, all_nz, at_root, G, H,
                    parent_score, leaf_rule, cfg, lam, mcw, hess_unit,
                    col_mask, best_gain, best_j, best_b, best_hl,
                    n, d, nbmax, dn,
                )

            do = best_gain > 0.0
            tgt = cand[do]
            split_feature[tgt] = best_j[do]
            split_bin[tgt] = best_b[do]
            split_gain[tgt] = best_gain[do]
            split_thr[tgt] = data.thr_pad[best_j[do], best_b[do]]
            if Hl_split is not None:
                Hl_split[tgt] = best_hl[do]

        is_split = split_feature >= 0
        sn = np.flatnonzero(is_split)
        S = sn.size
        # Children are allocated all-left-then-all-right per level; ids live
        # in each tree's own BFS numbering (a tree's nodes appear within
        # every level block in its own BFS order).
        st = node_tree[sn]
        S_t = np.bincount(st, minlength=B)
        if S:
            perm = np.argsort(st, kind="stable")
            gstart = np.concatenate([[0], np.cumsum(S_t)])[:-1]
            rank = np.empty(S, np.int64)
            rank[perm] = np.arange(S) - gstart[st[perm]]
            lid = n_alloc[st] + rank
            rid = lid + S_t[st]
        else:
            lid = rid = np.empty(0, np.int64)

        lcol = node_bfs.copy()
        rcol = node_bfs.copy()
        if S:
            lcol[sn] = lid
            rcol[sn] = rid
        feat_lv.append(split_feature)
        thr_lv.append(split_thr)
        left_lv.append(lcol)
        right_lv.append(rcol)
        val_lv.append(value)
        gain_lv.append(np.where(is_split, split_gain, 0.0))
        cov_lv.append(H)
        tree_lv.append(node_tree)
        bfs_lv.append(node_bfs)

        if S == 0:
            leaf_flat[srows] = np.repeat(node_bfs, counts)
            break
        scounts = counts[sn]
        if S < F:
            row_split = np.repeat(is_split, counts)
            settled = srows[~row_split]
            leaf_flat[settled] = np.repeat(node_bfs[~is_split], counts[~is_split])
        if nat:
            srows, lcounts = _native.partition(
                starts[sn], starts[sn + 1], srows, Xb,
                split_feature[sn], split_bin[sn], nthreads=nthreads,
            )
        else:
            arows = srows if S == F else srows[row_split]
            rj = np.repeat(split_feature[sn], scounts)
            rb = np.repeat(split_bin[sn], scounts)
            go_left = Xb[arows % n, rj] <= rb
            seg = np.concatenate([[0], np.cumsum(scounts)[:-1]])
            lcounts = np.add.reduceat(go_left.astype(np.int64), seg)
            srows = np.concatenate([arows[go_left], arows[~go_left]])
        counts = np.concatenate([lcounts, scounts - lcounts])
        node_tree = np.concatenate([st, st])
        node_bfs = np.concatenate([lid, rid])
        if node_path is not None:
            node_path = np.concatenate(
                [2 * node_path[sn], 2 * node_path[sn] + 1]
            )
        n_alloc += 2 * S_t
        if hess_int:
            Hl = Hl_split[sn]
            H_state = np.concatenate([Hl, H[sn] - Hl])

    # Assemble per-tree BFS arrays with one scatter per field, then permute
    # each tree into the reference's DFS emission order.
    tree_all = np.concatenate(tree_lv)
    bfs_all = np.concatenate(bfs_lv)
    tree_base = np.concatenate([[0], np.cumsum(n_alloc)])
    pos = tree_base[tree_all] + bfs_all
    ntot = int(tree_base[-1])

    def scat(chunks, dtype=np.float64):
        buf = np.empty(ntot, dtype)
        buf[pos] = np.concatenate(chunks)
        return buf

    feat_a = scat(feat_lv, np.int64)
    thr_a = scat(thr_lv)
    left_a = scat(left_lv, np.int64)
    right_a = scat(right_lv, np.int64)
    val_a = scat(val_lv)
    gain_a = scat(gain_lv)
    cov_a = scat(cov_lv)

    out: List[Tuple[TreeArrays, np.ndarray]] = []
    for t in range(B):
        lo, hi = int(tree_base[t]), int(tree_base[t + 1])
        tree, leaf = _relabel_to_reference_order(
            feat_a[lo:hi],
            thr_a[lo:hi],
            left_a[lo:hi],
            right_a[lo:hi],
            val_a[lo:hi],
            gain_a[lo:hi],
            cov_a[lo:hi],
            leaf_flat[t * n : (t + 1) * n],
        )
        out.append((tree, leaf))
    return out


def _build_batched(
    Xb,
    edges: list,
    grad: np.ndarray,
    hess: np.ndarray,
    cfg: TreeBuilderConfig,
    rng: Optional[np.random.Generator],
    colsample: float,
) -> Tuple[TreeArrays, np.ndarray]:
    """Single-tree entry point: the batched kernel with B=1 (shares the
    ensemble scratch via BinnedData; like every engine it consumes ``rng``
    for exactly one base-key draw when ``colsample < 1.0``).

    Tiny builds delegate to the level engine: below ~50 rows the batched
    frontier bookkeeping costs more than it saves, and the two engines are
    bit-identical for single trees (including the colsample RNG stream), so
    the delegation is invisible in the output."""
    if grad.shape[0] <= 48:
        return _build_levelwise(Xb, edges, grad, hess, cfg, rng, colsample)
    data = Xb if isinstance(Xb, BinnedData) else BinnedData.build(Xb, edges)
    rngs = [rng] if rng is not None else None
    return build_forest_batched(
        data, grad[None, :], hess[None, :], cfg, rngs=rngs, colsample=colsample
    )[0]


_ENGINES = {
    "batched": _build_batched,
    "level": _build_levelwise,
    "reference": _build_reference,
}


def build_tree_with_leaves(
    Xb,
    edges: Optional[list] = None,
    grad: Optional[np.ndarray] = None,
    hess: Optional[np.ndarray] = None,
    cfg: Optional[TreeBuilderConfig] = None,
    rng: Optional[np.random.Generator] = None,
    colsample: float = 1.0,
    engine: Optional[str] = None,
) -> Tuple[TreeArrays, np.ndarray]:
    """Build one tree and return ``(tree, leaf_of_row)``.

    ``Xb`` is either a uint16 bin matrix (with ``edges``) or a prebuilt
    :class:`BinnedData`.  ``leaf_of_row[i]`` is the node id row i settles in —
    the builder already knows it from partitioning, so boosting can scatter
    leaf values instead of re-descending every row (``predict_tree_np``) each
    round.
    """
    name = resolve_engine(engine)
    try:
        fn = _ENGINES[name]
    except KeyError:
        raise ValueError(f"unknown tree engine {name!r}; want one of {sorted(_ENGINES)}")
    return fn(Xb, edges, grad, hess, cfg, rng, colsample)


def build_tree(
    Xb,
    edges: Optional[list] = None,
    grad: Optional[np.ndarray] = None,
    hess: Optional[np.ndarray] = None,
    cfg: Optional[TreeBuilderConfig] = None,
    rng: Optional[np.random.Generator] = None,
    colsample: float = 1.0,
    engine: Optional[str] = None,
) -> TreeArrays:
    """Greedy histogram tree on pre-binned features ``Xb``."""
    return build_tree_with_leaves(Xb, edges, grad, hess, cfg, rng, colsample, engine)[0]


def predict_tree_np(tree: TreeArrays, X: np.ndarray, max_depth: int) -> np.ndarray:
    """Numpy oracle for a single tree (matches JAX/Pallas descent exactly)."""
    idx = np.zeros(X.shape[0], dtype=np.int64)
    for _ in range(max_depth + 1):
        f = tree.feature[idx]
        leaf = f < 0
        fx = X[np.arange(X.shape[0]), np.maximum(f, 0)]
        go_left = fx <= tree.threshold[idx]
        nxt = np.where(go_left, tree.left[idx], tree.right[idx])
        idx = np.where(leaf, idx, nxt)
    return tree.value[idx].astype(np.float64)
