"""Histogram-based decision-tree builder shared by GBT (gbt.py) and RF (forest.py).

Design
------
Building greedy trees is inherently sequential and data-dependent, so the
*builder* runs host-side on numpy (fast for the paper's n=141..10^4 regime).
The *fitted* trees are packed into dense, fixed-shape arrays (heap-free child
pointers) so that inference is a pure JAX tensor program: iterative descent,
``max_depth`` gather steps, fully vmappable over rows and trees, and
Pallas-tileable (see ``repro/kernels/gbt_predict.py``).

The split objective is the XGBoost second-order gain

    gain = 1/2 * [ GL^2/(HL+lam) + GR^2/(HR+lam) - G^2/(H+lam) ] - gamma

with leaf weight ``w = -G/(H+lam)``.  Random-Forest regression is the special
case g = -(y - mean), h = 1, lam = 0 (variance reduction; leaf = mean).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

__all__ = [
    "TreeArrays",
    "TreeBuilderConfig",
    "build_tree",
    "compute_bins",
    "bin_features",
    "predict_tree_np",
]


@dataclasses.dataclass
class TreeArrays:
    """One fitted tree as dense arrays (size = n_nodes, BFS order).

    ``feature[i] < 0`` marks a leaf; leaves self-loop (left==right==i) so a
    fixed ``max_depth``-step descent always lands on the correct leaf.
    """

    feature: np.ndarray  # int32  [n_nodes]
    threshold: np.ndarray  # float32[n_nodes]  (raw feature-space threshold)
    left: np.ndarray  # int32  [n_nodes]
    right: np.ndarray  # int32  [n_nodes]
    value: np.ndarray  # float32[n_nodes]  (leaf weight; internal nodes too, for truncation)
    gain: np.ndarray  # float32[n_nodes]  (split gain; 0 at leaves) — for importances
    cover: np.ndarray  # float32[n_nodes]  (sum of hessians reaching node)

    @property
    def n_nodes(self) -> int:
        return int(self.feature.shape[0])

    def padded(self, max_nodes: int) -> "TreeArrays":
        """Pad to ``max_nodes`` so trees stack into a ragged-free ensemble."""
        n = self.n_nodes
        if n > max_nodes:
            raise ValueError(f"tree has {n} nodes > max_nodes={max_nodes}")
        pad = max_nodes - n

        def _pad(a: np.ndarray, fill) -> np.ndarray:
            return np.concatenate([a, np.full((pad,), fill, dtype=a.dtype)])

        # Padded nodes are self-looping leaves with value 0.
        idx = np.arange(n, max_nodes, dtype=np.int32)
        return TreeArrays(
            feature=_pad(self.feature, -1),
            threshold=_pad(self.threshold, 0.0),
            left=np.concatenate([self.left, idx]),
            right=np.concatenate([self.right, idx]),
            value=_pad(self.value, 0.0),
            gain=_pad(self.gain, 0.0),
            cover=_pad(self.cover, 0.0),
        )


@dataclasses.dataclass(frozen=True)
class TreeBuilderConfig:
    max_depth: int = 6
    min_samples_split: int = 2
    min_child_weight: float = 1e-3  # min hessian sum per child
    reg_lambda: float = 1.0
    gamma: float = 0.0  # min gain to split
    max_bins: int = 64


def compute_bins(X: np.ndarray, max_bins: int) -> list[np.ndarray]:
    """Quantile bin edges per feature. Edges are *upper* bounds; a row goes
    left iff ``x <= threshold``."""
    edges = []
    for j in range(X.shape[1]):
        col = X[:, j]
        qs = np.quantile(col, np.linspace(0, 1, max_bins + 1)[1:-1])
        e = np.unique(qs.astype(np.float64))
        edges.append(e)
    return edges


def bin_features(X: np.ndarray, edges: list[np.ndarray]) -> np.ndarray:
    """Map raw features to bin indices (uint16)."""
    out = np.empty(X.shape, dtype=np.uint16)
    for j, e in enumerate(edges):
        out[:, j] = np.searchsorted(e, X[:, j], side="left")
    return out


def _leaf_value(G: float, H: float, lam: float) -> float:
    return float(-G / (H + lam))


def build_tree(
    Xb: np.ndarray,
    edges: list[np.ndarray],
    grad: np.ndarray,
    hess: np.ndarray,
    cfg: TreeBuilderConfig,
    rng: Optional[np.random.Generator] = None,
    colsample: float = 1.0,
) -> TreeArrays:
    """Greedy BFS histogram tree on pre-binned features ``Xb``."""
    n, d = Xb.shape
    feature, threshold, left, right, value, gains, covers = [], [], [], [], [], [], []

    # Each queue entry: (node_id, row_indices, depth)
    def new_node() -> int:
        feature.append(-1)
        threshold.append(0.0)
        left.append(0)
        right.append(0)
        value.append(0.0)
        gains.append(0.0)
        covers.append(0.0)
        return len(feature) - 1

    root = new_node()
    stack = [(root, np.arange(n), 0)]
    lam = cfg.reg_lambda

    while stack:
        nid, rows, depth = stack.pop()
        g = grad[rows]
        h = hess[rows]
        G, H = float(g.sum()), float(h.sum())
        value[nid] = _leaf_value(G, H, lam)
        covers[nid] = H
        parent_score = G * G / (H + lam)

        make_leaf = (
            depth >= cfg.max_depth
            or rows.size < cfg.min_samples_split
            or H < 2 * cfg.min_child_weight
        )
        best = None  # (gain, feat, bin_idx)
        if not make_leaf:
            feats = np.arange(d)
            if colsample < 1.0 and rng is not None:
                k = max(1, int(round(colsample * d)))
                feats = rng.choice(d, size=k, replace=False)
            for j in feats:
                e = edges[j]
                nb = e.size + 1
                if nb <= 1:
                    continue
                b = Xb[rows, j]
                Gh = np.bincount(b, weights=g, minlength=nb)
                Hh = np.bincount(b, weights=h, minlength=nb)
                GL = np.cumsum(Gh)[:-1]
                HL = np.cumsum(Hh)[:-1]
                GR = G - GL
                HR = H - HL
                ok = (HL >= cfg.min_child_weight) & (HR >= cfg.min_child_weight)
                if not ok.any():
                    continue
                with np.errstate(divide="ignore", invalid="ignore"):
                    gain = 0.5 * (
                        GL * GL / (HL + lam) + GR * GR / (HR + lam) - parent_score
                    ) - cfg.gamma
                gain = np.where(ok, gain, -np.inf)
                bi = int(np.argmax(gain))
                if best is None or gain[bi] > best[0]:
                    best = (float(gain[bi]), int(j), bi)
            if best is None or best[0] <= 0.0:
                make_leaf = True

        if make_leaf:
            left[nid] = nid
            right[nid] = nid
            continue

        gbest, j, bi = best
        thr = float(edges[j][bi])
        go_left = Xb[rows, j] <= bi
        lrows, rrows = rows[go_left], rows[~go_left]
        lid, rid = new_node(), new_node()
        feature[nid] = j
        threshold[nid] = thr
        left[nid] = lid
        right[nid] = rid
        gains[nid] = gbest
        stack.append((lid, lrows, depth + 1))
        stack.append((rid, rrows, depth + 1))

    return TreeArrays(
        feature=np.asarray(feature, np.int32),
        threshold=np.asarray(threshold, np.float32),
        left=np.asarray(left, np.int32),
        right=np.asarray(right, np.int32),
        value=np.asarray(value, np.float32),
        gain=np.asarray(gains, np.float32),
        cover=np.asarray(covers, np.float32),
    )


def predict_tree_np(tree: TreeArrays, X: np.ndarray, max_depth: int) -> np.ndarray:
    """Numpy oracle for a single tree (matches JAX/Pallas descent exactly)."""
    idx = np.zeros(X.shape[0], dtype=np.int64)
    for _ in range(max_depth + 1):
        f = tree.feature[idx]
        leaf = f < 0
        fx = X[np.arange(X.shape[0]), np.maximum(f, 0)]
        go_left = fx <= tree.threshold[idx]
        nxt = np.where(go_left, tree.left[idx], tree.right[idx])
        idx = np.where(leaf, idx, nxt)
    return tree.value[idx].astype(np.float64)
