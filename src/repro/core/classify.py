"""Format/backend recommendation classifiers (paper RQ3, the "three
classification approaches"): logistic regression (JAX), Random Forest,
GBT — one-vs-rest for multiclass."""

from __future__ import annotations

from functools import partial
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .forest import RandomForestClassifier, RFConfig
from .gbt import GBTBinaryClassifier, GBTConfig

__all__ = ["LogisticRegression", "OneVsRestClassifier", "CLASSIFIER_ZOO", "make_classifier"]


@partial(jax.jit, static_argnames=("n_iter",))
def _fit_logistic(X, y, l2, lr, n_iter=500):
    n, d = X.shape

    def loss(wb):
        w, b = wb
        z = X @ w + b
        # stable logistic loss
        ll = jnp.mean(jnp.maximum(z, 0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z))))
        return ll + l2 * jnp.sum(w * w)

    def body(_, wb):
        g = jax.grad(loss)(wb)
        return (wb[0] - lr * g[0], wb[1] - lr * g[1])

    w0 = (jnp.zeros(d, X.dtype), jnp.zeros((), X.dtype))
    return jax.lax.fori_loop(0, n_iter, body, w0)


class LogisticRegression:
    def __init__(self, l2: float = 1e-3, lr: float = 0.5, n_iter: int = 500):
        self.l2, self.lr, self.n_iter = l2, lr, n_iter
        self.w, self.b = None, None
        self._mu, self._sd = None, None

    def fit(self, X, y):
        X = np.asarray(X, np.float64)
        self._mu = X.mean(0)
        sd = X.std(0)
        self._sd = np.where(sd > 0, sd, 1.0)
        Xs = jnp.asarray((X - self._mu) / self._sd)
        self.w, self.b = _fit_logistic(
            Xs, jnp.asarray(np.asarray(y, np.float64)), self.l2, self.lr, self.n_iter
        )
        return self

    def decision_function(self, X):
        Xs = (np.asarray(X, np.float64) - self._mu) / self._sd
        return np.asarray(Xs @ np.asarray(self.w) + float(self.b))

    def predict_proba(self, X):
        return 1.0 / (1.0 + np.exp(-self.decision_function(X)))

    def predict(self, X):
        return (self.predict_proba(X) >= 0.5).astype(np.int64)


class OneVsRestClassifier:
    def __init__(self, make_binary, n_classes: int):
        self.make_binary = make_binary
        self.n_classes = n_classes
        self.models = []

    def fit(self, X, y):
        y = np.asarray(y, np.int64)
        self.models = []
        for c in range(self.n_classes):
            m = self.make_binary()
            m.fit(X, (y == c).astype(np.float64))
            self.models.append(m)
        return self

    def predict(self, X):
        scores = np.stack([m.predict_proba(X) for m in self.models], axis=1)
        return np.argmax(scores, axis=1)


CLASSIFIER_ZOO: Dict[str, object] = {
    "logistic": lambda n_classes, seed=0: OneVsRestClassifier(
        lambda: LogisticRegression(), n_classes
    ),
    "random_forest": lambda n_classes, seed=0: OneVsRestClassifier(
        lambda: RandomForestClassifier(
            RFConfig(n_estimators=50, max_depth=8, seed=seed)
        ),
        n_classes,
    ),
    "gbt": lambda n_classes, seed=0: OneVsRestClassifier(
        lambda: GBTBinaryClassifier(
            GBTConfig(n_estimators=50, max_depth=4, learning_rate=0.2, seed=seed)
        ),
        n_classes,
    ),
}


def make_classifier(name: str, n_classes: int, seed: int = 0):
    return CLASSIFIER_ZOO[name](n_classes, seed=seed)
