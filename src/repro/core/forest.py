"""Random Forest (paper §3.3.2: 100 trees, max_depth=10, min_samples_split=5).

Each tree is fit on a bootstrap sample with sqrt-ish column subsampling using
the shared histogram builder (g = -(y - y_bar), h = 1, lambda = 0 reduces the
XGBoost gain to variance reduction; leaf value = node mean offset).
Prediction averages trees via the shared packed-ensemble JAX program.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .ensemble_base import PackedEnsemble, pack_trees, predict_ensemble
from .tree import (
    BinnedData,
    TreeBuilderConfig,
    _colsample_base,
    bin_features,
    build_forest_batched,
    build_tree,
    compute_bins,
    resolve_engine,
)

__all__ = ["RFConfig", "RandomForestRegressor", "RandomForestClassifier"]


@dataclasses.dataclass(frozen=True)
class RFConfig:
    n_estimators: int = 100
    max_depth: int = 10
    min_samples_split: int = 5
    colsample: float = 1.0  # paper uses default sklearn (all features for regression)
    max_bins: int = 64
    seed: int = 0


class RandomForestRegressor:
    def __init__(self, config: Optional[RFConfig] = None, engine: Optional[str] = None, **kw):
        self.config = config or RFConfig(**kw)
        self.engine = engine  # tree-builder engine; None = tree.DEFAULT_ENGINE
        self.ensemble: Optional[PackedEnsemble] = None
        self.feature_importances_: Optional[np.ndarray] = None

    def fit(self, X: np.ndarray, y: np.ndarray):
        cfg = self.config
        X = np.asarray(X, np.float64)
        y = np.asarray(y, np.float64)
        n, d = X.shape
        rng = np.random.default_rng(cfg.seed)
        edges = compute_bins(X, cfg.max_bins)
        binned = BinnedData.build(bin_features(X, edges), edges)
        tcfg = TreeBuilderConfig(
            max_depth=cfg.max_depth,
            min_samples_split=cfg.min_samples_split,
            min_child_weight=1.0,  # at least one bootstrap row per child
            reg_lambda=0.0,
            gamma=0.0,
            max_bins=cfg.max_bins,
        )
        ybar = float(y.mean())
        engine = resolve_engine(self.engine)
        if engine == "batched":
            # All B trees in one lockstep ensemble build.  The per-tree loop
            # below consumes the shared stream as (bootstrap_t, colsample
            # base key_t) per tree; pre-drawing both in the same order here
            # replays it exactly, and the keyed per-node column draws make
            # the lockstep build bit-identical to the level/reference
            # engines at any colsample.
            W = np.empty((cfg.n_estimators, n))
            col_keys = [] if cfg.colsample < 1.0 else None
            for t in range(cfg.n_estimators):
                W[t] = np.bincount(rng.integers(0, n, size=n), minlength=n)
                if col_keys is not None:
                    col_keys.append(_colsample_base(rng))
            grads = -(y - ybar)[None, :] * W
            trees = [
                t for t, _ in build_forest_batched(
                    binned, grads, W, tcfg,
                    colsample=cfg.colsample, col_keys=col_keys,
                )
            ]
        else:
            trees = []
            for _ in range(cfg.n_estimators):
                rows = rng.integers(0, n, size=n)  # bootstrap
                w = np.bincount(rows, minlength=n).astype(np.float64)
                # weighted residual target: g = -(y - ybar) * w, h = w
                g = -(y - ybar) * w
                h = w
                trees.append(
                    build_tree(binned, edges, g, h, tcfg, rng, cfg.colsample,
                               engine=engine)
                )
        imp = np.zeros(d)
        for tree in trees:
            split = tree.feature >= 0
            np.add.at(imp, tree.feature[split], tree.gain[split])
        tot = imp.sum()
        self.feature_importances_ = imp / tot if tot > 0 else imp
        self.ensemble = pack_trees(
            trees,
            cfg.max_depth,
            base_score=ybar,
            scale=1.0 / cfg.n_estimators,
        )
        return self

    def predict(self, X: np.ndarray) -> np.ndarray:
        assert self.ensemble is not None, "fit() first"
        return np.asarray(predict_ensemble(self.ensemble, np.asarray(X, np.float32)))


class RandomForestClassifier:
    """Binary RF classifier: average of per-tree probability-ish leaves.

    Implemented as RF regression on {0,1} labels with a 0.5 threshold —
    identical to sklearn's prob-vote for binary trees with pure-ish leaves.
    """

    def __init__(self, config: Optional[RFConfig] = None, **kw):
        self._reg = RandomForestRegressor(config, **kw)

    @property
    def feature_importances_(self):
        return self._reg.feature_importances_

    def fit(self, X, y):
        self._reg.fit(X, np.asarray(y, np.float64))
        return self

    def predict_proba(self, X):
        return np.clip(self._reg.predict(X), 0.0, 1.0)

    def predict(self, X):
        return (self.predict_proba(X) >= 0.5).astype(np.int64)
