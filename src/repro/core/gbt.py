"""XGBoost-style gradient-boosted trees, JAX inference + host-side builder.

Implements the paper's winning model (§3.3.2: 100 estimators, max_depth=6,
learning_rate=0.1, subsample=0.8) with second-order gradients, L2 leaf
regularization (lambda), min-split-gain (gamma), and row/column subsampling.

Boosting rounds are sequential (each tree fits the previous rounds'
residuals), so every round runs the tree engine with a single tree; the
default ``"batched"`` engine still pays off because all 100 rounds share the
``BinnedData`` precomputes and scratch, its native split kernel, and the
builder's own leaf assignments for the prediction update (see
``docs/fit-engine.md``).  ``engine=`` / REPRO_TREE_ENGINE select the
level/reference oracles, resolved at fit time.

Supports squared-error regression and binary logistic classification (the
paper's RQ3 classifiers); multiclass via one-vs-rest in classify.py.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .ensemble_base import PackedEnsemble, pack_trees, predict_ensemble
from .tree import (
    BinnedData,
    TreeBuilderConfig,
    bin_features,
    build_tree_with_leaves,
    compute_bins,
)

__all__ = ["GBTConfig", "GBTRegressor", "GBTBinaryClassifier"]


@dataclasses.dataclass(frozen=True)
class GBTConfig:
    n_estimators: int = 100
    max_depth: int = 6
    learning_rate: float = 0.1
    subsample: float = 0.8
    colsample_bytree: float = 1.0
    reg_lambda: float = 1.0
    gamma: float = 0.0
    min_child_weight: float = 1e-3
    max_bins: int = 64
    seed: int = 0


class _GBTBase:
    def __init__(self, config: Optional[GBTConfig] = None, engine: Optional[str] = None, **kw):
        self.config = config or GBTConfig(**kw)
        self.engine = engine  # tree-builder engine; None = tree.DEFAULT_ENGINE
        self.ensemble: Optional[PackedEnsemble] = None
        self._trees = []
        self.feature_importances_: Optional[np.ndarray] = None
        self.n_features_: int = 0

    # -- loss interface ----------------------------------------------------
    def _grad_hess(self, y: np.ndarray, pred: np.ndarray):
        raise NotImplementedError

    def _base_score(self, y: np.ndarray) -> float:
        raise NotImplementedError

    def fit(self, X: np.ndarray, y: np.ndarray):
        cfg = self.config
        X = np.asarray(X, np.float64)
        y = np.asarray(y, np.float64)
        n, d = X.shape
        self.n_features_ = d
        rng = np.random.default_rng(cfg.seed)
        edges = compute_bins(X, cfg.max_bins)
        binned = BinnedData.build(bin_features(X, edges), edges)

        base = self._base_score(y)
        pred = np.full(n, base, dtype=np.float64)
        tcfg = TreeBuilderConfig(
            max_depth=cfg.max_depth,
            min_child_weight=cfg.min_child_weight,
            reg_lambda=cfg.reg_lambda,
            gamma=cfg.gamma,
            max_bins=cfg.max_bins,
        )
        self._trees = []
        gain_imp = np.zeros(d)
        for _ in range(cfg.n_estimators):
            g, h = self._grad_hess(y, pred)
            if cfg.subsample < 1.0:
                mask = rng.random(n) < cfg.subsample
                if not mask.any():
                    mask[rng.integers(0, n)] = True
                gs = np.where(mask, g, 0.0)
                hs = np.where(mask, h, 0.0)
            else:
                gs, hs = g, h
            tree, leaf = build_tree_with_leaves(
                binned, edges, gs, hs, tcfg, rng, cfg.colsample_bytree, engine=self.engine
            )
            self._trees.append(tree)
            split = tree.feature >= 0
            np.add.at(gain_imp, tree.feature[split], tree.gain[split])
            # Scatter the builder's own leaf assignment instead of re-descending
            # every row (predict_tree_np): O(n) gather, and it trains on the
            # exact binned partition rather than the float32-rounded thresholds.
            pred += cfg.learning_rate * tree.value[leaf].astype(np.float64)

        tot = gain_imp.sum()
        self.feature_importances_ = gain_imp / tot if tot > 0 else gain_imp
        self.ensemble = pack_trees(
            self._trees, cfg.max_depth, base_score=base, scale=cfg.learning_rate
        )
        return self

    def _raw_predict(self, X: np.ndarray) -> np.ndarray:
        assert self.ensemble is not None, "fit() first"
        return np.asarray(predict_ensemble(self.ensemble, np.asarray(X, np.float32)))


class GBTRegressor(_GBTBase):
    """Squared-error objective: g = pred - y, h = 1."""

    def _grad_hess(self, y, pred):
        return pred - y, np.ones_like(y)

    def _base_score(self, y):
        return float(np.mean(y))

    def predict(self, X: np.ndarray) -> np.ndarray:
        return self._raw_predict(X)


class GBTBinaryClassifier(_GBTBase):
    """Logistic objective: g = sigmoid(pred) - y, h = p(1-p)."""

    def _grad_hess(self, y, pred):
        p = 1.0 / (1.0 + np.exp(-pred))
        return p - y, np.maximum(p * (1.0 - p), 1e-12)

    def _base_score(self, y):
        p = float(np.clip(np.mean(y), 1e-6, 1 - 1e-6))
        return float(np.log(p / (1 - p)))

    def predict_proba(self, X: np.ndarray) -> np.ndarray:
        return 1.0 / (1.0 + np.exp(-self._raw_predict(X)))

    def predict(self, X: np.ndarray) -> np.ndarray:
        return (self.predict_proba(X) >= 0.5).astype(np.int64)
