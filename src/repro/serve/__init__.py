"""repro.serve — batched serving engine (prefill + KV-cache decode)."""

from .engine import ServeEngine, Request  # noqa: F401
