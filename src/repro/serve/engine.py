"""Batched serving engine: fixed-slot continuous batching over the jit'd
decode step. Requests are prefilling into free slots; every decode step
advances all active slots one token; finished slots (EOS or max_tokens) are
recycled. Works on any model family exposing decode_step."""

from __future__ import annotations

import dataclasses
import queue
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import ModelConfig, get_api
from ..parallel.spec import init_params

__all__ = ["Request", "ServeEngine"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # [P] int32
    max_tokens: int = 16
    eos_id: int = -1  # -1: never
    tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    latency_s: float = 0.0


class ServeEngine:
    """Single-host engine; slots = decode batch size."""

    def __init__(self, cfg: ModelConfig, params, max_len: int = 512, slots: int = 4):
        assert cfg.family in ("dense", "moe", "vlm", "ssm", "hybrid"), cfg.family
        self.cfg = cfg
        self.api = get_api(cfg)
        self.params = params
        self.max_len = max_len
        self.slots = slots
        cache_specs = self.api.init_cache_specs(cfg, slots, max_len)
        self.cache = init_params(cache_specs, jax.random.PRNGKey(0))
        self._free = list(range(slots))
        self._active: Dict[int, Request] = {}
        self._slot_pos = np.zeros(slots, np.int64)
        self._slot_started = np.zeros(slots, np.float64)

        def step(params, cache, tokens, pos_vec):
            # per-slot positions differ; we use the max for cache_len masking
            # conservativeness and per-slot RoPE via the vectorized pos.
            logits, new_cache = self.api.decode_step(
                cfg, params, cache, tokens, pos_vec.max().astype(jnp.int32)
            )
            return logits, new_cache

        self._decode = jax.jit(step, donate_argnums=(1,))

    # ------------------------------------------------------------------
    def _prefill_slot(self, slot: int, req: Request):
        """Feed the prompt token-by-token through decode (cache fill)."""
        self._slot_started[slot] = time.perf_counter()
        for i, t in enumerate(req.prompt):
            tok = np.zeros((self.slots, 1), np.int32)
            tok[slot, 0] = t
            pos = jnp.asarray(self._slot_pos, jnp.int32)
            logits, self.cache = self._decode(self.params, self.cache, jnp.asarray(tok), pos)
            self._slot_pos[slot] += 1
        req.tokens = []
        self._active[slot] = req

    def submit(self, req: Request) -> bool:
        if not self._free:
            return False
        slot = self._free.pop()
        self._slot_pos[slot] = 0
        self._prefill_slot(slot, req)
        return True

    def step(self) -> List[Request]:
        """One decode step across all active slots; returns finished requests."""
        if not self._active:
            return []
        tok = np.zeros((self.slots, 1), np.int32)
        for slot, req in self._active.items():
            tok[slot, 0] = req.tokens[-1] if req.tokens else (
                req.prompt[-1] if len(req.prompt) else 0
            )
        pos = jnp.asarray(self._slot_pos, jnp.int32)
        logits, self.cache = self._decode(self.params, self.cache, jnp.asarray(tok), pos)
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        finished = []
        for slot in list(self._active):
            req = self._active[slot]
            t = int(nxt[slot])
            req.tokens.append(t)
            self._slot_pos[slot] += 1
            if t == req.eos_id or len(req.tokens) >= req.max_tokens or \
               self._slot_pos[slot] >= self.max_len:
                req.done = True
                req.latency_s = time.perf_counter() - self._slot_started[slot]
                finished.append(req)
                del self._active[slot]
                self._free.append(slot)
        return finished

    def run(self, requests: List[Request]) -> List[Request]:
        """Serve a list of requests to completion (simple scheduler)."""
        pending = list(requests)
        done: List[Request] = []
        while pending or self._active:
            while pending and self._free:
                self.submit(pending.pop(0))
            done.extend(self.step())
        return done
