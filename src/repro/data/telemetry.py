"""Pipeline telemetry (paper §3.1.2 measurements): samples/sec,
data_loading_ratio, throughput, and simulated accelerator utilization.

The trainer wraps each step in ``data_wait()`` / ``compute()`` blocks; the
telemetry window then exports exactly the paper's pipeline features, feeding
the OnlineAutotuner.
"""

from __future__ import annotations

import collections
import contextlib
import time
from typing import Deque, Optional

__all__ = ["StepTelemetry"]


class StepTelemetry:
    def __init__(self, window: int = 50):
        self.window = window
        self.data_times: Deque[float] = collections.deque(maxlen=window)
        self.compute_times: Deque[float] = collections.deque(maxlen=window)
        self.batch_sizes: Deque[int] = collections.deque(maxlen=window)
        self.batch_bytes: Deque[int] = collections.deque(maxlen=window)

    @contextlib.contextmanager
    def data_wait(self):
        # try/finally: a raising step body must still record its sample, or the
        # window's data/compute deques drift apart and every ratio is skewed
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.data_times.append(time.perf_counter() - t0)

    @contextlib.contextmanager
    def compute(self):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.compute_times.append(time.perf_counter() - t0)

    def record_batch(self, n_samples: int, n_bytes: int):
        self.batch_sizes.append(n_samples)
        self.batch_bytes.append(n_bytes)

    # ------------------------------------------------------------------
    @property
    def n_steps(self) -> int:
        return len(self.compute_times)

    def data_loading_ratio(self) -> float:
        d = sum(self.data_times)
        c = sum(self.compute_times)
        tot = d + c
        return d / tot if tot > 0 else 0.0

    def samples_per_second(self) -> float:
        tot = sum(self.data_times) + sum(self.compute_times)
        return sum(self.batch_sizes) / tot if tot > 0 else 0.0

    def throughput_mb_s(self) -> float:
        tot = sum(self.data_times) + sum(self.compute_times)
        return sum(self.batch_bytes) / 1e6 / tot if tot > 0 else 0.0

    def delivered_mb_s(self) -> float:
        """Bytes per second of *data-wait* time: the pipeline's own speed.

        With no data-wait recorded yet there is no measurement — return 0.0
        (a finite "unknown"), never ``inf``: these values land in exported
        features and JSONL rows, which must stay finite."""
        d = sum(self.data_times)
        return sum(self.batch_bytes) / 1e6 / d if d > 0 else 0.0

    def simulated_utilization(self) -> float:
        """Paper Fig 1: fraction of wall time the accelerator computes."""
        return 1.0 - self.data_loading_ratio()

    def features(self, batch_size: int, num_workers: int, block_kb: int = 0,
                 prefetch_policy=0, lookahead_batches: int = 0,
                 cache_budget_mb: float = 0.0) -> dict:
        """Export the paper's pipeline-benchmark features for the autotuner,
        plus the prefetch knobs (``prefetch_policy`` accepts a name or code
        and is exported as its numeric code — feature rows are numeric)."""
        from .prefetch import policy_code

        return {
            "batch_size": batch_size,
            "num_workers": num_workers,
            "block_kb": block_kb,
            "prefetch_policy": policy_code(prefetch_policy),
            "lookahead_batches": int(lookahead_batches),
            "cache_budget_mb": float(cache_budget_mb),
            "samples_per_second": self.samples_per_second(),
            "data_loading_ratio": self.data_loading_ratio(),
            "throughput_mb_s": self.throughput_mb_s(),
        }
