"""Columnar ETL micro-suite (paper §3.1.3: Spark filter/group-by/join,
CPU vs GPU) — TPU-native adaptation: the same three relational ops as jit'd
JAX programs, benchmarked against a numpy "CPU Spark" reference."""

from __future__ import annotations

import time
from functools import partial
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["etl_filter", "etl_group_aggregate", "etl_join", "bench_etl", "make_etl_table"]


@jax.jit
def etl_filter(values: jnp.ndarray, threshold: jnp.ndarray) -> jnp.ndarray:
    """SELECT * WHERE v > t — returns mask + compacted count (dense form)."""
    mask = values > threshold
    return jnp.where(mask, values, 0.0), mask.sum()


@partial(jax.jit, static_argnames=("n_groups",))
def etl_group_aggregate(keys: jnp.ndarray, values: jnp.ndarray, n_groups: int):
    """SELECT key, SUM(v), COUNT(*) GROUP BY key."""
    sums = jax.ops.segment_sum(values, keys, num_segments=n_groups)
    counts = jax.ops.segment_sum(jnp.ones_like(values), keys, num_segments=n_groups)
    return sums, counts


@jax.jit
def etl_join(left_keys: jnp.ndarray, left_vals: jnp.ndarray, right_keys: jnp.ndarray, right_vals: jnp.ndarray):
    """Sort-merge inner join on integer keys (right keys unique & sorted)."""
    order = jnp.argsort(right_keys)
    rk, rv = right_keys[order], right_vals[order]
    pos = jnp.searchsorted(rk, left_keys)
    pos = jnp.clip(pos, 0, rk.shape[0] - 1)
    matched = rk[pos] == left_keys
    return jnp.where(matched, left_vals + rv[pos], 0.0), matched.sum()


def make_etl_table(n_rows: int, n_groups: int = 64, seed: int = 0):
    rng = np.random.default_rng(seed)
    return {
        "keys": rng.integers(0, n_groups, size=n_rows).astype(np.int32),
        "values": rng.normal(size=n_rows).astype(np.float32),
    }


def _np_group_aggregate(keys, values, n_groups):
    return (
        np.bincount(keys, weights=values, minlength=n_groups),
        np.bincount(keys, minlength=n_groups).astype(np.float64),
    )


def bench_etl(n_rows: int = 100_000, n_groups: int = 64, seed: int = 0) -> Dict[str, dict]:
    """Return per-op timings for JAX (jit) vs numpy reference."""
    t = make_etl_table(n_rows, n_groups, seed)
    keys, values = jnp.asarray(t["keys"]), jnp.asarray(t["values"])
    rk = jnp.arange(n_groups, dtype=jnp.int32)
    rv = jnp.linspace(0, 1, n_groups, dtype=jnp.float32)
    out = {}

    def timeit(fn, *args, reps=5):
        fn(*args)  # compile/warm
        jax.block_until_ready(fn(*args))
        t0 = time.perf_counter()
        for _ in range(reps):
            r = fn(*args)
            jax.block_until_ready(r) if hasattr(r, "block_until_ready") or isinstance(r, tuple) else None
        return (time.perf_counter() - t0) / reps

    out["filter"] = {
        "jax_s": timeit(lambda: etl_filter(values, jnp.float32(0.0))),
        "np_s": timeit(lambda: (np.where(t["values"] > 0, t["values"], 0), (t["values"] > 0).sum())),
    }
    out["group_aggregate"] = {
        "jax_s": timeit(lambda: etl_group_aggregate(keys, values, n_groups)),
        "np_s": timeit(lambda: _np_group_aggregate(t["keys"], t["values"], n_groups)),
    }
    out["join"] = {
        "jax_s": timeit(lambda: etl_join(keys, values, rk, rv)),
        "np_s": timeit(
            lambda: (
                np.where(np.isin(t["keys"], np.arange(n_groups)), t["values"], 0),
                n_rows,
            )
        ),
    }
    for v in out.values():
        v["n_rows"] = n_rows
    return out
