"""Clairvoyant prefetching over the access-plan layer (PAPERS.md: *Clairvoyant
Prefetching for Distributed Machine Learning I/O*, Dryden et al.).

The pipeline's per-epoch access order is a pure function of ``(seed, epoch,
step)`` — ``DataPipeline.epoch_order`` — so the exact block-read sequence is
knowable ahead of the consumer.  ``ClairvoyantPrefetcher`` walks that known
schedule ``lookahead_batches`` ahead of the consumer, issues async block
reads through ``StorageBackend.read_block`` (so simulated backends charge
latency/bandwidth and the chaos harness's ``read:`` fault sites fire), and
parks the blocks in a bounded ``BlockCache``.

Eviction is schedule-aware LRU: a block whose **last scheduled use has
already been consumed** is dropped first; only then does plain
least-recently-used order apply.  Transient I/O errors in prefetch threads
are retried with backoff and never poison the cache — only complete,
successful reads are inserted; a block that ultimately cannot be prefetched
falls back to a synchronous read on the consumer path.

Policy knobs (plumbed through ``PipelineConfig`` → ``BenchCase`` →
``ConfigSpace`` → telemetry features):

- ``prefetch_policy`` ∈ {off, depth, clairvoyant}  (numeric codes 0/1/2 in
  feature rows and config grids)
- ``lookahead_batches`` — how many batches ahead of the consumer to schedule
- ``cache_budget_mb``   — block cache bound in MB
"""

from __future__ import annotations

import collections
import concurrent.futures as cf
import threading
import time
from typing import Dict, List, Optional, Tuple

from .formats import BlockRead, assemble_span

__all__ = ["PREFETCH_POLICIES", "policy_code", "policy_name",
           "BlockCache", "ClairvoyantPrefetcher"]

PREFETCH_POLICIES = ("off", "depth", "clairvoyant")


def policy_code(policy) -> int:
    """Numeric code of a prefetch policy (accepts a name or a code)."""
    if isinstance(policy, str):
        try:
            return PREFETCH_POLICIES.index(policy)
        except ValueError:
            raise ValueError(
                f"unknown prefetch_policy {policy!r}; valid: {PREFETCH_POLICIES}"
            ) from None
    code = int(policy)
    if not 0 <= code < len(PREFETCH_POLICIES):
        raise ValueError(
            f"unknown prefetch_policy code {policy!r}; valid: 0..{len(PREFETCH_POLICIES) - 1}"
        )
    return code


def policy_name(policy) -> str:
    """Canonical policy name (accepts a name or a numeric code)."""
    return PREFETCH_POLICIES[policy_code(policy)]


class _Entry:
    __slots__ = ("data", "last_use")

    def __init__(self, data: bytes, last_use: int):
        self.data = data
        self.last_use = last_use


class BlockCache:
    """Bounded block cache keyed by ``(file_index, block_offset)``.

    ``pos`` is the consumer's current step; an entry whose ``last_use``
    (last step scheduled to read it) is behind ``pos`` is expired and evicts
    before any still-useful block.  Not thread-safe — callers serialize
    access (``ClairvoyantPrefetcher`` holds one lock around all cache ops).
    """

    def __init__(self, budget_bytes: int):
        self.budget = max(int(budget_bytes), 1)
        self.pos = -1
        self.evicted = 0
        self.expired_evictions = 0
        self._entries: "collections.OrderedDict[Tuple[int, int], _Entry]" = \
            collections.OrderedDict()
        self._bytes = 0

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key) -> bool:
        return key in self._entries

    @property
    def nbytes(self) -> int:
        return self._bytes

    def get(self, key) -> Optional[bytes]:
        e = self._entries.get(key)
        if e is None:
            return None
        self._entries.move_to_end(key)
        return e.data

    def note_use(self, key, step: int):
        """Extend a cached block's scheduled lifetime to ``step``."""
        e = self._entries.get(key)
        if e is not None and step > e.last_use:
            e.last_use = step

    def put(self, key, data: bytes, last_use: int):
        old = self._entries.pop(key, None)
        if old is not None:
            self._bytes -= len(old.data)
        self._entries[key] = _Entry(data, last_use)
        self._bytes += len(data)
        self.evict_to_budget()

    def evict_to_budget(self):
        # keep at least one entry so a single over-budget block still serves
        while self._bytes > self.budget and len(self._entries) > 1:
            victim = None
            expired = False
            for k, e in self._entries.items():  # LRU-order scan
                if e.last_use < self.pos:
                    victim, expired = k, True
                    break
            if victim is None:
                victim = next(iter(self._entries))
            e = self._entries.pop(victim)
            self._bytes -= len(e.data)
            self.evicted += 1
            if expired:
                self.expired_evictions += 1


class ClairvoyantPrefetcher:
    """Walks a known batch schedule ahead of the consumer and keeps the
    blocks it will need in a bounded cache.

    ``schedule`` is duck-typed (``DataPipeline`` satisfies it): it provides
    ``batch_indices(epoch, step)`` and ``steps_per_epoch()``.  ``reader`` is
    a ``DatasetReader`` exposing the plan layer (``record_span`` /
    ``block_plan`` / ``fetch`` / ``decode_span``).
    """

    def __init__(
        self,
        reader,
        schedule,
        lookahead_batches: int = 8,
        cache_budget_mb: float = 64.0,
        workers: int = 2,
        max_retries: int = 2,
        retry_backoff_s: float = 0.002,
    ):
        self.reader = reader
        self.schedule = schedule
        self.lookahead_batches = max(0, int(lookahead_batches))
        self.max_retries = max(0, int(max_retries))
        self.retry_backoff_s = float(retry_backoff_s)
        self.block_bytes = int(reader.block_kb) * 1024
        self.cache = BlockCache(int(float(cache_budget_mb) * 1e6))
        self._pool = cf.ThreadPoolExecutor(
            max_workers=max(1, int(workers)), thread_name_prefix="prefetch"
        )
        self._lock = threading.Lock()
        self._inflight: Dict[Tuple[int, int], cf.Future] = {}
        self._last_use: Dict[Tuple[int, int], int] = {}
        self._epoch: Optional[int] = None
        self._steps = 0
        self._sched_hi = 0
        self._hits = 0
        self._misses = 0
        self._waits = 0
        self._retries = 0
        self._failed_fetches = 0
        self._prefetched_blocks = 0
        self._prefetched_bytes = 0

    # -- scheduling --------------------------------------------------------
    def advance(self, epoch: int, step: int):
        """Consumer is about to fetch batch ``step``: mark its position (for
        expiry) and schedule block reads up to ``step + lookahead_batches``."""
        with self._lock:
            if epoch != self._epoch:
                self._epoch = epoch
                self._steps = int(self.schedule.steps_per_epoch())
                self._sched_hi = step
                self._last_use.clear()
            self.cache.pos = step
            hi = min(self._steps, step + 1 + self.lookahead_batches)
            for s in range(max(self._sched_hi, step), hi):
                self._schedule_step(epoch, s)
            self._sched_hi = max(self._sched_hi, hi)

    def _schedule_step(self, epoch: int, s: int):
        # lock held; record every block's scheduled use, then submit fetches
        # for runs of blocks that are neither cached nor in flight
        for br in self.reader.block_plan(self.schedule.batch_indices(epoch, s)):
            run: List[Tuple[int, int]] = []  # (block_offset, block_end)
            end = br.offset + br.size
            boff = br.offset
            while boff < end:
                key = (br.file, boff)
                prev = self._last_use.get(key, -1)
                if s > prev:
                    self._last_use[key] = s
                if key in self.cache:
                    self.cache.note_use(key, s)
                    run = self._submit_run(br.file, run)
                elif key in self._inflight:
                    run = self._submit_run(br.file, run)
                else:
                    run.append((boff, min(boff + self.block_bytes, end)))
                boff += self.block_bytes
            self._submit_run(br.file, run)

    def _submit_run(self, fi: int, run: List[Tuple[int, int]]) -> List[Tuple[int, int]]:
        if run:
            start, span_end = run[0][0], run[-1][1]
            try:
                fut = self._pool.submit(self._fetch_span, fi, start, span_end - start)
            except RuntimeError:
                return []  # closed concurrently: consumer falls back to sync reads
            for boff, _ in run:
                self._inflight[(fi, boff)] = fut
        return []

    # -- prefetch worker ---------------------------------------------------
    def _fetch_span(self, fi: int, start: int, size: int):
        data = None
        for attempt in range(self.max_retries + 1):
            try:
                data = self.reader.fetch(BlockRead(fi, start, size))
                break
            except OSError:
                # transient I/O fault (incl. injected FaultInjected): retry
                # with backoff; never insert anything on failure
                with self._lock:
                    self._retries += 1
                if attempt == self.max_retries:
                    break
                time.sleep(self.retry_backoff_s * (attempt + 1))
        with self._lock:
            if data is None:
                self._failed_fetches += 1
                for boff in range(start, start + size, self.block_bytes):
                    self._inflight.pop((fi, boff), None)
                return
            for boff in range(start, start + size, self.block_bytes):
                key = (fi, boff)
                self._inflight.pop(key, None)
                blk = data[boff - start : boff - start + self.block_bytes]
                if blk:
                    self.cache.put(key, blk, self._last_use.get(key, self.cache.pos))
                    self._prefetched_blocks += 1
                    self._prefetched_bytes += len(blk)

    # -- consumer path -----------------------------------------------------
    def read_record(self, i: int) -> bytes:
        """Record ``i``'s payload, served from the block cache when possible
        (thread-safe: the pipeline's worker pool may call this concurrently)."""
        fi, off, size = self.reader.record_span(int(i))
        data = assemble_span(self._get_block, fi, off, size, self.block_bytes)
        return self.reader.decode_span(int(i), fi, off, data)

    def _get_block(self, fi: int, boff: int) -> bytes:
        key = (fi, boff)
        with self._lock:
            data = self.cache.get(key)
            fut = self._inflight.get(key)
        if data is not None:
            with self._lock:
                self._hits += 1
            return data
        if fut is not None:
            cf.wait([fut])
            with self._lock:
                data = self.cache.get(key)
                if data is not None:
                    self._hits += 1
                    self._waits += 1
            if data is not None:
                return data
        return self._sync_fetch(fi, boff)

    def _sync_fetch(self, fi: int, boff: int) -> bytes:
        """Miss path: read one aligned block directly, with the same bounded
        retry as the async path.  Persistent errors propagate to the caller."""
        size = min(boff + self.block_bytes, self.reader.file_size(fi)) - boff
        if size <= 0:
            return b""
        for attempt in range(self.max_retries + 1):
            try:
                data = self.reader.fetch(BlockRead(fi, boff, size))
                break
            except OSError:
                with self._lock:
                    self._retries += 1
                if attempt == self.max_retries:
                    raise
                time.sleep(self.retry_backoff_s * (attempt + 1))
        with self._lock:
            self._misses += 1
            self.cache.put((fi, boff), data, self._last_use.get((fi, boff), self.cache.pos))
        return data

    # -- knobs / stats / lifecycle ----------------------------------------
    def reconfigure(self, lookahead_batches: Optional[int] = None,
                    cache_budget_mb: Optional[float] = None):
        with self._lock:
            if lookahead_batches is not None:
                self.lookahead_batches = max(0, int(lookahead_batches))
            if cache_budget_mb is not None:
                self.cache.budget = max(1, int(float(cache_budget_mb) * 1e6))
                self.cache.evict_to_budget()

    def stats(self) -> dict:
        with self._lock:
            served = self._hits + self._misses
            return {
                "hits": self._hits,
                "misses": self._misses,
                "waits": self._waits,
                "hit_ratio": self._hits / served if served else 0.0,
                "retries": self._retries,
                "failed_fetches": self._failed_fetches,
                "prefetched_blocks": self._prefetched_blocks,
                "prefetched_mb": self._prefetched_bytes / 1e6,
                "cached_blocks": len(self.cache),
                "cached_mb": self.cache.nbytes / 1e6,
                "evicted": self.cache.evicted,
                "expired_evictions": self.cache.expired_evictions,
            }

    def close(self):
        self._pool.shutdown(wait=False, cancel_futures=True)
