"""Storage backends (paper §3.1.1: NVMe / network storage / tmpfs).

This container exposes two *real* tiers — tmpfs (/dev/shm) and local disk —
plus a calibrated simulator for network-attached storage (latency + shared
bandwidth cap), so the benchmark matrix covers the paper's three backends.
"""

from __future__ import annotations

import dataclasses
import os
import pathlib
import shutil
import threading
import time
from typing import BinaryIO, Optional

__all__ = [
    "StorageBackend",
    "get_backend",
    "BACKENDS",
    "drop_page_cache_hint",
    "set_fault_hook",
]

# Optional fault-injection hook (service.faults installs it): called as
# hook(f"read:{backend.name}", nbytes) before every read_block. Kept as a
# plain callable registry so this module never imports the service layer.
_FAULT_HOOK = None


def set_fault_hook(hook) -> None:
    """Install (or clear, with ``None``) the read-path fault hook."""
    global _FAULT_HOOK
    _FAULT_HOOK = hook


@dataclasses.dataclass
class StorageBackend:
    name: str
    root: pathlib.Path
    # Simulated constraints (None = native speed).
    latency_s: Optional[float] = None  # per-operation latency
    bandwidth_mb_s: Optional[float] = None  # shared link cap

    def __post_init__(self):
        self.root = pathlib.Path(self.root)
        self._lock = threading.Lock()
        self._link_free_at = 0.0

    def path(self, name: str) -> pathlib.Path:
        self.root.mkdir(parents=True, exist_ok=True)
        return self.root / name

    # -- throttled I/O (identity for native backends) ----------------------
    def charge(self, nbytes: int):
        """Apply simulated latency/bandwidth for an I/O of ``nbytes``."""
        if self.latency_s is None and self.bandwidth_mb_s is None:
            return
        delay = self.latency_s or 0.0
        if self.bandwidth_mb_s:
            xfer = nbytes / (self.bandwidth_mb_s * 1e6)
            with self._lock:  # shared-link contention across threads
                now = time.perf_counter()
                start = max(now, self._link_free_at)
                self._link_free_at = start + xfer
                delay += (start - now) + xfer
        if delay > 0:
            time.sleep(delay)

    def read_block(self, f: BinaryIO, offset: int, size: int) -> bytes:
        # os.pread is atomic w.r.t. the file offset -> safe under concurrent
        # worker threads sharing one handle (DataPipeline workers, §3.1.1
        # concurrent benchmarks).
        if _FAULT_HOOK is not None:
            _FAULT_HOOK(f"read:{self.name}", size)
        data = os.pread(f.fileno(), size, offset)
        self.charge(len(data))
        return data

    def cleanup(self):
        if self.root.exists():
            shutil.rmtree(self.root, ignore_errors=True)


def _default_roots():
    base = os.environ.get("REPRO_IO_DIR")
    disk = pathlib.Path(base) if base else pathlib.Path("/tmp/repro_io")
    shm = pathlib.Path("/dev/shm/repro_io")
    return disk, shm


def make_backends() -> dict:
    disk, shm = _default_roots()
    return {
        # tmpfs: in-memory filesystem (paper's fastest tier)
        "tmpfs": StorageBackend("tmpfs", shm),
        # local disk (stands in for the paper's NVMe tier)
        "disk": StorageBackend("disk", disk),
        # simulated network-attached storage: 1 ms op latency, 1.2 GB/s link
        "network_sim": StorageBackend(
            "network_sim", disk / "net", latency_s=1e-3, bandwidth_mb_s=1200.0
        ),
        # simulated object store: high latency, 400 MB/s
        "object_sim": StorageBackend(
            "object_sim", disk / "obj", latency_s=8e-3, bandwidth_mb_s=400.0
        ),
    }


BACKENDS = make_backends()


def get_backend(name: str) -> StorageBackend:
    return BACKENDS[name]


def drop_page_cache_hint(path: pathlib.Path):
    """Best-effort cold-cache: posix_fadvise(DONTNEED). No-op on failure."""
    try:
        fd = os.open(path, os.O_RDONLY)
        try:
            os.posix_fadvise(fd, 0, 0, os.POSIX_FADV_DONTNEED)
        finally:
            os.close(fd)
    except OSError:
        pass
