"""Record formats (paper §1.1's format axis: Parquet/ORC/TFRecord/WebDataset...).

We implement four self-contained formats with the same read API so the
benchmark/classifier can compare them:

- RAW       fixed-size records, no index (offset = i * record_size)
- PACKED    variable-size records + uint64 offset index (TFRecord-like)
- COMPRESSED zlib-per-record + index (compressed WebDataset-like)
- SHARDED   PACKED split across k shard files (webdataset/parquet-row-group-like)

All readers read via ``StorageBackend.read_block`` so simulated backends
charge latency/bandwidth, and support ``block_kb``-aligned reads (the paper's
block-size knob): a record fetch reads whole aligned blocks covering it.

Reading is split into an explicit **access plan** layer so schedulers (the
clairvoyant prefetcher in ``data/prefetch.py``) can separate offset math
from I/O from decode:

- ``record_span(i)`` — pure offset math: which file/byte-range holds record i
- ``block_plan(indices)`` — the ordered, coalesced aligned-block fetch list
  covering a set of records (adjacent records in one shard collapse to one
  read)
- ``fetch(BlockRead)`` — one ``StorageBackend.read_block`` call
- ``decode_span(i, ...)`` — header parse / decompress, no I/O

``read()`` / ``read_batch()`` are reimplemented on top of these and return
byte-identical results to the pre-plan implementation for all four formats.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
import struct
import zlib
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from .storage import StorageBackend

__all__ = ["FORMATS", "BlockRead", "assemble_span", "write_dataset",
           "open_dataset", "DatasetReader"]

MAGIC = b"RPR1"


def _index_path(base: pathlib.Path) -> pathlib.Path:
    return base.with_suffix(base.suffix + ".idx")


def write_dataset(
    backend: StorageBackend,
    name: str,
    records: Sequence[bytes],
    fmt: str = "packed",
    n_shards: int = 4,
) -> dict:
    """Write records in ``fmt``; returns a manifest dict."""
    if fmt == "raw":
        sizes = {len(r) for r in records}
        assert len(sizes) == 1, "raw format needs fixed-size records"
        rec_size = sizes.pop()
        p = backend.path(f"{name}.raw")
        with open(p, "wb") as f:
            for r in records:
                f.write(r)
        manifest = {
            "format": "raw",
            "files": [str(p)],
            "record_size": rec_size,
            "n_records": len(records),
        }
    elif fmt in ("packed", "compressed"):
        p = backend.path(f"{name}.{fmt}")
        with open(p, "wb") as f:
            f.write(MAGIC)
            pos = 4
            offs = []
            for r in records:
                payload = zlib.compress(r, 1) if fmt == "compressed" else r
                f.write(struct.pack("<I", len(payload)))
                f.write(payload)
                offs.append(pos)
                pos += 4 + len(payload)
        idx = np.asarray(offs, np.uint64)
        idx.tofile(_index_path(p))
        manifest = {
            "format": fmt,
            "files": [str(p)],
            "n_records": len(records),
        }
    elif fmt == "sharded":
        files = []
        per = (len(records) + n_shards - 1) // n_shards
        counts = []
        for s in range(n_shards):
            chunk = records[s * per : (s + 1) * per]
            if not chunk:
                break
            sub = write_dataset(backend, f"{name}.shard{s}", chunk, "packed")
            files.append(sub["files"][0])
            counts.append(len(chunk))
        manifest = {
            "format": "sharded",
            "files": files,
            "shard_counts": counts,
            "n_records": len(records),
        }
    else:
        raise ValueError(f"unknown format {fmt!r}")

    manifest["backend"] = backend.name
    mp = backend.path(f"{name}.manifest.json")
    mp.write_text(json.dumps(manifest))
    manifest["manifest_path"] = str(mp)
    return manifest


@dataclasses.dataclass(frozen=True)
class BlockRead:
    """One planned ``StorageBackend.read_block`` call: ``size`` bytes at
    block-aligned ``offset`` of file ``file`` (may span several aligned
    blocks when the plan coalesced adjacent records)."""

    file: int
    offset: int
    size: int


def assemble_span(
    get_block: Callable[[int, int], Optional[bytes]],
    fi: int,
    offset: int,
    size: int,
    block_bytes: int,
) -> bytes:
    """Stitch ``[offset, offset+size)`` of file ``fi`` from aligned blocks.

    ``get_block(fi, block_offset)`` returns the block's bytes (possibly short
    at EOF) or None/empty when unavailable — a missing or short block ends
    the span early, and the resulting truncation surfaces in decode, never
    here."""
    parts: List[bytes] = []
    start = (offset // block_bytes) * block_bytes
    boff = start
    while boff < offset + size:
        blk = get_block(fi, boff)
        if not blk:
            break
        parts.append(blk)
        if len(blk) < block_bytes:
            break
        boff += block_bytes
    data = b"".join(parts)
    return data[offset - start : offset - start + size]


@dataclasses.dataclass
class DatasetReader:
    backend: StorageBackend
    manifest: dict
    block_kb: int = 64

    def __post_init__(self):
        self._files = [open(p, "rb") for p in self.manifest["files"]]
        fmt = self.manifest["format"]
        # every indexed format (packed/compressed/sharded) loads one uint64
        # offset index per file; sharded additionally needs the record->shard
        # cumulative counts
        self._idx = (
            [np.fromfile(_index_path(pathlib.Path(p)), np.uint64)
             for p in self.manifest["files"]]
            if fmt != "raw" else None
        )
        if fmt == "sharded":
            self._cum = np.cumsum([0] + list(self.manifest["shard_counts"]))
        self._file_sizes = [pathlib.Path(p).stat().st_size for p in self.manifest["files"]]

    def __len__(self) -> int:
        return int(self.manifest["n_records"])

    @property
    def total_bytes(self) -> int:
        return int(sum(self._file_sizes))

    def file_size(self, fi: int) -> int:
        return int(self._file_sizes[fi])

    # -- plan layer: pure offset math, no I/O ------------------------------
    def record_span(self, i: int) -> Tuple[int, int, int]:
        """(file_index, byte_offset, byte_size) of record ``i``, header
        included for the indexed formats."""
        fmt = self.manifest["format"]
        i = int(i)
        if fmt == "raw":
            rs = int(self.manifest["record_size"])
            return 0, i * rs, rs
        if fmt == "sharded":
            fi = int(np.searchsorted(self._cum, i, side="right") - 1)
            local = i - int(self._cum[fi])
        else:  # packed / compressed
            fi, local = 0, i
        idx = self._idx[fi]
        off = int(idx[local])
        end = int(idx[local + 1]) if local + 1 < len(idx) else self._file_sizes[fi]
        return fi, off, end - off

    def block_plan(self, indices, block_kb: Optional[int] = None) -> List[BlockRead]:
        """Ordered, coalesced aligned-block fetch list covering ``indices``.

        Blocks appear in first-use order and exactly once; runs of adjacent
        blocks in one file (e.g. consecutive records in one shard) collapse
        into a single ``BlockRead``."""
        bs = int(block_kb or self.block_kb) * 1024
        plan: List[BlockRead] = []
        seen = set()
        for i in indices:
            fi, off, size = self.record_span(int(i))
            stop = min(((off + size + bs - 1) // bs) * bs, self._file_sizes[fi])
            boff = (off // bs) * bs
            while boff < stop:
                if (fi, boff) not in seen:
                    seen.add((fi, boff))
                    blk_end = min(boff + bs, self._file_sizes[fi])
                    if plan and plan[-1].file == fi and plan[-1].offset + plan[-1].size == boff:
                        plan[-1] = BlockRead(fi, plan[-1].offset, blk_end - plan[-1].offset)
                    else:
                        plan.append(BlockRead(fi, boff, blk_end - boff))
                boff += bs
        return plan

    # -- I/O ---------------------------------------------------------------
    def fetch(self, br: BlockRead) -> bytes:
        """Execute one planned block read through the storage backend."""
        return self.backend.read_block(self._files[br.file], br.offset, br.size)

    def _read_span(self, fi: int, offset: int, size: int) -> bytes:
        """Block-aligned read covering [offset, offset+size)."""
        bs = self.block_kb * 1024
        start = (offset // bs) * bs
        end = min(((offset + size + bs - 1) // bs) * bs, self._file_sizes[fi])
        data = self.backend.read_block(self._files[fi], start, end - start)
        return data[offset - start : offset - start + size]

    # -- decode: header parse / decompress, no I/O -------------------------
    def decode_span(self, i: int, fi: int, off: int, data: bytes) -> bytes:
        """Record ``i``'s payload from its span bytes ``data`` (which may be
        short when the underlying file is truncated)."""
        fmt = self.manifest["format"]
        if fmt == "raw":
            return data
        if len(data) < 4:
            raise IOError(
                f"truncated record header at offset {off} in "
                f"{self.manifest['files'][fi]} (got {len(data)}/4 bytes)"
            )
        (ln,) = struct.unpack("<I", data[:4])
        payload = data[4 : 4 + ln]
        if len(payload) < ln:
            raise IOError(
                f"truncated record payload at offset {off + 4} in "
                f"{self.manifest['files'][fi]} (got {len(payload)}/{ln} bytes)"
            )
        if fmt == "compressed":
            try:
                return zlib.decompress(payload)
            except zlib.error as exc:
                raise IOError(
                    f"corrupt compressed record {i} in "
                    f"{self.manifest['files'][fi]}: {exc}"
                ) from exc
        return payload

    # -- record API (plan -> fetch -> decode) ------------------------------
    def read(self, i: int) -> bytes:
        fi, off, size = self.record_span(int(i))
        return self.decode_span(int(i), fi, off, self._read_span(fi, off, size))

    def read_batch(self, indices) -> List[bytes]:
        idx = [int(i) for i in indices]
        bs = self.block_kb * 1024
        blocks = {}
        for br in self.block_plan(idx):
            data = self.fetch(br)
            for boff in range(br.offset, br.offset + len(data), bs):
                blocks[(br.file, boff)] = data[boff - br.offset : boff - br.offset + bs]
        out = []
        for i in idx:
            fi, off, size = self.record_span(i)
            span = assemble_span(lambda f, b: blocks.get((f, b)), fi, off, size, bs)
            out.append(self.decode_span(i, fi, off, span))
        return out

    def close(self):
        for f in self._files:
            f.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


FORMATS = ("raw", "packed", "compressed", "sharded")


def open_dataset(backend: StorageBackend, manifest: dict, block_kb: int = 64) -> DatasetReader:
    return DatasetReader(backend, manifest, block_kb)
