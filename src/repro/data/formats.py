"""Record formats (paper §1.1's format axis: Parquet/ORC/TFRecord/WebDataset...).

We implement four self-contained formats with the same read API so the
benchmark/classifier can compare them:

- RAW       fixed-size records, no index (offset = i * record_size)
- PACKED    variable-size records + uint64 offset index (TFRecord-like)
- COMPRESSED zlib-per-record + index (compressed WebDataset-like)
- SHARDED   PACKED split across k shard files (webdataset/parquet-row-group-like)

All readers read via ``StorageBackend.read_block`` so simulated backends
charge latency/bandwidth, and support ``block_kb``-aligned reads (the paper's
block-size knob): a record fetch reads whole aligned blocks covering it.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
import struct
import zlib
from typing import List, Sequence

import numpy as np

from .storage import StorageBackend

__all__ = ["FORMATS", "write_dataset", "open_dataset", "DatasetReader"]

MAGIC = b"RPR1"


def _index_path(base: pathlib.Path) -> pathlib.Path:
    return base.with_suffix(base.suffix + ".idx")


def write_dataset(
    backend: StorageBackend,
    name: str,
    records: Sequence[bytes],
    fmt: str = "packed",
    n_shards: int = 4,
) -> dict:
    """Write records in ``fmt``; returns a manifest dict."""
    if fmt == "raw":
        sizes = {len(r) for r in records}
        assert len(sizes) == 1, "raw format needs fixed-size records"
        rec_size = sizes.pop()
        p = backend.path(f"{name}.raw")
        with open(p, "wb") as f:
            for r in records:
                f.write(r)
        manifest = {
            "format": "raw",
            "files": [str(p)],
            "record_size": rec_size,
            "n_records": len(records),
        }
    elif fmt in ("packed", "compressed"):
        p = backend.path(f"{name}.{fmt}")
        offs = [0]
        with open(p, "wb") as f:
            f.write(MAGIC)
            pos = 4
            offs = []
            for r in records:
                payload = zlib.compress(r, 1) if fmt == "compressed" else r
                f.write(struct.pack("<I", len(payload)))
                f.write(payload)
                offs.append(pos)
                pos += 4 + len(payload)
        idx = np.asarray(offs, np.uint64)
        idx.tofile(_index_path(p))
        manifest = {
            "format": fmt,
            "files": [str(p)],
            "n_records": len(records),
        }
    elif fmt == "sharded":
        files = []
        per = (len(records) + n_shards - 1) // n_shards
        counts = []
        for s in range(n_shards):
            chunk = records[s * per : (s + 1) * per]
            if not chunk:
                break
            sub = write_dataset(backend, f"{name}.shard{s}", chunk, "packed")
            files.append(sub["files"][0])
            counts.append(len(chunk))
        manifest = {
            "format": "sharded",
            "files": files,
            "shard_counts": counts,
            "n_records": len(records),
        }
    else:
        raise ValueError(f"unknown format {fmt!r}")

    manifest["backend"] = backend.name
    mp = backend.path(f"{name}.manifest.json")
    mp.write_text(json.dumps(manifest))
    manifest["manifest_path"] = str(mp)
    return manifest


@dataclasses.dataclass
class DatasetReader:
    backend: StorageBackend
    manifest: dict
    block_kb: int = 64

    def __post_init__(self):
        self._files = [open(p, "rb") for p in self.manifest["files"]]
        fmt = self.manifest["format"]
        if fmt in ("packed", "compressed"):
            self._idx = [np.fromfile(_index_path(pathlib.Path(p)), np.uint64) for p in self.manifest["files"]]
        elif fmt == "sharded":
            self._idx = [np.fromfile(_index_path(pathlib.Path(p)), np.uint64) for p in self.manifest["files"]]
            self._cum = np.cumsum([0] + list(self.manifest["shard_counts"]))
        self._file_sizes = [pathlib.Path(p).stat().st_size for p in self.manifest["files"]]

    def __len__(self) -> int:
        return int(self.manifest["n_records"])

    @property
    def total_bytes(self) -> int:
        return int(sum(self._file_sizes))

    def _read_span(self, fi: int, offset: int, size: int) -> bytes:
        """Block-aligned read covering [offset, offset+size)."""
        bs = self.block_kb * 1024
        start = (offset // bs) * bs
        end = min(((offset + size + bs - 1) // bs) * bs, self._file_sizes[fi])
        data = self.backend.read_block(self._files[fi], start, end - start)
        return data[offset - start : offset - start + size]

    def read(self, i: int) -> bytes:
        fmt = self.manifest["format"]
        if fmt == "raw":
            rs = self.manifest["record_size"]
            return self._read_span(0, i * rs, rs)
        if fmt in ("packed", "compressed"):
            fi, local = 0, i
        else:  # sharded
            fi = int(np.searchsorted(self._cum, i, side="right") - 1)
            local = i - int(self._cum[fi])
            fmt = "packed"
        off = int(self._idx[fi][local])
        header = self._read_span(fi, off, 4)
        if len(header) < 4:
            raise IOError(
                f"truncated record header at offset {off} in "
                f"{self.manifest['files'][fi]} (got {len(header)}/4 bytes)"
            )
        (ln,) = struct.unpack("<I", header)
        payload = self._read_span(fi, off + 4, ln)
        if len(payload) < ln:
            raise IOError(
                f"truncated record payload at offset {off + 4} in "
                f"{self.manifest['files'][fi]} (got {len(payload)}/{ln} bytes)"
            )
        if self.manifest["format"] == "compressed":
            try:
                return zlib.decompress(payload)
            except zlib.error as exc:
                raise IOError(
                    f"corrupt compressed record {i} in "
                    f"{self.manifest['files'][fi]}: {exc}"
                ) from exc
        return payload

    def read_batch(self, indices) -> List[bytes]:
        return [self.read(int(i)) for i in indices]

    def close(self):
        for f in self._files:
            f.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


FORMATS = ("raw", "packed", "compressed", "sharded")


def open_dataset(backend: StorageBackend, manifest: dict, block_kb: int = 64) -> DatasetReader:
    return DatasetReader(backend, manifest, block_kb)
