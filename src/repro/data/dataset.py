"""Observation-dataset collection (paper §3.1, Fig 2).

Regenerates the paper's 141-observation core set deterministically on this
machine: 84 random-access I/O tests, 52 training-pipeline benchmarks, and
5 concurrent-I/O tests. Results are cached to JSON; ``n_repeats`` extends the
set toward the paper's 500-1000 future-work target.

Feature semantics (leakage-aware, matching the paper's design): rows mix
*configuration* knobs with *upstream measurements* (e.g. a file's sequential
throughput measured once per (backend, file, block)), while the target is the
*downstream* delivered throughput of the benchmark itself — "measurements at
different pipeline stages; the model learns the transformation between them"
(paper §4.3).
"""

from __future__ import annotations

import json
import pathlib
import time
from typing import Dict, List, Optional

import numpy as np

from ..core.features import FEATURE_NAMES, TARGET_NAME
from .bench_io import bench_concurrent_read, bench_random_read, bench_sequential_read, make_test_file
from .formats import open_dataset, write_dataset
from .pipeline import DataPipeline, PipelineConfig, TokenRecordCodec
from .storage import BACKENDS, StorageBackend
from .telemetry import StepTelemetry

__all__ = ["collect_observations", "observations_to_columns", "DEFAULT_CACHE"]

DEFAULT_CACHE = pathlib.Path("/tmp/repro_io/observations.json")

_RA_BACKENDS = ("tmpfs", "disk", "network_sim", "object_sim")
_RA_SIZES_MB = (4, 16, 64)
_RA_COMBOS = ((100, 4), (300, 4), (1000, 4), (100, 64), (300, 64), (1000, 64), (300, 16))
# latency-heavy simulated backends get proportionally fewer ops
_RA_SCALE = {"tmpfs": 1.0, "disk": 1.0, "network_sim": 0.5, "object_sim": 0.125}

_PL_FORMATS = ("raw", "packed", "compressed", "sharded")
_PL_BACKENDS = ("tmpfs", "disk")
_PL_BATCH = (16, 32, 64)
_PL_WORKERS = (0, 2)
_PL_EXTRA = [  # 4 extra rows -> 4*2*3*2 + 4 = 52 (paper Fig 2)
    ("raw", "tmpfs", 128, 4),
    ("packed", "tmpfs", 128, 4),
    ("compressed", "tmpfs", 128, 4),
    ("sharded", "tmpfs", 128, 4),
]

_CC_CASES = [("tmpfs", 1), ("tmpfs", 2), ("tmpfs", 4), ("tmpfs", 8), ("disk", 4)]


def _blank_row(bench_type: str) -> dict:
    row = {k: 0.0 for k in FEATURE_NAMES}
    row["bench_type"] = bench_type
    return row


def _simulated_compute(seconds: float):
    """Stand-in for the accelerator step (paper's 'simulated GPU')."""
    t0 = time.perf_counter()
    while time.perf_counter() - t0 < seconds:
        pass


def collect_random_access(seed: int = 0, fast: bool = False) -> List[dict]:
    rows = []
    sizes = (2, 4) if fast else _RA_SIZES_MB
    combos = _RA_COMBOS[:2] if fast else _RA_COMBOS
    backends = ("tmpfs", "disk") if fast else _RA_BACKENDS
    seq_cache: Dict[tuple, float] = {}
    for bname in backends:
        backend = BACKENDS[bname]
        for size_mb in sizes:
            path = make_test_file(backend, f"ra_{size_mb}mb.bin", size_mb, seed)
            for n_samples, sample_kb in combos:
                n = max(20, int(n_samples * _RA_SCALE.get(bname, 1.0)))
                key = (bname, size_mb, sample_kb)
                if key not in seq_cache:
                    seq = bench_sequential_read(backend, path, block_kb=max(sample_kb, 64))
                    seq_cache[key] = seq["throughput_mb_s"]
                r = bench_random_read(backend, path, n, sample_kb, seed=seed)
                row = _blank_row("io_random")
                row.update(
                    block_kb=sample_kb,
                    file_size_mb=r["file_size_mb"],
                    n_samples=n,
                    throughput_mb_s=seq_cache[key],  # upstream: sequential baseline
                    iops=r["iops"],
                    n_threads=1,
                )
                row[TARGET_NAME] = r["throughput_mb_s"]  # downstream: random-access
                row["backend"] = bname
                rows.append(row)
    return rows


def _run_pipeline_case(
    backend: StorageBackend,
    manifest: dict,
    fmt: str,
    batch: int,
    workers: int,
    seq_len: int,
    compute_s: float,
    probe_steps: int = 2,
    measure_steps: int = 6,
) -> dict:
    reader = open_dataset(backend, manifest, block_kb=64)
    pipe = DataPipeline.from_reader(
        reader, seq_len, PipelineConfig(batch_size=batch, num_workers=workers, seed=0)
    )
    tele = StepTelemetry()
    probe = StepTelemetry()
    steps = min(pipe.steps_per_epoch(), probe_steps + measure_steps)
    it = pipe.iter_epoch(0)
    for s in range(steps):
        t = probe if s < probe_steps else tele
        with t.data_wait():
            batch_arr = next(it)
        with t.compute():
            _simulated_compute(compute_s)
        t.record_batch(batch_arr.shape[0], batch_arr.nbytes)
    it.close()  # stops the producer thread before teardown
    pipe.close()
    reader.close()
    row = _blank_row("pipeline")
    row.update(
        batch_size=batch,
        num_workers=workers,
        block_kb=64,
        file_size_mb=reader.total_bytes / 1e6,
        samples_per_second=probe.samples_per_second(),  # upstream probe
        data_loading_ratio=probe.data_loading_ratio(),
        throughput_mb_s=probe.throughput_mb_s(),
    )
    # Target = overall delivered MB/s (samples/sec × record bytes), the
    # paper's pipeline-benchmark measurement; probe features come from the
    # separate warmup window above.
    row[TARGET_NAME] = tele.throughput_mb_s()
    row["backend"] = backend.name
    row["format"] = fmt
    row["utilization"] = tele.simulated_utilization()
    return row


def collect_pipeline(seed: int = 0, fast: bool = False) -> List[dict]:
    seq_len = 256
    codec = TokenRecordCodec(seq_len)
    rng = np.random.default_rng(seed)
    n_records = 256 if fast else 1024
    records = [
        codec.encode(rng.integers(0, 50_000, size=seq_len, dtype=np.int32))
        for _ in range(n_records)
    ]
    manifests: Dict[tuple, dict] = {}
    for bname in _PL_BACKENDS:
        for fmt in _PL_FORMATS:
            manifests[(bname, fmt)] = write_dataset(
                BACKENDS[bname], f"pl_{fmt}", records, fmt
            )
    cases = []
    batches = _PL_BATCH[:2] if fast else _PL_BATCH
    for fmt in _PL_FORMATS:
        for bname in _PL_BACKENDS if not fast else ("tmpfs",):
            for batch in batches:
                for workers in _PL_WORKERS:
                    cases.append((fmt, bname, batch, workers))
    if not fast:
        cases.extend(_PL_EXTRA)
    rows = []
    for fmt, bname, batch, workers in cases:
        rows.append(
            _run_pipeline_case(
                BACKENDS[bname],
                manifests[(bname, fmt)],
                fmt,
                batch,
                workers,
                seq_len,
                compute_s=0.002,
            )
        )
    return rows


def collect_concurrent(seed: int = 0, fast: bool = False) -> List[dict]:
    rows = []
    cases = _CC_CASES[:2] if fast else _CC_CASES
    for bname, n_threads in cases:
        backend = BACKENDS[bname]
        path = make_test_file(backend, "cc_32mb.bin", 8 if fast else 32, seed)
        r = bench_concurrent_read(backend, path, n_threads, per_thread_mb=2 if fast else 8)
        row = _blank_row("concurrent")
        row.update(
            block_kb=r["block_kb"],
            file_size_mb=r["file_size_mb"],
            n_threads=n_threads,
            throughput_mb_s=r["throughput_mb_s"],  # per-thread
            iops=r["iops"],
            aggregate_throughput_mb_s=r["aggregate_throughput_mb_s"],
        )
        row[TARGET_NAME] = r["aggregate_throughput_mb_s"]
        row["backend"] = bname
        rows.append(row)
    return rows


def collect_observations(
    cache: Optional[pathlib.Path] = DEFAULT_CACHE,
    force: bool = False,
    fast: bool = False,
    seed: int = 0,
    repeats: int = 1,
) -> List[dict]:
    """The 141-row core set (or a small ``fast`` subset for unit tests).

    ``repeats > 1`` re-runs the suite with different seeds (sample offsets,
    shuffles), growing the dataset toward the paper's 500-1000 future-work
    target (141 x repeats rows)."""
    expect = 141 * repeats
    if cache is not None and cache.exists() and not force:
        rows = json.loads(cache.read_text())
        if (fast and len(rows) >= 10) or (not fast and len(rows) >= expect):
            return rows[:expect] if not fast else rows
    rows = []
    for r in range(repeats):
        rows += (
            collect_random_access(seed + r, fast)
            + collect_pipeline(seed + r, fast)
            + collect_concurrent(seed + r, fast)
        )
    if cache is not None:
        cache.parent.mkdir(parents=True, exist_ok=True)
        cache.write_text(json.dumps(rows))
    return rows


def observations_to_columns(rows: List[dict]) -> dict:
    keys = list(FEATURE_NAMES) + [TARGET_NAME]
    return {k: np.asarray([float(r.get(k, 0.0)) for r in rows], np.float64) for k in keys}
