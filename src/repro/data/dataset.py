"""Observation-dataset collection (paper §3.1, Fig 2).

Regenerates the paper's 141-observation core set deterministically on this
machine: 84 random-access I/O tests, 52 training-pipeline benchmarks, and
5 concurrent-I/O tests.  The case matrix itself is declared in
``registry.py`` (campaigns ``paper_random_access`` / ``paper_pipeline`` /
``paper_concurrent``) and executed by ``campaign.py``; this module is the
thin, signature-stable wrapper the predictor and benchmarks consume.
Results are cached to JSON; ``repeats`` extends the set toward the paper's
500-1000 future-work target (see also the ``extended`` campaign and the
resumable JSONL runner in ``campaign.py`` for large collections).

Feature semantics (leakage-aware, matching the paper's design): rows mix
*configuration* knobs with *upstream measurements* (e.g. a file's sequential
throughput measured once per (backend, file, block)), while the target is the
*downstream* delivered throughput of the benchmark itself — "measurements at
different pipeline stages; the model learns the transformation between them"
(paper §4.3).
"""

from __future__ import annotations

import json
import pathlib
from typing import List, Optional

import numpy as np

from ..core.features import FEATURE_NAMES, TARGET_NAME  # noqa: F401 — re-export
from .campaign import RunContext, run_campaign
from .registry import get_campaign

__all__ = [
    "collect_observations",
    "collect_random_access",
    "collect_pipeline",
    "collect_concurrent",
    "observations_from_jsonl",
    "observations_to_columns",
    "DEFAULT_CACHE",
]

DEFAULT_CACHE = pathlib.Path("/tmp/repro_io/observations.json")


def _collect(campaign: str, seed: int, fast: bool,
             ctx: Optional[RunContext] = None) -> List[dict]:
    """Run one paper campaign in-memory and return its observation rows.

    Unlike the resumable JSONL runner, collection here is all-or-nothing:
    a failed case raises instead of yielding a silently truncated dataset."""
    result = run_campaign(campaign, out_path=None, fast=fast, seed=seed, ctx=ctx)
    if result.failures:
        ids = ", ".join(f"{cid}#r{rep}" for cid, rep in result.failures)
        first = result.errors[0] if result.errors else {}
        raise RuntimeError(
            f"campaign {campaign!r}: {len(result.failures)} case(s) failed: {ids}; "
            f"first error: {first.get('type', '?')}: {first.get('message', '?')}\n"
            f"{first.get('traceback', '')}"
            "(use repro.data.campaign.run_campaign for fault-tolerant collection)"
        )
    return result.rows


def collect_random_access(seed: int = 0, fast: bool = False) -> List[dict]:
    """The 84 random-access rows (campaign ``paper_random_access``)."""
    return _collect("paper_random_access", seed, fast)


def collect_pipeline(seed: int = 0, fast: bool = False) -> List[dict]:
    """The 52 training-pipeline rows (campaign ``paper_pipeline``)."""
    return _collect("paper_pipeline", seed, fast)


def collect_concurrent(seed: int = 0, fast: bool = False) -> List[dict]:
    """The 5 concurrent-I/O rows (campaign ``paper_concurrent``)."""
    return _collect("paper_concurrent", seed, fast)


def collect_observations(
    cache: Optional[pathlib.Path] = DEFAULT_CACHE,
    force: bool = False,
    fast: bool = False,
    seed: int = 0,
    repeats: int = 1,
) -> List[dict]:
    """The 141-row core set (or a small ``fast`` subset for unit tests).

    Thin wrapper over the ``paper_core`` campaign.  ``repeats > 1`` re-runs
    the suite with different seeds (sample offsets, shuffles), growing the
    dataset toward the paper's 500-1000 future-work target (141 x repeats
    rows)."""
    expect = len(get_campaign("paper_core").cases(fast=False)) * repeats
    if cache is not None and cache.exists() and not force:
        rows = json.loads(cache.read_text())
        if (fast and len(rows) >= 10) or (not fast and len(rows) >= expect):
            return rows[:expect] if not fast else rows
    rows: List[dict] = []
    for r in range(repeats):
        # fresh per-repeat context; test files and manifests carry the seed in
        # their names, so each repeat benchmarks seed-specific file content
        rows += _collect("paper_core", seed + r, fast, ctx=RunContext())
    if cache is not None:
        cache.parent.mkdir(parents=True, exist_ok=True)
        cache.write_text(json.dumps(rows))
    return rows


def observations_from_jsonl(paths) -> List[dict]:
    """Deduplicated observation rows from campaign JSONL result files — the
    offline consumer of a loop/campaign-grown dataset (feed the result to
    ``observations_to_columns`` and the full-featured
    ``IOPerformancePredictor``, e.g. on ``merged.jsonl`` from the continuous
    loop).

    Loads every record from the given shard/merged files (in collection
    order), dedups by ``(case_id, rep, seed)`` keeping the latest, and
    returns the successful observation rows in stable first-seen order."""
    from .campaign import load_records, merge_records, rows_from_records

    records: List[dict] = []
    for p in paths:
        records.extend(load_records(pathlib.Path(p)))
    return rows_from_records(merge_records(records))


def observations_to_columns(rows: List[dict]) -> dict:
    keys = list(FEATURE_NAMES) + [TARGET_NAME]
    return {k: np.asarray([float(r.get(k, 0.0)) for r in rows], np.float64) for k in keys}
