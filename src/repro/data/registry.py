"""Declarative benchmark-case registry (paper §3.1, Fig 2).

The paper's 141-observation core set — 84 random-access I/O tests, 52
training-pipeline benchmarks, 5 concurrent-I/O tests — used to live as
hardcoded module-level tuples in ``dataset.py``.  This module replaces them
with a declarative catalogue: every benchmark the repo can run is a frozen
:class:`BenchCase` with a stable string id, and a :class:`Campaign` is a named,
registered generator of cases.

Three *paper* campaigns reproduce the exact 84/52/5 split; the ``extended``
campaign sweeps a deeper grid (all four formats x all four backends, wider
worker/prefetch/batch axes) toward the paper's 500-1000-observation
future-work target.  ``campaign.py`` executes cases resumably and shardably;
this module is pure data — no I/O happens here.

Registering a new campaign::

    @register_campaign("my_sweep", "one-line description")
    def _my_sweep(fast: bool = False):
        return matrix_cases(
            "pipeline", id_prefix="my",
            backend=["tmpfs"], format=["packed", "sharded"],
            batch_size=[32, 64], num_workers=[0, 4],
        )
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Dict, Iterable, List, Sequence, Tuple

__all__ = [
    "BenchCase",
    "Campaign",
    "CAMPAIGNS",
    "register_campaign",
    "get_campaign",
    "list_campaigns",
    "matrix_cases",
    "BENCH_TYPES",
    "RA_LATENCY_SCALE",
]

BENCH_TYPES = ("io_random", "pipeline", "concurrent")

# Latency-heavy simulated backends get proportionally fewer random-access ops
# so one campaign run stays tractable (same wall-clock budget per backend).
RA_LATENCY_SCALE = {"tmpfs": 1.0, "disk": 1.0, "network_sim": 0.5, "object_sim": 0.125}


@dataclasses.dataclass(frozen=True)
class BenchCase:
    """One executable benchmark configuration.

    ``id`` is the resume/shard key: it must be unique within a campaign and
    stable across processes.  Fields past ``tags`` are bench-type specific —
    e.g. ``n_samples`` only matters for ``io_random``, ``n_threads`` /
    ``per_thread_mb`` for ``concurrent``, and the pipeline knobs
    (``batch_size``, ``num_workers``, ``prefetch_depth``, ``format``,
    ``n_records``, ``seq_len``, ``compute_s``) for ``pipeline``.
    """

    id: str
    bench_type: str                       # one of BENCH_TYPES
    backend: str = "tmpfs"                # key into storage.BACKENDS
    format: str = ""                      # record format ("" = not applicable)
    batch_size: int = 0
    num_workers: int = 0
    block_kb: int = 64
    file_size_mb: float = 0.0
    repeats: int = 1                      # independent reruns (seed offset)
    tags: Tuple[str, ...] = ()
    # -- bench-type-specific extras ------------------------------------
    n_samples: int = 0                    # io_random: number of random reads
    n_threads: int = 1                    # concurrent: reader thread count
    per_thread_mb: float = 8.0            # concurrent: bytes read per thread
    prefetch_depth: int = 2               # pipeline: prefetch queue depth
    compute_s: float = 0.002              # pipeline: simulated step compute
    n_records: int = 1024                 # pipeline: dataset size (records)
    seq_len: int = 256                    # pipeline: tokens per record
    # pipeline: prefetch-policy knobs + access pattern (data/prefetch.py)
    prefetch_policy: str = "depth"        # off | depth | clairvoyant
    lookahead_batches: int = 8            # clairvoyant: batches scheduled ahead
    cache_budget_mb: float = 64.0         # clairvoyant: block cache bound
    access: str = "shuffle"               # shuffle | seq | zipf epoch order
    n_hosts: int = 1                      # sharded epochs: this host's slice of H

    def __post_init__(self):
        if self.bench_type not in BENCH_TYPES:
            raise ValueError(f"unknown bench_type {self.bench_type!r}")
        if not self.id:
            raise ValueError("BenchCase.id must be non-empty")
        if self.repeats < 1:
            raise ValueError("BenchCase.repeats must be >= 1")


@dataclasses.dataclass(frozen=True)
class Campaign:
    """A named, registered generator of :class:`BenchCase` lists.

    ``builder(fast)`` returns the expanded case list; ``fast=True`` yields a
    small CI-sized subset with the same row schema."""

    name: str
    description: str
    builder: Callable[[bool], Tuple[BenchCase, ...]]

    def cases(self, fast: bool = False) -> Tuple[BenchCase, ...]:
        cases = tuple(self.builder(fast))
        seen: Dict[str, BenchCase] = {}
        for c in cases:
            if c.id in seen:
                raise ValueError(f"duplicate case id {c.id!r} in campaign {self.name!r}")
            seen[c.id] = c
        return cases


CAMPAIGNS: Dict[str, Campaign] = {}


def register_campaign(name: str, description: str):
    """Decorator: register ``fn(fast) -> cases`` as campaign ``name``."""

    def deco(fn: Callable[[bool], Iterable[BenchCase]]):
        if name in CAMPAIGNS:
            raise ValueError(f"campaign {name!r} already registered")
        CAMPAIGNS[name] = Campaign(name, description, lambda fast=False: tuple(fn(fast)))
        return fn

    return deco


def get_campaign(name: str) -> Campaign:
    try:
        return CAMPAIGNS[name]
    except KeyError:
        raise KeyError(
            f"unknown campaign {name!r}; available: {', '.join(sorted(CAMPAIGNS))}"
        ) from None


def list_campaigns() -> List[Campaign]:
    return [CAMPAIGNS[k] for k in sorted(CAMPAIGNS)]


def matrix_cases(bench_type: str, id_prefix: str, tags: Sequence[str] = (), **axes) -> List[BenchCase]:
    """Cartesian-product expansion helper.

    Each keyword is a BenchCase field name mapped to a list of values; the
    product is expanded in keyword order and ids are generated as
    ``{id_prefix}-{v1}-{v2}-...``."""
    names = list(axes)
    out = []
    for combo in itertools.product(*(axes[n] for n in names)):
        kw = dict(zip(names, combo))
        cid = "-".join([id_prefix] + [_fmt_id_part(n, v) for n, v in kw.items()])
        out.append(BenchCase(id=cid, bench_type=bench_type, tags=tuple(tags), **kw))
    return out


def _fmt_id_part(name: str, value) -> str:
    abbrev = {
        "backend": "", "format": "", "batch_size": "b", "num_workers": "w",
        "block_kb": "k", "file_size_mb": "mb", "n_samples": "n",
        "n_threads": "t", "prefetch_depth": "pf",
        "prefetch_policy": "", "lookahead_batches": "la",
        "cache_budget_mb": "cb", "access": "", "n_hosts": "h",
    }
    prefix = abbrev.get(name, name[:2])
    if isinstance(value, float) and value == int(value):
        value = int(value)
    return f"{prefix}{value}"


# ---------------------------------------------------------------------------
# Paper campaigns (Fig 2): 84 random-access + 52 pipeline + 5 concurrent.
# ---------------------------------------------------------------------------

_RA_BACKENDS = ("tmpfs", "disk", "network_sim", "object_sim")
_RA_SIZES_MB = (4, 16, 64)
_RA_COMBOS = ((100, 4), (300, 4), (1000, 4), (100, 64), (300, 64), (1000, 64), (300, 16))

_PL_FORMATS = ("raw", "packed", "compressed", "sharded")
_PL_BACKENDS = ("tmpfs", "disk")
_PL_BATCH = (16, 32, 64)
_PL_WORKERS = (0, 2)
# 4 extra rows -> 4*2*3*2 + 4 = 52 (paper Fig 2)
_PL_EXTRA = (
    ("raw", "tmpfs", 128, 4),
    ("packed", "tmpfs", 128, 4),
    ("compressed", "tmpfs", 128, 4),
    ("sharded", "tmpfs", 128, 4),
)

_CC_CASES = (("tmpfs", 1), ("tmpfs", 2), ("tmpfs", 4), ("tmpfs", 8), ("disk", 4))


def _ra_case(backend: str, size_mb: float, n_nominal: int, sample_kb: int,
             tags: Tuple[str, ...]) -> BenchCase:
    n = max(20, int(n_nominal * RA_LATENCY_SCALE.get(backend, 1.0)))
    return BenchCase(
        id=f"ra-{backend}-{_fmt_id_part('file_size_mb', size_mb)}-n{n}-k{sample_kb}",
        bench_type="io_random", backend=backend, block_kb=sample_kb,
        file_size_mb=size_mb, n_samples=n, tags=tags,
    )


def _pl_case(fmt: str, backend: str, batch: int, workers: int,
             tags: Tuple[str, ...], prefetch: int = 2, n_records: int = 1024) -> BenchCase:
    # ids encode every non-default knob so a fast-mode case (smaller dataset)
    # can never alias a full-mode case in a shared resume file
    cid = f"pl-{fmt}-{backend}-b{batch}-w{workers}"
    if prefetch != 2:
        cid += f"-pf{prefetch}"
    if n_records != 1024:
        cid += f"-r{n_records}"
    return BenchCase(
        id=cid, bench_type="pipeline", backend=backend, format=fmt,
        batch_size=batch, num_workers=workers, block_kb=64,
        prefetch_depth=prefetch, n_records=n_records, tags=tags,
    )


def _cc_case(backend: str, n_threads: int, tags: Tuple[str, ...],
             file_size_mb: float = 32, per_thread_mb: float = 8) -> BenchCase:
    cid = f"cc-{backend}-t{n_threads}"
    if (file_size_mb, per_thread_mb) != (32, 8):
        cid += f"-mb{int(file_size_mb)}x{int(per_thread_mb)}"
    return BenchCase(
        id=cid,
        bench_type="concurrent", backend=backend, block_kb=256,
        file_size_mb=file_size_mb, n_threads=n_threads,
        per_thread_mb=per_thread_mb, tags=tags,
    )


@register_campaign("paper_random_access", "84 random-access I/O tests (paper Fig 2)")
def paper_random_access(fast: bool = False) -> List[BenchCase]:
    backends = ("tmpfs", "disk") if fast else _RA_BACKENDS
    sizes = (2, 4) if fast else _RA_SIZES_MB
    combos = _RA_COMBOS[:2] if fast else _RA_COMBOS
    tags = ("paper", "random-access")
    return [
        _ra_case(b, s, n, kb, tags)
        for b in backends for s in sizes for n, kb in combos
    ]


@register_campaign("paper_pipeline", "52 training-pipeline benchmarks (paper Fig 2)")
def paper_pipeline(fast: bool = False) -> List[BenchCase]:
    tags = ("paper", "pipeline")
    n_records = 256 if fast else 1024
    batches = _PL_BATCH[:2] if fast else _PL_BATCH
    backends = ("tmpfs",) if fast else _PL_BACKENDS
    cases = [
        _pl_case(fmt, b, batch, w, tags, n_records=n_records)
        for fmt in _PL_FORMATS for b in backends
        for batch in batches for w in _PL_WORKERS
    ]
    if not fast:
        cases += [_pl_case(fmt, b, batch, w, tags) for fmt, b, batch, w in _PL_EXTRA]
    return cases


@register_campaign("paper_concurrent", "5 concurrent-I/O tests (paper Fig 2)")
def paper_concurrent(fast: bool = False) -> List[BenchCase]:
    tags = ("paper", "concurrent")
    cases = _CC_CASES[:2] if fast else _CC_CASES
    kw = dict(file_size_mb=8, per_thread_mb=2) if fast else {}
    return [_cc_case(b, t, tags, **kw) for b, t in cases]


@register_campaign("paper_core", "the paper's full 141-observation core set")
def paper_core(fast: bool = False) -> List[BenchCase]:
    return (
        list(paper_random_access(fast))
        + list(paper_pipeline(fast))
        + list(paper_concurrent(fast))
    )


@register_campaign(
    "extended",
    "deep sweep toward the paper's 500-1000-observation future-work target",
)
def extended(fast: bool = False) -> List[BenchCase]:
    """All four backends x all four formats, wider batch/worker/prefetch grids.

    Full expansion is ~724 cases (128 random-access + 576 pipeline + 20
    concurrent), inside the paper's 500-1000 target band.  ``fast`` shrinks
    every axis for smoke tests."""
    tags = ("extended",)
    if fast:
        ra = [_ra_case(b, 2, 50, kb, tags) for b in ("tmpfs", "disk") for kb in (4, 64)]
        pl = [
            _pl_case(fmt, "tmpfs", 16, w, tags, n_records=128)
            for fmt in ("raw", "packed") for w in (0, 2)
        ]
        cc = [_cc_case("tmpfs", t, tags, file_size_mb=8, per_thread_mb=2) for t in (1, 2)]
        return ra + pl + cc
    ra = [
        _ra_case(b, s, n, kb, tags)
        for b in _RA_BACKENDS
        for s in (4, 16, 64, 256)
        for n, kb in ((100, 4), (300, 4), (1000, 4), (100, 64), (300, 64),
                      (1000, 64), (300, 16), (1000, 16))
    ]
    pl = [
        _pl_case(fmt, b, batch, w, tags, prefetch=pf)
        for fmt in _PL_FORMATS
        for b in _RA_BACKENDS
        for batch in (16, 32, 64, 128)
        for w in (0, 2, 4)
        for pf in (1, 2, 4)
    ]
    cc = [
        _cc_case(b, t, tags)
        for b in _RA_BACKENDS
        for t in (1, 2, 4, 8, 16)
    ]
    return ra + pl + cc


@register_campaign(
    "fleet_probe",
    "simulated-network I/O probe sized for fleet scaling runs",
)
def fleet_probe(fast: bool = False) -> List[BenchCase]:
    """Random-access cases on the *simulated* network/object backends only.

    Per-case wall time here is dominated by the simulators' calibrated
    latency/bandwidth waits rather than CPU, mirroring the fleet's real
    target (network/object storage, where collection time is I/O wait) — so
    rows-per-wallclock scales with collector count even on small CI boxes.
    The ``fleet`` bench group runs this campaign at 1/2/4 collectors and
    commits the scaling curve to ``BENCH_fleet.json``."""
    tags = ("fleet-probe",)
    if fast:
        combos = [("network_sim", 300, 4), ("object_sim", 120, 4)]
    else:
        # object_sim first, network_sim second: positional sharding then
        # deals every collector one slow and one fast case alike
        combos = [
            ("object_sim", 200, 4), ("object_sim", 200, 16),
            ("object_sim", 150, 64), ("object_sim", 100, 256),
            ("network_sim", 400, 4), ("network_sim", 400, 16),
            ("network_sim", 300, 64), ("network_sim", 300, 256),
        ]
    return [
        BenchCase(id=f"fp-{b}-n{n}-k{kb}", bench_type="io_random", backend=b,
                  block_kb=kb, file_size_mb=4, n_samples=n, tags=tags)
        for b, n, kb in combos
    ]


_PF_POLICIES = ("off", "depth", "clairvoyant")


def _pf_case(backend: str, fmt: str, access: str, policy: str, workers: int,
             tags: Tuple[str, ...], n_records: int = 1024,
             n_hosts: int = 1) -> BenchCase:
    cid = f"pfc-{fmt}-{backend}-{access}-{policy}-w{workers}"
    if n_hosts != 1:
        cid += f"-h{n_hosts}"
    if n_records != 1024:
        cid += f"-r{n_records}"
    return BenchCase(
        id=cid, bench_type="pipeline", backend=backend, format=fmt,
        batch_size=32, num_workers=workers, block_kb=16,
        n_records=n_records, prefetch_policy=policy, lookahead_batches=8,
        cache_budget_mb=4.0, access=access, n_hosts=n_hosts, tags=tags,
    )


@register_campaign(
    "prefetch",
    "prefetch-policy family: off/depth/clairvoyant across distributed "
    "shuffle patterns on the simulated network/object backends",
)
def prefetch(fast: bool = False) -> List[BenchCase]:
    """Pipeline cases where stalls actually bite (simulated network/object
    latency), sweeping ``prefetch_policy`` against the distributed shuffle
    patterns the clairvoyant prefetcher exploits: seeded permutations
    (``shuffle``), zipfian hot sets (``zipf``), and sharded epochs
    (``n_hosts=2`` — one host's slice of a 2-host run)."""
    tags = ("prefetch",)
    if fast:
        cases = [
            _pf_case("network_sim", "packed", a, p, 0, tags, n_records=192)
            for a in ("shuffle", "zipf") for p in _PF_POLICIES
        ]
        cases.append(_pf_case("network_sim", "sharded", "shuffle", "clairvoyant",
                              0, tags, n_records=192, n_hosts=2))
        return cases
    cases = [
        _pf_case(b, fmt, a, p, w, tags)
        for b in ("network_sim", "object_sim")
        for fmt in ("packed", "sharded")
        for a in ("shuffle", "zipf")
        for p in _PF_POLICIES
        for w in (1, 4)
    ]
    cases += [
        _pf_case("network_sim", "sharded", "shuffle", p, 1, tags, n_hosts=2)
        for p in _PF_POLICIES
    ]
    return cases
