"""The tunable training input pipeline (paper §3.1.2 made production-grade).

Knobs = the paper's features: batch_size, num_workers, prefetch_depth,
block_kb, format, backend. Properties needed at pod scale:

- **per-host sharding**: host h of H reads global indices h::H — each pod
  host feeds only its data-parallel slice.
- **restart-exact**: the sample order is a pure function of (seed, epoch,
  step); resuming from a checkpointed step reproduces the same batches.
- **live reconfiguration**: ``reconfigure()`` swaps worker pool / prefetch /
  block size between steps without losing position (the autotuner's actuator);
  unknown knob names raise ``ValueError`` so actuator typos surface.
- **prefetch policies** (``prefetch_policy`` knob): ``off`` fetches batches
  synchronously; ``depth`` keeps ``prefetch_depth`` batches ready via a
  background producer thread; ``clairvoyant`` additionally walks the known
  epoch schedule ``lookahead_batches`` ahead and stages the underlying
  storage blocks in a bounded cache (``data/prefetch.py``).  All three
  policies yield byte-identical batch streams.
- **access patterns**: ``access`` selects the epoch order — seeded
  permutations (``shuffle``), sequential (``seq``), or a zipfian hot set
  (``zipf``) — all pure functions of (seed, epoch), so every pattern stays
  restart-exact.
"""

from __future__ import annotations

import concurrent.futures as cf
import dataclasses
import queue
import threading
from typing import Callable, Iterator, Optional

import numpy as np

from .formats import DatasetReader
from .prefetch import ClairvoyantPrefetcher, policy_name

__all__ = ["PipelineConfig", "TokenRecordCodec", "ImageRecordCodec",
           "TabularRecordCodec", "DataPipeline", "SyntheticTokenSource",
           "ACCESS_PATTERNS"]

ACCESS_PATTERNS = ("shuffle", "seq", "zipf")


class _ProducerError:
    """Wraps an exception raised in the prefetch thread for re-raise."""

    def __init__(self, exc: BaseException):
        self.exc = exc


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    batch_size: int = 32
    num_workers: int = 0  # 0 = synchronous in-thread fetch
    prefetch_depth: int = 2
    block_kb: int = 64
    shuffle: bool = True
    drop_last: bool = True
    seed: int = 0
    # prefetch-policy knobs (data/prefetch.py); "" access = derive from shuffle
    prefetch_policy: str = "depth"
    lookahead_batches: int = 8
    cache_budget_mb: float = 64.0
    access: str = ""

    @classmethod
    def knob_names(cls) -> tuple:
        return tuple(f.name for f in dataclasses.fields(cls))

    def replace(self, **kw) -> "PipelineConfig":
        return dataclasses.replace(self, **kw)


class TokenRecordCodec:
    """Fixed-length int32 token records <-> bytes."""

    def __init__(self, seq_len: int):
        self.seq_len = seq_len

    @property
    def nbytes(self) -> int:
        return 4 * self.seq_len

    def encode(self, tokens: np.ndarray) -> bytes:
        assert tokens.shape == (self.seq_len,)
        return np.asarray(tokens, np.int32).tobytes()

    def decode(self, data: bytes) -> np.ndarray:
        return np.frombuffer(data, np.int32, count=self.seq_len)


class ImageRecordCodec:
    """CIFAR-style fixed-size image records (paper §3.1.2: 32x32 RGB uint8)."""

    def __init__(self, h: int = 32, w: int = 32, c: int = 3):
        self.shape = (h, w, c)

    @property
    def nbytes(self) -> int:
        h, w, c = self.shape
        return h * w * c

    def encode(self, img: np.ndarray) -> bytes:
        assert img.shape == self.shape
        return np.asarray(img, np.uint8).tobytes()

    def decode(self, data: bytes) -> np.ndarray:
        return np.frombuffer(data, np.uint8, count=self.nbytes).reshape(self.shape)


class TabularRecordCodec:
    """Fixed-width float32 feature rows (paper §3.1.2 tabular workloads)."""

    def __init__(self, n_features: int):
        self.n_features = n_features

    @property
    def nbytes(self) -> int:
        return 4 * self.n_features

    def encode(self, row: np.ndarray) -> bytes:
        assert row.shape == (self.n_features,)
        return np.asarray(row, np.float32).tobytes()

    def decode(self, data: bytes) -> np.ndarray:
        return np.frombuffer(data, np.float32, count=self.n_features)


class SyntheticTokenSource:
    """I/O-free source: deterministic tokens(i). Used by smoke tests and the
    dry-run path where no real storage is wanted."""

    def __init__(self, n_records: int, seq_len: int, vocab: int, seed: int = 0):
        self.n_records, self.seq_len, self.vocab, self.seed = n_records, seq_len, vocab, seed

    def __len__(self):
        return self.n_records

    def read(self, i: int) -> np.ndarray:
        rng = np.random.default_rng(self.seed * 1_000_003 + i)
        return rng.integers(0, self.vocab, size=self.seq_len, dtype=np.int32)

    def record_nbytes(self) -> int:
        return 4 * self.seq_len


class _ReaderSource:
    """Adapter: DatasetReader + codec -> sample source."""

    def __init__(self, reader: DatasetReader, codec: TokenRecordCodec):
        self.reader, self.codec = reader, codec

    def __len__(self):
        return len(self.reader)

    def read(self, i: int) -> np.ndarray:
        return self.codec.decode(self.reader.read(i))

    def record_nbytes(self) -> int:
        return self.codec.nbytes


class DataPipeline:
    def __init__(
        self,
        source,
        config: PipelineConfig,
        host_id: int = 0,
        n_hosts: int = 1,
        collate: Optional[Callable] = None,
    ):
        self.source = source
        self.config = config
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.collate = collate or (lambda recs: np.stack(recs))
        self._pool: Optional[cf.ThreadPoolExecutor] = None
        self._prefetcher: Optional[ClairvoyantPrefetcher] = None
        policy_name(config.prefetch_policy)  # validate early
        self._rebuild_pool()

    @classmethod
    def from_reader(cls, reader, seq_len: int, config: PipelineConfig, **kw):
        # push block_kb into the reader (the knob acts at the format layer)
        reader.block_kb = config.block_kb
        return cls(_ReaderSource(reader, TokenRecordCodec(seq_len)), config, **kw)

    # -- deterministic order ------------------------------------------------
    def epoch_order(self, epoch: int) -> np.ndarray:
        n = len(self.source)
        mode = self.config.access or ("shuffle" if self.config.shuffle else "seq")
        if mode == "shuffle":
            rng = np.random.default_rng((self.config.seed, epoch))
            order = rng.permutation(n)
        elif mode == "zipf":
            # zipfian hot set: rank r of a seeded permutation is drawn with
            # probability ∝ 1/r^a, so a few hot records dominate the epoch;
            # still a pure function of (seed, epoch) -> restart-exact
            rng = np.random.default_rng((self.config.seed, epoch))
            ranks = rng.permutation(n)
            order = ranks[np.minimum(rng.zipf(a=1.6, size=n) - 1, n - 1)]
        elif mode == "seq":
            order = np.arange(n)
        else:
            raise ValueError(
                f"unknown access pattern {mode!r}; valid: {ACCESS_PATTERNS}"
            )
        return order[self.host_id :: self.n_hosts]

    def steps_per_epoch(self) -> int:
        n = self.epoch_order(0).shape[0]
        b = self.config.batch_size
        return n // b if self.config.drop_last else (n + b - 1) // b

    def batch_indices(self, epoch: int, step: int) -> np.ndarray:
        order = self.epoch_order(epoch)
        b = self.config.batch_size
        return order[step * b : (step + 1) * b]

    # -- fetching -------------------------------------------------------------
    def _rebuild_pool(self):
        old = self._pool
        self._pool = (
            cf.ThreadPoolExecutor(max_workers=self.config.num_workers)
            if self.config.num_workers > 0
            else None
        )
        if old is not None:
            old.shutdown(wait=False)

    def fetch_batch(self, epoch: int, step: int) -> np.ndarray:
        idx = self.batch_indices(epoch, step)
        pool = self._pool  # snapshot: reconfigure() may swap it concurrently
        if pool is not None:
            recs = list(pool.map(self.source.read, idx))
        else:
            recs = [self.source.read(int(i)) for i in idx]
        return self.collate(recs)

    # -- clairvoyant prefetching (data/prefetch.py) ------------------------
    def _ensure_prefetcher(self) -> Optional[ClairvoyantPrefetcher]:
        """Lazily build the block prefetcher; None when the source has no
        plan-layer reader (e.g. SyntheticTokenSource — nothing to prefetch)."""
        if self._prefetcher is None:
            reader = getattr(self.source, "reader", None)
            if reader is None or not hasattr(reader, "block_plan"):
                return None
            self._prefetcher = ClairvoyantPrefetcher(
                reader,
                self,
                lookahead_batches=self.config.lookahead_batches,
                cache_budget_mb=self.config.cache_budget_mb,
                workers=max(2, self.config.num_workers),
            )
        return self._prefetcher

    def _drop_prefetcher(self):
        if self._prefetcher is not None:
            self._prefetcher.close()
            self._prefetcher = None

    def prefetch_stats(self) -> Optional[dict]:
        return self._prefetcher.stats() if self._prefetcher is not None else None

    def _fetch_step(self, epoch: int, step: int) -> np.ndarray:
        """One batch, honoring the *current* prefetch policy (checked per
        step so mid-epoch reconfigure() changes mechanics, never order)."""
        if policy_name(self.config.prefetch_policy) == "clairvoyant":
            pf = self._ensure_prefetcher()
            if pf is not None:
                pf.advance(epoch, step)
                idx = self.batch_indices(epoch, step)
                decode = self.source.codec.decode

                def _read(i):
                    return decode(pf.read_record(int(i)))

                pool = self._pool
                recs = (list(pool.map(_read, idx)) if pool is not None
                        else [_read(i) for i in idx])
                return self.collate(recs)
        return self.fetch_batch(epoch, step)

    def batch_nbytes(self) -> int:
        return self.config.batch_size * self.source.record_nbytes()

    # -- prefetched iteration ---------------------------------------------
    def iter_epoch(self, epoch: int, start_step: int = 0) -> Iterator[np.ndarray]:
        """Batch iterator; restart-exact given (epoch, start_step) under
        every prefetch policy — the step sequence and batch bytes are
        identical whether batches are fetched synchronously (``off``),
        through the depth-bounded producer thread (``depth``), or via the
        clairvoyant block cache (``clairvoyant``)."""
        if policy_name(self.config.prefetch_policy) == "off":
            return self._iter_sync(epoch, start_step)
        return self._iter_queued(epoch, start_step)

    def _iter_sync(self, epoch: int, start_step: int) -> Iterator[np.ndarray]:
        for s in range(start_step, self.steps_per_epoch()):
            yield self._fetch_step(epoch, s)

    def _iter_queued(self, epoch: int, start_step: int) -> Iterator[np.ndarray]:
        steps = self.steps_per_epoch()
        depth = max(1, self.config.prefetch_depth)
        q: queue.Queue = queue.Queue(maxsize=depth)
        stop = threading.Event()

        def _put(item) -> bool:
            while not stop.is_set():
                try:
                    q.put(item, timeout=0.05)
                    return True
                except queue.Full:
                    continue
            return False

        def producer():
            try:
                for s in range(start_step, steps):
                    if stop.is_set():
                        return
                    if not _put(self._fetch_step(epoch, s)):
                        return
                _put(None)
            except BaseException as e:  # noqa: BLE001 — surface in consumer
                _put(_ProducerError(e))

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        try:
            while True:
                item = q.get()
                if item is None:
                    return
                if isinstance(item, _ProducerError):
                    raise item.exc
                yield item
        finally:
            stop.set()
            t.join(timeout=5.0)

    # -- live reconfiguration (autotuner actuator) --------------------------
    def reconfigure(self, **knobs) -> PipelineConfig:
        """Apply knob changes between steps.  Unknown knob names raise
        ``ValueError`` (a silent no-op here means an autotuner decision was
        never actuated).  ``prefetch_policy`` accepts a name or its numeric
        code (the config grids are numeric)."""
        valid = PipelineConfig.knob_names()
        unknown = sorted(set(knobs) - set(valid))
        if unknown:
            raise ValueError(
                f"unknown pipeline knob(s): {', '.join(unknown)}; "
                f"valid knobs: {', '.join(valid)}"
            )
        if "prefetch_policy" in knobs:
            knobs["prefetch_policy"] = policy_name(knobs["prefetch_policy"])
        old = self.config
        self.config = self.config.replace(**knobs)
        if self.config.num_workers != old.num_workers:
            self._rebuild_pool()
        if self.config.block_kb != old.block_kb:
            if hasattr(self.source, "reader"):
                self.source.reader.block_kb = self.config.block_kb
            # block granularity changed: the cached plan/blocks are stale
            self._drop_prefetcher()
        if self._prefetcher is not None and (
            self.config.lookahead_batches != old.lookahead_batches
            or self.config.cache_budget_mb != old.cache_budget_mb
        ):
            self._prefetcher.reconfigure(
                lookahead_batches=self.config.lookahead_batches,
                cache_budget_mb=self.config.cache_budget_mb,
            )
        return self.config

    def close(self):
        if self._pool is not None:
            self._pool.shutdown(wait=False)
        self._drop_prefetcher()
