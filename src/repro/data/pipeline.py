"""The tunable training input pipeline (paper §3.1.2 made production-grade).

Knobs = the paper's features: batch_size, num_workers, prefetch_depth,
block_kb, format, backend. Properties needed at pod scale:

- **per-host sharding**: host h of H reads global indices h::H — each pod
  host feeds only its data-parallel slice.
- **restart-exact**: the sample order is a pure function of (seed, epoch,
  step); resuming from a checkpointed step reproduces the same batches.
- **live reconfiguration**: ``reconfigure()`` swaps worker pool / prefetch /
  block size between steps without losing position (the autotuner's actuator).
- **prefetch**: a background thread keeps ``prefetch_depth`` batches ready;
  workers fetch records concurrently within a batch.
"""

from __future__ import annotations

import concurrent.futures as cf
import dataclasses
import queue
import threading
from typing import Callable, Iterator, Optional

import numpy as np

from .formats import DatasetReader

__all__ = ["PipelineConfig", "TokenRecordCodec", "ImageRecordCodec",
           "TabularRecordCodec", "DataPipeline", "SyntheticTokenSource"]


class _ProducerError:
    """Wraps an exception raised in the prefetch thread for re-raise."""

    def __init__(self, exc: BaseException):
        self.exc = exc


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    batch_size: int = 32
    num_workers: int = 0  # 0 = synchronous in-thread fetch
    prefetch_depth: int = 2
    block_kb: int = 64
    shuffle: bool = True
    drop_last: bool = True
    seed: int = 0

    def replace(self, **kw) -> "PipelineConfig":
        return dataclasses.replace(self, **kw)


class TokenRecordCodec:
    """Fixed-length int32 token records <-> bytes."""

    def __init__(self, seq_len: int):
        self.seq_len = seq_len

    @property
    def nbytes(self) -> int:
        return 4 * self.seq_len

    def encode(self, tokens: np.ndarray) -> bytes:
        assert tokens.shape == (self.seq_len,)
        return np.asarray(tokens, np.int32).tobytes()

    def decode(self, data: bytes) -> np.ndarray:
        return np.frombuffer(data, np.int32, count=self.seq_len)


class ImageRecordCodec:
    """CIFAR-style fixed-size image records (paper §3.1.2: 32x32 RGB uint8)."""

    def __init__(self, h: int = 32, w: int = 32, c: int = 3):
        self.shape = (h, w, c)

    @property
    def nbytes(self) -> int:
        h, w, c = self.shape
        return h * w * c

    def encode(self, img: np.ndarray) -> bytes:
        assert img.shape == self.shape
        return np.asarray(img, np.uint8).tobytes()

    def decode(self, data: bytes) -> np.ndarray:
        return np.frombuffer(data, np.uint8, count=self.nbytes).reshape(self.shape)


class TabularRecordCodec:
    """Fixed-width float32 feature rows (paper §3.1.2 tabular workloads)."""

    def __init__(self, n_features: int):
        self.n_features = n_features

    @property
    def nbytes(self) -> int:
        return 4 * self.n_features

    def encode(self, row: np.ndarray) -> bytes:
        assert row.shape == (self.n_features,)
        return np.asarray(row, np.float32).tobytes()

    def decode(self, data: bytes) -> np.ndarray:
        return np.frombuffer(data, np.float32, count=self.n_features)


class SyntheticTokenSource:
    """I/O-free source: deterministic tokens(i). Used by smoke tests and the
    dry-run path where no real storage is wanted."""

    def __init__(self, n_records: int, seq_len: int, vocab: int, seed: int = 0):
        self.n_records, self.seq_len, self.vocab, self.seed = n_records, seq_len, vocab, seed

    def __len__(self):
        return self.n_records

    def read(self, i: int) -> np.ndarray:
        rng = np.random.default_rng(self.seed * 1_000_003 + i)
        return rng.integers(0, self.vocab, size=self.seq_len, dtype=np.int32)

    def record_nbytes(self) -> int:
        return 4 * self.seq_len


class _ReaderSource:
    """Adapter: DatasetReader + codec -> sample source."""

    def __init__(self, reader: DatasetReader, codec: TokenRecordCodec):
        self.reader, self.codec = reader, codec

    def __len__(self):
        return len(self.reader)

    def read(self, i: int) -> np.ndarray:
        return self.codec.decode(self.reader.read(i))

    def record_nbytes(self) -> int:
        return self.codec.nbytes


class DataPipeline:
    def __init__(
        self,
        source,
        config: PipelineConfig,
        host_id: int = 0,
        n_hosts: int = 1,
        collate: Optional[Callable] = None,
    ):
        self.source = source
        self.config = config
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.collate = collate or (lambda recs: np.stack(recs))
        self._pool: Optional[cf.ThreadPoolExecutor] = None
        self._rebuild_pool()

    @classmethod
    def from_reader(cls, reader, seq_len: int, config: PipelineConfig, **kw):
        # push block_kb into the reader (the knob acts at the format layer)
        reader.block_kb = config.block_kb
        return cls(_ReaderSource(reader, TokenRecordCodec(seq_len)), config, **kw)

    # -- deterministic order ------------------------------------------------
    def epoch_order(self, epoch: int) -> np.ndarray:
        n = len(self.source)
        if self.config.shuffle:
            rng = np.random.default_rng((self.config.seed, epoch))
            order = rng.permutation(n)
        else:
            order = np.arange(n)
        return order[self.host_id :: self.n_hosts]

    def steps_per_epoch(self) -> int:
        n = self.epoch_order(0).shape[0]
        b = self.config.batch_size
        return n // b if self.config.drop_last else (n + b - 1) // b

    def batch_indices(self, epoch: int, step: int) -> np.ndarray:
        order = self.epoch_order(epoch)
        b = self.config.batch_size
        return order[step * b : (step + 1) * b]

    # -- fetching -------------------------------------------------------------
    def _rebuild_pool(self):
        old = self._pool
        self._pool = (
            cf.ThreadPoolExecutor(max_workers=self.config.num_workers)
            if self.config.num_workers > 0
            else None
        )
        if old is not None:
            old.shutdown(wait=False)

    def fetch_batch(self, epoch: int, step: int) -> np.ndarray:
        idx = self.batch_indices(epoch, step)
        pool = self._pool  # snapshot: reconfigure() may swap it concurrently
        if pool is not None:
            recs = list(pool.map(self.source.read, idx))
        else:
            recs = [self.source.read(int(i)) for i in idx]
        return self.collate(recs)

    def batch_nbytes(self) -> int:
        return self.config.batch_size * self.source.record_nbytes()

    # -- prefetched iteration ---------------------------------------------
    def iter_epoch(self, epoch: int, start_step: int = 0) -> Iterator[np.ndarray]:
        """Prefetched iterator; restart-exact given (epoch, start_step)."""
        steps = self.steps_per_epoch()
        depth = max(1, self.config.prefetch_depth)
        q: queue.Queue = queue.Queue(maxsize=depth)
        stop = threading.Event()

        def _put(item) -> bool:
            while not stop.is_set():
                try:
                    q.put(item, timeout=0.05)
                    return True
                except queue.Full:
                    continue
            return False

        def producer():
            try:
                for s in range(start_step, steps):
                    if stop.is_set():
                        return
                    if not _put(self.fetch_batch(epoch, s)):
                        return
                _put(None)
            except BaseException as e:  # noqa: BLE001 — surface in consumer
                _put(_ProducerError(e))

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        try:
            while True:
                item = q.get()
                if item is None:
                    return
                if isinstance(item, _ProducerError):
                    raise item.exc
                yield item
        finally:
            stop.set()
            t.join(timeout=5.0)

    # -- live reconfiguration (autotuner actuator) --------------------------
    def reconfigure(self, **knobs) -> PipelineConfig:
        old = self.config
        self.config = self.config.replace(
            **{k: v for k, v in knobs.items() if hasattr(old, k)}
        )
        if self.config.num_workers != old.num_workers:
            self._rebuild_pool()
        if self.config.block_kb != old.block_kb and hasattr(self.source, "reader"):
            self.source.reader.block_kb = self.config.block_kb
        return self.config

    def close(self):
        if self._pool is not None:
            self._pool.shutdown(wait=False)
