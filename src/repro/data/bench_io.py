"""I/O microbenchmarks (paper §3.1.1): sequential, random, concurrent reads.

Each function returns the canonical observation fields so rows drop straight
into the predictor's FeatureSpec.
"""

from __future__ import annotations

import concurrent.futures as cf
import os
import pathlib
import time
from typing import Optional

import numpy as np

from .storage import StorageBackend, drop_page_cache_hint

__all__ = [
    "make_test_file",
    "bench_sequential_read",
    "bench_random_read",
    "bench_concurrent_read",
]


def make_test_file(backend: StorageBackend, name: str, size_mb: float, seed: int = 0) -> pathlib.Path:
    """Create a test file of pseudo-random bytes (incompressible)."""
    p = backend.path(name)
    if p.exists() and p.stat().st_size == int(size_mb * 1e6):
        return p
    rng = np.random.default_rng(seed)
    chunk = rng.integers(0, 256, size=1 << 20, dtype=np.uint8).tobytes()
    remaining = int(size_mb * 1e6)
    with open(p, "wb") as f:
        while remaining > 0:
            n = min(remaining, len(chunk))
            f.write(chunk[:n])
            remaining -= n
    return p


def bench_sequential_read(
    backend: StorageBackend, path: pathlib.Path, block_kb: int, cold: bool = False
) -> dict:
    if cold:
        drop_page_cache_hint(path)
    size = path.stat().st_size
    bs = block_kb * 1024
    t0 = time.perf_counter()
    n_ops = 0
    with open(path, "rb") as f:
        off = 0
        while off < size:
            data = backend.read_block(f, off, min(bs, size - off))
            if not data:
                break
            off += len(data)
            n_ops += 1
    dt = max(time.perf_counter() - t0, 1e-9)
    return {
        "block_kb": block_kb,
        "file_size_mb": size / 1e6,
        "throughput_mb_s": size / 1e6 / dt,
        "iops": n_ops / dt,
        "n_threads": 1,
        "elapsed_s": dt,
    }


def bench_random_read(
    backend: StorageBackend,
    path: pathlib.Path,
    n_samples: int,
    sample_kb: int = 4,
    seed: int = 0,
    cold: bool = False,
) -> dict:
    if cold:
        drop_page_cache_hint(path)
    size = path.stat().st_size
    bs = sample_kb * 1024
    rng = np.random.default_rng(seed)
    offsets = rng.integers(0, max(size - bs, 1), size=n_samples)
    offsets = (offsets // bs) * bs  # aligned
    t0 = time.perf_counter()
    read_bytes = 0
    with open(path, "rb") as f:
        for off in offsets:
            read_bytes += len(backend.read_block(f, int(off), bs))
    dt = max(time.perf_counter() - t0, 1e-9)
    return {
        "block_kb": sample_kb,
        "file_size_mb": size / 1e6,
        "n_samples": n_samples,
        "throughput_mb_s": read_bytes / 1e6 / dt,
        "iops": n_samples / dt,
        "n_threads": 1,
        "elapsed_s": dt,
    }


def bench_concurrent_read(
    backend: StorageBackend,
    path: pathlib.Path,
    n_threads: int,
    per_thread_mb: float = 8.0,
    block_kb: int = 256,
    seed: int = 0,
) -> dict:
    """Aggregate throughput with k threads doing strided sequential reads."""
    size = path.stat().st_size
    bs = block_kb * 1024
    per_bytes = int(per_thread_mb * 1e6)

    def worker(tid: int) -> int:
        rng = np.random.default_rng(seed + tid)
        start = int(rng.integers(0, max(size - per_bytes, 1)))
        start = (start // bs) * bs
        done = 0
        with open(path, "rb") as f:
            off = start
            while done < per_bytes:
                data = backend.read_block(f, off % max(size - bs, 1), bs)
                if not data:
                    break
                done += len(data)
                off += bs
        return done

    t0 = time.perf_counter()
    with cf.ThreadPoolExecutor(max_workers=n_threads) as ex:
        totals = list(ex.map(worker, range(n_threads)))
    dt = max(time.perf_counter() - t0, 1e-9)
    agg = sum(totals) / 1e6 / dt
    return {
        "block_kb": block_kb,
        "file_size_mb": size / 1e6,
        "n_threads": n_threads,
        "throughput_mb_s": agg / n_threads,  # per-thread
        "aggregate_throughput_mb_s": agg,
        "iops": sum(totals) / bs / dt,
        "elapsed_s": dt,
    }
