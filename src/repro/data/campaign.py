"""Resumable, shardable execution of benchmark campaigns (paper §3.1).

``registry.py`` declares *what* to run (frozen :class:`BenchCase` lists);
this module runs it.  Every completed case appends exactly one JSONL record —
observation row plus provenance (case id, repeat index, seed, shard, host,
elapsed time, git describe, and a failure record on exception) — so a crashed
or killed campaign loses at most the in-flight case:

- **resume**: re-running a campaign against the same JSONL file skips case
  (id, rep) pairs that already succeeded and re-runs failed ones;
- **shard**: ``--shard h/H`` partitions the case list across H hosts by
  position (disjoint and complete), each appending to its own file;
- **summarize**: aggregates per-backend/format throughput distributions and
  failure counts from one or more JSONL files.

CLI::

    python -m repro.data.campaign list
    python -m repro.data.campaign run --campaign paper_core --fast
    python -m repro.data.campaign resume --campaign extended --shard 0/4
    python -m repro.data.campaign summarize --out /tmp/repro_io/campaigns/extended.jsonl
    python -m repro.data.campaign merge shard0.jsonl shard1.jsonl --out merged.jsonl

The JSONL record schema is documented in ``docs/benchmark-matrix.md``.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import socket
import subprocess
import sys
import threading
import time
import traceback
import zlib
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple, Union

import numpy as np

from ..core.features import FEATURE_NAMES, TARGET_NAME
from .bench_io import bench_concurrent_read, bench_random_read, bench_sequential_read, make_test_file
from .formats import open_dataset, write_dataset
from .pipeline import DataPipeline, PipelineConfig, TokenRecordCodec
from .registry import BenchCase, Campaign, get_campaign, list_campaigns
from .storage import BACKENDS, StorageBackend

__all__ = [
    "SCHEMA_VERSION",
    "DEFAULT_OUT_DIR",
    "RunContext",
    "RunResult",
    "CaseTimeout",
    "run_case",
    "run_campaign",
    "run_campaign_batch",
    "load_records",
    "load_records_ex",
    "repair_jsonl_tail",
    "completed_keys",
    "terminal_keys",
    "rows_from_records",
    "shard_cases",
    "merge_records",
    "merge_files",
    "canonical_records",
    "case_index",
    "CANONICAL_VOLATILE_KEYS",
    "classify_error",
    "set_fault_hook",
    "summarize",
    "format_summary",
    "format_backends",
    "simulated_compute",
    "run_pipeline_case",
    "main",
]

SCHEMA_VERSION = 1
DEFAULT_OUT_DIR = pathlib.Path("/tmp/repro_io/campaigns")

# Optional fault-injection plan (service.faults installs it): duck-typed with
# ``on_case(site)`` (raise/sleep before case execution) and
# ``check_append(site)`` (ENOSPC / torn-write scheduling for the durable
# JSONL append).  A registry, not an import — data never depends on service.
_FAULT_HOOK = None


def set_fault_hook(plan) -> None:
    """Install (or clear, with ``None``) the campaign fault-injection plan."""
    global _FAULT_HOOK
    _FAULT_HOOK = plan


class CaseTimeout(Exception):
    """A case exceeded its per-case wall-clock deadline (``deadline_s``)."""


def simulated_compute(seconds: float):
    """Stand-in busy-wait for the accelerator step (paper's 'simulated GPU')."""
    t0 = time.perf_counter()
    while time.perf_counter() - t0 < seconds:
        pass


def _git_describe() -> str:
    try:
        out = subprocess.run(
            ["git", "describe", "--always", "--dirty"],
            cwd=pathlib.Path(__file__).parent, capture_output=True, text=True, timeout=5,
        )
        return out.stdout.strip() if out.returncode == 0 else ""
    except (OSError, subprocess.SubprocessError):
        return ""


class RunContext:
    """Shared per-run caches so cases stay cheap to execute independently.

    Random-access cases share test files and the per-(backend, size, block)
    sequential-throughput baseline; pipeline cases share written dataset
    manifests per (backend, format, n_records, seq_len, seed)."""

    def __init__(self):
        self.seq_baseline: Dict[tuple, float] = {}
        self.test_files: Dict[tuple, pathlib.Path] = {}
        self.manifests: Dict[tuple, dict] = {}
        self._records: Dict[tuple, list] = {}
        self.git = _git_describe()
        self.host = socket.gethostname()

    def test_file(self, backend: StorageBackend, size_mb: float, seed: int,
                  prefix: str = "ra") -> pathlib.Path:
        key = (backend.name, prefix, size_mb, seed)
        if key not in self.test_files:
            # seed in the name: make_test_file reuses an existing same-size
            # file, so without it every seed would silently share seed-0 bytes
            sz = int(size_mb) if size_mb == int(size_mb) else size_mb
            name = f"{prefix}_{sz}mb_s{seed}.bin"
            self.test_files[key] = make_test_file(backend, name, size_mb, seed)
        return self.test_files[key]

    def token_records(self, n_records: int, seq_len: int, seed: int) -> list:
        key = (n_records, seq_len, seed)
        if key not in self._records:
            codec = TokenRecordCodec(seq_len)
            rng = np.random.default_rng(seed)
            self._records[key] = [
                codec.encode(rng.integers(0, 50_000, size=seq_len, dtype=np.int32))
                for _ in range(n_records)
            ]
        return self._records[key]

    def manifest(self, backend: StorageBackend, fmt: str, n_records: int,
                 seq_len: int, seed: int) -> dict:
        key = (backend.name, fmt, n_records, seq_len, seed)
        if key not in self.manifests:
            # the name carries every cache-key axis: cases differing only in
            # n_records/seq_len/seed must not overwrite each other's files
            # while an earlier case's cached manifest still points at them
            self.manifests[key] = write_dataset(
                backend, f"pl_{fmt}_r{n_records}x{seq_len}_s{seed}",
                self.token_records(n_records, seq_len, seed), fmt,
            )
        return self.manifests[key]


def _blank_row(bench_type: str) -> dict:
    row = {k: 0.0 for k in FEATURE_NAMES}
    row["bench_type"] = bench_type
    return row


# ---------------------------------------------------------------- executors

def _exec_random(case: BenchCase, ctx: RunContext, seed: int) -> dict:
    backend = BACKENDS[case.backend]
    path = ctx.test_file(backend, case.file_size_mb, seed)
    # seed in the key: the baseline must be measured on the same seed's file
    # as the random-read target (repeats > 1 runs each rep with seed + rep)
    key = (case.backend, case.file_size_mb, case.block_kb, seed)
    if key not in ctx.seq_baseline:
        seq = bench_sequential_read(backend, path, block_kb=max(case.block_kb, 64))
        ctx.seq_baseline[key] = seq["throughput_mb_s"]
    r = bench_random_read(backend, path, case.n_samples, case.block_kb, seed=seed)
    row = _blank_row("io_random")
    row.update(
        block_kb=case.block_kb,
        file_size_mb=r["file_size_mb"],
        n_samples=case.n_samples,
        throughput_mb_s=ctx.seq_baseline[key],  # upstream: sequential baseline
        iops=r["iops"],
        n_threads=1,
    )
    row[TARGET_NAME] = r["throughput_mb_s"]  # downstream: random-access
    row["backend"] = case.backend
    return row


def run_pipeline_case(
    backend: StorageBackend,
    manifest: dict,
    fmt: str,
    batch: int,
    workers: int,
    seq_len: int,
    compute_s: float,
    probe_steps: int = 2,
    measure_steps: int = 6,
    prefetch_depth: int = 2,
    block_kb: int = 64,
    prefetch_policy: str = "depth",
    lookahead_batches: int = 8,
    cache_budget_mb: float = 64.0,
    access: str = "shuffle",
    n_hosts: int = 1,
) -> dict:
    """Run one pipeline benchmark: probe window feeds the upstream features,
    the measure window feeds the downstream target (paper §4.3)."""
    from .prefetch import policy_code
    from .telemetry import StepTelemetry

    reader = open_dataset(backend, manifest, block_kb=block_kb)
    pipe = DataPipeline.from_reader(
        reader, seq_len,
        PipelineConfig(batch_size=batch, num_workers=workers,
                       prefetch_depth=prefetch_depth, seed=0,
                       prefetch_policy=prefetch_policy,
                       lookahead_batches=lookahead_batches,
                       cache_budget_mb=cache_budget_mb, access=access),
        host_id=0, n_hosts=n_hosts,
    )
    tele = StepTelemetry()
    probe = StepTelemetry()
    steps = min(pipe.steps_per_epoch(), probe_steps + measure_steps)
    it = pipe.iter_epoch(0)
    for s in range(steps):
        t = probe if s < probe_steps else tele
        with t.data_wait():
            batch_arr = next(it)
        with t.compute():
            simulated_compute(compute_s)
        t.record_batch(batch_arr.shape[0], batch_arr.nbytes)
    it.close()  # stops the producer thread before teardown
    pf_stats = pipe.prefetch_stats()
    pipe.close()
    reader.close()
    row = _blank_row("pipeline")
    row.update(
        batch_size=batch,
        num_workers=workers,
        block_kb=block_kb,
        file_size_mb=reader.total_bytes / 1e6,
        samples_per_second=probe.samples_per_second(),  # upstream probe
        data_loading_ratio=probe.data_loading_ratio(),
        throughput_mb_s=probe.throughput_mb_s(),
        prefetch_policy=policy_code(prefetch_policy),
        lookahead_batches=lookahead_batches,
        cache_budget_mb=cache_budget_mb,
    )
    # Target = overall delivered MB/s (samples/sec x record bytes), the
    # paper's pipeline-benchmark measurement; probe features come from the
    # separate warmup window above.
    row[TARGET_NAME] = tele.throughput_mb_s()
    row["backend"] = backend.name
    row["format"] = fmt
    row["access"] = access
    row["utilization"] = tele.simulated_utilization()
    # stall diagnostics (not features): measure-window data-wait seconds
    row["data_wait_s"] = float(sum(tele.data_times))
    if pf_stats is not None:
        row["prefetch_hit_ratio"] = pf_stats["hit_ratio"]
    return row


def _exec_pipeline(case: BenchCase, ctx: RunContext, seed: int) -> dict:
    backend = BACKENDS[case.backend]
    manifest = ctx.manifest(backend, case.format, case.n_records, case.seq_len, seed)
    return run_pipeline_case(
        backend, manifest, case.format, case.batch_size, case.num_workers,
        case.seq_len, compute_s=case.compute_s,
        prefetch_depth=case.prefetch_depth, block_kb=case.block_kb,
        prefetch_policy=case.prefetch_policy,
        lookahead_batches=case.lookahead_batches,
        cache_budget_mb=case.cache_budget_mb,
        access=case.access, n_hosts=case.n_hosts,
    )


def _exec_concurrent(case: BenchCase, ctx: RunContext, seed: int) -> dict:
    backend = BACKENDS[case.backend]
    path = ctx.test_file(backend, case.file_size_mb, seed, prefix="cc")
    r = bench_concurrent_read(
        backend, path, case.n_threads, per_thread_mb=case.per_thread_mb,
        block_kb=case.block_kb, seed=seed,
    )
    row = _blank_row("concurrent")
    row.update(
        block_kb=r["block_kb"],
        file_size_mb=r["file_size_mb"],
        n_threads=case.n_threads,
        throughput_mb_s=r["throughput_mb_s"],  # per-thread
        iops=r["iops"],
        aggregate_throughput_mb_s=r["aggregate_throughput_mb_s"],
    )
    row[TARGET_NAME] = r["aggregate_throughput_mb_s"]
    row["backend"] = case.backend
    return row


_EXECUTORS = {
    "io_random": _exec_random,
    "pipeline": _exec_pipeline,
    "concurrent": _exec_concurrent,
}


def run_case(case: BenchCase, ctx: Optional[RunContext] = None, seed: int = 0) -> dict:
    """Execute one case and return its observation row (features + target)."""
    return _EXECUTORS[case.bench_type](case, ctx or RunContext(), seed)


# ---------------------------------------------------------------- JSONL store

def load_records_ex(path: pathlib.Path) -> Tuple[List[dict], int, bool]:
    """Read JSONL records, distinguishing the two corruption shapes.

    Returns ``(records, n_corrupt, torn_tail)``.  A malformed *final* line
    with no trailing newline is a **torn tail** — the expected residue of a
    killed writer, dropped silently (resume re-runs the in-flight case).  Any
    other malformed line is **mid-stream corruption**: skipped and counted
    (never raised — one bad line must not take down a merge), with a warning,
    since the affected cases silently re-run on resume."""
    path = pathlib.Path(path)
    if not path.exists():
        return [], 0, False
    text = path.read_text()
    ends_nl = text.endswith("\n")
    lines = [ln for ln in text.splitlines() if ln.strip()]
    records: List[dict] = []
    n_corrupt = 0
    torn_tail = False
    for i, line in enumerate(lines):
        try:
            records.append(json.loads(line))
        except json.JSONDecodeError:
            if i == len(lines) - 1 and not ends_nl:
                torn_tail = True
            else:
                n_corrupt += 1
                print(f"warning: {path}:{i + 1}: dropping malformed JSONL line "
                      "(file corrupted mid-stream?)", file=sys.stderr)
    return records, n_corrupt, torn_tail


def repair_jsonl_tail(path: pathlib.Path) -> bool:
    """Make a JSONL artifact safe to append to; returns True if repaired.

    A file whose final line lacks its newline would glue the next appended
    record onto it, and both would read back as one corrupt mid-stream line
    — the in-flight case *and* the new case would silently vanish.  A
    malformed un-terminated tail (torn write / killed writer) is truncated
    back to the last record boundary; a *valid* un-terminated tail (only the
    newline was lost) is sealed by writing the missing newline, keeping the
    record."""
    path = pathlib.Path(path)
    if not path.exists():
        return False
    data = path.read_bytes()
    if not data or data.endswith(b"\n"):
        return False
    tail = data[data.rfind(b"\n") + 1:]
    try:
        json.loads(tail)
    except ValueError:
        with open(path, "rb+") as f:
            f.truncate(data.rfind(b"\n") + 1)
    else:
        with open(path, "ab") as f:
            f.write(b"\n")
    return True


def load_records(path: pathlib.Path) -> List[dict]:
    """:func:`load_records_ex` without the corruption counters."""
    return load_records_ex(path)[0]


def completed_keys(records: Iterable[dict]) -> set:
    """(case_id, rep, seed) triples that already succeeded — the resume
    skip-set.  Keying on seed means a re-run with a new ``--seed`` collects a
    fresh set of rows into the same file (growing the dataset) instead of
    silently no-opping against records from another seed."""
    return {
        (r["case_id"], r.get("rep", 0), r.get("seed", 0))
        for r in records if r.get("status") == "ok"
    }


def terminal_keys(records: Iterable[dict]) -> set:
    """The resume skip-set: succeeded keys plus quarantined ones.  A
    quarantined key has permanently failed ``quarantine_after`` times —
    re-running it forever would just burn the collection budget."""
    return {
        (r["case_id"], r.get("rep", 0), r.get("seed", 0))
        for r in records if r.get("status") in ("ok", "quarantined")
    }


def rows_from_records(records: Iterable[dict]) -> List[dict]:
    """Observation rows (dataset.py schema) from successful JSONL records."""
    return [r["row"] for r in records if r.get("status") == "ok" and r.get("row")]


def shard_cases(cases: Sequence[BenchCase], shard: int, n_shards: int) -> List[BenchCase]:
    """Positional partition: shard h of H takes cases h, h+H, h+2H, ...

    Disjoint and complete across shards by construction."""
    if not (0 <= shard < n_shards):
        raise ValueError(f"shard {shard} out of range for {n_shards} shards")
    return [c for i, c in enumerate(cases) if i % n_shards == shard]


@dataclasses.dataclass
class RunResult:
    """What one ``run_campaign`` invocation did."""

    campaign: str
    out_path: Optional[pathlib.Path]
    executed: List[Tuple[str, int]]       # (case_id, rep) run this invocation
    skipped: int                          # already-complete (resume hits)
    failures: List[Tuple[str, int]]       # (case_id, rep) that raised
    rows: List[dict]                      # observation rows from this run
    errors: List[dict] = dataclasses.field(default_factory=list)
    # one {case_id, rep, type, message, traceback} per entry in failures
    retried: int = 0                      # transient-failure retry attempts
    n_timeouts: int = 0                   # cases that hit the deadline
    n_quarantined: int = 0                # keys quarantined this invocation
    write_retries: int = 0                # durable-append recoveries

    @property
    def n_executed(self) -> int:
        return len(self.executed)


# ------------------------------------------------------- failure taxonomy

def classify_error(exc: BaseException) -> str:
    """``transient`` (retried) / ``timeout`` / ``permanent`` (neither is
    retried: a deadline overrun will overrun again, and a logic error will
    raise again — both only count toward quarantine)."""
    if isinstance(exc, CaseTimeout):
        return "timeout"
    if isinstance(exc, OSError):  # IOError is an alias; injected faults too
        return "transient"
    return "permanent"


def _backoff_sleep(backoff_s: float, attempt: int, key: str) -> None:
    """Exponential backoff with deterministic, key-hashed jitter (crc32, not
    hash(): stable across processes and PYTHONHASHSEED)."""
    jitter = (zlib.crc32(f"{key}:{attempt}".encode()) % 1000) / 2000.0  # 0..0.5
    time.sleep(backoff_s * (2 ** (attempt - 1)) * (1.0 + jitter))


def _run_attempt(exec_fn, case, ctx, seed: int, deadline_s: Optional[float]):
    """One execution attempt, optionally bounded by a wall-clock deadline.

    The deadline runs the executor on a daemon worker thread and abandons it
    on overrun (Python threads cannot be killed) — the campaign moves on and
    the straggler finishes into a discarded dict.  Without a deadline the
    executor runs inline, exactly as before."""
    def call():
        if _FAULT_HOOK is not None:
            _FAULT_HOOK.on_case(f"case:{case.id}")
        return exec_fn(case, ctx, seed)

    if deadline_s is None:
        return call()
    result: dict = {}
    th = threading.Thread(target=lambda: _capture(call, result), daemon=True)
    th.start()
    th.join(deadline_s)
    if th.is_alive():
        raise CaseTimeout(f"{case.id} exceeded the {deadline_s}s case deadline")
    if "exc" in result:
        raise result["exc"]
    return result["row"]


def _capture(call, result: dict) -> None:
    try:
        result["row"] = call()
    except BaseException as e:  # noqa: BLE001 — re-raised on the caller thread
        result["exc"] = e


def _durable_append(f, line: str, site: str) -> int:
    """Append one JSONL line, surviving injected (or real) write failures.

    ENOSPC refuses the write before any byte lands — just retry.  A torn
    write leaves a flushed partial line — recover by truncating back to the
    pre-write position and seeking to the new EOF (O_APPEND writes land at
    EOF, so the retry produces exactly the intended bytes, once).  Returns
    the number of recoveries; re-raises after 4 so a genuinely full disk
    still fails loudly."""
    retries = 0
    while True:
        f.flush()
        pos = f.tell()
        try:
            torn = (_FAULT_HOOK.check_append(site)
                    if _FAULT_HOOK is not None else None)
            if torn is not None:
                f.write(line[:max(1, min(torn, len(line) - 1))])
                f.flush()
                raise OSError(f"injected torn write at {site}")
            f.write(line)
            f.flush()
            return retries
        except OSError:
            retries += 1
            if retries > 4:
                raise
            f.flush()
            f.truncate(pos)
            f.seek(0, 2)


def run_campaign(
    campaign: Union[str, Campaign],
    out_path: Optional[Union[str, pathlib.Path]] = None,
    fast: bool = False,
    seed: int = 0,
    shard: Tuple[int, int] = (0, 1),
    resume: bool = True,
    max_cases: Optional[int] = None,
    ctx: Optional[RunContext] = None,
    executor: Optional[Callable[[BenchCase, RunContext, int], dict]] = None,
    progress: Optional[Callable[[str], None]] = None,
    on_record: Optional[Callable[[dict], None]] = None,
    deadline_s: Optional[float] = None,
    max_retries: int = 2,
    backoff_s: float = 0.05,
    quarantine_after: Optional[int] = 3,
) -> RunResult:
    """Run (or resume) a campaign, appending one JSONL record per case.

    ``out_path=None`` keeps results in memory only (no resume across
    processes).  ``shard=(h, H)`` runs the h-th positional slice of the case
    list.  ``max_cases`` stops after that many executions (used by tests to
    simulate a killed run).  ``executor`` overrides case execution (tests).
    ``on_record`` is called with each completed record (ok or error) after it
    is durably written — the continuous loop's streaming-ingest hook.

    Failure handling (``docs/robustness.md``): each attempt that raises is
    classified by :func:`classify_error` — *transient* errors are retried up
    to ``max_retries`` times with exponential backoff and deterministic
    jitter; *timeout* (a case overrunning ``deadline_s``) and *permanent*
    errors are not.  A key whose permanent/timeout failure count (across all
    records in the file plus this run) reaches ``quarantine_after`` gets one
    ``status="quarantined"`` record and is skipped by every later resume
    (``None`` disables quarantine)."""
    camp = get_campaign(campaign) if isinstance(campaign, str) else campaign
    cases = shard_cases(camp.cases(fast), *shard)
    ctx = ctx or RunContext()
    exec_fn = executor or run_case

    done: set = set()
    fail_counts: Dict[tuple, int] = {}
    if out_path is not None:
        out_path = pathlib.Path(out_path)
        out_path.parent.mkdir(parents=True, exist_ok=True)
        if resume:
            repair_jsonl_tail(out_path)  # new appends need a fresh line
            prior = load_records(out_path)
            done = terminal_keys(prior)
            for r in prior:
                if r.get("status") == "error":
                    k = (r["case_id"], r.get("rep", 0), r.get("seed", 0))
                    if r.get("error", {}).get("category") != "transient":
                        fail_counts[k] = fail_counts.get(k, 0) + 1
        elif out_path.exists():
            out_path.unlink()

    executed: List[Tuple[str, int]] = []
    failures: List[Tuple[str, int]] = []
    errors: List[dict] = []
    rows: List[dict] = []
    skipped = 0
    retried = n_timeouts = n_quarantined = write_retries = 0
    out_f = open(out_path, "a") if out_path is not None else None

    def emit(record: dict) -> None:
        nonlocal write_retries
        if out_f is not None:
            site = f"append:{out_path.name}"
            write_retries += _durable_append(out_f, json.dumps(record) + "\n",
                                             site)
        if on_record is not None:
            on_record(record)

    try:
        for case in cases:
            for rep in range(case.repeats):
                key = (case.id, rep)  # RunResult bookkeeping for this run
                full_key = (case.id, rep, seed + rep)
                if full_key in done:
                    skipped += 1
                    continue
                record = {
                    "schema_version": SCHEMA_VERSION,
                    "campaign": camp.name,
                    "case_id": case.id,
                    "rep": rep,
                    "seed": seed + rep,
                    "shard": f"{shard[0]}/{shard[1]}",
                    "host": ctx.host,
                    "git": ctx.git,
                    "case": dataclasses.asdict(case),
                }
                if quarantine_after is not None and \
                        fail_counts.get(full_key, 0) >= quarantine_after:
                    record.update(
                        status="quarantined", row=None,
                        error={"type": "Quarantined", "category": "quarantined",
                               "message": f"quarantined after "
                                          f"{fail_counts[full_key]} "
                                          "non-transient failures",
                               "retries": 0},
                        elapsed_s=0.0,
                    )
                    done.add(full_key)
                    n_quarantined += 1
                    emit(record)
                    if progress is not None:
                        progress(f"quar  {case.id}#r{rep} (0.00s)")
                    continue
                if max_cases is not None and len(executed) >= max_cases:
                    raise _MaxCasesReached
                t0 = time.perf_counter()
                attempt = 0
                while True:
                    try:
                        row = _run_attempt(exec_fn, case, ctx, seed + rep,
                                           deadline_s)
                        record.update(status="ok", row=row)
                        if attempt:
                            record["retries"] = attempt
                        rows.append(row)
                        executed.append(key)
                        break
                    except KeyboardInterrupt:
                        raise
                    except Exception as e:  # noqa: BLE001 — per-case isolation
                        category = classify_error(e)
                        if category == "transient" and attempt < max_retries:
                            attempt += 1
                            retried += 1
                            _backoff_sleep(backoff_s, attempt,
                                           f"{case.id}:{seed + rep}")
                            continue
                        record.update(
                            status="error", row=None,
                            error={"type": type(e).__name__, "message": str(e),
                                   "category": category, "retries": attempt,
                                   "traceback": traceback.format_exc(limit=8)},
                        )
                        failures.append(key)
                        errors.append({"case_id": case.id, "rep": rep,
                                       **record["error"]})
                        executed.append(key)
                        if category == "timeout":
                            n_timeouts += 1
                        if category != "transient":
                            fail_counts[full_key] = \
                                fail_counts.get(full_key, 0) + 1
                        break
                record["elapsed_s"] = round(time.perf_counter() - t0, 6)
                emit(record)
                if progress is not None:
                    progress(f"{record['status']:5s} {case.id}#r{rep} "
                             f"({record['elapsed_s']:.2f}s)")
    except _MaxCasesReached:
        pass
    finally:
        if out_f is not None:
            out_f.close()
    return RunResult(camp.name, out_path, executed, skipped, failures, rows,
                     errors, retried=retried, n_timeouts=n_timeouts,
                     n_quarantined=n_quarantined, write_retries=write_retries)


class _MaxCasesReached(Exception):
    pass


def run_campaign_batch(
    campaign: Union[str, Campaign],
    out_path: Union[str, pathlib.Path],
    seeds: Sequence[int],
    fast: bool = False,
    shard: Tuple[int, int] = (0, 1),
    max_cases: Optional[int] = None,
    ctx: Optional[RunContext] = None,
    executor: Optional[Callable[[BenchCase, RunContext, int], dict]] = None,
    progress: Optional[Callable[[str], None]] = None,
    on_record: Optional[Callable[[dict], None]] = None,
    deadline_s: Optional[float] = None,
    max_retries: int = 2,
    backoff_s: float = 0.05,
    quarantine_after: Optional[int] = 3,
) -> List[RunResult]:
    """Run a campaign once per seed in ``seeds`` (a *seed window*), appending
    everything to one JSONL file.

    Resume keys on ``(case_id, rep, seed)``, so a window of fresh seeds grows
    the dataset by ``len(seeds) * n_cases`` rows while re-running the same
    window resumes exactly the missing/failed cases — this is how the
    continuous loop pushes the dataset past the paper's 141 rows toward its
    500-1000 target, one batch per cycle.  One shared :class:`RunContext`
    keeps per-seed test files and dataset manifests cached across the window.

    ``max_cases`` bounds total executions across the whole window (kill
    simulation in tests); the window stops early once it is exhausted.
    """
    ctx = ctx or RunContext()
    results: List[RunResult] = []
    remaining = max_cases
    for s in seeds:
        res = run_campaign(
            campaign, out_path, fast=fast, seed=s, shard=shard, resume=True,
            max_cases=remaining, ctx=ctx, executor=executor, progress=progress,
            on_record=on_record, deadline_s=deadline_s,
            max_retries=max_retries, backoff_s=backoff_s,
            quarantine_after=quarantine_after,
        )
        results.append(res)
        if remaining is not None:
            remaining -= res.n_executed
            if remaining <= 0:
                break
    return results


# ---------------------------------------------------------------- merge

def merge_records(records: Iterable[dict]) -> List[dict]:
    """Deduplicate records by (case_id, rep, seed), keeping the *latest*.

    "Latest" is last-in-input order, so pass files in collection order; within
    one file, appended resume re-runs naturally supersede earlier failures.
    Output preserves first-seen key order (stable across re-merges).
    """
    latest: Dict[tuple, dict] = {}
    for r in records:
        latest[(r.get("case_id"), r.get("rep", 0), r.get("seed", 0))] = r
    return list(latest.values())


# Per-record provenance that varies run to run (wall time, how many transient
# faults a record survived) or with the collection topology (which
# shard/host/process executed the case).  The canonical dataset strips these
# so its bytes depend only on *what was measured*, never on *who measured it*
# or *what faults the run weathered* — the full provenance stays in the
# per-shard files and the fleet/loop state logs.  This is the chaos-
# equivalence invariant: a fault-injected fleet run whose transient failures
# all healed merges to bytes identical to a fault-free run.
CANONICAL_VOLATILE_KEYS = ("elapsed_s", "shard", "host", "git", "collector",
                           "retries")


def case_index(campaign: Union[str, Campaign], fast: bool = False) -> Dict[str, int]:
    """``case_id -> position`` in the campaign's declared case order — the
    sort key that lets :func:`canonical_records` reconstruct single-host
    execution order from arbitrarily sharded collections."""
    camp = get_campaign(campaign) if isinstance(campaign, str) else campaign
    return {c.id: i for i, c in enumerate(camp.cases(fast))}


def canonical_records(
    records: Iterable[dict], index: Dict[str, int]
) -> List[dict]:
    """Topology-independent view of a record set: dedup latest-wins by
    ``(case_id, rep, seed)``, order by ``(seed window, case position, rep)``,
    and strip :data:`CANONICAL_VOLATILE_KEYS`.

    ``seed - rep`` recovers the campaign pass's base seed (rep ``r`` executes
    with ``seed + r``), so the sort key ``(seed - rep, case position, rep)``
    is exactly the order a single uninterrupted host would have executed the
    cases in.  With a deterministic executor this makes the serialized
    dataset **byte-identical no matter how many collectors produced it** —
    the invariant the fleet layer (``repro.service.fleet``) is built on.

    Unlike the positional ``merge_records``, duplicates here resolve
    status-aware: a success is never shadowed by an error record for the same
    key.  Resume semantics only ever re-run keys that never succeeded, so any
    error duplicated against an ``ok`` is by construction stale — but after a
    fleet is re-sharded mid-cycle (``--collectors`` changed under a killed
    coordinator), the stale error can sit in a *later-sorted* shard file than
    the success, and input order alone would pick the wrong record.
    """
    latest: Dict[tuple, dict] = {}
    for r in records:
        key = (r.get("case_id"), r.get("rep", 0), r.get("seed", 0))
        prev = latest.get(key)
        if prev is not None and prev.get("status") == "ok" and r.get("status") != "ok":
            continue  # stale failure never supersedes a success
        latest[key] = r
    merged = list(latest.values())
    merged.sort(key=lambda r: (
        r.get("seed", 0) - r.get("rep", 0),
        index.get(r.get("case_id"), len(index)),
        r.get("rep", 0),
    ))
    return [{k: v for k, v in r.items() if k not in CANONICAL_VOLATILE_KEYS}
            for r in merged]


def merge_files(
    inputs: Sequence[pathlib.Path],
    out_path: pathlib.Path,
    index: Optional[Dict[str, int]] = None,
    counters: Optional[dict] = None,
) -> Tuple[int, List[dict]]:
    """Merge + dedup sharded JSONL result files (multi-host ``--shard h/H``
    runs) into one file.  Returns (n_read, merged_records).

    With ``index`` (from :func:`case_index`) the output is *canonicalized*
    via :func:`canonical_records`: stable order and stable bytes regardless
    of how the inputs were sharded.  Without it, records keep first-seen
    order and full provenance (the standalone ``merge`` CLI behavior).

    Corrupted mid-file lines in the inputs are skipped, never fatal; pass a
    ``counters`` dict to receive their count (``counters["corrupt_lines"]``
    accumulates across inputs)."""
    records: List[dict] = []
    n_corrupt = 0
    for p in inputs:
        recs, nc, _torn = load_records_ex(p)
        records.extend(recs)
        n_corrupt += nc
    if counters is not None:
        counters["corrupt_lines"] = counters.get("corrupt_lines", 0) + n_corrupt
    merged = (canonical_records(records, index) if index is not None
              else merge_records(records))
    out_path = pathlib.Path(out_path)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    tmp = out_path.with_suffix(out_path.suffix + ".tmp")
    with open(tmp, "w") as f:
        for r in merged:
            f.write(json.dumps(r) + "\n")
    tmp.replace(out_path)  # atomic: a crashed merge never truncates results
    return len(records), merged


# ---------------------------------------------------------------- summarize

def _dist(values: List[float]) -> dict:
    a = np.asarray(values, np.float64)
    return {
        "count": int(a.size),
        "mean": float(a.mean()),
        "median": float(np.median(a)),
        "p10": float(np.percentile(a, 10)),
        "p90": float(np.percentile(a, 90)),
        "min": float(a.min()),
        "max": float(a.max()),
    }


def summarize(records: Iterable[dict], corrupt_lines: int = 0) -> dict:
    """Aggregate report: per-(bench_type, backend, format) target-throughput
    distributions plus failure counts per group.

    Records are deduplicated by (case_id, rep, seed) keeping the *last* one,
    so an error record superseded by a successful resume re-run no longer
    counts as a failure.  ``corrupt_lines`` (from :func:`load_records_ex`)
    is carried into the report so corruption is surfaced, not swallowed;
    quarantined keys are counted both in ``n_failed`` and separately."""
    latest: Dict[tuple, dict] = {}
    for r in records:
        latest[(r.get("case_id"), r.get("rep", 0), r.get("seed", 0))] = r
    groups: Dict[tuple, List[float]] = {}
    fails: Dict[tuple, int] = {}
    n_ok = n_err = n_quarantined = n_retried = 0
    for r in latest.values():
        case = r.get("case", {})
        key = (
            case.get("bench_type", "?"),
            case.get("backend", "?"),
            case.get("format") or "-",
        )
        if r.get("status") == "ok" and r.get("row"):
            n_ok += 1
            n_retried += int(r.get("retries", 0))
            groups.setdefault(key, []).append(float(r["row"].get(TARGET_NAME, 0.0)))
        else:
            n_err += 1
            fails[key] = fails.get(key, 0) + 1
            if r.get("status") == "quarantined":
                n_quarantined += 1
    backends: Dict[str, dict] = {}
    for r in latest.values():
        case = r.get("case", {})
        b = str(case.get("backend", "?"))
        agg = backends.setdefault(b, {"rows": 0, "failures": 0,
                                      "quarantined": 0, "retried": 0})
        if r.get("status") == "ok" and r.get("row"):
            agg["rows"] += 1
            agg["retried"] += int(r.get("retries", 0))
        else:
            agg["failures"] += 1
            if r.get("status") == "quarantined":
                agg["quarantined"] += 1
    for agg in backends.values():
        total = agg["rows"] + agg["failures"]
        agg["error_rate"] = round(agg["failures"] / total, 6) if total else 0.0
    return {
        "n_ok": n_ok,
        "n_failed": n_err,
        "n_quarantined": n_quarantined,
        "n_retried": n_retried,
        "corrupt_lines": int(corrupt_lines),
        # per-backend breakdown: makes leave-one-backend-out transfer splits
        # auditable (docs/transfer.md) — corrupt_lines is file-level and
        # cannot be attributed to a backend, so it stays a top-level count
        "backends": {b: backends[b] for b in sorted(backends)},
        "groups": {
            "/".join(k): {
                "target_throughput_mb_s": _dist(v),
                "failures": fails.get(k, 0),
            }
            for k, v in sorted(groups.items())
        },
        "failed_groups": {"/".join(k): n for k, n in sorted(fails.items())
                          if k not in groups},
    }


def format_backends(report: dict) -> str:
    """Per-backend table for ``summarize --by-backend``: one row per storage
    backend with row counts, failures, and error rate, so transfer splits
    (leave-one-backend-out, ``core/transfer.py``) are auditable at a glance.
    ``corrupt_lines`` is a file-level count and is reported in the header."""
    head = f"backends={len(report.get('backends', {}))}"
    if report.get("corrupt_lines"):
        head += f" corrupt_lines={report['corrupt_lines']} (file-level)"
    lines = [head]
    hdr = (f"{'backend':16s} {'rows':>6s} {'failed':>6s} {'quar':>5s} "
           f"{'retried':>7s} {'err_rate':>8s}")
    lines.append(hdr)
    lines.append("-" * len(hdr))
    for name, agg in report.get("backends", {}).items():
        lines.append(
            f"{name:16s} {agg['rows']:>6d} {agg['failures']:>6d} "
            f"{agg['quarantined']:>5d} {agg['retried']:>7d} "
            f"{agg['error_rate']:>8.4f}"
        )
    return "\n".join(lines)


def format_summary(report: dict) -> str:
    head = f"ok={report['n_ok']} failed={report['n_failed']}"
    for key in ("n_quarantined", "n_retried", "corrupt_lines"):
        if report.get(key):
            head += f" {key.removeprefix('n_')}={report[key]}"
    lines = [head]
    hdr = f"{'bench/backend/format':40s} {'n':>4s} {'mean':>10s} {'median':>10s} {'p10':>10s} {'p90':>10s} {'fail':>5s}"
    lines.append(hdr)
    lines.append("-" * len(hdr))
    for name, g in report["groups"].items():
        d = g["target_throughput_mb_s"]
        lines.append(
            f"{name:40s} {d['count']:>4d} {d['mean']:>10.1f} {d['median']:>10.1f} "
            f"{d['p10']:>10.1f} {d['p90']:>10.1f} {g['failures']:>5d}"
        )
    for name, n in report.get("failed_groups", {}).items():
        lines.append(f"{name:40s} {'-':>4s} {'-':>10s} {'-':>10s} {'-':>10s} {'-':>10s} {n:>5d}")
    return "\n".join(lines)


# ---------------------------------------------------------------- CLI

def _parse_shard(s: str) -> Tuple[int, int]:
    try:
        h, n = s.split("/")
        return int(h), int(n)
    except ValueError:
        raise argparse.ArgumentTypeError(f"--shard wants 'h/H', got {s!r}") from None


def _default_out(campaign: str, shard: Tuple[int, int], fast: bool = False) -> pathlib.Path:
    # fast-mode rows measure smaller datasets/files — keep them out of the
    # full campaign's default result file so summaries never mix the two
    suffix = ".fast" if fast else ""
    if shard[1] > 1:
        suffix += f".shard{shard[0]}of{shard[1]}"
    return DEFAULT_OUT_DIR / f"{campaign}{suffix}.jsonl"


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.data.campaign",
        description="List, run, resume, and summarize benchmark campaigns.",
    )
    sub = ap.add_subparsers(dest="cmd", required=True)

    p_list = sub.add_parser("list", help="list registered campaigns and case counts")
    p_list.add_argument("--fast", action="store_true", help="count fast-mode cases")

    for name, hlp in (("run", "run a campaign (resumes by default)"),
                      ("resume", "alias of run: skip completed, re-run failed"),
                      ("smoke", "run the paper + prefetch campaigns fast and check summaries")):
        p = sub.add_parser(name, help=hlp)
        if name != "smoke":
            p.add_argument("--campaign", default="paper_core")
            p.add_argument("--shard", type=_parse_shard, default=(0, 1),
                           metavar="h/H", help="run shard h of H (positional slice)")
            if name == "run":
                p.add_argument("--force", action="store_true",
                               help="discard existing results and start over")
            p.add_argument("--out", type=pathlib.Path, default=None,
                           help=f"JSONL path (default: {DEFAULT_OUT_DIR}/<campaign>.jsonl)")
            p.add_argument("--fast", action="store_true", help="small CI-sized subset")
        else:
            p.add_argument("--out", type=pathlib.Path, default=None,
                           help="directory for per-campaign JSONL files "
                                f"(default: {DEFAULT_OUT_DIR})")
        p.add_argument("--seed", type=int, default=0)

    p_sum = sub.add_parser("summarize", help="aggregate JSONL results")
    p_sum.add_argument("--out", type=pathlib.Path, nargs="+", required=True,
                       help="one or more campaign JSONL files (e.g. per-shard)")
    p_sum.add_argument("--json", action="store_true", help="print JSON, not a table")
    p_sum.add_argument("--by-backend", action="store_true",
                       help="per-backend breakdown (rows, error rates) instead "
                            "of the per-group table")

    p_merge = sub.add_parser(
        "merge",
        help="merge + dedup sharded JSONL results (latest per (case_id, rep, seed))",
    )
    p_merge.add_argument("inputs", type=pathlib.Path, nargs="+",
                         help="shard JSONL files, in collection order")
    p_merge.add_argument("--out", type=pathlib.Path, required=True,
                         help="merged JSONL destination (written atomically)")

    args = ap.parse_args(argv)

    if args.cmd == "list":
        for c in list_campaigns():
            n = len(c.cases(args.fast))
            print(f"{c.name:24s} {n:>5d} cases  {c.description}")
        return 0

    if args.cmd == "merge":
        missing = [p for p in args.inputs if not pathlib.Path(p).exists()]
        if missing:
            print(f"error: no such result file: {', '.join(map(str, missing))}",
                  file=sys.stderr)
            return 2
        n_read, merged = merge_files(args.inputs, args.out)
        print(f"merged {len(args.inputs)} files: {n_read} records -> "
              f"{len(merged)} unique -> {args.out}")
        print(format_summary(summarize(merged)))
        return 0

    if args.cmd == "summarize":
        missing = [p for p in args.out if not pathlib.Path(p).exists()]
        if missing:
            print(f"error: no such result file: {', '.join(map(str, missing))}",
                  file=sys.stderr)
            return 2
        records = []
        total_corrupt = 0
        for p in args.out:
            recs, nc, _torn = load_records_ex(p)
            records.extend(recs)
            total_corrupt += nc
        report = summarize(records, corrupt_lines=total_corrupt)
        if args.json:
            out = report["backends"] if args.by_backend else report
            print(json.dumps(out, indent=2))
        else:
            print(format_backends(report) if args.by_backend
                  else format_summary(report))
        return 0 if report["n_ok"] and not report["n_failed"] else 1

    if args.cmd == "smoke":
        failures = 0
        for name in ("paper_random_access", "paper_pipeline", "paper_concurrent",
                     "prefetch"):
            out = (args.out / f"{name}.jsonl") if args.out else _default_out(name, (0, 1), fast=True)
            res = run_campaign(name, out, fast=True, seed=args.seed,
                               progress=lambda m: print(f"  {m}"))
            report = summarize(load_records(out))
            ok = report["n_ok"] > 0 and not res.failures
            print(f"{name}: executed={res.n_executed} skipped={res.skipped} "
                  f"failed={len(res.failures)} summary_groups={len(report['groups'])}")
            if not ok:
                failures += 1
        print("smoke: " + ("PASS" if not failures else "FAIL"))
        return 1 if failures else 0

    # run / resume
    out = args.out or _default_out(args.campaign, args.shard, fast=args.fast)
    try:
        res = run_campaign(
            args.campaign, out, fast=args.fast, seed=args.seed, shard=args.shard,
            resume=not getattr(args, "force", False),  # --force exists on run only
            progress=lambda m: print(m),
        )
    except (KeyError, ValueError) as e:
        msg = e.args[0] if e.args else e
        print(f"error: {msg}", file=sys.stderr)
        return 2
    print(f"{res.campaign}: executed={res.n_executed} skipped={res.skipped} "
          f"failed={len(res.failures)} -> {out}")
    print(format_summary(summarize(load_records(out))))
    return 1 if res.failures else 0


if __name__ == "__main__":
    sys.exit(main())
