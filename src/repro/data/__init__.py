"""repro.data — storage backends, record formats, benchmarks, and the tunable
training input pipeline the paper's predictor optimizes."""

from .bench_io import (  # noqa: F401
    bench_concurrent_read,
    bench_random_read,
    bench_sequential_read,
    make_test_file,
)
from .dataset import collect_observations, observations_to_columns  # noqa: F401
from .formats import FORMATS, DatasetReader, open_dataset, write_dataset  # noqa: F401
from .pipeline import (  # noqa: F401
    DataPipeline,
    ImageRecordCodec,
    PipelineConfig,
    SyntheticTokenSource,
    TabularRecordCodec,
    TokenRecordCodec,
)
from .storage import BACKENDS, StorageBackend, get_backend  # noqa: F401
from .telemetry import StepTelemetry  # noqa: F401
