"""repro.data — storage backends, record formats, the declarative benchmark
campaign subsystem (``registry``/``campaign``), and the tunable training
input pipeline the paper's predictor optimizes."""

from .bench_io import (  # noqa: F401
    bench_concurrent_read,
    bench_random_read,
    bench_sequential_read,
    make_test_file,
)
from .campaign import (  # noqa: F401
    RunContext,
    RunResult,
    format_summary,
    load_records,
    merge_files,
    merge_records,
    run_campaign,
    run_campaign_batch,
    run_case,
    summarize,
)
from .dataset import (  # noqa: F401
    collect_observations,
    observations_from_jsonl,
    observations_to_columns,
)
from .formats import FORMATS, DatasetReader, open_dataset, write_dataset  # noqa: F401
from .pipeline import (  # noqa: F401
    DataPipeline,
    ImageRecordCodec,
    PipelineConfig,
    SyntheticTokenSource,
    TabularRecordCodec,
    TokenRecordCodec,
)
from .registry import (  # noqa: F401
    BenchCase,
    Campaign,
    CAMPAIGNS,
    get_campaign,
    list_campaigns,
    matrix_cases,
    register_campaign,
)
from .storage import BACKENDS, StorageBackend, get_backend  # noqa: F401
from .telemetry import StepTelemetry  # noqa: F401
