import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST run before any jax import (jax locks the device count at first init).
# Everything below may import jax.

import argparse  # noqa: E402
import json  # noqa: E402
import pathlib  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402


def _early_device_override(argv):
    for i, a in enumerate(argv):
        if a == "--devices" and i + 1 < len(argv):
            os.environ["XLA_FLAGS"] = (
                f"--xla_force_host_platform_device_count={argv[i + 1]}"
            )


_early_device_override(sys.argv)

import jax  # noqa: E402
import numpy as np  # noqa: E402

from ..configs import ARCHS, SHAPES, get_config, input_specs, shape_supported  # noqa: E402
from ..models.config import ModelConfig  # noqa: E402
from ..train.step import make_prefill_bundle, make_serve_bundle, make_train_bundle  # noqa: E402
from .analysis import parse_collectives, roofline_terms, summarize_collectives  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402

DEFAULT_OUT = pathlib.Path("/root/repo/results/dryrun")


def model_flops_for(cfg: ModelConfig, shape) -> float:
    """MODEL_FLOPS: 6·N_active·D for train (fwd+bwd), 2·N_active·D for
    inference-like steps; D = processed tokens."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * (
            shape.seq_len if cfg.family != "encdec" else shape.seq_len + cfg.dec_len
        )
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def build_bundle(cfg, shape, mesh, multi_pod, rules=None):
    if shape.kind == "train":
        return make_train_bundle(cfg, shape, mesh=mesh, multi_pod=multi_pod, rules=rules)
    if shape.kind == "prefill":
        return make_prefill_bundle(cfg, shape, mesh=mesh, multi_pod=multi_pod, rules=rules)
    return make_serve_bundle(cfg, shape, mesh=mesh, multi_pod=multi_pod, rules=rules)


# --------------------------------------------------------------------------
# Loop-aware cost extraction.
#
# XLA's cost_analysis counts while-loop bodies ONCE (verified by calibration:
# scan(8 matmuls) reports 1 matmul of flops) and reports PER-DEVICE numbers
# for SPMD executables. We therefore compile tiny fully-unrolled layer-count
# variants (scans replaced by unrolled bodies) and extrapolate linearly:
# cost(L) = base + L*delta. The full scanned compile remains the deliverable
# artifact (memory analysis, compile proof, collective schedule).
# --------------------------------------------------------------------------
def _measure_cfg(cfg: ModelConfig, shape, **layer_kw) -> ModelConfig:
    S = shape.seq_len
    kw = dict(layer_kw, unroll_scans=True)
    kw["q_chunk"] = max(cfg.q_chunk, min(S, 512), S // 8)
    kw["kv_chunk"] = max(cfg.kv_chunk, min(S, 1024), S // 8)
    if shape.kind == "train":
        tok_per_seq = (
            cfg.dec_len if cfg.family == "encdec"
            else (S - cfg.prefix_len if cfg.family == "vlm" else S)
        )
        T = shape.global_batch * tok_per_seq
        kw["xent_chunk"] = max(T // 8, min(T, 2048))
    kw["ssm_scan_chunk"] = max(cfg.ssm_scan_chunk, S // 8, 64)
    return cfg.replace(**kw)


def _points_and_weights(cfg: ModelConfig, kind: str):
    """[(layer_kwargs, weight)] with sum_i w_i*cost_i = full-model cost."""
    if cfg.family == "encdec" and kind != "decode":
        Le, Ld = cfg.n_enc_layers, cfg.n_layers
        return [
            ({"n_enc_layers": 1, "n_layers": 1}, 1.0 - (Le - 1) - (Ld - 1)),
            ({"n_enc_layers": 2, "n_layers": 1}, float(Le - 1)),
            ({"n_enc_layers": 1, "n_layers": 2}, float(Ld - 1)),
        ]
    if cfg.local_global_period > 0:
        p = cfg.local_global_period
        n_super = cfg.n_layers // p
        tail = cfg.n_layers - n_super * p
        pts = [
            ({"n_layers": p}, 1.0 - (n_super - 1) - (1.0 if tail else 0.0)),
            ({"n_layers": 2 * p}, float(n_super - 1)),
        ]
        if tail:
            pts.append(({"n_layers": p + tail}, 1.0))
        return pts
    if cfg.family == "hybrid":
        n_super = cfg.n_layers // 8
        return [
            ({"n_layers": 8}, 1.0 - (n_super - 1)),
            ({"n_layers": 16}, float(n_super - 1)),
        ]
    L = cfg.n_layers
    return [({"n_layers": 1}, 2.0 - L), ({"n_layers": 2}, float(L - 1))]


def _measure_point(cfg_v, shape, mesh, multi_pod, rules):
    from jax.sharding import NamedSharding, PartitionSpec as _P

    bundle = build_bundle(cfg_v, shape, mesh, multi_pod, rules)

    def _named(tree):
        return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                            is_leaf=lambda x: isinstance(x, _P))

    with mesh:
        compiled = (
            jax.jit(bundle.fn, in_shardings=_named(bundle.in_shardings),
                    out_shardings=_named(bundle.out_shardings))
            .lower(*bundle.abstract_inputs)
            .compile()
        )
    cost = compiled.cost_analysis()
    colls = parse_collectives(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "wire": float(sum(o.wire_bytes for o in colls)),
        "collectives": summarize_collectives(colls),
    }


def extrapolate_cost(cfg, shape, mesh, multi_pod, rules=None):
    pts = _points_and_weights(cfg, shape.kind)
    total = {"flops": 0.0, "bytes": 0.0, "wire": 0.0}
    coll_total: dict = {}
    for layer_kw, w in pts:
        cfg_v = _measure_cfg(cfg, shape, **layer_kw)
        m = _measure_point(cfg_v, shape, mesh, multi_pod, rules)
        for k in total:
            total[k] += w * m[k]
        for op, d in m["collectives"].items():
            acc = coll_total.setdefault(op, {"count": 0.0, "wire_bytes": 0.0})
            acc["count"] += w * d["count"]
            acc["wire_bytes"] += w * d["wire_bytes"]
    total = {k: max(v, 0.0) for k, v in total.items()}
    total["collectives"] = {
        op: {k2: max(v2, 0.0) for k2, v2 in d.items()} for op, d in coll_total.items()
    }
    return total


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: pathlib.Path,
             verbose: bool = True, rules_override=None, tag: str = "baseline",
             cfg_override=None):
    cfg = cfg_override if cfg_override is not None else get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_supported(cfg, shape_name)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": "2x16x16" if multi_pod else "16x16",
        "tag": tag, "status": "skipped", "skip_reason": why,
    }
    if not ok:
        out_dir.mkdir(parents=True, exist_ok=True)
        fname = f"{arch}__{shape_name}__{rec['mesh'].replace('x', '_')}__{tag}.json"
        (out_dir / fname).write_text(json.dumps(rec, indent=2))
        if verbose:
            print(f"[{rec['mesh']}] {arch} x {shape_name}: SKIP ({why})")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    try:
        bundle = build_bundle(cfg, shape, mesh, multi_pod, rules_override)

        from jax.sharding import NamedSharding, PartitionSpec as _P

        def _named(tree):
            return jax.tree.map(
                lambda s: NamedSharding(mesh, s), tree,
                is_leaf=lambda x: isinstance(x, _P),
            )

        with mesh:
            jitted = jax.jit(
                bundle.fn,
                in_shardings=_named(bundle.in_shardings),
                out_shardings=_named(bundle.out_shardings),
                donate_argnums=bundle.donate_argnums,
            )
            lowered = jitted.lower(*bundle.abstract_inputs)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        colls = parse_collectives(hlo)
        csum = summarize_collectives(colls)
        wire_raw = sum(o.wire_bytes for o in colls)

        # loop-aware extrapolated costs (see module docstring)
        rules = getattr(bundle.ctx, "rules", None)
        extr = extrapolate_cost(cfg, shape, mesh, multi_pod, rules)
        mf = model_flops_for(cfg, shape)
        terms = roofline_terms(
            extr["flops"], extr["bytes"], extr["wire"], model_flops=mf, n_chips=n_chips
        )

        rec.update(
            status="ok",
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            memory=dict(
                argument_bytes=getattr(mem, "argument_size_in_bytes", None),
                output_bytes=getattr(mem, "output_size_in_bytes", None),
                temp_bytes=getattr(mem, "temp_size_in_bytes", None),
                peak_bytes=(
                    getattr(mem, "argument_size_in_bytes", 0) or 0
                ) + (getattr(mem, "temp_size_in_bytes", 0) or 0),
            ),
            raw_cost={"flops_loopbody_once": float(cost.get("flops", 0.0)),
                      "bytes_loopbody_once": float(cost.get("bytes accessed", 0.0)),
                      "wire_loopbody_once": wire_raw},
            cost={"flops": extr["flops"], "bytes_accessed": extr["bytes"],
                  "wire_bytes": extr["wire"]},
            collectives_schedule_sample=csum,
            collectives=extr["collectives"],
            roofline=terms,
        )
        if verbose:
            print(f"[{rec['mesh']}] {arch} x {shape_name} ({tag}): OK "
                  f"compile={t_compile:.0f}s flops={extr['flops']:.3e} "
                  f"bytes={extr['bytes']:.3e} wire={extr['wire']:.3e} "
                  f"bottleneck={terms['bottleneck']}"
                  f" roofline_frac={terms.get('roofline_fraction', 0):.3f}")
    except Exception as e:  # noqa: BLE001
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        if verbose:
            print(f"[{rec['mesh']}] {arch} x {shape_name}: FAIL {type(e).__name__}: {e}")

    out_dir.mkdir(parents=True, exist_ok=True)
    fname = f"{arch}__{shape_name}__{rec['mesh'].replace('x', '_')}__{tag}.json"
    (out_dir / fname).write_text(json.dumps(rec, indent=2, default=str))
    return rec


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run: lower+compile every (arch x shape x mesh)")
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", help="shape name or 'all'")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--devices", default=None, help="(handled pre-import)")
    ap.add_argument("--out", default=str(DEFAULT_OUT))
    ap.add_argument("--tag", default="baseline")
    args = ap.parse_args()

    archs = list(ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    out_dir = pathlib.Path(args.out)

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, mp, out_dir, tag=args.tag)
                if rec["status"] == "error":
                    n_fail += 1
    print(f"done; failures: {n_fail}")
    sys.exit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
