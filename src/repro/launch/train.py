"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Local mode (default) runs a reduced config end-to-end on this host with the
real data pipeline + autotuner + checkpointing. ``--dry-mesh`` instead lowers
the full-size pjit train step on the production mesh (see dryrun.py for the
batch sweep version).
"""

from __future__ import annotations

import argparse
import pathlib

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--backend", default="tmpfs", choices=["tmpfs", "disk"])
    ap.add_argument("--format", default="packed")
    ap.add_argument("--num-workers", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--no-autotune", action="store_true")
    ap.add_argument("--n-records", type=int, default=2048)
    args = ap.parse_args()

    from ..configs import get_config, reduced
    from ..data import (
        BACKENDS, DataPipeline, PipelineConfig, TokenRecordCodec, write_dataset,
        open_dataset,
    )
    from ..train.trainer import Trainer, TrainerConfig

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    assert cfg.family in ("dense", "moe", "ssm", "hybrid"), (
        "the token-LM launcher covers LM families; whisper/vlm use examples/")

    # build a real on-disk dataset
    seq = args.seq_len + 1  # +1 for the shifted labels
    codec = TokenRecordCodec(seq)
    rng = np.random.default_rng(0)
    records = [
        codec.encode(rng.integers(0, cfg.vocab_size, size=seq, dtype=np.int32))
        for _ in range(args.n_records)
    ]
    backend = BACKENDS[args.backend]
    manifest = write_dataset(backend, f"train_{args.arch}", records, args.format)
    reader = open_dataset(backend, manifest)
    pipe = DataPipeline.from_reader(
        reader, seq,
        PipelineConfig(batch_size=args.batch_size, num_workers=args.num_workers),
    )

    tcfg = TrainerConfig(
        num_steps=args.steps,
        ckpt_dir=args.ckpt_dir,
        autotune=not args.no_autotune,
    )
    trainer = Trainer(cfg, pipe, tcfg)
    out = trainer.run()
    h = out["history"]
    print(f"[train] done at step {out['final_step']}; "
          f"loss {h[0]:.4f} -> {h[-1]:.4f} over {len(h)} steps")
    pipe.close()
    reader.close()


if __name__ == "__main__":
    main()
