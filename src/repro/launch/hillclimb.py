import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# §Perf hillclimbing driver (see EXPERIMENTS.md §Perf for the log).
# Three targets chosen from the baseline roofline table:
#   T1 falcon-mamba-7b  prefill_32k 16x16   — worst roofline fraction (memory)
#   T2 codeqwen1.5-7b   train_4k    2x16x16 — most collective-bound
#   T3 granite-moe-1b   train_4k    16x16   — paper-representative (MoE+EP)
# Each iteration: hypothesis -> change (cfg/rules) -> re-lower -> terms.

import argparse  # noqa: E402
import json  # noqa: E402
import pathlib  # noqa: E402
import sys  # noqa: E402

from ..configs import get_config  # noqa: E402
from ..parallel.rules import make_rules  # noqa: E402
from .dryrun import run_cell  # noqa: E402

OUT = pathlib.Path("/root/repo/results/hillclimb")


def _iters_t1():
    cfg = get_config("falcon-mamba-7b")
    return "falcon-mamba-7b", "prefill_32k", False, [
        ("it1_chunk_local_gates", cfg.replace(ssm_chunk_local=True), None),
        ("it2_chunk256", cfg.replace(ssm_chunk_local=True, ssm_scan_chunk=256), None),
        ("it3_chunk1024", cfg.replace(ssm_chunk_local=True, ssm_scan_chunk=1024), None),
        ("it4_chunk4096", cfg.replace(ssm_chunk_local=True, ssm_scan_chunk=4096), None),
    ]


def _iters_t2():
    cfg = get_config("codeqwen1.5-7b")
    rules_sp = make_rules(cfg, "train", 256, multi_pod=True).replace(act_seq="model")
    return "codeqwen1.5-7b", "train_4k", True, [
        ("it1_seq_parallel", cfg, rules_sp),
        ("it2_sp_qchunk1024", cfg.replace(q_chunk=1024), rules_sp),
        ("it3_sp_qchunk2048", cfg.replace(q_chunk=2048), rules_sp),
    ]


def _iters_t3():
    cfg = get_config("granite-moe-1b-a400m")
    rules = make_rules(cfg, "train", 256, multi_pod=False)
    rules_repl = rules.replace(expert=None)
    return "granite-moe-1b-a400m", "train_4k", False, [
        ("it1_local_dispatch", cfg.replace(moe_local_dispatch=True), None),
        ("it2_replicate_experts",
         cfg.replace(moe_local_dispatch=True, moe_replicate_experts=True), rules_repl),
        ("it3_capacity1.0",
         cfg.replace(moe_local_dispatch=True, moe_replicate_experts=True,
                     capacity_factor=1.0), rules_repl),
    ]


TARGETS = {"t1": _iters_t1, "t2": _iters_t2, "t3": _iters_t3}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--target", choices=[*TARGETS, "all"], default="all")
    ap.add_argument("--iter", default=None, help="run a single iteration tag")
    args = ap.parse_args()

    targets = list(TARGETS) if args.target == "all" else [args.target]
    for t in targets:
        arch, shape, multi_pod, iters = TARGETS[t]()
        for tag, cfg_v, rules_v in iters:
            if args.iter and args.iter != tag:
                continue
            rec = run_cell(arch, shape, multi_pod, OUT, tag=f"{t}_{tag}",
                           cfg_override=cfg_v, rules_override=rules_v)
            if rec["status"] != "ok":
                print("FAILED:", rec.get("error"))


if __name__ == "__main__":
    main()
