"""Serving launcher: ``python -m repro.launch.serve --arch <id>`` runs the
batched engine on a reduced config (CPU demo); the full-size decode path is
exercised on the production mesh by ``repro.launch.dryrun`` (decode cells)."""

from __future__ import annotations

import argparse

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="codeqwen1.5-7b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    args = ap.parse_args()

    from ..configs import get_config, reduced
    from ..models import get_api
    from ..parallel.spec import init_params
    from ..serve import Request, ServeEngine

    cfg = reduced(get_config(args.arch))
    api = get_api(cfg)
    params = init_params(api.param_specs(cfg), jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, max_len=args.max_len, slots=args.slots)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(1, cfg.vocab_size, 4 + i % 6).astype(np.int32),
                    max_tokens=args.max_tokens) for i in range(args.requests)]
    import time
    t0 = time.perf_counter()
    done = engine.run(reqs)
    dt = time.perf_counter() - t0
    toks = sum(len(r.tokens) for r in done)
    print(f"served {len(done)} requests, {toks} tokens in {dt:.1f}s "
          f"({toks / dt:.1f} tok/s, {cfg.name} reduced)")


if __name__ == "__main__":
    main()
