"""Production mesh construction.

Defined as a FUNCTION so importing this module never touches jax device
state. Single pod: 16x16 = 256 chips ("data", "model"). Multi-pod: 2 pods x
256 = 512 chips with a leading "pod" axis carrying only data-parallel
gradient traffic (matching slow inter-pod links).
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_test_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 4, pod: int = 0):
    """Small mesh for CI-scale sharding tests (requires
    XLA_FLAGS=--xla_force_host_platform_device_count >= data*model*max(pod,1))."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
