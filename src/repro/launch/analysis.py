"""Compiled-artifact analysis: collective-byte extraction from HLO text and
roofline-term computation. Pure text/number processing — safe to import
anywhere (no jax device-state side effects).

Hardware model (TPU v5e-class, per assignment):
  peak bf16 compute: 197 TFLOP/s per chip
  HBM bandwidth:     819 GB/s per chip
  ICI link:          ~50 GB/s per link
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

__all__ = ["HW", "CollectiveOp", "parse_collectives", "roofline_terms", "summarize_collectives"]

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

HW = {"peak_flops": PEAK_FLOPS, "hbm_bw": HBM_BW, "link_bw": LINK_BW}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?P<outs>\(?[a-z0-9]+\[[0-9,]*\][^=]*?)\s*"
    r"(?P<op>all-reduce-start|all-reduce|all-gather-start|all-gather|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


@dataclasses.dataclass
class CollectiveOp:
    op: str
    out_bytes: int
    group_size: int
    wire_bytes: float  # estimated bytes on the wire per participating device
    line: str = ""


def _line_group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def _shapes_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _wire_bytes(op: str, out_bytes: int, g: int) -> float:
    """Ring-algorithm wire-byte estimates per device."""
    if g <= 1:
        return 0.0
    frac = (g - 1) / g
    if op.startswith("all-reduce"):
        return 2.0 * out_bytes * frac
    if op.startswith("all-gather"):
        return out_bytes * frac  # out is the gathered size
    if op == "reduce-scatter":
        return out_bytes * (g - 1)  # out is the per-shard size
    if op == "all-to-all":
        return out_bytes * frac
    if op.startswith("collective-permute"):
        return float(out_bytes)
    return float(out_bytes)


def parse_collectives(hlo_text: str, default_group: int = 1) -> List[CollectiveOp]:
    ops: List[CollectiveOp] = []
    for line in hlo_text.splitlines():
        if "replica_groups" not in line and "all-" not in line and "collective-permute" not in line:
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op").replace("-start", "")
        out_bytes = _shapes_bytes(m.group("outs"))
        g = _line_group_size(line, default_group)
        ops.append(CollectiveOp(op, out_bytes, g, _wire_bytes(op, out_bytes, g),
                                line.strip()[:160]))
    return ops


def summarize_collectives(ops: List[CollectiveOp]) -> Dict[str, Dict[str, float]]:
    out: Dict[str, Dict[str, float]] = {}
    for o in ops:
        d = out.setdefault(o.op, {"count": 0, "out_bytes": 0.0, "wire_bytes": 0.0})
        d["count"] += 1
        d["out_bytes"] += o.out_bytes
        d["wire_bytes"] += o.wire_bytes
    return out


def roofline_terms(
    per_device_flops: float,
    per_device_bytes: float,
    per_device_wire_bytes: float,
    model_flops: Optional[float] = None,
    n_chips: int = 256,
) -> Dict[str, float]:
    """All inputs are per-device quantities from the SPMD executable."""
    t_compute = per_device_flops / PEAK_FLOPS
    t_memory = per_device_bytes / HBM_BW
    t_coll = per_device_wire_bytes / LINK_BW
    dom = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_coll),
        key=lambda kv: kv[1],
    )[0]
    out = {
        "compute_s": t_compute,
        "memory_s": t_memory,
        "collective_s": t_coll,
        "bottleneck": dom,
        "per_device_flops": per_device_flops,
        "per_device_bytes": per_device_bytes,
        "per_device_wire_bytes": per_device_wire_bytes,
        "n_chips": n_chips,
    }
    if model_flops is not None:
        hlo_global = per_device_flops * n_chips
        out["model_flops"] = model_flops
        out["useful_flops_ratio"] = model_flops / hlo_global if hlo_global else 0.0
        # roofline fraction: useful work / (time-bound * peak)
        t_bound = max(t_compute, t_memory, t_coll)
        out["roofline_fraction"] = (
            (model_flops / n_chips / PEAK_FLOPS) / t_bound if t_bound > 0 else 0.0
        )
    return out
