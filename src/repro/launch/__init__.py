"""repro.launch — mesh construction, dry-run, train/serve entry points.

NOTE: do not import .dryrun from here — it sets XLA_FLAGS at import time.
"""

from .mesh import make_production_mesh, make_test_mesh  # noqa: F401
