"""Decoder-only transformer family: dense (granite-20b, deepseek, codeqwen),
MoE (granite-moe), local/global (gemma3), prefix-LM VLM (paligemma).

Functional design: ``param_specs(cfg)`` declares the pytree of ParamSpec;
``loss_fn`` / ``prefill`` / ``decode_step`` are pure functions lowered under
pjit. Layers are stacked on a leading axis and executed with ``lax.scan``
(small HLO, fast compile at 34..62 layers). gemma3's 5:1 local/global
pattern scans super-blocks of 6; the remainder tail is a second scan.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..parallel.spec import ParamSpec, Rules, logical_constraint as lc
from .common import (
    attention_decode,
    attention_heads_tp,
    attention_seq_tp,
    chunked_cross_entropy,
    ffn,
    moe_combine,
    moe_dispatch,
    moe_expert_compute,
    rms_norm,
    rope,
)
from .config import ModelConfig

# shard_map moved out of jax.experimental, and its replication-check kwarg
# was renamed check_rep -> check_vma, on independent version boundaries;
# resolve both from what this jax actually exposes.
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

import inspect as _inspect

_SHMAP_KW = {
    ("check_vma" if "check_vma" in _inspect.signature(_shard_map).parameters
     else "check_rep"): False
}


# --------------------------------------------------------------------------
# Shard context
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShardCtx:
    mesh: Optional[Any] = None  # jax.sharding.Mesh
    rules: Optional[Rules] = None
    dp_axes: Tuple[str, ...] = ("data",)
    tp_axis: str = "model"

    @property
    def active(self) -> bool:
        return self.mesh is not None


LOCAL_CTX = ShardCtx()


# --------------------------------------------------------------------------
# Param specs
# --------------------------------------------------------------------------
def _attn_specs(cfg: ModelConfig, L: int) -> Dict[str, ParamSpec]:
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return {
        "wq": ParamSpec((L, D, H, hd), ("layers", "embed", "heads", None), cfg.dtype),
        "wk": ParamSpec((L, D, KV, hd), ("layers", "embed", "kv_heads", None), cfg.dtype),
        "wv": ParamSpec((L, D, KV, hd), ("layers", "embed", "kv_heads", None), cfg.dtype),
        "wo": ParamSpec((L, H, hd, D), ("layers", "heads", None, "embed"), cfg.dtype),
    }


def _ffn_specs(cfg: ModelConfig, L: int) -> Dict[str, ParamSpec]:
    D, F = cfg.d_model, cfg.d_ff
    s = {
        "w_in": ParamSpec((L, D, F), ("layers", "embed", "mlp"), cfg.dtype),
        "w_out": ParamSpec((L, F, D), ("layers", "mlp", "embed"), cfg.dtype),
    }
    if cfg.gated_mlp:
        s["w_gate"] = ParamSpec((L, D, F), ("layers", "embed", "mlp"), cfg.dtype)
    return s


def _moe_specs(cfg: ModelConfig, L: int) -> Dict[str, ParamSpec]:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts_padded
    s = {
        "router": ParamSpec((L, D, cfg.n_experts), ("layers", "embed", None), jnp.float32),
        "w_in": ParamSpec((L, E, D, F), ("layers", "expert", "embed", None), cfg.dtype),
        "w_out": ParamSpec((L, E, F, D), ("layers", "expert", None, "embed"), cfg.dtype),
    }
    if cfg.gated_mlp:
        s["w_gate"] = ParamSpec((L, E, D, F), ("layers", "expert", "embed", None), cfg.dtype)
    return s


def _block_specs(cfg: ModelConfig, L: int, moe: bool) -> Dict[str, Any]:
    D = cfg.d_model
    s: Dict[str, Any] = {
        "ln1": ParamSpec((L, D), ("layers", "embed"), jnp.float32, init="zeros" if cfg.rms_plus_one else "ones"),
        "ln2": ParamSpec((L, D), ("layers", "embed"), jnp.float32, init="zeros" if cfg.rms_plus_one else "ones"),
        "attn": _attn_specs(cfg, L),
    }
    s["moe" if moe else "ffn"] = _moe_specs(cfg, L) if moe else _ffn_specs(cfg, L)
    return s


def param_specs(cfg: ModelConfig) -> Dict[str, Any]:
    D, Vp = cfg.d_model, cfg.vocab_padded
    specs: Dict[str, Any] = {
        "embed": ParamSpec((Vp, D), ("vocab", "embed"), cfg.dtype, scale=1.0),
        "final_norm": ParamSpec((D,), ("embed",), jnp.float32, init="zeros" if cfg.rms_plus_one else "ones"),
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = ParamSpec((D, Vp), ("embed", "vocab"), cfg.dtype)

    if cfg.local_global_period > 0:
        # gemma3: scan super-blocks of (period) layers; remainder tail of local
        period = cfg.local_global_period
        n_super = cfg.n_layers // period
        tail = cfg.n_layers - n_super * period
        specs["blocks"] = {
            f"pos{j}": _block_specs_super(cfg, n_super) for j in range(period)
        }
        if tail:
            specs["tail"] = _block_specs_super(cfg, tail)
    else:
        assert cfg.n_experts == 0 or cfg.moe_period == 1, "use jamba.py for interleaved MoE"
        moe_all = cfg.n_experts > 0
        specs["blocks"] = _block_specs(cfg, cfg.n_layers, moe=moe_all)
    return specs


def _block_specs_super(cfg: ModelConfig, L: int) -> Dict[str, Any]:
    return _block_specs(cfg, L, moe=False)


# --------------------------------------------------------------------------
# Layer application
# --------------------------------------------------------------------------
def _project_qkv(cfg: ModelConfig, lp, x, positions, theta, ctx: ShardCtx):
    q = jnp.einsum("bsd,dhk->bshk", x, lp["attn"]["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, lp["attn"]["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, lp["attn"]["wv"])
    q = rope(q, positions, theta)
    k = rope(k, positions, theta)
    if cfg.attn_mode == "seq_tp":
        # kv must be full-sequence (replicated) for the kv-chunk scan
        k = lc(k, ("batch", None, "kv_heads", None), ctx.rules)
        v = lc(v, ("batch", None, "kv_heads", None), ctx.rules)
    else:
        k = lc(k, ("batch", None, "kv_heads", None), ctx.rules)
        v = lc(v, ("batch", None, "kv_heads", None), ctx.rules)
    return q, k, v


def _attention_block(cfg: ModelConfig, lp, x, *, layer_global: bool,
                     prefix: Optional[int], ctx: ShardCtx, q_offset: int = 0):
    B, S, D = x.shape
    window = None if layer_global else cfg.window
    theta = cfg.rope_theta_global if (layer_global and cfg.local_global_period) else cfg.rope_theta
    positions = q_offset + jnp.arange(S, dtype=jnp.int32)
    h = rms_norm(x, lp["ln1"], plus_one=cfg.rms_plus_one)
    q, k, v = _project_qkv(cfg, lp, h, positions, theta, ctx)
    kw = dict(causal=True, window=window, prefix=prefix, q_offset=q_offset,
              rules=ctx.rules, scale=cfg.attn_logit_scale,
              unroll=cfg.unroll_scans, probs_bf16=cfg.attn_probs_bf16)
    if cfg.attn_mode == "seq_tp":
        o = attention_seq_tp(q, k, v, kv_chunk=cfg.kv_chunk, **kw)
    else:
        o = attention_heads_tp(q, k, v, q_chunk=cfg.q_chunk, **kw)
    o = jnp.einsum("bshk,hkd->bsd", o, lp["attn"]["wo"])
    return x + lc(o, ("batch", "act_seq", "embed"), ctx.rules)


def _moe_block_fn(cfg: ModelConfig, ctx: ShardCtx):
    """Returns a (possibly shard_mapped) MoE FFN: (x[B,S,D], moe_params)->y.

    Expert parallelism: activations are replicated over the TP axis between
    layers (Megatron convention), so each TP shard dispatches the *same*
    tokens, computes only its expert slice, and a single psum over the TP
    axis combines — one [T, D] all-reduce per MoE layer, no all-to-all.
    """
    E = cfg.n_experts_padded
    cf = cfg.capacity_factor

    def compute(x, router, w_in, w_gate, w_out, ep_rank, ep_size):
        B, S, D = x.shape
        x2d = x.reshape(B * S, D)
        e_loc = E // ep_size
        if cfg.moe_local_dispatch and ep_size > 1:
            # §Perf lever: only materialize the local expert range's buffer
            xe, meta, C = moe_dispatch(
                x2d, router, n_experts=E, top_k=cfg.top_k, capacity_factor=cf,
                renormalize=cfg.router_renormalize,
                expert_lo=ep_rank * e_loc, n_local=e_loc,
            )
            out_e = moe_expert_compute(xe, w_in, w_gate, w_out, cfg.act)
            y = moe_combine(out_e, meta, B * S, D, e_loc, C, x.dtype)
            return y.reshape(B, S, D)
        xe_all, meta, C = moe_dispatch(
            x2d, router, n_experts=E, top_k=cfg.top_k, capacity_factor=cf,
            renormalize=cfg.router_renormalize,
        )
        xe = jax.lax.dynamic_slice_in_dim(xe_all, ep_rank * e_loc, e_loc, axis=0)
        out_e = moe_expert_compute(xe, w_in, w_gate, w_out, cfg.act)
        # place local experts' outputs back into the full [E, C, D] frame
        out_all = jnp.zeros((E, C, D), out_e.dtype)
        out_all = jax.lax.dynamic_update_slice_in_dim(out_all, out_e, ep_rank * e_loc, axis=0)
        y = moe_combine(out_all, meta, B * S, D, E, C, x.dtype)
        return y.reshape(B, S, D)

    if not ctx.active:
        return lambda x, mp: compute(
            x, mp["router"], mp["w_in"], mp.get("w_gate"), mp["w_out"], 0, 1
        )

    mesh, tp = ctx.mesh, ctx.tp_axis
    ep_size = 1 if cfg.moe_replicate_experts else int(mesh.shape[tp])

    def shmap_fn(x, router, w_in, w_gate, w_out):
        if cfg.moe_replicate_experts:
            # §Perf lever: experts replicated -> no EP psum at all
            return compute(x, router, w_in, w_gate, w_out, 0, 1)
        ep_rank = jax.lax.axis_index(tp)
        y = compute(x, router, w_in, w_gate, w_out, ep_rank, ep_size)
        return jax.lax.psum(y, tp)

    def run(x, mp):
        # tokens shard over DP axes only when the batch divides; tiny decode
        # batches fall back to replicated tokens (every shard dispatches the
        # same tokens; expert compute stays sharded; psum still combines).
        n_dp = 1
        for a in ctx.dp_axes:
            n_dp *= int(mesh.shape[a])
        if x.shape[0] % n_dp == 0:
            dp_spec = ctx.dp_axes if len(ctx.dp_axes) > 1 else ctx.dp_axes[0]
        else:
            dp_spec = None
        x_spec = P(dp_spec, None, None)
        w_spec = P(None, None, None) if cfg.moe_replicate_experts else P(tp, None, None)
        in_specs = [x_spec, P(None, None), w_spec]
        if cfg.gated_mlp:
            in_specs.append(w_spec)
        in_specs.append(w_spec)

        args = [x, mp["router"], mp["w_in"]]
        if cfg.gated_mlp:
            args.append(mp["w_gate"])
        args.append(mp["w_out"])
        body = shmap_fn if cfg.gated_mlp else (
            lambda x, r, wi, wo: shmap_fn(x, r, wi, None, wo)
        )
        fn = _shard_map(
            body, mesh=mesh, in_specs=tuple(in_specs),
            out_specs=x_spec, **_SHMAP_KW,
        )
        return fn(*args)

    return run


def _ffn_or_moe(cfg: ModelConfig, lp, x, is_moe: bool, ctx: ShardCtx):
    h = rms_norm(x, lp["ln2"], plus_one=cfg.rms_plus_one)
    if is_moe:
        y = _moe_block_fn(cfg, ctx)(h, lp["moe"])
    else:
        y = ffn(h, lp["ffn"]["w_in"], lp["ffn"].get("w_gate"), lp["ffn"]["w_out"],
                act=cfg.act, rules=ctx.rules)
    return x + y


def _layer(cfg: ModelConfig, lp, x, *, layer_global: bool, is_moe: bool,
           prefix: Optional[int], ctx: ShardCtx, q_offset: int = 0):
    x = _attention_block(cfg, lp, x, layer_global=layer_global, prefix=prefix,
                         ctx=ctx, q_offset=q_offset)
    return _ffn_or_moe(cfg, lp, x, is_moe, ctx)


def _maybe_remat(cfg: ModelConfig, fn):
    if not cfg.remat:
        return fn
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


# --------------------------------------------------------------------------
# Forward (training) pass
# --------------------------------------------------------------------------
def _embed(cfg: ModelConfig, params, tokens, ctx: ShardCtx,
           prefix_embeds: Optional[jnp.ndarray] = None):
    x = params["embed"].astype(cfg.dtype)[tokens]
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(cfg.dtype), x], axis=1)
    if cfg.embed_scale:
        x = x * jnp.asarray(np.sqrt(cfg.d_model), cfg.dtype)
    return lc(x, ("batch", "act_seq", "embed"), ctx.rules)


def _backbone(cfg: ModelConfig, params, x, ctx: ShardCtx,
              prefix: Optional[int] = None, q_offset: int = 0):
    """Run all layers via scan(s)."""
    if cfg.local_global_period > 0:
        period = cfg.local_global_period

        def super_block(x, lps):
            for j in range(period):
                is_glob = j == period - 1
                x = _layer(cfg, lps[f"pos{j}"], x,
                           layer_global=is_glob, is_moe=False, prefix=prefix,
                           ctx=ctx, q_offset=q_offset)
            return x, None

        x, _ = jax.lax.scan(_maybe_remat(cfg, super_block), x, params["blocks"],
                            unroll=True if cfg.unroll_scans else 1)
        if "tail" in params:
            def tail_block(x, lp):
                return _layer(cfg, lp, x, layer_global=False, is_moe=False,
                              prefix=prefix, ctx=ctx, q_offset=q_offset), None
            x, _ = jax.lax.scan(_maybe_remat(cfg, tail_block), x, params["tail"],
                                unroll=True if cfg.unroll_scans else 1)
        return x

    is_moe = cfg.n_experts > 0 and cfg.moe_period == 1

    def block(x, lp):
        return _layer(cfg, lp, x, layer_global=True, is_moe=is_moe,
                      prefix=prefix, ctx=ctx, q_offset=q_offset), None

    x, _ = jax.lax.scan(_maybe_remat(cfg, block), x, params["blocks"],
                        unroll=True if cfg.unroll_scans else 1)
    return x


def _unembed_weight(cfg: ModelConfig, params):
    if cfg.tie_embeddings:
        return params["embed"].astype(cfg.dtype).T
    return params["unembed"].astype(cfg.dtype)


def loss_fn(cfg: ModelConfig, params, batch, ctx: ShardCtx = LOCAL_CTX):
    """batch: {"tokens": [B, S] int32, "labels": [B, S] int32,
    optional "prefix_embeds": [B, P, D]} -> mean NLL."""
    tokens = batch["tokens"]
    prefix_embeds = batch.get("prefix_embeds")
    prefix = cfg.prefix_len if prefix_embeds is not None else None
    x = _embed(cfg, params, tokens, ctx, prefix_embeds)
    x = _backbone(cfg, params, x, ctx, prefix=prefix)
    x = rms_norm(x, params["final_norm"], plus_one=cfg.rms_plus_one)
    if prefix_embeds is not None:
        x = x[:, cfg.prefix_len:]
    B, S, D = x.shape
    labels = batch["labels"].reshape(B * S)
    return chunked_cross_entropy(
        x.reshape(B * S, D), _unembed_weight(cfg, params), labels,
        chunk=min(cfg.xent_chunk, B * S), rules=ctx.rules,
        unroll=cfg.unroll_scans,
    )


# --------------------------------------------------------------------------
# Serving: prefill + decode
# --------------------------------------------------------------------------
def init_cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    """KV cache ShapeDtypeStructs (per stacked block group)."""
    KV, hd = cfg.n_kv_heads, cfg.head_dim

    def group(L, length):
        return {
            "k": ParamSpec((L, batch, length, KV, hd), ("layers", "batch", "kv_seq", "kv_heads", None), cfg.dtype, init="zeros"),
            "v": ParamSpec((L, batch, length, KV, hd), ("layers", "batch", "kv_seq", "kv_heads", None), cfg.dtype, init="zeros"),
        }

    if cfg.local_global_period > 0:
        period = cfg.local_global_period
        n_super = cfg.n_layers // period
        tail = cfg.n_layers - n_super * period
        local_len = min(max_len, (cfg.window or max_len))
        specs = {"blocks": {}}
        for j in range(period):
            is_glob = j == period - 1
            specs["blocks"][f"pos{j}"] = group(n_super, max_len if is_glob else local_len)
        if tail:
            specs["tail"] = group(tail, local_len)
        return specs
    return {"blocks": group(cfg.n_layers, max_len)}


def _decode_layer(cfg: ModelConfig, lp, cache_lp, x, pos, *, layer_global: bool,
                  is_moe: bool, ctx: ShardCtx):
    """x: [B, 1, D]; cache_lp: {"k": [B, S, KV, hd], "v": ...}. Returns x', cache'."""
    B = x.shape[0]
    window = None if layer_global else cfg.window
    theta = cfg.rope_theta_global if (layer_global and cfg.local_global_period) else cfg.rope_theta
    h = rms_norm(x, lp["ln1"], plus_one=cfg.rms_plus_one)
    positions = jnp.full((1,), pos, jnp.int32)
    q = rope(jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wq"]), positions, theta)
    k = rope(jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wk"]), positions, theta)
    v = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wv"])
    S = cache_lp["k"].shape[1]
    slot = pos % S if window is not None else pos  # ring buffer for local layers
    k_cache = jax.lax.dynamic_update_slice(cache_lp["k"], k, (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(cache_lp["v"], v, (0, slot, 0, 0))
    cache_len = jnp.minimum(pos + 1, S)
    o = attention_decode(q, k_cache, v_cache, cache_len,
                         window=None,  # ring buffer already bounds local layers
                         rules=ctx.rules, scale=cfg.attn_logit_scale)
    o = jnp.einsum("bshk,hkd->bsd", o, lp["attn"]["wo"])
    x = x + lc(o, ("batch", None, "embed"), ctx.rules)
    x = _ffn_or_moe(cfg, lp, x, is_moe, ctx)
    return x, {"k": k_cache, "v": v_cache}


def decode_step(cfg: ModelConfig, params, cache, token, pos, ctx: ShardCtx = LOCAL_CTX):
    """token: [B, 1] int32; pos: scalar int32 (current position). Returns
    (logits [B, Vp], new_cache)."""
    x = _embed(cfg, params, token, ctx)

    if cfg.local_global_period > 0:
        period = cfg.local_global_period

        def super_block(x, lps_cache):
            lps, cch = lps_cache
            new_c = {}
            for j in range(period):
                is_glob = j == period - 1
                x, new_c[f"pos{j}"] = _decode_layer(
                    cfg, lps[f"pos{j}"], cch[f"pos{j}"], x, pos,
                    layer_global=is_glob, is_moe=False, ctx=ctx)
            return x, new_c

        x, new_blocks = jax.lax.scan(super_block, x, (params["blocks"], cache["blocks"]),
                                     unroll=True if cfg.unroll_scans else 1)
        new_cache = {"blocks": new_blocks}
        if "tail" in params:
            def tail_block(x, lc_):
                lp, cch = lc_
                x, nc = _decode_layer(cfg, lp, cch, x, pos, layer_global=False,
                                      is_moe=False, ctx=ctx)
                return x, nc
            x, new_tail = jax.lax.scan(tail_block, x, (params["tail"], cache["tail"]),
                                       unroll=True if cfg.unroll_scans else 1)
            new_cache["tail"] = new_tail
    else:
        is_moe = cfg.n_experts > 0 and cfg.moe_period == 1

        def block(x, lp_cache):
            lp, cch = lp_cache
            return _decode_layer(cfg, lp, cch, x, pos, layer_global=True,
                                 is_moe=is_moe, ctx=ctx)

        x, new_blocks = jax.lax.scan(block, x, (params["blocks"], cache["blocks"]),
                                     unroll=True if cfg.unroll_scans else 1)
        new_cache = {"blocks": new_blocks}

    x = rms_norm(x, params["final_norm"], plus_one=cfg.rms_plus_one)
    logits = jnp.einsum("bsd,dv->bsv", x, _unembed_weight(cfg, params))
    logits = lc(logits, ("batch", None, "vocab"), ctx.rules)
    return logits[:, 0], new_cache


def prefill(cfg: ModelConfig, params, tokens, ctx: ShardCtx = LOCAL_CTX,
            prefix_embeds: Optional[jnp.ndarray] = None):
    """Process a full prompt, producing last-position logits. (The KV cache
    write-out variant is exercised via decode; prefill here returns logits —
    the dominant cost is identical.)"""
    prefix = cfg.prefix_len if prefix_embeds is not None else None
    x = _embed(cfg, params, tokens, ctx, prefix_embeds)
    x = _backbone(cfg, params, x, ctx, prefix=prefix)
    x = rms_norm(x, params["final_norm"], plus_one=cfg.rms_plus_one)
    logits = jnp.einsum("bd,dv->bv", x[:, -1], _unembed_weight(cfg, params))
    return lc(logits, ("batch", "vocab"), ctx.rules)
