"""Unified architecture config covering all 10 assigned archs."""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax.numpy as jnp

__all__ = ["ModelConfig"]


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_period: int = 1  # layer i is MoE iff family has moe and i % moe_period == moe_phase
    moe_phase: int = 0
    capacity_factor: float = 1.25
    router_renormalize: bool = True

    # --- attention pattern ---
    gated_mlp: bool = True  # SwiGLU/GeGLU vs plain MLP
    window: Optional[int] = None  # sliding-window size for "local" layers
    local_global_period: int = 0  # gemma3: 6 -> every 6th layer is global
    rope_theta: float = 10_000.0
    rope_theta_global: float = 1_000_000.0
    attn_logit_scale: Optional[float] = None  # override 1/sqrt(head_dim)

    # --- hybrid (jamba) ---
    attn_period: int = 0  # jamba: 8 -> one attention layer per 8
    attn_phase: int = 4

    # --- ssm (mamba1) ---
    d_state: int = 0
    d_conv: int = 4
    expand: int = 2

    # --- enc-dec (whisper) ---
    n_enc_layers: int = 0
    dec_len: int = 448

    # --- vlm (paligemma) ---
    prefix_len: int = 0  # image-patch prefix (stub embeddings)

    # --- misc ---
    act: str = "silu"
    norm: str = "rmsnorm"
    rms_plus_one: bool = False  # gemma convention
    embed_scale: bool = False  # gemma: x *= sqrt(d_model)
    tie_embeddings: bool = True
    dtype: Any = jnp.bfloat16

    # --- runtime / partitioning knobs (hillclimb levers) ---
    attn_mode: str = "heads_tp"  # heads_tp | seq_tp
    q_chunk: int = 256
    kv_chunk: int = 1024
    xent_chunk: int = 2048
    ssm_scan_chunk: int = 64
    remat: bool = True
    capacity_factor_decode: float = 2.0
    # Cost-measurement mode (dry-run roofline extraction): fully unroll every
    # lax.scan so XLA cost_analysis (which counts while bodies ONCE) sees the
    # true op counts. Never used for real execution.
    unroll_scans: bool = False

    # --- §Perf hillclimb levers (beyond-paper optimizations) ---
    # MoE: build dispatch buffers only for the shard's local experts
    # ([E_loc, C, D] instead of [E, C, D]) — 16x less dispatch HBM traffic.
    moe_local_dispatch: bool = False
    # SSM: compute the scan gates (a, b) per chunk inside the outer scan
    # instead of materializing full-sequence [B, S, di, ds] tensors.
    ssm_chunk_local: bool = False
    # SSM: dtype for the (a, bx) gate tensors — bf16 halves the dominant HBM
    # traffic of the reference scan; carries stay f32.
    ssm_gate_dtype: Any = jnp.float32
    # MoE: replicate expert weights instead of EP (kills the per-layer psum;
    # pays expert-weight HBM; wins when experts are small, e.g. granite).
    moe_replicate_experts: bool = False
    # Attention: cast softmax probabilities to bf16 before the p@V matmul
    # (what real flash kernels feed the MXU) — halves the dominant f32
    # score/prob HBM traffic of the reference lowering.
    attn_probs_bf16: bool = False
    # Remat policy: "full" recomputes the whole layer in backward;
    # "dots" saves matmul outputs (jax.checkpoint_policies) — ~25% less
    # backward compute at the cost of saved activations.
    remat_policy: str = "full"

    # ------------------------------------------------------------------
    @property
    def vocab_padded(self) -> int:
        return _round_up(self.vocab_size, 256)

    @property
    def n_experts_padded(self) -> int:
        """Experts padded so EP over 16 divides evenly (dummy experts get no
        traffic: router logits only span the real experts)."""
        if self.n_experts == 0:
            return 0
        return _round_up(self.n_experts, 16)

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return max(1, (self.d_model + 15) // 16)

    def is_moe_layer(self, i: int) -> bool:
        if self.n_experts == 0:
            return False
        return i % self.moe_period == self.moe_phase

    def is_attn_layer(self, i: int) -> bool:
        if self.family != "hybrid":
            return self.family != "ssm"
        return self.attn_period > 0 and i % self.attn_period == self.attn_phase

    def is_global_layer(self, i: int) -> bool:
        """gemma3 5:1 pattern — every ``local_global_period``-th layer global."""
        if self.local_global_period == 0:
            return True  # no local/global distinction -> all global
        return i % self.local_global_period == self.local_global_period - 1

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # approximate parameter counts (for 6ND roofline bookkeeping)
    def param_count(self) -> int:
        D, H, KV, hd, F, L = (
            self.d_model, self.n_heads, self.n_kv_heads, self.head_dim, self.d_ff, self.n_layers,
        )
        total = self.vocab_padded * D  # embed (tied)
        if not self.tie_embeddings:
            total += self.vocab_padded * D
        for i in range(L):
            total += 2 * D  # norms
            if self.is_attn_layer(i):
                total += D * (H + 2 * KV) * hd + H * hd * D
            elif self.family in ("ssm", "hybrid"):
                di, ds, dr = self.d_inner, self.d_state, self.dt_rank
                total += D * 2 * di + di * self.d_conv + di * (dr + 2 * ds) + dr * di + di + di * ds + di * D
            if self.is_moe_layer(i):
                E = self.n_experts
                gates = 3 if self.gated_mlp else 2
                total += D * E + E * gates * D * F
            elif self.family != "ssm" or not self.is_attn_layer(i):
                gates = 3 if self.gated_mlp else 2
                if self.family != "ssm":
                    total += gates * D * F
        if self.family == "encdec":
            # encoder layers + cross-attention in decoder
            for _ in range(self.n_enc_layers):
                total += 2 * D + D * (H + 2 * KV) * hd + H * hd * D
                total += (3 if self.gated_mlp else 2) * D * F
            total += self.n_layers * (D * (H + 2 * KV) * hd + H * hd * D + D)
        return total

    def active_param_count(self) -> int:
        """MoE: only top_k of n_experts active per token."""
        if self.n_experts == 0:
            return self.param_count()
        total = self.param_count()
        E, K = self.n_experts, self.top_k
        gates = 3 if self.gated_mlp else 2
        n_moe = sum(1 for i in range(self.n_layers) if self.is_moe_layer(i))
        expert_params = n_moe * E * gates * self.d_model * self.d_ff
        active = n_moe * K * gates * self.d_model * self.d_ff
        return total - expert_params + active
