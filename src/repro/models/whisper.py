"""Whisper-base encoder-decoder (arXiv:2212.04356), transformer backbone only.

The conv frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed frame embeddings [B, S_enc, D] (the two stride-2 convs of real
Whisper happen upstream). Sinusoidal positions, pre-LayerNorm blocks, GELU
MLPs, MHA (kv == heads). Decoder: causal self-attention + cross-attention
over encoder states.
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..parallel.spec import ParamSpec, logical_constraint as lc
from .common import attention_decode, attention_seq_tp, chunked_cross_entropy, layer_norm
from .config import ModelConfig
from .transformer import LOCAL_CTX, ShardCtx


def _attn(cfg: ModelConfig, L: int) -> Dict[str, ParamSpec]:
    D, H, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    return {
        "wq": ParamSpec((L, D, H, hd), ("layers", "embed", "heads", None), cfg.dtype),
        "wk": ParamSpec((L, D, H, hd), ("layers", "embed", "kv_heads", None), cfg.dtype),
        "wv": ParamSpec((L, D, H, hd), ("layers", "embed", "kv_heads", None), cfg.dtype),
        "wo": ParamSpec((L, H, hd, D), ("layers", "heads", None, "embed"), cfg.dtype),
    }


def _ln(L: int, D: int, what: str) -> Dict[str, ParamSpec]:
    return {
        f"{what}_scale": ParamSpec((L, D), ("layers", "embed"), jnp.float32, init="ones"),
        f"{what}_bias": ParamSpec((L, D), ("layers", "embed"), jnp.float32, init="zeros"),
    }


def _mlp(cfg: ModelConfig, L: int) -> Dict[str, ParamSpec]:
    D, F = cfg.d_model, cfg.d_ff
    return {
        "w_in": ParamSpec((L, D, F), ("layers", "embed", "mlp"), cfg.dtype),
        "w_out": ParamSpec((L, F, D), ("layers", "mlp", "embed"), cfg.dtype),
    }


def param_specs(cfg: ModelConfig) -> Dict[str, Any]:
    D, Vp = cfg.d_model, cfg.vocab_padded
    Le, Ld = cfg.n_enc_layers, cfg.n_layers
    return {
        "embed": ParamSpec((Vp, D), ("vocab", "embed"), cfg.dtype),
        "enc": {
            **_ln(Le, D, "ln1"), **_ln(Le, D, "ln2"),
            "attn": _attn(cfg, Le), "mlp": _mlp(cfg, Le),
        },
        "enc_final": {
            "scale": ParamSpec((D,), ("embed",), jnp.float32, init="ones"),
            "bias": ParamSpec((D,), ("embed",), jnp.float32, init="zeros"),
        },
        "dec": {
            **_ln(Ld, D, "ln1"), **_ln(Ld, D, "ln2"), **_ln(Ld, D, "ln3"),
            "self_attn": _attn(cfg, Ld),
            "cross_attn": _attn(cfg, Ld),
            "mlp": _mlp(cfg, Ld),
        },
        "dec_final": {
            "scale": ParamSpec((D,), ("embed",), jnp.float32, init="ones"),
            "bias": ParamSpec((D,), ("embed",), jnp.float32, init="zeros"),
        },
    }


def _sinusoid(S: int, D: int, dtype) -> jnp.ndarray:
    pos = np.arange(S)[:, None]
    dim = np.arange(D // 2)[None, :]
    inv = np.exp(-np.log(10000.0) * dim / max(D // 2 - 1, 1))
    tab = np.concatenate([np.sin(pos * inv), np.cos(pos * inv)], axis=1)
    return jnp.asarray(tab, dtype)


def _self_attention(cfg, lp, x, causal, ctx, name="attn", kv_x=None):
    h = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,dhk->bshk", x, lp[name]["wq"])
    k = jnp.einsum("bsd,dhk->bshk", h, lp[name]["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h, lp[name]["wv"])
    k = lc(k, ("batch", None, "kv_heads", None), ctx.rules)
    v = lc(v, ("batch", None, "kv_heads", None), ctx.rules)
    o = attention_seq_tp(q, k, v, causal=causal, kv_chunk=cfg.kv_chunk,
                         rules=ctx.rules, unroll=cfg.unroll_scans)
    return jnp.einsum("bshk,hkd->bsd", o, lp[name]["wo"])


def _enc_layer(cfg, lp, x, ctx):
    h = layer_norm(x, lp["ln1_scale"], lp["ln1_bias"])
    x = x + _self_attention(cfg, lp, h, causal=False, ctx=ctx)
    h = layer_norm(x, lp["ln2_scale"], lp["ln2_bias"])
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", h, lp["mlp"]["w_in"]))
    h = lc(h, ("batch", "act_seq", "mlp"), ctx.rules)
    return x + jnp.einsum("bsf,fd->bsd", h, lp["mlp"]["w_out"])


def encode(cfg: ModelConfig, params, frames, ctx: ShardCtx = LOCAL_CTX):
    """frames: [B, S_enc, D] stub embeddings -> encoder states."""
    B, S, D = frames.shape
    x = frames.astype(cfg.dtype) + _sinusoid(S, D, cfg.dtype)
    x = lc(x, ("batch", "act_seq", "embed"), ctx.rules)

    def body(x, lp):
        return _enc_layer(cfg, lp, x, ctx), None

    body = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body, x, params["enc"], unroll=True if cfg.unroll_scans else 1)
    return layer_norm(x, params["enc_final"]["scale"], params["enc_final"]["bias"])


def _dec_layer(cfg, lp, x, enc_states, ctx):
    h = layer_norm(x, lp["ln1_scale"], lp["ln1_bias"])
    x = x + _self_attention(cfg, lp, h, causal=True, ctx=ctx, name="self_attn")
    h = layer_norm(x, lp["ln2_scale"], lp["ln2_bias"])
    x = x + _self_attention(cfg, lp, h, causal=False, ctx=ctx, name="cross_attn",
                            kv_x=enc_states)
    h = layer_norm(x, lp["ln3_scale"], lp["ln3_bias"])
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", h, lp["mlp"]["w_in"]))
    h = lc(h, ("batch", "act_seq", "mlp"), ctx.rules)
    return x + jnp.einsum("bsf,fd->bsd", h, lp["mlp"]["w_out"])


def decode_train(cfg: ModelConfig, params, tokens, enc_states, ctx: ShardCtx = LOCAL_CTX):
    B, S = tokens.shape
    x = params["embed"].astype(cfg.dtype)[tokens] + _sinusoid(S, cfg.d_model, cfg.dtype)
    x = lc(x, ("batch", "act_seq", "embed"), ctx.rules)

    def body(x, lp):
        return _dec_layer(cfg, lp, x, enc_states, ctx), None

    body = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body, x, params["dec"], unroll=True if cfg.unroll_scans else 1)
    return layer_norm(x, params["dec_final"]["scale"], params["dec_final"]["bias"])


def loss_fn(cfg: ModelConfig, params, batch, ctx: ShardCtx = LOCAL_CTX):
    """batch: {"frames": [B, S_enc, D], "tokens": [B, S_dec], "labels": ...}."""
    enc_states = encode(cfg, params, batch["frames"], ctx)
    x = decode_train(cfg, params, batch["tokens"], enc_states, ctx)
    B, S, D = x.shape
    return chunked_cross_entropy(
        x.reshape(B * S, D), params["embed"].astype(cfg.dtype).T,
        batch["labels"].reshape(B * S), chunk=min(cfg.xent_chunk, B * S),
        rules=ctx.rules, unroll=cfg.unroll_scans,
    )


def prefill_logits(cfg: ModelConfig, params, frames, ctx: ShardCtx = LOCAL_CTX):
    """Inference-prefill: encode the full frame sequence (the dominant cost)
    and produce first-token logits from a BOS-only decoder pass."""
    enc_states = encode(cfg, params, frames, ctx)
    B = frames.shape[0]
    tokens = jnp.zeros((B, 1), jnp.int32)
    x = decode_train(cfg, params, tokens, enc_states, ctx)
    logits = jnp.einsum("bd,dv->bv", x[:, -1], params["embed"].astype(cfg.dtype).T)
    return lc(logits, ("batch", "vocab"), ctx.rules)


def init_cache_specs(cfg: ModelConfig, batch: int, enc_len: int):
    """Decoder self-attn KV cache + precomputed cross K/V over encoder states."""
    H, hd, Ld = cfg.n_heads, cfg.head_dim, cfg.n_layers
    return {
        "self_k": ParamSpec((Ld, batch, cfg.dec_len, H, hd), ("layers", "batch", None, "kv_heads", None), cfg.dtype, init="zeros"),
        "self_v": ParamSpec((Ld, batch, cfg.dec_len, H, hd), ("layers", "batch", None, "kv_heads", None), cfg.dtype, init="zeros"),
        "cross_k": ParamSpec((Ld, batch, enc_len, H, hd), ("layers", "batch", "kv_seq", "kv_heads", None), cfg.dtype, init="zeros"),
        "cross_v": ParamSpec((Ld, batch, enc_len, H, hd), ("layers", "batch", "kv_seq", "kv_heads", None), cfg.dtype, init="zeros"),
    }


def decode_step(cfg: ModelConfig, params, cache, token, pos, ctx: ShardCtx = LOCAL_CTX):
    """One decoder token against cached cross K/V (encoder already run)."""
    B = token.shape[0]
    x = params["embed"].astype(cfg.dtype)[token]
    pos_emb = jax.lax.dynamic_slice_in_dim(
        _sinusoid(cfg.dec_len, cfg.d_model, cfg.dtype), 0, 1, axis=0
    )
    x = x + pos_emb

    def body(x, lp_cache):
        lp, cch = lp_cache
        h = layer_norm(x, lp["ln1_scale"], lp["ln1_bias"])
        q = jnp.einsum("bsd,dhk->bshk", h, lp["self_attn"]["wq"])
        k = jnp.einsum("bsd,dhk->bshk", h, lp["self_attn"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, lp["self_attn"]["wv"])
        k_cache = jax.lax.dynamic_update_slice(cch["self_k"], k, (0, pos, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(cch["self_v"], v, (0, pos, 0, 0))
        o = attention_decode(q, k_cache, v_cache, pos + 1, rules=ctx.rules)
        x = x + jnp.einsum("bshk,hkd->bsd", o, lp["self_attn"]["wo"])
        # cross-attention over the full cached encoder K/V
        h = layer_norm(x, lp["ln2_scale"], lp["ln2_bias"])
        q = jnp.einsum("bsd,dhk->bshk", h, lp["cross_attn"]["wq"])
        o = attention_decode(q, cch["cross_k"], cch["cross_v"],
                             cch["cross_k"].shape[1], rules=ctx.rules)
        x = x + jnp.einsum("bshk,hkd->bsd", o, lp["cross_attn"]["wo"])
        h = layer_norm(x, lp["ln3_scale"], lp["ln3_bias"])
        h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", h, lp["mlp"]["w_in"]))
        x = x + jnp.einsum("bsf,fd->bsd", h, lp["mlp"]["w_out"])
        return x, {"self_k": k_cache, "self_v": v_cache,
                   "cross_k": cch["cross_k"], "cross_v": cch["cross_v"]}

    x, new_cache = jax.lax.scan(
        body, x,
        (params["dec"], {k: cache[k] for k in ("self_k", "self_v", "cross_k", "cross_v")}),
        unroll=True if cfg.unroll_scans else 1,
    )
    x = layer_norm(x, params["dec_final"]["scale"], params["dec_final"]["bias"])
    logits = jnp.einsum("bsd,dv->bsv", x, params["embed"].astype(cfg.dtype).T)
    return lc(logits[:, 0], ("batch", "vocab"), ctx.rules), new_cache
