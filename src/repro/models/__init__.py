"""repro.models — architecture zoo. ``get_api(cfg)`` dispatches by family."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

from . import jamba, mamba, transformer, whisper
from .config import ModelConfig
from .transformer import LOCAL_CTX, ShardCtx  # noqa: F401

__all__ = ["ModelConfig", "ModelAPI", "get_api", "ShardCtx", "LOCAL_CTX"]


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    param_specs: Callable
    loss_fn: Callable
    prefill: Callable
    decode_step: Callable
    init_cache_specs: Optional[Callable] = None  # (cfg, batch, max_len)


def get_api(cfg: ModelConfig) -> ModelAPI:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return ModelAPI(
            param_specs=transformer.param_specs,
            loss_fn=transformer.loss_fn,
            prefill=transformer.prefill,
            decode_step=transformer.decode_step,
            init_cache_specs=transformer.init_cache_specs,
        )
    if fam == "ssm":
        return ModelAPI(
            param_specs=mamba.param_specs,
            loss_fn=mamba.loss_fn,
            prefill=mamba.prefill,
            decode_step=mamba.decode_step,
            init_cache_specs=lambda cfg, batch, max_len: mamba.init_state_specs(cfg, batch),
        )
    if fam == "hybrid":
        return ModelAPI(
            param_specs=jamba.param_specs,
            loss_fn=jamba.loss_fn,
            prefill=jamba.prefill,
            decode_step=jamba.decode_step,
            init_cache_specs=jamba.init_cache_specs,
        )
    if fam == "encdec":
        return ModelAPI(
            param_specs=whisper.param_specs,
            loss_fn=whisper.loss_fn,
            prefill=whisper.prefill_logits,
            decode_step=whisper.decode_step,
            init_cache_specs=whisper.init_cache_specs,
        )
    raise ValueError(f"unknown family {fam!r}")
