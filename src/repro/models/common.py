"""Shared model components: norms, RoPE, attention (two TP modes), FFN, MoE,
and chunked cross-entropy. Everything is a pure function of (config-ish args,
params, activations) so it lowers identically under jit/pjit on any mesh.

Attention TP modes
------------------
- ``heads_tp``  (n_heads % tp == 0): q-chunked online-softmax scan; heads
  sharded over "model". Memory per step: [B, qc, H_loc, S] scores.
- ``seq_tp``    (small-head archs): q-sequence sharded over "model", kv
  replicated; kv-chunked online-softmax scan. Scores [B, S_loc, H, kc].

Both are flash-style (never materialize [S, S]), differentiable (lax.scan),
and masked for causal / sliding-window / prefix-LM.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..parallel.spec import Rules, logical_constraint as lc

NEG_INF = -1e30


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------
def rms_norm(x, scale, eps: float = 1e-6, plus_one: bool = False):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    s = scale.astype(jnp.float32)
    if plus_one:  # gemma convention
        s = 1.0 + s
    return (y * s).astype(dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = x.mean(-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------
def rope(x, positions, theta: float = 10_000.0):
    """x: [..., S, H, D]; positions: [..., S] (broadcastable)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None, None].astype(jnp.float32) * freq  # [..., S, 1, half]
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Masking
# --------------------------------------------------------------------------
def _mask_bias(q_pos, k_pos, causal: bool, window: Optional[int], prefix: Optional[int]):
    """Additive bias [*q, *k] given global positions (int32 arrays)."""
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    ok = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), bool)
    if causal:
        allowed = kp <= qp
        if prefix is not None:
            allowed = allowed | (kp < prefix)
        ok &= allowed
    if window is not None:
        ok &= kp > qp - window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


# --------------------------------------------------------------------------
# Attention — heads_tp mode (q-chunked scan, heads sharded)
# --------------------------------------------------------------------------
def attention_heads_tp(
    q, k, v, *,
    causal: bool = True,
    window: Optional[int] = None,
    prefix: Optional[int] = None,
    q_offset: int = 0,
    q_chunk: int = 512,
    rules: Optional[Rules] = None,
    scale: Optional[float] = None,
    unroll: bool = False,
    probs_bf16: bool = False,
):
    """q: [B, Sq, H, D]; k/v: [B, Sk, KVH, D] -> [B, Sq, H, D]."""
    B, Sq, H, D = q.shape
    Sk, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    scale = scale or D ** -0.5
    qc = min(q_chunk, Sq)
    n_chunks = Sq // qc
    assert Sq % qc == 0, (Sq, qc)

    # Constrain the 4D [B,S,H,D] view (H = KVH*G shards over "model"); the
    # grouped 5/6D views inherit the split sharding via propagation. The seq
    # dim is deliberately unconstrained here: under sequence-parallel rules
    # (act_seq="model") the residual stream is seq-sharded between layers and
    # XLA inserts the all-gather/reduce-scatter pair at the block boundary.
    q = lc(q, ("batch", None, "heads", None), rules)
    q = q.reshape(B, n_chunks, qc, KVH, G, D)
    k_pos = jnp.arange(Sk, dtype=jnp.int32)

    def chunk_body(carry, xs):
        ci, qi = xs  # qi: [B, qc, KVH, G, D]
        s = jnp.einsum("bqhgd,bshd->bhgqs", qi.astype(jnp.float32) * scale,
                       k.astype(jnp.float32))
        q_pos = q_offset + ci * qc + jnp.arange(qc, dtype=jnp.int32)
        s = s + _mask_bias(q_pos, k_pos, causal, window, prefix)
        m = jax.lax.stop_gradient(jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m)
        l = jnp.sum(p, axis=-1, keepdims=True)
        p = p / jnp.maximum(l, 1e-30)
        if probs_bf16:
            p = p.astype(jnp.bfloat16)
            o = jnp.einsum("bhgqs,bshd->bqhgd", p, v.astype(jnp.bfloat16))
        else:
            o = jnp.einsum("bhgqs,bshd->bqhgd", p, v.astype(jnp.float32))
        return carry, o.astype(q.dtype)

    _, out = jax.lax.scan(
        chunk_body, None, (jnp.arange(n_chunks), jnp.moveaxis(q, 1, 0)),
        unroll=True if unroll else 1,
    )
    out = jnp.moveaxis(out, 0, 1).reshape(B, Sq, H, D)
    return lc(out, ("batch", None, "heads", None), rules)


# --------------------------------------------------------------------------
# Attention — seq_tp mode (kv-chunked scan, q-sequence sharded)
# --------------------------------------------------------------------------
def attention_seq_tp(
    q, k, v, *,
    causal: bool = True,
    window: Optional[int] = None,
    prefix: Optional[int] = None,
    q_offset: int = 0,
    kv_chunk: int = 1024,
    rules: Optional[Rules] = None,
    scale: Optional[float] = None,
    unroll: bool = False,
    probs_bf16: bool = False,
):
    """Online-softmax over kv chunks; q seq dim stays sharded ("act_seq")."""
    B, Sq, H, D = q.shape
    Sk, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    scale = scale or D ** -0.5
    kc = min(kv_chunk, Sk)
    n_chunks = Sk // kc
    assert Sk % kc == 0, (Sk, kc)

    q5 = q.reshape(B, Sq, KVH, G, D).astype(jnp.float32) * scale
    q5 = lc(q5, ("batch", "act_seq", "kv_heads", "heads", None), rules)
    q_pos = q_offset + jnp.arange(Sq, dtype=jnp.int32)

    k_r = jnp.moveaxis(k.reshape(B, n_chunks, kc, KVH, D), 1, 0)
    v_r = jnp.moveaxis(v.reshape(B, n_chunks, kc, KVH, D), 1, 0)

    def body(carry, xs):
        m, l, acc = carry  # m,l: [B, Sq, KVH, G]; acc: [B, Sq, KVH, G, D]
        ci, ki, vi = xs
        s = jnp.einsum("bqhgd,bshd->bqhgs", q5, ki.astype(jnp.float32))
        k_pos = ci * kc + jnp.arange(kc, dtype=jnp.int32)
        bias = _mask_bias(q_pos, k_pos, causal, window, prefix)  # [Sq, kc]
        s = s + bias[None, :, None, None, :]
        m_new = jnp.maximum(m, jax.lax.stop_gradient(jnp.max(s, axis=-1)))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = p.astype(jnp.bfloat16) if probs_bf16 else p
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqhgs,bshd->bqhgd", pv,
            vi.astype(jnp.bfloat16 if probs_bf16 else jnp.float32),
        ).astype(jnp.float32)
        carry = (m_new, l_new, lc(acc_new, ("batch", "act_seq", "kv_heads", "heads", None), rules))
        return carry, None

    m0 = jnp.full((B, Sq, KVH, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, KVH, G), jnp.float32)
    a0 = jnp.zeros((B, Sq, KVH, G, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (jnp.arange(n_chunks), k_r, v_r),
        unroll=True if unroll else 1,
    )
    out = (acc / jnp.maximum(l, 1e-30)[..., None]).reshape(B, Sq, H, D).astype(q.dtype)
    return lc(out, ("batch", "act_seq", "heads", None), rules)


# --------------------------------------------------------------------------
# Decode attention (single query position against a cache)
# --------------------------------------------------------------------------
def attention_decode(q, k_cache, v_cache, cache_len, *,
                     window: Optional[int] = None,
                     rules: Optional[Rules] = None,
                     scale: Optional[float] = None):
    """q: [B, 1, H, D]; caches: [B, S, KVH, D]; cache_len: effective length.

    Attends over cache[0:cache_len] (+ the new position itself must already
    be written into the cache). Softmax over a (possibly sharded) S axis —
    XLA inserts the max/sum all-reduces automatically.
    """
    B, _, H, D = q.shape
    S, KVH = k_cache.shape[1], k_cache.shape[2]
    G = H // KVH
    scale = scale or D ** -0.5
    q5 = q.reshape(B, KVH, G, D).astype(jnp.float32) * scale
    s = jnp.einsum("bhgd,bshd->bhgs", q5, k_cache.astype(jnp.float32))
    pos = jnp.arange(S, dtype=jnp.int32)
    ok = pos[None, :] < cache_len  # [1, S] or [B, S]
    if window is not None:
        ok = ok & (pos[None, :] > cache_len - 1 - window)
    s = jnp.where(ok[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, 1, H, D).astype(q.dtype)


# --------------------------------------------------------------------------
# FFN (dense) — SwiGLU / GeGLU / GELU-mlp
# --------------------------------------------------------------------------
def ffn(x, w_in, w_gate, w_out, *, act: str = "silu", rules: Optional[Rules] = None):
    """x: [B, S, D]; w_in/w_gate: [D, F]; w_out: [F, D]."""
    h = jnp.einsum("bsd,df->bsf", x, w_in)
    if w_gate is not None:
        g = jnp.einsum("bsd,df->bsf", x, w_gate)
        h = _activate(g, act) * h
    else:
        h = _activate(h, act)
    h = lc(h, ("batch", None, "mlp"), rules)
    out = jnp.einsum("bsf,fd->bsd", h, w_out)
    return lc(out, ("batch", "act_seq", "embed"), rules)


def _activate(x, act: str):
    if act == "silu":
        return jax.nn.silu(x)
    if act == "gelu":
        return jax.nn.gelu(x)
    if act == "relu":
        return jax.nn.relu(x)
    raise ValueError(act)


# --------------------------------------------------------------------------
# MoE — top-k routing, sort-based dropless-ish dispatch with capacity,
# expert-parallel over "model" via replicated-activation + psum combine.
# --------------------------------------------------------------------------
def moe_dispatch(x2d, router_w, *, n_experts: int, top_k: int,
                 capacity_factor: float = 1.25, renormalize: bool = True,
                 expert_lo=None, n_local: Optional[int] = None):
    """Top-k routing + sort-based capacity dispatch. x2d: [T, D].

    Returns (xe [E_out, C, D], dispatch_meta, C). ``n_experts`` may exceed the
    router's width (padded experts receive no traffic — the top_k indices only
    span router_w.shape[1] real experts).

    When ``expert_lo``/``n_local`` are given (local-dispatch optimization),
    only the shard's expert range [lo, lo+n_local) is materialized — the
    buffer is [n_local, C, D] and assignments outside the range are masked,
    cutting dispatch HBM traffic by the EP degree.
    """
    T, D = x2d.shape
    n_real = router_w.shape[1]
    logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32), router_w.astype(jnp.float32))
    gate_vals, expert_idx = jax.lax.top_k(logits, top_k)  # [T, K]
    if renormalize:
        gate_vals = jax.nn.softmax(gate_vals, axis=-1)
    else:
        gate_vals = jax.nn.sigmoid(gate_vals)

    K = top_k
    flat_e = expert_idx.reshape(-1).astype(jnp.int32)  # [T*K]
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
    flat_g = gate_vals.reshape(-1)

    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    # rank of each assignment within its expert group
    starts = jnp.searchsorted(se, jnp.arange(n_experts, dtype=se.dtype), side="left")
    pos_in_e = jnp.arange(T * K, dtype=jnp.int32) - starts[se].astype(jnp.int32)

    C = max(1, int(np.ceil(T * K / max(n_real, 1) * capacity_factor)))
    keep = pos_in_e < C
    if expert_lo is not None and n_local is not None:
        lo = jnp.asarray(expert_lo, jnp.int32)
        local = (se >= lo) & (se < lo + n_local)
        keep = keep & local
        e_out = n_local
        slot = jnp.where(keep, (se - lo) * C + pos_in_e, e_out * C)
    else:
        e_out = n_experts
        slot = jnp.where(keep, se * C + pos_in_e, e_out * C)  # overflow -> dropped

    # dispatch: buffer [E_out*C(+1), D]
    buf = jnp.zeros((e_out * C + 1, D), x2d.dtype)
    buf = buf.at[slot].set(jnp.where(keep[:, None], x2d[st], 0), mode="drop")
    xe = buf[: e_out * C].reshape(e_out, C, D)

    return xe, (slot, st, sg, keep), C


def moe_expert_compute(xe, w_in, w_gate, w_out, act: str = "silu"):
    """xe: [E_loc, C, D] -> [E_loc, C, D]."""
    h = jnp.einsum("ecd,edf->ecf", xe, w_in)
    if w_gate is not None:
        g = jnp.einsum("ecd,edf->ecf", xe, w_gate)
        h = _activate(g, act) * h
    else:
        h = _activate(h, act)
    return jnp.einsum("ecf,efd->ecd", h, w_out)


def moe_combine(out_e_all, dispatch_meta, T: int, D: int, n_experts: int, C: int, dtype):
    """Scatter expert outputs back to token order with gate weights."""
    slot, st, sg, keep = dispatch_meta
    flat = out_e_all.reshape(n_experts * C, -1)
    padded = jnp.concatenate([flat, jnp.zeros((1, flat.shape[1]), flat.dtype)], 0)
    contrib = padded[slot] * (sg * keep).astype(flat.dtype)[:, None]
    y = jnp.zeros((T, D), flat.dtype).at[st].add(contrib)
    return y.astype(dtype)


# --------------------------------------------------------------------------
# Chunked softmax cross-entropy (big-vocab safe)
# --------------------------------------------------------------------------
def chunked_cross_entropy(x2d, unembed, labels, *, chunk: int = 4096,
                          rules: Optional[Rules] = None, z_loss: float = 0.0,
                          unroll: bool = False):
    """x2d: [T, D] hidden; unembed: [D, V]; labels: [T] int32. Mean NLL.

    Scans token chunks so the [chunk, V] logits tensor never materializes for
    all T at once; body is rematerialized in backward.
    """
    T, D = x2d.shape
    c = min(chunk, T)
    while T % c:  # largest divisor of T not exceeding the requested chunk
        c -= 1
    n = T // c
    xs = (x2d.reshape(n, c, D), labels.reshape(n, c))

    @jax.checkpoint
    def body(tot, xs):
        xc, yc = xs
        logits = jnp.einsum("td,dv->tv", xc, unembed).astype(jnp.float32)
        logits = lc(logits, ("act_seq", "vocab"), rules)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yc[:, None].astype(jnp.int32), axis=-1)[:, 0]
        nll = (lse - gold).sum()
        if z_loss:
            nll = nll + z_loss * (lse ** 2).sum()
        return tot + nll, None

    tot, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), xs,
                          unroll=True if unroll else 1)
    return tot / T
