"""Mamba-1 selective SSM (falcon-mamba-7b) — TPU-native adaptation.

The CUDA reference fuses the selective scan into one kernel operating in SRAM.
TPU adaptation: channels (d_inner) are the TP axis (all scan/conv/gating ops
are elementwise over channels → zero collectives inside the block; in/out
projections follow the Megatron column/row pattern). The scan itself is
*chunked*: outer ``lax.scan`` over sequence chunks carrying h [B, di, ds];
within a chunk a work-efficient ``associative_scan`` materializes only
[B, chunk, di_local, ds] in VMEM-sized pieces. A Pallas chunk-scan kernel
(kernels/mamba_scan.py) implements the same contract for the TPU hot path.
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..parallel.spec import ParamSpec, logical_constraint as lc
from .common import rms_norm
from .config import ModelConfig
from .transformer import ShardCtx, LOCAL_CTX, _embed, _unembed_weight
from .common import chunked_cross_entropy


# --------------------------------------------------------------------------
def mamba_layer_specs(cfg: ModelConfig, L: int) -> Dict[str, ParamSpec]:
    D, di, ds, dr, dc = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.dt_rank, cfg.d_conv
    return {
        "ln": ParamSpec((L, D), ("layers", "embed"), jnp.float32, init="ones"),
        "w_x": ParamSpec((L, D, di), ("layers", "embed", "mlp"), cfg.dtype),
        "w_z": ParamSpec((L, D, di), ("layers", "embed", "mlp"), cfg.dtype),
        "conv_w": ParamSpec((L, di, dc), ("layers", "mlp", None), cfg.dtype, scale=0.5),
        "conv_b": ParamSpec((L, di), ("layers", "mlp"), cfg.dtype, init="zeros"),
        "w_bcdt": ParamSpec((L, di, dr + 2 * ds), ("layers", "mlp", None), cfg.dtype),
        "w_dt": ParamSpec((L, dr, di), ("layers", None, "mlp"), cfg.dtype),
        "b_dt": ParamSpec((L, di), ("layers", "mlp"), jnp.float32, init="zeros"),
        "a_log": ParamSpec((L, di, ds), ("layers", "mlp", "state"), jnp.float32, init="mamba_a"),
        "d_skip": ParamSpec((L, di), ("layers", "mlp"), jnp.float32, init="ones"),
        "w_out": ParamSpec((L, di, D), ("layers", "mlp", "embed"), cfg.dtype),
    }


def param_specs(cfg: ModelConfig) -> Dict[str, Any]:
    D, Vp = cfg.d_model, cfg.vocab_padded
    return {
        "embed": ParamSpec((Vp, D), ("vocab", "embed"), cfg.dtype),
        "final_norm": ParamSpec((D,), ("embed",), jnp.float32, init="ones"),
        "blocks": mamba_layer_specs(cfg, cfg.n_layers),
    }


# --------------------------------------------------------------------------
def _causal_conv(x, w, b, ctx: ShardCtx):
    """Depthwise causal conv. x: [B, S, di]; w: [di, K]; b: [di]."""
    K = w.shape[-1]
    acc = x * w[:, K - 1]
    for i in range(K - 1):
        shift = K - 1 - i
        xi = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, : x.shape[1]]
        acc = acc + xi * w[:, i]
    return acc + b


def _ssm_scan_chunked(a, b, h0, chunk: int, unroll: bool = False):
    """h_t = a_t*h_{t-1} + b_t over seq axis 1. a,b: [B, S, di, ds].
    Outer scan over chunks, associative scan within."""
    B, S, di, ds = a.shape
    c = min(chunk, S)
    n = S // c
    assert S % c == 0
    a_r = jnp.moveaxis(a.reshape(B, n, c, di, ds), 1, 0)
    b_r = jnp.moveaxis(b.reshape(B, n, c, di, ds), 1, 0)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    def outer(h, ab):
        ai, bi = ab
        A_cum, B_cum = jax.lax.associative_scan(combine, (ai, bi), axis=1)
        h_all = B_cum + A_cum * h[:, None]
        return h_all[:, -1], h_all

    h_end, ys = jax.lax.scan(outer, h0, (a_r, b_r), unroll=True if unroll else 1)
    ys = jnp.moveaxis(ys, 0, 1).reshape(B, S, di, ds)
    return ys, h_end


def _ssm_chunk_local(cfg: ModelConfig, lp, xc, ctx: ShardCtx):
    """§Perf lever (ssm_chunk_local): compute gates (dt, B, C, a, bx) PER
    CHUNK inside the scan instead of materializing full-sequence
    [B, S, di, ds] tensors — the reference path's dominant HBM traffic.
    xc: [B, S, di] (post-conv, activated). Returns y [B, S, di] f32."""
    B, S, di = xc.shape
    ds, dr = cfg.d_state, cfg.dt_rank
    c = min(cfg.ssm_scan_chunk, S)
    while S % c:
        c -= 1
    n = S // c
    xc_r = jnp.moveaxis(xc.reshape(B, n, c, di), 1, 0)
    A = -jnp.exp(lp["a_log"])  # [di, ds]

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    def outer(h, xc_c):
        bcdt = jnp.einsum("bse,ef->bsf", xc_c, lp["w_bcdt"]).astype(jnp.float32)
        dt_in, Bmat, Cmat = jnp.split(bcdt, [dr, dr + ds], axis=-1)
        dt = jax.nn.softplus(
            jnp.einsum("bsr,re->bse", dt_in.astype(xc_c.dtype), lp["w_dt"]).astype(jnp.float32)
            + lp["b_dt"]
        )
        gd = cfg.ssm_gate_dtype
        a = jnp.exp(dt[..., None] * A).astype(gd)
        bx = ((dt * xc_c.astype(jnp.float32))[..., None]
              * Bmat[:, :, None, :]).astype(gd)
        A_cum, B_cum = jax.lax.associative_scan(combine, (a, bx), axis=1)
        h_all = B_cum.astype(jnp.float32) + A_cum.astype(jnp.float32) * h[:, None]
        y_c = (h_all * Cmat[:, :, None, :]).sum(-1)  # [B, c, di]
        y_c = y_c + lp["d_skip"] * xc_c.astype(jnp.float32)
        return h_all[:, -1], y_c

    h0 = jnp.zeros((B, di, ds), jnp.float32)
    _, ys = jax.lax.scan(outer, h0, xc_r, unroll=True if cfg.unroll_scans else 1)
    return jnp.moveaxis(ys, 0, 1).reshape(B, S, di)


def mamba_mixer(cfg: ModelConfig, lp, x, ctx: ShardCtx, h0=None, conv_state=None):
    """Full-sequence mamba mixer. x: [B, S, D] -> [B, S, D].

    If h0/conv_state given (decode), S must be 1 and states are returned.
    """
    B, S, D = x.shape
    di, ds, dr = cfg.d_inner, cfg.d_state, cfg.dt_rank
    xin = jnp.einsum("bsd,de->bse", x, lp["w_x"])
    z = jnp.einsum("bsd,de->bse", x, lp["w_z"])
    xin = lc(xin, ("batch", "act_seq", "mlp"), ctx.rules)
    z = lc(z, ("batch", "act_seq", "mlp"), ctx.rules)

    if conv_state is not None:
        # decode: conv over [conv_state ++ x]
        full = jnp.concatenate([conv_state, xin], axis=1)  # [B, K, di]
        K = lp["conv_w"].shape[-1]
        xc = (full * lp["conv_w"].T[None]).sum(axis=1, keepdims=True) + lp["conv_b"]
        new_conv_state = full[:, 1:]
    else:
        xc = _causal_conv(xin, lp["conv_w"], lp["conv_b"], ctx)
        new_conv_state = None
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)

    if cfg.ssm_chunk_local and conv_state is None and S > 1:
        y = _ssm_chunk_local(cfg, lp, xc, ctx)
        y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
        out = jnp.einsum("bse,ed->bsd", y, lp["w_out"])
        return lc(out, ("batch", "act_seq", "embed"), ctx.rules)

    bcdt = jnp.einsum("bse,ef->bsf", xc, lp["w_bcdt"]).astype(jnp.float32)
    dt_in, Bmat, Cmat = jnp.split(bcdt, [dr, dr + ds], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,re->bse", dt_in.astype(x.dtype), lp["w_dt"]).astype(jnp.float32)
        + lp["b_dt"]
    )  # [B, S, di]
    A = -jnp.exp(lp["a_log"])  # [di, ds]
    a = jnp.exp(dt[..., None] * A)  # [B, S, di, ds]
    bx = (dt * xc.astype(jnp.float32))[..., None] * Bmat[:, :, None, :]  # [B,S,di,ds]

    if h0 is None:
        h0 = jnp.zeros((B, di, ds), jnp.float32)
    if S == 1:
        h_all = a * h0[:, None] + bx
        h_end = h_all[:, -1]
    else:
        h_all, h_end = _ssm_scan_chunked(a, bx, h0, cfg.ssm_scan_chunk,
                                         unroll=cfg.unroll_scans)

    y = (h_all * Cmat[:, :, None, :]).sum(-1)  # [B, S, di]
    y = y + lp["d_skip"] * xc.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, lp["w_out"])
    out = lc(out, ("batch", "act_seq", "embed"), ctx.rules)
    if conv_state is not None:
        return out, (h_end, new_conv_state)
    return out


def _mamba_block(cfg: ModelConfig, lp, x, ctx: ShardCtx):
    return x + mamba_mixer(cfg, lp, rms_norm(x, lp["ln"]), ctx)


# --------------------------------------------------------------------------
def loss_fn(cfg: ModelConfig, params, batch, ctx: ShardCtx = LOCAL_CTX):
    x = _embed(cfg, params, batch["tokens"], ctx)

    def block(x, lp):
        return _mamba_block(cfg, lp, x, ctx), None

    body = jax.checkpoint(block) if cfg.remat else block
    x, _ = jax.lax.scan(body, x, params["blocks"], unroll=True if cfg.unroll_scans else 1)
    x = rms_norm(x, params["final_norm"])
    B, S, D = x.shape
    return chunked_cross_entropy(
        x.reshape(B * S, D), _unembed_weight(cfg, params),
        batch["labels"].reshape(B * S), chunk=min(cfg.xent_chunk, B * S),
        rules=ctx.rules, unroll=cfg.unroll_scans,
    )


def init_state_specs(cfg: ModelConfig, batch: int):
    di, ds, dc, L = cfg.d_inner, cfg.d_state, cfg.d_conv, cfg.n_layers
    return {
        "h": ParamSpec((L, batch, di, ds), ("layers", "batch", "mlp", "state"), jnp.float32, init="zeros"),
        "conv": ParamSpec((L, batch, dc - 1, di), ("layers", "batch", None, "mlp"), cfg.dtype, init="zeros"),
    }


def decode_step(cfg: ModelConfig, params, state, token, pos, ctx: ShardCtx = LOCAL_CTX):
    """SSM decode: O(1) state, no KV cache. token: [B, 1]."""
    x = _embed(cfg, params, token, ctx)

    def block(x, lp_state):
        lp, (h, conv) = lp_state
        xn = rms_norm(x, lp["ln"])
        out, (h2, conv2) = mamba_mixer(cfg, lp, xn, ctx, h0=h, conv_state=conv)
        return x + out, (h2, conv2)

    x, new_states = jax.lax.scan(
        block, x, (params["blocks"], (state["h"], state["conv"])),
        unroll=True if cfg.unroll_scans else 1,
    )
    x = rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, _unembed_weight(cfg, params))
    logits = lc(logits, ("batch", None, "vocab"), ctx.rules)
    return logits[:, 0], {"h": new_states[0], "conv": new_states[1]}


def prefill(cfg: ModelConfig, params, tokens, ctx: ShardCtx = LOCAL_CTX):
    x = _embed(cfg, params, tokens, ctx)

    def block(x, lp):
        return _mamba_block(cfg, lp, x, ctx), None

    x, _ = jax.lax.scan(block, x, params["blocks"], unroll=True if cfg.unroll_scans else 1)
    x = rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bd,dv->bv", x[:, -1], _unembed_weight(cfg, params))
    return lc(logits, ("batch", "vocab"), ctx.rules)
