"""Jamba-v0.1 hybrid: attention:mamba 1:7 interleave + MoE every other layer.

Structure (arXiv:2403.19887): 4 "Jamba blocks" of 8 layers each; within a
block, layer 4 is attention, the rest are Mamba mixers; odd layers carry MoE
FFNs, even layers dense FFNs. We scan over the 4 homogeneous super-blocks
(params stacked [4, ...] per position), so the HLO contains one unrolled
super-block.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..parallel.spec import ParamSpec, logical_constraint as lc
from .common import chunked_cross_entropy, rms_norm
from .config import ModelConfig
from .mamba import init_state_specs as _mamba_state_specs  # noqa: F401
from .mamba import mamba_layer_specs, mamba_mixer
from .transformer import (
    LOCAL_CTX,
    ShardCtx,
    _attn_specs,
    _decode_layer,
    _embed,
    _ffn_or_moe,
    _ffn_specs,
    _layer,
    _moe_specs,
    _unembed_weight,
)

N_SUPER_LAYERS = 8  # layers per Jamba block


def _pos_specs(cfg: ModelConfig, j: int, n_super: int) -> Dict[str, Any]:
    """Specs for position j within the super-block, stacked over n_super."""
    D = cfg.d_model
    is_attn = j == cfg.attn_phase
    is_moe = j % cfg.moe_period == cfg.moe_phase
    s: Dict[str, Any] = {}
    if is_attn:
        s["ln1"] = ParamSpec((n_super, D), ("layers", "embed"), jnp.float32, init="ones")
        s["attn"] = _attn_specs(cfg, n_super)
    else:
        s["mixer"] = mamba_layer_specs(cfg, n_super)
    s["ln2"] = ParamSpec((n_super, D), ("layers", "embed"), jnp.float32, init="ones")
    s["moe" if is_moe else "ffn"] = (
        _moe_specs(cfg, n_super) if is_moe else _ffn_specs(cfg, n_super)
    )
    return s


def param_specs(cfg: ModelConfig) -> Dict[str, Any]:
    assert cfg.n_layers % N_SUPER_LAYERS == 0
    n_super = cfg.n_layers // N_SUPER_LAYERS
    D, Vp = cfg.d_model, cfg.vocab_padded
    return {
        "embed": ParamSpec((Vp, D), ("vocab", "embed"), cfg.dtype),
        "final_norm": ParamSpec((D,), ("embed",), jnp.float32, init="ones"),
        "blocks": {
            f"pos{j}": _pos_specs(cfg, j, n_super) for j in range(N_SUPER_LAYERS)
        },
    }


def _super_block(cfg: ModelConfig, lps, x, ctx: ShardCtx):
    for j in range(N_SUPER_LAYERS):
        lp = lps[f"pos{j}"]
        is_moe = j % cfg.moe_period == cfg.moe_phase
        if j == cfg.attn_phase:
            x = _layer(cfg, lp, x, layer_global=True, is_moe=is_moe,
                       prefix=None, ctx=ctx)
        else:
            x = x + mamba_mixer(cfg, lp["mixer"], rms_norm(x, lp["mixer"]["ln"]), ctx)
            x = _ffn_or_moe(cfg, lp, x, is_moe, ctx)
    return x


def loss_fn(cfg: ModelConfig, params, batch, ctx: ShardCtx = LOCAL_CTX):
    x = _embed(cfg, params, batch["tokens"], ctx)

    def body(x, lps):
        return _super_block(cfg, lps, x, ctx), None

    body = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body, x, params["blocks"], unroll=True if cfg.unroll_scans else 1)
    x = rms_norm(x, params["final_norm"])
    B, S, D = x.shape
    return chunked_cross_entropy(
        x.reshape(B * S, D), _unembed_weight(cfg, params),
        batch["labels"].reshape(B * S), chunk=min(cfg.xent_chunk, B * S),
        rules=ctx.rules, unroll=cfg.unroll_scans,
    )


def init_cache_specs(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    """Per-position caches: KV for the attention position, SSM+conv states
    for mamba positions."""
    n_super = cfg.n_layers // N_SUPER_LAYERS
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    di, ds, dc = cfg.d_inner, cfg.d_state, cfg.d_conv
    caches: Dict[str, Any] = {}
    for j in range(N_SUPER_LAYERS):
        if j == cfg.attn_phase:
            caches[f"pos{j}"] = {
                "k": ParamSpec((n_super, batch, max_len, KV, hd),
                               ("layers", "batch", "kv_seq", "kv_heads", None), cfg.dtype, init="zeros"),
                "v": ParamSpec((n_super, batch, max_len, KV, hd),
                               ("layers", "batch", "kv_seq", "kv_heads", None), cfg.dtype, init="zeros"),
            }
        else:
            caches[f"pos{j}"] = {
                "h": ParamSpec((n_super, batch, di, ds), ("layers", "batch", "mlp", "state"), jnp.float32, init="zeros"),
                "conv": ParamSpec((n_super, batch, dc - 1, di), ("layers", "batch", None, "mlp"), cfg.dtype, init="zeros"),
            }
    return {"blocks": caches}


def decode_step(cfg: ModelConfig, params, cache, token, pos, ctx: ShardCtx = LOCAL_CTX):
    x = _embed(cfg, params, token, ctx)

    def body(x, lps_caches):
        lps, cch = lps_caches
        new_c: Dict[str, Any] = {}
        for j in range(N_SUPER_LAYERS):
            lp = lps[f"pos{j}"]
            is_moe = j % cfg.moe_period == cfg.moe_phase
            if j == cfg.attn_phase:
                x, new_c[f"pos{j}"] = _decode_layer(
                    cfg, lp, cch[f"pos{j}"], x, pos, layer_global=True,
                    is_moe=is_moe, ctx=ctx)
            else:
                xn = rms_norm(x, lp["mixer"]["ln"])
                out, (h2, conv2) = mamba_mixer(
                    cfg, lp["mixer"], xn, ctx,
                    h0=cch[f"pos{j}"]["h"], conv_state=cch[f"pos{j}"]["conv"])
                x = x + out
                x = _ffn_or_moe(cfg, lp, x, is_moe, ctx)
                new_c[f"pos{j}"] = {"h": h2, "conv": conv2}
        return x, new_c

    x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache["blocks"]),
                                unroll=True if cfg.unroll_scans else 1)
    x = rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bsd,dv->bsv", x, _unembed_weight(cfg, params))
    logits = lc(logits, ("batch", None, "vocab"), ctx.rules)
    return logits[:, 0], {"blocks": new_cache}


def prefill(cfg: ModelConfig, params, tokens, ctx: ShardCtx = LOCAL_CTX):
    x = _embed(cfg, params, tokens, ctx)

    def body(x, lps):
        return _super_block(cfg, lps, x, ctx), None

    x, _ = jax.lax.scan(body, x, params["blocks"], unroll=True if cfg.unroll_scans else 1)
    x = rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bd,dv->bv", x[:, -1], _unembed_weight(cfg, params))
    return lc(logits, ("batch", "vocab"), ctx.rules)
