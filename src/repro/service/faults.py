"""Seeded, deterministic fault injection for the collect→merge→refit→serve path.

The paper's premise is that storage misbehaves — transient errors, latency
spikes, torn writes, corrupted bytes — yet a collection/serving stack tested
only on healthy I/O rots the moment it meets a real heterogeneous fleet.
This module turns those faults into a *reproducible schedule*: a
:class:`FaultPlan` is a seeded set of :class:`FaultSpec` rules that fire at
named injection **sites** threaded through the stack:

========================  =====================================================
site prefix               where it is checked
========================  =====================================================
``case:<case_id>``        campaign case execution (``data/campaign.py``), just
                          before the executor runs — ``io_error`` / ``latency``
``append:<file>``         the campaign runner's durable JSONL append —
                          ``enospc`` (write refused) / ``torn_write`` (partial
                          line lands, then the write is repaired and retried)
``log:<file>``            ``LoopState``/``FleetLog`` appends (``state.py``) —
                          ``corrupt_line`` injects a garbage JSONL line the
                          readers must skip-and-count
``read:<backend>``        ``StorageBackend.read_block`` (``data/storage.py``),
                          which every ``formats.py`` reader goes through —
                          ``io_error`` / ``latency``
========================  =====================================================

Fault kinds and who heals them:

- ``io_error``   transient ``FaultInjected`` (an ``IOError``) — healed by the
  campaign runner's bounded retries with exponential backoff.
- ``latency``    a deterministic sleep — healed by nobody; per-case deadlines
  (``--case-deadline``) bound the damage.
- ``enospc``     ``OSError(ENOSPC)`` on append — healed by the durable-append
  retry (nothing was written, write again).
- ``torn_write`` a partial line is written and flushed — healed by the
  durable-append recovery (truncate back to the record boundary, rewrite), so
  the shard file holds the complete record exactly once.
- ``corrupt_line`` a garbage line is appended *before* a real log record —
  healed by every JSONL reader skipping and counting malformed complete lines.

Scheduling is deterministic two ways: ``every=k`` fires on every k-th check of
a (kind, site-class) stream — the chaos-equivalence tests and ``make
chaos-smoke`` use this, because with ``k >= 2`` two consecutive checks never
both fire, so one retry always heals an injected failure and the merged
dataset provably matches a fault-free run.  ``rate=r`` draws from a per-stream
``numpy`` RNG seeded by ``seed ^ crc32(kind:site-class)`` — order-independent
across sites, reproducible under any thread interleaving within a site.

Activation is process-global (``activate()``/``deactivate()``) and installs
lightweight hooks into the data layer (``storage.set_fault_hook``,
``campaign.set_fault_hook``) so ``repro.data`` never imports this package.
``activate()`` also exports the plan to ``REPRO_FAULT_PLAN`` in this process's
environment, and spawned fleet collectors (which inherit the environment)
re-activate it via :func:`activate_from_env` — one fixed seed drives the whole
fleet.  Every injection is counted per (kind, site); ``FaultPlan.report()`` is
the ledger the chaos tests reconcile against the provenance counters
(retried / timed-out / quarantined / write-retries / corrupt-lines).
"""

from __future__ import annotations

import dataclasses
import errno
import json
import os
import threading
import time
import zlib
from typing import Dict, List, Optional, Tuple

import numpy as np

__all__ = [
    "FAULT_KINDS",
    "FaultInjected",
    "FaultSpec",
    "FaultPlan",
    "default_plan",
    "activate",
    "deactivate",
    "active_plan",
    "activate_from_env",
    "ENV_VAR",
]

ENV_VAR = "REPRO_FAULT_PLAN"

FAULT_KINDS = ("io_error", "latency", "enospc", "torn_write", "corrupt_line")


class FaultInjected(IOError):
    """The transient error the plan raises — an ``IOError`` subclass so the
    campaign runner's taxonomy classifies it transient and retries it."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One injection rule: fire ``kind`` at sites matching ``site`` (prefix).

    Exactly one of ``every``/``rate`` schedules it: ``every=k`` fires each
    k-th check of the (kind, site-class) stream (deterministic; ``k >= 2``
    guarantees a single retry heals it); ``rate=r`` fires each check with
    probability ``r`` from a seeded per-stream RNG.  ``max_injections`` caps
    total fires for this spec (``None`` = unlimited)."""

    kind: str
    site: str = ""                 # prefix match; "" matches every site
    every: int = 0
    rate: float = 0.0
    latency_s: float = 0.02        # sleep per fire (latency kind only)
    max_injections: Optional[int] = None

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(one of {FAULT_KINDS})")
        if (self.every > 0) == (self.rate > 0):
            raise ValueError("exactly one of every/rate must be positive")
        if self.kind in ("io_error", "enospc", "torn_write") and \
                0 < self.every < 2:
            raise ValueError(f"{self.kind}: every must be >= 2 so a bounded "
                             "retry can always heal the injected failure")


def _stream_key(site: str) -> str:
    """Site-class a spec's counter/RNG stream is keyed on: the ``prefix:``
    class, so e.g. every ``case:*`` check of one spec shares one schedule
    regardless of which case is being checked — the schedule depends only on
    how many checks happened, never on case naming."""
    return site.split(":", 1)[0]


class FaultPlan:
    """A seeded set of fault specs with per-stream deterministic schedules
    and an injection ledger.  Thread-safe: streams advance under one lock."""

    def __init__(self, seed: int, specs: List[FaultSpec]):
        self.seed = int(seed)
        self.specs = list(specs)
        self._lock = threading.Lock()
        self._counters: Dict[Tuple[int, str], int] = {}
        self._rngs: Dict[Tuple[int, str], np.random.Generator] = {}
        self._fired: Dict[Tuple[int, str], int] = {}  # per (spec idx, class)
        self.injected: Dict[Tuple[str, str], int] = {}  # (kind, site) -> n

    # -- scheduling ----------------------------------------------------
    def _fire(self, i: int, spec: FaultSpec, site: str) -> bool:
        cls = _stream_key(site)
        key = (i, cls)
        with self._lock:
            if spec.max_injections is not None and \
                    self._fired.get(key, 0) >= spec.max_injections:
                return False
            if spec.every > 0:
                n = self._counters.get(key, 0) + 1
                self._counters[key] = n
                fire = n % spec.every == 0
            else:
                rng = self._rngs.get(key)
                if rng is None:
                    s = self.seed ^ zlib.crc32(f"{spec.kind}:{cls}".encode())
                    rng = np.random.default_rng(s)
                    self._rngs[key] = rng
                fire = bool(rng.random() < spec.rate)
            if fire:
                self._fired[key] = self._fired.get(key, 0) + 1
                sk = (spec.kind, site)
                self.injected[sk] = self.injected.get(sk, 0) + 1
            return fire

    def _check(self, site: str, kinds: Tuple[str, ...]) -> List[FaultSpec]:
        fired = []
        for i, spec in enumerate(self.specs):
            if spec.kind in kinds and site.startswith(spec.site):
                if self._fire(i, spec, site):
                    fired.append(spec)
        return fired

    # -- site hooks ----------------------------------------------------
    def on_case(self, site: str) -> None:
        """Campaign case-execution site: sleep for latency fires, then raise
        on an io_error fire (the executor never runs that attempt)."""
        for spec in self._check(site, ("latency", "io_error")):
            if spec.kind == "latency":
                time.sleep(spec.latency_s)
            else:
                raise FaultInjected(f"injected transient I/O error at {site}")

    def on_storage(self, site: str, nbytes: int) -> None:
        """Storage read site (``StorageBackend.read_block``)."""
        self.on_case(site)  # same kinds, same semantics

    def check_append(self, site: str) -> Optional[int]:
        """Durable-append site.  Raises ``OSError(ENOSPC)`` for an enospc
        fire; returns a tear offset (bytes of the line that will land) for a
        torn_write fire; returns ``None`` for a clean write.

        The two kinds are checked in sequence, torn_write only when enospc
        did not fire (each spec keeps its own stream, so schedules stay
        deterministic): one check then injects at most one write fault, so
        every ledger entry is exactly one durable-append recovery — the
        accounting identity the chaos tests reconcile."""
        if self._check(site, ("enospc",)):
            raise OSError(errno.ENOSPC, f"injected ENOSPC at {site}")
        if self._check(site, ("torn_write",)):
            return 1 + zlib.crc32(f"{site}:{self.seed}".encode()) % 16
        return None

    def corrupt_line(self, site: str) -> Optional[str]:
        """Log-append site: a garbage JSONL line to write before the real
        record, or ``None``.  Readers must skip and count it."""
        if self._check(site, ("corrupt_line",)):
            return '{"injected": "corrupt", truncated-not-json'
        return None

    # -- accounting / serialization ------------------------------------
    def total_injected(self, kind: Optional[str] = None) -> int:
        with self._lock:
            return sum(n for (k, _s), n in self.injected.items()
                       if kind is None or k == kind)

    def report(self) -> dict:
        """The injection ledger: totals per kind and per (kind, site)."""
        with self._lock:
            by_kind: Dict[str, int] = {}
            for (k, _s), n in self.injected.items():
                by_kind[k] = by_kind.get(k, 0) + n
            return {
                "seed": self.seed,
                "total": sum(self.injected.values()),
                "by_kind": dict(sorted(by_kind.items())),
                "by_site": {f"{k}@{s}": n for (k, s), n
                            in sorted(self.injected.items())},
            }

    def to_json(self) -> str:
        return json.dumps({
            "seed": self.seed,
            "specs": [dataclasses.asdict(s) for s in self.specs],
        }, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "FaultPlan":
        obj = json.loads(text)
        return cls(obj["seed"], [FaultSpec(**s) for s in obj["specs"]])


def default_plan(seed: int, rate: float = 0.0, every: int = 0,
                 latency_s: float = 0.02) -> FaultPlan:
    """The standard chaos mix (what ``--chaos-seed`` activates): one spec of
    every fault kind across every site class it applies to.  With neither
    ``rate`` nor ``every`` given, defaults to ``every=5`` — the deterministic
    schedule whose injected failures a single retry always heals."""
    if rate <= 0 and every <= 0:
        every = 5
    kw = {"every": every} if every > 0 else {"rate": rate}
    return FaultPlan(seed, [
        FaultSpec("io_error", site="case:", **kw),
        FaultSpec("latency", site="case:", latency_s=latency_s, **kw),
        # read: checks fire once per *block read*, and one case attempt makes
        # many of them — unbudgeted, an every=k schedule would re-fire on
        # every retry of a real-I/O case and no bounded retry could ever
        # heal it.  A small budget keeps the retry path exercised while
        # guaranteeing the schedule drains.  (latency stays unbudgeted:
        # it is non-fatal, bounded by --case-deadline.)
        FaultSpec("io_error", site="read:", max_injections=2, **kw),
        FaultSpec("latency", site="read:", latency_s=latency_s, **kw),
        FaultSpec("enospc", site="append:", **kw),
        FaultSpec("torn_write", site="append:", **kw),
        FaultSpec("corrupt_line", site="log:", **kw),
    ])


# ---------------------------------------------------------------- activation

_active: Optional[FaultPlan] = None
_active_lock = threading.Lock()


def _install_hooks(plan: Optional[FaultPlan]) -> None:
    from ..data import campaign, storage

    storage.set_fault_hook(plan.on_storage if plan is not None else None)
    campaign.set_fault_hook(plan if plan is not None else None)


def activate(plan: FaultPlan, export_env: bool = True) -> FaultPlan:
    """Install ``plan`` process-wide: data-layer hooks + (by default) the
    ``REPRO_FAULT_PLAN`` environment export that spawned fleet collectors
    inherit and re-activate."""
    global _active
    with _active_lock:
        _active = plan
        _install_hooks(plan)
        if export_env:
            os.environ[ENV_VAR] = plan.to_json()
    return plan


def deactivate() -> None:
    """Remove the active plan, its hooks, and the environment export."""
    global _active
    with _active_lock:
        _active = None
        _install_hooks(None)
        os.environ.pop(ENV_VAR, None)


def active_plan() -> Optional[FaultPlan]:
    return _active


def activate_from_env() -> Optional[FaultPlan]:
    """Activate the plan exported by a parent process (fleet collectors call
    this at startup), if any.  Each process gets its own schedule state —
    determinism holds per process, and the chaos invariants are end-state
    properties (merged bytes, accounted counters), not per-fire alignment."""
    text = os.environ.get(ENV_VAR)
    if not text:
        return None
    return activate(FaultPlan.from_json(text), export_env=False)
