"""Recommendation-as-a-service: a concurrent HTTP serving tier over the
predictor (the ROADMAP's "millions of users" query path).

The loop/fleet (``loop.py``/``fleet.py``) keep the model fresh; this module
answers queries about it, many clients at a time, from one long-running
stdlib-only process (``http.server.ThreadingHTTPServer`` — no new deps):

- ``POST /predict``    — predicted MB/s for one (context, config) pair
- ``POST /recommend``  — ranked top-k configs for a workload context
- ``GET  /explain``    — fitted-model feature importances + knob grid
- ``GET  /healthz``    — liveness, fitted flag, model generation
- ``GET  /stats``      — request/batch/cache counters + loop cycle log

Core mechanics, in the order a request meets them:

1. **Response cache** — a bounded LRU keyed by (endpoint, model generation,
   order-insensitive context hash).  The generation in the key is what makes
   refit invalidation *atomic*: the instant a refit publishes, lookups move
   to the new generation and every stale entry becomes unreachable.
2. **Micro-batching** — cache misses enqueue into a collector that drains
   whatever is concurrently queued (up to ``max_batch``, optionally waiting
   ``batch_window_ms``) and scores the whole batch against ONE model
   snapshot: predict rows stack into a single vectorized
   ``predict_throughput_batch`` call (amortizing per-call dispatch ~10x for
   the paper GBT), and recommend requests sharing a context hash collapse
   into a single cached-grid scoring.  Serializing scoring through one
   worker is also what lets it reuse the ``ConfigSpace`` cached feature
   matrix zero-copy — the unbatched mode must serialize on a lock instead.
3. **Hot swap** — ``OnlineAutotuner.maybe_refit`` builds the new model off
   to the side and publishes (model, generation) in one atomic swap;
   ``snapshot()`` pins that pair per batch, so a response can never mix
   model generations and in-flight batches finish on the model they started
   with.  The embedded continuous loop (``--loop``) drives refits in a
   background thread while requests are served.

Responses are canonical JSON (sorted keys, fixed separators) and scoring is
per-row deterministic for the tree models, so N concurrent batched requests
return byte-identical bodies to N serial ones — asserted by
``tests/test_serve.py``; load numbers live in ``BENCH_serve.json``
(``benchmarks/serve_bench.py``).

CLI::

    python -m repro.service.serve --smoke                   # self-test
    python -m repro.service.serve --warm-from merged.jsonl  # frozen model
    python -m repro.service.serve --loop --fast --cycles 6  # serve + tune
    python -m repro.service.serve --status                  # loop audit log

See ``docs/serving.md``.
"""

from __future__ import annotations

import argparse
import dataclasses
import http.client
import http.server
import json
import os
import pathlib
import queue
import sys
import threading
import time
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core.autotune import KNOB_NAMES, ConfigSpace, OnlineAutotuner, recommend
from ..core.ensemble_base import ceil_pow2
from ..core.features import TARGET_NAME
from ._cli import add_chaos_args, add_serve_args, add_tuning_args, \
    chaos_plan_from_args
from .state import LoopState

__all__ = [
    "ServeConfig",
    "RecommendationService",
    "ResponseCache",
    "MicroBatcher",
    "context_key",
    "main",
    "DEFAULT_SERVE_DIR",
]

DEFAULT_SERVE_DIR = pathlib.Path("/tmp/repro_io/serve")


def _json_bytes(obj) -> bytes:
    """Canonical response encoding: key order and separators are fixed so the
    same result is the same bytes — the batched-vs-sequential equivalence
    and cache-hit-vs-cold tests compare raw bodies."""
    return json.dumps(obj, sort_keys=True, separators=(",", ":")).encode()


def context_key(mapping: Optional[dict]) -> tuple:
    """Order-insensitive canonical key for a context/knob dict.

    ``{"a": 1, "b": 2}`` and ``{"b": 2, "a": 1}`` hash identically; numeric
    values are canonicalized through ``float`` so ``1`` and ``1.0`` (JSON
    clients disagree about this constantly) share a cache line."""
    if not mapping:
        return ()
    items = []
    for k, v in mapping.items():
        if isinstance(v, bool) or not isinstance(v, (int, float)):
            items.append((str(k), repr(v)))
        else:
            items.append((str(k), float(v)))
    return tuple(sorted(items))


class ResponseCache:
    """Bounded, thread-safe LRU for serialized response bodies.

    Keys embed the model generation (see ``RecommendationService._cache_key``)
    — a refit makes every previous generation's entries unreachable in the
    same atomic swap that publishes the new model, so a stale response can
    never be served after the swap completes.  The LRU bound then evicts the
    dead generation's bytes as fresh traffic arrives."""

    def __init__(self, capacity: int = 1024):
        self.capacity = int(capacity)
        self._lock = threading.Lock()
        self._data: "OrderedDict[tuple, bytes]" = OrderedDict()
        self.hits = 0
        self.misses = 0

    def get(self, key: tuple) -> Optional[bytes]:
        with self._lock:
            body = self._data.get(key)
            if body is None:
                self.misses += 1
                return None
            self._data.move_to_end(key)
            self.hits += 1
            return body

    def put(self, key: tuple, body: bytes) -> None:
        if self.capacity <= 0:
            return
        with self._lock:
            self._data[key] = body
            self._data.move_to_end(key)
            while len(self._data) > self.capacity:
                self._data.popitem(last=False)

    def __len__(self) -> int:
        with self._lock:
            return len(self._data)


class _Pending:
    """One enqueued request: inputs pre-featurized on the handler thread,
    result delivered through an event by the scorer."""

    __slots__ = ("kind", "ctx_key", "row", "filtered", "top_k", "event",
                 "status", "body", "deadline")

    def __init__(self, kind: str, ctx_key: tuple, row=None, filtered=None,
                 top_k: int = 0):
        self.kind = kind
        self.ctx_key = ctx_key
        self.row = row              # predict: [F] feature row
        self.filtered = filtered    # recommend: filtered context dict
        self.top_k = top_k
        self.event = threading.Event()
        self.status = 500
        self.body = b'{"error":"internal"}'
        self.deadline = None        # monotonic budget set by _serve_scored

    def finish(self, status: int, body: bytes) -> None:
        self.status = status
        self.body = body
        self.event.set()


_STOP = object()


class MicroBatcher:
    """Coalesces concurrent requests into single vectorized scoring calls.

    The worker takes the first queued request, drains whatever else is
    already waiting (optionally holding the door open ``window_s``), and
    hands the whole batch to ``score_fn`` — which scores it against exactly
    one model snapshot.  Under load, requests pile up while the worker
    scores, so batches form naturally without adding idle latency.

    ``stop()`` drains: everything submitted before the close wins a result
    before the worker exits (the graceful-shutdown guarantee).

    The queue is **bounded** (``max_queue``): past that depth the service is
    not keeping up, and letting the backlog grow only converts overload into
    unbounded client latency and coordinator memory.  ``submit`` raises
    ``queue.Full`` instead of enqueueing — the caller sheds the request with
    a 503 + ``Retry-After`` so clients back off (``docs/robustness.md``).
    ``max_queue=0`` disables the bound."""

    def __init__(self, score_fn, max_batch: int = 64, window_s: float = 0.0,
                 max_queue: int = 1024):
        self._score_fn = score_fn
        self.max_batch = max(1, int(max_batch))
        self.window_s = float(window_s)
        self.max_queue = max(0, int(max_queue))
        self._q: "queue.Queue" = queue.Queue()
        self._lock = threading.Lock()
        self._closed = False
        self.n_batches = 0
        self.n_scored = 0
        self.max_batch_seen = 0
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="serve-batcher")
        self._thread.start()

    def submit(self, pending: _Pending) -> bool:
        """Enqueue for scoring.  False = closed (shutting down); raises
        ``queue.Full`` when the admission bound is hit (caller sheds)."""
        with self._lock:
            if self._closed:
                return False
            if self.max_queue and self._q.qsize() >= self.max_queue:
                raise queue.Full
            self._q.put(pending)
            return True

    @property
    def depth(self) -> int:
        """Approximate queued-request count (admission/stats reporting)."""
        return self._q.qsize()

    def _collect(self, first) -> Tuple[List[_Pending], bool]:
        batch = [first]
        saw_stop = False
        deadline = time.monotonic() + self.window_s
        while len(batch) < self.max_batch:
            try:
                if self.window_s > 0:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        break
                    item = self._q.get(timeout=remaining)
                else:
                    item = self._q.get_nowait()
            except queue.Empty:
                break
            if item is _STOP:
                saw_stop = True
                break
            batch.append(item)
        return batch, saw_stop

    def _score(self, batch: List[_Pending]) -> None:
        self.n_batches += 1
        self.n_scored += len(batch)
        self.max_batch_seen = max(self.max_batch_seen, len(batch))
        try:
            self._score_fn(batch)
        except Exception as e:  # noqa: BLE001 — a scoring bug must not hang clients
            body = _json_bytes({"error": f"{type(e).__name__}: {e}"})
            for p in batch:
                if not p.event.is_set():
                    p.finish(500, body)

    def _run(self) -> None:
        while True:
            item = self._q.get()
            if item is _STOP:
                break
            batch, saw_stop = self._collect(item)
            self._score(batch)
            if saw_stop:
                break
        # drain everything enqueued before the close (FIFO: all real items
        # precede the sentinel, so nothing submitted successfully is lost)
        leftover: List[_Pending] = []
        while True:
            try:
                item = self._q.get_nowait()
            except queue.Empty:
                break
            if item is not _STOP:
                leftover.append(item)
        for i in range(0, len(leftover), self.max_batch):
            self._score(leftover[i:i + self.max_batch])

    def stop(self) -> None:
        """Close to new submissions, drain the queue, join the worker."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
        self._q.put(_STOP)
        self._thread.join()

    @property
    def mean_batch(self) -> float:
        return self.n_scored / self.n_batches if self.n_batches else 0.0


@dataclasses.dataclass
class ServeConfig:
    """Serving-tier knobs (CLI flags mirror these; see ``add_serve_args``)."""

    host: str = "127.0.0.1"
    port: int = 0                 # 0 = OS-assigned ephemeral port
    batching: bool = True         # False: score inline per request (baseline)
    max_batch: int = 64
    batch_window_ms: float = 0.0  # >0: hold the batch open for stragglers
    max_queue: int = 1024         # admission bound; past it requests shed 503
    deadline_ms: float = 60000.0  # per-request queue+scoring budget -> 504
    cache_size: int = 1024        # 0 disables the response cache
    top_k: int = 5                # default /recommend depth
    out_dir: Optional[pathlib.Path] = None  # serve_info.json + loop state home

    def __post_init__(self):
        if self.out_dir is not None:
            self.out_dir = pathlib.Path(self.out_dir)


class RecommendationService:
    """The serving tier: HTTP front, cache, micro-batcher, model hot-swap.

    ``tuner`` is the live model source (its ``snapshot()``/``generation`` are
    the swap point); pass ``loop`` (a ``ContinuousTuningLoop`` sharing that
    tuner) to drive collect→refit cycles in a background thread while
    serving.  ``handle()`` is a pure (method, path, body) → (status, bytes)
    function, so the routing/scoring logic is testable without sockets."""

    def __init__(
        self,
        tuner: OnlineAutotuner,
        cfg: Optional[ServeConfig] = None,
        loop=None,
        progress=None,
    ):
        self.cfg = cfg or ServeConfig()
        self.tuner = tuner
        self.loop = loop
        if loop is not None and loop.tuner is not tuner:
            raise ValueError("loop and service must share one OnlineAutotuner "
                             "(pass loop.tuner as tuner)")
        self._progress = progress
        self.cache = ResponseCache(self.cfg.cache_size)
        # Private grid: scoring rewrites the cached feature matrix's context
        # columns in place, and the embedded loop's own ranked() call uses
        # tuner.space concurrently — each side gets its own cache.
        self.space = ConfigSpace(
            **{k: getattr(tuner.space, k) for k in KNOB_NAMES})
        self._score_lock = threading.Lock()
        self._batcher: Optional[MicroBatcher] = None
        self._httpd: Optional[http.server.ThreadingHTTPServer] = None
        self._http_thread: Optional[threading.Thread] = None
        self._loop_thread: Optional[threading.Thread] = None
        self.loop_error: Optional[str] = None
        self._draining = False
        self._started = 0.0
        self._counter_lock = threading.Lock()
        self._requests: Dict[str, int] = {}
        self._errors = 0
        self._shed = 0       # 503s from the admission bound (queue full)
        self._timeouts = 0   # 504s from the per-request deadline budget
        self._active = 0
        self._idle = threading.Condition(self._counter_lock)

    # -- lifecycle ------------------------------------------------------
    def _log(self, msg: str) -> None:
        if self._progress is not None:
            self._progress(msg)

    def start(self) -> None:
        """Bind the port, start the batcher, the HTTP thread, and (if
        configured) the embedded tuning-loop thread."""
        self._started = time.time()
        if self.cfg.batching:
            self._batcher = MicroBatcher(
                self._score_batch, max_batch=self.cfg.max_batch,
                window_s=self.cfg.batch_window_ms / 1e3,
                max_queue=self.cfg.max_queue)
        handler = _make_handler(self)
        self._httpd = _Server((self.cfg.host, self.cfg.port), handler)
        self._http_thread = threading.Thread(
            target=self._httpd.serve_forever, kwargs={"poll_interval": 0.05},
            daemon=True, name="serve-http")
        self._http_thread.start()
        if self.loop is not None:
            self._loop_thread = threading.Thread(
                target=self._run_loop, daemon=True, name="serve-loop")
            self._loop_thread.start()
        if self.cfg.out_dir is not None:
            self.cfg.out_dir.mkdir(parents=True, exist_ok=True)
            (self.cfg.out_dir / "serve_info.json").write_text(json.dumps({
                "host": self.cfg.host, "port": self.port, "pid": os.getpid(),
            }) + "\n")
        self._log(f"listening on http://{self.cfg.host}:{self.port} "
                  f"(batching={self.cfg.batching}, cache={self.cfg.cache_size})")

    def _run_loop(self) -> None:
        try:
            self.loop.run()
            self._log("embedded loop: all cycles complete")
        except Exception as e:  # noqa: BLE001 — serving outlives a loop crash
            self.loop_error = f"{type(e).__name__}: {e}"
            self._log(f"embedded loop failed: {self.loop_error}")

    @property
    def port(self) -> int:
        assert self._httpd is not None, "start() first"
        return self._httpd.server_address[1]

    def shutdown(self, timeout: float = 10.0) -> None:
        """Graceful: stop accepting, drain queued + in-flight requests (each
        gets its response), then close the socket."""
        self._draining = True
        if self._httpd is not None:
            self._httpd.shutdown()  # stop the accept loop
        if self._batcher is not None:
            self._batcher.stop()  # score everything already queued
        deadline = time.monotonic() + timeout
        with self._idle:
            while self._active > 0 and time.monotonic() < deadline:
                self._idle.wait(timeout=0.1)
        if self._httpd is not None:
            self._httpd.server_close()

    # -- scoring --------------------------------------------------------
    def _snapshot(self):
        return self.tuner.snapshot()

    def _predict_pending(self, context: dict, config: dict) -> _Pending:
        feats = self.tuner.filter_context(context, knobs=config)
        return _Pending(
            "predict",
            ctx_key=(context_key(context), context_key(config)),
            row=self.tuner.spec.row(feats),
        )

    def _recommend_pending(self, context: dict, top_k: int) -> _Pending:
        return _Pending(
            "recommend",
            ctx_key=(context_key(context),),
            filtered=self.tuner.filter_context(context),
            top_k=top_k,
        )

    def _score_batch(self, batch: List[_Pending]) -> None:
        """Score one micro-batch against ONE model snapshot.

        All responses of a batch carry the same ``model_generation`` — a
        refit landing mid-batch affects only later batches (the snapshot
        pins the model; see ``PredictorSnapshot``).  Predict rows become one
        stacked ``predict_throughput_batch`` call; recommend requests with
        equal context hashes share one grid scoring."""
        with self._score_lock:
            snap = self._snapshot()
            if snap is None:
                body = _json_bytes({"error": "model not fitted yet",
                                    "model_generation": 0})
                for p in batch:
                    p.finish(503, body)
                return
            predicts = [p for p in batch if p.kind == "predict"]
            recs = [p for p in batch if p.kind == "recommend"]
            if predicts:
                X = np.stack([p.row for p in predicts])
                # pad to power-of-two row counts: the tree ensembles re-jit
                # per input shape, and free-form batch sizes would recompile
                # (hundreds of ms) on nearly every batch under load; buckets
                # bound the shape set to log2(max_batch).  Per-row outputs
                # are independent, so padding never changes a real row.
                bucket = ceil_pow2(len(predicts))
                if bucket != len(predicts):
                    X = np.concatenate(
                        [X, np.repeat(X[-1:], bucket - len(predicts), axis=0)])
                vals = snap.predict_throughput_batch(X)[: len(predicts)]
                for p, v in zip(predicts, vals):
                    p.finish(200, _json_bytes({
                        "model_generation": snap.generation,
                        "predicted_throughput_mb_s": float(v),
                    }))
            groups: Dict[tuple, List[_Pending]] = {}
            for p in recs:
                groups.setdefault(p.ctx_key + (p.top_k,), []).append(p)
            for group in groups.values():
                lead = group[0]
                top = recommend(snap, lead.filtered, self.space,
                                top_k=lead.top_k)
                body = _json_bytes({"model_generation": snap.generation,
                                    "top": top})
                for p in group:
                    p.finish(200, body)

    def _dispatch(self, pending: _Pending) -> None:
        """Batched mode: admit (or shed), enqueue, and wait out the request's
        remaining deadline budget; unbatched: score inline (still serialized —
        the grid cache is shared scorer state either way)."""
        if self._batcher is not None:
            try:
                admitted = self._batcher.submit(pending)
            except queue.Full:
                # overload: shed instead of queueing unboundedly — clients
                # retry after backoff (Retry-After is set by the HTTP layer)
                with self._counter_lock:
                    self._shed += 1
                pending.finish(503, _json_bytes(
                    {"error": "overloaded: scoring queue full",
                     "retry_after_s": 1}))
                return
            if not admitted:
                pending.finish(503, _json_bytes({"error": "shutting down",
                                                 "retry_after_s": 1}))
                return
            budget = (pending.deadline - time.monotonic()
                      if pending.deadline is not None
                      else self.cfg.deadline_ms / 1e3)
            if not pending.event.wait(timeout=max(0.0, budget)):
                with self._counter_lock:
                    self._timeouts += 1
                pending.finish(504, _json_bytes(
                    {"error": "deadline exceeded before scoring finished"}))
            return
        self._score_batch([pending])

    # -- endpoints ------------------------------------------------------
    def _cache_key(self, endpoint: str, pending: _Pending) -> tuple:
        return (endpoint, self.tuner.generation, pending.top_k) + pending.ctx_key

    def _serve_scored(self, endpoint: str, pending: _Pending) -> Tuple[int, bytes]:
        key = self._cache_key(endpoint, pending)
        if self.cfg.cache_size > 0:
            body = self.cache.get(key)
            if body is not None:
                return 200, body
        if self.cfg.deadline_ms > 0:
            pending.deadline = time.monotonic() + self.cfg.deadline_ms / 1e3
        self._dispatch(pending)
        if pending.status == 200 and self.cfg.cache_size > 0:
            # re-derive the key from the response's generation: a swap racing
            # this request must not file a new-model response under the old
            # generation (the reverse — old result under new key — cannot
            # happen: the snapshot is taken after the lookup's generation read)
            gen = json.loads(pending.body)["model_generation"]
            self.cache.put((endpoint, gen, pending.top_k) + pending.ctx_key,
                           pending.body)
        return pending.status, pending.body

    def _predict(self, payload: dict) -> Tuple[int, bytes]:
        context = payload.get("context", {})
        config = payload.get("config", {})
        if not isinstance(context, dict) or not isinstance(config, dict):
            return 400, _json_bytes({"error": "context/config must be objects"})
        return self._serve_scored("predict", self._predict_pending(context, config))

    def _recommend(self, payload: dict) -> Tuple[int, bytes]:
        context = payload.get("context", {})
        if not isinstance(context, dict):
            return 400, _json_bytes({"error": "context must be an object"})
        top_k = payload.get("top_k", self.cfg.top_k)
        if not isinstance(top_k, int) or top_k < 1:
            return 400, _json_bytes({"error": "top_k must be a positive integer"})
        return self._serve_scored("recommend", self._recommend_pending(context, top_k))

    def _explain(self) -> Tuple[int, bytes]:
        snap = self._snapshot()
        if snap is None:
            return 503, _json_bytes({"error": "model not fitted yet",
                                     "model_generation": 0})
        imp = snap.feature_importances_
        names = list(snap.spec.names)
        features = [
            {"name": n,
             "importance": (float(imp[i]) if imp is not None else None)}
            for i, n in enumerate(names)
        ]
        return 200, _json_bytes({
            "model": snap.model_name,
            "model_generation": snap.generation,
            "n_observations": self.tuner.n_observations,
            "features": features,
            "knobs": {k: list(getattr(self.space, k)) for k in KNOB_NAMES},
        })

    def _healthz(self) -> Tuple[int, bytes]:
        """Liveness + circuit state.  Always 200 (the process is serving);
        ``status`` degrades to "degraded" when the embedded loop thread died
        on an error or the model was rolled back to its previous generation —
        the service still answers, but its freshness pipeline is broken and
        an operator/orchestrator should look (``docs/robustness.md``)."""
        loop_dead = (self._loop_thread is not None
                     and not self._loop_thread.is_alive()
                     and self.loop_error is not None)
        degraded = loop_dead or bool(getattr(self.tuner, "degraded", False))
        status = ("draining" if self._draining
                  else "degraded" if degraded else "ok")
        return 200, _json_bytes({
            "status": status,
            "fitted": self.tuner.fitted,
            "model_generation": self.tuner.generation,
            "circuit": {
                "loop_alive": (self._loop_thread.is_alive()
                               if self._loop_thread is not None else None),
                "loop_error": self.loop_error,
                "model_degraded": bool(getattr(self.tuner, "degraded", False)),
                "rollbacks": int(getattr(self.tuner, "rollbacks", 0)),
            },
        })

    def _loop_stats(self) -> Optional[dict]:
        if self.loop is None and self.cfg.out_dir is None:
            return None
        state_path = (self.loop.state.path if self.loop is not None
                      else self.cfg.out_dir / "loop_state.jsonl")
        # read_complete_records under the hood: safe against the loop thread
        # appending a record mid-read
        cycles = LoopState(state_path).cycles()
        out = {
            "cycles_completed": len(cycles),
            "running": self._loop_thread.is_alive() if self._loop_thread else False,
            "error": self.loop_error,
        }
        if cycles:
            last = cycles[-1]
            out["last_cycle"] = {
                "cycle": last.get("cycle"),
                "n_observations": last.get("n_observations"),
                "refit": last.get("refit"),
                "drift": last.get("drift"),
                "current_config": last.get("current_config"),
            }
        return out

    def _stats(self) -> Tuple[int, bytes]:
        with self._counter_lock:
            requests = dict(self._requests)
            errors = self._errors
            shed = self._shed
            timeouts = self._timeouts
        stats = {
            "uptime_s": round(time.time() - self._started, 3),
            "model_generation": self.tuner.generation,
            "fitted": self.tuner.fitted,
            "n_observations": self.tuner.n_observations,
            "requests": requests,
            "errors": errors,
            "batching": {
                "enabled": self.cfg.batching,
                "n_batches": self._batcher.n_batches if self._batcher else 0,
                "n_scored": self._batcher.n_scored if self._batcher else 0,
                "max_batch": self._batcher.max_batch_seen if self._batcher else 0,
                "mean_batch": round(self._batcher.mean_batch, 3) if self._batcher else 0.0,
            },
            "admission": {
                "max_queue": self.cfg.max_queue,
                "queue_depth": self._batcher.depth if self._batcher else 0,
                "shed": shed,
                "deadline_ms": self.cfg.deadline_ms,
                "deadline_timeouts": timeouts,
            },
            "cache": {
                "capacity": self.cfg.cache_size,
                "size": len(self.cache),
                "hits": self.cache.hits,
                "misses": self.cache.misses,
            },
            "loop": self._loop_stats(),
        }
        return 200, _json_bytes(stats)

    # -- routing --------------------------------------------------------
    def handle(self, method: str, path: str, body: bytes) -> Tuple[int, bytes]:
        """(method, path, body) -> (status, canonical-JSON bytes)."""
        path = path.split("?", 1)[0].rstrip("/") or "/"
        with self._counter_lock:
            self._requests[path] = self._requests.get(path, 0) + 1
            self._active += 1
        try:
            if method == "GET" and path == "/healthz":
                return self._healthz()
            if method == "GET" and path == "/stats":
                return self._stats()
            if method == "GET" and path == "/explain":
                return self._explain()
            if method == "POST" and path in ("/predict", "/recommend"):
                try:
                    payload = json.loads(body or b"{}")
                except json.JSONDecodeError as e:
                    return 400, _json_bytes({"error": f"invalid JSON: {e}"})
                if not isinstance(payload, dict):
                    return 400, _json_bytes({"error": "body must be a JSON object"})
                if path == "/predict":
                    return self._predict(payload)
                return self._recommend(payload)
            return 404, _json_bytes({"error": f"no route for {method} {path}"})
        except Exception as e:  # noqa: BLE001 — one bad request must not kill serving
            with self._counter_lock:
                self._errors += 1
            return 500, _json_bytes({"error": f"{type(e).__name__}: {e}"})
        finally:
            with self._idle:
                self._active -= 1
                if self._active == 0:
                    self._idle.notify_all()


class _Server(http.server.ThreadingHTTPServer):
    daemon_threads = True      # idle keep-alive connections must not pin exit
    block_on_close = False     # draining is explicit (shutdown()), not implicit
    request_queue_size = 128   # a client burst must not overflow the default
    #                            listen(5) backlog into connection resets


def _make_handler(service: RecommendationService):
    class Handler(http.server.BaseHTTPRequestHandler):
        protocol_version = "HTTP/1.1"  # keep-alive: load clients reuse sockets
        disable_nagle_algorithm = True  # headers+body are two send()s; Nagle
        #                                 would stall the body ~40ms per
        #                                 response behind the delayed ACK

        def log_message(self, *args):  # quiet: the service logs, not every hit
            pass

        def _respond(self, method: str) -> None:
            try:
                length = int(self.headers.get("Content-Length") or 0)
                body = self.rfile.read(length) if length else b""
                status, payload = service.handle(method, self.path, body)
            except Exception as e:  # noqa: BLE001
                status, payload = 500, _json_bytes({"error": str(e)})
            try:
                self.send_response(status)
                self.send_header("Content-Type", "application/json")
                self.send_header("Content-Length", str(len(payload)))
                if status == 503:
                    # shed/unfitted/draining: tell well-behaved clients how
                    # long to back off instead of hammering the queue
                    self.send_header("Retry-After", "1")
                self.end_headers()
                self.wfile.write(payload)
            except (BrokenPipeError, ConnectionResetError):
                pass  # client went away; nothing to do

        def do_GET(self):  # noqa: N802 — http.server API
            self._respond("GET")

        def do_POST(self):  # noqa: N802
            self._respond("POST")

    return Handler


# ---------------------------------------------------------------- warm start

def synthetic_observations(space: ConfigSpace, n_repeats: int = 2) -> List[dict]:
    """Deterministic knob-sweep observations (no storage I/O): workers and
    prefetch help with diminishing returns, larger batches amortize overhead.
    Enough cross-config signal for a real fit — the --smoke/--demo warm
    path and the serve benchmark both start from this."""
    rows: List[dict] = []
    for rep in range(n_repeats):
        for i, cand in enumerate(space.candidates()):
            w = cand.get("num_workers", 0)
            pf = cand.get("prefetch_depth", 1)
            b = cand.get("batch_size", 64)
            thr = 80.0 * (1 + 0.9 * w ** 0.7) * (1 + 0.15 * (pf - 1))
            thr *= (b / 64.0) ** 0.2
            thr *= 1 + 0.01 * ((i * 2654435761 + rep * 97) % 17 - 8) / 8.0
            rows.append({**cand, "file_size_mb": 64.0, "n_samples": 1000.0,
                         TARGET_NAME: thr})
    return rows


def warm_tuner_from_records(tuner: OnlineAutotuner, path: pathlib.Path) -> int:
    """Ingest a campaign/merged JSONL file and fit once; returns rows added."""
    from ..data.campaign import load_records

    n = tuner.ingest_records(load_records(path))
    tuner.maybe_refit()
    return n


# ---------------------------------------------------------------- smoke

def _http_json(conn: http.client.HTTPConnection, method: str, path: str,
               payload: Optional[dict] = None) -> Tuple[int, dict]:
    body = json.dumps(payload).encode() if payload is not None else None
    conn.request(method, path, body=body,
                 headers={"Content-Type": "application/json"} if body else {})
    resp = conn.getresponse()
    return resp.status, json.loads(resp.read())


def run_smoke(cfg: ServeConfig, progress=print) -> int:
    """Self-contained end-to-end check: warm-fit a synthetic dataset, serve,
    hit every endpoint through real HTTP, verify status + schema, drain."""
    space = ConfigSpace(batch_size=(16, 32, 64), num_workers=(0, 2, 4),
                        block_kb=(64, 256), n_threads=(1,),
                        prefetch_depth=(1, 2))
    tuner = OnlineAutotuner(space=space, min_observations=8, refit_every=8)
    tuner.seed_observations(synthetic_observations(space, n_repeats=1))
    tuner.maybe_refit()
    service = RecommendationService(tuner, cfg, progress=lambda m: progress(f"[serve] {m}"))
    service.start()
    failures: List[str] = []
    n_checks = 0

    def check(name, ok):
        nonlocal n_checks
        n_checks += 1
        progress(f"[smoke] {name}: {'ok' if ok else 'FAIL'}")
        if not ok:
            failures.append(name)

    try:
        conn = http.client.HTTPConnection(cfg.host, service.port, timeout=10)
        status, h = _http_json(conn, "GET", "/healthz")
        check("healthz", status == 200 and h["fitted"]
              and h["model_generation"] >= 1)
        ctx = {"file_size_mb": 64.0, "n_samples": 1000.0,
               "throughput_mb_s": 120.0}
        status, p = _http_json(conn, "POST", "/predict",
                               {"context": ctx, "config": {"batch_size": 32,
                                                           "num_workers": 2}})
        check("predict", status == 200 and p["predicted_throughput_mb_s"] > 0)
        status, r = _http_json(conn, "POST", "/recommend",
                               {"context": ctx, "top_k": 3})
        check("recommend", status == 200 and len(r["top"]) == 3
              and all("predicted_throughput_mb_s" in t for t in r["top"]))
        status, r2 = _http_json(conn, "POST", "/recommend",
                                {"context": dict(reversed(list(ctx.items()))),
                                 "top_k": 3})
        check("cache_order_insensitive", status == 200 and r2 == r)
        status, e = _http_json(conn, "GET", "/explain")
        check("explain", status == 200 and len(e["features"]) > 0)
        status, s = _http_json(conn, "GET", "/stats")
        cache_ok = (s["cache"]["hits"] >= 1 if cfg.cache_size > 0
                    else s["cache"]["hits"] == 0)
        check("stats", status == 200 and s["requests"].get("/recommend") == 2
              and cache_ok)
        conn.close()
    finally:
        service.shutdown()
    progress(f"[smoke] {'PASSED' if not failures else 'FAILED'} "
             f"({n_checks - len(failures)}/{n_checks} checks ok)")
    return 1 if failures else 0


# ---------------------------------------------------------------- CLI

def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.service.serve",
        description="Concurrent recommendation service over the I/O "
                    "predictor: batched /predict + /recommend scoring, "
                    "refit-aware response cache, hot model swap, optional "
                    "embedded tuning loop.",
    )
    add_tuning_args(ap)
    add_serve_args(ap, DEFAULT_SERVE_DIR)
    add_chaos_args(ap)
    args = ap.parse_args(argv)
    chaos_plan_from_args(args)

    cfg = ServeConfig(
        host=args.host, port=args.port, batching=not args.no_batch,
        max_batch=args.max_batch, batch_window_ms=args.batch_window_ms,
        max_queue=args.max_queue, deadline_ms=args.deadline_ms,
        cache_size=0 if args.no_cache else args.cache_size,
        top_k=args.top_k, out_dir=args.out_dir,
    )

    if args.smoke:
        return run_smoke(cfg)

    from .loop import ContinuousTuningLoop, LoopConfig, _format_status, \
        config_kwargs_from_args

    if args.status:
        state = LoopState(args.out_dir / "loop_state.jsonl")
        cycles = state.cycles()
        print(_format_status(cycles, state.corrupt_lines))
        return 0

    loop = None
    if args.loop:
        loop = ContinuousTuningLoop(LoopConfig(**config_kwargs_from_args(args)),
                                    progress=lambda m: print(f"[loop] {m}"))
        if args.force:
            loop.state.path.unlink(missing_ok=True)
            loop.merged_path.unlink(missing_ok=True)
            for p in loop._shard_files():
                p.unlink()
        tuner = loop.tuner
    else:
        tuner = OnlineAutotuner(
            refit_every=args.refit_every,
            min_observations=args.min_observations,
            gain_threshold=args.gain_threshold,
            drift_threshold=args.drift_threshold,
            model=args.model,
        )
    if args.warm_from is not None:
        n = warm_tuner_from_records(tuner, args.warm_from)
        print(f"[serve] warm start: {n} rows from {args.warm_from}, "
              f"fitted={tuner.fitted}")

    service = RecommendationService(tuner, cfg, loop=loop,
                                    progress=lambda m: print(f"[serve] {m}"))
    service.start()
    try:
        while True:
            time.sleep(1.0)
    except KeyboardInterrupt:
        print("[serve] draining...")
        service.shutdown()
    return 0


if __name__ == "__main__":
    sys.exit(main())
